"""End-to-end training driver: pushdown data pipeline -> model -> AdamW,
with checkpoints, auto-resume, and preemption handling.

    PYTHONPATH=src python examples/train_pushdown_pipeline.py             # demo
    PYTHONPATH=src python examples/train_pushdown_pipeline.py --preset 100m \
        --steps 300                                                       # full

The corpus query (quality filter + domain selection + shuffle-to-rank) is
executed through the SAME adaptive-pushdown engine the OLAP benchmarks use:
each corpus partition becomes a pushdown request, and Algorithm 1 decides
per partition whether the storage host runs the filter/pack/shuffle or
pushes raw data back (where the identical operators run as Pallas kernels).
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.core.cost import StorageResources
from repro.data.pipeline import CorpusQuery, PushdownDataPipeline, synth_corpus
from repro.train import optimizer as opt_lib
from repro.train.loop import TrainConfig, train

PRESETS = {
    # ~3M params: finishes in ~2 min on one CPU core (the demo default)
    "demo": dict(layers=2, d_model=128, heads=4, d_ff=512, vocab=4096,
                 seq=128, batch=8, accum=2),
    # ~25M params
    "25m": dict(layers=8, d_model=384, heads=8, d_ff=1536, vocab=16384,
                seq=256, batch=8, accum=2),
    # ~110M params (GPT-2-small-ish) — the "train a ~100M model" driver;
    # plan several hours on CPU, minutes on one TPU host
    "100m": dict(layers=12, d_model=640, heads=10, d_ff=2560, vocab=32768,
                 seq=512, batch=8, accum=4),
}


def build_cfg(p) -> ModelConfig:
    base = get_config("olmo-1b", reduced=True)
    return dataclasses.replace(
        base, name=f"train-example-{p['d_model']}", num_layers=p["layers"],
        d_model=p["d_model"], num_heads=p["heads"], num_kv_heads=p["heads"],
        d_ff=p["d_ff"], vocab_size=p["vocab"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_example")
    ap.add_argument("--storage-power", type=float, default=1.0,
                    help="emulated storage-host load (0,1]")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = build_cfg(p)
    from repro.models import api
    print(f"model: {cfg.name}  params={api.count_params(cfg)/1e6:.1f}M")

    corpus = synth_corpus(num_partitions=8, docs_per_part=128,
                          doc_len=p["seq"], vocab=p["vocab"], hosts=2)
    query = CorpusQuery(min_quality=0.25, domains=(0, 1, 2, 3, 4, 5),
                        seq_len=p["seq"], global_batch=p["batch"],
                        accum=p["accum"], dp_ranks=2)
    pipe = PushdownDataPipeline(
        corpus, query,
        res=StorageResources(storage_power=args.storage_power))
    print("ingest arbitration:", pipe.stats())

    tcfg = TrainConfig(
        steps=args.steps, ckpt_every=max(10, args.steps // 4),
        ckpt_dir=args.ckpt_dir, log_every=max(1, args.steps // 10),
        opt=opt_lib.AdamWConfig(lr=3e-3, warmup_steps=max(2, args.steps // 10),
                                total_steps=args.steps))
    out = train(cfg, iter(pipe), tcfg,
                hooks=lambda s, m: print(
                    f"  step {s:4d} loss {m['loss']:.4f} "
                    f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f} "
                    f"({m['wall_s']:.0f}s)"))
    h = out["history"]
    print(f"\ntrained {out['final_step']} steps: loss "
          f"{h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}; checkpoints in "
          f"{args.ckpt_dir} (re-run to auto-resume)")


if __name__ == "__main__":
    main()
