"""Batched serving example: prefill + continuous decode over request waves.

    PYTHONPATH=src python examples/serve_batch.py [--arch olmo-1b]

Loads a reduced-config model (random weights — the point is the serving
machinery: left-padded batched prefill, KV-cache splicing, per-family cache
layouts incl. SSM states and sliding-window rings) and serves a queue of
batched requests.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.models import api
from repro.serve.engine import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b",
                    choices=[a for a in ARCH_IDS
                             if a not in ("whisper-small",
                                          "llava-next-mistral-7b")])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    print(f"arch={args.arch} (reduced: {cfg.num_layers}L d={cfg.d_model}, "
          f"family={cfg.family})")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, ServeConfig(max_batch=4, max_len=96))

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, int(rng.integers(4, 24)))
               .astype(np.int32) for _ in range(args.requests)]
    t0 = time.time()
    outs = eng.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    for i, (p, o) in enumerate(zip(prompts, outs)):
        print(f"  req{i}: prompt[{len(p)}] -> {o}")
    tok = sum(len(o) for o in outs)
    print(f"{tok} tokens in {dt:.1f}s ({tok/dt:.1f} tok/s on 1 CPU core, "
          f"waves of {4})")


if __name__ == "__main__":
    main()
