"""Quickstart: adaptive computation pushdown on TPC-H in ~30 seconds.

    PYTHONPATH=src python examples/quickstart.py

Walks the paper's three contributions end to end:
1. Adaptive pushdown (Algorithm 1) vs No-pushdown / Eager across storage
   load levels, on real query executions (results verified identical).
2. Selection-bitmap pushdown: ship 1 bit/row instead of filtered columns.
3. Distributed-data-shuffle pushdown: partition at the storage node,
   route straight to the target compute node.

Queries come from ``repro.compiler.compile_query``: each is a logical-plan
IR that the compiler splits into a storage frontier + compute residual by
the paper's §4.1 amenability principle (docs/compiler.md).
"""
import numpy as np

from repro.compiler import compile_query
from repro.core import engine
from repro.core.bitmap import CacheState, rewrite_all
from repro.core.cost import StorageResources
from repro.core.shuffle import ShuffleConfig, run_shuffle
from repro.core.simulator import MODE_ADAPTIVE, MODE_EAGER, MODE_NO_PUSHDOWN
from repro.queryproc import tpch

print("building TPC-H catalog (sf=2, 2 storage nodes)...")
cat = tpch.build_catalog(sf=2.0, num_nodes=2, rows_per_partition=2_000)

# ---------------------------------------------------- 1. adaptive pushdown
print("\n== Adaptive pushdown: Q14, t_total normalized to No-pushdown ==")
q = compile_query("Q14")
print(f"{'power':>6} {'eager':>7} {'adaptive':>9} {'admitted':>9}")
for power in (1.0, 0.5, 0.25, 0.12, 0.06):
    res = StorageResources(storage_power=power)
    runs = {m: engine.run_query(q, cat, engine.EngineConfig(res=res, mode=m))
            for m in (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE)}
    npd = runs[MODE_NO_PUSHDOWN].t_total
    a = runs[MODE_ADAPTIVE]
    assert engine.results_equal(a.result, runs[MODE_NO_PUSHDOWN].result)
    print(f"{power:>6} {runs[MODE_EAGER].t_total/npd:>7.2f} "
          f"{a.t_total/npd:>9.2f} {a.n_admitted:>4}/{len(a.requests)}")
print("(eager degrades when the storage layer is loaded; the arbitrator's "
      "pushback\n mechanism keeps adaptive at or below both baselines)")

# ------------------------------------------------ 2. selection bitmap
print("\n== Selection-bitmap pushdown: Q14, output columns cached ==")
cfg = engine.EngineConfig(mode=MODE_EAGER)
for sel in (0.2, 0.5, 0.9):
    qs = compile_query("Q14", fact_selectivity=sel)
    reqs = engine.plan_requests(qs, cat)
    base = engine.run_query(qs, cat, cfg, requests=reqs)
    cache = CacheState()
    cache.cache_columns("lineitem", {"l_partkey", "l_extendedprice",
                                     "l_discount"})
    rw, met = rewrite_all(reqs, cache)
    bm = engine.run_query(qs, cat, cfg, requests=rw)
    t_b = base.t_pushable + base.net_bytes / cfg.compute_bw
    t_m = bm.t_pushable + bm.net_bytes / cfg.compute_bw
    saved = 1 - met["net_bitmap"] / met["net_baseline"]
    print(f"  selectivity {sel}: {t_b/t_m:.2f}x faster, "
          f"{saved*100:.0f}% network saved (bitmaps are 1 bit/row)")

# ------------------------- 2b. cost-based cuts + online s_out correction
print("\n== Cost-calibrated frontier + online s_out correction ==")
from repro.compiler import compile_query_costed  # noqa: E402
from repro.core.cost import CardinalityCorrector  # noqa: E402

# Q19's multi-table join predicate lowers onto both tables (the part
# disjunction as a pushed conjunct, the l_quantity bound as the §4.2
# verdict-bitmap exchange) — strictly fewer bytes, identical result.
q19 = compile_query_costed("Q19", cat)
rm = engine.run_query(compile_query("Q19"), cat, cfg)
rc = engine.run_query(q19.query, cat, cfg)
assert engine.results_equal(rm.result, rc.result)
print(f"  Q19 costed frontier {q19.frontier_signature()}\n"
      f"      net bytes {rm.real_net_bytes} -> {rc.real_net_bytes} "
      f"({100 * (1 - rc.real_net_bytes / rm.real_net_bytes):.0f}% saved)")

# Q4: the static model overestimates the derived column (8 B/row vs two
# narrow dates), so the uncorrected chooser cuts at the scan. Running
# the maximal plan with a corrector observes the real bytes — the
# corrected chooser flips the cut back to the measured-truth frontier.
corr = CardinalityCorrector()
engine.run_query(compile_query("Q4"), cat,
                 engine.EngineConfig(mode=MODE_EAGER, corrector=corr))
before = compile_query_costed("Q4", cat).frontier_signature()["lineitem"]
after = compile_query_costed("Q4", cat,
                             corrector=corr).frontier_signature()["lineitem"]
print(f"  Q4 lineitem cut, model-only -> measured-feedback: "
      f"{before!r} -> {after!r}")
assert before == "scan" and after == "scan+derive"

# ---------------------------------------------- 3. shuffle pushdown
print("\n== Distributed shuffle pushdown: 4 compute nodes ==")
scfg = ShuffleConfig(num_compute_nodes=4)
for qid in ("Q7", "Q14"):
    qq = compile_query(qid)
    c4 = engine.EngineConfig(mode=MODE_EAGER, num_compute_nodes=4)
    basep = run_shuffle(qq, cat, c4, scfg, pushdown=False)
    push = run_shuffle(qq, cat, c4, scfg, pushdown=True)
    print(f"  {qid}: {basep.t_total/push.t_total:.2f}x vs baseline pushdown; "
          f"compute-fabric traffic {basep.cross_compute_bytes/2**20:.1f} MiB "
          f"-> {push.cross_compute_bytes/2**20:.1f} MiB")

print("\ndone — see benchmarks/ for the full paper-figure suite.")
