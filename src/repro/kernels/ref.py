"""Pure-jnp oracles for the Pallas kernels.

These mirror the storage layer's numpy operators (repro.queryproc.operators)
1:1 — tests cross-check kernel == ref == numpy so the pushed-back on-device
operators provably compute the same thing the storage layer would have.
"""
from __future__ import annotations

import jax.numpy as jnp

KNUTH = jnp.uint32(2654435761)


def pack_bitmap(mask: jnp.ndarray) -> jnp.ndarray:
    """(R,) bool -> (R/32,) uint32, little-endian bit order. R % 32 == 0."""
    m = mask.reshape(-1, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))
    return (m * weights).sum(axis=1, dtype=jnp.uint32)


def unpack_bitmap(words: jnp.ndarray, n: int) -> jnp.ndarray:
    bits = (words[:, None] >> jnp.arange(32, dtype=jnp.uint32)) & jnp.uint32(1)
    return bits.reshape(-1)[:n].astype(bool)


def predicate_bitmap(cols: dict, pred_fn) -> jnp.ndarray:
    """Evaluate pred_fn over full columns, emit a packed bitmap."""
    return pack_bitmap(pred_fn(cols))


def bitmap_apply(words: jnp.ndarray, col: jnp.ndarray, block: int = 8192):
    """Late materialization: masked column (zeros at dropped rows) plus a
    per-block selected-row count. col: (R,), R % block == 0."""
    keep = unpack_bitmap(words, col.shape[0])
    masked = jnp.where(keep, col, jnp.zeros((), col.dtype))
    counts = keep.reshape(-1, block).sum(axis=1, dtype=jnp.int32)
    return masked, counts


def grouped_agg(ids: jnp.ndarray, values: jnp.ndarray, num_groups: int):
    """(R,) int32 ids in [0, G), (R,) f32 values -> (sums (G,), counts (G,))."""
    onehot = (ids[:, None] == jnp.arange(num_groups)[None, :])
    sums = (onehot * values[:, None].astype(jnp.float32)).sum(axis=0)
    counts = onehot.sum(axis=0, dtype=jnp.int32)
    return sums, counts


def fused_scan_agg(cols: dict, pred_fn, ids: jnp.ndarray,
                   values: jnp.ndarray, num_groups: int):
    """Predicate -> mask -> grouped agg in one jnp expression: sums/counts
    over rows passing pred_fn (None = all). Masking is arithmetic (failing
    rows contribute 0), matching the fused kernel exactly."""
    keep = (pred_fn(cols) if pred_fn is not None
            else jnp.ones(ids.shape, bool)).astype(jnp.float32)
    onehot = (ids[:, None] == jnp.arange(num_groups)[None, :]
              ).astype(jnp.float32)
    sums = ((values.astype(jnp.float32) * keep)[:, None] * onehot).sum(axis=0)
    counts = (keep[:, None] * onehot).sum(axis=0).astype(jnp.int32)
    return sums, counts


def fused_scan_shuffle(cols: dict, pred_fn, keys: jnp.ndarray,
                       num_parts: int):
    """Predicate -> packed bitmap -> hash partition in one jnp expression:
    (words (R/32,) u32, pids (R,) i32, surviving-rows hist (P,) i32).
    R % 32 == 0; pred_fn=None means all rows survive."""
    keep = (pred_fn(cols) if pred_fn is not None
            else jnp.ones(keys.shape, bool))
    words = pack_bitmap(keep)
    h = keys.astype(jnp.uint32) * KNUTH
    pid = ((h >> jnp.uint32(16)) % jnp.uint32(num_parts)).astype(jnp.int32)
    onehot = pid[:, None] == jnp.arange(num_parts)[None, :]
    hist = (onehot & keep[:, None]).sum(axis=0, dtype=jnp.int32)
    return words, pid, hist


def hash_partition(keys: jnp.ndarray, num_parts: int, block: int = 8192):
    """Knuth multiplicative hash -> (pids (R,) int32, hist (R/block, P))."""
    h = keys.astype(jnp.uint32) * KNUTH
    pid = ((h >> jnp.uint32(16)) % jnp.uint32(num_parts)).astype(jnp.int32)
    onehot = (pid.reshape(-1, block)[:, :, None]
              == jnp.arange(num_parts)[None, None, :])
    hist = onehot.sum(axis=1, dtype=jnp.int32)
    return pid, hist
