"""Pallas TPU kernels for the pushed-back storage operators.

predicate_bitmap / bitmap_apply / grouped_agg / hash_partition /
fused_scan_agg (predicate -> bitmap-apply -> grouped-agg in one pass, no
materialized intermediates) — each with an ``ops.py`` jit wrapper and a
``ref.py`` pure-jnp oracle; tests sweep shapes x dtypes in interpret mode
against both ref.py and the numpy storage engine.
"""
from repro.kernels import ops, ref  # noqa: F401
