"""Pallas TPU kernels for the pushed-back storage operators.

predicate_bitmap / bitmap_apply / grouped_agg / hash_partition /
fused_scan_agg (predicate -> mask -> grouped agg, one pass, no materialized
intermediates) / fused_scan_shuffle (predicate -> packed bitmap -> hash
partition, one pass) — each with an ``ops.py`` jit wrapper and a ``ref.py``
pure-jnp oracle; tests sweep shapes x dtypes in interpret mode against both
ref.py and the numpy storage engine.

The padded/jit'd op-level entry points are re-exported here — import
``from repro.kernels import bitmap_apply`` (etc.) rather than reaching into
the submodules; the submodules hold the raw ``pallas_call`` bodies with
their exact-multiple shape preconditions.
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import (bitmap_apply, compile_predicate,  # noqa: F401
                               fused_scan_agg, fused_scan_shuffle,
                               grouped_agg, hash_partition, predicate_bitmap,
                               predicate_bitmap_np)
