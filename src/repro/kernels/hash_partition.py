"""Shuffle partition function + histogram (Pallas TPU).

The partitioning half of distributed-data-shuffle pushdown (paper §4.2,
Fig 5): assign each row its destination compute node and count per-block
occupancy. Knuth multiplicative hashing runs in uint32 VREG lanes; the
per-block histogram is a one-hot MXU contraction (TPUs have no scatter
unit — the actual reorder is an XLA sort keyed on the partition id, or on
the host; the paper's storage nodes buffer per-target anyway).

The (R/block, P) histogram doubles as the *position vector* summary the
paper uses for cached-data interop: log2(n) bits/row suffice to route
cached columns without re-reading keys.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8192
KNUTH = 2654435761


def _kernel(num_parts: int, keys_ref, pid_ref, hist_ref):
    keys = keys_ref[...].astype(jnp.uint32)
    h = keys * jnp.uint32(KNUTH)                       # wraps mod 2^32
    pid = ((h >> jnp.uint32(16)) % jnp.uint32(num_parts)).astype(jnp.int32)
    pid_ref[...] = pid
    onehot = (pid[:, None] == jnp.arange(num_parts)[None, :]
              ).astype(jnp.float32)
    ones = jnp.dot(jnp.ones((1, pid.shape[0]), jnp.float32), onehot,
                   preferred_element_type=jnp.float32)[0]
    hist_ref[...] = ones.astype(jnp.int32)[None, :]


def hash_partition(keys: jax.Array, num_parts: int,
                   block: int = DEFAULT_BLOCK, interpret: bool = True):
    """keys: (R,) int32/uint32, R % block == 0.
    Returns (pids (R,) int32, hist (R/block, P) int32)."""
    R = keys.shape[0]
    assert R % block == 0, (R, block)
    grid = (R // block,)
    return pl.pallas_call(
        functools.partial(_kernel, num_parts),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1, num_parts), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R,), jnp.int32),
                   jax.ShapeDtypeStruct((R // block, num_parts), jnp.int32)],
        interpret=interpret,
    )(keys)
