"""Fused predicate -> bitmap-apply -> grouped partial agg (Pallas TPU).

The storage-side hot path of a pushed ``filter + grouped-agg`` plan as ONE
kernel: per row tile, the compiled predicate tree evaluates branch-free
over VREG-resident column tiles (as in ``predicate_bitmap``), the boolean
row mask gates the values and the one-hot group matrix (as in
``bitmap_apply``'s late materialization — no compacted intermediate is ever
built), and masked sums/counts accumulate on the MXU into revisited output
blocks (as in ``grouped_agg``). Fusion removes the two HBM round-trips the
three-kernel pipeline pays between predicate, apply, and aggregate —
exactly the ISSUE's "no materialized intermediates" requirement, and the
Pallas mirror of the numpy batch executor (``core.executor``).

Masking is arithmetic, not control flow: a failing row multiplies to 0.0 in
both the value vector and the count contraction, so SUM semantics are exact
(0 contribution == filtered out). Padding rows carry a poison group id
(== num_groups) whose one-hot column is sliced off by the wrapper.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8192


def _kernel(pred_fn: Callable, names: Sequence[str], num_groups: int, *refs):
    *col_refs, ids_ref, val_ref, sum_ref, cnt_ref = refs
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    cols = {n: r[...] for n, r in zip(names, col_refs)}
    keep = (pred_fn(cols) if pred_fn is not None
            else jnp.ones(ids_ref.shape, bool)).astype(jnp.float32)  # (B,)
    ids = ids_ref[...]                                               # (B,)
    vals = val_ref[...].astype(jnp.float32) * keep                   # masked
    onehot = (ids[:, None] == jnp.arange(num_groups)[None, :]
              ).astype(jnp.float32)                                  # (B, G)
    # MXU contractions: (1, B) @ (B, G) — masked sum and masked count
    sums = jnp.dot(vals[None, :], onehot,
                   preferred_element_type=jnp.float32)[0]            # (G,)
    cnts = jnp.dot(keep[None, :], onehot,
                   preferred_element_type=jnp.float32)[0]
    sum_ref[...] += sums
    cnt_ref[...] += cnts.astype(jnp.int32)


def fused_scan_agg(cols, pred_fn: Callable, ids: jax.Array, values: jax.Array,
                   num_groups: int, block: int = DEFAULT_BLOCK,
                   interpret: bool = True):
    """cols: dict of equal-length 1-D predicate input arrays; ids: (R,)
    int32 in [0, num_groups); values: (R,). R % block == 0.
    Returns (sums (G,) f32, counts (G,) int32) over rows passing pred_fn.
    ``pred_fn=None`` means all rows pass (plain grouped agg)."""
    names = list(cols)
    arrs = [cols[n] for n in names]
    R = ids.shape[0]
    assert R % block == 0, (R, block)
    grid = (R // block,)
    in_specs = ([pl.BlockSpec((block,), lambda i: (i,)) for _ in arrs]
                + [pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,))])
    return pl.pallas_call(
        functools.partial(_kernel, pred_fn, names, num_groups),
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((num_groups,), lambda i: (0,)),
                   pl.BlockSpec((num_groups,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((num_groups,), jnp.float32),
                   jax.ShapeDtypeStruct((num_groups,), jnp.int32)],
        interpret=interpret,
    )(*arrs, ids, values)
