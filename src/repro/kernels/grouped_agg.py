"""Grouped aggregation via one-hot matmul (Pallas TPU).

The pushed-back form of grouped-aggregation pushdown (paper Table 1).
Hash tables — the CPU storage engine's implementation — do not vectorize
on a systolic array; the TPU-native formulation builds a per-tile one-hot
group matrix and contracts it against the values on the MXU:

    sums_partial (G,)  =  values (1, BLOCK) @ onehot (BLOCK, G)

accumulated across grid steps in the output block (same output block for
every step — a revisited accumulator, the standard Pallas reduction
pattern). G is capped by the tile budget (G <= 4096 comfortably fits VMEM);
larger group counts fall back to partial-agg + host merge, exactly like the
paper's two-phase S3-Select workaround — except one phase here is free.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8192


def _kernel(num_groups: int, ids_ref, val_ref, sum_ref, cnt_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        sum_ref[...] = jnp.zeros_like(sum_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    ids = ids_ref[...]                                     # (block,) int32
    vals = val_ref[...].astype(jnp.float32)                # (block,)
    onehot = (ids[:, None] == jnp.arange(num_groups)[None, :]
              ).astype(jnp.float32)                        # (block, G)
    # MXU contraction: (1, block) @ (block, G)
    part = jnp.dot(vals[None, :], onehot,
                   preferred_element_type=jnp.float32)[0]  # (G,)
    ones = jnp.dot(jnp.ones((1, ids.shape[0]), jnp.float32), onehot,
                   preferred_element_type=jnp.float32)[0]
    sum_ref[...] += part
    cnt_ref[...] += ones.astype(jnp.int32)


def grouped_agg(ids: jax.Array, values: jax.Array, num_groups: int,
                block: int = DEFAULT_BLOCK, interpret: bool = True):
    """ids: (R,) int32 in [0, num_groups); values: (R,).
    Returns (sums (G,) f32, counts (G,) int32). R % block == 0."""
    R = ids.shape[0]
    assert R % block == 0, (R, block)
    grid = (R // block,)
    return pl.pallas_call(
        functools.partial(_kernel, num_groups),
        grid=grid,
        in_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((num_groups,), lambda i: (0,)),
                   pl.BlockSpec((num_groups,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((num_groups,), jnp.float32),
                   jax.ShapeDtypeStruct((num_groups,), jnp.int32)],
        interpret=interpret,
    )(ids, values)
