"""Fused predicate evaluation -> packed selection bitmap (Pallas TPU).

TPU adaptation of the paper's §4.2 selection-bitmap operator: instead of a
row-at-a-time branchy filter (the C++ storage engine's form), the predicate
tree is evaluated branch-free over VREG-resident column tiles, and the
resulting boolean lane values are packed 32 rows/word with a
weighted-sum-over-lanes (a (R/32, 32) x (32,) contraction — disjoint powers
of two make SUM == OR, and uint32 wraparound is exact).

The predicate arrives as a *traced closure* over the column tile dict —
the same Expr tree that the numpy storage path evaluates is compiled into
the kernel body by ``compile_predicate`` below, so both sides share one
plan representation (the paper ships serialized plans, not SQL).

Block layout: rows are processed in BLOCK-row tiles; each tile's columns
live in VMEM ((BLOCK,) f32 = 32 KiB at the default 8192 — a handful of
columns fit comfortably in the ~16 MiB VMEM budget).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.queryproc import expressions as ex

DEFAULT_BLOCK = 8192


def _kernel(pred_fn: Callable, names: Sequence[str], *refs):
    *col_refs, out_ref = refs
    cols = {n: r[...] for n, r in zip(names, col_refs)}
    mask = pred_fn(cols)                          # (BLOCK,) bool
    m = mask.reshape(-1, 32).astype(jnp.uint32)   # 32 rows per word
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, :]
    out_ref[...] = (m * weights).sum(axis=1, dtype=jnp.uint32)


def predicate_bitmap(cols: Dict[str, jax.Array], pred_fn: Callable,
                     block: int = DEFAULT_BLOCK, interpret: bool = True
                     ) -> jax.Array:
    """cols: dict of equal-length 1-D arrays (R % block == 0).
    Returns packed (R/32,) uint32 bitmap."""
    names = list(cols)
    arrs = [cols[n] for n in names]
    R = arrs[0].shape[0]
    assert R % block == 0 and block % 32 == 0, (R, block)
    grid = (R // block,)
    in_specs = [pl.BlockSpec((block,), lambda i: (i,)) for _ in arrs]
    out_spec = pl.BlockSpec((block // 32,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_kernel, pred_fn, names),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((R // 32,), jnp.uint32),
        interpret=interpret,
    )(*arrs)


# ---------------------------------------------------------------- compiler
def compile_predicate(expr: ex.Expr) -> Callable:
    """Expr tree -> branch-free jnp closure over a column-tile dict.
    The same tree the numpy storage path evaluates (one plan, two engines)."""
    if isinstance(expr, ex.Cmp):
        op = {"<=": jnp.less_equal, "<": jnp.less, ">=": jnp.greater_equal,
              ">": jnp.greater, "==": jnp.equal}[expr.op]
        name, v = expr.col.name, expr.value
        if isinstance(v, ex.Col):  # column-column compare (e.g. Q4-style)
            rname = v.name
            return lambda cols: op(cols[name], cols[rname])
        return lambda cols: op(cols[name], v)
    if isinstance(expr, ex.In):
        name, vals = expr.col.name, expr.values
        def fn(cols):
            c = cols[name]
            acc = jnp.zeros(c.shape, bool)
            for v in vals:
                acc = acc | (c == v)
            return acc
        return fn
    if isinstance(expr, ex.And):
        l, r = compile_predicate(expr.left), compile_predicate(expr.right)
        return lambda cols: l(cols) & r(cols)
    if isinstance(expr, ex.Or):
        l, r = compile_predicate(expr.left), compile_predicate(expr.right)
        return lambda cols: l(cols) | r(cols)
    raise TypeError(expr)
