"""Public jit'd wrappers for the Pallas kernels.

Handle the unglamorous edges: pad to block multiples (padding rows carry a
poison group id / always-false predicate so results are exact), dtype
guards, and un-padding. ``interpret=True`` everywhere on this CPU
container; on a real TPU the same calls lower natively.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bitmap_apply as _ba
from repro.kernels import fused_scan_agg as _fsa
from repro.kernels import fused_scan_shuffle as _fss
from repro.kernels import grouped_agg as _ga
from repro.kernels import hash_partition as _hp
from repro.kernels import predicate_bitmap as _pb
from repro.kernels.predicate_bitmap import compile_predicate  # noqa: F401 re-export

DEFAULT_BLOCK = 8192


def _pad_to(x: jax.Array, mult: int, fill=0):
    R = x.shape[0]
    pad = (-R) % mult
    if pad == 0:
        return x, R
    return jnp.concatenate([x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)]), R


def predicate_bitmap(cols: Dict[str, jax.Array], pred_fn: Callable,
                     block: int = DEFAULT_BLOCK, interpret: bool = True):
    """Packed (ceil(R/32),) uint32 bitmap of pred_fn over the columns.
    Padding rows evaluate through pred_fn but are masked off the result."""
    R = next(iter(cols.values())).shape[0]
    padded = {}
    for k, v in cols.items():
        assert v.shape == (R,), (k, v.shape)
        padded[k], _ = _pad_to(v.astype(jnp.float32) if v.dtype == jnp.float64
                               else v, block)
    words = _pb.predicate_bitmap(padded, pred_fn, block, interpret)
    # mask bits beyond R (padding rows may satisfy the predicate)
    n_words = -(-R // 32)
    words = words[:max(n_words, 1)] if R else words[:0]
    tail_bits = R - 32 * (n_words - 1)
    if R and tail_bits < 32:
        mask = jnp.uint32((1 << tail_bits) - 1)
        words = words.at[-1].set(words[-1] & mask)
    return words


def bitmap_apply(words: jax.Array, col: jax.Array,
                 block: int = DEFAULT_BLOCK, interpret: bool = True):
    """(masked col (R,), total selected count). Accepts any R."""
    col_p, R = _pad_to(col, block)
    words_p, _ = _pad_to(words, col_p.shape[0] // 32)
    masked, counts = _ba.bitmap_apply(words_p, col_p, block, interpret)
    return masked[:R], counts.sum()


def grouped_agg(ids: jax.Array, values: jax.Array, num_groups: int,
                block: int = DEFAULT_BLOCK, interpret: bool = True):
    """(sums (G,) f32, counts (G,) int32); padding rows get id == G and an
    extra scratch group that is dropped."""
    ids_p, R = _pad_to(ids.astype(jnp.int32), block, fill=num_groups)
    vals_p, _ = _pad_to(values.astype(jnp.float32), block)
    sums, counts = _ga.grouped_agg(ids_p, vals_p, num_groups + 1, block,
                                   interpret)
    return sums[:num_groups], counts[:num_groups]


def fused_scan_agg(cols: Dict[str, jax.Array], pred_fn: Optional[Callable],
                   ids: jax.Array, values: jax.Array, num_groups: int,
                   block: int = DEFAULT_BLOCK, interpret: bool = True):
    """Fused predicate -> mask -> grouped agg: (sums (G,) f32, counts (G,)
    int32) over rows passing pred_fn. Padding rows carry the poison group
    id == G (their one-hot column is an extra scratch group, dropped), so
    they cannot contribute even when the padded predicate holds."""
    ids_p, R = _pad_to(ids.astype(jnp.int32), block, fill=num_groups)
    vals_p, _ = _pad_to(values.astype(jnp.float32), block)
    padded = {}
    for k, v in cols.items():
        assert v.shape == (R,), (k, v.shape)
        padded[k], _ = _pad_to(v.astype(jnp.float32) if v.dtype == jnp.float64
                               else v, block)
    sums, counts = _fsa.fused_scan_agg(padded, pred_fn, ids_p, vals_p,
                                       num_groups + 1, block, interpret)
    return sums[:num_groups], counts[:num_groups]


def fused_scan_shuffle(cols: Dict[str, jax.Array], pred_fn: Optional[Callable],
                       keys: jax.Array, num_parts: int,
                       block: int = DEFAULT_BLOCK, interpret: bool = True):
    """Fused predicate -> packed bitmap -> hash partition: (packed bitmap
    (ceil(R/32),) uint32, pids (R,) int32, surviving-rows-per-target hist
    (P,) int32) in one pass. A validity lane zeroes padding rows inside the
    kernel, so no tail-word masking or histogram subtraction is needed —
    pad rows can neither set a bit nor count toward a target."""
    R = keys.shape[0]
    keys_p, _ = _pad_to(keys, block)
    valid_p, _ = _pad_to(jnp.ones(R, jnp.int32), block)
    padded = {}
    for k, v in cols.items():
        assert v.shape == (R,), (k, v.shape)
        padded[k], _ = _pad_to(v.astype(jnp.float32) if v.dtype == jnp.float64
                               else v, block)
    words, pids, hist = _fss.fused_scan_shuffle(padded, pred_fn, keys_p,
                                                valid_p, num_parts, block,
                                                interpret)
    n_words = -(-R // 32)
    return (words[:n_words] if R else words[:0], pids[:R],
            hist.sum(axis=0))


def hash_partition(keys: jax.Array, num_parts: int,
                   block: int = DEFAULT_BLOCK, interpret: bool = True):
    """(pids (R,) int32, hist (P,) int32). Padding keys hash somewhere but
    are excluded from the histogram by subtraction."""
    keys_p, R = _pad_to(keys, block)
    pids, hist = _hp.hash_partition(keys_p, num_parts, block, interpret)
    hist = hist.sum(axis=0)
    pad = keys_p.shape[0] - R
    if pad:
        pad_pids = pids[R:]
        pad_hist = (pad_pids[:, None] == jnp.arange(num_parts)[None, :]
                    ).sum(axis=0, dtype=jnp.int32)
        hist = hist - pad_hist
    return pids[:R], hist


# ------------------------------------------------------- numpy conveniences
def predicate_bitmap_np(cols: Dict[str, np.ndarray], expr) -> np.ndarray:
    """Expr tree + numpy columns -> packed bitmap as numpy (storage interop)."""
    fn = compile_predicate(expr)
    jcols = {k: jnp.asarray(v.astype(np.float32) if v.dtype == np.float64
                            else v) for k, v in cols.items()}
    return np.asarray(predicate_bitmap(jcols, fn))
