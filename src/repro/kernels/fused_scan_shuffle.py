"""Fused predicate -> packed bitmap -> hash partition (Pallas TPU).

The storage-side hot path of a pushed filter + shuffle (or bitmap-exchange)
chain as ONE kernel — the device mirror of the numpy batch executor's aux
emission (``core.executor._emit_aux``). Per row tile:

- the compiled predicate tree evaluates branch-free over VREG-resident
  column tiles (as in ``predicate_bitmap``),
- the boolean row mask packs 32 rows/word with the weighted-sum-over-lanes
  contraction (disjoint powers of two make SUM == OR),
- the shuffle key hashes to its target compute node in uint32 lanes (as in
  ``hash_partition``),
- and a mask-gated one-hot MXU contraction counts the *surviving* rows per
  target — the per-target output sizes the storage node's pull buffers
  need (§4.2), for free in the same pass.

Fusion removes the two HBM round-trips the three-kernel pipeline
(``predicate_bitmap`` -> ``bitmap_apply`` -> ``hash_partition``) pays
between predicate, apply, and partition.

A ``valid`` lane (1 real row / 0 padding) rides along with the columns so
padding rows can never set a bitmap bit or count toward a target — the
wrapper needs no tail-word masking and no histogram subtraction.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8192
KNUTH = 2654435761


def _kernel(pred_fn: Optional[Callable], names: Sequence[str],
            num_parts: int, *refs):
    *col_refs, key_ref, valid_ref, words_ref, pid_ref, hist_ref = refs
    cols = {n: r[...] for n, r in zip(names, col_refs)}
    keep = (pred_fn(cols) if pred_fn is not None
            else jnp.ones(key_ref.shape, bool))
    keep = keep & (valid_ref[...] > 0)                        # (B,) bool
    # pack: 32 rows/word, little-endian bit order (== np.packbits)
    m = keep.reshape(-1, 32).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(32, dtype=jnp.uint32))[None, :]
    words_ref[...] = (m * weights).sum(axis=1, dtype=jnp.uint32)
    # hash: Knuth multiplicative, wraps mod 2^32 in uint32 lanes
    keys = key_ref[...].astype(jnp.uint32)
    h = keys * jnp.uint32(KNUTH)
    pid = ((h >> jnp.uint32(16)) % jnp.uint32(num_parts)).astype(jnp.int32)
    pid_ref[...] = pid
    # per-target survivor count: mask-gated (1, B) @ (B, P) MXU contraction
    onehot = (pid[:, None] == jnp.arange(num_parts)[None, :]
              ).astype(jnp.float32)
    hist = jnp.dot(keep.astype(jnp.float32)[None, :], onehot,
                   preferred_element_type=jnp.float32)[0]
    hist_ref[...] = hist.astype(jnp.int32)[None, :]


def fused_scan_shuffle(cols, pred_fn: Optional[Callable], keys: jax.Array,
                       valid: jax.Array, num_parts: int,
                       block: int = DEFAULT_BLOCK, interpret: bool = True):
    """cols: dict of equal-length 1-D predicate input arrays; keys: (R,)
    shuffle key; valid: (R,) 1/0 row-validity lane. R % block == 0,
    block % 32 == 0. Returns (packed bitmap (R/32,) uint32, pids (R,)
    int32, surviving-rows-per-target hist (R/block, P) int32).
    ``pred_fn=None`` means every valid row survives."""
    names = list(cols)
    arrs = [cols[n] for n in names]
    R = keys.shape[0]
    assert R % block == 0 and block % 32 == 0, (R, block)
    grid = (R // block,)
    in_specs = ([pl.BlockSpec((block,), lambda i: (i,)) for _ in arrs]
                + [pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,))])
    return pl.pallas_call(
        functools.partial(_kernel, pred_fn, names, num_parts),
        grid=grid,
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block // 32,), lambda i: (i,)),
                   pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1, num_parts), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((R // 32,), jnp.uint32),
                   jax.ShapeDtypeStruct((R,), jnp.int32),
                   jax.ShapeDtypeStruct((R // block, num_parts), jnp.int32)],
        interpret=interpret,
    )(*arrs, keys, valid)
