"""Apply a packed selection bitmap to a column (Pallas TPU).

The compute-layer half of selection-bitmap pushdown (paper §4.2, Figs 3/4):
a bitmap shipped across the network filters a *device-cached* column.

TPU adaptation: late materialization — the output keeps the input's shape
with dropped rows zeroed, plus a per-block popcount partial sum. Row
compaction is a data-dependent scatter (a sort on TPU) and is deliberately
NOT done here; downstream consumers either work on masked form directly
(aggregations) or compact once on the host. Bits unpack with a broadcasted
variable-shift against the lane index — branch-free VREG bit twiddling.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8192


def _kernel(block: int, words_ref, col_ref, out_ref, cnt_ref):
    words = words_ref[...]                                  # (block/32,) u32
    shifts = jnp.arange(32, dtype=jnp.uint32)[None, :]
    bits = (words[:, None] >> shifts) & jnp.uint32(1)       # (block/32, 32)
    keep = bits.reshape(-1).astype(bool)                    # (block,)
    col = col_ref[...]
    out_ref[...] = jnp.where(keep, col, jnp.zeros((), col.dtype))
    cnt_ref[...] = bits.sum(dtype=jnp.int32).reshape(1)


def bitmap_apply(words: jax.Array, col: jax.Array,
                 block: int = DEFAULT_BLOCK, interpret: bool = True):
    """words: (R/32,) uint32; col: (R,). R % block == 0.
    Returns (masked column (R,), per-block counts (R/block,) int32)."""
    R = col.shape[0]
    assert R % block == 0 and words.shape[0] == R // 32
    grid = (R // block,)
    return pl.pallas_call(
        functools.partial(_kernel, block),
        grid=grid,
        in_specs=[pl.BlockSpec((block // 32,), lambda i: (i,)),
                  pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((R,), col.dtype),
                   jax.ShapeDtypeStruct((R // block,), jnp.int32)],
        interpret=interpret,
    )(words, col)
