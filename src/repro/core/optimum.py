"""Theoretical optimum of adaptive pushdown (§3.1, Eq. 1-7).

Closed form (uniform requests): with k = T_npd / T_pd,

    n_opt  = k/(k+1) * N                                  (Eq. 6)
    T_opt  = k/(k+1) * T_pd = 1/(k+1) * T_npd             (Eq. 7)

plus the *discrete* optimum over integer admit counts for heterogeneous
request sets (the oracle the paper compares its heuristic against in Fig. 7
— "constructed with a global view of all requests ahead of execution").
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.cost import RequestCost, StorageResources


def n_opt_uniform(N: int, k: float) -> float:
    """Eq. 6 (real-valued; the paper rounds to integers in practice)."""
    return k / (k + 1.0) * N


def t_opt_uniform(t_pd: float, k: float) -> float:
    """Eq. 7."""
    return k / (k + 1.0) * t_pd


def k_of(t_npd: float, t_pd: float) -> float:
    return t_npd / t_pd if t_pd > 0 else 0.0


@dataclasses.dataclass
class Split:
    n_pushdown: int
    time: float
    t_pd_part: float
    t_pb_part: float


def _time_of_split(costs: Sequence[RequestCost], admit: Sequence[bool],
                   res: StorageResources) -> Tuple[float, float, float]:
    """Makespan of a given admit/pushback split under the §3.1 fluid model:
    admitted work shares the pd slots; pushback work shares the net streams;
    the two proceed in parallel (Eq. 1-3)."""
    cpu_work = sum(c.compute_in for c, a in zip(costs, admit) if a)
    pd_net = sum(c.s_out for c, a in zip(costs, admit) if a)
    pb_net = sum(c.s_in for c, a in zip(costs, admit) if not a)
    scan = sum(c.s_in for c in costs)
    t_pd_part = cpu_work / (res.eff_core_bw * res.pd_slots)
    # the storage<->compute pipe is shared by pushdown results and pushbacks
    t_net = (pd_net + pb_net) / res.net_bw
    t_scan = scan / res.disk_bw
    t_pb_part = t_net
    return max(t_pd_part, t_pb_part) + t_scan, t_pd_part, t_pb_part


def discrete_optimum(costs: Sequence[RequestCost], res: StorageResources
                     ) -> Split:
    """Best integer split: admit the n most pushdown-amenable requests
    (sorted by PA, §3.4 — exchange argument: any optimal split can be
    reordered into a PA-prefix split without increasing either term)."""
    order = sorted(range(len(costs)), key=lambda i: -costs[i].pa(res))
    best = None
    for n in range(len(costs) + 1):
        admit = [False] * len(costs)
        for i in order[:n]:
            admit[i] = True
        t, tpd, tpb = _time_of_split(costs, admit, res)
        if best is None or t < best.time:
            best = Split(n, t, tpd, tpb)
    return best


def simulated_optimum(sim_reqs, res: StorageResources,
                      coarse: int = 16) -> Split:
    """The paper's oracle evaluated apples-to-apples: with a global view,
    pick the integer split (PA-ordered prefix admitted) that minimizes the
    *simulated* makespan under the same slot/fluid dynamics the heuristic
    runs in. Coarse grid then local refinement (makespan is ~unimodal in n)."""
    from repro.core.arbitrator import PUSHBACK, PUSHDOWN
    from repro.core.simulator import simulate

    N = len(sim_reqs)
    order = sorted(range(N), key=lambda i: -sim_reqs[i].cost.pa(res))

    def evaluate(n: int) -> float:
        dec = {}
        admit = set(order[:n])
        for i, r in enumerate(sim_reqs):
            dec[r.req_id] = PUSHDOWN if i in admit else PUSHBACK
        return simulate(sim_reqs, res, decisions=dec).makespan

    grid = sorted({0, N} | {round(i * N / coarse) for i in range(coarse + 1)})
    times = {n: evaluate(n) for n in grid}
    n0 = min(times, key=times.get)
    lo = max(0, n0 - max(1, N // coarse))
    hi = min(N, n0 + max(1, N // coarse))
    for n in range(lo, hi + 1):
        if n not in times:
            times[n] = evaluate(n)
    best = min(times, key=times.get)
    return Split(best, times[best], 0.0, 0.0)


def uniform_prediction(costs: Sequence[RequestCost], res: StorageResources
                       ) -> Split:
    """Closed-form Eq. 6-7 applied to the mean request (the paper's model)."""
    N = len(costs)
    if N == 0:
        return Split(0, 0.0, 0.0, 0.0)
    mean = RequestCost(
        s_in=sum(c.s_in for c in costs) // N,
        s_out=sum(c.s_out for c in costs) // N,
        compute_in=sum(c.compute_in for c in costs) // N,
    )
    # T_pd / T_npd of the whole pushable portion (Eq. 4), scan excluded —
    # it is common to both (the paper's k compares the differing parts).
    t_pd = N * mean.compute_in / (res.eff_core_bw * res.pd_slots) \
        + N * mean.s_out / res.net_bw
    t_npd = N * mean.s_in / res.net_bw
    k = k_of(t_npd, t_pd)
    n = round(n_opt_uniform(N, k))
    return Split(n, t_opt_uniform(t_pd, k), 0.0, 0.0)
