"""Decision-faithful adaptive runtime: arbitration drives real execution.

The simulator/Arbitrator produce a per-request pushdown/pushback decision
vector (``SimResult.per_request``). Before this module, those decisions
only shaped the *simulated* timeline — ``engine.execute_requests`` ran
every partition through the storage-side batched executor regardless. Here
the decision vector routes the real bytes, exactly as the paper's adaptive
pushdown does:

- **pushdown** requests execute at the storage layer through the fused
  batched executor (``core.executor``), and ship only their *results*
  (plus any §4.2 aux by-products);
- **pushback** requests ship the raw accessed-column projection — the
  paper's ``S_in`` — and the *compute layer* replays the very same
  ``CompiledPushPlan`` over the shipped batch (including the shuffle /
  bitmap aux paths), so the work moves but the plan does not change.

The merged per-table results are **byte-identical to all-pushdown
execution for any decision vector**: per-partition outputs are
batch-composition-invariant (pinned by ``tests/test_executor.py``), and
``execute_split`` reassembles them in original request order. Real
execution is therefore correct under every engine mode
(no_pushdown / eager / adaptive / adaptive_pa).

Real net-bytes accounting rides along: pushdown requests are charged their
actual result bytes (vs the cost model's estimated ``s_out``), pushback
requests their stored accessed-column bytes (identical to the simulator's
``s_in`` — the estimate is exact on that path), and
``reconcile_net_bytes`` lines both up against ``SimResult.net_bytes``.

``run_stream`` is the concurrent wall-clock driver: arrival-timed
multi-query waves, per-node worker pools sized by the storage slot pools
(``pd_slots`` execution workers, ``pb_slots`` transfer workers per node, a
compute pool for pushback replay + final plan residuals), with dispatch
order taken live from the Arbitrator's decision callback. It feeds the
``benchmarks/adaptive.py`` real adaptive-vs-eager-vs-no-pushdown A/B.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from concurrent.futures import (FIRST_COMPLETED, Future, ThreadPoolExecutor,
                                TimeoutError as FutTimeout, wait as fut_wait)
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import faults as _faults
from repro.core.arbitrator import PUSHBACK, PUSHDOWN
from repro.core.cost import CardinalityCorrector
from repro.core.executor import (EXECUTOR_BATCHED, EXECUTOR_REFERENCE,
                                 CompiledPushPlan, compile_push_plan)
from repro.core.plan import execute_push_plan, plan_signature
from repro.obs import trace as obs_trace
from repro.obs.metrics import get_metrics
from repro.queryproc.table import ColumnTable

# residual backends (EngineConfig.residual): how the compute layer
# evaluates the post-pushdown residual plan over the merged tables.
#   interpreter — the numpy tree-walker (compiler.interpreter), the oracle
#   tensor      — fused jax.jit programs (compiler.tensorize), results
#                 identical, faster on residual-dominant queries
#   auto        — tensor iff the merged input is at or above the
#                 calibrated crossover (tensorize.auto_threshold)
RESIDUAL_INTERPRETER = "interpreter"
RESIDUAL_TENSOR = "tensor"
RESIDUAL_AUTO = "auto"
RESIDUALS = (RESIDUAL_INTERPRETER, RESIDUAL_TENSOR, RESIDUAL_AUTO)


def run_residual(query, merged: Dict[str, ColumnTable],
                 backend: str = RESIDUAL_INTERPRETER):
    """Evaluate ``query``'s residual over the merged per-table results.

    Returns ``(table, info)`` where ``info`` is ``None`` on the
    interpreter path and a ``tensorize.TensorRun`` (jit-cache hit/miss,
    fallback and observe accounting) on the tensor path. Queries with no
    attached residual IR (hand-built seed queries) always run their
    ``compute`` closure — the tensor backend needs the IR. Both backends
    produce identical tables for every query and decision vector
    (tests/test_tensorize.py)."""
    if backend is not None and backend not in RESIDUALS:
        raise ValueError(f"unknown residual backend {backend!r}; "
                         f"expected one of {RESIDUALS}")
    residual = getattr(query, "residual", None)
    if residual is None or backend in (None, RESIDUAL_INTERPRETER):
        return query.compute(merged), None
    from repro.compiler import tensorize  # lazy: keeps jax off cold paths
    if backend == RESIDUAL_AUTO:
        rows = sum(len(t) for t in merged.values())
        if rows < tensorize.auto_threshold():
            return query.compute(merged), None
    run = tensorize.execute(residual, merged)
    return run.table, run


# --------------------------------------------------------- split execution
@dataclasses.dataclass
class RequestOutcome:
    """What one request really did: where it ran and what it shipped."""
    req_id: int
    table: str
    path: str            # PUSHDOWN | PUSHBACK
    rows_out: int        # plan-output rows for this partition
    shipped_bytes: int   # pushdown: actual result(+aux) bytes;
    #                      pushback: stored accessed-column bytes (s_in)
    replayed: bool       # True when the plan ran at the compute layer
    cache: Optional[str] = None  # "exact" | "containment" when the result
    #                              was served by the pushed-result cache
    # ---- fault/recovery accounting (core.faults; zero when no fault plan)
    attempts: int = 1    # storage-execute attempts (1 = clean first try)
    demoted: bool = False  # decided pushdown, exhausted retries, recovered
    #                        via pushback (path above reflects the demotion)
    hedged: bool = False   # a hedge duplicate won this group's race


@dataclasses.dataclass
class SplitExecution:
    """Merged tables + real-traffic accounting of one decision vector."""
    merged: Dict[str, ColumnTable]
    outcomes: List[RequestOutcome]   # original request order
    n_pushdown: int
    n_pushback: int
    pushdown_bytes: int              # actually shipped pushdown results
    pushback_bytes: int              # actually shipped raw projections
    # ---- recovery accounting (zero on fault-free runs)
    n_demoted: int = 0               # decided-pushdown requests recovered
    #                                  via pushback demotion
    retries: int = 0                 # backoff-retried attempts, all groups
    faults_injected: int = 0         # injected fault events hit by this run

    @property
    def real_net_bytes(self) -> int:
        return self.pushdown_bytes + self.pushback_bytes


def result_bytes(result: ColumnTable, aux: Dict) -> int:
    """Bytes a pushdown result really ships — same arithmetic as
    ``plan.actual_out_bytes`` (64-byte floor, packed bitmap rides along)
    without materializing column stats for every per-partition slice."""
    b = sum(int(v.nbytes) for v in result.cols.values()) if len(result) \
        else 64
    if "bitmap" in aux:
        b += int(aux["bitmap"].nbytes)
    return int(b)


def pushback_bytes(cplan: CompiledPushPlan, data: ColumnTable) -> int:
    """Stored bytes of the raw accessed-column projection — exactly the
    cost model's ``s_in`` (the pushback estimate is exact, not a guess)."""
    return int(data.nbytes([c for c in cplan.accessed if c in data.cols],
                           stored=True))


def _exec_group(cplan: CompiledPushPlan, sub, path: str, executor: str,
                threshold: Optional[float],
                bitmaps: Optional[Dict[int, np.ndarray]] = None,
                shipped: Optional[List[ColumnTable]] = None,
                cache=None, tier=None,
                parent: Optional[obs_trace.Span] = None
                ) -> List[Tuple[ColumnTable, Dict]]:
    """Execute one same-(table, plan, path) request group. Pushback groups
    run the same compiled plan over raw projections (``shipped`` lets the
    stream driver pass transfer-copied batches instead of in-place views).

    ``cache`` (a ``core.result_cache.ResultCache``) applies to the
    storage-side batched pushdown path only: pushback replays run at the
    compute layer over already-shipped bytes (nothing storage-side to
    save), and the per-partition reference stays the uncached oracle.

    ``tier`` (a ``distributed.workers.WorkerPool``) reroutes the storage
    side over the wire: pushdown dispatches the compiled plan to the
    partition-owning worker *process*; pushback fetches the raw
    accessed-column projection as real serialized bytes and replays the
    plan compute-side over the decoded tables — byte-identical to the
    in-process paths (the tier oracle contract, docs/distributed.md). A
    dead/overdue channel raises ``faults.WorkerFault``, which the
    recovery loop maps onto the retry -> demote machinery.
    """
    if tier is not None and shipped is None:
        if path == PUSHDOWN:
            return tier.execute_group(cplan, sub, executor, threshold,
                                      bitmaps=bitmaps, parent=parent)
        shipped = tier.fetch_projection(cplan, sub, parent=parent)
    if shipped is not None:
        tabs = shipped
    elif path == PUSHDOWN:
        tabs = [r.part.data for r in sub]
    else:
        tabs = [cplan.raw_projection(r.part.data) for r in sub]
    bms = [bitmaps[r.req_id] for r in sub] if bitmaps else None
    if executor == EXECUTOR_REFERENCE:
        return [execute_push_plan(cplan.plan, t,
                                  None if bms is None else bms[i])
                for i, t in enumerate(tabs)]
    cache_parts = ([r.part for r in sub]
                   if cache is not None and path == PUSHDOWN
                   and shipped is None else None)
    parts, aux = cplan.execute_batch_parts(
        tabs, bms, threshold,
        cache=cache if cache_parts is not None else None, parts=cache_parts)
    return list(zip(parts, aux))


def _exec_group_traced(cplan: CompiledPushPlan, sub, path: str,
                       executor: str, threshold: Optional[float],
                       bitmaps: Optional[Dict[int, np.ndarray]] = None,
                       shipped: Optional[List[ColumnTable]] = None,
                       parent: Optional[obs_trace.Span] = None,
                       node: Optional[int] = None,
                       cache=None, tier=None
                       ) -> Tuple[List[Tuple[ColumnTable, Dict]],
                                  obs_trace.Span]:
    """``_exec_group`` under a span: ``storage_execute`` for pushdown
    batches, ``compute_replay`` for pushed-back ones. Returns the (closed)
    span alongside the results so the caller can attach ``shipped_bytes``
    from the **same** per-request accounting it computes anyway
    (``result_bytes`` / ``pushback_bytes``) — traces reconcile with
    ``SplitExecution.real_net_bytes`` *exactly*, and the bytes are never
    computed twice."""
    tr = obs_trace.get_tracer()
    name = "storage_execute" if path == PUSHDOWN else "compute_replay"
    with tr.span(name, parent=parent, table=sub[0].table,
                 n_parts=len(sub), node=node) as sp:
        out = _exec_group(cplan, sub, path, executor, threshold,
                          bitmaps=bitmaps, shipped=shipped, cache=cache,
                          tier=tier, parent=sp)
        if tr.enabled:
            sp.set(rows_out=int(sum(len(res) for res, _ in out)),
                   signature=plan_signature(cplan.plan),
                   cache_hits=sum(1 for _res, a in out if a.get("cache")))
    return out, sp


@dataclasses.dataclass
class GroupRecovery:
    """What recovery did for one executed request group."""
    attempts: int = 1                 # executions tried (incl. the success)
    retries: int = 0                  # failed attempts that were retried
    injected: List[str] = dataclasses.field(default_factory=list)
    real_faults: List[str] = dataclasses.field(default_factory=list)
    #   WorkerFault kinds observed at the process-tier channel boundary
    #   (disjoint from ``injected`` — the pool's ``events`` ledger is the
    #   authoritative real-fault record the tests reconcile against)
    demoted: bool = False             # exhausted -> fallback execution ran
    charged_s: float = 0.0            # charged (virtual) seconds consumed


def _exec_group_recovered(cplan: CompiledPushPlan, sub, path: str,
                          executor: str, threshold: Optional[float],
                          faults: Optional["_faults.FaultPlan"],
                          retry: "_faults.RetryPolicy",
                          breaker: Optional["_faults.CircuitBreaker"] = None,
                          bitmaps: Optional[Dict[int, np.ndarray]] = None,
                          shipped: Optional[List[ColumnTable]] = None,
                          parent: Optional[obs_trace.Span] = None,
                          node: Optional[int] = None,
                          cache=None, salt: str = "", tier=None,
                          abort: Optional[threading.Event] = None
                          ) -> Tuple[List[Tuple[ColumnTable, Dict]],
                                     obs_trace.Span, GroupRecovery]:
    """``_exec_group_traced`` under the fault/recovery contract.

    Each attempt consults the ``FaultPlan`` (when one is active) at the
    storage-execute boundary. A ``straggler`` completes (late: the
    injected delay is both charged and really slept, scaled);
    ``crash``/``timeout``/``transient`` abort the attempt, charge the
    deadline budget their nominal detection cost, and retry after capped
    exponential backoff with deterministic jitter. On the process storage
    tier the same loop also absorbs **real** failures: a
    :class:`core.faults.WorkerFault` raised at the channel boundary
    (worker SIGKILL -> EOF, or an overdue request) is handled exactly like
    an injected fault of the same kind — charged, counted, retried — except
    that a real timeout already waited its detection time out on the wire,
    so nothing extra is slept. On exhaustion (attempts or charged budget):

    - ``retry.demote_on_exhaust`` (the contract): a pushdown group is
      **demoted to pushback** — ship the raw projection, replay the
      compiled plan compute-side, byte-identical by the PR-4 contract; an
      already-pushback group replays cleanly from the durable projection
      (``retry.local_replays``). The fallback execution is not re-injected
      and, on the process tier, runs **in-process from the parent's
      catalog copy** (``tier=None``): the recovery tier (durable store +
      local compute) is outside the storage fault model — which is what
      makes "never an error" a guarantee rather than a probability.
    - otherwise: raise :class:`core.faults.FaultExhausted` — the
      fail-to-error baseline the chaos benchmark compares against.

    ``abort`` is the hedge loser's cancellation token: a set token raises
    :class:`core.faults.HedgeAborted` at the next attempt boundary (and
    before the demote fallback), so a lost race cannot keep charging the
    fault ledger, the byte counters, or the calibration samples.

    Every outcome feeds the circuit breaker (when given) and the
    ``faults.node<N>.<path>.failures``/``.successes`` counters — the same
    live per-node signals ``MeasuredLoad``-style pollers consume.
    """
    m = get_metrics()
    tr = obs_trace.get_tracer()
    node_id = node if node is not None else sub[0].part.node_id
    table = sub[0].table
    key = f"{min(r.req_id for r in sub)}x{len(sub)}"
    rec = GroupRecovery()
    budget = retry.deadline_s
    scale = retry.real_scale()
    attempt = 1
    while True:
        if abort is not None and abort.is_set():
            raise _faults.HedgeAborted(node_id, path, table)
        action = faults.draw(node_id, path, table, key, attempt, salt) \
            if faults is not None else None
        kind = real = None
        if action is None or action.kind == _faults.FAULT_STRAGGLER:
            if action is not None:
                m.counter(f"faults.{_faults.FAULT_STRAGGLER}").inc()
                rec.injected.append(_faults.FAULT_STRAGGLER)
                delay = action.param if action.param is not None \
                    else retry.attempt_timeout_s
                rec.charged_s += delay
                if tr.enabled:
                    tr.event("fault_injected", parent=parent,
                             kind=_faults.FAULT_STRAGGLER, node=node_id,
                             table=table, path=path, attempt=attempt,
                             delay_s=delay)
                if delay * scale > 0:
                    time.sleep(delay * scale)
            try:
                out, sp = _exec_group_traced(cplan, sub, path, executor,
                                             threshold, bitmaps=bitmaps,
                                             shipped=shipped, parent=parent,
                                             node=node_id, cache=cache,
                                             tier=tier)
            except _faults.WorkerFault as wf:
                kind, real = wf.kind, True
                rec.real_faults.append(kind)
            else:
                rec.attempts = attempt
                m.counter(f"faults.node{node_id}.{path}.successes").inc()
                if breaker is not None:
                    breaker.record_success(node_id, path)
                return out, sp, rec
        else:
            kind = action.kind
            rec.injected.append(kind)
        m.counter(f"faults.{kind}").inc()
        m.counter(f"faults.node{node_id}.{path}.failures").inc()
        if breaker is not None:
            breaker.record_failure(node_id, path)
        if tr.enabled:
            tr.event("worker_fault" if real else "fault_injected",
                     parent=parent, kind=kind, node=node_id, table=table,
                     path=path, attempt=attempt)
        charge = retry.charge(kind)
        rec.charged_s += charge
        budget -= charge
        if not real and kind == _faults.FAULT_TIMEOUT and charge * scale > 0:
            time.sleep(charge * scale)  # an *injected* timeout really waits
            #   the attempt out; a real one already did, on the wire
        if attempt < retry.max_attempts and budget > 0:
            u = faults.jitter(node_id, path, table, key, attempt) \
                if faults is not None else 0.5
            back = retry.backoff_s(attempt, u)
            rec.charged_s += back
            budget -= back
            if budget > 0:
                rec.retries += 1
                m.counter("retry.attempts").inc()
                if tr.enabled:
                    tr.event("retry", parent=parent, attempt=attempt + 1,
                             node=node_id, table=table, backoff_s=back,
                             budget_s=budget)
                if back * scale > 0:
                    time.sleep(back * scale)
                attempt += 1
                continue
        # exhausted: retries or charged deadline budget ran out
        rec.attempts = attempt
        if not retry.demote_on_exhaust:
            m.counter("retry.exhausted").inc()
            raise _faults.FaultExhausted(kind, node_id, path, table, attempt)
        if abort is not None and abort.is_set():
            raise _faults.HedgeAborted(node_id, path, table)
        rec.demoted = True
        m.counter("retry.demotions" if path == PUSHDOWN
                  else "retry.local_replays").inc()
        with tr.span("demote", parent=parent, node=node_id, table=table,
                     from_path=path, attempts=attempt, kind=kind):
            out, sp = _exec_group_traced(cplan, sub, PUSHBACK, executor,
                                         threshold, bitmaps=bitmaps,
                                         shipped=shipped, parent=parent,
                                         node=node_id, cache=cache)
        if breaker is not None and path == PUSHDOWN:
            # the fallback succeeded on the *other* path
            breaker.record_success(node_id, PUSHBACK)
        return out, sp, rec


def execute_split(reqs, decisions: Dict[int, str],
                  executor: str = EXECUTOR_BATCHED,
                  threshold: Optional[float] = None,
                  bitmaps: Optional[Dict[int, np.ndarray]] = None,
                  cache=None, faults=None, retry=None,
                  breaker=None, tier=None) -> SplitExecution:
    """Route every request down its decided path and merge.

    ``reqs`` is a list of ``engine.PlannedRequest``; ``decisions`` maps
    ``req_id -> PUSHDOWN | PUSHBACK`` (missing ids default to pushdown).
    Requests sharing a (table, plan, path) execute as one fused batch; the
    per-table merge concatenates per-partition results in **original
    request order**, so the merged tables are byte-identical to
    all-pushdown execution for any decision vector.

    ``faults``/``retry``/``breaker`` (core.faults): with a ``FaultPlan``
    active — passed in, or ambient via ``REPRO_FAULT_SPEC`` — every group
    executes through the retry/deadline/demote recovery loop
    (``_exec_group_recovered``), grouped additionally **per storage node**
    so injection scopes match the fleet topology, and the split carries
    the recovery accounting (``n_demoted``/``retries``/``faults_injected``).
    Byte-identity holds under ANY fault schedule: demotion is just the
    pushback path, and the merge order never changes. Without a plan this
    function is byte-for-byte the fault-free PR-4 code path.

    ``tier`` (``distributed.workers.WorkerPool``): route the storage side
    through real worker processes. Grouping always splits per node (each
    worker owns its node's partitions), execution always runs through the
    recovery loop (real channel faults must flow retry -> demote even
    with no injected plan; the retry policy is auto-armed), and the
    result cache is bypassed (the workers own the storage side — a
    parent-side cache would fake locality the wire no longer has).
    """
    if faults is None:
        faults = _faults.env_plan()
    if faults is not None or tier is not None:
        retry = retry if retry is not None else _faults.RetryPolicy()
    if tier is not None:
        cache = None
    tr = obs_trace.get_tracer()
    with tr.span("execute_split", n_requests=len(reqs)) as es:
        per_req: Dict[int, ColumnTable] = {}
        out_by_id: Dict[int, RequestOutcome] = {}
        n_pd = n_pb = n_dem = retries = injected = 0
        pd_bytes = pb_bytes = 0
        groups: Dict[Tuple, List] = {}
        recovered = faults is not None or tier is not None
        for r in reqs:
            # with a fault plan or a process tier, groups split per node:
            # injection, recovery, and partition ownership are all
            # per-(node, path) — the fleet's failure unit
            gkey = (r.table, id(r.plan)) if not recovered \
                else (r.table, id(r.plan), r.part.node_id)
            groups.setdefault(gkey, []).append(r)
        for _gkey, rs in groups.items():
            cplan = compile_push_plan(rs[0].plan)
            for path in (PUSHDOWN, PUSHBACK):
                sub = [r for r in rs
                       if decisions.get(r.req_id, PUSHDOWN) == path]
                if not sub:
                    continue
                if not recovered:
                    out, gsp = _exec_group_traced(cplan, sub, path, executor,
                                                  threshold, bitmaps=bitmaps,
                                                  cache=cache)
                    rec = None
                    eff_path = path
                else:
                    out, gsp, rec = _exec_group_recovered(
                        cplan, sub, path, executor, threshold, faults,
                        retry, breaker=breaker, bitmaps=bitmaps, cache=cache,
                        tier=tier)
                    retries += rec.retries
                    injected += len(rec.injected)
                    eff_path = PUSHBACK if rec.demoted else path
                demoted = rec is not None and rec.demoted \
                    and path == PUSHDOWN
                g_bytes = 0
                for r, (res, aux) in zip(sub, out):
                    per_req[r.req_id] = res
                    if eff_path == PUSHDOWN:
                        b = result_bytes(res, aux)
                        pd_bytes += b
                        n_pd += 1
                    else:
                        b = pushback_bytes(cplan, r.part.data)
                        pb_bytes += b
                        n_pb += 1
                        if demoted:
                            n_dem += 1
                    g_bytes += b
                    out_by_id[r.req_id] = RequestOutcome(
                        r.req_id, r.table, eff_path, len(res), b,
                        replayed=(eff_path == PUSHBACK),
                        cache=aux.get("cache"),
                        attempts=rec.attempts if rec is not None else 1,
                        demoted=demoted)
                tr.amend(gsp, shipped_bytes=int(g_bytes))
        by_table: Dict[str, List[ColumnTable]] = {}
        for r in reqs:
            by_table.setdefault(r.table, []).append(per_req[r.req_id])
        with tr.span("merge", tables=sorted(by_table)):
            merged = {t: ColumnTable.concat(parts)
                      for t, parts in by_table.items()}
        outs = [out_by_id[r.req_id] for r in reqs]
        if tr.enabled:
            # the RequestOutcome list rides along by reference; exporters
            # coerce dataclasses to dicts at export time
            es.set(n_pushdown=n_pd, n_pushback=n_pb,
                   pushdown_bytes=int(pd_bytes),
                   pushback_bytes=int(pb_bytes),
                   cache_hits=sum(1 for o in outs if o.cache),
                   n_demoted=n_dem, retries=retries,
                   faults_injected=injected,
                   outcomes=outs)
    return SplitExecution(merged, outs, n_pd, n_pb, pd_bytes, pb_bytes,
                          n_demoted=n_dem, retries=retries,
                          faults_injected=injected)


def reconcile_net_bytes(sim, reqs, split: SplitExecution) -> Dict:
    """Line real shipped bytes up against the simulator's ``net_bytes``.

    The pushback component must match exactly (both sides count the stored
    accessed-column bytes); the pushdown component differs by exactly the
    cost model's ``s_out`` cardinality-estimation error, surfaced as
    ``s_out_estimate_ratio`` (sim / real — 1.0 means the estimate was
    spot-on) plus a per-table breakdown the ``CardinalityCorrector``
    learns from."""
    decisions = sim.decisions()
    sim_pd = sum(r.cost.s_out for r in reqs
                 if decisions.get(r.req_id, PUSHDOWN) == PUSHDOWN)
    sim_pb = sum(r.cost.s_in for r in reqs
                 if decisions.get(r.req_id, PUSHDOWN) == PUSHBACK)
    by_table: Dict[str, Dict[str, float]] = {}
    real_pd_by_id = {o.req_id: o.shipped_bytes for o in split.outcomes
                     if o.path == PUSHDOWN}
    for r in reqs:
        if r.req_id not in real_pd_by_id:
            continue
        row = by_table.setdefault(r.table, {"sim_pushdown_bytes": 0,
                                            "real_pushdown_bytes": 0})
        row["sim_pushdown_bytes"] += r.cost.s_out
        row["real_pushdown_bytes"] += real_pd_by_id[r.req_id]
    for row in by_table.values():
        row["s_out_estimate_ratio"] = (
            row["sim_pushdown_bytes"] / row["real_pushdown_bytes"]
            if row["real_pushdown_bytes"] else None)
    return {
        "sim_net_bytes": sim_pd + sim_pb,
        "real_net_bytes": split.real_net_bytes,
        "sim_pushdown_bytes": sim_pd,
        "real_pushdown_bytes": split.pushdown_bytes,
        "sim_pushback_bytes": sim_pb,
        "real_pushback_bytes": split.pushback_bytes,
        "s_out_estimate_ratio": (sim_pd / split.pushdown_bytes
                                 if split.pushdown_bytes else None),
        "by_table": by_table,
    }


def feed_corrector(corrector: CardinalityCorrector, qid: str, reqs,
                   outcomes: Sequence[RequestOutcome]) -> None:
    """Feed one executed decision split back into the corrector: per
    (table, frontier signature), the summed *uncorrected* ``s_out``
    estimate of the pushdown requests against the bytes they actually
    shipped. Pushback requests are skipped — their byte estimate (stored
    ``s_in``) is exact by construction, there is nothing to learn."""
    real_by_id = {o.req_id: o.shipped_bytes for o in outcomes
                  if o.path == PUSHDOWN}
    groups: Dict[Tuple[str, str], List] = {}
    for r in reqs:
        if r.req_id in real_by_id:
            groups.setdefault((r.table, plan_signature(r.plan)),
                              []).append(r)
    for (table, sig), rs in groups.items():
        est = sum(r.s_out_raw or r.cost.s_out for r in rs)
        real = sum(real_by_id[r.req_id] for r in rs)
        corrector.observe(qid, table, sig, est, real)


# ------------------------------------------------- concurrent stream driver
@dataclasses.dataclass
class StreamQuery:
    query: object                 # queries.Query
    arrival: float = 0.0          # seconds after stream start


@dataclasses.dataclass
class StreamRun:
    mode: str
    wall_clock: float                      # execution makespan, seconds
    t_decide: float                        # plan + arbitration (fluid sim)
    #   seconds — kept OUT of wall_clock: the Python fluid simulator
    #   stands in for the storage node's microsecond-scale arbitration,
    #   so its interpreter cost is an artifact, not a runtime cost
    per_query: Dict[str, Dict]             # qid -> timings + split counts
    results: Dict[str, ColumnTable]        # qid -> final query result
    sim: object                            # the shared SimResult
    n_pushdown: int
    n_pushback: int
    real_net_bytes: int
    # ---- recovery accounting (zero on fault-free, hedge-free runs)
    n_demoted: int = 0
    retries: int = 0
    hedged: int = 0                        # hedge races won by the duplicate


def _ship(cplan: CompiledPushPlan, parts_data: List[ColumnTable]
          ) -> List[ColumnTable]:
    """The pushback transfer: materialize (copy) the raw accessed-column
    projection of each partition — the driver actually moves the ``s_in``
    bytes instead of handing the replay an in-place view."""
    shipped = []
    for d in parts_data:
        proj = cplan.raw_projection(d)
        shipped.append(ColumnTable(
            {c: np.array(v, copy=True) for c, v in proj.cols.items()},
            stats=proj._stats))
    return shipped


def _ship_traced(cplan: CompiledPushPlan, parts_data: List[ColumnTable],
                 parent: Optional[obs_trace.Span] = None,
                 node: Optional[int] = None) -> List[ColumnTable]:
    """``_ship`` under a ``pushback_ship`` span (its ``ship_bytes`` is the
    stored ``s_in`` the transfer moves — the same bytes ``pushback_bytes``
    charges, counted once by the matching ``compute_replay`` span)."""
    tr = obs_trace.get_tracer()
    with tr.span("pushback_ship", parent=parent,
                 n_parts=len(parts_data), node=node) as sp:
        out = _ship(cplan, parts_data)
        if tr.enabled:
            sp.set(ship_bytes=int(sum(pushback_bytes(cplan, d)
                                      for d in parts_data)))
    return out


def run_stream(stream: Sequence[StreamQuery], catalog, cfg,
               time_scale: float = 1.0) -> StreamRun:
    """Drive an arrival-timed multi-query stream through real split
    execution on per-node worker pools sized by the slot pools.

    Per storage node: ``res.pd_slots`` pushdown-execution workers and
    ``res.pb_slots`` transfer workers (a pushback slot is the transfer
    stream, as in the simulator); a compute pool replays pushed-back
    batches and runs each query's residual ``compute``. Dispatch order
    within a query follows the Arbitrator's live decision callback, so the
    arbitration both *chooses the path* and *orders the work*. A query id
    appearing several times in one stream is keyed ``qid``, ``qid#1``, ...
    in ``per_query``/``results``.
    """
    from repro.core import engine as _engine  # deferred: engine imports us
    from repro.core.simulator import SimRequest, simulate

    tr = obs_trace.get_tracer()
    metrics = get_metrics()
    stream_cm = tr.span("run_stream", mode=cfg.mode, n_queries=len(stream))
    stream_span = stream_cm.__enter__()
    try:
        return _run_stream_body(stream, catalog, cfg, time_scale, tr,
                                metrics, stream_span, _engine, SimRequest,
                                simulate)
    finally:
        stream_cm.__exit__(None, None, None)


def _run_stream_body(stream, catalog, cfg, time_scale, tr, metrics,
                     stream_span, _engine, SimRequest, simulate) -> StreamRun:
    t_plan0 = time.perf_counter()
    ordered = sorted(stream, key=lambda s: s.arrival)
    # each stream entry gets a unique key so the same query id may appear
    # several times in one stream (a repeated-query workload): duplicates
    # become "Q1#1", "Q1#2", ... in per_query/results
    seen: Dict[str, int] = {}
    keys: List[str] = []
    for sq in ordered:
        n = seen.get(sq.query.qid, 0)
        seen[sq.query.qid] = n + 1
        keys.append(sq.query.qid if n == 0 else f"{sq.query.qid}#{n}")
    all_reqs: List = []
    reqs_by_key: Dict[str, List] = {}
    cache = getattr(cfg, "result_cache", None)
    for key, sq in zip(keys, ordered):
        reqs = _engine.plan_requests(sq.query, catalog,
                                     start_id=len(all_reqs),
                                     corrector=cfg.corrector,
                                     cache=cache)
        for r in reqs:
            r.query_id = key   # one sim/stream identity per stream entry
        reqs_by_key[key] = reqs
        all_reqs.extend(reqs)
    arrival_of = dict(zip(keys, (sq.arrival for sq in ordered)))
    sim_reqs = [SimRequest(r.req_id, r.part.node_id, r.query_id, r.cost,
                           arrival=arrival_of[r.query_id])
                for r in all_reqs]
    decision_pos: Dict[int, int] = {}
    sim = simulate(sim_reqs, cfg.res, cfg.mode,
                   on_decision=lambda rid, _path: decision_pos.setdefault(
                       rid, len(decision_pos)),
                   measured=_engine._measured_of(cfg),
                   breaker=getattr(cfg, "breaker", None))
    decisions = sim.decisions()
    t_decide = time.perf_counter() - t_plan0

    nodes = sorted({r.part.node_id for r in all_reqs})
    # worker pools sized by the slot pools, capped at each node's fair
    # share of the machine's real cores — and a machine-wide semaphore
    # capping *running* tasks at the physical core count: the pools carry
    # the paper's queueing semantics (which path waits on which slot
    # class), the semaphore carries the physics (a slot beyond the real
    # CPUs adds GIL churn and cache thrash, not service rate; without it
    # the adaptive mix runs both path families at once and oversubscribes
    # where the forced baselines don't). The fluid simulator models the
    # full 16-vCPU node; the real driver measures what this container can
    # actually run.
    ncpu = os.cpu_count() or 1
    per_node = max(1, ncpu // max(1, len(nodes)))
    cores = threading.BoundedSemaphore(ncpu)
    exec_pools = {n: ThreadPoolExecutor(
        max(1, min(cfg.res.pd_slots, per_node))) for n in nodes}
    ship_pools = {n: ThreadPoolExecutor(
        max(1, min(cfg.res.pb_slots, per_node))) for n in nodes}
    compute_pool = ThreadPoolExecutor(
        max(1, min(2 * cfg.num_compute_nodes, ncpu)))
    finish_pool = ThreadPoolExecutor(max(1, min(len(ordered),
                                                max(2, ncpu))))
    threshold = cfg.filter_gather_threshold

    # fault-tolerance wiring (core.faults; getattr: plain configs without
    # the fields — and older pickled ones — stay fault-free)
    faults = getattr(cfg, "faults", None)
    if faults is None:
        faults = _faults.env_plan()
    # storage tier (distributed.workers): "process" dispatches every
    # storage-side group to real worker processes over the wire; real
    # channel faults must flow through retry -> demote, so the recovery
    # loop is always armed on this tier
    tier = _engine.resolve_tier(cfg, catalog)
    retry = getattr(cfg, "retry", None)
    if (faults is not None or tier is not None) and retry is None:
        retry = _faults.RetryPolicy()
    recovered = faults is not None or tier is not None
    hedge = getattr(cfg, "hedge", None)
    breaker = getattr(cfg, "breaker", None)
    exec_samples: List[float] = []     # storage-execute durations (hedging
    samples_lock = threading.Lock()    # calibrates its delay from these)

    def on_core(fn, *args, **kw):
        with cores:
            return fn(*args, **kw)

    # on the process tier the submitting thread mostly *waits* on the wire
    # while the worker process burns its own cores — gating dispatch on
    # the parent's core semaphore would serialize I/O, not CPU
    gate = on_core if tier is None else (lambda fn, *a, **kw: fn(*a, **kw))

    def exec_group(cplan, sub, path, shipped=None, qspan=None, node=None,
                   salt="", abort=None):
        """One storage-execute (or replay) group, through the recovery
        loop when a fault plan or the process tier is active; always
        returns the uniform ``(out, span, GroupRecovery-or-None)`` triple
        and records its duration for hedge-delay calibration — unless its
        ``abort`` token was set (a lost hedge race must not pollute the
        calibration stream; ``stream.exec_samples`` counts exactly the
        recorded ones)."""
        t_ex = time.perf_counter()
        if not recovered:
            out, sp = _exec_group_traced(cplan, sub, path, cfg.executor,
                                         threshold, shipped=shipped,
                                         parent=qspan, node=node,
                                         cache=cache)
            rec = None
        else:
            out, sp, rec = _exec_group_recovered(
                cplan, sub, path, cfg.executor, threshold, faults, retry,
                breaker=breaker, shipped=shipped, parent=qspan, node=node,
                cache=cache, salt=salt, tier=tier, abort=abort)
        if abort is None or not abort.is_set():
            with samples_lock:
                exec_samples.append(time.perf_counter() - t_ex)
            metrics.counter("stream.exec_samples").inc()
        return out, sp, rec

    def sample_wave(qspan) -> None:
        """Per-wave load signals: on the in-process tier, slot-pool queue
        depths + free cores; on the process tier, each *worker's* live
        queue-depth / in-flight / CPU-occupancy snapshot polled over the
        wire (``WorkerPool.publish_load``) — written to the very metrics
        gauges the Arbitrator's ``MeasuredLoad`` consumes every dispatch
        wave and, when tracing, stamped on the query as a ``wave_sample``
        instant."""
        cores_free = getattr(cores, "_value", None)
        if cores_free is not None:
            metrics.gauge("stream.cores_free").set(cores_free)
        if tier is not None:
            loads = tier.publish_load()
            if tr.enabled:
                tr.event("wave_sample", parent=qspan, worker_loads=loads,
                         cores_free=cores_free)
            return
        exec_q = {n: exec_pools[n]._work_queue.qsize() for n in nodes}
        ship_q = {n: ship_pools[n]._work_queue.qsize() for n in nodes}
        for n in nodes:
            metrics.gauge(f"stream.node{n}.exec_queue").set(exec_q[n])
            metrics.gauge(f"stream.node{n}.ship_queue").set(ship_q[n])
        if tr.enabled:
            tr.event("wave_sample", parent=qspan,
                     exec_queue=exec_q, ship_queue=ship_q,
                     cores_free=cores_free)

    def submit_query(key: str, qspan) -> List[Tuple[object, Future]]:
        """Fan the query's requests out as (req-group, future) chunks."""
        sample_wave(qspan)
        chunks: Dict[Tuple[str, int, int, str], List] = {}
        for r in reqs_by_key[key]:
            path = decisions.get(r.req_id, PUSHDOWN)
            chunks.setdefault(
                (r.table, id(r.plan), r.part.node_id, path), []).append(r)
        futs: List[Tuple[object, Future]] = []
        for (table, _pid, node, path), sub in sorted(
                chunks.items(),
                key=lambda kv: min(decision_pos.get(r.req_id, 0)
                                   for r in kv[1])):
            cplan = compile_push_plan(sub[0].plan)
            abort = threading.Event() if hedge is not None else None
            if path == PUSHDOWN:
                fut = exec_pools[node].submit(
                    gate, exec_group, cplan, sub, path,
                    qspan=qspan, node=node, abort=abort)
            elif tier is not None:
                # process tier: the fetch is a real wire transfer made
                # inside the recovery loop (a dead worker mid-fetch must
                # flow retry -> local replay, not error) — one future on
                # the node's transfer pool, replay inline after decode
                fut = ship_pools[node].submit(
                    gate, exec_group, cplan, sub, path,
                    qspan=qspan, node=node, abort=abort)
            else:
                ship_fut = ship_pools[node].submit(
                    on_core, _ship_traced, cplan,
                    [r.part.data for r in sub], parent=qspan, node=node)
                # wait for the transfer OUTSIDE the core gate, replay inside
                fut = compute_pool.submit(
                    lambda cp=cplan, s=sub, sf=ship_fut, qs=qspan, nd=node,
                    ab=abort:
                    on_core(exec_group, cp, s, PUSHBACK,
                            shipped=sf.result(), qspan=qs, node=nd,
                            abort=ab))
            futs.append(((sub, path, cplan, node, abort), fut))
        return futs

    t0 = time.perf_counter()

    def resolve(meta, fut, qspan):
        """Await one group future, hedging pushdown stragglers: when the
        original outlives the calibrated percentile delay, a duplicate
        launches on the same node's exec pool (salted so its fault draws
        differ — a retried RPC, not a replayed one); first completion
        wins, the loser is cancelled if still queued and its **abort
        token is set** otherwise: a thread cannot be killed mid-attempt,
        but the token makes the running loser bail at its next attempt
        boundary (``HedgeAborted``) and suppresses its calibration
        sample — a lost race never double-counts shipped bytes,
        fault-ledger entries, or ``exec_samples`` updates (the winner is
        the only future whose results reach the accounting). Returns
        ``(out, span, rec, hedge_won)``."""
        sub, path, _cplan, node, abort = meta
        delay = None
        if hedge is not None and path == PUSHDOWN:
            with samples_lock:
                delay = hedge.delay_s(exec_samples)
        if delay is None:
            return (*fut.result(), False)
        try:
            return (*fut.result(timeout=delay), False)
        except FutTimeout:
            pass
        metrics.counter("hedge.launched").inc()
        if tr.enabled:
            tr.event("hedge", parent=qspan, node=node,
                     table=sub[0].table, delay_s=delay)
        dup_abort = threading.Event()
        dup = exec_pools[node].submit(gate, exec_group, _cplan, sub,
                                      path, qspan=qspan, node=node,
                                      salt="hedge", abort=dup_abort)
        done, _ = fut_wait({fut, dup}, return_when=FIRST_COMPLETED)
        winner = fut if fut in done else dup       # original preferred
        loser, loser_abort = (dup, dup_abort) if winner is fut \
            else (fut, abort)
        loser.cancel()
        if loser_abort is not None:
            loser_abort.set()
        won = winner is dup
        metrics.counter("hedge.won" if won else "hedge.lost").inc()
        return (*winner.result(), won)

    def finish_query(key: str, sq: StreamQuery, futs, qspan) -> Dict:
        try:
            return _finish_query(key, sq, futs, qspan)
        except BaseException as e:
            # a failed worker must neither leak the open query span nor
            # swallow its error: close the span with the failure attached
            # and re-raise — the driver surfaces it after draining peers
            if tr.enabled:
                tr.end(qspan, error=repr(e))
            raise

    def _finish_query(key: str, sq: StreamQuery, futs, qspan) -> Dict:
        per_req: Dict[int, ColumnTable] = {}
        outcomes: List[RequestOutcome] = []
        n_pd = n_pb = n_hit = n_dem = n_retry = n_hedge = 0
        pd_b = pb_b = 0
        for meta, fut in futs:
            (sub, path, cplan, node, _abort) = meta
            out, gsp, rec, hedged = resolve(meta, fut, qspan)
            eff_path = PUSHBACK if (rec is not None and rec.demoted) \
                else path
            demoted = eff_path != path
            if rec is not None:
                n_retry += rec.retries
            if hedged:
                n_hedge += 1
            g_bytes = 0
            for r, (res, aux) in zip(sub, out):
                per_req[r.req_id] = res
                if eff_path == PUSHDOWN:
                    n_pd += 1
                    b = result_bytes(res, aux)
                    pd_b += b
                else:
                    n_pb += 1
                    b = pushback_bytes(cplan, r.part.data)
                    pb_b += b
                    if demoted:
                        n_dem += 1
                g_bytes += b
                kind = aux.get("cache")
                if kind:
                    n_hit += 1
                outcomes.append(RequestOutcome(
                    r.req_id, r.table, eff_path, len(res), b,
                    replayed=(eff_path == PUSHBACK), cache=kind,
                    attempts=rec.attempts if rec is not None else 1,
                    demoted=demoted, hedged=hedged))
            tr.amend(gsp, shipped_bytes=int(g_bytes))
        if cfg.corrector is not None:
            # per-stream-entry feedback: repeated streams converge the
            # estimates (the key strips the '#n' repeat suffix — the
            # correction belongs to the query, not the stream slot)
            feed_corrector(cfg.corrector, sq.query.qid, reqs_by_key[key],
                           outcomes)
        by_table: Dict[str, List[ColumnTable]] = {}
        for r in reqs_by_key[key]:
            by_table.setdefault(r.table, []).append(per_req[r.req_id])

        def merge_and_compute():
            with tr.span("merge", parent=qspan, tables=sorted(by_table)):
                merged = {t: ColumnTable.concat(p)
                          for t, p in by_table.items()}
            backend = getattr(cfg, "residual", RESIDUAL_INTERPRETER)
            with tr.span("residual_compute", parent=qspan) as rsp:
                res, trun = run_residual(sq.query, merged, backend)
                if tr.enabled:
                    tr.amend(rsp, backend=("tensor" if trun is not None
                                           else "interpreter"),
                             jit_hits=(trun.jit_hits if trun else None),
                             jit_misses=(trun.jit_misses if trun else None))
                return res

        result = on_core(merge_and_compute)
        sim_pd = sum(r.cost.s_out for r in reqs_by_key[key]
                     if decisions.get(r.req_id, PUSHDOWN) == PUSHDOWN)
        finish_s = time.perf_counter() - t0
        metrics.counter("stream.requests.pushdown").inc(n_pd)
        metrics.counter("stream.requests.pushback").inc(n_pb)
        metrics.counter("stream.net_bytes.real").inc(pd_b + pb_b)
        if n_hit:
            metrics.counter("stream.cache_hits").inc(n_hit)
        if n_dem:
            metrics.counter("stream.requests.demoted").inc(n_dem)
        metrics.histogram("stream.query_finish_s").observe(finish_s)
        if tr.enabled:
            sim_pb = sum(r.cost.s_in for r in reqs_by_key[key]
                         if decisions.get(r.req_id, PUSHDOWN) == PUSHBACK)
            tr.end(qspan, real_net_bytes=int(pd_b + pb_b),
                   sim_net_bytes=int(sim_pd + sim_pb),
                   n_pushdown=n_pd, n_pushback=n_pb,
                   cache_hits=n_hit,
                   n_demoted=n_dem, retries=n_retry, hedged=n_hedge,
                   s_out_est_ratio=(sim_pd / pd_b if pd_b else None),
                   finish_s=finish_s)
        return {"result": result,
                "finish_s": finish_s,
                "n_pushdown": n_pd, "n_pushback": n_pb,
                "cache_hits": n_hit,
                "n_demoted": n_dem, "retries": n_retry, "hedged": n_hedge,
                "real_net_bytes": pd_b + pb_b,
                "s_out_estimate_ratio": (sim_pd / pd_b if pd_b else None),
                "sim_finish": sim.finish_by_query.get(key)}

    finishers: Dict[str, Future] = {}
    errors: Dict[str, BaseException] = {}
    per_query: Dict[str, Dict] = {}
    try:
        for key, sq in zip(keys, ordered):
            delay = t0 + sq.arrival * time_scale - time.perf_counter()
            if delay > 0:
                time.sleep(delay)
            # detached span: opened at dispatch in this thread, closed by
            # the finish-pool worker (explicit parent, no stack propagation)
            qspan = tr.start("query", parent=stream_span,
                             qid=key, mode=cfg.mode, arrival=sq.arrival)
            finishers[key] = finish_pool.submit(
                finish_query, key, sq, submit_query(key, qspan), qspan)
        # drain EVERY finisher before surfacing any failure: a worker
        # exception must not strand its peers' futures on half-shut pools
        for qid, f in finishers.items():
            try:
                per_query[qid] = f.result()
            except BaseException as e:  # noqa: BLE001 — drained, re-raised
                errors[qid] = e
        wall = time.perf_counter() - t0
    finally:
        # cancel whatever never started, then join the worker threads —
        # run_stream returns (or raises) with every pool fully shut down
        for p in (*exec_pools.values(), *ship_pools.values(),
                  compute_pool, finish_pool):
            p.shutdown(wait=True, cancel_futures=True)
    if errors:
        qid, err = next(iter(errors.items()))
        raise RuntimeError(
            f"stream query {qid!r} failed "
            f"({len(errors)}/{len(finishers)} queries errored)") from err
    results = {qid: d.pop("result") for qid, d in per_query.items()}
    if tr.enabled:
        stream_span.set(
            wall_clock=wall, t_decide=t_decide,
            n_pushdown=sum(d["n_pushdown"] for d in per_query.values()),
            n_pushback=sum(d["n_pushback"] for d in per_query.values()),
            n_demoted=sum(d["n_demoted"] for d in per_query.values()),
            retries=sum(d["retries"] for d in per_query.values()),
            hedged=sum(d["hedged"] for d in per_query.values()),
            real_net_bytes=sum(d["real_net_bytes"]
                               for d in per_query.values()))
    return StreamRun(
        mode=cfg.mode, wall_clock=wall, t_decide=t_decide,
        per_query=per_query, results=results, sim=sim,
        n_pushdown=sum(d["n_pushdown"] for d in per_query.values()),
        n_pushback=sum(d["n_pushback"] for d in per_query.values()),
        real_net_bytes=sum(d["real_net_bytes"] for d in per_query.values()),
        n_demoted=sum(d["n_demoted"] for d in per_query.values()),
        retries=sum(d["retries"] for d in per_query.values()),
        hedged=sum(d["hedged"] for d in per_query.values()))
