"""Deterministic fluid discrete-event simulator of the storage layer.

This container has one CPU core and no real network, so the paper's
wall-clock A/B (16-vCPU storage node, 10 Gbps pipe) is reproduced as a
*fluid* simulation over the paper's own cost model (§3.3): every task is a
sequence of (resource, bytes) stages; resources serve active tasks at
deterministic rates; events fire when the earliest stage drains.

Resource semantics per storage node:
- disk:  shared scan bandwidth, equal fluid share across active scans
- cpu:   one pushdown execution slot = one core at ``eff_core_bw``
         (slot count = Arbitrator's S_exec-pd; queueing handled there)
- net:   shared storage<->compute pipe, equal share capped at the fixed
         per-stream bandwidth BW_net of §3.3

Stage chains:
    pushdown: scan(s_in) -> cpu(compute_in) -> net(s_out)  [slot held
              through scan+compute; the result transfer frees the core]
    pushback: scan(s_in) -> net(s_in)        [slot = the transfer stream,
              held for the whole task]

The same engine serves all four execution modes (the two baselines force a
path; adaptive modes delegate to the Arbitrator).

The per-request ``RequestCost`` is consumed as handed in: when the engine
runs with a ``CardinalityCorrector`` (core.cost), ``plan_requests`` has
already rescaled each ``s_out`` by the measured-feedback ratio, so both
the simulated timeline and the Arbitrator's decisions arbitrate over
corrected estimates — the correction loop needs no simulator changes, by
construction (tests/test_runtime.py pins that corrected runs stay
byte-identical while the estimate error shrinks).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.arbitrator import PUSHBACK, PUSHDOWN, Arbitrator
from repro.core.cost import RequestCost, StorageResources
from repro.obs import trace as obs_trace

EPS = 1e-12

MODE_NO_PUSHDOWN = "no_pushdown"
MODE_EAGER = "eager"
MODE_ADAPTIVE = "adaptive"
MODE_ADAPTIVE_PA = "adaptive_pa"


@dataclasses.dataclass
class SimRequest:
    req_id: int
    node_id: int
    query_id: str
    cost: RequestCost
    arrival: float = 0.0


@dataclasses.dataclass
class TaskState:
    req: SimRequest
    path: str
    stages: List[Tuple[str, float]]   # (resource, remaining bytes)
    slot_until: int = 10 ** 9         # slot frees once idx passes this stage
    idx: int = 0
    start: float = 0.0
    finish: Optional[float] = None
    slot_freed: bool = False

    @property
    def resource(self) -> str:
        return self.stages[self.idx][0]


@dataclasses.dataclass
class SimResult:
    per_request: Dict[int, Tuple[str, float, float]]  # id -> (path, start, finish)
    finish_by_query: Dict[str, float]
    admitted_by_query: Dict[str, int]
    pushed_back_by_query: Dict[str, int]
    net_bytes: float                 # storage->compute traffic
    net_bytes_by_query: Dict[str, float]
    cpu_busy_by_node: Dict[int, float]
    makespan: float

    def admitted(self, qid: Optional[str] = None) -> int:
        if qid is None:
            return sum(self.admitted_by_query.values())
        return self.admitted_by_query.get(qid, 0)

    def decisions(self) -> Dict[int, str]:
        """The per-request path decisions — the vector the decision-faithful
        runtime (``core.runtime``) routes real execution by."""
        return {rid: path for rid, (path, _s, _f) in self.per_request.items()}


def _mk_task(req: SimRequest, path: str, now: float) -> TaskState:
    c = req.cost
    if path == PUSHDOWN:
        # the execution slot (a core) is held through scan+compute; the
        # result transfer does NOT hold it — Eq 3 charges pushdown to
        # BW_cpu only, so a slot cycles at compute rate
        stages = [("disk", float(c.s_in)), ("cpu", float(c.compute_in)),
                  ("net", float(c.s_out))]
        slot_until = 1
    else:
        # a pushback slot IS the transfer stream — held to completion
        stages = [("disk", float(c.s_in)), ("net", float(c.s_in))]
        slot_until = 10 ** 9
    return TaskState(req, path, stages, slot_until, 0, now)


class _ForcedArbitrator:
    """Oracle mode: per-request decisions fixed up front (global view,
    §3.1); two FIFO queues so a blocked path never blocks the other."""

    def __init__(self, res: StorageResources, decisions, on_decide=None):
        self.res = res
        self.decisions = decisions
        self.on_decide = on_decide
        self.q = {PUSHDOWN: [], PUSHBACK: []}
        self.free = {PUSHDOWN: res.pd_slots, PUSHBACK: res.pb_slots}
        self.admitted = 0
        self.pushed_back = 0

    def submit(self, req_id, cost):
        self.q[self.decisions[req_id]].append(req_id)
        return self.drain()

    def release(self, path):
        self.free[path] += 1
        return self.drain()

    def drain(self):
        out = []
        for path in (PUSHDOWN, PUSHBACK):
            while self.q[path] and self.free[path] > 0:
                self.free[path] -= 1
                if path == PUSHDOWN:
                    self.admitted += 1
                else:
                    self.pushed_back += 1
                out.append((self.q[path].pop(0), path))
        if out:
            tr = obs_trace.get_tracer()
            if tr.enabled:
                tr.decisions.record_batch(
                    out, kind="arbitrate",
                    queue_depth=len(self.q[PUSHDOWN])
                    + len(self.q[PUSHBACK]),
                    free_pd=self.free[PUSHDOWN],
                    free_pb=self.free[PUSHBACK],
                    pa_aware=False, forced="oracle")
        if self.on_decide is not None:
            for rid, path in out:
                self.on_decide(rid, path)
        return out


def simulate(requests: List[SimRequest],
             res: StorageResources,
             mode: str = MODE_ADAPTIVE,
             num_nodes: Optional[int] = None,
             decisions: Optional[Dict[int, str]] = None,
             on_decision: Optional[Callable[[int, str], None]] = None,
             measured=None, breaker=None) -> SimResult:
    """``measured`` (an ``arbitrator.MeasuredLoad``) makes every node's
    Arbitrator gauge backlog from the live ``stream.*`` metrics instead of
    its fluid wait queue — the flag-gated measured-signal port.
    ``breaker`` (a ``faults.CircuitBreaker``) is shared by every node's
    Arbitrator: new decisions on a tripped (node, pushdown) route to
    pushback until a half-open probe succeeds (docs/faults.md)."""
    tr = obs_trace.get_tracer()
    with tr.span("arbitrate", mode=mode, n_requests=len(requests)) as sp:
        result = _simulate(requests, res, mode, num_nodes, decisions,
                           on_decision, measured, breaker)
        if tr.enabled:
            # per_request is attached by reference (complete and immutable
            # once _simulate returns) — the exporters coerce it to JSON at
            # export time, so the hot path never copies it
            sp.set(makespan=result.makespan,
                   sim_net_bytes=float(result.net_bytes),
                   n_pushdown=result.admitted(),
                   n_pushback=sum(result.pushed_back_by_query.values()),
                   decisions=result.per_request)
    return result


def _simulate(requests: List[SimRequest],
              res: StorageResources,
              mode: str,
              num_nodes: Optional[int],
              decisions: Optional[Dict[int, str]],
              on_decision: Optional[Callable[[int, str], None]],
              measured=None, breaker=None) -> SimResult:
    nodes = sorted({r.node_id for r in requests}) if num_nodes is None \
        else list(range(num_nodes))
    forced = {MODE_NO_PUSHDOWN: PUSHBACK, MODE_EAGER: PUSHDOWN}.get(mode)
    if decisions is not None:
        arbs = {n: _ForcedArbitrator(res, decisions, on_decide=on_decision)
                for n in nodes}
    else:
        arbs = {n: Arbitrator(res, pa_aware=(mode == MODE_ADAPTIVE_PA),
                              forced_path=forced, on_decide=on_decision,
                              measured=measured, node_id=n,
                              breaker=breaker)
                for n in nodes}
    by_id = {r.req_id: r for r in requests}
    pending = sorted(requests, key=lambda r: (r.arrival, r.req_id))
    active: List[TaskState] = []
    done: Dict[int, TaskState] = {}
    cpu_busy = {n: 0.0 for n in nodes}
    now = 0.0
    i = 0

    def start_assignments(assigns, n, t):
        for req_id, path in assigns:
            active.append(_mk_task(by_id[req_id], path, t))

    while i < len(pending) or active:
        # admit arrivals at `now`
        while i < len(pending) and pending[i].arrival <= now + EPS:
            r = pending[i]
            start_assignments(arbs[r.node_id].submit(r.req_id, r.cost),
                              r.node_id, now)
            i += 1
        if not active:
            if i < len(pending):
                now = pending[i].arrival
                continue
            break

        # fluid rates for the current instant
        disk_n = {n: 0 for n in nodes}
        net_n = {n: 0 for n in nodes}
        for t in active:
            if t.resource == "disk":
                disk_n[t.req.node_id] += 1
            elif t.resource == "net":
                net_n[t.req.node_id] += 1

        def rate(t: TaskState) -> float:
            n = t.req.node_id
            if t.resource == "disk":
                return res.disk_bw / max(1, disk_n[n])
            if t.resource == "cpu":
                return res.eff_core_bw
            return min(res.stream_bw, res.net_bw / max(1, net_n[n]))

        # next event: earliest stage completion or next arrival
        dt = math.inf
        for t in active:
            rem = t.stages[t.idx][1]
            dt = min(dt, rem / rate(t) if rem > 0 else 0.0)
        if i < len(pending):
            dt = min(dt, pending[i].arrival - now)
        dt = max(dt, 0.0)

        # advance
        for t in active:
            r = rate(t)
            res_name, rem = t.stages[t.idx]
            t.stages[t.idx] = (res_name, rem - r * dt)
            if res_name == "cpu":
                cpu_busy[t.req.node_id] += dt  # slot held through scan+compute
        now += dt

        # stage transitions / completions
        still: List[TaskState] = []
        freed: List[Tuple[int, str]] = []
        for t in active:
            while t.idx < len(t.stages) and t.stages[t.idx][1] <= EPS * max(
                    1.0, t.req.cost.s_in):
                t.idx += 1
            if not t.slot_freed and t.idx > t.slot_until:
                t.slot_freed = True
                freed.append((t.req.node_id, t.path))
            if t.idx >= len(t.stages):
                t.finish = now
                done[t.req.req_id] = t
                if not t.slot_freed:
                    t.slot_freed = True
                    freed.append((t.req.node_id, t.path))
            else:
                still.append(t)
        active = still
        for n, path in freed:
            start_assignments(arbs[n].release(path), n, now)

    # ---- metrics
    per_request = {rid: (t.path, t.start, t.finish) for rid, t in done.items()}
    fin_q: Dict[str, float] = {}
    adm_q: Dict[str, int] = {}
    pb_q: Dict[str, int] = {}
    net_q: Dict[str, float] = {}
    net_total = 0.0
    for t in done.values():
        q = t.req.query_id
        fin_q[q] = max(fin_q.get(q, 0.0), t.finish)
        b = t.req.cost.s_out if t.path == PUSHDOWN else t.req.cost.s_in
        net_total += b
        net_q[q] = net_q.get(q, 0.0) + b
        if t.path == PUSHDOWN:
            adm_q[q] = adm_q.get(q, 0) + 1
        else:
            pb_q[q] = pb_q.get(q, 0) + 1
    return SimResult(per_request, fin_q, adm_q, pb_q, net_total, net_q,
                     cpu_busy, max(fin_q.values()) if fin_q else 0.0)
