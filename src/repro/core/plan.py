"""Pushable sub-plans and per-partition pushdown requests.

The planner (engine.py) walks a query plan from the scans upward and cuts
at the first operator that is not *local + bounded* (§4.1) — everything
below the cut ships to storage as a ``PushPlan``; everything above runs in
the compute layer. One pushdown request is issued per fact-table partition
(the paper sends requests per data partition, §4.2).

A ``PushPlan`` is deliberately restricted to the paper's pushdown-amenable
operator set: projection, selection (expression tree), selection *bitmap*
(ship the bitmap instead of columns, §4.2), partial grouped/scalar
aggregation, top-k, and the shuffle partition function (§4.2).

``execute_push_plan`` below is the *interpretive per-partition reference*:
it re-walks the plan per call and is kept as the correctness oracle. The
production path is ``core.executor``: plans compile once per query and all
partitions of a table execute in a single vectorized pass, byte-identical
to this reference (tests/test_executor.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import RequestCost
from repro.queryproc import expressions as ex
from repro.queryproc import operators as ops
from repro.queryproc.table import ColumnTable
from repro.storage.catalog import Partition


@dataclasses.dataclass(frozen=True)
class PushPlan:
    """What a single pushdown request executes at the storage node."""
    table: str
    columns: Tuple[str, ...]                       # projection (output cols)
    predicate: Optional[ex.Expr] = None            # selection
    derive: Tuple = ()                             # ((name, (in_cols), fn), ...)
    agg: Optional[Tuple[Tuple[str, ...], Tuple[Tuple[str, str, str], ...]]] = None
    #     ^ partial grouped agg: (keys, ((out, fn, col), ...))
    top_k: Optional[Tuple[str, int, bool]] = None  # (col, k, ascending)
    shuffle: Optional[Tuple[str, int]] = None      # (partition key, n_targets)
    bitmap_only: bool = False                      # return the selection bitmap
    apply_bitmap: bool = False                     # storage filters with a
    #                                                compute-layer bitmap
    having: Optional[ex.Expr] = None               # post-agg filter over the
    #                                                partial aggregate's output
    #                                                (sound only when groups
    #                                                are partition-local — the
    #                                                splitter absorbs it only
    #                                                on clustered catalogs)

    def accessed_columns(self) -> Tuple[str, ...]:
        derived = {name for name, _, _ in self.derive}
        cols = set(self.columns) - derived
        if self.predicate is not None and not self.apply_bitmap:
            cols |= ex.columns_of(self.predicate)
        for _, incols, _ in self.derive:
            cols |= set(incols)
        if self.agg:
            keys, aggs = self.agg
            cols |= (set(keys) | {c for _, _, c in aggs if c}) - derived
            if self.having is not None:
                agg_out = {o for o, _, _ in aggs}
                cols |= (ex.columns_of(self.having) - agg_out
                         - set(keys) - derived)
        if self.top_k:
            cols.add(self.top_k[0])
        if self.shuffle:
            cols.add(self.shuffle[0])
        return tuple(sorted(cols))


def plan_signature(plan: PushPlan, shuffle_key: Optional[str] = None) -> str:
    """Stage signature of one pushed frontier, e.g. ``scan+filter+agg``.
    The compiler's ``frontier_signature`` is the per-table dict of these;
    it also keys the online ``CardinalityCorrector`` (core.cost) — two
    candidate cuts of the same table have different signatures, so a
    measured ``s_out`` correction learned for one cut never silently
    applies to another."""
    stages = ["scan"]
    if plan.predicate is not None:
        stages.append("filter")
    if plan.bitmap_only:
        stages.append("bitmap")
    if plan.derive:
        stages.append("derive")
    if plan.agg is not None:
        stages.append("agg")
        if plan.having is not None:
            stages.append("having")
    if plan.top_k is not None:
        stages.append("topk")
    if plan.shuffle is not None or shuffle_key is not None:
        stages.append("shuffle")
    return "+".join(stages)


def batchable_stages(plan: PushPlan, shuffle_key: Optional[str] = None
                     ) -> Tuple[str, ...]:
    """The stages of this frontier the batch executor (``core.executor``)
    fuses into its single vectorized pass — including the aux-producing
    ones (bitmap emission, shuffle partitioning). The splitter uses this to
    mark shuffle/bitmap-bearing frontiers batchable
    (``SplitResult.batchable``). Pure plan introspection — lives here so
    the compiler can consult it without importing the execution module."""
    stages: List[str] = []
    if plan.apply_bitmap:
        stages.append("apply_bitmap")
    elif plan.predicate is not None:
        stages.append("filter")
        if plan.bitmap_only:
            stages.append("bitmap")
    if plan.derive:
        stages.append("derive")
    if plan.agg is not None:
        stages.append("agg")
        if plan.having is not None:
            stages.append("having")
    if plan.top_k is not None:
        stages.append("topk")
    if plan.shuffle is not None or shuffle_key is not None:
        stages.append("shuffle")
    return tuple(stages)


def execute_push_plan(plan: PushPlan, data: ColumnTable,
                      bitmap: Optional[np.ndarray] = None):
    """Run the pushable sub-plan on one partition (storage-native numpy).
    Returns (result, aux) where aux carries bitmap/shuffle by-products."""
    t = data
    aux: Dict[str, object] = {}
    if plan.apply_bitmap:
        assert bitmap is not None, "compute-layer bitmap required"
        t = ops.apply_bitmap(t, bitmap)
    elif plan.predicate is not None:
        if plan.bitmap_only:
            words = ops.selection_bitmap(t, plan.predicate)
            aux["bitmap"] = words
            t = ops.apply_bitmap(t, words)
        else:
            t = ops.filter_table(t, plan.predicate)
    if plan.derive:
        cols = dict(t.cols)
        for name, incols, fn in plan.derive:
            cols[name] = fn(*[cols[c] for c in incols])
        t = ColumnTable(cols)
    if plan.agg is not None:
        keys, aggs = plan.agg
        t = ops.grouped_agg(t, list(keys), {o: (f, c) for o, f, c in aggs})
        if plan.having is not None:
            t = ops.filter_table(t, plan.having)
    elif plan.columns:
        t = t.select([c for c in plan.columns if c in t.cols])
    if plan.top_k is not None:
        col, k, asc = plan.top_k
        t = ops.top_k(t, col, k, asc)
    if plan.shuffle is not None:
        key, n = plan.shuffle
        aux["shuffle_parts"] = ops.shuffle_partition(t, key, n)
        aux["position_vector"] = ops.position_vector(t, key, n)
    return t, aux


# ------------------------------------------------------------- request cost
_AGG_OUT_ROWS = 4096  # conservative group-count cap for partial aggs


def estimate_cost(plan: PushPlan, part: Partition) -> RequestCost:
    """Static byte estimates for the §3.3 cost model (cardinality estimation
    via per-column stats — the paper's S_out source)."""
    data = part.data
    stats = data.stats()
    acc_cols = [c for c in plan.accessed_columns() if c in data.cols]
    s_in = data.nbytes(acc_cols, stored=True)
    raw_in = data.nbytes(acc_cols, stored=False)
    sel = 1.0
    if plan.predicate is not None:
        sel = ex.estimate_selectivity(plan.predicate, stats)
    derived = {n for n, _, _ in plan.derive}
    n_derived_out = len(derived & set(plan.columns))
    if plan.bitmap_only:
        out_cols = [c for c in plan.columns if c in data.cols]
        s_out = ((data.nbytes(out_cols, stored=False)
                  + 8 * n_derived_out * len(data)) * sel + len(data) / 8)
    elif plan.agg is not None:
        keys, aggs = plan.agg
        groups = 1
        for k in keys:
            # derived group keys have no stored stats: assume the cap
            groups *= max(1, stats[k].ndv if k in stats else _AGG_OUT_ROWS)
        groups = min(groups, _AGG_OUT_ROWS, len(data))
        s_out = groups * 8 * (len(keys) + len(aggs))
        if plan.having is not None:
            # agg outputs have no stored stats -> estimate_selectivity's
            # missing-stats fallback (0.5) applies per comparison
            s_out *= ex.estimate_selectivity(plan.having, stats)
    else:
        out_cols = [c for c in plan.columns if c in data.cols]
        s_out = (data.nbytes(out_cols, stored=False)
                 + 8 * n_derived_out * len(data)) * sel
    if plan.top_k is not None:
        s_out = min(s_out, plan.top_k[1] * 8 * max(1, len(plan.columns)))
    return RequestCost(s_in=int(s_in), s_out=int(max(64, s_out)),
                       compute_in=int(raw_in))


def actual_out_bytes(result: ColumnTable, aux: Dict) -> int:
    b = result.nbytes(stored=False) if len(result) else 64
    if "bitmap" in aux:
        b += aux["bitmap"].nbytes
    return int(b)
