"""The paper's contribution: adaptive computation pushdown.

- cost.py       lightweight time-estimation model (Eq. 8-11)
- optimum.py    theoretical bound (Eq. 1-7) + discrete oracle
- arbitrator.py Adaptive Pushdown Arbitrator (Algorithm 1, §3.4 PA-aware)
- simulator.py  deterministic fluid event simulator of the storage layer
- plan.py       pushable sub-plans + per-partition requests (§4.1 principle)
- engine.py     end-to-end query execution in all four modes
- bitmap.py     selection-bitmap pushdown (§4.2)
- shuffle.py    distributed-data-shuffle pushdown (§4.2)
"""
from repro.core import (arbitrator, bitmap, cost, engine, optimum,  # noqa: F401
                        plan, shuffle, simulator)
