"""Distributed-data-shuffle pushdown (paper §4.2, Fig 5 / Fig 15).

Baseline (shuffle at compute): storage executes filter/project pushdown,
returns results round-robin to the n compute nodes, which then hash-
redistribute on the join key — (n-1)/n of the bytes cross the compute
interconnect.

Shuffle pushdown: the storage node runs the partition function itself
(repro.kernels.hash_partition is the device form; numpy here) and routes
each partition's slice *directly* to its target compute node — the
compute-side redistribution disappears. Parameters shipped with each
request: partition fn, key, target identities (§4.2). Results are buffered
at storage in a bounded pull buffer; when full, the shuffle throttles
(modelled as a net-stage rate cap).

Cached-data interop: a *position vector* (log2 n bits/row) lets the
compute cluster shuffle its cached columns locally, saving ~1/n of the
redistribution and keeping cache utility (§4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core import engine
from repro.core.engine import EngineConfig, PlannedRequest, plan_requests
from repro.core.executor import compile_push_plan
from repro.core.plan import PushPlan
from repro.core.simulator import SimRequest, simulate
from repro.obs import trace as obs_trace
from repro.queryproc import operators as ops
from repro.queryproc.queries import Query
from repro.queryproc.table import ColumnTable
from repro.storage.catalog import Catalog


@dataclasses.dataclass
class ShuffleConfig:
    num_compute_nodes: int = 4
    compute_net_bw: float = 1.25e9  # 10 Gbps NICs (the paper's r5.4xlarge)
    partition_bw: float = 2.4e9     # compute-node partition/serialize rate
    buffer_bytes: int = 256 << 20   # bounded pull buffer at storage (§4.2)
    position_vector: bool = True    # cached-column interop variant


@dataclasses.dataclass
class ShuffleRun:
    qid: str
    t_total: float
    cross_compute_bytes: float      # redistribution traffic inside compute
    storage_net_bytes: float        # storage -> compute traffic
    position_vector_bytes: float


def _exec_table_bytes(reqs: List[PlannedRequest],
                      executor: str = engine.EXECUTOR_BATCHED
                      ) -> Dict[str, List[Tuple[int, int]]]:
    """Actually run each request's plan and record (node, out_bytes).
    ``batched`` runs one fused pass per (table, plan) and splits the result
    back per partition — identical bytes to the per-request reference loop."""
    tr = obs_trace.get_tracer()
    by_table: Dict[str, List[Tuple[int, int]]] = {}
    if executor == engine.EXECUTOR_REFERENCE:
        from repro.core.plan import execute_push_plan
        for r in reqs:
            res, _ = execute_push_plan(r.plan, r.part.data)
            b = res.nbytes(stored=False) if len(res) else 0
            by_table.setdefault(r.table, []).append((r.part.node_id, b))
        return by_table
    groups: Dict[Tuple[str, int], List[PlannedRequest]] = {}
    for r in reqs:
        groups.setdefault((r.table, id(r.plan)), []).append(r)
    for (table, _pid), rs in groups.items():
        with tr.span("storage_execute", cat="shuffle", table=table,
                     n_parts=len(rs)) as sp:
            parts, _aux = compile_push_plan(rs[0].plan).execute_batch_parts(
                [r.part.data for r in rs])
            total = 0
            for r, res in zip(rs, parts):
                b = res.nbytes(stored=False) if len(res) else 0
                total += b
                by_table.setdefault(table, []).append((r.part.node_id, b))
            if tr.enabled:
                sp.set(shipped_bytes=int(total))
    return by_table


def run_shuffle(query: Query, catalog: Catalog, cfg: EngineConfig,
                scfg: ShuffleConfig, pushdown: bool) -> ShuffleRun:
    """End-to-end time of the pushable portion + redistribution under
    baseline pushdown (shuffle at compute) vs shuffle pushdown."""
    reqs = plan_requests(query, catalog)
    # storage phase: same pushdown execution either way (the partition
    # function is linear in the result size — folded into compute_in below)
    sim_reqs = []
    for r in reqs:
        cost = r.cost
        if pushdown and r.table in query.shuffle_keys:
            cost = dataclasses.replace(
                cost, compute_in=int(cost.compute_in * 1.05))  # hash+route
        sim_reqs.append(SimRequest(r.req_id, r.part.node_id, query.qid, cost))
    sim = simulate(sim_reqs, cfg.res, "eager")

    out_bytes = _exec_table_bytes(reqs)
    cross = 0.0
    part_bytes = 0.0
    pv_bytes = 0.0
    storage_net = sim.net_bytes
    n = scfg.num_compute_nodes
    for table, parts in out_bytes.items():
        total = float(sum(b for _, b in parts))
        if table not in query.shuffle_keys:
            continue
        if pushdown:
            # storage routes directly; optional position vector for the
            # cached columns (log2 n bits per row — negligible but counted)
            if scfg.position_vector:
                rows = sum(len(r.part.data) for r in reqs if r.table == table)
                pv_bytes += rows * max(1, int(np.ceil(np.log2(n)))) / 8
        else:
            # round-robin landing, then every landed byte is hashed +
            # serialized by the compute partitioner; (n-1)/n crosses the wire
            part_bytes += total
            cross += total * (n - 1) / n
    # redistribution phase: partitioning CPU + cross-compute wire time,
    # all n nodes working in parallel
    t_shuffle = (part_bytes / (scfg.partition_bw * n)
                 + cross / (scfg.compute_net_bw * n))
    # bounded-buffer throttle: storage can hold buffer_bytes of routed
    # results; beyond that the net stage caps at the drain rate (modelled
    # as an extra serial term for the overflow fraction)
    if pushdown:
        overflow = max(0.0, storage_net - scfg.buffer_bytes * len(
            {r.part.node_id for r in reqs}))
        t_shuffle += overflow / cfg.res.net_bw
        storage_net += pv_bytes
    t_np = sum(float(b) for parts in out_bytes.values()
               for _, b in parts) / (cfg.compute_bw * n)
    return ShuffleRun(query.qid, sim.makespan + t_shuffle + t_np,
                      cross, storage_net, pv_bytes)


# ---------------------------------------------------- real shuffle (numpy)
def shuffle_at_storage(catalog: Catalog, table: str, key: str, n: int
                       ) -> List[ColumnTable]:
    """Actually partition every partition of ``table`` by ``key`` at its
    storage node and concatenate per-target slices (what the target compute
    nodes would receive). Per-partition reference loop — the oracle for
    ``shuffle_at_storage_batched``."""
    targets: List[List[ColumnTable]] = [[] for _ in range(n)]
    for part in catalog.partitions_of(table):
        for t, piece in enumerate(ops.shuffle_partition(part.data, key, n)):
            targets[t].append(piece)
    return [ColumnTable.concat(ps) for ps in targets]


def shuffle_at_storage_batched(catalog: Catalog, table: str, key: str, n: int
                               ) -> List[ColumnTable]:
    """The same per-target slices via the batch executor's shuffle aux: one
    hash + one stable sort over all partitions instead of
    ``n_partitions * n`` boolean filters — byte-identical to
    ``shuffle_at_storage``."""
    parts = [p.data for p in catalog.partitions_of(table)]
    plan = PushPlan(table, tuple(parts[0].columns), shuffle=(key, n))
    _merged, aux = compile_push_plan(plan).execute_batch_aux(parts)
    targets: List[List[ColumnTable]] = [[] for _ in range(n)]
    for a in aux:
        for t, piece in enumerate(a["shuffle_parts"]):
            targets[t].append(piece)
    return [ColumnTable.concat(ps) for ps in targets]


def apply_position_vector(t: ColumnTable, pv, n: int) -> List[ColumnTable]:
    """Cached-data interop (§4.2): route a compute-cached table's rows with
    a storage-shipped position vector — no key columns re-read, no re-hash.
    Equivalent to ``ops.shuffle_partition(t, key, n)`` when ``pv`` is the
    position vector the storage node computed over ``key``."""
    return [t.filter(pv == i) for i in range(n)]


def shuffle_at_compute(catalog: Catalog, table: str, key: str, n: int
                       ) -> List[ColumnTable]:
    """Baseline: round-robin landing then redistribution — same final
    placement (tests assert equality with shuffle_at_storage)."""
    landed: List[List[ColumnTable]] = [[] for _ in range(n)]
    for i, part in enumerate(catalog.partitions_of(table)):
        landed[i % n].append(part.data)
    out: List[List[ColumnTable]] = [[] for _ in range(n)]
    for node_tables in landed:
        if not node_tables:
            continue
        merged = ColumnTable.concat(node_tables)
        for t, piece in enumerate(ops.shuffle_partition(merged, key, n)):
            out[t].append(piece)
    return [ColumnTable.concat(ps) for ps in out]
