"""Distributed-data-shuffle pushdown (paper §4.2, Fig 5 / Fig 15).

Baseline (shuffle at compute): storage executes filter/project pushdown,
returns results round-robin to the n compute nodes, which then hash-
redistribute on the join key — (n-1)/n of the bytes cross the compute
interconnect.

Shuffle pushdown: the storage node runs the partition function itself
(repro.kernels.hash_partition is the device form; numpy here) and routes
each partition's slice *directly* to its target compute node — the
compute-side redistribution disappears. Parameters shipped with each
request: partition fn, key, target identities (§4.2). Results are buffered
at storage in a bounded pull buffer; when full, the shuffle throttles
(modelled as a net-stage rate cap).

Cached-data interop: a *position vector* (log2 n bits/row) lets the
compute cluster shuffle its cached columns locally, saving ~1/n of the
redistribution and keeping cache utility (§4.2).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.engine import EngineConfig, PlannedRequest, plan_requests
from repro.core.simulator import SimRequest, simulate
from repro.queryproc import operators as ops
from repro.queryproc.queries import Query
from repro.queryproc.table import ColumnTable
from repro.storage.catalog import Catalog


@dataclasses.dataclass
class ShuffleConfig:
    num_compute_nodes: int = 4
    compute_net_bw: float = 1.25e9  # 10 Gbps NICs (the paper's r5.4xlarge)
    partition_bw: float = 2.4e9     # compute-node partition/serialize rate
    buffer_bytes: int = 256 << 20   # bounded pull buffer at storage (§4.2)
    position_vector: bool = True    # cached-column interop variant


@dataclasses.dataclass
class ShuffleRun:
    qid: str
    t_total: float
    cross_compute_bytes: float      # redistribution traffic inside compute
    storage_net_bytes: float        # storage -> compute traffic
    position_vector_bytes: float


def _exec_table_bytes(reqs: List[PlannedRequest]) -> Dict[str, List[Tuple[int, int]]]:
    """Actually run each request's plan and record (node, out_bytes)."""
    from repro.core.plan import execute_push_plan
    by_table: Dict[str, List[Tuple[int, int]]] = {}
    for r in reqs:
        res, _ = execute_push_plan(r.plan, r.part.data)
        b = res.nbytes(stored=False) if len(res) else 0
        by_table.setdefault(r.table, []).append((r.part.node_id, b))
    return by_table


def run_shuffle(query: Query, catalog: Catalog, cfg: EngineConfig,
                scfg: ShuffleConfig, pushdown: bool) -> ShuffleRun:
    """End-to-end time of the pushable portion + redistribution under
    baseline pushdown (shuffle at compute) vs shuffle pushdown."""
    reqs = plan_requests(query, catalog)
    # storage phase: same pushdown execution either way (the partition
    # function is linear in the result size — folded into compute_in below)
    sim_reqs = []
    for r in reqs:
        cost = r.cost
        if pushdown and r.table in query.shuffle_keys:
            cost = dataclasses.replace(
                cost, compute_in=int(cost.compute_in * 1.05))  # hash+route
        sim_reqs.append(SimRequest(r.req_id, r.part.node_id, query.qid, cost))
    sim = simulate(sim_reqs, cfg.res, "eager")

    out_bytes = _exec_table_bytes(reqs)
    cross = 0.0
    part_bytes = 0.0
    pv_bytes = 0.0
    storage_net = sim.net_bytes
    n = scfg.num_compute_nodes
    for table, parts in out_bytes.items():
        total = float(sum(b for _, b in parts))
        if table not in query.shuffle_keys:
            continue
        if pushdown:
            # storage routes directly; optional position vector for the
            # cached columns (log2 n bits per row — negligible but counted)
            if scfg.position_vector:
                rows = sum(len(r.part.data) for r in reqs if r.table == table)
                pv_bytes += rows * max(1, int(np.ceil(np.log2(n)))) / 8
        else:
            # round-robin landing, then every landed byte is hashed +
            # serialized by the compute partitioner; (n-1)/n crosses the wire
            part_bytes += total
            cross += total * (n - 1) / n
    # redistribution phase: partitioning CPU + cross-compute wire time,
    # all n nodes working in parallel
    t_shuffle = (part_bytes / (scfg.partition_bw * n)
                 + cross / (scfg.compute_net_bw * n))
    # bounded-buffer throttle: storage can hold buffer_bytes of routed
    # results; beyond that the net stage caps at the drain rate (modelled
    # as an extra serial term for the overflow fraction)
    if pushdown:
        overflow = max(0.0, storage_net - scfg.buffer_bytes * len(
            {r.part.node_id for r in reqs}))
        t_shuffle += overflow / cfg.res.net_bw
        storage_net += pv_bytes
    t_np = sum(float(b) for parts in out_bytes.values()
               for _, b in parts) / (cfg.compute_bw * n)
    return ShuffleRun(query.qid, sim.makespan + t_shuffle + t_np,
                      cross, storage_net, pv_bytes)


# ---------------------------------------------------- real shuffle (numpy)
def shuffle_at_storage(catalog: Catalog, table: str, key: str, n: int
                       ) -> List[ColumnTable]:
    """Actually partition every partition of ``table`` by ``key`` at its
    storage node and concatenate per-target slices (what the target compute
    nodes would receive)."""
    targets: List[List[ColumnTable]] = [[] for _ in range(n)]
    for part in catalog.partitions_of(table):
        for t, piece in enumerate(ops.shuffle_partition(part.data, key, n)):
            targets[t].append(piece)
    return [ColumnTable.concat(ps) for ps in targets]


def shuffle_at_compute(catalog: Catalog, table: str, key: str, n: int
                       ) -> List[ColumnTable]:
    """Baseline: round-robin landing then redistribution — same final
    placement (tests assert equality with shuffle_at_storage)."""
    landed: List[List[ColumnTable]] = [[] for _ in range(n)]
    for i, part in enumerate(catalog.partitions_of(table)):
        landed[i % n].append(part.data)
    out: List[List[ColumnTable]] = [[] for _ in range(n)]
    for node_tables in landed:
        if not node_tables:
            continue
        merged = ColumnTable.concat(node_tables)
        for t, piece in enumerate(ops.shuffle_partition(merged, key, n)):
            out[t].append(piece)
    return [ColumnTable.concat(ps) for ps in out]
