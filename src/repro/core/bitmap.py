"""Selection-bitmap pushdown (paper §4.2, Figs 3/4/13/14).

Late materialization across the storage<->compute network boundary:

- storage-side bitmap (Fig 3): output columns are cached at compute;
  the storage node evaluates the fact predicate, ships the packed bitmap
  (1 bit/row) instead of the filtered output columns — the compute layer
  applies it to its cache (repro.kernels.bitmap_apply on device).
- compute-side bitmap (Fig 4): predicate columns are cached at compute;
  the compute node evaluates the predicate locally, ships the bitmap to
  storage — the storage node skips scanning the predicate columns
  entirely (disk bytes + columns-accessed both drop, Fig 14b).
- fine-grained AND/OR split: sub-predicates are assigned to whichever
  side caches their columns; both sides exchange bitmaps and combine with
  cheap bitwise ops (the §4.2 design-space discussion).

Bitmap pushdown is a *variant of filtering* — local and bounded — so its
requests flow through the same Arbitrator/simulator as everything else;
this module only rewrites the per-request byte accounting.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.cost import RequestCost
from repro.core.engine import PlannedRequest
from repro.core.executor import compile_push_plan
from repro.core.plan import PushPlan
from repro.queryproc import expressions as ex
from repro.queryproc import operators as ops
from repro.queryproc.table import ColumnTable


@dataclasses.dataclass
class CacheState:
    """Which columns of which table the compute layer holds locally."""
    cached: Dict[str, Set[str]] = dataclasses.field(default_factory=dict)

    def has(self, table: str, col: str) -> bool:
        return col in self.cached.get(table, set())

    def cache_columns(self, table: str, cols) -> None:
        self.cached.setdefault(table, set()).update(cols)


def split_predicate(expr: ex.Expr, cached: Set[str]
                    ) -> Tuple[Optional[ex.Expr], Optional[ex.Expr]]:
    """(compute_side, storage_side) for a fine-grained AND split: a
    conjunct goes to the compute layer iff all its columns are cached.
    OR nodes are atomic (both branches must co-locate)."""
    if isinstance(expr, ex.And):
        lc, ls = split_predicate(expr.left, cached)
        rc, rs = split_predicate(expr.right, cached)
        comp = lc if rc is None else (rc if lc is None else ex.And(lc, rc))
        stor = ls if rs is None else (rs if ls is None else ex.And(ls, rs))
        return comp, stor
    if ex.columns_of(expr) <= cached:
        return expr, None
    return None, expr


@dataclasses.dataclass
class BitmapRewrite:
    """Byte-accounting deltas of bitmap pushdown for one request."""
    cost: RequestCost
    bitmap_bytes: int
    disk_bytes_saved: int
    columns_skipped: int
    direction: str  # "storage" | "compute" | "mixed" | "none"


def rewrite_request(req: PlannedRequest, cache: CacheState) -> BitmapRewrite:
    """Recost one fact-table request under bitmap pushdown given the cache.

    Baseline (no bitmaps): storage scans predicate+output columns, ships
    filtered output columns (sel * raw bytes).
    """
    plan, part = req.plan, req.part
    data = part.data
    stats = data.stats()
    rows = len(data)
    if plan.predicate is None:
        return BitmapRewrite(req.cost, 0, 0, 0, "none")
    pred_cols = ex.columns_of(plan.predicate)
    out_cols = [c for c in plan.columns if c in data.cols]
    sel = ex.estimate_selectivity(plan.predicate, stats)
    bitmap_bytes = -(-rows // 32) * 4

    cached = cache.cached.get(req.table, set())
    comp_pred, stor_pred = split_predicate(plan.predicate, cached)

    cached_out = [c for c in out_cols if c in cached]
    uncached_out = [c for c in out_cols if c not in cached]

    if comp_pred is not None and stor_pred is None:
        # Fig 4: compute side evaluates everything; storage just applies
        s_in = data.nbytes(uncached_out, stored=True)  # pred cols unscanned
        disk_saved = req.cost.s_in - s_in
        s_out = int(data.nbytes(uncached_out, stored=False) * sel) + 64
        cost = RequestCost(s_in=int(s_in), s_out=s_out,
                           compute_in=int(data.nbytes(uncached_out, False)))
        return BitmapRewrite(cost, bitmap_bytes, int(disk_saved),
                             len(set(pred_cols) - set(uncached_out)),
                             "compute")
    if comp_pred is None and cached_out:
        # Fig 3: storage builds the bitmap; cached outputs filtered locally
        scan_cols = sorted(set(pred_cols) | set(uncached_out))
        s_in = data.nbytes([c for c in scan_cols if c in data.cols], True)
        s_out = (int(data.nbytes(uncached_out, False) * sel)
                 + bitmap_bytes + 64)
        cost = RequestCost(s_in=int(s_in), s_out=s_out,
                           compute_in=int(data.nbytes(
                               [c for c in scan_cols if c in data.cols], False)))
        return BitmapRewrite(cost, bitmap_bytes, 0, 0, "storage")
    if comp_pred is not None and stor_pred is not None:
        # mixed: exchange bitmaps; storage scans only its sub-predicate's
        # columns + uncached outputs
        stor_cols = sorted((ex.columns_of(stor_pred) | set(uncached_out))
                           & set(data.cols))
        s_in = data.nbytes(stor_cols, True)
        disk_saved = req.cost.s_in - s_in
        s_out = (int(data.nbytes(uncached_out, False) * sel)
                 + bitmap_bytes + 64)
        cost = RequestCost(s_in=int(s_in), s_out=s_out + bitmap_bytes,
                           compute_in=int(data.nbytes(stor_cols, False)))
        return BitmapRewrite(cost, 2 * bitmap_bytes, int(disk_saved),
                             len(set(pred_cols) - set(stor_cols)), "mixed")
    return BitmapRewrite(req.cost, 0, 0, 0, "none")


def rewrite_all(reqs: List[PlannedRequest], cache: CacheState,
                table: str = "lineitem") -> Tuple[List[PlannedRequest], Dict]:
    """Apply bitmap rewriting to every request of ``table``; other tables
    pass through. Returns (new requests, metrics)."""
    out: List[PlannedRequest] = []
    metrics = {"bitmap_bytes": 0, "disk_saved": 0, "cols_skipped": 0,
               "net_baseline": 0, "net_bitmap": 0}
    for r in reqs:
        if r.table != table:
            out.append(r)
            continue
        rw = rewrite_request(r, cache)
        metrics["bitmap_bytes"] += rw.bitmap_bytes
        metrics["disk_saved"] += rw.disk_bytes_saved
        metrics["cols_skipped"] += rw.columns_skipped
        metrics["net_baseline"] += r.cost.s_out
        metrics["net_bitmap"] += rw.cost.s_out
        out.append(dataclasses.replace(r, cost=rw.cost))
    return out, metrics


# --------------------------------------------------- real bitmap execution
def storage_side_bitmap(part_data, predicate, out_cols_uncached):
    """Actually produce (packed bitmap, filtered uncached columns) at the
    storage node — the numpy half; the device half is kernels.bitmap_apply.
    Per-partition reference — the oracle for the batched form below."""
    words = ops.selection_bitmap(part_data, predicate)
    filtered = ops.apply_bitmap(part_data.select(
        [c for c in out_cols_uncached if c in part_data.cols]), words)
    return words, filtered


def storage_side_bitmap_batched(parts, predicate, out_cols_uncached,
                                table: str = "lineitem"
                                ) -> Tuple[List[np.ndarray], List[ColumnTable]]:
    """Fig-3 path over ALL partitions in one fused pass (the batch
    executor's ``bitmap_only`` aux): one predicate evaluation over the
    concatenation, per-partition packed bitmaps + filtered uncached columns
    split back out — byte-identical to looping ``storage_side_bitmap``."""
    cols = tuple(c for c in out_cols_uncached if c in parts[0].cols)
    plan = PushPlan(table, cols, predicate=predicate, bitmap_only=True)
    tabs, aux = compile_push_plan(plan).execute_batch_parts(parts)
    return [a["bitmap"] for a in aux], tabs


def compute_side_apply_batched(parts, bitmaps, out_cols,
                               table: str = "lineitem") -> List[ColumnTable]:
    """Fig-4 path over ALL partitions: the storage node applies
    compute-built bitmaps (predicate columns never scanned) and returns
    each partition's filtered output columns — byte-identical to
    per-partition ``execute_push_plan(plan, part, bitmap=words)``.

    Routed through the decision-faithful ``runtime.execute_split``: each
    partition becomes a pushdown ``PlannedRequest`` carrying its bitmap,
    so bitmap application runs under the same fused batch executor, span
    tree (execute_split → storage_execute → merge) and real-byte
    accounting as every other storage request — not a side door."""
    from repro.core import runtime
    from repro.core.arbitrator import PUSHDOWN
    from repro.storage.catalog import Partition
    cols = tuple(c for c in out_cols if c in parts[0].cols)
    plan = PushPlan(table, cols, apply_bitmap=True)
    cplan = compile_push_plan(plan)
    reqs: List[PlannedRequest] = []
    bms: Dict[int, np.ndarray] = {}
    for i, (p, words) in enumerate(zip(parts, bitmaps)):
        part = Partition(table, i, 0, p)
        reqs.append(PlannedRequest(i, "BITMAP", table, part, plan,
                                   cplan.estimate_cost(part)))
        bms[i] = words
    split = runtime.execute_split(reqs, {i: PUSHDOWN for i in bms},
                                  bitmaps=bms)
    merged = split.merged[table]
    out: List[ColumnTable] = []
    off = 0
    for o in split.outcomes:
        out.append(ColumnTable({c: v[off:off + o.rows_out]
                                for c, v in merged.cols.items()}))
        off += o.rows_out
    return out


def combine_bitmaps(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Cheap bitwise combine of exchanged bitmaps (§4.2)."""
    n = max(len(a), len(b))
    aa = np.zeros(n, np.uint32); aa[:len(a)] = a
    bb = np.zeros(n, np.uint32); bb[:len(b)] = b
    return aa & bb


def merged_verdicts(bitmaps: List[np.ndarray],
                    part_rows: List[int]) -> np.ndarray:
    """Unpack the per-partition §4.2 verdict bitmaps a bitmap-lowered
    frontier ships (``PushPlan.bitmap_only`` — see
    ``compiler/multitable.py``) into one boolean vector over the merged
    pre-filter row order. This is the compute layer's view of an
    exchanged multi-table sub-predicate: instead of re-reading the
    predicate columns across the join fan-out, it combines these words
    with the other table's verdicts via ``combine_bitmaps``-style bitwise
    ops. The exchange contract — each bitmap equals the pushed
    predicate's mask over the raw partition — is pinned by
    tests/test_cost_split.py."""
    return np.concatenate([ops.unpack_bitmap(words, int(n))
                           for words, n in zip(bitmaps, part_rows)])
