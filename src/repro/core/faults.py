"""Deterministic fault injection + the runtime's recovery contract.

Real disaggregated storage fleets fail, straggle, and time out — the
paper's §3 adaptive mechanism exists *because* the storage layer is a
shared, contended resource, yet a runtime that only reacts to load still
assumes every storage-side execution succeeds. This module gives the
engine a failure model it can rehearse against, deterministically:

- :class:`FaultPlan` — a seedable, schedule-driven injector the runtime
  consults at every storage-execute boundary. Rules are scoped per
  (node, path[, table]) and cover the four fleet failure archetypes:
  ``crash`` (the worker died), ``timeout`` (the request would blow its
  attempt budget), ``transient`` (retryable remote error), and
  ``straggler`` (the request completes, late). Draws are pure hashes of
  ``(seed, rule, node, path, table, group-key, attempt)`` — no RNG
  state, no wall clock — so a fault schedule replays **identically**
  regardless of thread interleaving, and every injection is logged for
  exact reconciliation against the runtime's counters.
- :class:`RetryPolicy` — capped exponential backoff with deterministic
  jitter under a *charged* per-request deadline budget (timeouts and
  backoffs consume nominal seconds whether or not the test actually
  sleeps), and the recovery contract on exhaustion: **demote the group
  to pushback** (ship the raw projection, replay the compiled plan
  compute-side — byte-identical by the PR-4 contract) rather than
  surface an error.
- :class:`HedgePolicy` — straggler hedging for the stream driver:
  duplicate a storage future that outlives a calibrated percentile of
  observed execution times; first completion wins, the loser is
  cancelled/discarded.
- :class:`CircuitBreaker` — per-(node, path) consecutive-failure trip
  with half-open probe recovery. The runtime records every storage
  outcome into it (and publishes the same signals as ``faults.node*``
  metrics, next to the ``stream.*`` gauges ``MeasuredLoad`` polls); the
  Arbitrator consults it so *new* decisions route around a tripped
  node's pushdown path until a probe succeeds.

Environment overrides (picked up by ``runtime.execute_split`` /
``run_stream`` when no explicit plan is configured):

- ``REPRO_FAULT_SPEC`` — e.g.
  ``"pushdown.crash:0.05,node1.pushdown.timeout:0.1,straggler:0.2:0.05"``
- ``REPRO_FAULT_SEED`` — integer seed (default 0)
- ``REPRO_FAULT_SLEEP_SCALE`` — scales *real* sleeps (backoff,
  straggler delay, timeout charges); 0 makes chaos tests instant while
  the charged deadline arithmetic stays exact.

Everything here is policy + bookkeeping; the execution-side integration
lives in ``core.runtime`` (see docs/faults.md).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import threading
from typing import Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import get_metrics

FAULT_CRASH = "crash"
FAULT_TIMEOUT = "timeout"
FAULT_TRANSIENT = "transient"
FAULT_STRAGGLER = "straggler"
FAULT_KINDS = (FAULT_CRASH, FAULT_TIMEOUT, FAULT_TRANSIENT, FAULT_STRAGGLER)
# kinds that abort the attempt (straggler completes, just late)
FAILURE_KINDS = (FAULT_CRASH, FAULT_TIMEOUT, FAULT_TRANSIENT)


class FaultExhausted(RuntimeError):
    """A request group ran out of retry budget with recovery disabled
    (``RetryPolicy.demote_on_exhaust=False`` — the fail-to-error baseline)
    or failed on a path that has no further fallback."""

    def __init__(self, kind: str, node: int, path: str, table: str,
                 attempts: int):
        super().__init__(
            f"storage {kind} on node {node} ({path}, table={table}) "
            f"persisted through {attempts} attempt(s)")
        self.kind = kind
        self.node = node
        self.path = path
        self.table = table
        self.attempts = attempts


class WorkerFault(RuntimeError):
    """A *real* storage-worker failure observed at the channel boundary
    (``distributed.workers``): the worker process died (``crash`` — the
    channel hit EOF, e.g. after a SIGKILL) or a request outlived the
    channel's deadline (``timeout``). The runtime's recovery loop treats
    these exactly like injected draws of the same kind — retry under the
    charged budget, then demote to pushback — so moving the fault domain
    from schedules to real processes changes *where* faults come from,
    never what recovery does. Real events are ledgered on the
    ``WorkerPool`` (``pool.events``), next to the ``FaultPlan``'s injected
    ledger; counters reconcile against the two ledgers' sum."""

    def __init__(self, kind: str, node: int, detail: str = ""):
        assert kind in (FAULT_CRASH, FAULT_TIMEOUT), kind
        super().__init__(f"storage worker {kind} on node {node}"
                         + (f": {detail}" if detail else ""))
        self.kind = kind
        self.node = node
        self.detail = detail


class HedgeAborted(RuntimeError):
    """A hedged race's loser observed its abort token between attempts
    and stopped instead of completing. Raised *inside the loser's future*
    — the stream driver never retrieves it (only the winner's result is
    read), so it surfaces nowhere; its purpose is to stop the loser from
    double-counting calibration samples, fault-ledger draws, and demotion
    counters after the race is already decided
    (tests/test_faults.py)."""

    def __init__(self, node: int, path: str, table: str):
        super().__init__(f"hedge loser aborted on node {node} "
                         f"({path}, table={table})")
        self.node = node
        self.path = path
        self.table = table


# --------------------------------------------------------------- fault plan
@dataclasses.dataclass(frozen=True)
class FaultRule:
    """One injection rule. ``prob`` is evaluated independently per
    (group, attempt) draw; ``param`` is the straggler delay in seconds
    (ignored by other kinds). ``node``/``path``/``table`` of ``None``
    match anything; ``max_times`` caps total injections (None = no cap,
    the only stateful part of a plan — deterministic schedules that use
    it depend on draw order, so keep it to single-threaded tests)."""
    kind: str
    prob: float
    param: Optional[float] = None
    node: Optional[int] = None
    path: Optional[str] = None
    table: Optional[str] = None
    max_times: Optional[int] = None

    def matches(self, node: int, path: str, table: str) -> bool:
        return ((self.node is None or self.node == node)
                and (self.path is None or self.path == path)
                and (self.table is None or self.table == table))


@dataclasses.dataclass(frozen=True)
class FaultAction:
    """What the plan injected for one draw."""
    kind: str
    param: Optional[float] = None
    rule: int = 0                      # index of the rule that fired


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One logged injection — the reconciliation ledger entry."""
    kind: str
    node: int
    path: str
    table: str
    key: str
    attempt: int
    salt: str
    rule: int


def _unit_draw(text: str) -> float:
    """Deterministic uniform [0, 1) from a key string (no RNG state)."""
    h = hashlib.blake2b(text.encode(), digest_size=8).digest()
    return int.from_bytes(h, "big") / 2.0 ** 64


class FaultPlan:
    """A deterministic fault schedule over (node, path, table, group).

    ``draw()`` is a pure function of the plan's seed/epoch and the draw
    coordinates, so concurrent drivers replay the same schedule in any
    interleaving; every injection is appended to a thread-safe event log
    (:meth:`events`) that tests reconcile exactly against the runtime's
    ``faults.*`` counters and per-request outcome accounting.

    ``epoch`` salts every draw: bump it (:meth:`bump_epoch`) to rehearse
    a *different* deterministic schedule with the same rules — the
    fail-to-error baseline uses this so a restarted query does not hit
    the byte-identical fault again forever.
    """

    def __init__(self, rules: Sequence[FaultRule], seed: int = 0):
        for r in rules:
            if r.kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {r.kind!r}")
            if not (0.0 <= r.prob <= 1.0):
                raise ValueError(f"fault prob out of range: {r.prob}")
        self.rules = tuple(rules)
        self.seed = int(seed)
        self.epoch = 0
        self._events: List[FaultEvent] = []
        self._fired: Dict[int, int] = {}       # rule idx -> times fired
        self._lock = threading.Lock()

    # ------------------------------------------------------------ schedule
    def _key(self, rule_idx: int, node: int, path: str, table: str,
             key: str, attempt: int, salt: str) -> str:
        return (f"{self.seed}|{self.epoch}|{rule_idx}|{node}|{path}|"
                f"{table}|{key}|{attempt}|{salt}")

    def draw(self, node: int, path: str, table: str, key: str,
             attempt: int, salt: str = "") -> Optional[FaultAction]:
        """The injection decision for one storage-execute attempt.
        ``key`` identifies the request group deterministically (the
        runtime uses ``"<min req_id>x<n requests>"``); ``salt``
        distinguishes otherwise-identical draws (hedge duplicates)."""
        for i, rule in enumerate(self.rules):
            if not rule.matches(node, path, table) or rule.prob <= 0.0:
                continue
            if rule.max_times is not None:
                with self._lock:
                    if self._fired.get(i, 0) >= rule.max_times:
                        continue
            u = _unit_draw(self._key(i, node, path, table, key, attempt,
                                     salt))
            if u < rule.prob:
                ev = FaultEvent(rule.kind, node, path, table, key, attempt,
                                salt, i)
                with self._lock:
                    self._fired[i] = self._fired.get(i, 0) + 1
                    self._events.append(ev)
                return FaultAction(rule.kind, rule.param, i)
        return None

    def jitter(self, node: int, path: str, table: str, key: str,
               attempt: int) -> float:
        """Deterministic uniform [0, 1) for backoff jitter — same
        coordinates as the draws, different salt, so jitter never
        correlates with the injection schedule."""
        return _unit_draw(self._key(-1, node, path, table, key, attempt,
                                    "jitter"))

    def bump_epoch(self) -> int:
        """Advance to the next deterministic schedule (see class doc)."""
        with self._lock:
            self.epoch += 1
            return self.epoch

    # ---------------------------------------------------------- the ledger
    def events(self) -> List[FaultEvent]:
        with self._lock:
            return list(self._events)

    def counts(self) -> Dict[str, int]:
        """Injected-event totals by kind (the reconciliation headline)."""
        out = {k: 0 for k in FAULT_KINDS}
        with self._lock:
            for ev in self._events:
                out[ev.kind] += 1
        return out

    def clear_events(self) -> None:
        with self._lock:
            self._events.clear()
            self._fired.clear()

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"FaultPlan(seed={self.seed}, epoch={self.epoch}, "
                f"rules={list(self.rules)!r})")

    # ------------------------------------------------------------- parsing
    @classmethod
    def from_spec(cls, spec: str, seed: int = 0) -> "FaultPlan":
        """Parse the ``REPRO_FAULT_SPEC`` grammar: comma-separated
        clauses ``[node<N>.][pushdown|pushback.][<table>.]kind:prob[:param]``.

        Examples::

            crash:0.1                       # 10% of any storage execute
            pushdown.transient:0.2          # pushdown attempts only
            node1.pushdown.timeout:0.05     # node 1's pushdown path
            straggler:0.3:0.05              # 30% of groups finish 50ms late
            node0.lineitem.crash:1.0        # every lineitem group on node 0
        """
        rules: List[FaultRule] = []
        for clause in spec.split(","):
            clause = clause.strip()
            if not clause:
                continue
            head, _, tail = clause.partition(":")
            if not tail:
                raise ValueError(f"fault clause needs kind:prob — {clause!r}")
            parts = head.split(".")
            kind = parts[-1]
            if kind not in FAULT_KINDS:
                raise ValueError(f"unknown fault kind {kind!r} in {clause!r}")
            node = path = table = None
            for scope in parts[:-1]:
                if scope.startswith("node") and scope[4:].isdigit():
                    node = int(scope[4:])
                elif scope in ("pushdown", "pushback"):
                    path = scope
                else:
                    table = scope
            nums = tail.split(":")
            prob = float(nums[0])
            param = float(nums[1]) if len(nums) > 1 else None
            rules.append(FaultRule(kind, prob, param, node, path, table))
        return cls(rules, seed=seed)


_ENV_CACHE: Dict[Tuple[str, str], Optional[FaultPlan]] = {}


def env_plan() -> Optional[FaultPlan]:
    """The process-wide plan from ``REPRO_FAULT_SPEC``/``REPRO_FAULT_SEED``
    (None when unset). Cached per (spec, seed) so repeated runtime calls
    share one event ledger — reassign the env vars to get a fresh plan."""
    spec = os.environ.get("REPRO_FAULT_SPEC", "")
    if not spec.strip():
        return None
    seed = os.environ.get("REPRO_FAULT_SEED", "0")
    key = (spec, seed)
    if key not in _ENV_CACHE:
        _ENV_CACHE[key] = FaultPlan.from_spec(spec, seed=int(seed))
    return _ENV_CACHE[key]


def sleep_scale() -> float:
    """Multiplier for *real* sleeps (charged seconds are always nominal)."""
    try:
        return max(0.0, float(os.environ.get("REPRO_FAULT_SLEEP_SCALE",
                                             "1.0")))
    except ValueError:
        return 1.0


# ------------------------------------------------------------ retry policy
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry/deadline semantics for one storage request group.

    The deadline is a *charged* budget: every failed attempt charges its
    nominal detection cost (``attempt_timeout_s`` for timeouts,
    ``detect_s`` for crash/transient) and every backoff its nominal
    duration, whether or not the process really slept (real sleeps are
    ``nominal * sleep_scale``; see :func:`sleep_scale`). Charged
    arithmetic makes exhaustion — and therefore demotion, and therefore
    the whole recovery trajectory — machine-independent and replayable.

    On exhaustion (attempts or budget): ``demote_on_exhaust=True`` (the
    contract) demotes the group to pushback; ``False`` raises
    :class:`FaultExhausted` — the fail-to-error baseline the chaos
    benchmark beats."""
    max_attempts: int = 3
    backoff_base_s: float = 0.002
    backoff_mult: float = 2.0
    backoff_cap_s: float = 0.05
    jitter: float = 0.5            # +/- fraction of the backoff
    deadline_s: float = 0.25       # charged budget across all attempts
    attempt_timeout_s: float = 0.03
    detect_s: float = 0.002
    demote_on_exhaust: bool = True
    sleep_scale: Optional[float] = None   # None -> env (REPRO_FAULT_SLEEP_SCALE)

    def charge(self, kind: str) -> float:
        return self.attempt_timeout_s if kind == FAULT_TIMEOUT \
            else self.detect_s

    def backoff_s(self, attempt: int, u: float) -> float:
        """Capped exponential backoff for retry number ``attempt`` (1-based),
        jittered by the deterministic uniform ``u``."""
        b = min(self.backoff_cap_s,
                self.backoff_base_s * self.backoff_mult ** (attempt - 1))
        return b * (1.0 - self.jitter + 2.0 * self.jitter * u)

    def real_scale(self) -> float:
        return self.sleep_scale if self.sleep_scale is not None \
            else sleep_scale()


# ------------------------------------------------------------ hedge policy
@dataclasses.dataclass
class HedgePolicy:
    """Straggler hedging for ``run_stream``'s storage futures.

    The hedge delay is calibrated online: ``multiplier`` times the
    ``percentile``-th percentile of the storage-execute durations
    observed so far in the same stream (at least ``min_delay_s``; no
    hedging before ``min_samples`` observations). ``fixed_delay_s``
    pins the delay instead — chaos tests use it to make hedges fire
    deterministically."""
    enabled: bool = True
    percentile: float = 95.0
    multiplier: float = 3.0
    min_samples: int = 6
    min_delay_s: float = 0.01
    fixed_delay_s: Optional[float] = None

    def delay_s(self, samples: Sequence[float]) -> Optional[float]:
        """Seconds to wait on a storage future before hedging it
        (None = do not hedge)."""
        if not self.enabled:
            return None
        if self.fixed_delay_s is not None:
            return self.fixed_delay_s
        if len(samples) < self.min_samples:
            return None
        s = sorted(samples)
        rank = min(len(s) - 1,
                   max(0, int(round(self.percentile / 100.0 * (len(s) - 1)))))
        return max(self.min_delay_s, self.multiplier * s[rank])


# --------------------------------------------------------- circuit breaker
BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"

ROUTE_ALLOW = "allow"
ROUTE_DENY = "deny"
ROUTE_PROBE = "probe"


class CircuitBreaker:
    """Per-(node, path) consecutive-failure breaker with half-open probes.

    State machine per (node, path):

    - ``closed`` — normal routing; ``trip_after`` *consecutive* recorded
      failures opens it.
    - ``open`` — :meth:`route` answers ``deny`` (the Arbitrator sends the
      request down the other path). After ``probe_after`` denials the
      breaker half-opens and grants exactly one ``probe``.
    - ``half_open`` — one probe is in flight; further routing is denied.
      A recorded success closes the breaker, a failure re-opens it (and
      the denial count restarts).

    Counting *routing decisions* rather than wall clock keeps recovery
    deterministic under any thread interleaving — the same property the
    fault schedule has. The runtime records every storage outcome here
    (and publishes the matching ``faults.node<N>.<path>.failures`` /
    ``.successes`` counters next to the ``stream.*`` gauges that
    ``MeasuredLoad`` polls, so a distributed poller sees the same
    signals the breaker trips on). Thread-safe."""

    def __init__(self, trip_after: int = 3, probe_after: int = 8):
        assert trip_after >= 1 and probe_after >= 1
        self.trip_after = trip_after
        self.probe_after = probe_after
        self._state: Dict[Tuple[int, str], str] = {}
        self._consec: Dict[Tuple[int, str], int] = {}
        self._denied: Dict[Tuple[int, str], int] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------- routing
    def state(self, node: int, path: str) -> str:
        with self._lock:
            return self._state.get((node, path), BREAKER_CLOSED)

    def route(self, node: int, path: str) -> str:
        """Routing verdict for one *new* decision on (node, path):
        ``allow`` | ``deny`` | ``probe`` (probe = route it, and the next
        recorded outcome decides whether the breaker closes)."""
        key = (node, path)
        with self._lock:
            st = self._state.get(key, BREAKER_CLOSED)
            if st == BREAKER_CLOSED:
                return ROUTE_ALLOW
            if st == BREAKER_HALF_OPEN:
                return ROUTE_DENY            # one probe already in flight
            denied = self._denied.get(key, 0) + 1
            if denied >= self.probe_after:
                self._state[key] = BREAKER_HALF_OPEN
                self._denied[key] = 0
                get_metrics().counter("breaker.probe").inc()
                return ROUTE_PROBE
            self._denied[key] = denied
            get_metrics().counter("breaker.denied").inc()
            return ROUTE_DENY

    # ------------------------------------------------------------ feedback
    def record_failure(self, node: int, path: str) -> None:
        key = (node, path)
        with self._lock:
            st = self._state.get(key, BREAKER_CLOSED)
            n = self._consec.get(key, 0) + 1
            self._consec[key] = n
            if st == BREAKER_HALF_OPEN or \
                    (st == BREAKER_CLOSED and n >= self.trip_after):
                self._state[key] = BREAKER_OPEN
                self._denied[key] = 0
                get_metrics().counter("breaker.trip").inc()

    def record_success(self, node: int, path: str) -> None:
        key = (node, path)
        with self._lock:
            self._consec[key] = 0
            if self._state.get(key, BREAKER_CLOSED) != BREAKER_CLOSED:
                self._state[key] = BREAKER_CLOSED
                self._denied[key] = 0
                get_metrics().counter("breaker.close").inc()

    def snapshot(self) -> Dict[str, Dict]:
        with self._lock:
            keys = set(self._state) | set(self._consec)
            return {f"node{n}.{p}": {
                "state": self._state.get((n, p), BREAKER_CLOSED),
                "consecutive_failures": self._consec.get((n, p), 0),
                "denied_since_open": self._denied.get((n, p), 0),
            } for n, p in sorted(keys)}
