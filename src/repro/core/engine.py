"""End-to-end query engine over the disaggregated storage layer.

For one query the engine:

1. plans per-partition pushdown requests (one per partition of every
   scanned table — the paper's request granularity),
2. runs the Arbitrator + fluid simulator to obtain the pushdown/pushback
   decisions and the simulated timeline (this is the paper's measured
   quantity — the container has no real 16-core storage node),
3. *really executes* both paths (numpy storage operators; the pushed-back
   portion uses the same operators at the compute layer — and optionally
   the TPU Pallas kernels, validated in tests) and merges, so correctness
   is independent of the scheduling mode — by default through the fused
   batched executor (``core.executor``: compile-once plans, one vectorized
   pass per table), with the seed's per-partition loop kept as the
   ``executor="reference"`` oracle,
4. charges the non-pushable portion (joins/final aggs) to the compute
   layer's bandwidth.

Modes: no_pushdown / eager / adaptive / adaptive_pa (§6.2 baselines).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import optimum
from repro.core.arbitrator import PUSHBACK, PUSHDOWN
from repro.core.cost import RequestCost, StorageResources
from repro.core.executor import compile_push_plan
from repro.core.plan import PushPlan, actual_out_bytes, execute_push_plan
from repro.core.simulator import (MODE_ADAPTIVE, MODE_ADAPTIVE_PA, MODE_EAGER,
                                  MODE_NO_PUSHDOWN, SimRequest, SimResult,
                                  simulate)
from repro.queryproc.queries import Query
from repro.queryproc.table import ColumnTable
from repro.storage.catalog import Catalog, Partition

MODES = (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE, MODE_ADAPTIVE_PA)


EXECUTOR_BATCHED = "batched"      # compile-once plans, one pass per table
EXECUTOR_REFERENCE = "reference"  # per-partition interpretive oracle


@dataclasses.dataclass
class EngineConfig:
    res: StorageResources = StorageResources()
    mode: str = MODE_ADAPTIVE
    compute_bw: float = 2.4e9   # compute-node operator bandwidth (16 vCPU)
    num_compute_nodes: int = 1
    executor: str = EXECUTOR_BATCHED  # real-execution path (results identical)
    # adaptive filter stage: estimated selectivity at/above which the batch
    # executor concatenates whole columns then masks once instead of
    # gathering survivors per partition. None = the import-time calibrated
    # crossover (core.executor.FILTER_GATHER_THRESHOLD). Bytes identical
    # either way — this knob is purely a performance override.
    filter_gather_threshold: Optional[float] = None


@dataclasses.dataclass
class PlannedRequest:
    req_id: int
    query_id: str
    table: str
    part: Partition
    plan: PushPlan
    cost: RequestCost


@dataclasses.dataclass
class QueryRun:
    qid: str
    result: ColumnTable
    sim: SimResult
    t_pushable: float
    t_nonpushable: float
    requests: List[PlannedRequest]
    net_bytes: float
    n_admitted: int
    n_pushed_back: int

    @property
    def t_total(self) -> float:
        return self.t_pushable + self.t_nonpushable


def plan_requests(query: Query, catalog: Catalog, start_id: int = 0
                  ) -> List[PlannedRequest]:
    out: List[PlannedRequest] = []
    rid = start_id
    for table, plan in query.plans.items():
        # compile once per (query, table): the cost model's plan-level
        # invariants (accessed columns, selectivity closure) are shared by
        # every partition instead of recomputed ~160 times
        cplan = compile_push_plan(plan)
        for part in catalog.partitions_of(table):
            out.append(PlannedRequest(rid, query.qid, table, part, plan,
                                      cplan.estimate_cost(part)))
            rid += 1
    return out


def execute_requests(reqs: List[PlannedRequest],
                     executor: str = EXECUTOR_BATCHED,
                     filter_gather_threshold: Optional[float] = None
                     ) -> Dict[str, ColumnTable]:
    """Run every pushable sub-plan (path-independent result) and merge.

    ``executor="batched"`` stacks all partitions sharing one plan and runs a
    single fused, vectorized pass per (table, plan); ``"reference"`` is the
    seed's per-partition interpretive loop (the correctness oracle). Both
    return byte-identical merged tables (tests/test_executor.py) — with one
    caveat: a hand-built request list interleaving *several distinct plans
    for one table* merges group-by-group under "batched" (same rows, rows
    ordered per plan group rather than per request)."""
    if executor == EXECUTOR_REFERENCE:
        by_table: Dict[str, List[ColumnTable]] = {}
        for r in reqs:
            res, _aux = execute_push_plan(r.plan, r.part.data)
            by_table.setdefault(r.table, []).append(res)
        return {t: ColumnTable.concat(parts) for t, parts in by_table.items()}
    groups: Dict[Tuple[str, int], List[PlannedRequest]] = {}
    for r in reqs:
        groups.setdefault((r.table, id(r.plan)), []).append(r)
    by_table: Dict[str, List[ColumnTable]] = {}
    for (table, _pid), rs in groups.items():
        by_table.setdefault(table, []).append(
            compile_push_plan(rs[0].plan).execute_batch(
                [r.part.data for r in rs],
                threshold=filter_gather_threshold))
    # a table normally carries one plan (query.plans is table-keyed); with
    # hand-built request lists carrying several, merge in group order
    return {t: parts[0] if len(parts) == 1 else ColumnTable.concat(parts)
            for t, parts in by_table.items()}


def nonpushable_time(merged: Dict[str, ColumnTable], cfg: EngineConfig) -> float:
    """Joins/final aggregation at the compute layer: modeled as its input
    bytes over the compute-node operator bandwidth (stable across modes —
    the paper's Fig 9 shows exactly this invariance)."""
    b = sum(t.nbytes(stored=False) for t in merged.values())
    return b / (cfg.compute_bw * cfg.num_compute_nodes)


def run_query(query: Query, catalog: Catalog, cfg: EngineConfig,
              requests: Optional[List[PlannedRequest]] = None) -> QueryRun:
    reqs = requests if requests is not None else plan_requests(query, catalog)
    sim_reqs = [SimRequest(r.req_id, r.part.node_id, query.qid, r.cost)
                for r in reqs]
    sim = simulate(sim_reqs, cfg.res, cfg.mode)
    merged = execute_requests(reqs, cfg.executor,
                              cfg.filter_gather_threshold)
    result = query.compute(merged)
    t_np = nonpushable_time(merged, cfg)
    return QueryRun(
        qid=query.qid, result=result, sim=sim,
        t_pushable=sim.makespan, t_nonpushable=t_np, requests=reqs,
        net_bytes=sim.net_bytes,
        n_admitted=sim.admitted(query.qid),
        n_pushed_back=sim.pushed_back_by_query.get(query.qid, 0))


def run_concurrent(queries: List[Query], catalog: Catalog, cfg: EngineConfig
                   ) -> Dict[str, QueryRun]:
    """Multiple queries submitted simultaneously (§6.2 PA-aware experiment).
    All requests share the storage nodes' wait queues and slots."""
    all_reqs: List[PlannedRequest] = []
    for q in queries:
        all_reqs.extend(plan_requests(q, catalog, start_id=len(all_reqs)))
    sim_reqs = [SimRequest(r.req_id, r.part.node_id, r.query_id, r.cost)
                for r in all_reqs]
    sim = simulate(sim_reqs, cfg.res, cfg.mode)
    out: Dict[str, QueryRun] = {}
    for q in queries:
        reqs = [r for r in all_reqs if r.query_id == q.qid]
        merged = execute_requests(reqs, cfg.executor,
                                  cfg.filter_gather_threshold)
        result = q.compute(merged)
        t_np = nonpushable_time(merged, cfg)
        out[q.qid] = QueryRun(
            qid=q.qid, result=result, sim=sim,
            t_pushable=sim.finish_by_query[q.qid], t_nonpushable=t_np,
            requests=reqs, net_bytes=sim.net_bytes_by_query[q.qid],
            n_admitted=sim.admitted(q.qid),
            n_pushed_back=sim.pushed_back_by_query.get(q.qid, 0))
    return out


def compile_and_run(qid: str, catalog: Catalog, cfg: EngineConfig,
                    fact_selectivity: Optional[float] = None) -> QueryRun:
    """Compiler front door: logical-plan IR -> amenability split -> run.
    Equivalent to ``run_query(compiler.compile_query(qid), ...)``."""
    from repro.compiler import compile_query  # deferred: avoids cycle
    return run_query(compile_query(qid, fact_selectivity), catalog, cfg)


# ------------------------------------------------------------ validation
def theoretical_split(query: Query, catalog: Catalog, res: StorageResources):
    """Discrete oracle split (§3.1) for the gap evaluation (Fig 7)."""
    reqs = plan_requests(query, catalog)
    return optimum.discrete_optimum([r.cost for r in reqs], res)


def results_equal(a: ColumnTable, b: ColumnTable, tol: float = 1e-6) -> bool:
    if set(a.columns) != set(b.columns) or len(a) != len(b):
        return False
    for c in a.columns:
        x, y = np.asarray(a.cols[c]), np.asarray(b.cols[c])
        if x.dtype.kind in "fc" or y.dtype.kind in "fc":
            if not np.allclose(np.sort(x), np.sort(y), rtol=tol, atol=tol):
                return False
        elif not np.array_equal(np.sort(x), np.sort(y)):
            return False
    return True
