"""End-to-end query engine over the disaggregated storage layer.

For one query the engine:

1. plans per-partition pushdown requests (one per partition of every
   scanned table — the paper's request granularity),
2. runs the Arbitrator + fluid simulator to obtain the pushdown/pushback
   decisions and the simulated timeline (the timeline is the paper's
   measured quantity — the container has no real 16-core storage node),
3. *really executes* the decision split (``core.runtime``): pushdown
   requests run storage-side through the fused batched executor
   (``core.executor``; the seed's per-partition loop stays as the
   ``executor="reference"`` oracle), pushed-back requests ship the raw
   accessed-column projection and the compute layer replays the same
   compiled plan — merged byte-identically for any decision vector, so
   correctness is independent of the scheduling mode while the bytes
   really flow where the Arbitrator sent them,
4. charges the non-pushable portion (joins/final aggs) to the compute
   layer's bandwidth, and reconciles real shipped bytes against the
   simulator's ``net_bytes``.

Modes: no_pushdown / eager / adaptive / adaptive_pa (§6.2 baselines).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import optimum, runtime
from repro.core.arbitrator import PUSHBACK, PUSHDOWN, MeasuredLoad
from repro.obs import trace as obs_trace
from repro.obs.metrics import get_metrics
from repro.core.cost import (CardinalityCorrector, RequestCost,
                             StorageResources)
from repro.core.executor import (EXECUTOR_BATCHED, EXECUTOR_REFERENCE,
                                 compile_push_plan)
from repro.core.plan import PushPlan, execute_push_plan, plan_signature
from repro.core.simulator import (MODE_ADAPTIVE, MODE_ADAPTIVE_PA, MODE_EAGER,
                                  MODE_NO_PUSHDOWN, SimRequest, SimResult,
                                  simulate)
from repro.queryproc.queries import Query
from repro.queryproc.table import ColumnTable
from repro.storage.catalog import Catalog, Partition

MODES = (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE, MODE_ADAPTIVE_PA)

# storage tiers (EngineConfig.storage_tier): where the storage side of a
# split really runs.
#   inproc  — partitions execute in this process (the oracle; the seed's
#             behavior, byte-for-byte)
#   process — one real storage-worker process per catalog node
#             (distributed.workers.WorkerPool): plans dispatch over a
#             length-prefixed wire codec, pushback ships real serialized
#             bytes, workers publish live load signals, and worker death
#             flows through retry -> demote recovery. Results are
#             byte-identical across tiers for any decision vector and
#             fault schedule (docs/distributed.md).
STORAGE_INPROC = "inproc"
STORAGE_PROCESS = "process"
STORAGE_TIERS = (STORAGE_INPROC, STORAGE_PROCESS)


def resolve_tier(cfg, catalog: Catalog):
    """The worker pool a config's storage tier routes through, or ``None``
    for the in-process oracle. An explicit ``cfg.worker_pool`` (a
    ``distributed.workers.WorkerPool``, e.g. a test's own pool with a
    pinned kill schedule) wins over the named tier; otherwise
    ``storage_tier="process"`` resolves to the shared per-catalog pool
    (``workers.pool_for``), sized by the config's ``pd_slots``."""
    pool = getattr(cfg, "worker_pool", None)
    if pool is not None:
        return pool
    tier = getattr(cfg, "storage_tier", STORAGE_INPROC)
    if tier in (None, STORAGE_INPROC):
        return None
    if tier != STORAGE_PROCESS:
        raise ValueError(f"unknown storage_tier {tier!r}; "
                         f"expected one of {STORAGE_TIERS}")
    from repro.distributed.workers import pool_for  # lazy: keeps the
    #   multiprocessing machinery off every in-process import path
    return pool_for(catalog, pd_slots=cfg.res.pd_slots)


@dataclasses.dataclass
class EngineConfig:
    res: StorageResources = StorageResources()
    mode: str = MODE_ADAPTIVE
    compute_bw: float = 2.4e9   # compute-node operator bandwidth (16 vCPU)
    num_compute_nodes: int = 1
    executor: str = EXECUTOR_BATCHED  # real-execution path (results identical)
    # adaptive filter stage: estimated selectivity at/above which the batch
    # executor concatenates whole columns then masks once instead of
    # gathering survivors per partition. None = the import-time calibrated
    # crossover (core.executor.FILTER_GATHER_THRESHOLD). Bytes identical
    # either way — this knob is purely a performance override.
    filter_gather_threshold: Optional[float] = None
    # online s_out cardinality correction (core.cost.CardinalityCorrector):
    # when set, plan_requests rescales every request's estimated s_out by
    # the measured ratios and each executed run feeds its reconciliation
    # back — repeated runs converge the cost model (and through it the
    # Arbitrator's decisions) toward observed bytes. Purely an estimation
    # knob: results are byte-identical with or without it.
    corrector: Optional[CardinalityCorrector] = None
    # semantic pushed-result cache (core.result_cache.ResultCache): when
    # set, storage-side pushdown execution serves/fills it per partition,
    # and plan_requests probes it so warm partitions arbitrate with
    # compute_in=0 and the *known* result bytes as s_out — a cache hit
    # makes pushdown nearly free, flipping warm decisions toward pushdown.
    # Results stay byte-identical with or without it (the cache's core
    # contract; tests/test_cache.py).
    result_cache: Optional[object] = None
    # arbitrate over *measured* occupancy signals (the stream.* gauges
    # run_stream publishes every dispatch wave) instead of the fluid
    # model's own wait queues — see arbitrator.MeasuredLoad. Default ON
    # since the chaos soak (docs/faults.md) stress-tested the port; when a
    # node's gauges were never published the Arbitrator still falls back
    # to its fluid queue, and measured_feedback=False restores the pure
    # fluid reference behavior (regression-pinned in tests/test_cache.py).
    measured_feedback: bool = True
    # ---- fault tolerance (core.faults; docs/faults.md) -------------------
    # a FaultPlan makes every storage-execute boundary consult the
    # injection schedule; with one active (here or via REPRO_FAULT_SPEC)
    # execution retries under `retry` (default RetryPolicy) and demotes
    # exhausted pushdown groups to pushback — results stay byte-identical
    # under ANY schedule. All four default to None: fault-free configs run
    # the exact pre-fault code path.
    faults: Optional[object] = None       # faults.FaultPlan
    retry: Optional[object] = None        # faults.RetryPolicy
    hedge: Optional[object] = None        # faults.HedgePolicy (run_stream)
    breaker: Optional[object] = None      # faults.CircuitBreaker
    # storage tier (STORAGE_TIERS): "inproc" executes the storage side in
    # this process (the oracle); "process" dispatches it to real worker
    # processes over the wire (distributed.workers) — byte-identical
    # results, real transfer bytes, live worker load signals, and a real
    # process-failure fault domain. `worker_pool` (a WorkerPool) overrides
    # the named tier with an explicitly constructed pool.
    storage_tier: str = STORAGE_INPROC
    worker_pool: Optional[object] = None
    # residual backend (runtime.RESIDUALS): "interpreter" walks the
    # residual IR with the numpy oracle; "tensor" compiles it into fused
    # jax.jit programs (compiler.tensorize — jit-cached per input-shape
    # bucket); "auto" picks tensor at/above the calibrated row-count
    # crossover. Results are identical under every backend for every
    # mode and decision vector (tests/test_tensorize.py) — this knob is
    # purely a performance override, like filter_gather_threshold.
    residual: str = runtime.RESIDUAL_INTERPRETER


@dataclasses.dataclass
class PlannedRequest:
    req_id: int
    query_id: str
    table: str
    part: Partition
    plan: PushPlan
    cost: RequestCost      # as arbitrated (corrector-rescaled when active)
    s_out_raw: int = 0     # uncorrected s_out estimate — what the
    #                        corrector's feedback is measured against


@dataclasses.dataclass
class QueryRun:
    qid: str
    result: ColumnTable
    sim: SimResult
    t_pushable: float
    t_nonpushable: float
    requests: List[PlannedRequest]
    net_bytes: float            # simulated traffic (cost-model s_out/s_in)
    n_admitted: int
    n_pushed_back: int
    # real-execution accounting (core.runtime): bytes that actually crossed
    # the storage->compute boundary under the decision split, and the
    # reconciliation against the simulated figure above
    real_net_bytes: float = 0.0
    net_bytes_recon: Optional[Dict] = None
    outcomes: Optional[List[runtime.RequestOutcome]] = None
    # fault/recovery accounting (None on fault-free runs): n_demoted,
    # retries, faults_injected — reconciles exactly with the FaultPlan's
    # event ledger (tests/test_faults.py)
    recovery: Optional[Dict] = None
    # residual-backend accounting: which backend evaluated the residual
    # ("interpreter" | "tensor") and, on the tensor path, its jit-cache
    # hit/miss + fallback counters (None when the interpreter ran)
    residual_backend: str = "interpreter"
    residual_jit: Optional[Dict] = None

    @property
    def t_total(self) -> float:
        return self.t_pushable + self.t_nonpushable

    @property
    def cache_hits(self) -> int:
        """Pushdown partitions served by the pushed-result cache."""
        return sum(1 for o in (self.outcomes or ()) if o.cache)

    @property
    def n_demoted(self) -> int:
        """Admitted-pushdown requests recovered via pushback demotion."""
        return sum(1 for o in (self.outcomes or ()) if o.demoted)


def plan_requests(query: Query, catalog: Catalog, start_id: int = 0,
                  corrector: Optional[CardinalityCorrector] = None,
                  cache=None) -> List[PlannedRequest]:
    tr = obs_trace.get_tracer()
    with tr.span("plan_requests", qid=query.qid) as sp:
        out: List[PlannedRequest] = []
        rid = start_id
        n_warm = 0
        for table, plan in query.plans.items():
            # compile once per (query, table): the cost model's plan-level
            # invariants (accessed columns, selectivity closure) are shared
            # by every partition instead of recomputed ~160 times
            cplan = compile_push_plan(plan)
            sig = plan_signature(plan)
            for part in catalog.partitions_of(table):
                cost = cplan.estimate_cost(part)
                raw = cost.s_out
                hint = (cache.cost_hint(cplan, part)
                        if cache is not None else None)
                if hint is not None:
                    # warm partition: the pushed result already exists, so
                    # pushdown pays no storage CPU and ships a *known* byte
                    # count — the corrector is skipped (nothing estimated)
                    cost = dataclasses.replace(cost, compute_in=0,
                                               s_out=max(64, int(hint)))
                    n_warm += 1
                elif corrector is not None:
                    cost = corrector.correct(query.qid, table, sig, cost)
                out.append(PlannedRequest(rid, query.qid, table, part, plan,
                                          cost, s_out_raw=raw))
                rid += 1
        if tr.enabled:
            sp.set(n_requests=len(out), n_tables=len(query.plans),
                   est_s_out=sum(r.cost.s_out for r in out),
                   n_cache_warm=n_warm,
                   # the corrector's EWMA state *as used* for these
                   # estimates — decision-time provenance in the trace
                   corrector_state=(corrector.state(query.qid)
                                    if corrector is not None else None))
    return out


def _measured_of(cfg: EngineConfig) -> Optional[MeasuredLoad]:
    """The measured-signal port, when the config opts in (default off)."""
    return MeasuredLoad() if cfg.measured_feedback else None


def execute_requests(reqs: List[PlannedRequest],
                     executor: str = EXECUTOR_BATCHED,
                     filter_gather_threshold: Optional[float] = None
                     ) -> Dict[str, ColumnTable]:
    """Run every pushable sub-plan storage-side and merge in request order.

    ``executor="batched"`` stacks all partitions sharing one plan and runs a
    single fused, vectorized pass per (table, plan); ``"reference"`` is the
    seed's per-partition interpretive loop (the correctness oracle). Both
    return byte-identical merged tables for **any** request list
    (tests/test_executor.py): a table whose requests interleave several
    distinct plans merges its per-partition results back in original
    request order via ``execute_batch_parts``."""
    if executor == EXECUTOR_REFERENCE:
        by_table: Dict[str, List[ColumnTable]] = {}
        for r in reqs:
            res, _aux = execute_push_plan(r.plan, r.part.data)
            by_table.setdefault(r.table, []).append(res)
        return {t: ColumnTable.concat(parts) for t, parts in by_table.items()}
    by_table: Dict[str, List[PlannedRequest]] = {}
    for r in reqs:
        by_table.setdefault(r.table, []).append(r)
    if any(len({id(r.plan) for r in rs}) > 1 for rs in by_table.values()):
        # multi-plan tables: the request-order reassembly already lives in
        # the decision-split machinery — an empty decision vector routes
        # every request storage-side (pushdown is the default)
        return runtime.execute_split(reqs, {}, executor,
                                     filter_gather_threshold).merged
    # the common case: one plan per table — each table's requests form one
    # batch in request order, so the fused merged output needs no
    # reassembly
    return {table: compile_push_plan(rs[0].plan).execute_batch(
                [r.part.data for r in rs],
                threshold=filter_gather_threshold)
            for table, rs in by_table.items()}


def nonpushable_time(merged: Dict[str, ColumnTable], cfg: EngineConfig) -> float:
    """Joins/final aggregation at the compute layer: modeled as its input
    bytes over the compute-node operator bandwidth (stable across modes —
    the paper's Fig 9 shows exactly this invariance)."""
    b = sum(t.nbytes(stored=False) for t in merged.values())
    return b / (cfg.compute_bw * cfg.num_compute_nodes)


def _run_decided(query: Query, reqs: List[PlannedRequest], sim: SimResult,
                 cfg: EngineConfig, t_pushable: float, net_bytes: float,
                 bitmaps: Optional[Dict[int, np.ndarray]] = None,
                 tier=None) -> QueryRun:
    """Real execution routed by the simulator's decision vector
    (``core.runtime.execute_split``), plus the net-bytes reconciliation.
    ``bitmaps`` (req_id -> packed words) feeds apply_bitmap plans;
    ``tier`` (resolve_tier) routes the storage side through real worker
    processes."""
    tr = obs_trace.get_tracer()
    split = runtime.execute_split(reqs, sim.decisions(), cfg.executor,
                                  cfg.filter_gather_threshold,
                                  bitmaps=bitmaps, cache=cfg.result_cache,
                                  faults=cfg.faults, retry=cfg.retry,
                                  breaker=cfg.breaker, tier=tier)
    # the real split IS the simulated split — one decision vector, two
    # uses; under an active fault plan, admitted requests that exhausted
    # their retries were *demoted* to pushback (graceful degradation, the
    # recovery contract) and are accounted separately
    assert split.n_pushdown + split.n_demoted == sim.admitted(query.qid), \
        (query.qid, split.n_pushdown, split.n_demoted,
         sim.admitted(query.qid))
    if cfg.corrector is not None:
        # close the loop: measured pushdown bytes correct future estimates
        runtime.feed_corrector(cfg.corrector, query.qid, reqs,
                               split.outcomes)
    with tr.span("residual_compute", qid=query.qid,
                 backend=cfg.residual) as rsp:
        result, trun = runtime.run_residual(query, split.merged,
                                            cfg.residual)
        if tr.enabled and trun is not None:
            tr.amend(rsp, backend="tensor", jit_hits=trun.jit_hits,
                     jit_misses=trun.jit_misses, fell_back=trun.fell_back)
    residual_jit = None
    if trun is not None:
        residual_jit = {"hits": trun.jit_hits, "misses": trun.jit_misses,
                        "fell_back": trun.fell_back,
                        "observed": trun.observed,
                        "n_stages": trun.n_stages}
    t_np = nonpushable_time(split.merged, cfg)
    m = get_metrics()
    m.counter("engine.queries").inc()
    m.counter("engine.requests.pushdown").inc(split.n_pushdown)
    m.counter("engine.requests.pushback").inc(len(reqs) - split.n_pushdown)
    m.counter("engine.net_bytes.real").inc(split.real_net_bytes)
    n_hit = sum(1 for o in split.outcomes if o.cache)
    if n_hit:
        m.counter("engine.cache_hits").inc(n_hit)
    if split.n_demoted:
        m.counter("engine.requests.demoted").inc(split.n_demoted)
    recovery = None
    if split.n_demoted or split.retries or split.faults_injected:
        recovery = {"n_demoted": split.n_demoted,
                    "retries": split.retries,
                    "faults_injected": split.faults_injected}
    return QueryRun(
        qid=query.qid, result=result, sim=sim,
        t_pushable=t_pushable, t_nonpushable=t_np, requests=reqs,
        net_bytes=net_bytes,
        n_admitted=sim.admitted(query.qid),
        n_pushed_back=sim.pushed_back_by_query.get(query.qid, 0),
        real_net_bytes=split.real_net_bytes,
        net_bytes_recon=runtime.reconcile_net_bytes(sim, reqs, split),
        outcomes=split.outcomes, recovery=recovery,
        residual_backend=("tensor" if trun is not None else "interpreter"),
        residual_jit=residual_jit)


def run_query(query: Query, catalog: Catalog, cfg: EngineConfig,
              requests: Optional[List[PlannedRequest]] = None,
              bitmaps: Optional[Dict[int, np.ndarray]] = None) -> QueryRun:
    tr = obs_trace.get_tracer()
    with tr.span("query", qid=query.qid, mode=cfg.mode) as qs:
        reqs = requests if requests is not None \
            else plan_requests(query, catalog, corrector=cfg.corrector,
                               cache=cfg.result_cache)
        sim_reqs = [SimRequest(r.req_id, r.part.node_id, query.qid, r.cost)
                    for r in reqs]
        sim = simulate(sim_reqs, cfg.res, cfg.mode,
                       measured=_measured_of(cfg), breaker=cfg.breaker)
        run = _run_decided(query, reqs, sim, cfg,
                           t_pushable=sim.makespan, net_bytes=sim.net_bytes,
                           bitmaps=bitmaps,
                           tier=resolve_tier(cfg, catalog))
        if tr.enabled:
            _set_query_attrs(qs, run)
    return run


def _set_query_attrs(qs, run: "QueryRun") -> None:
    """Roll the run's accounting up onto its ``query`` span."""
    recon = run.net_bytes_recon or {}
    qs.set(real_net_bytes=float(run.real_net_bytes),
           sim_net_bytes=float(run.net_bytes),
           n_pushdown=run.n_admitted, n_pushback=run.n_pushed_back,
           t_pushable=run.t_pushable, t_nonpushable=run.t_nonpushable,
           s_out_est_ratio=recon.get("s_out_estimate_ratio"),
           cache_hits=run.cache_hits,
           net_bytes_recon=recon)


def run_concurrent(queries: List[Query], catalog: Catalog, cfg: EngineConfig
                   ) -> Dict[str, QueryRun]:
    """Multiple queries submitted simultaneously (§6.2 PA-aware experiment).
    All requests share the storage nodes' wait queues and slots."""
    all_reqs: List[PlannedRequest] = []
    for q in queries:
        all_reqs.extend(plan_requests(q, catalog, start_id=len(all_reqs),
                                      corrector=cfg.corrector,
                                      cache=cfg.result_cache))
    sim_reqs = [SimRequest(r.req_id, r.part.node_id, r.query_id, r.cost)
                for r in all_reqs]
    sim = simulate(sim_reqs, cfg.res, cfg.mode,
                   measured=_measured_of(cfg), breaker=cfg.breaker)
    tr = obs_trace.get_tracer()
    tier = resolve_tier(cfg, catalog)
    out: Dict[str, QueryRun] = {}
    for q in queries:
        reqs = [r for r in all_reqs if r.query_id == q.qid]
        with tr.span("query", qid=q.qid, mode=cfg.mode,
                     concurrent=True) as qs:
            run = _run_decided(
                q, reqs, sim, cfg, t_pushable=sim.finish_by_query[q.qid],
                net_bytes=sim.net_bytes_by_query[q.qid], tier=tier)
            if tr.enabled:
                _set_query_attrs(qs, run)
        out[q.qid] = run
    return out


def compile_and_run(qid: str, catalog: Catalog, cfg: EngineConfig,
                    fact_selectivity: Optional[float] = None,
                    cost_based: bool = False) -> QueryRun:
    """Compiler front door: logical-plan IR -> amenability split -> run.
    Equivalent to ``run_query(compiler.compile_query(qid), ...)``.
    ``cost_based=True`` routes through ``compile_query_costed`` instead:
    the frontier cut is chosen by estimated cost over this catalog (and by
    the config's corrector, when one is set) — results are identical
    either way."""
    # deferred imports: the compiler imports core.plan/core.cost
    if cost_based:
        from repro.compiler import compile_query_costed
        cq = compile_query_costed(qid, catalog, res=cfg.res,
                                  corrector=cfg.corrector,
                                  fact_selectivity=fact_selectivity,
                                  compute_bw=cfg.compute_bw)
        return run_query(cq.query, catalog, cfg)
    from repro.compiler import compile_query
    return run_query(compile_query(qid, fact_selectivity), catalog, cfg)


# ------------------------------------------------------------ validation
def theoretical_split(query: Query, catalog: Catalog, res: StorageResources):
    """Discrete oracle split (§3.1) for the gap evaluation (Fig 7)."""
    reqs = plan_requests(query, catalog)
    return optimum.discrete_optimum([r.cost for r in reqs], res)


def results_equal(a: ColumnTable, b: ColumnTable, tol: float = 1e-6) -> bool:
    """Order-insensitive table equality: same *row multiset* up to float
    tolerance.

    Rows are aligned via one lexsort over ALL columns (exact columns
    leading, float columns last so a sub-tolerance jitter cannot flip the
    row order between the two tables), then compared row-wise. Sorting
    each column independently — the previous implementation — accepts
    tables with entirely different row sets whenever every column happens
    to hold the same value multiset (e.g. rows {(1,2),(2,1)} vs
    {(1,1),(2,2)}); tests/test_runtime.py pins the regression."""
    if set(a.columns) != set(b.columns) or len(a) != len(b):
        return False
    if len(a) == 0:
        return True
    cols = sorted(a.columns)
    is_float = {c: (np.asarray(a.cols[c]).dtype.kind in "fc"
                    or np.asarray(b.cols[c]).dtype.kind in "fc")
                for c in cols}
    # exact columns first in sort priority (lexsort: last key is primary)
    key_order = [c for c in cols if is_float[c]] + \
                [c for c in cols if not is_float[c]]

    def row_order(t: ColumnTable) -> np.ndarray:
        return np.lexsort(tuple(np.asarray(t.cols[c]) for c in key_order))

    ia, ib = row_order(a), row_order(b)
    for c in cols:
        x = np.asarray(a.cols[c])[ia]
        y = np.asarray(b.cols[c])[ib]
        if is_float[c]:
            if not np.allclose(x, y, rtol=tol, atol=tol):
                return False
        elif not np.array_equal(x, y):
            return False
    return True
