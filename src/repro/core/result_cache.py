"""Semantic pushed-result cache: storage-layer partial results as a
first-class, cost-aware cache tier.

The paper's adaptive pushdown decides *where* a pushed task runs; under
repeated-query traffic the bigger win is not re-running it at all. This
module caches each partition's pushed output — the merged-table slice plus
its §4.2 aux by-products (packed selection bitmaps, shuffle slices,
position vectors) — keyed by the partition's identity and a semantic plan
key, merging the paper's adaptive mechanism with the FlexPushdownDB line
of caching work (see PAPERS.md).

Keying
------
An entry is keyed ``(table, partition index, plan key)`` where the plan
key is derived from the *semantics* of the ``PushPlan`` (predicate repr,
output columns, derive closures' bytecode, agg/top-k/shuffle/having
specs) — two plan objects with equal semantics share entries across
queries and compiles. Each entry also records the partition's monotone
``version`` stamp from the storage catalog; append/update bumps the stamp
and stale entries are evicted lazily at their next lookup, so the cache
never serves rows derived from overwritten bytes.

Semantic containment
--------------------
For pure filter/project(+derive) plans — no agg/top-k/shuffle/bitmap, the
predicate's columns all present in the output and untouched by derives —
a cached entry whose predicate A is *looser* than a request's predicate B
(``expressions.implies(B, A)``) is a superset of the rows B selects, in
partition order. Re-filtering the cached columns with B's compiled kernel
then yields exactly the bytes the uncached path produces: subsetting
commutes with elementwise derives, and filtering a partition-ordered
superset by B leaves B's rows in the same order. Entries with the same
key shape but different predicates are indexed together so a tighter
request can find its looser donors.

Eviction & concurrency
----------------------
The cache is byte-budgeted: inserts evict from the LRU end, weighted by
observed hit counts (among the ``evict_window`` least-recent entries the
least-hit one goes first), so a once-written-never-read entry cannot
outlive a hot one merely by being touched recently. A single lock guards
the index — the wave driver (``runtime.run_stream``) hammers it from
many threads — while the served arrays themselves are immutable copies,
so re-filtering for containment happens outside the lock.

Everything is metered through ``obs.metrics``: ``cache.hit`` /
``cache.hit.containment`` / ``cache.miss`` / ``cache.evict`` /
``cache.evict.stale`` counters plus ``cache.bytes`` / ``cache.entries``
gauges. Cost probes (``cost_hint``) are deliberately silent so that
plan-time probing never masquerades as serving — the acceptance contract
is ``cache.hit`` == partitions actually skipped by the executor.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.obs.metrics import Metrics, get_metrics
from repro.queryproc import expressions as ex
from repro.queryproc.table import ColumnTable
from repro.storage.catalog import Partition

DEFAULT_BUDGET_BYTES = 256 << 20


# ------------------------------------------------------------- plan keying
def _fn_key(fn) -> str:
    """Semantic identity of a derive closure: bytecode + consts + captured
    cell values (repr'd best-effort). Two lambdas computing the same thing
    from the same captures key identically even across compiles."""
    code = getattr(fn, "__code__", None)
    if code is None:
        return repr(fn)
    cells = getattr(fn, "__closure__", None)
    closure = tuple(repr(c.cell_contents) for c in cells) if cells else ()
    return f"{code.co_code.hex()}/{code.co_consts!r}/{closure!r}"


def plan_cache_key(plan, with_predicate: bool = True) -> str:
    """The semantic cache key of a PushPlan. With ``with_predicate=False``
    the predicate slot is blanked — that is the *shape* key under which
    containment donors with different predicates are indexed together."""
    return "|".join([
        plan.table,
        ",".join(plan.columns),
        repr(plan.predicate) if with_predicate else "<pred>",
        ";".join(f"{n}({','.join(ic)})#{_fn_key(fn)}"
                 for n, ic, fn in plan.derive),
        repr(plan.agg), repr(plan.top_k), repr(plan.shuffle),
        f"bm{int(plan.bitmap_only)}ab{int(plan.apply_bitmap)}",
        repr(plan.having),
    ])


@dataclasses.dataclass(frozen=True)
class PlanKeys:
    exact: str               # full semantic key
    shape: Optional[str]     # predicate-blanked key; None = containment-
    #                          ineligible (see module docstring)
    cacheable: bool          # apply_bitmap plans depend on an external
    #                          bitmap, so their outputs are never cached


_KEYS_MEMO: "OrderedDict[int, Tuple[object, PlanKeys]]" = OrderedDict()
_KEYS_CAP = 512
_KEYS_LOCK = threading.Lock()


def plan_keys(plan) -> PlanKeys:
    """Memoized per plan object (same id-guard idiom as the executor's
    compile cache)."""
    with _KEYS_LOCK:
        hit = _KEYS_MEMO.get(id(plan))
        if hit is not None and hit[0] is plan:
            _KEYS_MEMO.move_to_end(id(plan))
            return hit[1]
    shape = None
    if (plan.predicate is not None and plan.agg is None
            and plan.top_k is None and plan.shuffle is None
            and not plan.bitmap_only and not plan.apply_bitmap):
        pred_cols = ex.columns_of(plan.predicate)
        derived = {n for n, _, _ in plan.derive}
        if pred_cols <= set(plan.columns) and not (pred_cols & derived):
            shape = plan_cache_key(plan, with_predicate=False)
    keys = PlanKeys(exact=plan_cache_key(plan), shape=shape,
                    cacheable=not plan.apply_bitmap)
    with _KEYS_LOCK:
        _KEYS_MEMO[id(plan)] = (plan, keys)
        while len(_KEYS_MEMO) > _KEYS_CAP:
            _KEYS_MEMO.popitem(last=False)
    return keys


# ----------------------------------------------------------------- entries
def _copy_table(t: ColumnTable) -> ColumnTable:
    # own the bytes: batch results are views into the fused pass's arrays;
    # caching a view would pin the whole batch allocation
    return ColumnTable({c: np.array(v, copy=True) for c, v in t.cols.items()})


def _copy_aux(aux: Dict) -> Tuple[Dict, int]:
    out: Dict = {}
    extra = 0
    if "bitmap" in aux:
        out["bitmap"] = np.array(aux["bitmap"], copy=True)
        extra += int(out["bitmap"].nbytes)
    if "shuffle_parts" in aux:
        out["shuffle_parts"] = [_copy_table(p) for p in aux["shuffle_parts"]]
        extra += sum(int(np.asarray(v).nbytes)
                     for p in out["shuffle_parts"] for v in p.cols.values())
    if "position_vector" in aux:
        out["position_vector"] = np.array(aux["position_vector"], copy=True)
        extra += int(out["position_vector"].nbytes)
    return out, extra


@dataclasses.dataclass
class CacheEntry:
    key: Tuple[str, int, str]        # (table, partition index, exact key)
    version: int                     # partition version at fill time
    result: ColumnTable              # this partition's output slice
    aux: Dict                        # its aux by-products (owned copies)
    nbytes: int
    predicate: Optional[ex.Expr]     # for containment donor checks
    shape: Optional[str]
    hits: int = 0

    def ship_bytes(self) -> int:
        """Same arithmetic as ``runtime.result_bytes``: what serving this
        entry would put on the wire (the warm ``s_out``)."""
        n = sum(int(np.asarray(v).nbytes) for v in self.result.cols.values())
        if "bitmap" in self.aux:
            n += int(self.aux["bitmap"].nbytes)
        return max(64, n)


class ResultCache:
    """Thread-safe, byte-budgeted cache of per-(partition, plan) pushed
    outputs. See the module docstring for semantics."""

    def __init__(self, budget_bytes: int = DEFAULT_BUDGET_BYTES,
                 evict_window: int = 8,
                 metrics: Optional[Metrics] = None):
        self.budget_bytes = int(budget_bytes)
        self.evict_window = int(evict_window)
        self._m = metrics  # None -> resolve the live registry per call, so
        #                    obs.set_metrics() swaps apply to the cache too
        self._lock = threading.Lock()
        self._entries: "OrderedDict[Tuple[str, int, str], CacheEntry]" = \
            OrderedDict()
        self._by_shape: Dict[Tuple[str, int, str],
                             List[Tuple[str, int, str]]] = {}
        self.bytes = 0

    # ------------------------------------------------------------ plumbing
    def _metrics(self) -> Metrics:
        return self._m if self._m is not None else get_metrics()

    def _drop(self, key: Tuple[str, int, str]) -> Optional[CacheEntry]:
        e = self._entries.pop(key, None)
        if e is None:
            return None
        self.bytes -= e.nbytes
        if e.shape is not None:
            sk = (key[0], key[1], e.shape)
            lst = self._by_shape.get(sk)
            if lst is not None:
                try:
                    lst.remove(key)
                except ValueError:
                    pass
                if not lst:
                    del self._by_shape[sk]
        return e

    def _evict_one(self) -> None:
        """Hit-rate-weighted LRU: among the ``evict_window`` least-recently
        used entries, evict the least-hit one (ties -> oldest)."""
        window = []
        for key, e in self._entries.items():
            window.append((key, e))
            if len(window) >= self.evict_window:
                break
        victim = min(window, key=lambda kv: kv[1].hits)[0]
        self._drop(victim)

    def _publish_gauges(self) -> None:
        m = self._metrics()
        m.gauge("cache.bytes").set(float(self.bytes))
        m.gauge("cache.entries").set(float(len(self._entries)))

    # ------------------------------------------------------------- serving
    def serve(self, cplan, part: Partition
              ) -> Optional[Tuple[ColumnTable, Dict, str]]:
        """Try to serve one partition's pushed output for ``cplan``.

        Returns ``(result, aux, kind)`` with kind ``"exact"`` or
        ``"containment"``, or None on a miss. The returned aux dict carries
        a ``"cache"`` marker so the runtime's per-request outcomes reconcile
        exactly with the ``cache.hit`` counter. Counters move only here —
        ``cost_hint`` probes are silent."""
        keys = plan_keys(cplan.plan)
        if not keys.cacheable:
            return None
        m = self._metrics()
        key = (part.table, part.index, keys.exact)
        donor: Optional[CacheEntry] = None
        with self._lock:
            e = self._entries.get(key)
            if e is not None and e.version != part.version:
                self._drop(key)
                m.counter("cache.evict.stale").inc()
                self._publish_gauges()
                e = None
            if e is not None:
                self._entries.move_to_end(key)
                e.hits += 1
            elif keys.shape is not None:
                sk = (part.table, part.index, keys.shape)
                # newest donors first: they survived eviction longest
                for ck in reversed(self._by_shape.get(sk, ())):
                    c = self._entries.get(ck)
                    if c is None:
                        continue
                    if c.version != part.version:
                        self._drop(ck)
                        m.counter("cache.evict.stale").inc()
                        self._publish_gauges()
                        continue
                    if ck != key and ex.implies(cplan.plan.predicate,
                                                c.predicate):
                        donor = c
                        self._entries.move_to_end(ck)
                        c.hits += 1
                        break
        if e is not None:
            m.counter("cache.hit").inc()
            return e.result, dict(e.aux, cache="exact"), "exact"
        if donor is not None:
            # the cached looser-predicate superset, re-filtered by the
            # request's tighter predicate — outside the lock over immutable
            # copies; byte-identical per the module docstring argument
            mask = cplan.pred_fn(donor.result.cols)
            res = ColumnTable({c: v[mask]
                               for c, v in donor.result.cols.items()})
            m.counter("cache.hit").inc()
            m.counter("cache.hit.containment").inc()
            return res, {"cache": "containment"}, "containment"
        m.counter("cache.miss").inc()
        return None

    def put(self, cplan, part: Partition, result: ColumnTable,
            aux: Dict) -> None:
        """Install one partition's freshly computed pushed output."""
        keys = plan_keys(cplan.plan)
        if not keys.cacheable:
            return
        res = _copy_table(result)
        nbytes = sum(int(np.asarray(v).nbytes) for v in res.cols.values())
        stored_aux, extra = _copy_aux(aux)
        nbytes = max(64, nbytes + extra)
        if nbytes > self.budget_bytes:
            return  # larger than the whole budget: not worth thrashing for
        entry = CacheEntry(key=(part.table, part.index, keys.exact),
                           version=part.version, result=res, aux=stored_aux,
                           nbytes=nbytes, predicate=cplan.plan.predicate,
                           shape=keys.shape)
        n_evicted = 0
        with self._lock:
            self._drop(entry.key)  # replace-in-place keeps accounting exact
            self._entries[entry.key] = entry
            self.bytes += entry.nbytes
            if keys.shape is not None:
                sk = (part.table, part.index, keys.shape)
                self._by_shape.setdefault(sk, []).append(entry.key)
            while self.bytes > self.budget_bytes and len(self._entries) > 1:
                self._evict_one()
                n_evicted += 1
            if self.bytes > self.budget_bytes:
                self._drop(entry.key)
                n_evicted += 1
            self._publish_gauges()
        if n_evicted:
            self._metrics().counter("cache.evict").inc(n_evicted)

    # ------------------------------------------------------- cost probing
    def cost_hint(self, cplan, part: Partition) -> Optional[int]:
        """The bytes a warm serve of ``(cplan, part)`` would ship, or None
        when cold. Read-only and silent: no counters, no LRU motion — the
        engine probes every request at plan time (``plan_requests``), and
        probes must not be mistaken for hits. A containment donor's size is
        an upper bound on the re-filtered ship size, which keeps the hint
        conservative for the pushdown-vs-pushback comparison."""
        keys = plan_keys(cplan.plan)
        if not keys.cacheable:
            return None
        with self._lock:
            e = self._entries.get((part.table, part.index, keys.exact))
            if e is not None and e.version == part.version:
                return e.ship_bytes()
            if keys.shape is not None:
                sk = (part.table, part.index, keys.shape)
                for ck in reversed(self._by_shape.get(sk, ())):
                    c = self._entries.get(ck)
                    if (c is not None and c.version == part.version
                            and ex.implies(cplan.plan.predicate,
                                           c.predicate)):
                        return c.ship_bytes()
        return None

    # ------------------------------------------------------- introspection
    def stats(self) -> Dict[str, float]:
        with self._lock:
            return {"entries": len(self._entries), "bytes": self.bytes,
                    "hits": sum(e.hits for e in self._entries.values())}

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._by_shape.clear()
            self.bytes = 0
            self._publish_gauges()
