"""The paper's lightweight cost model (§3.3, Eq. 8-11).

    t_pd = t_scan + S_in / C_storage + S_out / BW_net        (Eq. 8-9)
    t_pb = t_scan + S_in / BW_net                            (Eq. 10-11)

``t_scan`` appears in both and cancels in the Arbitrator's comparison
(Algorithm 1 line 5) — estimators below expose both the full times (used by
the simulator) and scan-free times (used for the decision, like the paper).

``C_storage`` is per-request compute bandwidth at storage: one execution
slot = one core. Multi-tenancy is emulated by scaling the number of cores
available for pushdown by ``storage_power`` ∈ (0, 1], exactly as the paper
does by capping the actor-scheduler thread pool (§6.2).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class StorageResources:
    """Per-storage-node resources (defaults ~ r5d.4xlarge of the paper:
    16 vCPU, 2x NVMe, 10 Gbps). ``core_bw`` is the measured-style per-core
    operator bandwidth over *decoded* bytes (the paper estimates C_storage
    by micro-benchmarking operators at the storage servers, §3.3)."""
    cores: int = 16
    core_bw: float = 800e6      # bytes/s of pushdown compute per core
    disk_bw: float = 8e9        # warm scan path (page-cached NVMe — the
    #                             paper averages 3 repetitions per query)
    net_bw: float = 1.25e9      # 10 Gbps storage<->compute pipe
    net_streams: int = 16       # max concurrent transfers (pushback slots)
    storage_power: float = 1.0  # fraction of cores available (multi-tenancy)

    @property
    def pd_slots(self) -> int:
        """Pushdown execution slots S_exec-pd (>= 1)."""
        return max(1, round(self.cores * self.storage_power))

    @property
    def eff_core_bw(self) -> float:
        """Per-slot compute bandwidth. At power >= 1/cores a slot is one full
        core; below that the single remaining slot runs at a core fraction."""
        return self.core_bw * min(1.0, self.cores * self.storage_power)

    @property
    def pb_slots(self) -> int:
        """Pushback execution slots S_exec-pb (network streams)."""
        return self.net_streams

    @property
    def stream_bw(self) -> float:
        """Fixed per-request network share BW_net (paper assumption §3.3)."""
        return self.net_bw / self.net_streams

    def with_power(self, power: float) -> "StorageResources":
        return dataclasses.replace(self, storage_power=power)


@dataclasses.dataclass(frozen=True)
class RequestCost:
    """Static byte counts of one pushdown request (known from the catalog +
    cardinality estimation; see repro.core.plan)."""
    s_in: int        # stored bytes of accessed columns
    s_out: int       # estimated pushdown-result bytes
    compute_in: int  # bytes the pushdown computation must chew through

    def t_scan(self, res: StorageResources) -> float:
        return self.s_in / res.disk_bw

    def t_compute(self, res: StorageResources) -> float:
        return self.compute_in / res.eff_core_bw

    def t_pd(self, res: StorageResources, include_scan: bool = True) -> float:
        t = self.t_compute(res) + self.s_out / res.stream_bw
        return t + (self.t_scan(res) if include_scan else 0.0)

    def t_pb(self, res: StorageResources, include_scan: bool = True) -> float:
        t = self.s_in / res.stream_bw
        return t + (self.t_scan(res) if include_scan else 0.0)

    def pa(self, res: StorageResources) -> float:
        """Pushdown Amenability, Eq. 12 (scan cancels)."""
        return self.t_pb(res, False) - self.t_pd(res, False)
