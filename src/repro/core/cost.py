"""The paper's lightweight cost model (§3.3, Eq. 8-11).

    t_pd = t_scan + S_in / C_storage + S_out / BW_net        (Eq. 8-9)
    t_pb = t_scan + S_in / BW_net                            (Eq. 10-11)

``t_scan`` appears in both and cancels in the Arbitrator's comparison
(Algorithm 1 line 5) — estimators below expose both the full times (used by
the simulator) and scan-free times (used for the decision, like the paper).

``C_storage`` is per-request compute bandwidth at storage: one execution
slot = one core. Multi-tenancy is emulated by scaling the number of cores
available for pushdown by ``storage_power`` ∈ (0, 1], exactly as the paper
does by capping the actor-scheduler thread pool (§6.2).
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class StorageResources:
    """Per-storage-node resources (defaults ~ r5d.4xlarge of the paper:
    16 vCPU, 2x NVMe, 10 Gbps). ``core_bw`` is the measured-style per-core
    operator bandwidth over *decoded* bytes (the paper estimates C_storage
    by micro-benchmarking operators at the storage servers, §3.3)."""
    cores: int = 16
    core_bw: float = 800e6      # bytes/s of pushdown compute per core
    disk_bw: float = 8e9        # warm scan path (page-cached NVMe — the
    #                             paper averages 3 repetitions per query)
    net_bw: float = 1.25e9      # 10 Gbps storage<->compute pipe
    net_streams: int = 16       # max concurrent transfers (pushback slots)
    storage_power: float = 1.0  # fraction of cores available (multi-tenancy)

    @property
    def pd_slots(self) -> int:
        """Pushdown execution slots S_exec-pd (>= 1)."""
        return max(1, round(self.cores * self.storage_power))

    @property
    def eff_core_bw(self) -> float:
        """Per-slot compute bandwidth. At power >= 1/cores a slot is one full
        core; below that the single remaining slot runs at a core fraction."""
        return self.core_bw * min(1.0, self.cores * self.storage_power)

    @property
    def pb_slots(self) -> int:
        """Pushback execution slots S_exec-pb (network streams)."""
        return self.net_streams

    @property
    def stream_bw(self) -> float:
        """Fixed per-request network share BW_net (paper assumption §3.3)."""
        return self.net_bw / self.net_streams

    def with_power(self, power: float) -> "StorageResources":
        return dataclasses.replace(self, storage_power=power)


@dataclasses.dataclass(frozen=True)
class RequestCost:
    """Static byte counts of one pushdown request (known from the catalog +
    cardinality estimation; see repro.core.plan)."""
    s_in: int        # stored bytes of accessed columns
    s_out: int       # estimated pushdown-result bytes
    compute_in: int  # bytes the pushdown computation must chew through

    def t_scan(self, res: StorageResources) -> float:
        return self.s_in / res.disk_bw

    def t_compute(self, res: StorageResources) -> float:
        return self.compute_in / res.eff_core_bw

    def t_pd(self, res: StorageResources, include_scan: bool = True) -> float:
        t = self.t_compute(res) + self.s_out / res.stream_bw
        return t + (self.t_scan(res) if include_scan else 0.0)

    def t_pb(self, res: StorageResources, include_scan: bool = True) -> float:
        t = self.s_in / res.stream_bw
        return t + (self.t_scan(res) if include_scan else 0.0)

    def pa(self, res: StorageResources) -> float:
        """Pushdown Amenability, Eq. 12 (scan cancels)."""
        return self.t_pb(res, False) - self.t_pd(res, False)

    def with_s_out(self, s_out: int) -> "RequestCost":
        return dataclasses.replace(self, s_out=int(max(64, s_out)))


# ------------------------------------------------------ frontier-cut score
def cut_score(cost: RequestCost, res: StorageResources,
              has_operator_work: bool, cache_hit: bool = False) -> float:
    """Objective the cost-based frontier chooser minimizes per request:
    predicted storage-side operator CPU plus the result-ship time
    (``s_out`` over the per-stream share). The scan term is identical for
    every candidate cut of one table (same accessed bytes leave the disk)
    and cancels, exactly like Algorithm 1's decision comparison.

    ``has_operator_work`` is False for the raw-projection baseline (a bare
    ``scan+project`` cut): the storage node streams the accessed columns
    without running any operator, so it is charged ship time only — that
    is what makes pushing a partial aggregate over a high-NDV group key
    (Q18-style: partials ~ input rows, CPU spent for no reduction) lose to
    cutting at the scan.

    ``cache_hit`` zeroes the CPU term: a warm pushed-result cache entry
    (core.result_cache) means the storage node ships the cached bytes
    without re-running the operator chain, so only the ship time remains
    — pushdown on a warm partition is nearly free. The engine applies the
    same collapse at request level (``plan_requests`` sets
    ``compute_in=0`` and the known entry bytes as ``s_out``), which is
    what flips warm arbitration toward pushdown."""
    cpu = (cost.t_compute(res)
           if has_operator_work and not cache_hit else 0.0)
    return cpu + cost.s_out / res.stream_bw


# ------------------------------------------------- online s_out correction
class CardinalityCorrector:
    """Online cardinality correction of the cost model's ``s_out``.

    The reconciliation in ``core.runtime`` measures, per executed query,
    the *actual* bytes every pushdown request shipped; this class turns
    those observations into a multiplicative correction the planner
    applies to subsequent estimates. State is an EWMA **in log space** of
    ``log(real / estimated)`` keyed by ``(query, table, frontier
    signature)`` — so a ratio learned for ``scan+agg`` on Q18's lineitem
    never silently applies to the ``scan`` candidate of the same table —
    with a ``(query, table)`` fallback for unseen signatures.

    With a stationary workload the corrected-estimate error contracts
    geometrically: after k observations the log-error is ``(1-alpha)^k``
    of the initial one (tests/test_runtime.py pins the monotone decay).
    Corrections are clamped to ``[1/clamp, clamp]`` so one degenerate
    observation can never catapult the arbitration, and they only ever
    rescale ``s_out`` — decisions may flip, result bytes cannot (the
    decision-faithful runtime is byte-identical for any vector).

    Consumers: ``engine.plan_requests`` rescales each request's cost (the
    simulator and the Arbitrator then arbitrate over corrected costs), and
    ``compile.compile_query_costed`` rescales candidate-cut scores, so the
    frontier choice converges toward measured truth too. Thread-safe (the
    stream driver observes from worker threads)."""

    def __init__(self, alpha: float = 0.5, clamp: float = 32.0):
        assert 0.0 < alpha <= 1.0
        self.alpha = alpha
        self.clamp = clamp
        self._log: Dict[Tuple[str, str, Optional[str]], float] = {}
        self._n: Dict[Tuple[str, str, Optional[str]], int] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------- reads
    def ratio(self, qid: str, table: str, sig: Optional[str] = None,
              exact: bool = False) -> float:
        """Correction multiplier for an s_out estimate (1.0 = no data).
        ``exact=True`` disables the (query, table) fallback — the cut
        chooser compares candidates of *different* signatures against each
        other, so a ratio measured under one frontier must not leak onto
        the others (the planner's per-request correction keeps the
        fallback: there one table runs one plan)."""
        with self._lock:
            key = (qid, table, sig)
            if key not in self._log and not exact:
                key = (qid, table, None)
            log_r = self._log.get(key)
        if log_r is None:
            return 1.0
        return float(min(self.clamp, max(1.0 / self.clamp, math.exp(log_r))))

    def correct(self, qid: str, table: str, sig: Optional[str],
                cost: RequestCost, exact: bool = False) -> RequestCost:
        r = self.ratio(qid, table, sig, exact=exact)
        return cost if r == 1.0 else cost.with_s_out(round(cost.s_out * r))

    def snapshot(self) -> Dict[str, float]:
        """Learned ratios as readable strings (benchmarks/reporting) —
        clamped exactly like ``ratio()``, so reports show the correction
        that is actually applied."""
        with self._lock:
            return {"/".join(str(p) for p in key if p is not None):
                    float(min(self.clamp, max(1.0 / self.clamp,
                                              math.exp(v))))
                    for key, v in self._log.items()}

    # ------------------------------------------------------------ writes
    def observe(self, qid: str, table: str, sig: Optional[str],
                est_s_out: float, real_s_out: float) -> None:
        """Feed one measured (estimate, actual) pushdown-byte pair.
        ``est_s_out`` must be the *uncorrected* estimate — the EWMA state
        tracks the model's raw bias, so repeated observation is idempotent
        rather than compounding."""
        if est_s_out <= 0 or real_s_out <= 0:
            return
        obs = math.log(real_s_out / est_s_out)
        with self._lock:
            for key in ((qid, table, sig), (qid, table, None)):
                prev = self._log.get(key)
                self._log[key] = obs if prev is None \
                    else (1.0 - self.alpha) * prev + self.alpha * obs
                self._n[key] = self._n.get(key, 0) + 1

    def state(self, qid: Optional[str] = None) -> Dict[str, Dict]:
        """EWMA state (applied ratio + observation count) per learned key —
        what the tracer captures at decision time so a trace shows exactly
        which correction steered each arbitration. ``qid`` filters to one
        query's keys."""
        with self._lock:
            items = list(self._log.items())
            counts = dict(self._n)
        out: Dict[str, Dict] = {}
        for key, log_r in items:
            if qid is not None and key[0] != qid:
                continue
            name = "/".join(str(p) for p in key if p is not None)
            out[name] = {
                "ratio": float(min(self.clamp,
                                   max(1.0 / self.clamp, math.exp(log_r)))),
                "n": counts.get(key, 0),
            }
        return out

    @property
    def n_observations(self) -> int:
        with self._lock:
            return sum(n for (q, t, s), n in self._n.items() if s is None)
