"""Fused batched storage executor: compile-once PushPlans, vectorized
multi-partition execution — including the aux-producing data paths.

The reference path (``core.plan.execute_push_plan``) interprets a
``PushPlan`` per partition: it re-walks the predicate expression tree,
re-derives columns, and re-runs the grouping machinery for every one of the
~160 per-partition requests a query issues. The paper's pushdown wins rest
on the storage-side operator path being tight (PushdownDB; Farview), so
this module lowers each plan **once per query**:

- ``compile_push_plan(plan)`` -> ``CompiledPushPlan``: the predicate is
  compiled to a single numpy kernel (``expressions.compile_expr``, the same
  lowering the Pallas ``predicate_bitmap`` kernel uses), the derive/agg/
  top-k stages are bound into one fused closure, and plan-level invariants
  (``accessed_columns``, the cost model's per-plan constants, the
  selectivity closure) are memoized instead of recomputed per partition.

- ``CompiledPushPlan.execute_batch(tables)`` stacks all partitions of a
  table that share one plan and executes them in a single vectorized pass:
  filter + derive run once over the concatenated columns, and partial
  aggregation uses the partition id as an implicit leading segment key
  (``np.bincount``/``ufunc.reduceat`` over the concatenation), so the
  Python-per-partition loop in ``engine.execute_requests`` collapses to one
  call per (table, plan).

- ``execute_batch_aux`` / ``execute_batch_parts`` additionally emit the
  §4.2 **auxiliary by-products** in the same fused pass: per-partition
  packed selection bitmaps (``bitmap_only`` plans — Figs 3/4), and
  per-partition hash-partition slices + position vectors (``shuffle``
  plans — Fig 5/15). One predicate/hash evaluation over the concatenation
  serves every partition; a single stable sort by ``(partition, target)``
  replaces the reference's ``n_parts * n_targets`` boolean filters.

The filter stage is **selectivity-adaptive**: the compiled ``sel_fn``
estimate (or the exact bitmap popcount on ``apply_bitmap`` plans) picks
between gathering survivors per partition (cheap when the predicate is
selective) and concatenating whole columns then applying one big mask
(cheap when most rows survive — scan-heavy plans used to pay per-partition
gather overhead for nothing). The crossover threshold is micro-calibrated
at import time (``calibrate_gather_threshold``), overridable via
``EngineConfig.filter_gather_threshold`` or ``REPRO_GATHER_THRESHOLD``;
each batch's decision lands in the observability subsystem's bounded
filter-decision channel (``repro.obs.filter_decision_channel``) for the
benchmarks and traces to report. Both branches produce the same bytes —
the choice is purely a performance one.

Bitwise contract: the batch path returns **byte-identical** merged tables
and aux products to the per-partition reference. The load-bearing facts:
elementwise numpy ops distribute over concatenation exactly;
``np.bincount`` accumulates weights in array order (so segment-keyed sums
add the same floats in the same order as per-partition sums); stable
argsort + ``reduceat`` reduce identical segments; a stable sort by
``(partition, target)`` slices into exactly the rows ``pid == target``
selects per partition, in the same order; and the keyless-agg / top-k
stages intentionally drop to a per-segment loop because their reference
semantics (``np.sum`` pairwise summation, ``argpartition`` tie choices,
the empty-partition ``[0.]`` placeholder) are not concatenation-invariant
— those loops run on the already-filtered rows, so the heavy stages stay
fused. ``tests/test_executor.py`` pins all of this against the reference
oracle.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import RequestCost
from repro.core.plan import _AGG_OUT_ROWS, PushPlan
from repro.obs import trace as obs_trace
from repro.core.plan import batchable_stages  # noqa: F401 re-export
from repro.queryproc import expressions as ex
from repro.queryproc import operators as ops
from repro.queryproc.table import ColumnTable
from repro.storage.catalog import Partition

# real-execution path names (shared by engine and runtime)
EXECUTOR_BATCHED = "batched"      # compile-once plans, one pass per table
EXECUTOR_REFERENCE = "reference"  # per-partition interpretive oracle

# --------------------------------------------- adaptive filter calibration
DEFAULT_GATHER_THRESHOLD = 0.55  # fallback when calibration is disabled


def calibrate_gather_threshold(n_parts: int = 160, rows_per_part: int = 1000,
                               n_cols: int = 3,
                               sels: Sequence[float] = (0.9, 0.7, 0.5, 0.3),
                               repeats: int = 2) -> float:
    """Micro-benchmark the two filter-stage strategies at the engine's real
    request shape (~160 small partitions) and return the estimated-
    selectivity crossover above which concat-everything beats
    gather-survivors on this machine.

    gather copies ~sel*N bytes through ``n_parts`` cache-resident boolean
    gathers; concat copies ~(1+sel)*N bytes in two big bandwidth-bound ops
    — the crossover is machine-dependent (allocator + memcpy throughput vs
    per-call overhead), hence measured, not assumed. The scan walks the
    selectivities DOWNWARD and stops at the first one where gather wins, so
    a noisy concat win at low selectivity can never drag the threshold down
    — the adaptive stage must never lose to the always-gather baseline."""
    rng = np.random.default_rng(0)
    n_rows = n_parts * rows_per_part
    # one shared buffer stands in for every column: the strategies only
    # read the sources (outputs are fresh allocations either way), so the
    # work profile is identical and data generation stays cheap at import
    base = rng.uniform(0.0, 1.0, n_rows)
    data = [base] * n_cols
    bnd = np.linspace(0, n_rows, n_parts + 1).astype(np.intp)
    parts = [[a[bnd[p]:bnd[p + 1]] for a in data] for p in range(n_parts)]
    u = rng.random(n_rows)

    def best_of(fn) -> float:
        fn()  # warm
        return min(_t(fn) for _ in range(repeats))

    def _t(fn) -> float:
        t0 = time.perf_counter()
        fn()
        return time.perf_counter() - t0

    lowest_concat_win = None
    for sel in sorted(sels, reverse=True):
        mask = u < sel
        masks = [mask[bnd[p]:bnd[p + 1]] for p in range(n_parts)]
        t_gather = best_of(lambda: [np.concatenate(
            [parts[p][i][masks[p]] for p in range(n_parts)])
            for i in range(n_cols)])
        t_concat = best_of(lambda: [np.concatenate(
            [parts[p][i] for p in range(n_parts)])[mask]
            for i in range(n_cols)])
        if t_concat >= t_gather:
            break
        lowest_concat_win = sel
    if lowest_concat_win is None:
        return 1.01  # gather always won: never switch
    lower = max((s for s in sels if s < lowest_concat_win), default=None)
    return (lowest_concat_win if lower is None
            else (lowest_concat_win + lower) / 2)


def _init_threshold() -> float:
    env = os.environ.get("REPRO_GATHER_THRESHOLD")
    if env:
        return float(env)
    if os.environ.get("REPRO_NO_CALIBRATE"):
        return DEFAULT_GATHER_THRESHOLD
    try:
        return calibrate_gather_threshold()
    except Exception:  # pragma: no cover - calibration is best-effort
        return DEFAULT_GATHER_THRESHOLD


FILTER_GATHER_THRESHOLD = _init_threshold()

# Batch filter-stage decisions now live in the observability subsystem's
# bounded, thread-safe channel (repro.obs.filter_decision_channel) — the
# old FILTER_DECISIONS module list grew without bound across runs and
# raced under run_stream's thread pools. These wrappers keep the public
# surface; FILTER_DECISIONS itself survives one release as a deprecated
# read-only snapshot via the module __getattr__ below.


def reset_filter_decisions() -> None:
    obs_trace.filter_decision_channel().clear()


def filter_decision_counts() -> Dict[str, int]:
    counts = obs_trace.filter_decision_channel().counts("branch")
    return {"gather": counts.get("gather", 0),
            "concat": counts.get("concat", 0)}


def _record_decision(table: str, est: Optional[float], branch: str,
                     n_parts: int, rows: int) -> None:
    obs_trace.record_filter_decision(table, est, branch, n_parts, rows)


def __getattr__(name: str):
    if name == "FILTER_DECISIONS":
        # deprecated alias (one release): read-only snapshot of the channel
        return obs_trace.filter_decision_channel().snapshot()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


@dataclasses.dataclass
class CompiledPushPlan:
    """A PushPlan lowered once: compiled kernels + memoized invariants."""
    plan: PushPlan
    accessed: Tuple[str, ...]               # memoized plan.accessed_columns()
    pred_fn: Optional[Callable]             # fused numpy predicate kernel
    pred_cols: Tuple[str, ...]              # columns the predicate reads
    sel_fn: Optional[Callable]              # compiled selectivity estimator
    agg_spec: Optional[Dict[str, Tuple[str, str]]]  # out -> (fn, col)
    having_fn: Optional[Callable] = None    # post-agg filter kernel
    having_sel_fn: Optional[Callable] = None  # its selectivity estimator
    # cost-model per-plan constants (plan.estimate_cost recomputes these
    # per partition; only the stats lookups actually vary across partitions)
    _n_derived_out: int = 0
    _agg_keys: Tuple[str, ...] = ()

    # ------------------------------------------------------------ execution
    def raw_projection(self, data: ColumnTable) -> ColumnTable:
        """The pushback payload: the raw accessed-column projection of one
        partition — the paper's ``S_in``. Executing this plan over the
        projection is byte-identical to executing it over the full
        partition (output columns ⊆ accessed ∪ derived), which is what
        lets the compute layer replay the same compiled plan."""
        return data.select([c for c in self.accessed if c in data.cols])

    def execute(self, data: ColumnTable, bitmap: Optional[np.ndarray] = None
                ) -> Tuple[ColumnTable, Dict]:
        """Single-partition fused path: the same ``(result, aux)`` as
        ``plan.execute_push_plan`` — aux-producing plans (bitmap_only,
        shuffle) emit their by-products from the batch machinery."""
        merged, aux = self.execute_batch_aux(
            [data], None if bitmap is None else [bitmap])
        return merged, aux[0]

    def execute_batch(self, tables: Sequence[ColumnTable],
                      bitmaps: Optional[Sequence[np.ndarray]] = None,
                      threshold: Optional[float] = None,
                      cache=None, parts: Optional[Sequence] = None
                      ) -> ColumnTable:
        """All partitions sharing this plan in one vectorized pass.
        Returns the merged table — byte-identical to
        ``ColumnTable.concat([execute_push_plan(plan, t)[0] for t in tables])``.

        With ``cache`` (a ``core.result_cache.ResultCache``) and ``parts``
        (the matching catalog ``Partition`` per table), cached partitions
        are served and *skipped* in the vectorized pass; only the misses
        run, and their outputs are spliced back in original partition
        order — byte-identical because the fused pass's per-partition
        outputs are batch-composition-invariant (pinned by
        tests/test_executor.py)."""
        out, _, _ = self._run_batch(tables, bitmaps, threshold,
                                    want_aux=False, cache=cache, parts=parts)
        return out

    def execute_batch_aux(self, tables: Sequence[ColumnTable],
                          bitmaps: Optional[Sequence[np.ndarray]] = None,
                          threshold: Optional[float] = None,
                          cache=None, parts: Optional[Sequence] = None
                          ) -> Tuple[ColumnTable, List[Dict]]:
        """(merged table, per-partition aux dicts) — each aux dict is
        byte-identical to ``execute_push_plan(plan, tables[i])[1]``:
        ``bitmap`` (packed uint32 words) for bitmap_only plans,
        ``shuffle_parts`` + ``position_vector`` for shuffle plans. A
        cache-served partition's aux additionally carries a ``"cache"``
        marker (``"exact"``/``"containment"``)."""
        out, _, aux = self._run_batch(tables, bitmaps, threshold,
                                      want_aux=True, cache=cache,
                                      parts=parts)
        return out, aux

    def execute_batch_parts(self, tables: Sequence[ColumnTable],
                            bitmaps: Optional[Sequence[np.ndarray]] = None,
                            threshold: Optional[float] = None,
                            cache=None, parts: Optional[Sequence] = None
                            ) -> Tuple[List[ColumnTable], List[Dict]]:
        """(per-partition result tables, per-partition aux dicts) — each
        entry byte-identical to ``execute_push_plan(plan, tables[i])``. The
        per-partition views slice one fused pass; nothing is re-executed."""
        out, bounds, aux = self._run_batch(tables, bitmaps, threshold,
                                           want_aux=True, cache=cache,
                                           parts=parts)
        out_parts = [ColumnTable({c: v[bounds[p]:bounds[p + 1]]
                                  for c, v in out.cols.items()})
                     for p in range(len(tables))]
        return out_parts, aux

    def _run_batch(self, tables: Sequence[ColumnTable],
                   bitmaps: Optional[Sequence[np.ndarray]],
                   threshold: Optional[float], want_aux: bool,
                   cache=None, parts: Optional[Sequence] = None
                   ) -> Tuple[ColumnTable, np.ndarray, List[Dict]]:
        """The fused pass. Returns (merged, per-partition output-row bounds
        (n_parts+1,), per-partition aux dicts)."""
        if cache is not None and parts is not None \
                and not self.plan.apply_bitmap:
            return self._run_batch_cached(tables, threshold, cache, parts)
        plan = self.plan
        assert plan.columns or plan.agg is not None, \
            "plans must declare output columns (the splitter guarantees it)"
        n_parts = len(tables)
        lens = np.asarray([len(t) for t in tables], np.int64)

        def concat(column: str) -> np.ndarray:
            if n_parts == 1:
                return np.asarray(tables[0].cols[column])
            return np.concatenate([t.cols[column] for t in tables])

        # accessed columns only: the reference filters whole partitions,
        # but output columns are always a subset of accessed + derived
        present = [c for c in self.accessed if c in tables[0].cols]

        # ---- filter stage: one fused predicate pass over the predicate
        # columns; remaining columns materialize through the adaptive
        # gather-vs-concat branch below
        cols: Dict[str, np.ndarray] = {}
        masks: Optional[List[np.ndarray]] = None
        mask_full: Optional[np.ndarray] = None
        est: Optional[float] = None
        if plan.apply_bitmap:
            assert bitmaps is not None, "compute-layer bitmaps required"
            masks = [ops.unpack_bitmap(w, int(m))
                     for w, m in zip(bitmaps, lens)]
            mask_full = masks[0] if n_parts == 1 else np.concatenate(masks)
            total = int(lens.sum())
            # the bitmap is in hand: the selectivity is exact, not estimated
            est = float(mask_full.sum()) / total if total else 0.0
        elif self.pred_fn is not None:
            pcols = {c: concat(c) for c in self.pred_cols
                     if c in tables[0].cols}
            mask_full = self.pred_fn(pcols)
            masks = (np.split(mask_full, np.cumsum(lens)[:-1]) if n_parts > 1
                     else [mask_full])
            # predicate columns are already concatenated: one gather
            cols = {c: v[mask_full] for c, v in pcols.items() if c in present}
            if self.sel_fn is not None:
                est = float(self.sel_fn(tables[0].stats()))

        segmented = plan.agg is not None or plan.top_k is not None
        if masks is None:
            counts = lens
            seg = np.repeat(np.arange(n_parts), lens) if segmented else None
            for c in present:
                cols.setdefault(c, concat(c))
        else:
            counts = np.asarray([int(m.sum()) for m in masks], np.int64)
            seg = np.repeat(np.arange(n_parts), counts) if segmented else None
            missing = [c for c in present if c not in cols]
            if missing:
                thr = (FILTER_GATHER_THRESHOLD if threshold is None
                       else threshold)
                branch = ("concat" if est is not None and est >= thr
                          else "gather")
                _record_decision(plan.table, est, branch, n_parts,
                                 int(lens.sum()))
                if branch == "concat":
                    # most rows survive: two big copies beat n_parts gathers
                    for c in missing:
                        cols[c] = concat(c)[mask_full]
                else:
                    # selective predicate: copy only the survivors
                    for c in missing:
                        cols[c] = (tables[0].cols[c][masks[0]]
                                   if n_parts == 1 else np.concatenate(
                                       [t.cols[c][m]
                                        for t, m in zip(tables, masks)]))

        # ---- derive stage (fused: one elementwise pass per derived column)
        for name, incols, fn in plan.derive:
            cols[name] = fn(*[cols[c] for c in incols])

        t = ColumnTable(cols)
        if plan.agg is not None:
            # aggregation collapses rows: seg is re-derived at group level
            # so a downstream top-k segments the agg *output*, not the input
            out, seg = self._batched_agg(t, seg, n_parts)
            if self.having_fn is not None:
                # post-agg filter over the partial aggregate's output; seg
                # stays sorted under the mask so bounds/top-k still apply
                hm = self.having_fn(out.cols)
                out = ColumnTable({c: v[hm] for c, v in out.cols.items()})
                seg = np.asarray(seg)[hm]
        elif plan.columns:
            out = t.select([c for c in plan.columns if c in t.cols])
        else:
            out = t
        if plan.top_k is not None:
            out, bounds = self._segmented_top_k(out, seg, n_parts)
        elif plan.agg is not None:
            bounds = np.searchsorted(seg, np.arange(n_parts + 1))
        else:
            bounds = np.concatenate([[0], np.cumsum(counts)])

        aux: List[Dict] = [{} for _ in range(n_parts)]
        if want_aux:
            self._emit_aux(out, bounds, masks, aux)
        return out, bounds, aux

    def _run_batch_cached(self, tables: Sequence[ColumnTable],
                          threshold: Optional[float], cache,
                          parts: Sequence
                          ) -> Tuple[ColumnTable, np.ndarray, List[Dict]]:
        """Serve cached partitions, run the fused pass over the misses
        only, fill the cache from their bounds-sliced outputs, and splice
        everything back in original partition order.

        ``merged == concat(per-partition outputs)`` holds for every plan
        type (the batch path's contract vs the per-partition reference),
        so the spliced merge is byte-identical to the uncached batch —
        including when the miss subset runs as its own smaller batch,
        because per-partition outputs are batch-composition-invariant."""
        assert len(parts) == len(tables)
        n = len(tables)
        res: List[Optional[ColumnTable]] = [None] * n
        auxs: List[Dict] = [{} for _ in range(n)]
        miss: List[int] = []
        for i, part in enumerate(parts):
            hit = cache.serve(self, part)
            if hit is None:
                miss.append(i)
            else:
                res[i], auxs[i] = hit[0], hit[1]
        if miss:
            sub = [tables[i] for i in miss]
            out, bounds, aux = self._run_batch(sub, None, threshold,
                                               want_aux=True)
            for j, i in enumerate(miss):
                r = ColumnTable({c: v[bounds[j]:bounds[j + 1]]
                                 for c, v in out.cols.items()})
                res[i] = r
                auxs[i] = aux[j]
                cache.put(self, parts[i], r, aux[j])
        merged = ColumnTable.concat(res) if n > 1 else res[0]
        out_bounds = np.concatenate(
            [[0], np.cumsum([len(r) for r in res])]).astype(np.int64)
        return merged, out_bounds, auxs

    def _emit_aux(self, out: ColumnTable, bounds: np.ndarray,
                  masks: Optional[List[np.ndarray]], aux: List[Dict]) -> None:
        """The §4.2 by-products, vectorized over the whole batch."""
        plan = self.plan
        n_parts = len(aux)
        if plan.bitmap_only and masks is not None and not plan.apply_bitmap:
            # the reference packs the full-partition predicate mask — which
            # is exactly the per-partition split of the batch mask
            for a, m in zip(aux, masks):
                a["bitmap"] = ops.pack_bitmap(m)
        if plan.shuffle is not None:
            key, n_t = plan.shuffle
            pid = ops.hash_partition_ids(np.asarray(out.cols[key]), n_t)
            seg_of_row = np.repeat(np.arange(n_parts), np.diff(bounds))
            code = seg_of_row * n_t + pid
            order = np.argsort(code, kind="stable")
            # one gather per column; a stable sort by (partition, target)
            # makes each (p, t) run exactly the rows `pid == t` selects per
            # partition, in the reference's row order
            sorted_cols = {c: v[order] for c, v in out.cols.items()}
            bb = np.searchsorted(code[order],
                                 np.arange(n_parts * n_t + 1))
            for p, a in enumerate(aux):
                a["shuffle_parts"] = [
                    ColumnTable({c: v[bb[p * n_t + i]:bb[p * n_t + i + 1]]
                                 for c, v in sorted_cols.items()})
                    for i in range(n_t)]
                a["position_vector"] = pid[bounds[p]:bounds[p + 1]]

    # ----------------------------------------------------- agg / top-k
    def _batched_agg(self, t: ColumnTable, seg: np.ndarray, n_parts: int
                     ) -> Tuple[ColumnTable, np.ndarray]:
        """Returns (partials table, per-output-row partition id)."""
        keys, _ = self.plan.agg
        if keys:
            return self._segment_keyed_agg(t, seg, keys)
        # keyless (scalar) aggs: the reference emits one row per partition,
        # with np.sum's pairwise summation and a float64 [0.] placeholder
        # for empty partitions — neither is concatenation-invariant, so
        # reduce per segment over the already-filtered rows
        bounds = np.searchsorted(seg, np.arange(n_parts + 1))
        out: Dict[str, List[np.ndarray]] = {name: [] for name in self.agg_spec}
        for p in range(n_parts):
            lo, hi = int(bounds[p]), int(bounds[p + 1])
            for name, (fn, col) in self.agg_spec.items():
                if hi == lo:
                    val = np.asarray([0], np.float64)
                elif fn == "count":  # length-only: no column materialization
                    val = np.asarray([np.asarray(hi - lo, np.int64)])
                else:
                    arr = (t.cols[col] if col else next(iter(t.cols.values())))
                    val = np.asarray([ops.AGG_FUNCS[fn](arr[lo:hi])])
                out[name].append(val)
        return (ColumnTable({n: np.concatenate(v) for n, v in out.items()}),
                np.arange(n_parts))  # one output row per partition

    def _segment_keyed_agg(self, t: ColumnTable, seg: np.ndarray,
                           keys: Tuple[str, ...]
                           ) -> Tuple[ColumnTable, np.ndarray]:
        """Grouped partials over all partitions at once, the partition id as
        implicit leading segment key.

        The reference (``ops.grouped_agg`` per partition) sorts a rec array
        — a void-dtype comparison per element. Here one type-specialized
        stable ``np.lexsort`` over (pid, keys...) orders the concatenation;
        group boundaries fall out of adjacent-row key changes. Sorting by
        pid first makes the group order *identical* to concatenating the
        per-partition key-sorted outputs, and sums/counts go through
        ``np.bincount`` over the original-order group ids, so each group
        accumulates the same floats in the same order as the reference —
        bitwise-identical partials (reduceat is pairwise, bincount is
        sequential: only bincount matches)."""
        key_arrs = [t.cols[k] for k in keys]
        n = len(seg)
        # lexsort: last key is primary -> (seg, k1, .., kn) lexicographic
        order = np.lexsort(tuple(reversed(key_arrs)) + (seg,))
        sorted_keys = [a[order] for a in [seg, *key_arrs]]
        new_group = np.zeros(n, bool)
        if n:
            new_group[0] = True
        for a in sorted_keys:
            new_group[1:] |= a[1:] != a[:-1]
        starts = np.flatnonzero(new_group)           # sorted-domain offsets
        n_groups = len(starts)
        gid = np.cumsum(new_group) - 1               # sorted-domain group id
        inv = np.empty(n, np.intp)
        inv[order] = gid                             # original-order group id
        first_idx = order[starts]                    # stable: first original row
        counts = np.bincount(inv, minlength=n_groups)
        out = {k: t.cols[k][first_idx] for k in keys}
        for name, (fn, col) in self.agg_spec.items():
            if fn == "count":
                out[name] = counts.astype(np.int64)
            elif fn == "sum":
                out[name] = np.bincount(inv, weights=t.cols[col].astype(np.float64),
                                        minlength=n_groups)
            elif fn == "mean":
                s = np.bincount(inv, weights=t.cols[col].astype(np.float64),
                                minlength=n_groups)
                out[name] = s / np.maximum(counts, 1)
            else:
                red = np.minimum if fn == "min" else np.maximum
                out[name] = red.reduceat(t.cols[col][order], starts)
        return ColumnTable(out), sorted_keys[0][starts]  # per-group pid

    def _segmented_top_k(self, t: ColumnTable, seg: np.ndarray, n_parts: int
                         ) -> Tuple[ColumnTable, np.ndarray]:
        # per-partition top-k supersets, exactly as the reference selects
        # them (argpartition tie behavior is position-dependent, so the
        # reference operator runs per segment — on filtered rows only)
        col, k, asc = self.plan.top_k
        bounds = np.searchsorted(seg, np.arange(n_parts + 1))
        parts = [ops.top_k(
            ColumnTable({c: v[bounds[p]:bounds[p + 1]]
                         for c, v in t.cols.items()}), col, k, asc)
            for p in range(n_parts)]
        out_bounds = np.concatenate(
            [[0], np.cumsum([len(p) for p in parts])])
        return ColumnTable.concat(parts), out_bounds

    # ------------------------------------------------------------ cost
    def estimate_cost(self, part: Partition) -> RequestCost:
        """Identical arithmetic to ``plan.estimate_cost`` with the per-plan
        constants memoized; only the stats lookups touch the partition."""
        plan = self.plan
        data = part.data
        stats = data.stats()
        acc_cols = [c for c in self.accessed if c in data.cols]
        s_in = data.nbytes(acc_cols, stored=True)
        raw_in = data.nbytes(acc_cols, stored=False)
        sel = self.sel_fn(stats) if self.sel_fn is not None else 1.0
        if plan.bitmap_only:
            out_cols = [c for c in plan.columns if c in data.cols]
            s_out = ((data.nbytes(out_cols, stored=False)
                      + 8 * self._n_derived_out * len(data)) * sel
                     + len(data) / 8)
        elif plan.agg is not None:
            groups = 1
            for key in self._agg_keys:
                groups *= max(1, stats[key].ndv if key in stats
                              else _AGG_OUT_ROWS)
            groups = min(groups, _AGG_OUT_ROWS, len(data))
            s_out = groups * 8 * (len(self._agg_keys) + len(self.agg_spec))
            if self.having_sel_fn is not None:
                s_out *= self.having_sel_fn(stats)
        else:
            out_cols = [c for c in plan.columns if c in data.cols]
            s_out = (data.nbytes(out_cols, stored=False)
                     + 8 * self._n_derived_out * len(data)) * sel
        if plan.top_k is not None:
            s_out = min(s_out, plan.top_k[1] * 8 * max(1, len(plan.columns)))
        return RequestCost(s_in=int(s_in), s_out=int(max(64, s_out)),
                           compute_in=int(raw_in))


# ----------------------------------------------------------- compile cache
_CACHE: "OrderedDict[int, CompiledPushPlan]" = OrderedDict()
_CACHE_CAP = 256


def compile_push_plan(plan: PushPlan) -> CompiledPushPlan:
    """Lower a PushPlan once; memoized per plan object (the engine issues
    one plan instance per (query, table) shared by all its partitions)."""
    hit = _CACHE.get(id(plan))
    if hit is not None and hit.plan is plan:   # guard against id() reuse
        _CACHE.move_to_end(id(plan))
        return hit
    derived = frozenset(n for n, _, _ in plan.derive)
    cplan = CompiledPushPlan(
        plan=plan,
        accessed=plan.accessed_columns(),
        pred_fn=(ex.compile_expr(plan.predicate)
                 if plan.predicate is not None and not plan.apply_bitmap
                 else None),
        pred_cols=(tuple(sorted(ex.columns_of(plan.predicate)))
                   if plan.predicate is not None and not plan.apply_bitmap
                   else ()),
        sel_fn=(ex.compile_selectivity(plan.predicate)
                if plan.predicate is not None else None),
        agg_spec=({o: (f, c) for o, f, c in plan.agg[1]}
                  if plan.agg is not None else None),
        having_fn=(ex.compile_expr(plan.having)
                   if plan.having is not None else None),
        having_sel_fn=(ex.compile_selectivity(plan.having)
                       if plan.having is not None else None),
        _n_derived_out=len(derived & set(plan.columns)),
        _agg_keys=tuple(plan.agg[0]) if plan.agg is not None else (),
    )
    _CACHE[id(plan)] = cplan
    while len(_CACHE) > _CACHE_CAP:
        _CACHE.popitem(last=False)
    return cplan
