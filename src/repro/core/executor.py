"""Fused batched storage executor: compile-once PushPlans, vectorized
multi-partition execution.

The reference path (``core.plan.execute_push_plan``) interprets a
``PushPlan`` per partition: it re-walks the predicate expression tree,
re-derives columns, and re-runs the grouping machinery for every one of the
~160 per-partition requests a query issues. The paper's pushdown wins rest
on the storage-side operator path being tight (PushdownDB; Farview), so
this module lowers each plan **once per query**:

- ``compile_push_plan(plan)`` -> ``CompiledPushPlan``: the predicate is
  compiled to a single numpy kernel (``expressions.compile_expr``, the same
  lowering the Pallas ``predicate_bitmap`` kernel uses), the derive/agg/
  top-k stages are bound into one fused closure, and plan-level invariants
  (``accessed_columns``, the cost model's per-plan constants, the
  selectivity closure) are memoized instead of recomputed per partition.

- ``CompiledPushPlan.execute_batch(tables)`` stacks all partitions of a
  table that share one plan and executes them in a single vectorized pass:
  filter + derive run once over the concatenated columns, and partial
  aggregation uses the partition id as an implicit leading segment key
  (``np.bincount``/``ufunc.reduceat`` over the concatenation), so the
  Python-per-partition loop in ``engine.execute_requests`` collapses to one
  call per (table, plan).

Bitwise contract: the batch path returns **byte-identical** merged tables
to concatenating the per-partition reference results. The load-bearing
facts: elementwise numpy ops distribute over concatenation exactly;
``np.bincount`` accumulates weights in array order (so segment-keyed sums
add the same floats in the same order as per-partition sums); stable
argsort + ``reduceat`` reduce identical segments; and the keyless-agg /
top-k stages intentionally drop to a per-segment loop because their
reference semantics (``np.sum`` pairwise summation, ``argpartition`` tie
choices, the empty-partition ``[0.]`` placeholder) are not
concatenation-invariant — those loops run on the already-filtered rows, so
the heavy stages stay fused. ``tests/test_executor.py`` pins all of this
against the reference oracle.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cost import RequestCost
from repro.core.plan import _AGG_OUT_ROWS, PushPlan
from repro.queryproc import expressions as ex
from repro.queryproc import operators as ops
from repro.queryproc.table import ColumnTable
from repro.storage.catalog import Partition


@dataclasses.dataclass
class CompiledPushPlan:
    """A PushPlan lowered once: compiled kernels + memoized invariants."""
    plan: PushPlan
    accessed: Tuple[str, ...]               # memoized plan.accessed_columns()
    pred_fn: Optional[Callable]             # fused numpy predicate kernel
    pred_cols: Tuple[str, ...]              # columns the predicate reads
    sel_fn: Optional[Callable]              # compiled selectivity estimator
    agg_spec: Optional[Dict[str, Tuple[str, str]]]  # out -> (fn, col)
    # cost-model per-plan constants (plan.estimate_cost recomputes these
    # per partition; only the stats lookups actually vary across partitions)
    _n_derived_out: int = 0
    _agg_keys: Tuple[str, ...] = ()

    # ------------------------------------------------------------ execution
    def execute(self, data: ColumnTable, bitmap: Optional[np.ndarray] = None
                ) -> Tuple[ColumnTable, Dict]:
        """Single-partition fused path: the same *result table* as
        ``plan.execute_push_plan``, minus the per-call plan re-walk. The
        aux dict is always empty — plans whose value IS the aux by-product
        (bitmap_only's packed bitmap, shuffle's parts/position vector) must
        use the reference path, which this guards against."""
        assert not self.plan.bitmap_only and self.plan.shuffle is None, \
            "aux-producing plans need plan.execute_push_plan"
        merged = self.execute_batch([data],
                                    None if bitmap is None else [bitmap])
        return merged, {}

    def execute_batch(self, tables: Sequence[ColumnTable],
                      bitmaps: Optional[Sequence[np.ndarray]] = None
                      ) -> ColumnTable:
        """All partitions sharing this plan in one vectorized pass.
        Returns the merged table — byte-identical to
        ``ColumnTable.concat([execute_push_plan(plan, t)[0] for t in tables])``.
        """
        plan = self.plan
        assert plan.columns or plan.agg is not None, \
            "plans must declare output columns (the splitter guarantees it)"
        n_parts = len(tables)
        lens = np.asarray([len(t) for t in tables], np.int64)

        def concat(column: str) -> np.ndarray:
            if n_parts == 1:
                return np.asarray(tables[0].cols[column])
            return np.concatenate([t.cols[column] for t in tables])

        # accessed columns only: the reference filters whole partitions,
        # but output columns are always a subset of accessed + derived
        present = [c for c in self.accessed if c in tables[0].cols]

        # ---- filter stage: one fused predicate pass over the predicate
        # columns, then gather only the *surviving* rows of the remaining
        # columns (pushed predicates are selective — copying non-survivors
        # was the dominant batch cost)
        cols: Dict[str, np.ndarray]
        if plan.apply_bitmap:
            assert bitmaps is not None, "compute-layer bitmaps required"
            masks = [ops.unpack_bitmap(w, int(m))
                     for w, m in zip(bitmaps, lens)]
            cols = {}
        elif self.pred_fn is not None:
            pcols = {c: concat(c) for c in self.pred_cols
                     if c in tables[0].cols}
            mask = self.pred_fn(pcols)
            masks = (np.split(mask, np.cumsum(lens)[:-1]) if n_parts > 1
                     else [mask])
            # predicate columns are already concatenated: one gather
            cols = {c: v[mask] for c, v in pcols.items() if c in present}
        else:
            masks = None
            cols = {}
        segmented = plan.agg is not None or plan.top_k is not None
        if masks is None:
            seg = np.repeat(np.arange(n_parts), lens) if segmented else None
            for c in present:
                cols.setdefault(c, concat(c))
        else:
            counts = np.asarray([int(m.sum()) for m in masks])
            seg = np.repeat(np.arange(n_parts), counts) if segmented else None
            for c in present:
                if c not in cols:
                    cols[c] = (tables[0].cols[c][masks[0]] if n_parts == 1
                               else np.concatenate(
                                   [t.cols[c][m]
                                    for t, m in zip(tables, masks)]))

        # ---- derive stage (fused: one elementwise pass per derived column)
        for name, incols, fn in plan.derive:
            cols[name] = fn(*[cols[c] for c in incols])

        t = ColumnTable(cols)
        if plan.agg is not None:
            # aggregation collapses rows: seg is re-derived at group level
            # so a downstream top-k segments the agg *output*, not the input
            out, seg = self._batched_agg(t, seg, n_parts)
        elif plan.columns:
            out = t.select([c for c in plan.columns if c in t.cols])
        else:
            out = t
        if plan.top_k is not None:
            out = self._segmented_top_k(out, seg, n_parts)
        return out

    # ----------------------------------------------------- agg / top-k
    def _batched_agg(self, t: ColumnTable, seg: np.ndarray, n_parts: int
                     ) -> Tuple[ColumnTable, np.ndarray]:
        """Returns (partials table, per-output-row partition id)."""
        keys, _ = self.plan.agg
        if keys:
            return self._segment_keyed_agg(t, seg, keys)
        # keyless (scalar) aggs: the reference emits one row per partition,
        # with np.sum's pairwise summation and a float64 [0.] placeholder
        # for empty partitions — neither is concatenation-invariant, so
        # reduce per segment over the already-filtered rows
        bounds = np.searchsorted(seg, np.arange(n_parts + 1))
        out: Dict[str, List[np.ndarray]] = {name: [] for name in self.agg_spec}
        for p in range(n_parts):
            lo, hi = int(bounds[p]), int(bounds[p + 1])
            for name, (fn, col) in self.agg_spec.items():
                if hi == lo:
                    val = np.asarray([0], np.float64)
                elif fn == "count":  # length-only: no column materialization
                    val = np.asarray([np.asarray(hi - lo, np.int64)])
                else:
                    arr = (t.cols[col] if col else next(iter(t.cols.values())))
                    val = np.asarray([ops.AGG_FUNCS[fn](arr[lo:hi])])
                out[name].append(val)
        return (ColumnTable({n: np.concatenate(v) for n, v in out.items()}),
                np.arange(n_parts))  # one output row per partition

    def _segment_keyed_agg(self, t: ColumnTable, seg: np.ndarray,
                           keys: Tuple[str, ...]
                           ) -> Tuple[ColumnTable, np.ndarray]:
        """Grouped partials over all partitions at once, the partition id as
        implicit leading segment key.

        The reference (``ops.grouped_agg`` per partition) sorts a rec array
        — a void-dtype comparison per element. Here one type-specialized
        stable ``np.lexsort`` over (pid, keys...) orders the concatenation;
        group boundaries fall out of adjacent-row key changes. Sorting by
        pid first makes the group order *identical* to concatenating the
        per-partition key-sorted outputs, and sums/counts go through
        ``np.bincount`` over the original-order group ids, so each group
        accumulates the same floats in the same order as the reference —
        bitwise-identical partials (reduceat is pairwise, bincount is
        sequential: only bincount matches)."""
        key_arrs = [t.cols[k] for k in keys]
        n = len(seg)
        # lexsort: last key is primary -> (seg, k1, .., kn) lexicographic
        order = np.lexsort(tuple(reversed(key_arrs)) + (seg,))
        sorted_keys = [a[order] for a in [seg, *key_arrs]]
        new_group = np.zeros(n, bool)
        if n:
            new_group[0] = True
        for a in sorted_keys:
            new_group[1:] |= a[1:] != a[:-1]
        starts = np.flatnonzero(new_group)           # sorted-domain offsets
        n_groups = len(starts)
        gid = np.cumsum(new_group) - 1               # sorted-domain group id
        inv = np.empty(n, np.intp)
        inv[order] = gid                             # original-order group id
        first_idx = order[starts]                    # stable: first original row
        counts = np.bincount(inv, minlength=n_groups)
        out = {k: t.cols[k][first_idx] for k in keys}
        for name, (fn, col) in self.agg_spec.items():
            if fn == "count":
                out[name] = counts.astype(np.int64)
            elif fn == "sum":
                out[name] = np.bincount(inv, weights=t.cols[col].astype(np.float64),
                                        minlength=n_groups)
            elif fn == "mean":
                s = np.bincount(inv, weights=t.cols[col].astype(np.float64),
                                minlength=n_groups)
                out[name] = s / np.maximum(counts, 1)
            else:
                red = np.minimum if fn == "min" else np.maximum
                out[name] = red.reduceat(t.cols[col][order], starts)
        return ColumnTable(out), sorted_keys[0][starts]  # per-group pid

    def _segmented_top_k(self, t: ColumnTable, seg: np.ndarray, n_parts: int
                         ) -> ColumnTable:
        # per-partition top-k supersets, exactly as the reference selects
        # them (argpartition tie behavior is position-dependent, so the
        # reference operator runs per segment — on filtered rows only)
        col, k, asc = self.plan.top_k
        bounds = np.searchsorted(seg, np.arange(n_parts + 1))
        parts = [ops.top_k(
            ColumnTable({c: v[bounds[p]:bounds[p + 1]]
                         for c, v in t.cols.items()}), col, k, asc)
            for p in range(n_parts)]
        return ColumnTable.concat(parts)

    # ------------------------------------------------------------ cost
    def estimate_cost(self, part: Partition) -> RequestCost:
        """Identical arithmetic to ``plan.estimate_cost`` with the per-plan
        constants memoized; only the stats lookups touch the partition."""
        plan = self.plan
        data = part.data
        stats = data.stats()
        acc_cols = [c for c in self.accessed if c in data.cols]
        s_in = data.nbytes(acc_cols, stored=True)
        raw_in = data.nbytes(acc_cols, stored=False)
        sel = self.sel_fn(stats) if self.sel_fn is not None else 1.0
        if plan.bitmap_only:
            out_cols = [c for c in plan.columns if c in data.cols]
            s_out = ((data.nbytes(out_cols, stored=False)
                      + 8 * self._n_derived_out * len(data)) * sel
                     + len(data) / 8)
        elif plan.agg is not None:
            groups = 1
            for key in self._agg_keys:
                groups *= max(1, stats[key].ndv if key in stats
                              else _AGG_OUT_ROWS)
            groups = min(groups, _AGG_OUT_ROWS, len(data))
            s_out = groups * 8 * (len(self._agg_keys) + len(self.agg_spec))
        else:
            out_cols = [c for c in plan.columns if c in data.cols]
            s_out = (data.nbytes(out_cols, stored=False)
                     + 8 * self._n_derived_out * len(data)) * sel
        if plan.top_k is not None:
            s_out = min(s_out, plan.top_k[1] * 8 * max(1, len(plan.columns)))
        return RequestCost(s_in=int(s_in), s_out=int(max(64, s_out)),
                           compute_in=int(raw_in))


# ----------------------------------------------------------- compile cache
_CACHE: "OrderedDict[int, CompiledPushPlan]" = OrderedDict()
_CACHE_CAP = 256


def compile_push_plan(plan: PushPlan) -> CompiledPushPlan:
    """Lower a PushPlan once; memoized per plan object (the engine issues
    one plan instance per (query, table) shared by all its partitions)."""
    hit = _CACHE.get(id(plan))
    if hit is not None and hit.plan is plan:   # guard against id() reuse
        _CACHE.move_to_end(id(plan))
        return hit
    derived = frozenset(n for n, _, _ in plan.derive)
    cplan = CompiledPushPlan(
        plan=plan,
        accessed=plan.accessed_columns(),
        pred_fn=(ex.compile_expr(plan.predicate)
                 if plan.predicate is not None and not plan.apply_bitmap
                 else None),
        pred_cols=(tuple(sorted(ex.columns_of(plan.predicate)))
                   if plan.predicate is not None and not plan.apply_bitmap
                   else ()),
        sel_fn=(ex.compile_selectivity(plan.predicate)
                if plan.predicate is not None else None),
        agg_spec=({o: (f, c) for o, f, c in plan.agg[1]}
                  if plan.agg is not None else None),
        _n_derived_out=len(derived & set(plan.columns)),
        _agg_keys=tuple(plan.agg[0]) if plan.agg is not None else (),
    )
    _CACHE[id(plan)] = cplan
    while len(_CACHE) > _CACHE_CAP:
        _CACHE.popitem(last=False)
    return cplan
