"""Adaptive Pushdown Arbitrator — the paper's Algorithm 1 (+ §3.4 PA-aware).

Runs at each storage node. Invoked when a request arrives or an execution
slot frees. State: a wait queue and two finite slot pools (pushdown
execution / pushback transfer). The compute layer always submits *every*
pushable request (the core idea: the resource owner decides at runtime).

FIFO mode (Algorithm 1): head-of-queue only; for each request the faster
path (by the §3.3 cost model, scan cancelled) is tried first, then the
slower; if neither pool has a slot, arbitration stops (both saturated).

PA-aware mode (§3.4): the queue is kept sorted by PA = t_pb - t_pd;
pushdown slots consume from the high-PA end, pushback slots from the
low-PA end.

Both modes decide from the ``RequestCost`` they are handed: under an
active ``CardinalityCorrector`` (core.cost) the ``s_out`` inside has been
rescaled by measured feedback before submission, so ``t_pd`` — and with
it every decision and every PA ordering — converges toward observed
bytes across repeated runs without any change here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.cost import RequestCost, StorageResources
from repro.core.faults import ROUTE_DENY
from repro.obs import trace as obs_trace
from repro.obs.metrics import Metrics, get_metrics

PUSHDOWN, PUSHBACK = "pushdown", "pushback"

# a live decision hook: called once per request the moment the Arbitrator
# assigns it a path — the runtime uses it to route (and order) real work
DecisionHook = Callable[[int, str], None]


class MeasuredLoad:
    """Measured-signal feedback port for the backlog guard (flag-gated via
    ``EngineConfig.measured_feedback``; default off).

    Instead of the fluid model's own wait queue, the Arbitrator can gauge
    backlog from the *live* occupancy signals ``runtime.run_stream``
    publishes every dispatch wave: the ``stream.node{n}.exec_queue`` /
    ``stream.node{n}.ship_queue`` gauges and ``stream.cores_free`` — the
    same numbers stamped on ``wave_sample`` trace events. One instance is
    shared by every node's Arbitrator in a ``simulate()`` call; each
    ``drain()`` refreshes the snapshot through ``metrics.epoch()`` (delta
    semantics advance the shared epoch marker, matching how a distributed
    poller would consume the registry). When a node's gauges have never
    been published, ``queue_depth`` returns None and the Arbitrator falls
    back to its fluid queue — the port degrades to exact PR-6 behavior."""

    def __init__(self, metrics: Optional[Metrics] = None):
        self._m = metrics
        self._gauges: Dict[str, float] = {}

    def refresh(self) -> None:
        m = self._m if self._m is not None else get_metrics()
        self._gauges = dict(m.epoch().get("gauges", {}))

    def queue_depth(self, node_id: int, path: str) -> Optional[float]:
        kind = "exec" if path == PUSHDOWN else "ship"
        return self._gauges.get(f"stream.node{node_id}.{kind}_queue")

    def cores_free(self) -> Optional[float]:
        return self._gauges.get("stream.cores_free")


@dataclasses.dataclass
class Pending:
    req_id: int
    cost: RequestCost
    pa: float


class Arbitrator:
    def __init__(self, res: StorageResources, pa_aware: bool = False,
                 forced_path: Optional[str] = None,
                 backlog_guard: bool = True,
                 on_decide: Optional[DecisionHook] = None,
                 measured: Optional[MeasuredLoad] = None,
                 node_id: int = 0,
                 breaker=None):
        self.res = res
        self.pa_aware = pa_aware
        self.forced_path = forced_path  # "pushdown"/"pushback" for the baselines
        self.on_decide = on_decide      # live callback: (req_id, path)
        self.measured = measured        # measured-signal backlog source
        self.node_id = node_id
        # per-(node, path) circuit breaker (core.faults.CircuitBreaker),
        # fed by the runtime's storage-execute outcomes — the same live
        # signal family as `measured`. While this node's pushdown circuit
        # is open, NEW decisions route to pushback (recovery routing beats
        # the cost ordering and the backlog guard); a half-open probe is
        # admitted down pushdown so a recovered node can close the circuit.
        # Forced baselines ignore it: their path is the experiment.
        self.breaker = breaker
        # Alg 1 lines 7/10 assign to the SLOWER path whenever the faster
        # pool is full. Verbatim, that turns end-of-queue requests into
        # stragglers (the slower path outlives the fast pool's backlog).
        # The guard admits a request to the slower path only while the
        # faster pool's queued backlog would take at least as long — the
        # "balance the resource utilization" intuition of §3.2 made
        # explicit. backlog_guard=False restores verbatim Algorithm 1.
        self.backlog_guard = backlog_guard
        self.queue: List[Pending] = []
        self.free_pd = res.pd_slots
        self.free_pb = res.pb_slots
        self.admitted = 0
        self.pushed_back = 0

    # -------------------------------------------------------------- events
    def submit(self, req_id: int, cost: RequestCost) -> List[Tuple[int, str]]:
        p = Pending(req_id, cost, cost.pa(self.res))
        if self.pa_aware:
            # keep queue sorted descending by PA
            lo, hi = 0, len(self.queue)
            while lo < hi:
                mid = (lo + hi) // 2
                if self.queue[mid].pa >= p.pa:
                    lo = mid + 1
                else:
                    hi = mid
            self.queue.insert(lo, p)
        else:
            self.queue.append(p)
        return self.drain()

    def release(self, path: str) -> List[Tuple[int, str]]:
        # capped at the pool size: a spurious release (a double-release
        # from a retried/hedged execution, or a release racing a drain)
        # must not mint slots the node does not have
        if path == PUSHDOWN:
            self.free_pd = min(self.res.pd_slots, self.free_pd + 1)
        else:
            self.free_pb = min(self.res.pb_slots, self.free_pb + 1)
        return self.drain()

    def _pd_tripped(self) -> bool:
        """Consult the breaker for one new pushdown admission. Only called
        with a pushdown slot free — each call is one routing decision, so
        denials (not wall clock) advance the breaker toward its half-open
        probe, keeping recovery deterministic under any interleaving."""
        return (self.breaker is not None
                and self.breaker.route(self.node_id, PUSHDOWN) == ROUTE_DENY)

    # -------------------------------------------------------------- core
    def _try(self, path: str) -> bool:
        if path == PUSHDOWN and self.free_pd > 0:
            self.free_pd -= 1
            self.admitted += 1
            return True
        if path == PUSHBACK and self.free_pb > 0:
            self.free_pb -= 1
            self.pushed_back += 1
            return True
        return False

    def _emit(self, assigned: List[Tuple[int, str]]) -> List[Tuple[int, str]]:
        if assigned:
            tr = obs_trace.get_tracer()
            if tr.enabled:
                # live load signal at the instant of the decision batch:
                # what the Arbitrator saw (remaining queue, free slots)
                # when it routed — one compact channel entry per batch
                tr.decisions.record_batch(
                    assigned, kind="arbitrate",
                    queue_depth=len(self.queue),
                    free_pd=self.free_pd, free_pb=self.free_pb,
                    pa_aware=self.pa_aware, forced=self.forced_path)
        if self.on_decide is not None:
            for rid, path in assigned:
                self.on_decide(rid, path)
        return assigned

    def drain(self) -> List[Tuple[int, str]]:
        """Assign queued requests to slots; returns [(req_id, path), ...]."""
        out: List[Tuple[int, str]] = []
        if self.measured is not None:
            self.measured.refresh()  # one snapshot per drain batch
        if self.forced_path is not None:
            while self.queue and self._try(self.forced_path):
                out.append((self.queue.pop(0).req_id, self.forced_path))
            return self._emit(out)
        if self.pa_aware:
            return self._emit(self._drain_pa(out))
        while self.queue:
            p = self.queue[0]
            if self.free_pd > 0 and self._pd_tripped():
                # open circuit: this decision goes to pushback — recovery
                # routing overrides both the cost ordering and the backlog
                # guard (demotion is a safety decision, not a spill)
                if self._try(PUSHBACK):
                    out.append((self.queue.pop(0).req_id, PUSHBACK))
                    continue
                break  # transfer pool saturated too — wait for a release
            t_pd = p.cost.t_pd(self.res, include_scan=False)
            t_pb = p.cost.t_pb(self.res, include_scan=False)
            first, second = ((PUSHDOWN, PUSHBACK) if t_pd < t_pb
                             else (PUSHBACK, PUSHDOWN))
            if self._try(first):
                out.append((self.queue.pop(0).req_id, first))
            elif self._spill_ok(t_pd, t_pb, first) and self._try(second):
                out.append((self.queue.pop(0).req_id, second))
            else:
                break  # both pools saturated (Algorithm 1 line 14)
        return self._emit(out)

    def _spill_ok(self, t_pd: float, t_pb: float, fast: str) -> bool:
        if not self.backlog_guard:
            return True
        slots = self.res.pd_slots if fast == PUSHDOWN else self.res.pb_slots
        t_fast, t_slow = (t_pd, t_pb) if fast == PUSHDOWN else (t_pb, t_pd)
        depth = (self.measured.queue_depth(self.node_id, fast)
                 if self.measured is not None else None)
        if depth is None:
            depth = len(self.queue)  # fluid fallback (exact prior behavior)
        backlog = depth / max(1, slots) * t_fast
        return t_slow <= backlog

    def _drain_pa(self, out: List[Tuple[int, str]]) -> List[Tuple[int, str]]:
        """§3.4: pushdown takes the highest-PA request, pushback the lowest.
        Invariant kept: full utilization of both resources."""
        while self.queue:
            # a tripped pushdown circuit makes the exec pool unavailable
            # for NEW work this iteration (a granted probe re-enables it)
            pd_free = self.free_pd > 0 and not self._pd_tripped()
            head_hi, head_lo = self.queue[0], self.queue[-1]
            # prefer each slot type's best-suited end
            if pd_free and (head_hi.pa >= 0 or self.free_pb == 0):
                self._try(PUSHDOWN)
                out.append((self.queue.pop(0).req_id, PUSHDOWN))
            elif self.free_pb > 0:
                self._try(PUSHBACK)
                out.append((self.queue.pop().req_id, PUSHBACK))
            elif pd_free:
                self._try(PUSHDOWN)
                out.append((self.queue.pop(0).req_id, PUSHDOWN))
            else:
                break
        return out
