from repro.data.pipeline import CorpusQuery, PushdownDataPipeline  # noqa: F401
