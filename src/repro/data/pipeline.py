"""Training-data pipeline with adaptive computation pushdown.

The paper's engine, pointed at an ML corpus instead of TPC-H: the training
job declares a *corpus query* — quality/domain filters (selection), the
token columns it needs (projection), sequence packing (selection bitmap
over document slots), and shuffle-to-DP-rank (distributed data shuffle).
Each corpus partition becomes one pushdown request; the same Arbitrator
(Algorithm 1) decides per partition whether the storage host executes the
query or pushes raw data back to the accelerator side, where the identical
operators run as Pallas kernels (predicate_bitmap / bitmap_apply /
hash_partition).

Shuffle-to-rank is the ingest-side form of §4.2's shuffle pushdown: the
storage host hash-partitions *documents* by destination DP rank before the
feed, so the batch arrives microbatched as (accum, mb, S) with mb already
rank-aligned — the in-mesh redistribution all-to-all is gone from the
input path (see repro.launch.steps' batch layout).

Everything is deterministic in (seed, step): a restart resumes the stream
exactly (the checkpoint stores only the step counter).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.cost import RequestCost, StorageResources
from repro.core.simulator import (MODE_ADAPTIVE, SimRequest, SimResult,
                                  simulate)
from repro.queryproc import operators as ops
from repro.queryproc.expressions import Col, Expr, evaluate


@dataclasses.dataclass(frozen=True)
class CorpusQuery:
    """What the trainer asks of the corpus (the pushable plan)."""
    min_quality: float = 0.3
    domains: Optional[Tuple[int, ...]] = None
    seq_len: int = 1024
    global_batch: int = 8
    accum: int = 1
    dp_ranks: int = 1

    def predicate(self) -> Expr:
        p: Expr = Col("quality") >= self.min_quality
        if self.domains is not None:
            p = p & Col("domain").isin(self.domains)
        return p


@dataclasses.dataclass
class CorpusPartition:
    part_id: int
    host: int
    tokens: np.ndarray    # (docs, doc_len) int32
    quality: np.ndarray   # (docs,) f32
    domain: np.ndarray    # (docs,) int32
    doc_id: np.ndarray    # (docs,) int64 (stable global ids)


def synth_corpus(num_partitions: int = 8, docs_per_part: int = 256,
                 doc_len: int = 512, vocab: int = 32000, hosts: int = 2,
                 seed: int = 0) -> List[CorpusPartition]:
    rng = np.random.default_rng(seed)
    parts = []
    for p in range(num_partitions):
        parts.append(CorpusPartition(
            part_id=p, host=p % hosts,
            tokens=rng.integers(1, vocab, (docs_per_part, doc_len),
                                dtype=np.int32),
            quality=rng.random(docs_per_part).astype(np.float32),
            domain=rng.integers(0, 8, docs_per_part, dtype=np.int32),
            doc_id=(np.arange(docs_per_part, dtype=np.int64)
                    + p * docs_per_part)))
    return parts


class PushdownDataPipeline:
    """Iterator of rank-aligned microbatched token batches."""

    def __init__(self, corpus: List[CorpusPartition], query: CorpusQuery,
                 res: StorageResources = StorageResources(),
                 mode: str = MODE_ADAPTIVE, seed: int = 0):
        self.corpus = corpus
        self.query = query
        self.res = res
        self.mode = mode
        self.seed = seed
        self.last_sim: Optional[SimResult] = None
        self._stream = self._build_stream()

    # ------------------------------------------------ the pushdown query
    def _partition_cost(self, part: CorpusPartition) -> RequestCost:
        raw = part.tokens.nbytes + part.quality.nbytes + part.domain.nbytes
        sel = float(np.clip(1.0 - self.query.min_quality, 0.01, 1.0))
        if self.query.domains is not None:
            sel *= len(self.query.domains) / 8.0
        return RequestCost(s_in=raw, s_out=int(raw * sel) + 64,
                           compute_in=raw)

    def _run_query(self, part: CorpusPartition
                   ) -> Tuple[np.ndarray, np.ndarray]:
        """Execute the corpus query on one partition (either side runs the
        same operators -> identical batches regardless of the decision)."""
        cols = {"quality": part.quality, "domain": part.domain}
        mask = evaluate(self.query.predicate(),
                        type("T", (), {"cols": cols})())
        words = ops.pack_bitmap(mask)                      # selection bitmap
        keep = ops.unpack_bitmap(words, len(mask))
        toks = part.tokens[keep]
        ranks = ops.hash_partition_ids(part.doc_id[keep].astype(np.int64),
                                       self.query.dp_ranks)  # shuffle-to-rank
        return toks, ranks

    def _build_stream(self) -> Iterator[Dict[str, np.ndarray]]:
        q = self.query
        # arbitrate all partition requests once per epoch (they re-arrive
        # every epoch; decisions adapt to storage_power)
        reqs = [SimRequest(p.part_id, p.host, "corpus",
                           self._partition_cost(p)) for p in self.corpus]
        self.last_sim = simulate(reqs, self.res, self.mode)

        per_rank: List[List[np.ndarray]] = [[] for _ in range(q.dp_ranks)]
        rng = np.random.default_rng(self.seed)
        epoch = 0
        order = rng.permutation(len(self.corpus))
        while True:
            for pi in order:
                toks, ranks = self._run_query(self.corpus[pi])
                for r in range(q.dp_ranks):
                    rt = toks[ranks == r]
                    if len(rt):
                        per_rank[r].append(rt.reshape(-1))
                yield from self._drain(per_rank)
            epoch += 1
            order = rng.permutation(len(self.corpus))

    def _drain(self, per_rank) -> Iterator[Dict[str, np.ndarray]]:
        """Pack per-rank token streams into (accum, mb, S) batches."""
        q = self.query
        mb = q.global_batch // q.accum
        rows_per_rank = max(1, mb // q.dp_ranks)
        need = q.seq_len * rows_per_rank * q.accum
        while all(sum(map(len, s)) >= need for s in per_rank):
            rank_rows = []
            for r in range(q.dp_ranks):
                buf = np.concatenate(per_rank[r]) if len(per_rank[r]) > 1 \
                    else per_rank[r][0]
                take, rest = buf[:need], buf[need:]
                per_rank[r] = [rest] if len(rest) else []
                rank_rows.append(take.reshape(q.accum, rows_per_rank,
                                              q.seq_len))
            # (accum, mb, S): microbatch dim = concat over ranks — matches
            # the DP-sharded batch layout of launch/steps
            batch = np.concatenate(rank_rows, axis=1)
            yield {"tokens": batch}

    def __iter__(self):
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        return next(self._stream)

    # ------------------------------------------------------------ metrics
    def stats(self) -> Dict[str, float]:
        sim = self.last_sim
        if sim is None:
            return {}
        return {"admitted": float(sim.admitted()),
                "pushed_back": float(sum(sim.pushed_back_by_query.values())),
                "ingest_makespan_s": sim.makespan,
                "ingest_net_bytes": sim.net_bytes}
