"""Whisper-style encoder-decoder. Conv/mel frontend is a STUB: the model
consumes precomputed frame embeddings (B, n_frames, d_model). Learned absolute
positions are replaced by RoPE (decoder self-attn) / position-free encoder
self-attn — documented in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import flags
from repro.models.attention import (attention, attn_out, attn_specs,
                                    blockwise_attention, decode_attention, qkv_proj)
from repro.models.layers import (apply_mlp, apply_norm, embed_specs, embed_tokens,
                                 lm_logits, mlp_specs, norm_specs)
from repro.models.params import p
from repro.models.transformer import _cache_positions, cache_update


def init_specs(cfg: ModelConfig):
    E, L = cfg.num_encoder_layers, cfg.num_layers
    enc = {"norm1": norm_specs(cfg, (E,)), "attn": attn_specs(cfg, (E,)),
           "norm2": norm_specs(cfg, (E,)), "mlp": mlp_specs(cfg, (E,))}
    dec = {"norm1": norm_specs(cfg, (L,)), "attn": attn_specs(cfg, (L,)),
           "norm_x": norm_specs(cfg, (L,)), "xattn": attn_specs(cfg, (L,)),
           "norm2": norm_specs(cfg, (L,)), "mlp": mlp_specs(cfg, (L,))}
    return {"embed": embed_specs(cfg), "enc_layers": enc, "enc_norm": norm_specs(cfg),
            "dec_layers": dec, "final_norm": norm_specs(cfg)}


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, F, d_model) precomputed embeddings -> encoder states."""
    x = frames
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        h = apply_norm(x, lp["norm1"], cfg)
        q, k, v = qkv_proj(h, lp["attn"], cfg, positions, rope=False)
        x = x + attn_out(attention(q, k, v, cfg, kind="bidir"), lp["attn"])
        x = x + apply_mlp(apply_norm(x, lp["norm2"], cfg), lp["mlp"], cfg)
        return x, None

    x, _ = flags.maybe_scan(body, x, params["enc_layers"])
    return apply_norm(x, params["enc_norm"], cfg)


def _cross_kv(lp, cfg, enc):
    k = jnp.einsum("bsd,dhk->bshk", enc, lp["xattn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc, lp["xattn"]["wv"])
    return k, v


def forward(params, cfg: ModelConfig, batch, *, blockwise: bool = False,
            remat: bool = False, collect_cache: bool = False, **_):
    enc = encode(params, cfg, batch["frames"])
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens)
    mask = jnp.ones(tokens.shape, jnp.float32)
    positions = jnp.arange(x.shape[1])

    def body(x, lp):
        h = apply_norm(x, lp["norm1"], cfg)
        q, k, v = qkv_proj(h, lp["attn"], cfg, positions, rope=True)
        if blockwise:
            y = blockwise_attention(q, k, v, cfg, kind="causal")
        else:
            y = attention(q, k, v, cfg, kind="causal", q_pos=positions, kv_pos=positions)
        x = x + attn_out(y, lp["attn"])
        h = apply_norm(x, lp["norm_x"], cfg)
        qx = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"])
        kx, vx = _cross_kv(lp, cfg, enc)
        x = x + attn_out(attention(qx, kx, vx, cfg, kind="bidir"), lp["xattn"])
        x = x + apply_mlp(apply_norm(x, lp["norm2"], cfg), lp["mlp"], cfg)
        cache = (k, v, kx, vx) if collect_cache else None
        return x, cache

    body_fn = jax.checkpoint(body) if remat else body
    x, caches = flags.maybe_scan(body_fn, x, params["dec_layers"])
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_logits(params["embed"], x)
    cache = None
    if collect_cache:
        k, v, kx, vx = caches
        cache = {"k": k, "v": v, "xk": kx, "xv": vx}
    return logits, 0.0, mask, cache


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    L, KV, hd, F = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, cfg.num_audio_frames
    ax = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {
        "k": p((L, batch, seq_len, KV, hd), ax, init="zeros"),
        "v": p((L, batch, seq_len, KV, hd), ax, init="zeros"),
        "xk": p((L, batch, F, KV, hd), ax, init="zeros"),
        "xv": p((L, batch, F, KV, hd), ax, init="zeros"),
    }


def decode_step(params, cfg: ModelConfig, cache, pos, token):
    x = embed_tokens(params["embed"], token)

    def body(x, xs):
        lp, kc, vc, kx, vx = xs
        h = apply_norm(x, lp["norm1"], cfg)
        q, k, v = qkv_proj(h, lp["attn"], cfg, jnp.asarray(pos)[None], rope=True)
        kc = cache_update(kc, k, pos % kc.shape[1])
        vc = cache_update(vc, v, pos % vc.shape[1])
        y = decode_attention(q, kc, vc, pos)
        x = x + attn_out(y, lp["attn"])
        h = apply_norm(x, lp["norm_x"], cfg)
        qx = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"])
        y = decode_attention(qx, kx, vx, pos, kind="bidir")
        x = x + attn_out(y, lp["xattn"])
        x = x + apply_mlp(apply_norm(x, lp["norm2"], cfg), lp["mlp"], cfg)
        return x, (kc, vc)

    x, (ks, vs) = flags.maybe_scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"], cache["xk"], cache["xv"]))
    x = apply_norm(x, params["final_norm"], cfg)
    return lm_logits(params["embed"], x), {"k": ks, "v": vs,
                                           "xk": cache["xk"], "xv": cache["xv"]}
