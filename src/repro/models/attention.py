"""Attention: GQA projections + three execution regimes.

- ``attention``           train-time (scores materialized; fine at 4k with
                          gradient-accumulation microbatching)
- ``blockwise_attention`` prefill-time memory-bounded online-softmax over KV
                          blocks (pure JAX flash-attention formulation; the
                          baseline scans all KV blocks with masking — the
                          causal-skip variant is a §Perf hillclimb)
- ``decode_attention``    one query token vs a KV cache

GQA is computed with grouped einsums (no head replication). All softmax math
is fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraints import cs
from repro.models import flags
from repro.models.layers import apply_rope, rms_norm_1d
from repro.models.params import p

NEG_INF = -2.0e38


def attn_specs(cfg: ModelConfig, stack: tuple = ()):
    axes = tuple([("layers" if i == 0 else None) for i in range(len(stack))])
    hd, H, KV, d = cfg.head_dim, cfg.num_heads, cfg.num_kv_heads, cfg.d_model
    out = {
        "wq": p(stack + (d, H, hd), axes + ("embed", "heads", None)),
        "wk": p(stack + (d, KV, hd), axes + ("embed", "kv_heads", "kv_hd")),
        "wv": p(stack + (d, KV, hd), axes + ("embed", "kv_heads", "kv_hd")),
        "wo": p(stack + (H, hd, d), axes + ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        out["bq"] = p(stack + (H, hd), axes + ("heads", None), init="zeros")
        out["bk"] = p(stack + (KV, hd), axes + ("kv_heads", None), init="zeros")
        out["bv"] = p(stack + (KV, hd), axes + ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        out["q_norm"] = p(stack + (hd,), axes + (None,), init="ones")
        out["k_norm"] = p(stack + (hd,), axes + (None,), init="ones")
    return out


def qkv_proj(x, prm, cfg: ModelConfig, positions, rope: bool = True):
    """x: (B, S, d) -> q (B,S,H,hd), k/v (B,S,KV,hd).

    q: heads -> TP axis; when the head count doesn't divide it, the
    `attn_seq` fallback context-parallelizes the query sequence instead
    (k/v stay full-sequence — each shard attends its own q rows).
    """
    q = cs(jnp.einsum("bsd,dhk->bshk", x, prm["wq"]),
           "batch", "attn_seq", "heads", None)
    k = cs(jnp.einsum("bsd,dhk->bshk", x, prm["wk"]),
           "batch", None, "kv_heads", "kv_hd")
    v = cs(jnp.einsum("bsd,dhk->bshk", x, prm["wv"]),
           "batch", None, "kv_heads", "kv_hd")
    if cfg.qkv_bias:
        q, k, v = q + prm["bq"], k + prm["bk"], v + prm["bv"]
    if cfg.qk_norm:
        q = rms_norm_1d(q, prm["q_norm"])
        k = rms_norm_1d(k, prm["k_norm"])
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(y, prm):
    return cs(jnp.einsum("bshk,hkd->bsd", y, prm["wo"]),
              "batch", "act_seq", None)


def _group(q, num_kv):
    """(B,S,H,hd) -> (B,S,KV,G,hd)."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, num_kv, H // num_kv, hd)


def _mask(q_pos, kv_pos, kind: str, width: int) -> jax.Array:
    """Boolean keep-mask (..., Sq, Sk)."""
    qp, kp = q_pos[..., :, None], kv_pos[..., None, :]
    if kind == "bidir":
        return jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), bool)
    keep = (kp <= qp) & (kp >= 0)  # kp < 0 marks never-written ring-cache slots
    if kind == "local_window":
        keep &= kp > qp - width
    elif kind == "local_chunk":
        keep &= (kp // width) == (qp // width)
    return keep


def attention(q, k, v, cfg: ModelConfig, kind: str = "causal", width: int = 0,
              q_pos: Optional[jax.Array] = None, kv_pos: Optional[jax.Array] = None):
    """Materialized-score attention. q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    if q_pos is None:
        q_pos = jnp.arange(Sq)
    if kv_pos is None:
        kv_pos = jnp.arange(Sk)
    keep = _mask(q_pos, kv_pos, kind, width)
    from repro.distributed.constraints import mesh_axis_size
    flat_ok = H % max(1, mesh_axis_size("model")) == 0  # else the repeated
    # K/V can't shard on heads and replicates (B,Sk,H,hd) per layer
    if flags.current_attn_impl() == "flat" and H != KV and flat_ok:
        # §Perf: the grouped form reshapes H -> (KV, G); when H is TP-
        # sharded (e.g. 64@16) neither factor divides the axis, so GSPMD
        # re-shards the fp32 score tensor (measured 512 MiB all-reduces
        # per layer on deepseek-67b). Repeating K/V to the head dim keeps
        # everything sharded on H — each shard repeats only its local
        # heads, so the "blowup" is (B, Sk, H/shards, hd), i.e. tiny.
        kf = cs(jnp.repeat(k, H // KV, axis=2), "batch", None, "heads", None)
        vf = cs(jnp.repeat(v, H // KV, axis=2), "batch", None, "heads", None)
        s = jnp.einsum("bshd,bthd->bhst", q, kf,
                       preferred_element_type=jnp.float32)
        s = jnp.where(keep, s / jnp.sqrt(hd).astype(jnp.float32), NEG_INF)
        w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhst,bthd->bshd", w, vf)
    qg = _group(q, KV)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(keep, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    y = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return y.reshape(B, Sq, H, hd)


def blockwise_attention(q, k, v, cfg: ModelConfig, kind: str = "causal", width: int = 0,
                        q_block: int = 1024, kv_block: int = 1024,
                        causal_skip: bool = False):
    """Memory-bounded online-softmax attention for long prefill.

    q: (B,S,H,hd); k/v: (B,S,KV,hd). S must divide by the block sizes.

    causal_skip=False (paper-faithful baseline): every (q-block, kv-block)
    pair is computed and masked — ~2x FLOP waste on causal.
    causal_skip=True (§Perf): scan only the lower-triangular pairs via a
    flattened (i, j<=i) schedule with dynamic slices.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if S < 2 * q_block or S % q_block or S % kv_block:
        # short/ragged prompts: the blocked schedule degenerates — use the
        # materialized form (S^2 is small here by construction)
        return attention(q, k, v, cfg, kind=kind, width=width)
    nq, nk = S // q_block, S // kv_block
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qg = _group(q, KV).reshape(B, nq, q_block, KV, G, hd)

    def block(qi, kj, vj, qpos, kpos):
        s = jnp.einsum("bqkgh,btkh->bkgqt", qi, kj, preferred_element_type=jnp.float32) * scale
        s = jnp.where(_mask(qpos, kpos, kind, width), s, NEG_INF)
        m = s.max(-1)
        e = jnp.exp(s - m[..., None])
        l = e.sum(-1)
        o = jnp.einsum("bkgqt,btkh->bkgqh", e.astype(v.dtype), vj)
        return m, l, o  # (B,KV,G,qb), (B,KV,G,qb), (B,KV,G,qb,hd)

    if not causal_skip:
        # scan over kv blocks; all q blocks in parallel (vmapped over nq)
        def body(carry, j):
            m, l, o = carry
            kj = jax.lax.dynamic_slice_in_dim(k, j * kv_block, kv_block, 1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * kv_block, kv_block, 1)
            kpos = j * kv_block + jnp.arange(kv_block)
            qpos = jnp.arange(S).reshape(nq, q_block)
            bm, bl, bo = jax.vmap(
                lambda qi, qp: block(qi, kj, vj, qp, kpos),
                in_axes=(1, 0), out_axes=1,
            )(qg, qpos)  # (B,nq,KV,G,qb[,hd])
            mn = jnp.maximum(m, bm)
            a1, a2 = jnp.exp(m - mn), jnp.exp(bm - mn)
            return (mn, l * a1 + bl * a2,
                    o * a1[..., None].astype(o.dtype) + bo * a2[..., None].astype(o.dtype)), None

        m0 = jnp.full((B, nq, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, nq, KV, G, q_block), jnp.float32)
        o0 = jnp.zeros((B, nq, KV, G, q_block, hd), jnp.float32)
        (m, l, o), _ = flags.maybe_scan(body, (m0, l0, o0), jnp.arange(nk))
    else:
        # lower-triangular schedule: one (i, j) pair per step, j <= i
        pairs = [(i, j) for i in range(nq) for j in range(nk) if j * kv_block < (i + 1) * q_block]
        idx = jnp.asarray(pairs, jnp.int32)

        def body(carry, ij):
            m, l, o = carry
            i, j = ij[0], ij[1]
            qi = jax.lax.dynamic_slice_in_dim(qg, i, 1, 1)[:, 0]
            kj = jax.lax.dynamic_slice_in_dim(k, j * kv_block, kv_block, 1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * kv_block, kv_block, 1)
            qpos = i * q_block + jnp.arange(q_block)
            kpos = j * kv_block + jnp.arange(kv_block)
            bm, bl, bo = block(qi, kj, vj, qpos, kpos)
            mi = jax.lax.dynamic_slice_in_dim(m, i, 1, 1)[:, 0]
            li = jax.lax.dynamic_slice_in_dim(l, i, 1, 1)[:, 0]
            oi = jax.lax.dynamic_slice_in_dim(o, i, 1, 1)[:, 0]
            mn = jnp.maximum(mi, bm)
            a1, a2 = jnp.exp(mi - mn), jnp.exp(bm - mn)
            ln = li * a1 + bl * a2
            on = oi * a1[..., None] + bo.astype(jnp.float32) * a2[..., None]
            upd = lambda full, blk: jax.lax.dynamic_update_slice_in_dim(full, blk[:, None], i, 1)
            return (upd(m, mn), upd(l, ln), upd(o, on)), None

        m0 = jnp.full((B, nq, KV, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, nq, KV, G, q_block), jnp.float32)
        o0 = jnp.zeros((B, nq, KV, G, q_block, hd), jnp.float32)
        (m, l, o), _ = flags.maybe_scan(body, (m0, l0, o0), idx)

    y = o / jnp.maximum(l[..., None], 1e-30)
    # (B,nq,KV,G,qb,hd) -> (B,S,H,hd)
    y = y.transpose(0, 1, 4, 2, 3, 5).reshape(B, S, H, hd)
    return y.astype(q.dtype)


def local_chunk_attention(q, k, v, cfg: ModelConfig, chunk: int,
                          blockwise: bool = True):
    """Block-diagonal causal attention (llama4 local layers). S % chunk == 0.

    Chunks fold into the batch dim (sharded over DP); within a chunk the
    blockwise online-softmax keeps scores memory bounded (an 8192-wide chunk
    would otherwise materialize 86 GiB/device of fp32 scores at prefill)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    nc = S // chunk
    fold = lambda t: cs(t.reshape(B * nc, chunk, *t.shape[2:]),
                        "batch", "attn_seq", None, None)
    qf, kf, vf = fold(q), fold(k), fold(v)
    if blockwise and chunk % 1024 == 0 and chunk > 1024:
        y = blockwise_attention(qf, kf, vf, cfg, kind="causal")
    else:
        y = attention(qf, kf, vf, cfg, kind="causal")
    return y.reshape(B, S, H, hd)


def local_window_attention(q, k, v, cfg: ModelConfig, window: int):
    """Banded sliding-window attention via (prev, self) block pairs.

    S % window == 0; each query attends to positions (p - window, p].
    Only the 2w band is materialized — no S x S scores.
    """
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    w = window
    nb = S // w
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    qb = cs(q.reshape(B, nb, w, KV, G, hd),
            "batch", "attn_seq", None, "kv_heads", None, None)
    blk = lambda t: cs(t.reshape(B, nb, w, KV, hd),
                       "batch", "attn_seq", None, "kv_heads", None)
    kb, vb = blk(k), blk(v)
    pair = lambda t: jnp.concatenate(
        [jnp.concatenate([jnp.zeros_like(t[:, :1]), t[:, :-1]], 1), t], axis=2)
    kp_, vp_ = pair(kb), pair(vb)  # (B, nb, 2w, KV, hd)
    s = jnp.einsum("bnqkgh,bntkh->bnkgqt", qb, kp_,
                   preferred_element_type=jnp.float32) * scale
    qpos, kpos = w + jnp.arange(w), jnp.arange(2 * w)
    keep = (kpos[None, :] <= qpos[:, None]) & (kpos[None, :] > qpos[:, None] - w)  # (w, 2w)
    valid = jnp.ones((nb, 2 * w), bool).at[0, :w].set(False)  # block 0 has no prev
    keep = keep[None, :, :] & valid[:, None, :]  # (nb, w, 2w)
    s = jnp.where(keep[None, :, None, None], s, NEG_INF)
    wts = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    y = jnp.einsum("bnkgqt,bntkh->bnqkgh", wts, vp_)
    return y.reshape(B, S, H, hd)


def decode_attention(q, k_cache, v_cache, pos, kind: str = "causal", width: int = 0,
                     kv_pos: Optional[jax.Array] = None):
    """q: (B,1,H,hd); caches: (B,S,KV,hd); pos: scalar current position.

    kv_pos: positions of cache slots (for ring-buffer local caches)."""
    B, _, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    if kv_pos is None:
        kv_pos = jnp.arange(S)
    qg = _group(q, KV)[:, 0]  # (B,KV,G,hd)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache, preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(hd).astype(jnp.float32)
    keep = _mask(jnp.asarray(pos)[None], kv_pos, kind, width)[0]  # (S,)
    s = jnp.where(keep, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    y = jnp.einsum("bkgt,btkh->bkgh", w, v_cache)
    return y.reshape(B, 1, H, hd)
