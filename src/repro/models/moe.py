"""Routed mixture-of-experts with capacity-based dispatch.

Baseline dispatch is the t5x-style position-in-expert cumsum + scatter into an
(E, C, d) buffer — pure jnp, works under pjit/GSPMD. Tokens routed past
capacity are dropped (standard). The expert-parallel shard_map variant with an
explicit all-to-all (the in-mesh analogue of the paper's *distributed data
shuffle pushdown*) lives in ``repro.distributed.collectives`` and is a §Perf
alternative.

The capacity *keep mask* is exactly a selection bitmap in the paper's sense —
``repro.kernels.bitmap_apply`` applies it on TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraints import cs
from repro.models.layers import apply_mlp, mlp_specs
from repro.models.params import p


def moe_specs(cfg: ModelConfig, stack: tuple = ()):
    axes = tuple([("layers" if i == 0 else None) for i in range(len(stack))])
    E, d, f = cfg.num_experts + cfg.expert_pad, cfg.d_model, cfg.moe_d_ff
    out = {
        "router": p(stack + (d, cfg.num_experts), axes + ("embed", None)),
        "w_gate": p(stack + (E, d, f), axes + ("experts", "embed", "mlp")),
        "w_up": p(stack + (E, d, f), axes + ("experts", "embed", "mlp")),
        "w_out": p(stack + (E, f, d), axes + ("experts", "mlp", "embed")),
    }
    if cfg.num_shared_experts > 0:
        # shared experts are dense and always-on; merged into one MLP of width d_ff
        out["shared"] = mlp_specs(cfg, stack, d_ff=cfg.d_ff)
    return out


def capacity_for(cfg: ModelConfig, num_tokens: int) -> int:
    c = int(cfg.capacity_factor * num_tokens * cfg.num_experts_per_tok / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def apply_moe(x: jax.Array, prm: dict, cfg: ModelConfig):
    """x: (B, S, d) -> (y, aux_loss). Top-k capacity-routed experts + shared MLP."""
    from repro.models import flags
    if flags.current_moe_impl() == "ep":
        y, aux = apply_moe_ep(x, prm, cfg)
        if y is not None:
            return y, aux
    B, S, d = x.shape
    E, k = cfg.num_experts + cfg.expert_pad, cfg.num_experts_per_tok
    T = B * S
    xt = x.reshape(T, d)
    C = capacity_for(cfg, T)

    logits = jnp.einsum("td,de->te", xt, prm["router"], preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E) fp32
    topk_p, topk_i = jax.lax.top_k(probs, k)  # (T, k)
    if k > 1:
        topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

    # position of each (token, slot) within its expert, by arrival order
    flat_e = topk_i.reshape(T * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1  # (T*k,)
    keep = pos < C  # selection bitmap over routed slots (capacity mask)
    pos_c = jnp.where(keep, pos, 0)

    # dispatch: scatter kept tokens into the (E, C, d) expert buffer
    x_rep = jnp.repeat(xt, k, axis=0)  # (T*k, d)
    x_disp = jnp.where(keep[:, None], x_rep, 0)
    buf = jnp.zeros((E, C, d), x.dtype).at[flat_e, pos_c].add(x_disp)
    buf = cs(buf, "experts", None, None)  # EP: expert dim on the model axis

    # expert FFN (SwiGLU), batched over experts
    g = cs(jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, prm["w_gate"])),
           "experts", None, "mlp")
    u = cs(jnp.einsum("ecd,edf->ecf", buf, prm["w_up"]), "experts", None, "mlp")
    h = cs(jnp.einsum("ecf,efd->ecd", g * u, prm["w_out"]),
           "experts", None, None)  # (E, C, d)

    # combine: gather back, weight by gate prob, drop over-capacity slots
    y_slots = h[flat_e, pos_c]  # (T*k, d)
    gates = (topk_p.reshape(T * k) * keep).astype(x.dtype)
    y = (y_slots * gates[:, None]).reshape(T, k, d).sum(axis=1)

    # Switch-style load-balance auxiliary loss (over REAL experts only)
    E_real = cfg.num_experts
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topk_i[:, 0], E_real, dtype=jnp.float32), axis=0)
    mean_probs = probs.mean(axis=0)
    aux = E_real * jnp.sum(frac_tokens * mean_probs)

    if cfg.num_shared_experts > 0:
        y = y + apply_mlp(xt, prm["shared"], cfg)
    return y.reshape(B, S, d), aux


# ------------------------------------------------------------------ EP
def apply_moe_ep(x: jax.Array, prm: dict, cfg: ModelConfig):
    """shard_map expert parallelism — the in-mesh form of the paper's
    distributed-data-shuffle pushdown (§4.2 / §Perf hillclimb).

    The residual stream is batch-sharded over `data` and replicated over
    `model`; experts are sharded over `model`. Every model shard therefore
    already HOLDS every token — it routes and executes only ITS experts
    (partition-at-the-source, Fig 5b) and the per-token outputs combine
    with one psum over `model` of a (T_local, d) tensor. GSPMD's generic
    dispatch instead re-shards the (E, C, d) buffer per layer — measured
    88s of collective time per step on qwen2-moe train_4k (§Perf).

    Returns (None, None) when the mesh doesn't apply (falls back to dense).
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import constraints, sharding as shd

    ctx = constraints._ACTIVE.get()
    if ctx is None:
        return None, None
    mesh, rules = ctx
    if "model" not in mesh.shape:
        return None, None
    n = mesh.shape["model"]
    E_tot = cfg.num_experts + cfg.expert_pad
    if E_tot % n:
        return None, None
    bax = shd.batch_axes(mesh, rules)
    B, S, d = x.shape
    dp = 1
    for a in bax:
        dp *= mesh.shape[a]
    if B % max(1, dp):
        bax, dp = (), 1
    E_loc = E_tot // n
    k = cfg.num_experts_per_tok
    E_real = cfg.num_experts

    def body(xl, router, wg, wu, wo):
        Bl, Sl, _ = xl.shape
        T = Bl * Sl
        xt = xl.reshape(T, d)
        C = capacity_for(cfg, T)
        logits = jnp.einsum("td,de->te", xt, router,
                            preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topk_p, topk_i = jax.lax.top_k(probs, k)
        if k > 1:
            topk_p = topk_p / jnp.maximum(topk_p.sum(-1, keepdims=True), 1e-9)

        r = jax.lax.axis_index("model")
        flat_e = topk_i.reshape(T * k)
        gates_all = topk_p.reshape(T * k)
        is_local = (flat_e // E_loc) == r
        le = jnp.where(is_local, flat_e - r * E_loc, E_loc)  # E_loc = trash
        onehot = jax.nn.one_hot(le, E_loc + 1, dtype=jnp.int32)
        pos = (jnp.cumsum(onehot, axis=0) * onehot).sum(-1) - 1
        keep = is_local & (pos < C)
        pos_c = jnp.where(keep, pos, 0)
        le_c = jnp.where(keep, le, 0)

        x_rep = jnp.repeat(xt, k, axis=0)
        x_disp = jnp.where(keep[:, None], x_rep, 0)
        buf = jnp.zeros((E_loc, C, d), x.dtype).at[le_c, pos_c].add(x_disp)

        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = jnp.einsum("ecf,efd->ecd", g * u, wo)

        y_slots = h[le_c, pos_c]
        gates = (gates_all * keep).astype(x.dtype)
        y = (y_slots * gates[:, None]).reshape(T, k, d).sum(axis=1)
        y = jax.lax.psum(y, "model")     # combine across expert shards

        frac = jnp.mean(jax.nn.one_hot(topk_i[:, 0], E_real,
                                       dtype=jnp.float32), axis=0)
        aux = E_real * jnp.sum(frac * probs.mean(axis=0))
        for a in bax:                     # batch shards see different tokens
            aux = jax.lax.pmean(aux, a)
        return y.reshape(Bl, Sl, d), aux

    bspec = P(bax if len(bax) > 1 else (bax[0] if bax else None), None, None)
    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(bspec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(bspec, P()),
        check_rep=False,
    )(x, prm["router"], prm["w_gate"], prm["w_up"], prm["w_out"])
    if cfg.num_shared_experts > 0:
        y = y + apply_mlp(x.reshape(-1, d), prm["shared"], cfg).reshape(x.shape)
    return y, aux
