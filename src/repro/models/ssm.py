"""Mamba2 SSD (state-space duality) blocks [arXiv:2405.21060].

Train/prefill use the chunked dual form: quadratic *within* a chunk (MXU
matmuls) + a linear inter-chunk state recurrence (lax.scan over chunks).
Decode is the O(1)/token recurrence on the (B, H, P, N) state.

ngroups = 1 (B/C shared across heads), scalar A per head — the mamba2-2.7b
configuration. Projections are kept un-fused (separate wz/wx/wB/wC/wdt) so
each gets a clean sharding; mathematically identical to the fused in_proj.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraints import cs
from repro.models import flags
from repro.models.layers import rms_norm_1d
from repro.models.params import p


def ssm_specs(cfg: ModelConfig, stack: tuple = ()):
    axes = tuple([("layers" if i == 0 else None) for i in range(len(stack))])
    d, di, N, H, W = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.conv_width
    return {
        "wz": p(stack + (d, di), axes + ("embed", "inner")),
        "wx": p(stack + (d, di), axes + ("embed", "inner")),
        "wB": p(stack + (d, N), axes + ("embed", None)),
        "wC": p(stack + (d, N), axes + ("embed", None)),
        "wdt": p(stack + (d, H), axes + ("embed", "inner")),
        "conv_x": p(stack + (W, di), axes + (None, "inner"), scale=0.5),
        "conv_B": p(stack + (W, N), axes + (None, None), scale=0.5),
        "conv_C": p(stack + (W, N), axes + (None, None), scale=0.5),
        "A_log": p(stack + (H,), axes + ("inner",), dtype=jnp.float32, init="ssm_a"),
        "D": p(stack + (H,), axes + ("inner",), dtype=jnp.float32, init="ones"),
        "dt_bias": p(stack + (H,), axes + ("inner",), dtype=jnp.float32, init="zeros"),
        "norm": p(stack + (di,), axes + ("inner",), init="ones"),
        "out": p(stack + (di, d), axes + ("inner", "embed")),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, x: (B, T, C), w: (W, C); manual shift-sum (W small)."""
    W = w.shape[0]
    y = x * w[W - 1]
    for i in range(W - 1):
        shift = W - 1 - i
        y = y + jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]] * w[i]
    return jax.nn.silu(y)


def _project(x, prm, cfg: ModelConfig):
    z = cs(jnp.einsum("btd,de->bte", x, prm["wz"]), "batch", "act_seq", "inner")
    xc = cs(jnp.einsum("btd,de->bte", x, prm["wx"]), "batch", "act_seq", "inner")
    Bc = jnp.einsum("btd,dn->btn", x, prm["wB"])
    Cc = jnp.einsum("btd,dn->btn", x, prm["wC"])
    dt = jnp.einsum("btd,dh->bth", x, prm["wdt"]).astype(jnp.float32)
    dt = jax.nn.softplus(dt + prm["dt_bias"])
    return z, xc, Bc, Cc, dt


def ssd_forward(x: jax.Array, prm: dict, cfg: ModelConfig,
                init_state: jax.Array | None = None, return_cache: bool = False):
    """x: (B, T, d_model) -> (y, final_state | decode_cache). Chunked SSD.

    T must divide by cfg.ssm_chunk."""
    Bsz, T, _ = x.shape
    H, P, N, Q = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.ssm_chunk
    W = cfg.conv_width
    z, xc, Bc, Cc, dt = _project(x, prm, cfg)
    conv_tails = (xc[:, T - (W - 1):], Bc[:, T - (W - 1):], Cc[:, T - (W - 1):])
    T_pad = -(-T // Q) * Q
    if T_pad != T:
        # pad to a chunk multiple; dt=0 on padded steps => decay 1, zero input:
        # state and valid outputs are exactly unchanged
        padt = ((0, 0), (0, T_pad - T), (0, 0))
        z, xc, Bc, Cc, dt = (jnp.pad(a, padt) for a in (z, xc, Bc, Cc, dt))
    nc = T_pad // Q
    xc = _causal_conv(xc, prm["conv_x"])
    Bc = _causal_conv(Bc, prm["conv_B"])
    Cc = _causal_conv(Cc, prm["conv_C"])

    A = -jnp.exp(prm["A_log"])  # (H,) negative
    xh = cs(xc.reshape(Bsz, nc, Q, H, P), "batch", None, None, "inner", None)
    Bh = Bc.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    Ch = Cc.reshape(Bsz, nc, Q, N).astype(jnp.float32)
    dth = dt.reshape(Bsz, nc, Q, H)  # fp32

    a = dth * A  # (B,nc,Q,H) log-decay per step
    cum_a = jnp.cumsum(a, axis=2)  # inclusive cumsum within chunk
    seg_end = cum_a[:, :, -1]  # (B,nc,H) total chunk decay

    # ---- intra-chunk (dual quadratic form) ----
    # L[s,t] = exp(cum_a[s] - cum_a[t]) for t <= s
    diff = cum_a[:, :, :, None, :] - cum_a[:, :, None, :, :]  # (B,nc,s,t,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    # mask the *exponent*: exp(+large) for future entries would be inf, and
    # inf * 0 in the VJP poisons gradients with NaNs
    diff = jnp.where(mask[None, None, :, :, None], diff, -1e30)
    L = jnp.exp(diff)
    cb = jnp.einsum("bcsn,bctn->bcst", Ch, Bh)  # (B,nc,s,t)
    xdt = xh.astype(jnp.float32) * dth[..., None]  # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcst,bcsth,bcthp->bcshp", cb, L, xdt)

    # ---- chunk states ----
    w_state = jnp.exp(seg_end[:, :, None, :] - cum_a)  # (B,nc,t,H): decay t -> chunk end
    S_chunk = jnp.einsum("bctn,bcthp->bchpn", Bh, xdt * w_state[..., None])

    # ---- inter-chunk recurrence ----
    h0 = (jnp.zeros((Bsz, H, P, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def body(h, inp):
        Cq, cum_q, seg_q, Sq = inp  # (B,Q,N), (B,Q,H), (B,H), (B,H,P,N)
        y_in = jnp.einsum("bqn,bhpn->bqhp", Cq, h) * jnp.exp(cum_q)[..., None]
        h_next = h * jnp.exp(seg_q)[..., None, None] + Sq
        return h_next, y_in

    xs = (Ch.transpose(1, 0, 2, 3), cum_a.transpose(1, 0, 2, 3),
          seg_end.transpose(1, 0, 2), S_chunk.transpose(1, 0, 2, 3, 4))
    h_final, y_inter = flags.maybe_scan(body, h0, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)  # (B,nc,Q,H,P)

    y = y_intra + y_inter + xh.astype(jnp.float32) * prm["D"][:, None]
    y = y.reshape(Bsz, T_pad, H * P)[:, :T].astype(x.dtype)
    y = rms_norm_1d(y * jax.nn.silu(z[:, :T]), prm["norm"])
    out = jnp.einsum("bte,ed->btd", y, prm["out"])
    if return_cache:
        cx, cB, cC = conv_tails
        return out, {"h": h_final, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    return out, h_final


def ssd_decode_step(x: jax.Array, prm: dict, cfg: ModelConfig, cache: dict):
    """x: (B, 1, d_model); cache: {h:(B,H,P,N)f32, conv_x:(B,W-1,di), conv_B/C:(B,W-1,N)}."""
    H, P, N, W = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.conv_width
    z, xc, Bc, Cc, dt = _project(x, prm, cfg)

    def conv_step(val, hist, w):  # val (B,1,C), hist (B,W-1,C)
        window = jnp.concatenate([hist, val], axis=1)  # (B,W,C)
        out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w))
        return out, window[:, 1:]

    xcs, conv_x = conv_step(xc, cache["conv_x"], prm["conv_x"])
    Bcs, conv_B = conv_step(Bc, cache["conv_B"], prm["conv_B"])
    Ccs, conv_C = conv_step(Cc, cache["conv_C"], prm["conv_C"])

    A = -jnp.exp(prm["A_log"])
    dt1 = dt[:, 0]  # (B,H)
    decay = jnp.exp(dt1 * A)  # (B,H)
    xhp = xcs.reshape(-1, H, P).astype(jnp.float32) * dt1[..., None]
    h = cache["h"] * decay[..., None, None] + jnp.einsum(
        "bn,bhp->bhpn", Bcs.astype(jnp.float32), xhp)
    y = jnp.einsum("bn,bhpn->bhp", Ccs.astype(jnp.float32), h)
    y = y + xcs.reshape(-1, H, P).astype(jnp.float32) * prm["D"][:, None]
    y = y.reshape(-1, 1, H * P).astype(x.dtype)
    y = rms_norm_1d(y * jax.nn.silu(z), prm["norm"])
    out = jnp.einsum("bte,ed->btd", y, prm["out"])
    return out, {"h": h, "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C}


def ssm_cache_specs(cfg: ModelConfig, batch: int, stack: tuple = ()):
    """Abstract decode-cache layout (per layer-stack)."""
    H, Pd, N, W, di = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state, cfg.conv_width, cfg.d_inner
    ax = tuple(["layers"] * len(stack))
    return {
        "h": p(stack + (batch, H, Pd, N), ax + ("batch", "inner", None, None),
               dtype=jnp.float32, init="zeros"),
        "conv_x": p(stack + (batch, W - 1, di), ax + ("batch", None, "inner"), init="zeros"),
        "conv_B": p(stack + (batch, W - 1, N), ax + ("batch", None, None), init="zeros"),
        "conv_C": p(stack + (batch, W - 1, N), ax + ("batch", None, None), init="zeros"),
    }
