"""Global tracing flags for the model zoo.

``unroll_scans`` — when True, every ``maybe_scan`` in the model code fully
unrolls. Used by the dry-run *cost* pass: XLA's HloCostAnalysis counts a
while-loop body exactly once, so rolled scans undercount FLOPs/bytes by the
trip count. The cost pass lowers shallow (1- and 2-unit) configs with all
scans unrolled and extrapolates linearly over depth; the full-depth compile
(memory analysis + collective schedule) keeps scans rolled for compile speed.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Any, Callable

import jax

_UNROLL = contextvars.ContextVar("repro_unroll_scans", default=False)
_MOE_IMPL = contextvars.ContextVar("repro_moe_impl", default="dense")
_ATTN_IMPL = contextvars.ContextVar("repro_attn_impl", default="grouped")


@contextlib.contextmanager
def unroll_scans(enable: bool = True):
    tok = _UNROLL.set(enable)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


@contextlib.contextmanager
def moe_impl(kind: str):
    """"dense" (baseline GSPMD dispatch) | "ep" (shard_map expert
    parallelism -- the in-mesh shuffle-pushdown variant, see §Perf)."""
    tok = _MOE_IMPL.set(kind)
    try:
        yield
    finally:
        _MOE_IMPL.reset(tok)


def current_moe_impl() -> str:
    return _MOE_IMPL.get()


def scans_unrolled() -> bool:
    return _UNROLL.get()


def maybe_scan(body: Callable, init: Any, xs: Any, length: int | None = None):
    """``lax.scan`` honouring the unroll flag (see module docstring)."""
    return jax.lax.scan(body, init, xs, length=length, unroll=True if _UNROLL.get() else 1)


@contextlib.contextmanager
def attn_impl(kind: str):
    """"grouped" (GQA einsums over (KV, G) split — baseline) | "flat"
    (repeat K/V to the head dim: under head-TP each shard repeats only its
    local heads, keeping every attention einsum collective-free — §Perf)."""
    tok = _ATTN_IMPL.set(kind)
    try:
        yield
    finally:
        _ATTN_IMPL.reset(tok)


def current_attn_impl() -> str:
    return _ATTN_IMPL.get()
