"""Shared layer primitives: norms, RoPE, MLPs, embeddings.

All norms compute in fp32 and cast back; params live in bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraints import cs
from repro.models.params import p


# ----------------------------------------------------------------- norms
def norm_specs(cfg: ModelConfig, stack: tuple = ()):
    """Spec for one norm layer (possibly layer-stacked with leading dims)."""
    axes = tuple([("layers" if i == 0 else None) for i in range(len(stack))])
    if cfg.norm_type == "layernorm_nonparam":
        return {}  # OLMo: no learned scale/bias
    if cfg.norm_type == "layernorm":
        return {
            "scale": p(stack + (cfg.d_model,), axes + (None,), init="ones"),
            "bias": p(stack + (cfg.d_model,), axes + (None,), init="zeros"),
        }
    return {"scale": p(stack + (cfg.d_model,), axes + (None,), init="ones")}


def apply_norm(x: jax.Array, prm: dict, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm_type in ("layernorm", "layernorm_nonparam"):
        mean = xf.mean(-1, keepdims=True)
        var = ((xf - mean) ** 2).mean(-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + 1e-5)
        if prm:
            y = y * prm["scale"].astype(jnp.float32) + prm["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = (xf * xf).mean(-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + 1e-6)
        if prm:
            y = y * prm["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_1d(x: jax.Array, scale: jax.Array | None, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last dim with optional scale (used by qk_norm, SSD gated norm)."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt((xf * xf).mean(-1, keepdims=True) + eps)
    if scale is not None:
        y = y * scale.astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., S, 1, hd/2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------- mlp
def mlp_specs(cfg: ModelConfig, stack: tuple = (), d_ff: int | None = None):
    d_ff = d_ff if d_ff is not None else cfg.d_ff
    axes = tuple([("layers" if i == 0 else None) for i in range(len(stack))])
    if cfg.mlp_act == "gelu":
        return {
            "w_in": p(stack + (cfg.d_model, d_ff), axes + ("embed", "mlp")),
            "w_out": p(stack + (d_ff, cfg.d_model), axes + ("mlp", "embed")),
        }
    return {
        "w_gate": p(stack + (cfg.d_model, d_ff), axes + ("embed", "mlp")),
        "w_up": p(stack + (cfg.d_model, d_ff), axes + ("embed", "mlp")),
        "w_out": p(stack + (d_ff, cfg.d_model), axes + ("mlp", "embed")),
    }


def apply_mlp(x: jax.Array, prm: dict, cfg: ModelConfig) -> jax.Array:
    nb = x.ndim - 1  # leading dims before the feature dim ((B,S,d) or (T,d))
    hid = ("batch",) + ("act_seq",) * (nb - 1) + ("mlp",)
    res = ("batch",) + ("act_seq",) * (nb - 1) + (None,)
    if "w_in" in prm:  # gelu
        h = cs(jax.nn.gelu(x @ prm["w_in"]), *hid)
        return cs(h @ prm["w_out"], *res)
    g = cs(jax.nn.silu(x @ prm["w_gate"]), *hid)
    return cs((g * cs(x @ prm["w_up"], *hid)) @ prm["w_out"], *res)


# ----------------------------------------------------------------- embeddings
def embed_specs(cfg: ModelConfig):
    out = {"embedding": p((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        out["lm_head"] = p((cfg.d_model, cfg.vocab_size), ("embed", "vocab"))
    return out


def embed_tokens(prm: dict, tokens: jax.Array) -> jax.Array:
    x = jnp.take(prm["embedding"], tokens, axis=0)
    return cs(x, *(("batch",) + ("act_seq",) * (tokens.ndim - 1) + (None,)))


def lm_logits(prm: dict, x: jax.Array) -> jax.Array:
    w = prm["lm_head"] if "lm_head" in prm else prm["embedding"].T
    logits = jnp.einsum("...d,dv->...v", x, w, preferred_element_type=jnp.float32)
    return cs(logits, *(("batch",) + ("act_seq",) * (x.ndim - 2) + ("vocab",)))
