"""RecurrentGemma-style hybrid: repeating (rec, rec, attn) units + tail.

26 layers = 8 scanned units of 3 + an unrolled 2-layer (rec, rec) tail. Every
block: x += temporal(norm1(x)); x += mlp(norm2(x)). Attention blocks use
sliding-window (local) attention with a ring cache at decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import flags
from repro.models.attention import (attention, attn_out, attn_specs, decode_attention,
                                    local_window_attention, qkv_proj)
from repro.models.layers import (apply_mlp, apply_norm, embed_specs, embed_tokens,
                                 lm_logits, mlp_specs, norm_specs)
from repro.models.params import p
from repro.models.rglru import (rglru_cache_specs, rglru_decode_step, rglru_forward,
                                rglru_specs)
from repro.models.transformer import _cache_positions, cache_update


def structure(cfg: ModelConfig):
    u = len(cfg.block_unit)
    full = cfg.num_layers // u
    tail = cfg.num_layers % u
    return full, tuple(cfg.block_unit[:tail])


def _block_specs(cfg: ModelConfig, kind: str, stack: tuple):
    t = rglru_specs(cfg, stack) if kind == "rec" else attn_specs(cfg, stack)
    return {"norm1": norm_specs(cfg, stack), "temporal": t,
            "norm2": norm_specs(cfg, stack), "mlp": mlp_specs(cfg, stack)}


def init_specs(cfg: ModelConfig):
    U, tail = structure(cfg)
    units = {f"b{i}": _block_specs(cfg, k, (U,)) for i, k in enumerate(cfg.block_unit)}
    tails = {f"b{i}": _block_specs(cfg, k, ()) for i, k in enumerate(tail)}
    return {"embed": embed_specs(cfg), "final_norm": norm_specs(cfg),
            "units": units, "tail": tails}


def _block_fwd(x, bp, cfg, kind, positions, collect_cache):
    h = apply_norm(x, bp["norm1"], cfg)
    cache = None
    if kind == "rec":
        y, state = rglru_forward(h, bp["temporal"], cfg)
        if collect_cache:
            W = cfg.conv_width
            u_pre = jnp.einsum("btd,dw->btw", h, bp["temporal"]["w_in"])
            cache = {"h": state, "conv": u_pre[:, u_pre.shape[1] - (W - 1):]}
    else:
        q, k, v = qkv_proj(h, bp["temporal"], cfg, positions, rope=True)
        S, w = q.shape[1], cfg.local_window
        if S > w and S % w == 0:
            y = local_window_attention(q, k, v, cfg, w)
        else:
            y = attention(q, k, v, cfg, kind="local_window", width=w,
                          q_pos=positions, kv_pos=positions)
        y = attn_out(y, bp["temporal"])
        if collect_cache:
            w_eff = min(w, S)
            cache = {"k": k[:, S - w_eff:], "v": v[:, S - w_eff:]}
    x = x + y
    x = x + apply_mlp(apply_norm(x, bp["norm2"], cfg), bp["mlp"], cfg)
    return x, cache


def forward(params, cfg: ModelConfig, batch, *, remat: bool = False,
            collect_cache: bool = False, **_):
    x = embed_tokens(params["embed"], batch["tokens"])
    mask = jnp.ones(batch["tokens"].shape, jnp.float32)
    positions = jnp.arange(x.shape[1])
    _, tail = structure(cfg)

    def unit_body(x, up):
        caches = {}
        for i, kind in enumerate(cfg.block_unit):
            x, c = _block_fwd(x, up[f"b{i}"], cfg, kind, positions, collect_cache)
            caches[f"b{i}"] = c
        return x, (caches if collect_cache else None)

    body = jax.checkpoint(unit_body) if remat else unit_body
    x, unit_caches = flags.maybe_scan(body, x, params["units"])
    tail_caches = {}
    for i, kind in enumerate(tail):
        x, c = _block_fwd(x, params["tail"][f"b{i}"], cfg, kind, positions, collect_cache)
        tail_caches[f"b{i}"] = c
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_logits(params["embed"], x)
    cache = {"units": unit_caches, "tail": tail_caches} if collect_cache else None
    return logits, 0.0, mask, cache


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    U, tail = structure(cfg)
    w = min(cfg.local_window, seq_len)
    KV, hd = cfg.num_kv_heads, cfg.head_dim

    def one(kind, stack):
        if kind == "rec":
            return rglru_cache_specs(cfg, batch, stack)
        ax = tuple(["layers"] * len(stack)) + ("batch", "kv_seq", "kv_heads", None)
        shp = stack + (batch, w, KV, hd)
        return {"k": p(shp, ax, init="zeros"), "v": p(shp, ax, init="zeros")}

    return {"units": {f"b{i}": one(k, (U,)) for i, k in enumerate(cfg.block_unit)},
            "tail": {f"b{i}": one(k, ()) for i, k in enumerate(tail)}}


def _block_decode(x, bp, cfg, kind, pos, bc):
    h = apply_norm(x, bp["norm1"], cfg)
    if kind == "rec":
        y, nc = rglru_decode_step(h, bp["temporal"], cfg, bc)
    else:
        q, k, v = qkv_proj(h, bp["temporal"], cfg, jnp.asarray(pos)[None], rope=True)
        size = bc["k"].shape[1]
        slot = pos % size
        kc = cache_update(bc["k"], k, slot)
        vc = cache_update(bc["v"], v, slot)
        cpos = _cache_positions(cfg, pos, size, "local_window", cfg.local_window)
        y = decode_attention(q, kc, vc, pos, kind="local_window",
                             width=cfg.local_window, kv_pos=cpos)
        y = attn_out(y, bp["temporal"])
        nc = {"k": kc, "v": vc}
    x = x + y
    x = x + apply_mlp(apply_norm(x, bp["norm2"], cfg), bp["mlp"], cfg)
    return x, nc


def decode_step(params, cfg: ModelConfig, cache, pos, token):
    x = embed_tokens(params["embed"], token)
    _, tail = structure(cfg)

    def unit_body(x, xs):
        up, uc = xs
        ncs = {}
        for i, kind in enumerate(cfg.block_unit):
            x, nc = _block_decode(x, up[f"b{i}"], cfg, kind, pos, uc[f"b{i}"])
            ncs[f"b{i}"] = nc
        return x, ncs

    x, new_units = flags.maybe_scan(unit_body, x, (params["units"], cache["units"]))
    new_tail = {}
    for i, kind in enumerate(tail):
        x, nc = _block_decode(x, params["tail"][f"b{i}"], cfg, kind, pos,
                              cache["tail"][f"b{i}"])
        new_tail[f"b{i}"] = nc
    x = apply_norm(x, params["final_norm"], cfg)
    return lm_logits(params["embed"], x), {"units": new_units, "tail": new_tail}
