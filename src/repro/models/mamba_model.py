"""Mamba2 LM assembly: embed -> [norm -> SSD -> residual] x L -> norm -> logits."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import flags
from repro.models.layers import apply_norm, embed_specs, embed_tokens, lm_logits, norm_specs
from repro.models.ssm import ssd_decode_step, ssd_forward, ssm_cache_specs, ssm_specs


def init_specs(cfg: ModelConfig):
    L = cfg.num_layers
    return {
        "embed": embed_specs(cfg),
        "final_norm": norm_specs(cfg),
        "layers": {"norm": norm_specs(cfg, (L,)), "ssm": ssm_specs(cfg, (L,))},
    }


def forward(params, cfg: ModelConfig, batch, *, remat: bool = False,
            collect_cache: bool = False, **_):
    x = embed_tokens(params["embed"], batch["tokens"])
    mask = jnp.ones(batch["tokens"].shape, jnp.float32)

    def body(x, lp):
        h = apply_norm(x, lp["norm"], cfg)
        y, cache = ssd_forward(h, lp["ssm"], cfg, return_cache=collect_cache)
        return x + y, (cache if collect_cache else None)

    body = jax.checkpoint(body) if remat else body
    x, caches = flags.maybe_scan(body, x, params["layers"])
    x = apply_norm(x, params["final_norm"], cfg)
    return lm_logits(params["embed"], x), 0.0, mask, caches


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    del seq_len  # O(1) state regardless of context
    return ssm_cache_specs(cfg, batch, (cfg.num_layers,))


def decode_step(params, cfg: ModelConfig, cache, pos, token):
    del pos  # stateful recurrence: position-free
    x = embed_tokens(params["embed"], token)

    def body(x, xs):
        lp, lc = xs
        h = apply_norm(x, lp["norm"], cfg)
        y, nc = ssd_decode_step(h, lp["ssm"], cfg, lc)
        return x + y, nc

    x, new_cache = flags.maybe_scan(body, x, (params["layers"], cache))
    x = apply_norm(x, params["final_norm"], cfg)
    return lm_logits(params["embed"], x), new_cache
