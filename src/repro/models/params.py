"""Parameter-spec trees.

Model ``init_specs`` functions return nested dicts of ``ParamSpec`` — shape,
dtype, *logical axis names* (one per dim), and an initializer. The same tree:

- ``materialize(specs, rng)``      -> real arrays (smoke tests / real training)
- ``abstract(specs, mesh, rules)`` -> ShapeDtypeStruct with NamedSharding
                                      (AOT dry-run: no allocation)
- logical axes drive the sharding rules in ``repro.distributed.sharding``.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = never sharded)
    dtype: Any = jnp.bfloat16
    init: str = "normal"  # normal | zeros | ones | ssm_a | conv
    scale: float = 0.02


def p(shape, axes, dtype=jnp.bfloat16, init="normal", scale=0.02) -> ParamSpec:
    shape = tuple(int(s) for s in shape)
    axes = tuple(axes)
    assert len(shape) == len(axes), (shape, axes)
    return ParamSpec(shape, axes, dtype, init, scale)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=is_spec)


def _init_leaf(spec: ParamSpec, key) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "ssm_a":  # A_log in [log 1, log 16] as in mamba2
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(spec.dtype)
    # fan-in scaled normal for >=2D, plain normal otherwise
    shape = spec.shape
    std = spec.scale
    if len(shape) >= 2:
        fan_in = shape[-2]
        std = min(spec.scale, 1.0 / np.sqrt(fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(spec.dtype)


def materialize(specs, rng) -> Any:
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    arrs = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, arrs)


def as_shape_dtype(specs) -> Any:
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs)


def count(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) for s in leaves))


def bytes_of(specs) -> int:
    leaves = jax.tree_util.tree_leaves(specs, is_leaf=is_spec)
    return int(sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize for s in leaves))
