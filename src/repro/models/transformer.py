"""Generic decoder LM assembly: dense / MoE / llama4-interleaved / VLM.

Layer stacks are ``lax.scan``-ed (HLO size depth-independent; see
``repro.models.flags`` for the cost-pass unroll). llama4-style configs scan
over *units* of ``len(cfg.attn_unit)`` layers with static per-position
local/global attention kinds.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraints import cs
from repro.models import flags
from repro.models.attention import (attention, attn_out, attn_specs,
                                    blockwise_attention, decode_attention,
                                    local_chunk_attention, local_window_attention,
                                    qkv_proj)
from repro.models.layers import (apply_mlp, apply_norm, embed_specs, embed_tokens,
                                 lm_logits, mlp_specs, norm_specs)
from repro.models.moe import apply_moe, moe_specs
from repro.models.params import p


# --------------------------------------------------------------- structure
def unit_len(cfg: ModelConfig) -> int:
    return len(cfg.attn_unit) if cfg.attn_unit else 1


def num_units(cfg: ModelConfig) -> int:
    u = unit_len(cfg)
    assert cfg.num_layers % u == 0, (cfg.num_layers, u)
    return cfg.num_layers // u


def _layer_specs(cfg: ModelConfig, stack: tuple):
    out = {
        "norm1": norm_specs(cfg, stack),
        "attn": attn_specs(cfg, stack),
        "norm2": norm_specs(cfg, stack),
    }
    if cfg.num_experts > 0:
        out["ffn"] = moe_specs(cfg, stack)
    else:
        out["ffn"] = mlp_specs(cfg, stack)
    return out


def init_specs(cfg: ModelConfig):
    U = num_units(cfg)
    stack = (U,) if unit_len(cfg) == 1 else (U, unit_len(cfg))
    specs = {"embed": embed_specs(cfg), "final_norm": norm_specs(cfg),
             "layers": _layer_specs(cfg, stack)}
    if cfg.family == "vlm":
        specs["projector"] = {
            "w1": p((cfg.patch_dim, cfg.d_model), (None, "embed")),
            "w2": p((cfg.d_model, cfg.d_model), ("embed", "embed")),
        }
    return specs


def _attn_kind(cfg: ModelConfig, pos_in_unit: int):
    if cfg.attn_unit:
        k = cfg.attn_unit[pos_in_unit]
        if k == "local":
            return "local_chunk", cfg.attn_chunk, True
        return "causal", 0, False  # llama4 global layers: NoPE (iRoPE)
    if cfg.local_window:
        return "local_window", cfg.local_window, True
    return "causal", 0, True


def _sublayer(x, lp, cfg: ModelConfig, positions, kind, width, rope, blockwise, causal_skip):
    h = apply_norm(x, lp["norm1"], cfg)
    q, k, v = qkv_proj(h, lp["attn"], cfg, positions, rope=rope)
    S = q.shape[1]
    if kind == "local_chunk" and S > width and S % width == 0:
        y = local_chunk_attention(q, k, v, cfg, width)
    elif kind == "local_window" and S > width and S % width == 0:
        y = local_window_attention(q, k, v, cfg, width)
    elif blockwise and kind == "causal":
        y = blockwise_attention(q, k, v, cfg, kind=kind, width=width, causal_skip=causal_skip)
    else:
        if kind == "local_chunk" and S <= width:
            kind = "causal"  # whole sequence fits in one chunk
        y = attention(q, k, v, cfg, kind=kind, width=width, q_pos=positions, kv_pos=positions)
    x = x + attn_out(y, lp["attn"])
    h = apply_norm(x, lp["norm2"], cfg)
    if cfg.num_experts > 0:
        f, aux = apply_moe(h, lp["ffn"], cfg)
    else:
        f, aux = apply_mlp(h, lp["ffn"], cfg), 0.0
    return x + f, aux, (k, v)


def _prefix_embed(params, cfg: ModelConfig, batch):
    """Token (+ patch-prefix) embedding. Returns (x, loss_mask)."""
    tokens = batch["tokens"]
    x = embed_tokens(params["embed"], tokens)
    mask = jnp.ones(tokens.shape, jnp.float32)
    if cfg.family == "vlm" and "patches" in batch:
        pj = params["projector"]
        pe = jax.nn.gelu(batch["patches"] @ pj["w1"]) @ pj["w2"]
        x = jnp.concatenate([pe.astype(x.dtype), x], axis=1)
        mask = jnp.concatenate([jnp.zeros(pe.shape[:2], jnp.float32), mask], axis=1)
    return x, mask


def forward(params, cfg: ModelConfig, batch, *, blockwise: bool = False,
            remat: bool = False, causal_skip: bool = False, collect_cache: bool = False):
    """-> (logits, aux_loss, loss_mask, cache_kv or None)."""
    x, mask = _prefix_embed(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    ul = unit_len(cfg)

    def unit_body(carry, lp):
        x, aux = carry
        kvs = []
        if ul == 1:
            kind, width, rope = _attn_kind(cfg, 0)
            x, a, kv = _sublayer(x, lp, cfg, positions, kind, width, rope,
                                 blockwise, causal_skip)
            aux = aux + a
            kvs = kv
        else:
            for j in range(ul):
                kind, width, rope = _attn_kind(cfg, j)
                lpj = jax.tree_util.tree_map(lambda t: t[j], lp)
                x, a, kv = _sublayer(x, lpj, cfg, positions, kind, width, rope,
                                     blockwise, causal_skip)
                aux = aux + a
                kvs.append(kv)
            kvs = jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *kvs)
        return (x, aux), (kvs if collect_cache else None)

    if remat == "dots":
        # selective remat (§Perf): keep matmul outputs, recompute only the
        # cheap elementwise chains — backward skips the full fwd replay
        body = jax.checkpoint(
            unit_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    elif remat:
        body = jax.checkpoint(unit_body)
    else:
        body = unit_body
    (x, aux), caches = flags.maybe_scan(body, (x, 0.0), params["layers"])
    x = apply_norm(x, params["final_norm"], cfg)
    logits = lm_logits(params["embed"], x)
    return logits, aux, mask, caches


# --------------------------------------------------------------- decode
def cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    """Abstract KV-cache layout for serve_step."""
    U, ul = num_units(cfg), unit_len(cfg)
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    if not cfg.attn_unit:
        S = min(seq_len, cfg.local_window) if cfg.local_window else seq_len
        shp, ax = (U, batch, S, KV, hd), ("layers", "batch", "kv_seq", "kv_heads", None)
        return {"k": p(shp, ax, init="zeros"), "v": p(shp, ax, init="zeros")}
    n_local = sum(1 for k in cfg.attn_unit if k == "local")
    n_glob = ul - n_local
    lshp = (U, n_local, batch, cfg.attn_chunk, KV, hd)
    gshp = (U, n_glob, batch, seq_len, KV, hd)
    ax = ("layers", None, "batch", "kv_seq", "kv_heads", None)
    return {"k_local": p(lshp, ax, init="zeros"), "v_local": p(lshp, ax, init="zeros"),
            "k_global": p(gshp, ax, init="zeros"), "v_global": p(gshp, ax, init="zeros")}


def _ring_slot(pos, size):
    return pos % size


def cache_update(c, new, slot):
    """Write one (B,1,KV,hd) entry at ``slot`` of a (B,S,KV,hd) cache as a
    masked elementwise select. A dynamic_update_slice at a *traced* position
    on the SP-sharded seq dim makes GSPMD materialize the cache unsharded
    (measured: +16 GiB temps on deepseek-67b decode_32k); the masked form
    stays sharded at the cost of a full cache rewrite — which the decode
    step's HBM roofline already pays for the attention read anyway."""
    mask = (jnp.arange(c.shape[1]) == slot)[None, :, None, None]
    c = jnp.where(mask, new.astype(c.dtype), c)
    return cs(c, "batch", "kv_seq", "kv_heads", None)


def _decode_sublayer(x, lp, cfg, pos, kc, vc, kind, width, rope, cache_pos):
    """One token through one attention sublayer; returns (x, new_k, new_v)."""
    h = apply_norm(x, lp["norm1"], cfg)
    q, k, v = qkv_proj(h, lp["attn"], cfg, jnp.asarray(pos)[None], rope=rope)
    slot = _ring_slot(pos, kc.shape[1])
    kc = cache_update(kc, k, slot)
    vc = cache_update(vc, v, slot)
    y = decode_attention(q, kc, vc, pos, kind=kind, width=width, kv_pos=cache_pos)
    x = x + attn_out(y, lp["attn"])
    h = apply_norm(x, lp["norm2"], cfg)
    if cfg.num_experts > 0:
        f, _ = apply_moe(h, lp["ffn"], cfg)
    else:
        f = apply_mlp(h, lp["ffn"], cfg)
    return x + f, kc, vc


def _cache_positions(cfg, pos, size, kind, width):
    """Logical positions held by each cache slot (invalid slots -> negative)."""
    s = jnp.arange(size)
    if kind == "causal" and width == 0 and size > 0:
        return s  # linear cache
    if kind == "local_chunk":
        base = (pos // width) * width
        return base + s  # slots beyond pos%width are future -> masked by causal rule
    # sliding window ring: most recent position congruent to s (mod size)
    return pos - ((pos - s) % size)


def decode_step(params, cfg: ModelConfig, cache: dict, pos, token):
    """token: (B, 1) int32; pos: scalar int32. Returns (logits, new_cache)."""
    x = embed_tokens(params["embed"], token)
    ul = unit_len(cfg)

    if not cfg.attn_unit:
        kind, width, rope = _attn_kind(cfg, 0)
        size = cache["k"].shape[2]
        cpos = _cache_positions(cfg, pos, size, kind, width)

        def body(x, xs):
            lp, kc, vc = xs
            x, kc, vc = _decode_sublayer(x, lp, cfg, pos, kc, vc, kind, width, rope, cpos)
            return x, (kc, vc)

        x, (ks, vs) = flags.maybe_scan(body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}
    else:
        def body(x, xs):
            lp, kl, vl, kg, vg = xs
            il = ig = 0
            nk, nv, ngk, ngv = [], [], [], []
            for j in range(ul):
                kind, width, rope = _attn_kind(cfg, j)
                lpj = jax.tree_util.tree_map(lambda t: t[j], lp)
                if kind == "local_chunk":
                    cpos = _cache_positions(cfg, pos, kl.shape[2], kind, width)
                    x, kc, vc = _decode_sublayer(x, lpj, cfg, pos, kl[il], vl[il],
                                                 kind, width, rope, cpos)
                    nk.append(kc), nv.append(vc)
                    il += 1
                else:
                    cpos = _cache_positions(cfg, pos, kg.shape[2], "causal", 0)
                    x, kc, vc = _decode_sublayer(x, lpj, cfg, pos, kg[ig], vg[ig],
                                                 kind, width, rope, cpos)
                    ngk.append(kc), ngv.append(vc)
                    ig += 1
            return x, (jnp.stack(nk), jnp.stack(nv), jnp.stack(ngk), jnp.stack(ngv))

        x, (kl, vl, kg, vg) = flags.maybe_scan(
            body, x, (params["layers"], cache["k_local"], cache["v_local"],
                      cache["k_global"], cache["v_global"]))
        new_cache = {"k_local": kl, "v_local": vl, "k_global": kg, "v_global": vg}

    x = apply_norm(x, params["final_norm"], cfg)
    return lm_logits(params["embed"], x), new_cache
