"""Unified model API: one entry point per step kind, dispatched by family.

- ``init_specs(cfg)``                     parameter ParamSpec tree
- ``forward(params, cfg, batch, ...)``    -> (logits, aux, loss_mask, cache?)
- ``loss_fn(params, cfg, batch, ...)``    next-token CE (+ MoE aux)
- ``cache_specs / prefill / decode_step`` serving path
- ``input_specs(cfg, shape)``             ShapeDtypeStruct stand-ins per cell
- ``with_depth / scan_units``             depth scaling for the dry-run cost
                                          extrapolation (see launch/dryrun.py)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import hybrid, mamba_model, transformer, whisper
from repro.models import params as P

_GENERIC = ("dense", "moe", "vlm")


def _mod(cfg: ModelConfig):
    if cfg.family == "ssm":
        return mamba_model
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "audio":
        return whisper
    return transformer


def init_specs(cfg: ModelConfig):
    return _mod(cfg).init_specs(cfg)


def init_params(cfg: ModelConfig, rng):
    return P.materialize(init_specs(cfg), rng)


def forward(params, cfg: ModelConfig, batch, **kw):
    return _mod(cfg).forward(params, cfg, batch, **kw)


def loss_fn(params, cfg: ModelConfig, batch, *, remat: bool = False,
            aux_weight: float = 0.01, blockwise: bool = False):
    logits, aux, mask, _ = forward(params, cfg, batch, remat=remat, blockwise=blockwise)
    labels = batch["tokens"]
    # VLM: logits cover patch prefix + tokens; score text positions only
    logits_t = logits[:, logits.shape[1] - labels.shape[1]:]
    lf = logits_t[:, :-1].astype(jnp.float32)
    tgt = labels[:, 1:]
    # Cross-entropy in a vocab-sharded-friendly form: every reduction is over
    # the (TP-sharded) vocab axis, so GSPMD keeps logits sharded and emits
    # tiny (B, S) all-reduces instead of gathering full logits per device
    # (take_along_axis over a sharded axis replicates the lm_head matmul).
    lmax = jax.lax.stop_gradient(lf.max(axis=-1))
    lse = jnp.log(jnp.exp(lf - lmax[..., None]).sum(-1)) + lmax
    onehot = jax.nn.one_hot(tgt, lf.shape[-1], dtype=lf.dtype)
    label_logit = (lf * onehot).sum(-1)
    nll = lse - label_logit
    m = mask[:, mask.shape[1] - labels.shape[1] + 1:]
    loss = (nll * m).sum() / jnp.maximum(m.sum(), 1.0)
    return loss + aux_weight * aux


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    return _mod(cfg).cache_specs(cfg, batch, seq_len)


def prefill(params, cfg: ModelConfig, batch, *, blockwise: bool = True):
    """Run the full prompt, return (last_logits, cache)."""
    logits, _, _, cache = forward(params, cfg, batch, blockwise=blockwise,
                                  collect_cache=True)
    return logits[:, -1], cache


def decode_step(params, cfg: ModelConfig, cache, pos, token):
    return _mod(cfg).decode_step(params, cfg, cache, pos, token)


def _pad_dim(x, dim, target):
    if x.shape[dim] == target:
        return x
    if x.shape[dim] > target:  # keep the most recent positions (ring layout)
        assert x.shape[dim] % target == 0, (x.shape, dim, target)
        return jax.lax.slice_in_dim(x, x.shape[dim] - target, x.shape[dim], axis=dim)
    pad = [(0, 0)] * x.ndim
    pad[dim] = (0, target - x.shape[dim])
    return jnp.pad(x, pad)


def build_decode_cache(params, cfg: ModelConfig, batch, max_len: int,
                       *, blockwise: bool = True):
    """Prefill the prompt and lay the collected KV out as a decode cache of
    capacity ``max_len`` (linear caches padded; ring caches ring-ified)."""
    last_logits, cache = prefill(params, cfg, batch, blockwise=blockwise)
    fam = cfg.family
    if fam == "ssm":
        return last_logits, cache
    if fam == "audio":
        cache = dict(cache)
        cache["k"] = _pad_dim(cache["k"], 2, max_len)
        cache["v"] = _pad_dim(cache["v"], 2, max_len)
        return last_logits, cache
    if fam == "hybrid":
        w = min(cfg.local_window, max_len)
        def fix(tree):
            out = {}
            for name, c in tree.items():
                out[name] = ({"k": _pad_dim(c["k"], 2, w), "v": _pad_dim(c["v"], 2, w)}
                             if "k" in c else c)
            return out
        return last_logits, {"units": fix(cache["units"]), "tail": fix(cache["tail"])}
    if cfg.attn_unit:  # llama4-style: (k, v) each (U, ul, B, S, KV, hd)
        k, v = cache
        loc = [j for j, t in enumerate(cfg.attn_unit) if t == "local"]
        glo = [j for j, t in enumerate(cfg.attn_unit) if t != "local"]
        return last_logits, {
            "k_local": _pad_dim(k[:, loc], 3, cfg.attn_chunk),
            "v_local": _pad_dim(v[:, loc], 3, cfg.attn_chunk),
            "k_global": _pad_dim(k[:, glo], 3, max_len),
            "v_global": _pad_dim(v[:, glo], 3, max_len),
        }
    k, v = cache  # (L, B, S, KV, hd)
    return last_logits, {"k": _pad_dim(k, 2, max_len), "v": _pad_dim(v, 2, max_len)}


# ------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Abstract model inputs for one shape cell (no allocation).

    train/prefill: full (B, S) token batch (+ modality stubs).
    decode: one new token (B, 1) + scalar position; the KV cache itself is
    part of the state signature (see launch/steps.py)."""
    B, S = shape.global_batch, shape.seq_len
    tok = jnp.int32
    if shape.kind in ("train", "prefill"):
        if cfg.family == "audio":
            return {"tokens": jax.ShapeDtypeStruct((B, S), tok),
                    "frames": jax.ShapeDtypeStruct(
                        (B, cfg.num_audio_frames, cfg.d_model), jnp.bfloat16)}
        if cfg.family == "vlm":
            return {"tokens": jax.ShapeDtypeStruct((B, S - cfg.num_patches), tok),
                    "patches": jax.ShapeDtypeStruct(
                        (B, cfg.num_patches, cfg.patch_dim), jnp.bfloat16)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), tok)}
    return {"token": jax.ShapeDtypeStruct((B, 1), tok),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


# ------------------------------------------------------------- param counts
def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    specs = init_specs(cfg)
    total = P.count(specs)
    if active_only and cfg.num_experts > 0:
        layers = specs["layers"]
        ep = sum(P.count(layers["ffn"][k]) for k in ("w_gate", "w_up", "w_out"))
        total = total - ep + int(ep * cfg.num_experts_per_tok / cfg.num_experts)
    return total


def count_matmul_params(cfg: ModelConfig, active_only: bool = False) -> int:
    """Params participating in per-token matmuls, for MODEL_FLOPS = 6*N*D.

    The embedding *gather* does no matmul FLOPs; the lm_head projection does.
    Tied models reuse the table as the lm_head weight, so the (V, d) count is
    kept either way — untied models already count lm_head separately, so the
    gather table is simply removed."""
    total = count_params(cfg, active_only)
    specs = init_specs(cfg)
    if "lm_head" in specs["embed"]:
        total -= cfg.vocab_size * cfg.d_model  # drop the gather-only table
    return total


# ------------------------------------------------------------- depth scaling
def scan_units(cfg: ModelConfig) -> int:
    """Number of scanned units (the linear-extrapolation variable)."""
    if cfg.family == "hybrid":
        return hybrid.structure(cfg)[0]
    if cfg.family == "audio":
        return cfg.num_layers  # enc and dec scale together
    return transformer.num_units(cfg) if cfg.family in _GENERIC else cfg.num_layers


def with_depth(cfg: ModelConfig, units: int) -> ModelConfig:
    """Config with ``units`` scanned units (tails/ratios preserved)."""
    if cfg.family == "hybrid":
        u = len(cfg.block_unit)
        tail = cfg.num_layers % u
        return dataclasses.replace(cfg, num_layers=units * u + tail)
    if cfg.family == "audio":
        return dataclasses.replace(cfg, num_layers=units, num_encoder_layers=units)
    ul = len(cfg.attn_unit) if cfg.attn_unit else 1
    return dataclasses.replace(cfg, num_layers=units * ul)
