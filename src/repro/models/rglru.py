"""RG-LRU recurrent block (Griffin / RecurrentGemma) [arXiv:2402.19427].

Recurrent block = [linear -> causal conv1d -> RG-LRU] * [linear -> GeLU]
-> linear out. The RG-LRU diagonal recurrence is computed with
``lax.associative_scan`` (log-depth, fp32) — no while-loop, so HLO cost
analysis counts it exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.constraints import cs
from repro.models.params import p

_C = 8.0  # Griffin's fixed temperature


def rglru_specs(cfg: ModelConfig, stack: tuple = ()):
    axes = tuple([("layers" if i == 0 else None) for i in range(len(stack))])
    d, w, W = cfg.d_model, cfg.lru_width, cfg.conv_width
    return {
        "w_in": p(stack + (d, w), axes + ("embed", "inner")),
        "w_gate_in": p(stack + (d, w), axes + ("embed", "inner")),
        "conv": p(stack + (W, w), axes + (None, "inner"), scale=0.5),
        "w_a": p(stack + (w, w), axes + ("inner", "inner2")),
        "b_a": p(stack + (w,), axes + ("inner",), init="zeros"),
        "w_i": p(stack + (w, w), axes + ("inner", "inner2")),
        "b_i": p(stack + (w,), axes + ("inner",), init="zeros"),
        "lam": p(stack + (w,), axes + ("inner",), dtype=jnp.float32, init="ones"),
        "w_out": p(stack + (w, d), axes + ("inner", "embed")),
    }


def _conv(x, w):
    W = w.shape[0]
    y = x * w[W - 1]
    for i in range(W - 1):
        shift = W - 1 - i
        y = y + jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, : x.shape[1]] * w[i]
    return y


def _gates(u, prm):
    r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", u, prm["w_a"]).astype(jnp.float32)
                       + prm["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", u, prm["w_i"]).astype(jnp.float32)
                       + prm["b_i"].astype(jnp.float32))
    log_a = -_C * r * jax.nn.softplus(-prm["lam"])  # (B,T,w) fp32, <= 0
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    return a, beta * i * u.astype(jnp.float32)


def rglru_forward(x: jax.Array, prm: dict, cfg: ModelConfig,
                  init_state: jax.Array | None = None):
    """x: (B, T, d_model) -> (y, final_state (B, w) fp32)."""
    u = cs(jnp.einsum("btd,dw->btw", x, prm["w_in"]), "batch", "act_seq", "inner")
    u = _conv(u, prm["conv"])
    a, b = _gates(u, prm)
    if init_state is not None:
        # fold carried state in as a virtual step 0: b_0' = b_0 + a_0 * h_in
        b = b.at[:, 0].add(a[:, 0] * init_state.astype(jnp.float32))

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, b2 + a2 * b1

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, prm["w_gate_in"]))
    y = (h.astype(x.dtype) * gate)
    return jnp.einsum("btw,wd->btd", y, prm["w_out"]), h[:, -1]


def rglru_decode_step(x: jax.Array, prm: dict, cfg: ModelConfig, cache: dict):
    """x: (B,1,d); cache: {h:(B,w)f32, conv:(B,W-1,w)}."""
    u = jnp.einsum("btd,dw->btw", x, prm["w_in"])  # (B,1,w)
    window = jnp.concatenate([cache["conv"], u], axis=1)
    uc = jnp.einsum("bwc,wc->bc", window, prm["conv"])[:, None]  # (B,1,w)
    a, b = _gates(uc, prm)
    h = a[:, 0] * cache["h"] + b[:, 0]  # (B,w)
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, prm["w_gate_in"]))
    y = (h[:, None].astype(x.dtype) * gate)
    out = jnp.einsum("btw,wd->btd", y, prm["w_out"])
    return out, {"h": h, "conv": window[:, 1:]}


def rglru_cache_specs(cfg: ModelConfig, batch: int, stack: tuple = ()):
    ax = tuple(["layers"] * len(stack))
    w, W = cfg.lru_width, cfg.conv_width
    return {
        "h": p(stack + (batch, w), ax + ("batch", "inner"), dtype=jnp.float32, init="zeros"),
        "conv": p(stack + (batch, W - 1, w), ax + ("batch", None, "inner"), init="zeros"),
    }
