"""``compile_expr`` retargeted at ``jax.numpy`` — the tensor-backend twin.

``compile_expr_jnp(e)`` lowers the same ``Expr`` tree that
``expressions.compile_expr`` lowers, into a closure over a dict of
**jax** arrays (or tracers): same tree walk, same association order, the
numpy ufuncs swapped for their ``jax.numpy`` twins. Under x64
(``jax.experimental.enable_x64``) the results match the numpy closure
bitwise — ``compiler/tensorize.py`` relies on this to evaluate residual
Filter predicates inside a ``jax.jit``-traced program, and
``tests/test_tensorize.py`` pins the equivalence on random columns.

Kept dependency-light on purpose: importing this module does not import
jax (the closures do, lazily), so the numpy-only paths never pay for it.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import numpy as np

from repro.queryproc.expressions import And, Cmp, Col, Expr, In, Or

# filled on first compile; maps the same op tokens _OPS maps for numpy
_JOPS: Dict[str, Callable] = {}


def _jnp():
    import jax.numpy as jnp
    if not _JOPS:
        _JOPS.update({"<=": jnp.less_equal, "<": jnp.less,
                      ">=": jnp.greater_equal, ">": jnp.greater,
                      "==": jnp.equal})
    return jnp


def compile_expr_jnp(expr: Expr) -> Callable[[Dict[str, Any]], Any]:
    """Lower the tree once into a jax.numpy closure over a column dict.

    Structurally identical to ``expressions.compile_expr`` — Cmp leaves
    bind the ufunc and operands, In binds a membership test, And/Or
    compose with ``&``/``|`` in the same association order — so the two
    closures compute the same boolean mask on the same inputs."""
    jnp = _jnp()
    if isinstance(expr, Cmp):
        op = _JOPS[expr.op]
        name = expr.col.name
        if isinstance(expr.value, Col):
            rname = expr.value.name
            return lambda cols: op(cols[name], cols[rname])
        v = expr.value
        return lambda cols: op(cols[name], v)
    if isinstance(expr, In):
        name = expr.col.name
        vals = jnp.asarray(np.asarray(expr.values))
        return lambda cols: jnp.isin(cols[name], vals)
    if isinstance(expr, And):
        lf, rf = compile_expr_jnp(expr.left), compile_expr_jnp(expr.right)
        return lambda cols: lf(cols) & rf(cols)
    if isinstance(expr, Or):
        lf, rf = compile_expr_jnp(expr.left), compile_expr_jnp(expr.right)
        return lambda cols: lf(cols) | rf(cols)
    raise TypeError(expr)
