from repro.queryproc import expressions, operators, table  # noqa: F401
