"""Relational operators over ColumnTable (numpy; the storage-native engine).

Each operator is *local* and *bounded* in the paper's sense where marked.
These are the oracles against which the Pallas kernels and the JAX versions
are tested.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.queryproc import expressions as ex
from repro.queryproc.table import ColumnTable

AGG_FUNCS = {
    "sum": np.sum, "min": np.min, "max": np.max, "mean": np.mean,
    "count": lambda a: np.asarray(a.shape[0], np.int64),
}


# ------------------------------------------------------ local + bounded ops
def filter_table(t: ColumnTable, pred: ex.Expr) -> ColumnTable:
    return t.filter(ex.evaluate(pred, t))


def project(t: ColumnTable, cols: Sequence[str]) -> ColumnTable:
    return t.select(cols)


def selection_bitmap(t: ColumnTable, pred: ex.Expr) -> np.ndarray:
    """Packed selection bitmap (uint32 words, little-endian bit order)."""
    mask = ex.evaluate(pred, t)
    return pack_bitmap(mask)


def pack_bitmap(mask: np.ndarray) -> np.ndarray:
    bits = np.packbits(mask.astype(np.uint8), bitorder="little")
    pad = (-len(bits)) % 4
    if pad:
        bits = np.concatenate([bits, np.zeros(pad, np.uint8)])
    return bits.view(np.uint32)


def unpack_bitmap(words: np.ndarray, n: int) -> np.ndarray:
    return np.unpackbits(words.view(np.uint8), bitorder="little")[:n].astype(bool)


def apply_bitmap(t: ColumnTable, words: np.ndarray) -> ColumnTable:
    return t.filter(unpack_bitmap(words, len(t)))


def grouped_agg(t: ColumnTable, keys: Sequence[str],
                aggs: Dict[str, Tuple[str, str]]) -> ColumnTable:
    """aggs: out_name -> (func, col). func 'count' ignores col.

    Partial-aggregatable (sum/min/max/count decompose; mean is computed from
    sum+count at the merge)."""
    if not keys:
        out = {}
        for name, (fn, col) in aggs.items():
            arr = t.cols[col] if col else next(iter(t.cols.values()))
            out[name] = np.asarray([AGG_FUNCS[fn](arr)]) if len(t) else np.asarray(
                [0], np.float64)
        return ColumnTable(out)
    key_arrs = [t.cols[k] for k in keys]
    combo = np.rec.fromarrays(key_arrs)
    uniq, inv = np.unique(combo, return_inverse=True)
    # one representative row index per group, in group-id order
    order = np.argsort(inv, kind="stable")
    sorted_inv = inv[order]
    boundaries = np.searchsorted(sorted_inv, np.arange(len(uniq)))
    first_idx = order[boundaries]
    out = {k: t.cols[k][first_idx] for k in keys}
    for name, (fn, col) in aggs.items():
        if fn == "count":
            out[name] = np.bincount(inv, minlength=len(uniq)).astype(np.int64)
        elif fn == "mean":
            s = np.bincount(inv, weights=t.cols[col].astype(np.float64), minlength=len(uniq))
            c = np.bincount(inv, minlength=len(uniq))
            out[name] = s / np.maximum(c, 1)
        elif fn == "sum":
            out[name] = np.bincount(inv, weights=t.cols[col].astype(np.float64),
                                    minlength=len(uniq))
        else:
            # reduceat over the group-sorted values: boundaries are each
            # group's first row, and every group is nonempty (the groups
            # come from the data), so segment reductions are well-defined
            vals = t.cols[col][order]
            red = np.minimum if fn == "min" else np.maximum
            out[name] = red.reduceat(vals, boundaries)
    return ColumnTable(out)


def top_k(t: ColumnTable, col: str, k: int, ascending: bool = False) -> ColumnTable:
    """O(K) memory / O(N log K)-ish: bounded."""
    v = t.cols[col]
    k = min(k, len(v))
    if k == 0:
        return t.filter(np.zeros(len(t), bool))
    part = np.argpartition(v if ascending else -v, k - 1)[:k]
    order = part[np.argsort(v[part] if ascending else -v[part], kind="stable")]
    return t.take(order)


def hash_partition_ids(keys: np.ndarray, n_parts: int) -> np.ndarray:
    """Multiplicative (Knuth) hashing — the storage-side shuffle partition fn.
    Local and bounded."""
    h = (keys.astype(np.uint64) * np.uint64(2654435761)) & np.uint64(0xFFFFFFFF)
    return ((h >> np.uint64(16)) % np.uint64(n_parts)).astype(np.int32)


def shuffle_partition(t: ColumnTable, key: str, n_parts: int) -> List[ColumnTable]:
    pid = hash_partition_ids(t.cols[key], n_parts)
    return [t.filter(pid == i) for i in range(n_parts)]


def position_vector(t: ColumnTable, key: str, n_parts: int) -> np.ndarray:
    """log2(n)-bit per-row destination vector (paper §4.2, cached-data interop)."""
    return hash_partition_ids(t.cols[key], n_parts)


# ------------------------------------------------------ compute-layer-only ops
def sort_table(t: ColumnTable, cols: Sequence[str], ascending: bool = True) -> ColumnTable:
    order = np.lexsort(tuple(t.cols[c] for c in reversed(cols)))
    return t.take(order if ascending else order[::-1])


def hash_join(left: ColumnTable, right: ColumnTable, lkey: str, rkey: str,
              how: str = "inner") -> ColumnTable:
    """Equi-join; non-local in general (requires co-location or shuffle)."""
    lv, rv = left.cols[lkey], right.cols[rkey]
    r_order = np.argsort(rv, kind="stable")
    rv_sorted = rv[r_order]
    lo = np.searchsorted(rv_sorted, lv, "left")
    hi = np.searchsorted(rv_sorted, lv, "right")
    counts = hi - lo
    l_idx = np.repeat(np.arange(len(lv)), counts)
    if len(l_idx) == 0:
        r_idx = np.asarray([], np.int64)
    else:
        offs = np.concatenate([[0], np.cumsum(counts)[:-1]])
        r_idx = r_order[np.arange(counts.sum()) - np.repeat(offs, counts) + np.repeat(lo, counts)]
    out = {k: v[l_idx] for k, v in left.cols.items()}
    for k, v in right.cols.items():
        if k != rkey or lkey != rkey:
            out[k if k not in out else f"r_{k}"] = v[r_idx]
    return ColumnTable(out)
