"""TPC-H queries: compiled entry point + the hand-built seed reference.

15 of the 22 TPC-H queries — every query named in the paper's figures
(Q1, Q3, Q4, Q6, Q12, Q14, Q19 in Figs 1/6-14; Q7, Q8, Q17 for shuffle in
Fig 15; Q15, Q18, Q22 for coverage). Q2/Q9/Q11/Q13/Q16/Q20/Q21 are omitted
(multi-level correlated subqueries orthogonal to pushdown; noted in
DESIGN.md §7).

``build_query`` now routes through ``repro.compiler``: each query is a
logical-plan IR construction (``compiler/tpch_ir.py``) that the compiler
splits into a maximal storage frontier + compute residual — the paper's
§4.1 amenability principle, derived instead of frozen at authoring time.

The hand-built builders below (``q1`` .. ``q22``, via
``build_query_legacy``) are the *seed reference*: each query = per-table
``PushPlan`` + a bespoke ``compute`` closure with the amenability split
decided by hand. ``tests/test_compiler.py`` asserts the compiled plans
reproduce their results exactly — on several queries with a strictly
larger pushed-down frontier (see docs/compiler.md).

Either way, the SAME plan executes at storage (pushdown) or at the compute
layer on raw shipped partitions (pushback / no-pushdown), so every
execution mode returns identical results — the engine asserts this.

``fact_selectivity`` rebuilds a query with the fact-table predicate replaced
by ``l_quantity <= 50*sel`` (uniform 1..50 -> selectivity ~= sel), the knob
the bitmap evaluation sweeps (Figs 13/14).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.plan import PushPlan
from repro.queryproc import operators as ops
from repro.queryproc.expressions import Col
from repro.queryproc.table import ColumnTable
from repro.queryproc.tpch import date

C = Col  # terse alias

# derived-column helpers (the storage layer evaluates these — S3-Select-style
# scalar expressions are pushdown-amenable: local + bounded)
REV = ("revenue", ("l_extendedprice", "l_discount"), lambda e, d: e * (1 - d))
DISC_PRICE = ("disc_price", ("l_extendedprice", "l_discount"),
              lambda e, d: e * (1 - d))
CHARGE = ("charge", ("l_extendedprice", "l_discount", "l_tax"),
          lambda e, d, t: e * (1 - d) * (1 + t))


@dataclasses.dataclass
class Query:
    qid: str
    plans: Dict[str, PushPlan]
    compute: Callable[[Dict[str, ColumnTable]], ColumnTable]
    shuffle_keys: Dict[str, str] = dataclasses.field(default_factory=dict)
    #   ^ table -> redistribution key required by the downstream join
    #     (drives the Fig-15 distributed-shuffle evaluation)
    # residual IR (compiler-produced queries only): lets the engine swap
    # the residual backend (runtime.run_residual) instead of being bound
    # to the ``compute`` closure; None for the hand-built seed queries
    residual: Optional[object] = None


def _agg(t, keys, aggs):
    return ops.grouped_agg(t, keys, aggs)


def _join(a, b, ka, kb):
    return ops.hash_join(a, b, ka, kb)


# --------------------------------------------------------------------- Q1
def q1() -> Query:
    cutoff = date(1998, 8, 2) - 90
    li = PushPlan(
        "lineitem", ("l_returnflag", "l_linestatus"),
        predicate=C("l_shipdate") <= cutoff,
        derive=(DISC_PRICE, CHARGE),
        agg=(("l_returnflag", "l_linestatus"),
             (("sum_qty", "sum", "l_quantity"),
              ("sum_base", "sum", "l_extendedprice"),
              ("sum_disc", "sum", "disc_price"),
              ("sum_charge", "sum", "charge"),
              ("cnt", "count", ""))))

    def compute(t):
        part = t["lineitem"]
        out = _agg(part, ["l_returnflag", "l_linestatus"],
                   {"sum_qty": ("sum", "sum_qty"),
                    "sum_base": ("sum", "sum_base"),
                    "sum_disc": ("sum", "sum_disc"),
                    "sum_charge": ("sum", "sum_charge"),
                    "cnt": ("sum", "cnt")})
        return ops.sort_table(out, ["l_returnflag", "l_linestatus"])

    return Query("Q1", {"lineitem": li}, compute)


# --------------------------------------------------------------------- Q3
def q3() -> Query:
    D = date(1995, 3, 15)
    cu = PushPlan("customer", ("c_custkey",), predicate=C("c_mktsegment").eq(1))
    od = PushPlan("orders", ("o_orderkey", "o_custkey", "o_orderdate",
                             "o_shippriority"), predicate=C("o_orderdate") < D)
    li = PushPlan("lineitem", ("l_orderkey", "revenue"),
                  predicate=C("l_shipdate") > D, derive=(REV,))

    def compute(t):
        j = _join(t["orders"], t["customer"], "o_custkey", "c_custkey")
        j = _join(t["lineitem"], j, "l_orderkey", "o_orderkey")
        g = _agg(j, ["l_orderkey", "o_orderdate", "o_shippriority"],
                 {"revenue": ("sum", "revenue")})
        return ops.top_k(g, "revenue", 10)

    return Query("Q3", {"customer": cu, "orders": od, "lineitem": li}, compute,
                 shuffle_keys={"lineitem": "l_orderkey", "orders": "o_orderkey"})


# --------------------------------------------------------------------- Q4
def q4() -> Query:
    D = date(1993, 7, 1)
    od = PushPlan("orders", ("o_orderkey", "o_orderpriority"),
                  predicate=C("o_orderdate").between(D, D + 92))
    # l_commitdate < l_receiptdate is a column-column compare: evaluated at
    # storage as a derived flag (S3-Select-style scalar expr — local+bounded)
    li = PushPlan("lineitem", ("l_orderkey", "_late"),
                  derive=(("_late", ("l_commitdate", "l_receiptdate"),
                           lambda c, r: (c < r).astype(np.int32)),))

    def compute(t):
        lt = t["lineitem"]
        lk = np.unique(lt.cols["l_orderkey"][lt.cols["_late"] == 1])
        o = t["orders"]
        mask = np.isin(o.cols["o_orderkey"], lk)
        return _agg(o.filter(mask), ["o_orderpriority"], {"cnt": ("count", "")})

    return Query("Q4", {"orders": od, "lineitem": li}, compute,
                 shuffle_keys={"lineitem": "l_orderkey", "orders": "o_orderkey"})


# --------------------------------------------------------------------- Q5
def q5() -> Query:
    D = date(1994, 1, 1)
    cu = PushPlan("customer", ("c_custkey", "c_nationkey"))
    od = PushPlan("orders", ("o_orderkey", "o_custkey"),
                  predicate=C("o_orderdate").between(D, D + 365))
    li = PushPlan("lineitem", ("l_orderkey", "l_suppkey", "revenue"),
                  derive=(REV,))
    su = PushPlan("supplier", ("s_suppkey", "s_nationkey"))
    na = PushPlan("nation", ("n_nationkey", "n_regionkey"))

    def compute(t):
        na_r = t["nation"].filter(t["nation"].cols["n_regionkey"] == 2)
        j = _join(t["orders"], t["customer"], "o_custkey", "c_custkey")
        j = _join(t["lineitem"], j, "l_orderkey", "o_orderkey")
        j = _join(j, t["supplier"], "l_suppkey", "s_suppkey")
        j = j.filter(j.cols["c_nationkey"] == j.cols["s_nationkey"])
        j = _join(j, na_r, "s_nationkey", "n_nationkey")
        g = _agg(j, ["s_nationkey"], {"revenue": ("sum", "revenue")})
        return ops.sort_table(g, ["revenue"], ascending=False)

    return Query("Q5", {"customer": cu, "orders": od, "lineitem": li,
                        "supplier": su, "nation": na}, compute,
                 shuffle_keys={"lineitem": "l_orderkey", "orders": "o_orderkey"})


# --------------------------------------------------------------------- Q6
def q6() -> Query:
    D = date(1994, 1, 1)
    li = PushPlan(
        "lineitem", ("disc_rev",),
        predicate=(C("l_shipdate").between(D, D + 365)
                   & C("l_discount").between(0.05, 0.0701) & (C("l_quantity") < 24)),
        derive=(("disc_rev", ("l_extendedprice", "l_discount"),
                 lambda e, d: e * d),),
        agg=((), (("revenue", "sum", "disc_rev"),)))

    def compute(t):
        return ColumnTable({"revenue": np.asarray(
            [t["lineitem"].cols["revenue"].sum()])})

    return Query("Q6", {"lineitem": li}, compute)


# --------------------------------------------------------------------- Q7
def q7() -> Query:
    d0, d1 = date(1995, 1, 1), date(1996, 12, 31)
    li = PushPlan("lineitem", ("l_orderkey", "l_suppkey", "l_shipdate", "volume"),
                  predicate=C("l_shipdate").between(d0, d1 + 1), derive=(
                      ("volume", ("l_extendedprice", "l_discount"),
                       lambda e, d: e * (1 - d)),))
    od = PushPlan("orders", ("o_orderkey", "o_custkey"))
    cu = PushPlan("customer", ("c_custkey", "c_nationkey"))
    su = PushPlan("supplier", ("s_suppkey", "s_nationkey"))

    def compute(t):
        j = _join(t["lineitem"], t["supplier"], "l_suppkey", "s_suppkey")
        j = _join(j, t["orders"], "l_orderkey", "o_orderkey")
        j = _join(j, t["customer"], "o_custkey", "c_custkey")
        m = ((j.cols["s_nationkey"] == 5) & (j.cols["c_nationkey"] == 7)) | (
            (j.cols["s_nationkey"] == 7) & (j.cols["c_nationkey"] == 5))
        j = j.filter(m)
        yr = (j.cols["l_shipdate"] // 365).astype(np.int32)
        j = ColumnTable({**j.cols, "l_year": yr})
        g = _agg(j, ["s_nationkey", "c_nationkey", "l_year"],
                 {"revenue": ("sum", "volume")})
        return ops.sort_table(g, ["s_nationkey", "c_nationkey", "l_year"])

    return Query("Q7", {"lineitem": li, "orders": od, "customer": cu,
                        "supplier": su}, compute,
                 shuffle_keys={"lineitem": "l_orderkey", "orders": "o_orderkey"})


# --------------------------------------------------------------------- Q8
def q8() -> Query:
    d0, d1 = date(1995, 1, 1), date(1996, 12, 31)
    od = PushPlan("orders", ("o_orderkey", "o_custkey", "o_orderdate"),
                  predicate=C("o_orderdate").between(d0, d1 + 1))
    li = PushPlan("lineitem", ("l_orderkey", "l_partkey", "l_suppkey", "volume"),
                  derive=(("volume", ("l_extendedprice", "l_discount"),
                           lambda e, d: e * (1 - d)),))
    pa = PushPlan("part", ("p_partkey",), predicate=C("p_type").eq(42))
    cu = PushPlan("customer", ("c_custkey", "c_nationkey"))
    su = PushPlan("supplier", ("s_suppkey", "s_nationkey"))
    na = PushPlan("nation", ("n_nationkey", "n_regionkey"))

    def compute(t):
        j = _join(t["lineitem"], t["part"], "l_partkey", "p_partkey")
        j = _join(j, t["orders"], "l_orderkey", "o_orderkey")
        j = _join(j, t["customer"], "o_custkey", "c_custkey")
        j = _join(j, t["nation"], "c_nationkey", "n_nationkey")
        j = j.filter(j.cols["n_regionkey"] == 1)
        j = _join(j, t["supplier"], "l_suppkey", "s_suppkey")
        yr = (j.cols["o_orderdate"] // 365).astype(np.int32)
        nat = (j.cols["s_nationkey"] == 3).astype(np.float64) * j.cols["volume"]
        j = ColumnTable({**j.cols, "o_year": yr, "nat_volume": nat})
        g = _agg(j, ["o_year"], {"nat": ("sum", "nat_volume"),
                                 "total": ("sum", "volume")})
        share = g.cols["nat"] / np.maximum(g.cols["total"], 1e-9)
        return ColumnTable({"o_year": g.cols["o_year"], "mkt_share": share})

    return Query("Q8", {"orders": od, "lineitem": li, "part": pa,
                        "customer": cu, "supplier": su, "nation": na}, compute,
                 shuffle_keys={"lineitem": "l_orderkey", "orders": "o_orderkey"})


# --------------------------------------------------------------------- Q10
def q10() -> Query:
    D = date(1993, 10, 1)
    cu = PushPlan("customer", ("c_custkey", "c_nationkey", "c_acctbal"))
    od = PushPlan("orders", ("o_orderkey", "o_custkey"),
                  predicate=C("o_orderdate").between(D, D + 92))
    li = PushPlan("lineitem", ("l_orderkey", "revenue"),
                  predicate=C("l_returnflag").eq(2), derive=(REV,))

    def compute(t):
        j = _join(t["lineitem"], t["orders"], "l_orderkey", "o_orderkey")
        j = _join(j, t["customer"], "o_custkey", "c_custkey")
        g = _agg(j, ["o_custkey"], {"revenue": ("sum", "revenue")})
        return ops.top_k(g, "revenue", 20)

    return Query("Q10", {"customer": cu, "orders": od, "lineitem": li}, compute,
                 shuffle_keys={"lineitem": "l_orderkey", "orders": "o_orderkey"})


# --------------------------------------------------------------------- Q12
def q12() -> Query:
    D = date(1994, 1, 1)
    li = PushPlan("lineitem", ("l_orderkey", "l_shipmode", "_ontime"),
                  predicate=(C("l_shipmode").isin((0, 4))
                             & C("l_receiptdate").between(D, D + 365)),
                  derive=(("_ontime",
                           ("l_shipdate", "l_commitdate", "l_receiptdate"),
                           lambda s, c, r: ((s < c) & (c < r)).astype(np.int32)),))
    od = PushPlan("orders", ("o_orderkey", "o_orderpriority"))

    def compute(t):
        li_t = t["lineitem"]
        li_t = li_t.filter(li_t.cols["_ontime"] == 1)
        j = _join(li_t, t["orders"], "l_orderkey", "o_orderkey")
        hi = np.isin(j.cols["o_orderpriority"], (0, 1)).astype(np.int64)
        j = ColumnTable({**j.cols, "high": hi, "low": 1 - hi})
        g = _agg(j, ["l_shipmode"], {"high_cnt": ("sum", "high"),
                                     "low_cnt": ("sum", "low")})
        return ops.sort_table(g, ["l_shipmode"])

    return Query("Q12", {"lineitem": li, "orders": od}, compute,
                 shuffle_keys={"lineitem": "l_orderkey", "orders": "o_orderkey"})


# --------------------------------------------------------------------- Q14
def q14() -> Query:
    D = date(1995, 9, 1)
    li = PushPlan("lineitem", ("l_partkey", "revenue"),
                  predicate=C("l_shipdate").between(D, D + 30), derive=(REV,))
    pa = PushPlan("part", ("p_partkey", "p_type"))

    def compute(t):
        j = _join(t["lineitem"], t["part"], "l_partkey", "p_partkey")
        promo = (j.cols["p_type"] < 15).astype(np.float64) * j.cols["revenue"]
        num, den = promo.sum(), j.cols["revenue"].sum()
        return ColumnTable({"promo_revenue": np.asarray(
            [100.0 * num / max(den, 1e-9)])})

    return Query("Q14", {"lineitem": li, "part": pa}, compute,
                 shuffle_keys={"lineitem": "l_partkey", "part": "p_partkey"})


# --------------------------------------------------------------------- Q15
def q15() -> Query:
    D = date(1996, 1, 1)
    li = PushPlan("lineitem", ("l_suppkey",),
                  predicate=C("l_shipdate").between(D, D + 92), derive=(REV,),
                  agg=(("l_suppkey",), (("total_rev", "sum", "revenue"),)))
    su = PushPlan("supplier", ("s_suppkey", "s_nationkey"))

    def compute(t):
        g = _agg(t["lineitem"], ["l_suppkey"], {"total_rev": ("sum", "total_rev")})
        mx = g.cols["total_rev"].max() if len(g) else 0.0
        top = g.filter(g.cols["total_rev"] >= mx - 1e-9)
        return _join(top, t["supplier"], "l_suppkey", "s_suppkey")

    return Query("Q15", {"lineitem": li, "supplier": su}, compute,
                 shuffle_keys={"lineitem": "l_suppkey"})


# --------------------------------------------------------------------- Q17
def q17() -> Query:
    li = PushPlan("lineitem", ("l_partkey", "l_quantity", "l_extendedprice"))
    pa = PushPlan("part", ("p_partkey",),
                  predicate=C("p_brand").eq(3) & C("p_container").eq(7))

    def compute(t):
        j = _join(t["lineitem"], t["part"], "l_partkey", "p_partkey")
        g = _agg(j, ["l_partkey"], {"avg_qty": ("mean", "l_quantity")})
        j = _join(j, g, "l_partkey", "l_partkey")
        m = j.cols["l_quantity"] < 0.2 * j.cols["avg_qty"]
        return ColumnTable({"avg_yearly": np.asarray(
            [j.cols["l_extendedprice"][m].sum() / 7.0])})

    return Query("Q17", {"lineitem": li, "part": pa}, compute,
                 shuffle_keys={"lineitem": "l_partkey", "part": "p_partkey"})


# --------------------------------------------------------------------- Q18
def q18(threshold: float = 150.0) -> Query:
    li = PushPlan("lineitem", ("l_orderkey",),
                  agg=(("l_orderkey",), (("sum_qty", "sum", "l_quantity"),)))
    od = PushPlan("orders", ("o_orderkey", "o_custkey", "o_orderdate",
                             "o_totalprice"))

    def compute(t):
        g = _agg(t["lineitem"], ["l_orderkey"], {"sum_qty": ("sum", "sum_qty")})
        big = g.filter(g.cols["sum_qty"] > threshold)
        j = _join(big, t["orders"], "l_orderkey", "o_orderkey")
        return ops.top_k(j, "o_totalprice", 100)

    return Query("Q18", {"lineitem": li, "orders": od}, compute,
                 shuffle_keys={"lineitem": "l_orderkey", "orders": "o_orderkey"})


# --------------------------------------------------------------------- Q19
def q19() -> Query:
    # OR-of-ANDs over brand/container/quantity/size — the composite-predicate
    # showcase for fine-grained bitmap pushdown (§4.2 design-space discussion)
    li = PushPlan(
        "lineitem", ("l_partkey", "l_quantity", "revenue"),
        predicate=(C("l_shipmode").isin((0, 1))
                   & C("l_shipinstruct").eq(2)
                   & ((C("l_quantity").between(1, 12)
                       | C("l_quantity").between(10, 21))
                      | C("l_quantity").between(20, 31))),
        derive=(REV,))
    pa = PushPlan("part", ("p_partkey", "p_brand", "p_container", "p_size"))

    def compute(t):
        j = _join(t["lineitem"], t["part"], "l_partkey", "p_partkey")
        c = j.cols
        m = (((c["p_brand"] == 3) & (c["p_container"] < 10)
              & (c["l_quantity"] < 12) & (c["p_size"] <= 5))
             | ((c["p_brand"] == 5) & (c["p_container"] < 20)
                & (c["l_quantity"] < 21) & (c["p_size"] <= 10))
             | ((c["p_brand"] == 9) & (c["p_container"] < 40)
                & (c["l_quantity"] < 31) & (c["p_size"] <= 15)))
        return ColumnTable({"revenue": np.asarray([c["revenue"][m].sum()])})

    return Query("Q19", {"lineitem": li, "part": pa}, compute,
                 shuffle_keys={"lineitem": "l_partkey", "part": "p_partkey"})


# --------------------------------------------------------------------- Q22
def q22() -> Query:
    cu = PushPlan("customer", ("c_custkey", "c_nationkey", "c_acctbal"),
                  predicate=C("c_acctbal") > 0.0)
    od = PushPlan("orders", ("o_custkey",))

    def compute(t):
        c = t["customer"]
        sel = np.isin(c.cols["c_nationkey"], (13, 17, 19, 21, 23))
        c = c.filter(sel)
        avg = c.cols["c_acctbal"].mean() if len(c) else 0.0
        rich = c.filter(c.cols["c_acctbal"] > avg)
        has_order = np.isin(rich.cols["c_custkey"],
                            np.unique(t["orders"].cols["o_custkey"]))
        no_ord = rich.filter(~has_order)
        g = _agg(no_ord, ["c_nationkey"], {"numcust": ("count", ""),
                                           "totacctbal": ("sum", "c_acctbal")})
        return ops.sort_table(g, ["c_nationkey"])

    return Query("Q22", {"customer": cu, "orders": od}, compute,
                 shuffle_keys={"orders": "o_custkey"})


_BUILDERS = {f.__name__.upper(): f for f in (
    q1, q3, q4, q5, q6, q7, q8, q10, q12, q14, q15, q17, q18, q19, q22)}
QUERY_IDS: List[str] = sorted(_BUILDERS, key=lambda q: int(q[1:]))


def build_query(qid: str, fact_selectivity: Optional[float] = None) -> Query:
    """Compile ``qid`` from its logical-plan IR (storage frontier derived
    by the amenability splitter — see ``repro.compiler``)."""
    from repro.compiler import compile_query  # deferred: avoids cycle
    return compile_query(qid, fact_selectivity)


def build_query_legacy(qid: str,
                       fact_selectivity: Optional[float] = None) -> Query:
    """The seed's hand-built plans (frozen amenability split) — kept as the
    reference the compiled plans are asserted equal against."""
    q = _BUILDERS[qid.upper()]()
    if fact_selectivity is not None and "lineitem" in q.plans:
        thresh = float(np.ceil(50 * fact_selectivity))
        q = dataclasses.replace(q, plans=dict(q.plans))
        q.plans["lineitem"] = dataclasses.replace(
            q.plans["lineitem"], predicate=(C("l_quantity") <= thresh))
    return q


def all_queries() -> List[Query]:
    return [build_query(qid) for qid in QUERY_IDS]
