"""Columnar tables: a dict of equal-length numpy arrays + per-column stats.

This is the storage-layer native format (the "Parquet" of the framework):
column-oriented, per-column byte accounting with a dtype/cardinality-based
compression model (mirrors the paper's observation that low-cardinality
columns like l_shipmode compress far better than decimal join keys).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

import numpy as np


@dataclasses.dataclass
class ColumnStats:
    min: float
    max: float
    ndv: int  # approx distinct values
    nbytes_raw: int
    nbytes_stored: int  # after the compression model

    @staticmethod
    def of(arr: np.ndarray) -> "ColumnStats":
        raw = arr.nbytes
        if arr.size == 0:
            return ColumnStats(0.0, 0.0, 0, 0, 0)
        ndv = min(len(np.unique(arr[:: max(1, len(arr) // 4096)])) * max(1, len(arr) // 4096),
                  len(arr))
        # compression model: low-cardinality dictionary-encodes well
        card_ratio = ndv / max(1, len(arr))
        comp = 0.08 + 0.92 * min(1.0, card_ratio * 8)
        return ColumnStats(float(arr.min()), float(arr.max()), int(ndv),
                           raw, int(raw * comp))


class ColumnTable:
    """Immutable-ish columnar block."""

    def __init__(self, cols: Dict[str, np.ndarray], stats: Optional[Dict[str, ColumnStats]] = None):
        lens = {len(v) for v in cols.values()}
        assert len(lens) <= 1, f"ragged columns: { {k: len(v) for k, v in cols.items()} }"
        self.cols = cols
        self._stats = stats

    def __len__(self) -> int:
        return len(next(iter(self.cols.values()))) if self.cols else 0

    @property
    def columns(self) -> List[str]:
        return list(self.cols)

    def stats(self) -> Dict[str, ColumnStats]:
        if self._stats is None:
            self._stats = {k: ColumnStats.of(v) for k, v in self.cols.items()}
        return self._stats

    def nbytes(self, columns: Optional[Iterable[str]] = None, stored: bool = True) -> int:
        st = self.stats()
        cols = list(columns) if columns is not None else self.columns
        return sum((st[c].nbytes_stored if stored else st[c].nbytes_raw) for c in cols)

    def select(self, columns: Iterable[str]) -> "ColumnTable":
        cols = list(columns)
        # projection keeps rows intact: already-computed per-column stats
        # stay valid, so propagate them (only when every column is covered
        # — a partial stats dict would mask the lazy recompute)
        st = self._stats
        if st is not None and all(c in st for c in cols):
            st = {c: st[c] for c in cols}
        else:
            st = None
        return ColumnTable({c: self.cols[c] for c in cols}, stats=st)

    def take(self, idx: np.ndarray) -> "ColumnTable":
        return ColumnTable({k: v[idx] for k, v in self.cols.items()})

    def filter(self, mask: np.ndarray) -> "ColumnTable":
        return ColumnTable({k: v[mask] for k, v in self.cols.items()})

    @staticmethod
    def concat(tables: List["ColumnTable"]) -> "ColumnTable":
        nonempty = [t for t in tables if len(t)]
        if not nonempty:
            # keep the schema: a filter matching zero rows everywhere must
            # still yield a joinable (0-row, correct-columns) table
            return tables[0] if tables else ColumnTable({})
        cols = nonempty[0].columns
        return ColumnTable({c: np.concatenate([t.cols[c] for t in nonempty]) for c in cols})

    def __repr__(self):
        return f"ColumnTable({len(self)} rows x {self.columns})"
