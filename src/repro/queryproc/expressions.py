"""Predicate/scalar expression trees.

Evaluated two ways:
- ``evaluate(table)``  -> numpy (storage-layer native execution)
- selectivity estimation from ColumnStats (the arbitrator's cardinality
  estimator, Eq. 9's S_out)

Both walks also have a *compile-once* form (``compile_expr`` /
``compile_selectivity``): the tree is lowered into a closure a single time
per query plan, so the per-partition executor (``core.executor``) never
re-walks the tree — the storage layer runs one request per partition and a
query touches ~160 of them.

The same tree is compiled to the fused Pallas ``predicate_bitmap`` kernel for
pushed-back on-device evaluation (see repro.kernels).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Tuple

import numpy as np

from repro.queryproc.table import ColumnStats, ColumnTable


class Expr:
    def __and__(self, other):
        return And(self, other)

    def __or__(self, other):
        return Or(self, other)


@dataclasses.dataclass
class Col(Expr):
    name: str

    def __le__(self, v):  # noqa: allow rich predicates
        return Cmp("<=", self, v)

    def __lt__(self, v):
        return Cmp("<", self, v)

    def __ge__(self, v):
        return Cmp(">=", self, v)

    def __gt__(self, v):
        return Cmp(">", self, v)

    def eq(self, v):
        return Cmp("==", self, v)

    def isin(self, vals):
        return In(self, tuple(vals))

    def between(self, lo, hi):
        return Cmp(">=", self, lo) & Cmp("<", self, hi)


@dataclasses.dataclass
class Cmp(Expr):
    op: str
    col: Col
    value: Any  # scalar, or Col for a column-column comparison


@dataclasses.dataclass
class In(Expr):
    col: Col
    values: Tuple


@dataclasses.dataclass
class And(Expr):
    left: Expr
    right: Expr


@dataclasses.dataclass
class Or(Expr):
    left: Expr
    right: Expr


_OPS = {"<=": np.less_equal, "<": np.less, ">=": np.greater_equal,
        ">": np.greater, "==": np.equal}


def evaluate(expr: Expr, table: ColumnTable) -> np.ndarray:
    if isinstance(expr, Cmp):
        rhs = (table.cols[expr.value.name] if isinstance(expr.value, Col)
               else expr.value)
        return _OPS[expr.op](table.cols[expr.col.name], rhs)
    if isinstance(expr, In):
        return np.isin(table.cols[expr.col.name], expr.values)
    if isinstance(expr, And):
        return evaluate(expr.left, table) & evaluate(expr.right, table)
    if isinstance(expr, Or):
        return evaluate(expr.left, table) | evaluate(expr.right, table)
    raise TypeError(expr)


def compile_expr(expr: Expr) -> Callable[[Dict[str, np.ndarray]], np.ndarray]:
    """Lower the tree once into a numpy closure over a column dict.

    ``compile_expr(e)({c: arr})`` is bitwise-identical to
    ``evaluate(e, ColumnTable({c: arr}))`` — same numpy ufuncs in the same
    association order — but the tree walk happens at compile time, not per
    partition. Mirrors ``kernels.predicate_bitmap.compile_predicate`` (one
    plan representation, numpy and Pallas backends)."""
    if isinstance(expr, Cmp):
        op = _OPS[expr.op]
        name = expr.col.name
        if isinstance(expr.value, Col):
            rname = expr.value.name
            return lambda cols: op(cols[name], cols[rname])
        v = expr.value
        return lambda cols: op(cols[name], v)
    if isinstance(expr, In):
        name, vals = expr.col.name, expr.values
        return lambda cols: np.isin(cols[name], vals)
    if isinstance(expr, And):
        lf, rf = compile_expr(expr.left), compile_expr(expr.right)
        return lambda cols: lf(cols) & rf(cols)
    if isinstance(expr, Or):
        lf, rf = compile_expr(expr.left), compile_expr(expr.right)
        return lambda cols: lf(cols) | rf(cols)
    raise TypeError(expr)


def implies(a, b) -> bool:
    """Conservative syntactic implication check: True means every row
    satisfying ``a`` also satisfies ``b``; False means "could not prove"
    (never "definitely not"). ``None`` stands for the vacuous predicate
    (all rows), so anything implies ``None`` and ``None`` implies only
    ``None``.

    This is the semantic-containment twin of
    ``compiler.multitable.implied_predicate``: the same ``And``/``Or``
    decomposition (an ``And`` antecedent proves through either side, an
    ``Or`` antecedent must prove through both), grounded in interval /
    membership arithmetic at the leaves. The storage-layer result cache
    (``core.result_cache``) uses it to decide when a cached
    looser-predicate result is a superset that can serve a tighter
    request after re-filtering."""
    if b is None:
        return True
    if a is None:
        return False
    if repr(a) == repr(b):
        return True
    if isinstance(b, And):
        return implies(a, b.left) and implies(a, b.right)
    if isinstance(a, And):
        # either conjunct alone proving b suffices (both hold on a's rows)
        if implies(a.left, b) or implies(a.right, b):
            return True
    if isinstance(a, Or):
        return implies(a.left, b) and implies(a.right, b)
    if isinstance(b, Or):
        return implies(a, b.left) or implies(a, b.right)
    return _atom_implies(a, b)


def _atom_implies(a: Expr, b: Expr) -> bool:
    """Leaf-level implication between two atoms over the *same* column."""
    if isinstance(a, And) or isinstance(b, And):
        return False  # composites were handled above; an And here is a's
        #               unproven conjunct pair reaching a leaf b — give up
    col_a = a.col.name if isinstance(a, (Cmp, In)) else None
    col_b = b.col.name if isinstance(b, (Cmp, In)) else None
    if col_a is None or col_a != col_b:
        return False
    # column-column compares carry no interval: repr equality (done) only
    if (isinstance(a, Cmp) and isinstance(a.value, Col)) or \
            (isinstance(b, Cmp) and isinstance(b.value, Col)):
        return False
    if isinstance(a, In) and isinstance(b, In):
        return set(a.values) <= set(b.values)
    if isinstance(a, In) and isinstance(b, Cmp):
        op = _OPS[b.op]
        return all(bool(op(v, b.value)) for v in a.values)
    if isinstance(a, Cmp) and isinstance(b, In):
        return a.op == "==" and a.value in b.values
    if isinstance(a, Cmp) and isinstance(b, Cmp):
        va, vb = a.value, b.value
        if b.op in ("<", "<="):
            if a.op == "<" and va <= vb:
                return True
            if a.op == "<=" and (va < vb if b.op == "<" else va <= vb):
                return True
            return a.op == "==" and bool(_OPS[b.op](va, vb))
        if b.op in (">", ">="):
            if a.op == ">" and va >= vb:
                return True
            if a.op == ">=" and (va > vb if b.op == ">" else va >= vb):
                return True
            return a.op == "==" and bool(_OPS[b.op](va, vb))
        if b.op == "==":
            return a.op == "==" and va == vb
    return False


def columns_of(expr: Expr) -> set:
    if isinstance(expr, Cmp):
        if isinstance(expr.value, Col):
            return {expr.col.name, expr.value.name}
        return {expr.col.name}
    if isinstance(expr, In):
        return {expr.col.name}
    if isinstance(expr, (And, Or)):
        return columns_of(expr.left) | columns_of(expr.right)
    raise TypeError(expr)


def estimate_selectivity(expr: Expr, stats: Dict[str, ColumnStats]) -> float:
    """Uniform-range cardinality estimate (the paper's lightweight model)."""
    if isinstance(expr, Cmp):
        if isinstance(expr.value, Col):
            return 0.5  # column-column compare: no per-column range applies
        st = stats.get(expr.col.name)
        if st is None or st.max <= st.min:
            return 0.5
        span = st.max - st.min
        v = float(expr.value)
        if expr.op in ("<", "<="):
            return float(np.clip((v - st.min) / span, 0.0, 1.0))
        if expr.op in (">", ">="):
            return float(np.clip((st.max - v) / span, 0.0, 1.0))
        return 1.0 / max(1, st.ndv)
    if isinstance(expr, In):
        st = stats.get(expr.col.name)
        return min(1.0, len(expr.values) / max(1, st.ndv if st else 10))
    if isinstance(expr, And):
        return estimate_selectivity(expr.left, stats) * estimate_selectivity(expr.right, stats)
    if isinstance(expr, Or):
        a = estimate_selectivity(expr.left, stats)
        b = estimate_selectivity(expr.right, stats)
        return a + b - a * b
    raise TypeError(expr)


def compile_selectivity(expr: Expr) -> Callable[[Dict[str, ColumnStats]], float]:
    """Compile-once form of ``estimate_selectivity``: returns a closure over
    a stats dict that computes the identical estimate without re-walking the
    tree per partition (partitions differ only in their stats)."""
    if isinstance(expr, Cmp):
        if isinstance(expr.value, Col):
            return lambda stats: 0.5
        name, op = expr.col.name, expr.op
        v = float(expr.value)

        def cmp_sel(stats: Dict[str, ColumnStats]) -> float:
            st = stats.get(name)
            if st is None or st.max <= st.min:
                return 0.5
            span = st.max - st.min
            if op in ("<", "<="):
                return float(np.clip((v - st.min) / span, 0.0, 1.0))
            if op in (">", ">="):
                return float(np.clip((st.max - v) / span, 0.0, 1.0))
            return 1.0 / max(1, st.ndv)

        return cmp_sel
    if isinstance(expr, In):
        name, n_vals = expr.col.name, len(expr.values)

        def in_sel(stats: Dict[str, ColumnStats]) -> float:
            st = stats.get(name)
            return min(1.0, n_vals / max(1, st.ndv if st else 10))

        return in_sel
    if isinstance(expr, And):
        lf, rf = compile_selectivity(expr.left), compile_selectivity(expr.right)
        return lambda stats: lf(stats) * rf(stats)
    if isinstance(expr, Or):
        lf, rf = compile_selectivity(expr.left), compile_selectivity(expr.right)

        def or_sel(stats: Dict[str, ColumnStats]) -> float:
            a, b = lf(stats), rf(stats)
            return a + b - a * b

        return or_sel
    raise TypeError(expr)
