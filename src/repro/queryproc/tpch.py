"""TPC-H-style dataset generator (columnar, numpy).

Scaled-down TPC-H: ``sf=1`` is 1/100 of the real SF1 row counts so the full
22-ish query suite runs on one CPU core in seconds; the byte *accounting*
(per-column stored sizes, compression model) is what the cost model feeds
on, so absolute scale does not change the pushdown/pushback trade-offs.

Strings are dictionary-encoded to int codes (the storage-native format is
numeric columnar; the compression model in table.py rewards low-cardinality
columns exactly like Parquet dictionary pages — the paper's l_shipmode
observation). Dates are int days since 1992-01-01.
"""
from __future__ import annotations

import datetime
from typing import Dict, Optional

import numpy as np

from repro.queryproc.table import ColumnTable
from repro.storage.catalog import Catalog

_EPOCH = datetime.date(1992, 1, 1)


def date(y: int, m: int, d: int) -> int:
    return (datetime.date(y, m, d) - _EPOCH).days


BASE_ROWS = dict(lineitem=60_000, orders=15_000, customer=1_500,
                 part=2_000, supplier=100, partsupp=8_000,
                 nation=25, region=5)

N_RETURNFLAG, N_LINESTATUS, N_SHIPMODE, N_SHIPINSTRUCT = 3, 2, 7, 4
N_MKTSEGMENT, N_ORDERPRIORITY, N_BRAND, N_TYPE, N_CONTAINER = 5, 5, 25, 150, 40
MAX_DATE = date(1998, 8, 2)


def generate_tables(sf: float = 1.0, seed: int = 0) -> Dict[str, ColumnTable]:
    rng = np.random.default_rng(seed)
    n = {k: max(1, int(v * sf)) for k, v in BASE_ROWS.items()}
    n["nation"], n["region"] = 25, 5

    region = ColumnTable({"r_regionkey": np.arange(5, dtype=np.int32)})
    nation = ColumnTable({
        "n_nationkey": np.arange(25, dtype=np.int32),
        "n_regionkey": (np.arange(25) % 5).astype(np.int32),
    })
    supplier = ColumnTable({
        "s_suppkey": np.arange(n["supplier"], dtype=np.int32),
        "s_nationkey": rng.integers(0, 25, n["supplier"], np.int32),
        "s_acctbal": rng.uniform(-999, 9999, n["supplier"]).astype(np.float64),
    })
    part = ColumnTable({
        "p_partkey": np.arange(n["part"], dtype=np.int32),
        "p_brand": rng.integers(0, N_BRAND, n["part"], np.int32),
        "p_type": rng.integers(0, N_TYPE, n["part"], np.int32),
        "p_size": rng.integers(1, 51, n["part"], np.int32),
        "p_container": rng.integers(0, N_CONTAINER, n["part"], np.int32),
        "p_retailprice": rng.uniform(900, 2000, n["part"]).astype(np.float64),
    })
    partsupp = ColumnTable({
        "ps_partkey": rng.integers(0, n["part"], n["partsupp"], np.int32),
        "ps_suppkey": rng.integers(0, n["supplier"], n["partsupp"], np.int32),
        "ps_availqty": rng.integers(1, 10_000, n["partsupp"], np.int32),
        "ps_supplycost": rng.uniform(1, 1000, n["partsupp"]).astype(np.float64),
    })
    customer = ColumnTable({
        "c_custkey": np.arange(n["customer"], dtype=np.int32),
        "c_mktsegment": rng.integers(0, N_MKTSEGMENT, n["customer"], np.int32),
        "c_nationkey": rng.integers(0, 25, n["customer"], np.int32),
        "c_acctbal": rng.uniform(-999, 9999, n["customer"]).astype(np.float64),
    })
    o_orderdate = rng.integers(0, date(1998, 8, 2) - 121, n["orders"], np.int32)
    # ~1/3 of customers have no orders (TPC-H's 3:2 customer:order-customer
    # ratio — keeps Q22's NOT EXISTS anti-join non-empty)
    orders = ColumnTable({
        "o_orderkey": np.arange(n["orders"], dtype=np.int32),
        "o_custkey": rng.integers(0, max(1, (2 * n["customer"]) // 3),
                                  n["orders"], np.int32),
        "o_orderdate": o_orderdate,
        "o_orderpriority": rng.integers(0, N_ORDERPRIORITY, n["orders"], np.int32),
        "o_shippriority": np.zeros(n["orders"], np.int32),
        "o_totalprice": rng.uniform(1000, 400_000, n["orders"]).astype(np.float64),
    })
    # lineitem rows reference a random order; dates derive from the order's
    lo = rng.integers(0, n["orders"], n["lineitem"], np.int32)
    odate = o_orderdate[lo]
    shipdate = odate + rng.integers(1, 122, n["lineitem"], np.int32)
    lineitem = ColumnTable({
        "l_orderkey": lo,
        "l_partkey": rng.integers(0, n["part"], n["lineitem"], np.int32),
        "l_suppkey": rng.integers(0, n["supplier"], n["lineitem"], np.int32),
        "l_quantity": rng.integers(1, 51, n["lineitem"], np.int32).astype(np.float64),
        "l_extendedprice": rng.uniform(900, 100_000, n["lineitem"]).astype(np.float64),
        "l_discount": rng.integers(0, 11, n["lineitem"]).astype(np.float64) / 100.0,
        "l_tax": rng.integers(0, 9, n["lineitem"]).astype(np.float64) / 100.0,
        "l_returnflag": rng.integers(0, N_RETURNFLAG, n["lineitem"], np.int32),
        "l_linestatus": rng.integers(0, N_LINESTATUS, n["lineitem"], np.int32),
        "l_shipdate": shipdate,
        "l_commitdate": odate + rng.integers(30, 91, n["lineitem"], np.int32),
        "l_receiptdate": shipdate + rng.integers(1, 31, n["lineitem"], np.int32),
        "l_shipinstruct": rng.integers(0, N_SHIPINSTRUCT, n["lineitem"], np.int32),
        "l_shipmode": rng.integers(0, N_SHIPMODE, n["lineitem"], np.int32),
    })
    return {"region": region, "nation": nation, "supplier": supplier,
            "part": part, "partsupp": partsupp, "customer": customer,
            "orders": orders, "lineitem": lineitem}


def build_catalog(sf: float = 1.0, seed: int = 0, num_nodes: int = 1,
                  rows_per_partition: int = 6_000,
                  cluster: Optional[Dict[str, str]] = None) -> Catalog:
    """Partition sizes follow the paper's ~fixed-size objects: the fact
    table ends up with ~10*sf partitions -> 10*sf pushdown requests/query.

    ``cluster`` maps table -> cluster key (e.g. ``{"lineitem":
    "l_orderkey"}``): those tables are sorted by the key with partition
    boundaries aligned to key runs (``Catalog.add_table(cluster_key=)``),
    which makes group-by-key partials partition-local and unlocks
    storage-side HAVING pushdown (Q18)."""
    tables = generate_tables(sf, seed)
    cat = Catalog(num_nodes)
    cluster = cluster or {}
    for name, data in tables.items():
        # dimension tables split too (4 objects/node) so a single large
        # object transfer never serializes the pushdown phase
        rpp = rows_per_partition if name == "lineitem" else max(
            len(data) // max(1, num_nodes * 4), 1)
        cat.add_table(name, data, rpp, cluster_key=cluster.get(name))
    return cat
