"""Training driver: pushdown data pipeline -> jit train_step -> checkpoints.

Fault tolerance in one loop:
- auto-resume from the latest checkpoint (step counter restores the
  deterministic data stream),
- async keep-k checkpoints every ``ckpt_every`` steps,
- SIGTERM preemption hook (save + exit),
- straggler mitigation falls out of the paper's mechanism: a storage host
  that falls behind *pushes work back* (Algorithm 1), degrading into a raw
  data server instead of stalling the feed; the loop double-buffers host
  batches against device steps.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api
from repro.train import optimizer as opt_lib
from repro.train.checkpoint import CheckpointManager, PreemptionGuard


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    log_every: int = 10
    opt: opt_lib.AdamWConfig = dataclasses.field(
        default_factory=opt_lib.AdamWConfig)


def make_host_train_step(cfg: ModelConfig, opt_cfg: opt_lib.AdamWConfig,
                         remat: bool = False):
    """Single-host jit train step over an (accum, mb, S) batch."""
    import jax.numpy as jnp

    def step_fn(params, opt, batch):
        def body(gsum, mb):
            loss, g = jax.value_and_grad(
                lambda p: api.loss_fn(p, cfg, mb, remat=remat))(params)
            return jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g), loss

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, losses = jax.lax.scan(body, zeros, batch)
        acc = losses.shape[0]
        grads = jax.tree.map(lambda g: g / acc, gsum)
        params, opt, stats = opt_lib.apply(opt_cfg, params, opt, grads)
        return params, opt, {"loss": losses.mean(), **stats}

    return jax.jit(step_fn, donate_argnums=(0, 1))


def train(cfg: ModelConfig, data: Iterator[Dict[str, np.ndarray]],
          tcfg: TrainConfig, rng: Optional[jax.Array] = None,
          hooks: Optional[Callable[[int, Dict], None]] = None) -> Dict:
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    params = api.init_params(cfg, rng)
    opt = opt_lib.init(params)
    start_step = 0

    mgr = CheckpointManager(tcfg.ckpt_dir, tcfg.keep) if tcfg.ckpt_dir else None
    if mgr and mgr.latest_step() is not None:
        (params, opt), start_step = mgr.restore((params, opt))
        for _ in range(start_step):   # fast-forward the deterministic stream
            next(data)

    step_fn = make_host_train_step(cfg, tcfg.opt)
    history = []
    t0 = time.time()

    def save_now(step):
        if mgr:
            mgr.save_async(step, (params, opt), extra={"cfg": cfg.name})

    guard_save = lambda: mgr and mgr.save(start_step, (params, opt))
    with PreemptionGuard(guard_save) as guard:
        step = start_step
        next_batch = next(data)  # prefetch (double buffer)
        while step < tcfg.steps:
            batch = jax.tree.map(jax.numpy.asarray, next_batch)
            try:
                next_batch = next(data)  # overlap host ingest w/ device step
            except StopIteration:
                next_batch = None
            params, opt, metrics = step_fn(params, opt, batch)
            step += 1
            if step % tcfg.log_every == 0 or step == tcfg.steps:
                m = {k: float(v) for k, v in metrics.items()}
                m["step"] = step
                m["wall_s"] = time.time() - t0
                history.append(m)
                if hooks:
                    hooks(step, m)
            if mgr and step % tcfg.ckpt_every == 0:
                save_now(step)
            if guard.fired or next_batch is None:
                break
    if mgr:
        mgr.wait()
        mgr.save(step, (params, opt), extra={"cfg": cfg.name, "final": True})
    return {"params": params, "opt": opt, "history": history,
            "final_step": step}
