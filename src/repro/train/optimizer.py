"""AdamW with ZeRO-sharded state.

Optimizer moments inherit each parameter's sharding (FSDP over `data`,
TP over `model` — see repro.distributed.sharding), so the optimizer is
ZeRO-3 by construction: every chip owns 1/(data*model) of m and v.

Moments are fp32; parameters stay in their storage dtype (bf16 master-less
training — the fp32 moment pair plus fp32 update math recovers most of the
precision; recorded as a deliberate memory/quality trade in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import params as Pm


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    m: Any
    v: Any
    step: jax.Array


def init(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(m=zeros, v=jax.tree.map(jnp.copy, zeros),
                    step=jnp.zeros((), jnp.int32))


def init_specs(param_specs) -> OptState:
    """ParamSpec tree for the optimizer state (same logical axes as params,
    fp32) — lets the dry-run build abstract opt state with real shardings."""
    f32 = Pm.tree_map_specs(
        lambda s: Pm.ParamSpec(s.shape, s.axes, jnp.float32, "zeros", 0.0),
        param_specs)
    step = Pm.ParamSpec((), (), jnp.int32, "zeros", 0.0)
    return OptState(m=f32, v=f32, step=step)


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * cos


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves))


def _decayed(path) -> bool:
    """Weight decay on matmul weights only (skip norms/biases/scalars)."""
    names = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    leafname = str(names[-1]) if names else ""
    return not any(s in leafname for s in ("norm", "bias", "scale", "b_", "lam",
                                           "A_log", "dt_bias", "D"))


def apply(cfg: AdamWConfig, params, opt: OptState, grads):
    """One AdamW step. Returns (new_params, new_opt, stats)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-6))
    step = opt.step + 1
    lr = schedule(cfg, opt.step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        if _decayed(path) and p.ndim >= 2:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    gl = jax.tree.leaves(grads)
    ml = jax.tree.leaves(opt.m)
    vl = jax.tree.leaves(opt.v)
    out = [upd(path, p, g, m, v) for (path, p), g, m, v in zip(flat, gl, ml, vl)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, OptState(new_m, new_v, step), {"grad_norm": gnorm, "lr": lr}
