"""Checkpointing: atomic, keep-k, async, elastic.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json
         <dir>/step_<N>.tmp.<pid>/ ... -> os.replace() on completion

- Atomic: writes land in a tmp dir; a single ``os.replace`` publishes the
  step — a crash mid-save never corrupts the latest checkpoint.
- Keep-k: older steps are pruned after a successful publish.
- Async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes on a worker thread, overlapping the next train steps.
- Elastic restore: arrays are stored *unsharded* (this is the single-
  process form; the multi-host design — one shard file per host + a merge
  manifest — is documented in DESIGN.md §5). ``restore`` takes the target
  sharding tree and lays the arrays onto whatever mesh the restarted job
  has: a 256-chip checkpoint restores onto 512 chips (or 8) unchanged.
- Preemption: ``PreemptionGuard`` installs a SIGTERM hook that saves and
  exits cleanly (the cloud eviction path).
"""
from __future__ import annotations

import json
import os
import shutil
import signal
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

_BF16 = np.dtype(jnp.bfloat16)


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
                       for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ----------------------------------------------------------- writing
    def save(self, step: int, state, extra: Optional[Dict] = None) -> Path:
        """Synchronous atomic save."""
        host = jax.tree.map(lambda x: np.asarray(x), state)
        return self._write(step, host, extra or {})

    def save_async(self, step: int, state, extra: Optional[Dict] = None):
        """Snapshot now, write on a worker thread."""
        self.wait()  # one in-flight save at a time
        host = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                self._write(step, host, extra or {})
            except BaseException as e:  # noqa: BLE001 — surfaced in wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    def _write(self, step: int, host_state, extra: Dict) -> Path:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".step_{step:08d}.tmp.{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_state)
        # npz has no bfloat16: store as a uint16 view, record which keys
        bf16_keys = [k for k, v in flat.items() if v.dtype == _BF16]
        disk = {k: (v.view(np.uint16) if k in set(bf16_keys) else v)
                for k, v in flat.items()}
        np.savez(tmp / "arrays.npz", **disk)
        manifest = {"step": step, "time": time.time(),
                    "keys": sorted(flat), "bf16_keys": bf16_keys,
                    "extra": extra}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)          # atomic publish
        self._prune()
        return final

    def _prune(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ----------------------------------------------------------- reading
    def all_steps(self) -> List[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if p.is_dir())

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, state_template, step: Optional[int] = None,
                shardings=None):
        """Restore into the template's structure. ``shardings`` (optional
        tree of NamedSharding) lays arrays onto a *different* mesh than the
        one that saved them — the elastic-scaling path."""
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoints under {self.dir}"
        final = self.dir / f"step_{step:08d}"
        raw = np.load(final / "arrays.npz")
        bf16 = set(self.manifest(step).get("bf16_keys", []))
        data = {k: (raw[k].view(_BF16) if k in bf16 else raw[k])
                for k in raw.files}
        keys = list(_flatten(state_template))
        leaves_t, treedef = jax.tree_util.tree_flatten(state_template)
        assert len(keys) == len(leaves_t)
        sh_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                     if shardings is not None else [None] * len(leaves_t))
        out = []
        for key, tmpl, sh in zip(keys, leaves_t, sh_leaves):
            arr = data[key]
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr, dtype=tmpl.dtype)
                           if hasattr(tmpl, "dtype") else arr)
        return jax.tree_util.tree_unflatten(treedef, out), step

    def manifest(self, step: int) -> Dict:
        return json.loads(
            (self.dir / f"step_{step:08d}" / "manifest.json").read_text())


class PreemptionGuard:
    """SIGTERM -> save + clean exit (cloud eviction). Use as context mgr."""

    def __init__(self, save_fn: Callable[[], None]):
        self.save_fn = save_fn
        self.fired = False
        self._prev = None

    def __enter__(self):
        def handler(signum, frame):
            self.fired = True
            self.save_fn()
        self._prev = signal.signal(signal.SIGTERM, handler)
        return self

    def __exit__(self, *exc):
        signal.signal(signal.SIGTERM, self._prev)
        return False
