import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402

from repro.configs import (ARCH_IDS, SHAPE_ORDER, get_config, get_shape,  # noqa: E402
                           shape_applicable)
from repro.distributed import sharding as shd  # noqa: E402
from repro.launch import analysis, steps  # noqa: E402
from repro.launch.mesh import make_production_mesh, mesh_chips, mesh_tag  # noqa: E402
from repro.models import api, flags  # noqa: E402

"""Multi-pod AOT dry-run.

For every (arch x shape x mesh) cell:

1. FULL-depth compile (scans rolled): proves the sharding config is coherent
   and that it fits — ``memory_analysis()`` per device; collective schedule
   recorded from the post-SPMD HLO.
2. Shallow COST pass (scans unrolled, U in {1,2}; train cells also sweep
   grad-accum A in {1,2}): ``cost_analysis()`` FLOPs/bytes and collective
   bytes, extrapolated (bi)linearly to full depth/accum — exact for
   depth-homogeneous stacks; see repro.launch.analysis.

Results land in reports/dryrun/<mesh>/<arch>__<shape>.json (resumable).
"""

RULES = {"baseline": None,  # kind-appropriate default (see steps.default_rules)
         "zero3": shd.ZERO3_POD_RULES}


def _lower_compile(cfg, shape, mesh, rules, *, accum=None,
                   variant="baseline"):
    if accum is not None:
        steps.ACCUM_OVERRIDES[(cfg.name, shape.name)] = accum
        if variant != "baseline":
            steps.VARIANTS[variant].setdefault("accum", {})[
                (cfg.name, shape.name)] = accum
    try:
        bundle = steps.build(cfg, shape, mesh, rules, variant=variant)
        with mesh:
            lowered = bundle.lower()
            compiled = lowered.compile()
        return compiled
    finally:
        if accum is not None:
            steps.ACCUM_OVERRIDES.pop((cfg.name, shape.name), None)


def run_cell(arch: str, shape_id: str, mesh, rules_name: str,
             *, cost_pass: bool = True, full_pass: bool = True,
             variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_id)
    rules = RULES[rules_name]
    if rules is not None and shape.kind == "decode":
        rules = dict(rules, embed=shd.INFERENCE_RULES["embed"],
                     kv_hd=shd.INFERENCE_RULES["kv_hd"])
    chips = mesh_chips(mesh)
    cpp = 256 if "pod" in mesh.shape else chips  # chips per pod
    rec: dict = {
        "arch": arch, "shape": shape_id, "mesh": mesh_tag(mesh),
        "rules": rules_name, "variant": variant, "chips": chips,
        "params_total": api.count_params(cfg),
        "params_active": api.count_params(cfg, active_only=True),
    }
    t0 = time.time()

    if full_pass:
        compiled = _lower_compile(cfg, shape, mesh, rules, variant=variant)
        rec["memory"] = analysis.memory_summary(compiled)
        hlo = compiled.as_text()
        rec["memory"]["cpu_upcast_bytes"] = analysis.cpu_upcast_bytes(hlo)
        ops = analysis.parse_collectives(hlo)
        rec["collectives_rolled"] = [dataclasses.asdict(o) for o in ops]
        rec["t_full_compile_s"] = round(time.time() - t0, 1)
        del compiled, hlo

    if cost_pass:
        U = api.scan_units(cfg)
        accums = (1, 2) if shape.kind == "train" else (None,)
        samples = {}
        with flags.unroll_scans():
            for u in (1, 2):
                for a in accums:
                    c = _lower_compile(api.with_depth(cfg, u), shape, mesh,
                                       rules, accum=a, variant=variant)
                    cs = analysis.cost_summary(c)
                    coll = analysis.collective_bytes(
                        analysis.parse_collectives(c.as_text()), cpp)
                    samples[(u, a)] = {**cs, "ici": coll["ici"],
                                       "dcn": coll["dcn"],
                                       "ici_eq": coll["ici_bf16eq"],
                                       "dcn_eq": coll["dcn_bf16eq"]}
                    del c

        def extrap_u(key, a):
            """Linear in scan depth at fixed accumulation."""
            return analysis.extrapolate(samples[(1, a)][key],
                                        samples[(2, a)][key], U)

        def extrap(key, bilinear=False):
            if accums == (None,):
                return extrap_u(key, None)
            if not bilinear:
                # total FLOPs/bytes are accum-invariant (the global batch is
                # fixed; only its slicing changes) — extrapolate over depth
                # at A=2 and keep. Bilinear blows up noise by (U-1)(A-1).
                return extrap_u(key, 2)
            # collectives DO scale with accum (per-microbatch FSDP gathers):
            # bilinear with non-negative increments
            A = steps.accum_for(cfg, shape)
            f11, f12 = samples[(1, 1)][key], samples[(2, 1)][key]
            f21, f22 = samples[(1, 2)][key], samples[(2, 2)][key]
            du = max(0.0, f12 - f11)
            da = max(0.0, f21 - f11)
            dau = max(0.0, f22 - f21 - f12 + f11)
            return f11 + (U - 1) * du + (A - 1) * da + (U - 1) * (A - 1) * dau

        flops_dev = extrap("flops")
        bytes_dev = extrap("bytes")
        coll = {"ici": max(0.0, extrap("ici", bilinear=True)),
                "dcn": max(0.0, extrap("dcn", bilinear=True))}
        coll["total"] = coll["ici"] + coll["dcn"]
        coll_eq = {"ici": max(0.0, extrap("ici_eq", bilinear=True)),
                   "dcn": max(0.0, extrap("dcn_eq", bilinear=True))}

        n_active = api.count_matmul_params(cfg, active_only=True)
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        factor = 6 if shape.kind == "train" else 2
        model_flops = factor * n_active * tokens

        rl = analysis.roofline(flops_dev, bytes_dev, coll, model_flops, chips)
        rec["roofline"] = {
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "dcn_s": rl.dcn_s,
            "dominant": rl.dominant, "step_time_s": rl.step_time_s,
            "mfu": rl.mfu, "useful_frac": rl.useful_frac,
            "flops_per_device": flops_dev, "bytes_per_device": bytes_dev,
            "coll_ici_bytes": coll["ici"], "coll_dcn_bytes": coll["dcn"],
            "coll_ici_bf16eq": coll_eq["ici"], "coll_dcn_bf16eq": coll_eq["dcn"],
            "collective_bf16eq_s": coll_eq["ici"] / 50e9 + coll_eq["dcn"] / 25e9,
            "model_flops": model_flops, "scan_units": U,
        }
        attach_adjusted_roofline(rec, cfg, shape, mesh, variant=variant)
    rec["t_total_s"] = round(time.time() - t0, 1)
    return rec


def attach_adjusted_roofline(rec: dict, cfg, shape, mesh=None,
                             mesh_shape=None, variant="baseline"):
    """Add the analytic-TPU-memory roofline terms (memory_adj_s, mfu_adj,
    dominant_adj) to a cell record. Pure post-processing — no compile."""
    from repro.launch.mesh import V5E
    from repro.models import params as Pm

    rl = rec.get("roofline")
    if not rl:
        return
    ms = mesh_shape or dict(mesh.shape)
    chips = rec["chips"]
    params_bytes = Pm.bytes_of(api.init_specs(cfg))
    cache_dev = 0.0
    if shape.kind == "decode":
        cache_dev = Pm.bytes_of(
            api.cache_specs(cfg, shape.global_batch, shape.seq_len)) / chips
    mem_adj = analysis.analytic_memory_bytes(
        cfg, shape, ms, steps.accum_for(cfg, shape, variant), shape.kind,
        params_bytes, cache_dev,
        remat=steps.VARIANTS.get(variant, {}).get("remat", True) is True)
    mem_adj_s = mem_adj / V5E.hbm_bw
    coll_total = rl.get("collective_bf16eq_s",
                        rl["collective_s"] + rl["dcn_s"])
    step_adj = max(rl["compute_s"], mem_adj_s, coll_total)
    rl["memory_adj_bytes"] = mem_adj
    rl["memory_adj_s"] = mem_adj_s
    rl["step_time_adj_s"] = step_adj
    rl["mfu_adj"] = rl["model_flops"] / (
        chips * V5E.peak_flops_bf16 * max(step_adj, 1e-12))
    terms = {"compute": rl["compute_s"], "memory": mem_adj_s,
             "collective": coll_total}
    rl["dominant_adj"] = max(terms, key=terms.get)


def cells(archs, shapes):
    for a in archs:
        cfg = get_config(a)
        for s in shapes:
            ok, why = shape_applicable(cfg, get_shape(s))
            yield a, s, ok, why


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--rules", default="baseline", choices=list(RULES))
    ap.add_argument("--variant", default="baseline",
                    choices=list(steps.VARIANTS))
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the shallow cost pass (multi-pod prove-out)")
    ap.add_argument("--no-full", action="store_true",
                    help="skip the full-depth compile (cost pass only)")
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = SHAPE_ORDER if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        tag = mesh_tag(mesh)
        suffix = "" if args.rules == "baseline" else f"__{args.rules}"
        if args.variant != "baseline":
            suffix += f"__{args.variant}"
        outdir = Path(args.out) / (tag + suffix)
        outdir.mkdir(parents=True, exist_ok=True)
        for arch, shape_id, ok, why in cells(archs, shapes):
            path = outdir / f"{arch}__{shape_id}.json"
            if path.exists() and not args.force:
                print(f"[skip cached] {tag} {arch} {shape_id}")
                continue
            if not ok:
                path.write_text(json.dumps(
                    {"arch": arch, "shape": shape_id, "mesh": tag,
                     "status": "skipped", "reason": why}, indent=1))
                print(f"[skip n/a]    {tag} {arch} {shape_id}: {why}")
                continue
            print(f"[cell] {tag} {arch} {shape_id} ...", flush=True)
            try:
                rec = run_cell(arch, shape_id, mesh, args.rules,
                               cost_pass=not args.no_cost,
                               full_pass=not args.no_full,
                               variant=args.variant)
                rec["status"] = "ok"
            except Exception as e:  # noqa: BLE001 — record, keep sweeping
                rec = {"arch": arch, "shape": shape_id, "mesh": tag,
                       "status": "error", "error": repr(e),
                       "traceback": traceback.format_exc()[-4000:]}
                failures.append((tag, arch, shape_id, repr(e)))
                print(f"  ERROR: {e!r}", flush=True)
            path.write_text(json.dumps(rec, indent=1))
            if rec.get("roofline"):
                r = rec["roofline"]
                print(f"  dominant={r['dominant']} step={r['step_time_s']:.4f}s "
                      f"mfu={r['mfu']:.3f} useful={r['useful_frac']:.2f}", flush=True)
            if rec.get("memory"):
                m = rec["memory"]
                hbm = (m["argument_bytes"] + m["output_bytes"] + m["temp_bytes"]
                       - m["alias_bytes"])
                # upcast parse can over-count (fusion aliases): clamp to temps
                adj = hbm - min(m.get("cpu_upcast_bytes", 0), m["temp_bytes"])
                print(f"  mem/device ~{hbm/2**30:.2f} GiB raw "
                      f"(args {m['argument_bytes']/2**30:.2f} + out "
                      f"{m['output_bytes']/2**30:.2f} + temp "
                      f"{m['temp_bytes']/2**30:.2f} - alias "
                      f"{m['alias_bytes']/2**30:.2f}); "
                      f"~{adj/2**30:.2f} GiB excl. CPU bf16->f32 copies",
                      flush=True)

    print(f"\n{len(failures)} failures")
    for f in failures:
        print("  FAIL:", *f)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
