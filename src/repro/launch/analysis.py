"""Compiled-artifact analysis: cost, memory, collective schedule, roofline.

The container is CPU-only, so the "profile" is the compiled HLO itself:

- ``compiled.cost_analysis()``  -> per-device HLO FLOPs / bytes accessed
- ``compiled.memory_analysis()``-> per-device argument/output/temp/peak bytes
- ``compiled.as_text()``        -> post-SPMD HLO; we parse every collective
  op's *per-device* operand bytes and classify it ICI (in-pod) vs DCN
  (crosses the pod axis, replica stride >= chips-per-pod).

Scan bodies appear once in HLO, so rolled-scan numbers undercount by the
trip count. The dry-run therefore lowers shallow (1- and 2-unit) configs
with all scans unrolled and extrapolates linearly over depth:
``f(U) = f1 + (f2 - f1) * (U - 1)`` — exact for depth-homogeneous stacks
(f1 = fixed + unit, f2 = fixed + 2*unit).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

from repro.launch.mesh import HardwareSpec, V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# one HLO op result, e.g.:  %all-gather.3 = bf16[16,512,128]{...} all-gather(
_OP_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s*"
    r"(all-reduce-start|all-gather-start|all-reduce|all-gather|reduce-scatter|"
    r"all-to-all|collective-permute-start|collective-permute)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}|replica_groups=\[")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_stride(line: str) -> int:
    """Smallest stride between consecutive ranks in the first replica group
    (1 = neighbours on the fastest mesh dim; >= chips/pod = crosses pods)."""
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if not m:
        return 1
    ranks = [int(x) for x in m.group(1).split(",") if x.strip()]
    if len(ranks) < 2:
        return 1
    return min(abs(b - a) for a, b in zip(ranks, ranks[1:]))


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_per_device: int
    stride: int
    count: int = 1
    f32: bool = False


def parse_collectives(hlo_text: str) -> List[CollectiveOp]:
    """Sum per-device operand bytes of every collective in post-SPMD HLO."""
    out: Dict[tuple, CollectiveOp] = {}
    for line in hlo_text.splitlines():
        if not any(k in line for k in _COLL_KINDS):
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, kind = m.groups()
        kind = kind.replace("-start", "")
        if tuple_body is not None:
            nbytes = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tuple_body))
            if kind in ("all-reduce", "collective-permute"):
                nbytes //= 2  # start-op tuples carry (operand, result) aliases
            f32 = "f32[" in tuple_body
        else:
            nbytes = _shape_bytes(dtype, dims)
            f32 = dtype == "f32"
        stride = _group_stride(line)
        key = (kind, nbytes, stride, f32)
        if key in out:
            out[key].count += 1
        else:
            out[key] = CollectiveOp(kind, nbytes, stride, f32=f32)
    return list(out.values())


def collective_bytes(ops: List[CollectiveOp], chips_per_pod: int = 256
                     ) -> Dict[str, float]:
    """Per-device collective bytes, split ICI/DCN.

    ``*_bf16eq`` halves fp32 ops: XLA:CPU upcasts every bf16 dot operand to
    f32 *before* the SPMD collectives (the model's large tensors are all
    bf16), so raw f32 collective bytes are ~2x what the TPU build moves.
    Genuinely-f32 reductions (scalars, norms stats) are negligible at these
    sizes. Raw numbers are kept alongside.
    """
    ici = dcn = ici_eq = dcn_eq = 0.0
    by_kind: Dict[str, float] = {}
    for op in ops:
        b = op.bytes_per_device * op.count
        beq = b * (0.5 if op.f32 else 1.0)
        by_kind[op.kind] = by_kind.get(op.kind, 0) + b
        if op.stride >= chips_per_pod:
            dcn += b
            dcn_eq += beq
        else:
            ici += b
            ici_eq += beq
    return {"ici": float(ici), "dcn": float(dcn), "by_kind": by_kind,
            "ici_bf16eq": float(ici_eq), "dcn_bf16eq": float(dcn_eq),
            "total": float(ici + dcn)}


_CONVERT_RE = re.compile(
    r"^\s*(?:ROOT )?%(wrapped_convert[\w.]*|convert[\w.]*) = (\w+)\[([\d,]*)\]"
    r"[^ ]* (?:fusion|convert)\(")


def cpu_upcast_bytes(hlo_text: str, min_bytes: int = 1 << 26) -> int:
    """XLA:CPU has no native bf16 dot — it converts operands to f32 and
    hoists the converted weight/KV-cache copies out of the layer loop. A TPU
    build keeps them bf16, so these buffers are pure CPU-backend overhead in
    the memory analysis. Sums large f32 convert results (deduped by name;
    fusion-ROOT converts are excluded — their buffer is the fusion op's)."""
    seen = set()
    total = 0
    for line in hlo_text.splitlines():
        m = _CONVERT_RE.match(line)
        if not m:
            continue
        name, dtype, dims = m.groups()
        if dtype != "f32" or name in seen:
            continue
        if line.lstrip().startswith("ROOT %convert"):
            continue  # fusion-internal ROOT: buffer owned by the fusion op
        b = _shape_bytes(dtype, dims)
        if b >= min_bytes:
            seen.add(name)
            total += b
    return total


# -------------------------------------------------------------- extraction
def cost_summary(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0))}


def memory_summary(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    get = lambda k: float(getattr(ma, k, 0) or 0)
    return {
        "argument_bytes": get("argument_size_in_bytes"),
        "output_bytes": get("output_size_in_bytes"),
        "temp_bytes": get("temp_size_in_bytes"),
        "generated_code_bytes": get("generated_code_size_in_bytes"),
        "alias_bytes": get("alias_size_in_bytes"),
    }


def extrapolate(f1: float, f2: float, units: int) -> float:
    """fixed + unit*U given samples at U=1 and U=2 (exact for linear)."""
    unit = f2 - f1
    fixed = f1 - unit
    return fixed + unit * units


# ---------------------------------------------------- analytic HBM model
def analytic_memory_bytes(cfg, shape, mesh_shape: Dict[str, int],
                          accum: int, kind: str, params_bytes: int,
                          cache_bytes_dev: float = 0.0,
                          remat: bool = True) -> float:
    """Per-device HBM traffic per step under TPU-like fusion (the CPU
    backend's `bytes accessed` is an unfusable upper bound — see
    EXPERIMENTS.md §Dry-run). Terms:

    - weights: FSDP re-gathers each layer per microbatch; every device
      reads the model-axis shard of the FULL weight set per pass
      (fwd + bwd + remat-recompute for train; once for prefill; the
      resident TP shard once per token for decode),
    - optimizer: m/v fp32 read+write, param read+write, grad read (train),
    - activations: K boundary tensors of (tokens_dev x d_model) x 2B per
      layer per pass (K~14 covers q/kv/mlp partials at their sharded
      widths, norms, residual r/w),
    - KV cache: decode reads the full per-device cache + writes one slot
      (masked-update writes the cache once more: 2x read-equivalent).
    """
    model_n = mesh_shape.get("model", 1)
    chips = 1
    for v in mesh_shape.values():
        chips *= v
    dp_n = chips // model_n

    L = cfg.num_layers
    d = cfg.d_model
    tokens = shape.global_batch * shape.seq_len

    if kind == "decode":
        w = params_bytes / model_n            # TP-resident, read once/token
        acts = 24 * L * (shape.global_batch / max(1, dp_n)) * d * 2
        return w + 2 * cache_bytes_dev + acts
    passes = (3 if remat else 2) if kind == "train" else 1
    w_gathered = params_bytes / model_n       # per device after FSDP gather
    weights = passes * accum * w_gathered
    if kind == "train":
        weights += 24 * params_bytes / 2 / chips  # opt: 24B/param, sharded
    tokens_dev = tokens / max(1, dp_n)
    acts = passes * 14 * L * tokens_dev * d * 2
    return weights + acts + cache_bytes_dev


# -------------------------------------------------------------- roofline
@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dcn_s: float
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float          # 6*N*D (active) — "useful" FLOPs, global
    chips: int

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s + self.dcn_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s + self.dcn_s)

    @property
    def mfu(self) -> float:
        """MODEL_FLOPS / (chips * peak * step_time) — roofline fraction."""
        denom = self.chips * V5E.peak_flops_bf16 * max(self.step_time_s, 1e-12)
        return self.model_flops / denom

    @property
    def useful_frac(self) -> float:
        hlo_global = self.flops_per_device * self.chips
        return self.model_flops / max(hlo_global, 1.0)


def roofline(flops_dev: float, bytes_dev: float, coll: Dict[str, float],
             model_flops: float, chips: int, hw: HardwareSpec = V5E) -> Roofline:
    return Roofline(
        compute_s=flops_dev / hw.peak_flops_bf16,
        memory_s=bytes_dev / hw.hbm_bw,
        collective_s=coll.get("ici", 0.0) / hw.ici_bw,
        dcn_s=coll.get("dcn", 0.0) / hw.dcn_bw,
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        coll_bytes_per_device=coll.get("total", 0.0),
        model_flops=model_flops,
        chips=chips,
    )
