"""Production mesh definitions (TPU v5e pods).

`make_production_mesh` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS for 512 host devices *before* any jax import; everything else
(smoke tests, benchmarks) sees the single real CPU device.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


# ------------------------------------------------------- hardware constants
@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """TPU v5e (the roofline constants from the task spec)."""
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_bw: float = 50e9                 # bytes/s per link (~per axis direction)
    dcn_bw: float = 25e9                 # bytes/s per host across pods
    hbm_bytes: int = 16 * 1024 ** 3      # 16 GiB HBM per chip


V5E = HardwareSpec()


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips per pod; 2 pods = 512 chips for the multi-pod pass."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=("data", "model")) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = jax.device_count()
    if shape is None:
        shape = (n, 1)
    devs = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def mesh_chips(mesh: jax.sharding.Mesh) -> int:
    return int(np.prod(list(mesh.shape.values())))


def mesh_tag(mesh: jax.sharding.Mesh) -> str:
    return "x".join(str(s) for s in mesh.shape.values())
