"""Step builders: one jit-able function per (arch x shape) cell.

- train_4k    -> ``train_step(params, opt, batch)``  (grad-accum scan + AdamW)
- prefill_32k -> ``prefill_step(params, batch)``     (forward + KV collection)
- decode_*    -> ``serve_step(params, cache, pos, token)`` (one token)

Each builder also produces the *abstract* argument tree (ShapeDtypeStruct +
NamedSharding) so the dry-run can ``jit(fn).lower(*abstract).compile()``
without allocating anything.

Batch layout: train batches arrive microbatched as ``(accum, mb, S)`` with
``mb`` sharded over the DP axes — every microbatch spans the full mesh, so
the grad-accumulation scan is local (no per-step resharding). The host data
pipeline (repro.data) delivers exactly this layout; that is the shuffle-
pushdown integration point (partitions are routed to their DP rank at the
storage layer, see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed import sharding as shd
from repro.distributed.constraints import activation_sharding, cs_like
from repro.models import api, flags
from repro.models import params as Pm
from repro.train import optimizer as opt_lib

# per-(arch, shape) grad-accumulation overrides (memory control; see
# EXPERIMENTS.md §Dry-run for the per-cell HBM numbers these were tuned on)
ACCUM_OVERRIDES: Dict[Tuple[str, str], int] = {
    ("deepseek-67b", "train_4k"): 16,
    ("llama4-scout-17b-a16e", "train_4k"): 16,
    ("qwen3-14b", "train_4k"): 8,
}

# ---------------------------------------------------------------- variants
# "baseline": the paper-faithful eager distribution.
# "opt": the §Perf hillclimb — lower grad-accum (FSDP weight gathers scale
#        with accum; HBM headroom allows it), selective remat (skip the
#        full-forward replay in backward), shard_map EP MoE (the in-mesh
#        shuffle-pushdown dispatch), expert-dim padding to the TP axis.
VARIANTS: Dict[str, Dict] = {
    "baseline": {},
    "opt": {
        "accum": {("deepseek-67b", "train_4k"): 2,
                  ("llama4-scout-17b-a16e", "train_4k"): 4,
                  ("qwen2-moe-a2.7b", "train_4k"): 4,
                  ("qwen3-14b", "train_4k"): 4,
                  ("qwen1.5-4b", "train_4k"): 4},
        "remat": "dots",
        "moe": "ep",
        "attn": "flat",
        # SP pays off only when the head count doesn't divide the TP axis
        # (otherwise `heads` wins `model` and the seq-sharded residual is
        # re-gathered every sublayer -- measured 4x collective blowup on
        # deepseek-67b, §Perf iter 2)
        "sp_archs": ("llama4-scout-17b-a16e",),
        "expert_pad": {"qwen2-moe-a2.7b": 4},
    },
}


def apply_variant(cfg: ModelConfig, variant: str) -> ModelConfig:
    import dataclasses as _dc
    pad = VARIANTS.get(variant, {}).get("expert_pad", {}).get(cfg.name, 0)
    return _dc.replace(cfg, expert_pad=pad) if pad else cfg


def accum_for(cfg: ModelConfig, shape: ShapeSpec,
              variant: str = "baseline") -> int:
    v = VARIANTS.get(variant, {}).get("accum", {})
    if (cfg.name, shape.name) in v:
        return v[(cfg.name, shape.name)]
    return ACCUM_OVERRIDES.get((cfg.name, shape.name), shape.accum)


@dataclasses.dataclass
class StepBundle:
    """Everything the dry-run / driver needs for one cell."""
    fn: Callable
    abstract_args: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...]
    out_shardings: Any
    meta: Dict[str, Any]

    def lower(self):
        return jax.jit(self.fn, donate_argnums=self.donate_argnums,
                       out_shardings=self.out_shardings).lower(*self.abstract_args)


# ---------------------------------------------------------------- helpers
def _named(mesh: Mesh, *spec) -> NamedSharding:
    return NamedSharding(mesh, P(*spec))


def _batch_abstract(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh, rules,
                    microbatched: bool, variant: str = "baseline"
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract input batch with DP sharding (+ optional accum leading dim)."""
    specs = api.input_specs(cfg, shape)
    bax = shd.batch_pspec(mesh, rules)
    dp = bax[0] if bax else None
    acc = accum_for(cfg, shape, variant)
    dp_n = _dp_size(mesh, rules)
    B = shape.global_batch
    # every microbatch must span the full DP axis (mb % dp == 0); larger DP
    # meshes proportionally lower the accumulation depth
    while acc > 1 and (B % acc or (B // acc) % dp_n):
        acc //= 2

    def mk(s: jax.ShapeDtypeStruct):
        if s.ndim == 0:
            return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=_named(mesh))
        shp, spec = s.shape, [dp] + [None] * (s.ndim - 1)
        if microbatched:
            assert shp[0] % acc == 0, (cfg.name, shape.name, shp, acc)
            shp = (acc, shp[0] // acc) + shp[1:]
            spec = [None] + spec
        return jax.ShapeDtypeStruct(shp, s.dtype, sharding=_named(mesh, *spec))

    return {k: mk(v) for k, v in specs.items()}


def _state_abstract(cfg: ModelConfig, mesh: Mesh, rules):
    pspecs = api.init_specs(cfg)
    params = shd.abstract(pspecs, mesh, rules)
    opt = jax.tree_util.tree_map(
        lambda x: x, opt_lib.init_specs(pspecs))  # OptState of ParamSpec
    opt_abs = opt_lib.OptState(
        m=shd.abstract(opt.m, mesh, rules),
        v=shd.abstract(opt.v, mesh, rules),
        step=jax.ShapeDtypeStruct((), jnp.int32, sharding=_named(mesh)))
    return params, opt_abs


def _shardings_of(tree):
    return jax.tree.map(lambda x: x.sharding, tree)


# ---------------------------------------------------------------- train
def make_train_step(cfg: ModelConfig, opt_cfg: Optional[opt_lib.AdamWConfig] = None,
                    remat=True, param_shardings=None, variant: str = "baseline"):
    opt_cfg = opt_cfg or opt_lib.AdamWConfig()
    v = VARIANTS.get(variant, {})
    remat = v.get("remat", remat)
    moe = v.get("moe", "dense")
    attn = v.get("attn", "grouped")

    def train_step(params, opt, batch):
        acc = next(iter(batch.values())).shape[0]

        def mb_loss(p, mb):
            with flags.moe_impl(moe), flags.attn_impl(attn):
                return api.loss_fn(p, cfg, mb, remat=remat)

        def pin(tree):  # keep grad accumulators in the params' layout
            if param_shardings is None:
                return tree
            return jax.tree.map(cs_like, tree, param_shardings)

        def body(gsum, mb):
            loss, g = jax.value_and_grad(mb_loss)(params, mb)
            gsum = pin(jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), gsum, g))
            return gsum, loss

        zeros = pin(jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        gsum, losses = flags.maybe_scan(body, zeros, batch)
        grads = jax.tree.map(lambda g: g / acc, gsum)
        params, opt, stats = opt_lib.apply(opt_cfg, params, opt, grads)
        metrics = {"loss": losses.mean(), **stats}
        return params, opt, metrics

    return train_step


def _with_act_ctx(fn, mesh, rules):
    def wrapped(*args):
        with activation_sharding(mesh, rules):
            return fn(*args)
    return wrapped


def build_train(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                rules=shd.BASELINE_RULES,
                opt_cfg: Optional[opt_lib.AdamWConfig] = None,
                variant: str = "baseline") -> StepBundle:
    cfg = apply_variant(cfg, variant)
    if cfg.name in VARIANTS.get(variant, {}).get("sp_archs", ()):
        rules = shd.SP_RULES
    params, opt = _state_abstract(cfg, mesh, rules)
    batch = _batch_abstract(cfg, shape, mesh, rules, microbatched=True,
                            variant=variant)
    fn = _with_act_ctx(
        make_train_step(cfg, opt_cfg, param_shardings=_shardings_of(params),
                        variant=variant),
        mesh, rules)
    out_sh = (_shardings_of(params), _shardings_of(opt),
              {"loss": _named(mesh), "grad_norm": _named(mesh), "lr": _named(mesh)})
    return StepBundle(fn, (params, opt, batch), donate_argnums=(0, 1),
                      out_shardings=out_sh,
                      meta={"kind": "train", "variant": variant,
                            "accum": accum_for(cfg, shape, variant)})


# ---------------------------------------------------------------- prefill
def _infer_out_shardings(out_shapes, mesh: Mesh, rules, B: int, S: int):
    """Heuristic shardings for the raw prefill outputs: the first dim equal
    to the global batch -> DP axes; the first long sequence dim -> `model`
    (SP). Applied leaf-wise over whatever cache layout the family emits."""
    bax = shd.batch_pspec(mesh, rules)
    dp = bax[0] if bax else None
    dp_n = _dp_size(mesh, rules)
    mdl_n = mesh.shape.get("model", 1)

    def one(leaf):
        spec = [None] * leaf.ndim
        used_b = used_s = False
        for i, d in enumerate(leaf.shape):
            if not used_b and d == B and dp is not None and d % dp_n == 0:
                spec[i] = dp
                used_b = True
            elif (not used_s and d == S and d >= 4096 and "model" in mesh.shape
                  and d % mdl_n == 0):
                spec[i] = "model"
                used_s = True
        return _named(mesh, *spec)

    return jax.tree.map(one, out_shapes)


def build_prefill(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                  rules=shd.BASELINE_RULES) -> StepBundle:
    pspecs = api.init_specs(cfg)
    params = shd.abstract(pspecs, mesh, rules)
    batch = _batch_abstract(cfg, shape, mesh, rules, microbatched=False)

    def prefill_step(params, batch):
        return api.prefill(params, cfg, batch, blockwise=True)

    prefill_step = _with_act_ctx(prefill_step, mesh, rules)
    out_shapes = jax.eval_shape(prefill_step, params, batch)
    out_sh = _infer_out_shardings(out_shapes, mesh, rules,
                                  shape.global_batch, shape.seq_len)
    return StepBundle(prefill_step, (params, batch), donate_argnums=(),
                      out_shardings=out_sh, meta={"kind": "prefill"})


# ---------------------------------------------------------------- decode
def build_decode(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
                 rules=shd.BASELINE_RULES) -> StepBundle:
    pspecs = api.init_specs(cfg)
    params = shd.abstract(pspecs, mesh, rules)
    cache = shd.abstract(
        api.cache_specs(cfg, shape.global_batch, shape.seq_len), mesh, rules)
    bax = shd.batch_pspec(mesh, rules)
    dp = bax[0] if bax else None
    B = shape.global_batch
    token = jax.ShapeDtypeStruct(
        (B, 1), jnp.int32,
        sharding=_named(mesh, dp if B % max(1, _dp_size(mesh, rules)) == 0 else None))
    pos = jax.ShapeDtypeStruct((), jnp.int32, sharding=_named(mesh))

    def serve_step(params, cache, pos, token):
        return api.decode_step(params, cfg, cache, pos, token)

    serve_step = _with_act_ctx(serve_step, mesh, rules)
    cache_sh = _shardings_of(cache)
    bdp = dp if B % max(1, _dp_size(mesh, rules)) == 0 else None
    lg = jax.eval_shape(serve_step, params, cache, pos, token)[0]
    vmdl = ("model" if "model" in mesh.shape
            and lg.shape[-1] % mesh.shape["model"] == 0 else None)
    logits_sh = _named(mesh, *([bdp] + [None] * (lg.ndim - 2) + [vmdl]))
    return StepBundle(serve_step, (params, cache, pos, token),
                      donate_argnums=(1,),
                      out_shardings=(logits_sh, cache_sh),
                      meta={"kind": "decode"})


def _dp_size(mesh: Mesh, rules) -> int:
    ax = shd.batch_axes(mesh, rules)
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    return n


# ---------------------------------------------------------------- dispatch
def default_rules(shape: ShapeSpec):
    """Training uses FSDP x TP; serving must not FSDP-gather weights per
    token, so decode defaults to the TP-only INFERENCE layout."""
    return shd.INFERENCE_RULES if shape.kind == "decode" else shd.BASELINE_RULES


def build(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh,
          rules=None, variant: str = "baseline") -> StepBundle:
    rules = rules if rules is not None else default_rules(shape)
    if shape.kind == "train":
        return build_train(cfg, shape, mesh, rules, variant=variant)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, rules)
    if shape.kind == "decode":
        return build_decode(cfg, shape, mesh, rules)
    raise ValueError(shape.kind)
