"""llava-next-mistral-7b — VLM, anyres tiling STUB [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
The anyres vision tower is a STUB: ``input_specs()`` provides precomputed
patch embeddings (B, num_patches, patch_dim) that a learned 2-layer projector
maps into the token stream (early fusion as a prefix).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1e6,
    num_patches=576,  # one anyres tile worth of CLIP patches
    patch_dim=1024,
)

REDUCED = ModelConfig(
    name="llava-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    num_patches=8,
    patch_dim=32,
)
