"""llama4-scout-17b-a16e — MoE 16e top-1, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E].

48L d_model=5120 40H (GQA kv=8) per-expert d_ff=8192 vocab=202048.
Interleaved chunked-local / global attention (iRoPE-style, 3 local : 1 global),
shared expert in every MoE layer. Chunked-local layers give bounded KV at 500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,  # shared expert hidden
    vocab_size=202048,
    num_experts=16,
    num_experts_per_tok=1,
    num_shared_experts=1,
    moe_d_ff=8192,
    attn_unit=("local", "local", "local", "global"),
    attn_chunk=8192,
    rope_theta=5e5,
    supports_long_context=True,
)

REDUCED = ModelConfig(
    name="llama4-scout-smoke",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    num_experts=4,
    num_experts_per_tok=1,
    num_shared_experts=1,
    moe_d_ff=128,
    attn_unit=("local", "local", "local", "global"),
    attn_chunk=64,
    supports_long_context=True,
)
