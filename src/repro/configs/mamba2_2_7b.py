"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

64L d_model=2560, attention-free, vocab=50280, ssm_state=128, headdim 64,
expand 2 => d_inner 5120, 80 SSD heads. O(1)/token decode state => long-ctx ok.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    conv_width=4,
    norm_type="rmsnorm",
    tie_embeddings=True,
    supports_long_context=True,
)

REDUCED = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=256,
    ssm_state=16,
    ssm_headdim=16,
    ssm_expand=2,
    ssm_chunk=32,
    conv_width=4,
    norm_type="rmsnorm",
    tie_embeddings=True,
    supports_long_context=True,
)
