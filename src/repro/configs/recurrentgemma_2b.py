"""recurrentgemma-2b — RG-LRU + local attention, 1 attn : 2 recurrent [arXiv:2402.19427].

26L d_model=2560 10H (MQA kv=1) d_ff=7680 vocab=256000, lru_width=2560,
local window 2048. Unit = (rec, rec, attn) x 8 + (rec, rec) tail.
Bounded state at any context => long-ctx ok.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    block_unit=("rec", "rec", "attn"),
    lru_width=2560,
    local_window=2048,
    conv_width=4,
    mlp_act="swiglu",
    tie_embeddings=True,
    supports_long_context=True,
)

REDUCED = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    num_layers=5,  # 1 unit (rec, rec, attn) + tail (rec, rec)
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=16,
    d_ff=192,
    vocab_size=256,
    block_unit=("rec", "rec", "attn"),
    lru_width=64,
    local_window=32,
    conv_width=4,
    tie_embeddings=True,
    supports_long_context=True,
)
