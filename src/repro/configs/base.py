"""Model + shape configuration for the assigned architecture pool.

Every architecture in the pool is expressed as a ``ModelConfig``. The full
configs (exact paper/hf dims) are exercised only via the AOT dry-run; smoke
tests use ``reduced()`` variants of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_type: str = "rmsnorm"  # rmsnorm | layernorm | layernorm_nonparam
    mlp_act: str = "swiglu"  # swiglu | gelu
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (d_ff is the shared/dense hidden)
    capacity_factor: float = 1.25
    # §Perf: pad the expert dim with never-routed dummies so it divides the
    # TP axis (e.g. qwen2-moe 60 -> 64 on a 16-wide mesh). 0 = no padding.
    expert_pad: int = 0

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4

    # hybrid (recurrentgemma): repeating unit of block kinds + tail
    block_unit: Tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    local_window: int = 0  # sliding-window size for local attention layers

    # llama4-style interleaved local(chunked)/global attention
    attn_unit: Tuple[str, ...] = ()  # e.g. ("local","local","local","global")
    attn_chunk: int = 0

    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    num_audio_frames: int = 1500

    # vlm stub frontend
    num_patches: int = 0
    patch_dim: int = 0

    # which shape cells run sub-quadratically at 500k ctx
    supports_long_context: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived ----
    @property
    def d_inner(self) -> int:  # ssm inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        from repro.models import api

        return api.count_params(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        from repro.models import api

        return api.count_params(self, active_only=True)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int
    # gradient-accumulation microbatches for train (memory control)
    accum: int = 1


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256, accum=8),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether a (arch, shape) cell runs; reason string if skipped."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, "full quadratic attention: 524k ctx skipped per spec (see DESIGN.md)"
    return True, ""
