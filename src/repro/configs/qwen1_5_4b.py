"""qwen1.5-4b — dense, QKV bias [hf:Qwen/Qwen1.5 family].

40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20,
    num_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=5e6,
)

REDUCED = ModelConfig(
    name="qwen1.5-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=192,
    vocab_size=256,
    qkv_bias=True,
)
