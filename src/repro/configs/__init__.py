"""Architecture registry: ``get_config(arch_id)`` / ``get_config(arch_id, reduced=True)``."""
from __future__ import annotations

import importlib

from repro.configs.base import SHAPE_ORDER, SHAPES, ModelConfig, ShapeSpec, shape_applicable

_MODULES = {
    "mamba2-2.7b": "mamba2_2_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen3-14b": "qwen3_14b",
    "qwen1.5-4b": "qwen1_5_4b",
    "deepseek-67b": "deepseek_67b",
    "olmo-1b": "olmo_1b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-small": "whisper_small",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
}

ARCH_IDS = list(_MODULES)


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.REDUCED if reduced else mod.CONFIG


def get_shape(shape_id: str) -> ShapeSpec:
    return SHAPES[shape_id]


__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "SHAPE_ORDER",
    "ModelConfig",
    "ShapeSpec",
    "get_config",
    "get_shape",
    "shape_applicable",
]
