"""whisper-small — enc-dec, conv frontend STUB [arXiv:2212.04356].

12L encoder + 12L decoder, d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
``input_specs()`` provides precomputed frame embeddings (B, 1500, 768); the
conv/mel frontend is a stub per the assignment. Learned absolute positions
in the reference model are replaced with RoPE on the decoder (TPU-friendly,
documented in DESIGN.md); encoder uses sinusoidal-free full attention.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    norm_type="layernorm",
    mlp_act="gelu",
    is_encoder_decoder=True,
    num_encoder_layers=12,
    num_audio_frames=1500,
)

REDUCED = ModelConfig(
    name="whisper-smoke",
    family="audio",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=256,
    norm_type="layernorm",
    mlp_act="gelu",
    is_encoder_decoder=True,
    num_encoder_layers=2,
    num_audio_frames=16,
)
