"""olmo-1b — dense, non-parametric LayerNorm [arXiv:2402.00838].

16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304, tied embeddings.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm_type="layernorm_nonparam",
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="olmo-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=192,
    vocab_size=256,
    norm_type="layernorm_nonparam",
    tie_embeddings=True,
)
