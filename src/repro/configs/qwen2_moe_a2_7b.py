"""qwen2-moe-a2.7b — 4 shared + 60 routed experts, top-4 [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16) routed d_ff=1408, shared expert hidden = 4*1408=5632,
vocab=151936.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=5632,  # shared-expert hidden (4 shared experts merged, 4*1408)
    vocab_size=151936,
    num_experts=60,
    num_experts_per_tok=4,
    num_shared_experts=4,
    moe_d_ff=1408,
    qkv_bias=True,
    rope_theta=1e6,
)

REDUCED = ModelConfig(
    name="qwen2-moe-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=192,
    vocab_size=256,
    num_experts=8,
    num_experts_per_tok=2,
    num_shared_experts=2,
    moe_d_ff=48,
    qkv_bias=True,
)
