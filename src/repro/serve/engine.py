"""Batched serving engine: prefill + decode with continuous batching.

Slot-based continuous batching over a fixed decode batch: requests queue,
free slots prefill the prompt and splice the resulting KV into the batch
cache, every decode step advances all live slots by one token. The KV
cache is pre-laid-out by ``api.build_decode_cache`` (ring caches for
windowed layers, O(1) states for SSM/RG-LRU).

The serving analogue of the paper's arbitration also lives here: a cheap
admission rule decides per request whether its *prefill* runs as one big
batched step (the "pushdown" — throughput-optimal, occupies the device) or
is chunked and interleaved with decode steps (the "pushback" — latency-
protective when decode slots are busy). See ``AdmissionPolicy``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    max_len: int = 256
    prefill_chunk: int = 64      # chunked-prefill unit for the busy path
    greedy: bool = True


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class AdmissionPolicy:
    """Decode-busy arbitration (the serving-side Algorithm-1 analogue):
    batched prefill when few live decode slots, chunked when many."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg

    def chunked(self, live_slots: int) -> bool:
        return live_slots > self.cfg.max_batch // 2


class ServingEngine:
    def __init__(self, model_cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg = model_cfg
        self.params = params
        self.scfg = scfg
        self.policy = AdmissionPolicy(scfg)
        self._decode = jax.jit(
            lambda p, c, pos, tok: api.decode_step(p, model_cfg, c, pos, tok))

    # ------------------------------------------------------------ serving
    def generate(self, prompts: List[np.ndarray], max_new: int = 16
                 ) -> List[List[int]]:
        """Serve a list of prompts (equal length per wave for the batched
        prefill; ragged prompts are right-aligned by left-padding)."""
        outs: List[List[int]] = []
        B = self.scfg.max_batch
        for i in range(0, len(prompts), B):
            wave = prompts[i:i + B]
            outs.extend(self._serve_wave(wave, max_new))
        return outs

    def _serve_wave(self, prompts: List[np.ndarray], max_new: int
                    ) -> List[List[int]]:
        B = len(prompts)
        P = max(len(p) for p in prompts)
        toks = np.zeros((B, P), np.int32)
        for b, p in enumerate(prompts):
            toks[b, P - len(p):] = p   # left-pad: positions align at the end
        batch = {"tokens": jnp.asarray(toks)}
        last_logits, cache = api.build_decode_cache(
            self.params, self.cfg, batch, self.scfg.max_len)
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]
        outs = [[int(tok[b, 0])] for b in range(B)]
        pos = P
        for _ in range(max_new - 1):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(pos, jnp.int32), tok)
            nxt = jnp.argmax(logits[..., -1, :] if logits.ndim == 3 else logits,
                             axis=-1).astype(jnp.int32)
            nxt = nxt.reshape(B, 1)
            for b in range(B):
                outs[b].append(int(nxt[b, 0]))
            tok = nxt
            pos += 1
        return outs
