"""Batched serving engine: prefill + decode with continuous batching.

Slot-based continuous batching over a fixed decode batch: requests queue,
free slots prefill the prompt and splice the resulting KV into the batch
cache, every decode step advances all live slots by one token. The KV
cache is pre-laid-out by ``api.build_decode_cache`` (ring caches for
windowed layers, O(1) states for SSM/RG-LRU).

The serving analogue of the paper's arbitration also lives here: a cheap
admission rule decides per wave whether its *prefill* runs as one big
batched step (the "pushdown" — throughput-optimal, occupies the device) or
is chunked and interleaved as single-token steps (the "pushback" —
latency-protective when many decode slots are about to go live). See
``AdmissionPolicy``. Both prefill paths produce the same next-token
logits for causal models — the chunk boundary only changes how the KV
cache fills, not what it holds (pinned by tests/test_serve.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import api


@dataclasses.dataclass
class ServeConfig:
    max_batch: int = 4
    max_len: int = 256
    prefill_chunk: int = 64      # chunked-prefill unit for the busy path
    greedy: bool = True


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (P,) int32
    max_new: int = 16            # per-request output budget (honored:
    #                              the slot stops accumulating — and flips
    #                              ``done`` — at exactly this many tokens)
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class AdmissionPolicy:
    """Decode-busy arbitration (the serving-side Algorithm-1 analogue):
    batched prefill when few decode slots are going live, chunked when
    many — a monolithic prefill monopolizes the device for its full
    prompt length, which is exactly when a big wave of live slots is
    about to need per-step latency."""

    def __init__(self, cfg: ServeConfig):
        self.cfg = cfg

    def chunked(self, live_slots: int) -> bool:
        return live_slots > self.cfg.max_batch // 2


class ServingEngine:
    def __init__(self, model_cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg = model_cfg
        self.params = params
        self.scfg = scfg
        self.policy = AdmissionPolicy(scfg)
        self.chunked_prefills = 0    # waves served via the chunked branch
        self._decode = jax.jit(
            lambda p, c, pos, tok: api.decode_step(p, model_cfg, c, pos, tok))

    # ------------------------------------------------------------ serving
    def generate(self, prompts: List[np.ndarray], max_new: int = 16
                 ) -> List[List[int]]:
        """Serve a list of prompts with a shared output budget. Sugar for
        :meth:`serve` over uniform ``Request``s."""
        reqs = [Request(rid=i, prompt=np.asarray(p, np.int32),
                        max_new=max_new)
                for i, p in enumerate(prompts)]
        self.serve(reqs)
        return [r.out_tokens for r in reqs]

    def serve(self, requests: List[Request]) -> List[Request]:
        """Serve requests in waves of ``max_batch`` slots, honoring each
        request's own ``max_new``: a slot stops accumulating (and its
        request flips ``done``) the moment its budget is reached, while
        the remaining live slots keep decoding; the wave ends when every
        slot is done. Ragged prompts are right-aligned by left-padding."""
        B = self.scfg.max_batch
        for i in range(0, len(requests), B):
            self._serve_wave(requests[i:i + B])
        return requests

    # ------------------------------------------------------------ prefill
    def _prefill(self, toks: np.ndarray, live_slots: int):
        """Batched or chunked prefill, per the admission policy. Returns
        ``(last_logits, cache)`` with ``last_logits`` shaped (B, V).

        The chunked branch builds the decode cache from the first
        ``prefill_chunk`` (left-padded) columns, then feeds the remaining
        prompt columns one position at a time through the jitted decode
        step — for causal models the final logits match the monolithic
        prefill (same tokens, same positions, KV filled incrementally),
        while the device is yielded between chunks instead of being held
        for the whole prompt."""
        B, P = toks.shape
        chunk = self.scfg.prefill_chunk
        use_chunked = self.policy.chunked(live_slots) and P > chunk
        first = toks if not use_chunked else toks[:, :chunk]
        last, cache = api.build_decode_cache(
            self.params, self.cfg, {"tokens": jnp.asarray(first)},
            self.scfg.max_len)
        if not use_chunked:
            return last, cache
        self.chunked_prefills += 1
        for pos in range(chunk, P):
            logits, cache = self._decode(
                self.params, cache, jnp.asarray(pos, jnp.int32),
                jnp.asarray(toks[:, pos:pos + 1]))
            last = logits[..., -1, :] if logits.ndim == 3 else logits
        return last, cache

    def _serve_wave(self, wave: List[Request]) -> None:
        B = len(wave)
        P = max(len(r.prompt) for r in wave)
        toks = np.zeros((B, P), np.int32)
        for b, r in enumerate(wave):
            toks[b, P - len(r.prompt):] = r.prompt  # left-pad: align ends
        last_logits, cache = self._prefill(toks, live_slots=B)
        tok = jnp.argmax(last_logits, axis=-1).astype(jnp.int32)[:, None]

        def emit(b: int, t: int) -> None:
            r = wave[b]
            if not r.done:
                r.out_tokens.append(t)
                if len(r.out_tokens) >= r.max_new:
                    r.done = True

        for b, r in enumerate(wave):
            if r.max_new <= 0:
                r.done = True
            else:
                emit(b, int(tok[b, 0]))
        pos = P
        while not all(r.done for r in wave):
            logits, cache = self._decode(self.params, cache,
                                         jnp.asarray(pos, jnp.int32), tok)
            nxt = jnp.argmax(logits[..., -1, :] if logits.ndim == 3
                             else logits, axis=-1).astype(jnp.int32)
            nxt = nxt.reshape(B, 1)
            for b in range(B):
                emit(b, int(nxt[b, 0]))
            tok = nxt
            pos += 1
