from repro.serve.engine import ServeConfig, ServingEngine  # noqa: F401
