"""Thread-safe engine metrics: counters, gauges, histograms, epoch snapshots.

The registry exposes the *live load signals* the future distributed
Arbitrator consumes (paper §3's adaptive mechanism reacts to storage-layer
load): per-node exec/ship queue depths and free compute cores are written
by ``run_stream`` every dispatch wave, request/byte totals by the engine,
filter-branch counts by the batch executor.

Design notes:

- One coarse lock per registry. Updates are a dict lookup + float add; at
  engine rates (a few hundred updates per query) contention is nil and
  the coarse lock keeps ``snapshot()`` consistent (no torn multi-metric
  reads).
- ``epoch()`` returns counter *deltas* since the previous epoch plus
  current gauge values and histogram summaries, then advances the epoch —
  the poll-style API a load balancer wants ("bytes shipped since I last
  looked"), without the writers ever resetting anything.
"""
from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "Metrics",
           "get_metrics", "set_metrics"]


class Counter:
    """Monotonically increasing total (thread-safe via the registry lock)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self.value = 0.0
        self._lock = lock

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins instantaneous value (e.g. queue depth, free cores)."""

    __slots__ = ("name", "value", "_lock")

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)


class Histogram:
    """Log2-bucketed distribution with exact count/sum/min/max.

    Buckets are powers of two: bucket ``i`` holds values in
    ``[2**(i-1), 2**i)`` (bucket 0 holds values < 1). Good enough
    resolution for latencies and byte sizes without per-observation
    allocation."""

    __slots__ = ("name", "count", "total", "vmin", "vmax", "buckets", "_lock")

    N_BUCKETS = 64

    def __init__(self, name: str, lock: threading.Lock):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.buckets = [0] * self.N_BUCKETS
        self._lock = lock

    def observe(self, v: float) -> None:
        v = float(v)
        b = 0 if v < 1.0 else min(self.N_BUCKETS - 1, int(v).bit_length())
        with self._lock:
            self.count += 1
            self.total += v
            if v < self.vmin:
                self.vmin = v
            if v > self.vmax:
                self.vmax = v
            self.buckets[b] += 1

    def summary(self) -> Dict:
        # caller holds the registry lock (or accepts a racy read)
        if self.count == 0:
            return {"count": 0, "sum": 0.0, "min": None, "max": None,
                    "mean": None}
        return {"count": self.count, "sum": self.total, "min": self.vmin,
                "max": self.vmax, "mean": self.total / self.count}


class Metrics:
    """Registry of named counters/gauges/histograms with epoch snapshots."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        self._epoch_base: Dict[str, float] = {}
        self._epoch_n = 0

    # --------------------------------------------------------- factories
    def counter(self, name: str) -> Counter:
        with self._lock:
            m = self._counters.get(name)
            if m is None:
                m = self._counters[name] = Counter(name, self._lock)
        return m

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            m = self._gauges.get(name)
            if m is None:
                m = self._gauges[name] = Gauge(name, self._lock)
        return m

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            m = self._histograms.get(name)
            if m is None:
                m = self._histograms[name] = Histogram(name, self._lock)
        return m

    # ----------------------------------------------------------- reads
    def snapshot(self) -> Dict:
        """Consistent point-in-time view of every metric (absolute values)."""
        with self._lock:
            return {
                "counters": {n: c.value for n, c in self._counters.items()},
                "gauges": {n: g.value for n, g in self._gauges.items()},
                "histograms": {n: h.summary()
                               for n, h in self._histograms.items()},
            }

    def epoch(self) -> Dict:
        """Counter deltas since the last ``epoch()`` call + current gauges
        and histogram summaries; advances the epoch marker."""
        with self._lock:
            self._epoch_n += 1
            deltas = {}
            for n, c in self._counters.items():
                deltas[n] = c.value - self._epoch_base.get(n, 0.0)
                self._epoch_base[n] = c.value
            return {
                "epoch": self._epoch_n,
                "counters": deltas,
                "gauges": {n: g.value for n, g in self._gauges.items()},
                "histograms": {n: h.summary()
                               for n, h in self._histograms.items()},
            }

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()
            self._epoch_base.clear()
            self._epoch_n = 0

    def names(self) -> List[str]:
        with self._lock:
            return sorted([*self._counters, *self._gauges, *self._histograms])


_metrics = Metrics()


def get_metrics() -> Metrics:
    """The process-wide default registry."""
    return _metrics


def set_metrics(metrics: Optional[Metrics]) -> Metrics:
    """Install a registry (None -> fresh one); returns the previous one."""
    global _metrics
    prev = _metrics
    _metrics = metrics if metrics is not None else Metrics()
    return prev
