"""Low-overhead per-query tracing: spans, decision channels, no-op default.

The engine is instrumented end-to-end — compile -> candidate-cut scoring ->
per-partition arbitration -> storage execute / pushback ship -> compute
replay -> merge — but tracing is OFF by default: every hook routes through
the module-level tracer, and the default :data:`NULL_TRACER` turns each
``tracer.span(...)`` / ``tracer.event(...)`` / ``tracer.start(...)`` call
into a constant-time no-op (a shared context manager yielding a shared
null span whose ``set()`` swallows everything). The benchmarked bound —
enforced by ``benchmarks.perf_guard`` over ``BENCH_engine.json`` — is
that even *enabled* tracing costs < 2% wall-clock on the sf=1
all-queries suite (``benchmarks.obs_overhead``).

Span parenting is thread-aware: within one thread, ``tracer.span(...)``
context managers nest via a thread-local stack; across thread boundaries
(the ``run_stream`` worker pools) the submitting code passes ``parent=``
explicitly — pool workers share no context, so implicit propagation would
silently mis-parent.

``DecisionChannel`` is the bounded, thread-safe event log that replaces
the old ``core.executor.FILTER_DECISIONS`` module global (which grew
unboundedly across runs and raced under the stream driver's pools): a
capped list behind a lock, with ``snapshot()``/``counts()`` readers. One
module-level channel records the batch executor's gather-vs-concat filter
decisions regardless of tracing (the benchmarks report them); each
``Tracer`` additionally owns an arbitration channel the Arbitrator feeds
live (queue depth and free slots at the moment each request is assigned a
path).
"""
from __future__ import annotations

import contextlib
import itertools
import threading
import time
from typing import Callable, Dict, Iterator, List, Optional

__all__ = [
    "Span", "Tracer", "DecisionChannel", "NULL_TRACER",
    "get_tracer", "set_tracer", "tracing",
    "record_filter_decision", "filter_decision_channel",
]


class Span:
    """One timed node of a query's span tree."""

    __slots__ = ("sid", "parent", "name", "cat", "t0", "dur", "tid", "attrs")

    def __init__(self, sid: int, parent: Optional[int], name: str, cat: str,
                 t0: float, tid: int, attrs: Dict):
        self.sid = sid
        self.parent = parent
        self.name = name
        self.cat = cat
        self.t0 = t0              # seconds since the tracer's epoch
        self.dur: Optional[float] = None   # seconds; None while open
        self.tid = tid
        self.attrs = attrs

    def set(self, **attrs) -> "Span":
        """Attach attributes (merging over earlier ones)."""
        self.attrs.update(attrs)
        return self

    def __bool__(self) -> bool:
        return True

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, sid={self.sid}, parent={self.parent}, "
                f"dur={self.dur}, attrs={self.attrs})")


class _NullSpan:
    """Falsy, attribute-swallowing stand-in used when tracing is off."""

    __slots__ = ()
    sid = -1
    parent = None
    name = ""
    cat = ""
    t0 = 0.0
    dur = 0.0
    tid = 0
    attrs: Dict = {}

    def set(self, **_attrs) -> "_NullSpan":
        return self

    def __bool__(self) -> bool:
        return False


NULL_SPAN = _NullSpan()


class _NullCM:
    """Reusable no-op context manager yielding the shared null span."""

    __slots__ = ()

    def __enter__(self) -> _NullSpan:
        return NULL_SPAN

    def __exit__(self, *exc) -> bool:
        return False


_NULL_CM = _NullCM()


class DecisionChannel:
    """Bounded, thread-safe decision log (append-only up to ``cap``).

    Replaces ad-hoc module-level lists: appends beyond the cap are counted
    (``dropped``) instead of growing memory. The hot path (``record``)
    leans on CPython's atomic ``list.append`` — no lock per decision, which
    matters at arbitration rates (hundreds of records per traced query);
    under a concurrent race at the exact cap boundary the channel may admit
    a few extra items (bounded by the number of racing threads), which is
    an acceptable trade for a memory *bound*. Readers and the dropped
    counter still serialize on the lock."""

    def __init__(self, cap: int = 8192):
        self.cap = cap
        self._items: List[Dict] = []
        self._dropped = 0
        self._lock = threading.Lock()

    def record(self, **fields) -> None:
        items = self._items
        if len(items) < self.cap:
            items.append(fields)        # atomic under the GIL
        else:
            with self._lock:
                self._dropped += 1

    def record_batch(self, assigned, **shared) -> None:
        """One compact entry for a batch of ``(req_id, path)`` decisions
        sharing the same load state (the Arbitrator drains whole batches
        under one queue/slot snapshot). The hot path appends a single
        tuple; readers expand to per-decision dicts lazily."""
        if not assigned:
            return
        items = self._items
        if len(items) < self.cap:
            items.append((tuple(assigned), shared))
        else:
            with self._lock:
                self._dropped += len(assigned)

    @staticmethod
    def _expand(entry) -> List[Dict]:
        if isinstance(entry, dict):
            return [dict(entry)]
        assigned, shared = entry
        return [dict(shared, req_id=rid, path=path)
                for rid, path in assigned]

    def snapshot(self) -> List[Dict]:
        """Copy of the recorded decisions (read-only view for callers)."""
        with self._lock:
            return [d for e in self._items for d in self._expand(e)]

    def counts(self, field: str) -> Dict:
        out: Dict = {}
        with self._lock:
            for e in self._items:
                for d in self._expand(e):
                    v = d.get(field)
                    out[v] = out.get(v, 0) + 1
        return out

    def clear(self) -> None:
        with self._lock:
            self._items.clear()
            self._dropped = 0

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._dropped

    def __len__(self) -> int:
        with self._lock:
            return sum(1 if isinstance(e, dict) else len(e[0])
                       for e in self._items)


class _SpanCM:
    """Hand-rolled span context manager — a generator-based
    ``@contextmanager`` costs ~4µs per use; at engine span rates that is
    the difference between fitting the <2% overhead bound and not."""

    __slots__ = ("_tr", "_name", "_cat", "_parent", "_attrs", "_sp",
                 "_stack")

    def __init__(self, tr: "Tracer", name: str, cat: str,
                 parent: Optional["Span"], attrs: Dict):
        self._tr = tr
        self._name = name
        self._cat = cat
        self._parent = parent
        self._attrs = attrs
        self._sp: Optional[Span] = None
        self._stack: Optional[List[Span]] = None

    def __enter__(self):
        sp = self._tr._new(self._name, self._cat, self._parent, self._attrs)
        if sp is None:
            return NULL_SPAN
        self._sp = sp
        stack = self._stack = self._tr._stack()
        stack.append(sp)
        return sp

    def __exit__(self, *exc) -> bool:
        sp = self._sp
        if sp is not None:
            sp.dur = time.perf_counter() - self._tr.t0 - sp.t0
            stack = self._stack
            if stack and stack[-1] is sp:
                stack.pop()
            elif sp in stack:          # mis-nested exit: drop just ours
                stack.remove(sp)
            sink = self._tr.sink
            if sink is not None:
                sink.on_end(sp)
        return False


class Tracer:
    """Collects a span forest for one (or several) traced runs.

    - ``span(name, ...)``: context manager; parents to the current
      thread's innermost open ``span(...)`` unless ``parent=`` is given.
    - ``start(name, ...)`` / ``end(span, ...)``: explicit pair for spans
      whose lifetime crosses threads (started by the submitter, ended by
      the finisher). Detached: never pushed on any thread-local stack.
    - ``event(name, ...)``: zero-duration span (instant).

    Span creation is lock-free: ids come from an atomic counter and
    ``list.append`` is atomic under the GIL, so the hot path pays no lock
    (a concurrent race at the exact ``max_spans`` boundary may admit a few
    extra spans — acceptable for a memory *bound*). ``max_spans`` keeps a
    runaway loop dropping spans rather than filling the heap.
    """

    enabled = True
    # optional streaming sink (e.g. ``obs.export.JsonlStreamWriter``):
    # ``on_start(span)`` fires the moment a span opens, ``on_end(span)``
    # when it closes — the crash-safe export path. Class-level None keeps
    # the sink-less hot path to a single attribute test per span.
    sink = None

    def __init__(self, max_spans: int = 1_000_000):
        self.t0 = time.perf_counter()
        self.max_spans = max_spans
        self.spans: List[Span] = []
        self.dropped = 0
        self.decisions = DecisionChannel()   # arbitration decision channel
        self._local = threading.local()
        self._sid = itertools.count()

    def attach_sink(self, sink) -> "Tracer":
        """Stream every span start/end to ``sink`` (None detaches)."""
        self.sink = sink
        return self

    # ------------------------------------------------------------ internals
    def _stack(self) -> List[Span]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _new(self, name: str, cat: str, parent: Optional[Span],
             attrs: Dict) -> Optional[Span]:
        pid = None
        if parent is not None:
            pid = parent.sid if parent.sid >= 0 else None
        else:
            stack = self._stack()
            if stack:
                pid = stack[-1].sid
        spans = self.spans
        if len(spans) >= self.max_spans:
            self.dropped += 1       # soft counter: benign race
            return None
        # slots assigned inline — skipping the __init__ frame is worth
        # a few hundred ns at engine span rates
        sp = Span.__new__(Span)
        sp.sid = next(self._sid)
        sp.parent = pid
        sp.name = name
        sp.cat = cat
        sp.dur = None
        sp.tid = threading.get_ident()
        sp.attrs = attrs
        sp.t0 = time.perf_counter() - self.t0
        spans.append(sp)            # atomic under the GIL
        sink = self.sink
        if sink is not None:
            sink.on_start(sp)
        return sp

    # ------------------------------------------------------------ public
    def span(self, name: str, cat: str = "engine",
             parent: Optional[Span] = None, **attrs) -> "_SpanCM":
        """Context manager for a same-thread span."""
        return _SpanCM(self, name, cat, parent, attrs)

    def start(self, name: str, cat: str = "engine",
              parent: Optional[Span] = None, **attrs) -> Span:
        """Open a detached span (close it with :meth:`end`, any thread)."""
        sp = self._new(name, cat, parent, attrs)
        return sp if sp is not None else NULL_SPAN

    def end(self, span: Span, **attrs) -> None:
        if span is NULL_SPAN or not isinstance(span, Span):
            return
        if attrs:
            span.attrs.update(attrs)
        span.dur = time.perf_counter() - self.t0 - span.t0
        sink = self.sink
        if sink is not None:
            sink.on_end(span)

    def event(self, name: str, cat: str = "engine",
              parent: Optional[Span] = None, **attrs) -> Span:
        sp = self._new(name, cat, parent, attrs)
        if sp is None:
            return NULL_SPAN
        sp.dur = 0.0
        sink = self.sink
        if sink is not None:
            sink.on_end(sp)
        return sp

    def amend(self, span: Span, **attrs) -> None:
        """Attach attrs to an already-closed span (accounting computed
        after the fact, e.g. ``shipped_bytes``), re-notifying a streaming
        sink so the crash-safe export carries them too — ``from_jsonl``
        merges the re-emitted end record over the first one."""
        if span is NULL_SPAN or not isinstance(span, Span):
            return
        span.attrs.update(attrs)
        sink = self.sink
        if sink is not None and span.dur is not None:
            sink.on_end(span)

    def current(self) -> Optional[Span]:
        stack = self._stack()
        return stack[-1] if stack else None

    # -------------------------------------------------------------- reads
    def snapshot(self) -> List[Span]:
        return list(self.spans)     # list copy is atomic under the GIL

    def find(self, name: str) -> List[Span]:
        return [s for s in self.snapshot() if s.name == name]

    def tree(self) -> List[Dict]:
        """The span forest as nested dicts (roots in creation order)."""
        spans = self.snapshot()
        nodes = {s.sid: {"name": s.name, "cat": s.cat, "t0": s.t0,
                         "dur": s.dur, "attrs": dict(s.attrs), "children": []}
                 for s in spans}
        roots: List[Dict] = []
        for s in spans:
            if s.parent is not None and s.parent in nodes:
                nodes[s.parent]["children"].append(nodes[s.sid])
            else:
                roots.append(nodes[s.sid])
        return roots


class _NullTracer(Tracer):
    """The disabled tracer: every hook is a constant-time no-op."""

    enabled = False

    def __init__(self):  # no state beyond a drop-everything channel
        self.t0 = 0.0
        self.max_spans = 0
        self.spans = []
        self.dropped = 0
        self.decisions = DecisionChannel(cap=0)

    def span(self, name, cat="engine", parent=None, **attrs):
        return _NULL_CM

    def start(self, name, cat="engine", parent=None, **attrs):
        return NULL_SPAN

    def end(self, span, **attrs):
        return None

    def amend(self, span, **attrs):
        return None

    def event(self, name, cat="engine", parent=None, **attrs):
        return NULL_SPAN

    def current(self):
        return None

    def snapshot(self):
        return []

    def tree(self):
        return []


NULL_TRACER = _NullTracer()

_tracer: Tracer = NULL_TRACER


def get_tracer() -> Tracer:
    """The process-wide tracer every engine hook routes through."""
    return _tracer


def set_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Install ``tracer`` (None -> disable); returns the previous one."""
    global _tracer
    prev = _tracer
    _tracer = tracer if tracer is not None else NULL_TRACER
    return prev


@contextlib.contextmanager
def tracing(tracer: Optional[Tracer] = None) -> Iterator[Tracer]:
    """Enable tracing for a block: ``with tracing() as tr: ...``."""
    tr = tracer if tracer is not None else Tracer()
    prev = set_tracer(tr)
    try:
        yield tr
    finally:
        set_tracer(prev)


# ----------------------------------------------- filter-decision channel
# The batch executor's gather-vs-concat branch choices. Recorded whether or
# not tracing is enabled (bounded + cheap; the benchmarks report the
# counts) — this channel is the replacement for the unbounded, racy
# ``core.executor.FILTER_DECISIONS`` module global.
_FILTER_CHANNEL = DecisionChannel(cap=8192)

# lazily bound to avoid importing metrics before it is needed
_metrics_hook: Optional[Callable[[str], None]] = None


def filter_decision_channel() -> DecisionChannel:
    return _FILTER_CHANNEL


def record_filter_decision(table: str, est_selectivity: Optional[float],
                           branch: str, n_parts: int, rows: int) -> None:
    """One batch filter-stage decision (called by ``executor._run_batch``)."""
    _FILTER_CHANNEL.record(table=table, est_selectivity=est_selectivity,
                           branch=branch, n_parts=n_parts, rows=rows)
    global _metrics_hook
    if _metrics_hook is None:
        from repro.obs.metrics import get_metrics
        _metrics_hook = lambda b: get_metrics().counter(
            f"executor.filter.{b}").inc()
    _metrics_hook(branch)
