"""Observability subsystem: tracing, metrics, exporters.

See ``docs/observability.md`` for the span taxonomy, metric names and
exporter usage. Quickstart::

    from repro import obs
    from repro.obs import export

    with obs.tracing() as tr:
        run = runtime.run_stream(stream, catalog, cfg)
    export.to_chrome_trace(tr, "stream.trace.json")   # chrome://tracing
    export.to_jsonl(tr, "stream.trace.jsonl")
    print(export.summary_table(tr))
"""
from repro.obs.trace import (
    DecisionChannel, NULL_TRACER, Span, Tracer,
    filter_decision_channel, get_tracer, record_filter_decision,
    set_tracer, tracing,
)
from repro.obs.metrics import (
    Counter, Gauge, Histogram, Metrics, get_metrics, set_metrics,
)
from repro.obs import export

__all__ = [
    "Span", "Tracer", "DecisionChannel", "NULL_TRACER",
    "get_tracer", "set_tracer", "tracing",
    "record_filter_decision", "filter_decision_channel",
    "Counter", "Gauge", "Histogram", "Metrics",
    "get_metrics", "set_metrics",
    "export",
]
