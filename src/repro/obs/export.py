"""Trace exporters: JSONL, Chrome ``trace_event`` (Perfetto), summary table.

- :func:`to_jsonl` / :func:`from_jsonl` — one span per line, lossless
  round-trip (``from_jsonl`` + :func:`build_tree` reproduce the tracer's
  own ``tree()``).
- :class:`JsonlStreamWriter` — the crash-safe variant: attached as a
  ``Tracer`` sink it streams a flushed ``span_start`` line the moment a
  span opens and a ``span_end`` line when it closes, so a process killed
  mid-run leaves a parseable trace prefix. :func:`from_jsonl` reads both
  formats, merges start/end pairs, keeps never-closed spans as open
  (``dur=None``), and ignores a torn final line.
- :func:`to_chrome_trace` — ``{"traceEvents": [...]}`` with complete
  ("X") events, microsecond timestamps, one Chrome "thread" per real
  Python thread; loadable in chrome://tracing or https://ui.perfetto.dev.
- :func:`summary_table` — terse per-query text table (duration, request
  split, real vs simulated net bytes, s_out estimate accuracy).

Span attributes may hold numpy scalars, tuples, and runtime dataclasses
(the hot path stores references — e.g. ``execute_split`` attaches its
``RequestOutcome`` list as-is rather than copying into JSON shapes, so
tracing never rebuilds data the engine already has); the single JSON
encoder here coerces them at export time (numpy -> Python scalars,
tuples -> lists, dataclasses -> dicts, anything else -> ``str``) so
every exporter stays dependency-free.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.obs.trace import Span, Tracer

__all__ = ["span_to_dict", "to_jsonl", "from_jsonl", "build_tree",
           "to_chrome_trace", "summary_table", "JsonlStreamWriter"]


def _coerce(obj):
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return dataclasses.asdict(obj)
    # numpy scalars expose .item(); arrays expose .tolist()
    item = getattr(obj, "item", None)
    if item is not None and getattr(obj, "ndim", 0) == 0:
        return item()
    tolist = getattr(obj, "tolist", None)
    if tolist is not None:
        return tolist()
    if isinstance(obj, (set, frozenset)):
        return sorted(obj)
    return str(obj)


def _dumps(obj) -> str:
    return json.dumps(obj, default=_coerce)


def span_to_dict(span: Span) -> Dict:
    return {"sid": span.sid, "parent": span.parent, "name": span.name,
            "cat": span.cat, "t0": span.t0, "dur": span.dur,
            "tid": span.tid, "attrs": span.attrs}


def _spans_of(source: Union[Tracer, Sequence[Span]]) -> List[Span]:
    if isinstance(source, Tracer):
        return source.snapshot()
    return list(source)


# ------------------------------------------------------------------ JSONL
def to_jsonl(source: Union[Tracer, Sequence[Span]], path,
             meta: Optional[Dict] = None) -> str:
    """Write one ``{"type": "meta"}`` header line then one span per line."""
    spans = _spans_of(source)
    with open(path, "w") as fh:
        header = {"type": "meta", "format": "repro-trace-v1",
                  "n_spans": len(spans)}
        if meta:
            header.update(meta)
        fh.write(_dumps(header) + "\n")
        for sp in spans:
            rec = span_to_dict(sp)
            rec["type"] = "span"
            fh.write(_dumps(rec) + "\n")
    return str(path)


class JsonlStreamWriter:
    """Crash-safe incremental trace export — a ``Tracer`` sink.

    ``tracer.attach_sink(JsonlStreamWriter(path))`` streams one flushed
    ``span_start`` line the instant each span opens and one ``span_end``
    line (final ``dur`` + attrs) when it closes. Because every line
    reaches the OS before the traced work proceeds, a process that dies
    mid-run — ``kill -9`` included — leaves a parseable trace: every
    span that had opened is present, spans that never closed read back
    open (``dur=None``), and :func:`from_jsonl` drops a torn final line
    instead of failing. ``fsync_per_line=True`` additionally survives an
    OS crash, at real I/O cost per span. Thread-safe; writes after
    ``close()`` are silently dropped (worker threads may still be
    finishing spans while the owner shuts the file)."""

    def __init__(self, path, meta: Optional[Dict] = None,
                 fsync_per_line: bool = False):
        self.path = str(path)
        self._fh = open(path, "w")
        self._lock = threading.Lock()
        self._fsync = fsync_per_line
        header = {"type": "meta", "format": "repro-trace-v1",
                  "streaming": True}
        if meta:
            header.update(meta)
        self._write(header)

    def _write(self, rec: Dict) -> None:
        line = _dumps(rec) + "\n"
        with self._lock:
            fh = self._fh
            if fh is None:
                return
            fh.write(line)
            fh.flush()
            if self._fsync:
                os.fsync(fh.fileno())

    # ---------------------------------------------------- Tracer sink API
    def on_start(self, span: Span) -> None:
        self._write({"type": "span_start", "sid": span.sid,
                     "parent": span.parent, "name": span.name,
                     "cat": span.cat, "t0": span.t0, "tid": span.tid,
                     "attrs": dict(span.attrs)})

    def on_end(self, span: Span) -> None:
        self._write({"type": "span_end", "sid": span.sid, "dur": span.dur,
                     "attrs": dict(span.attrs)})

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None

    def __enter__(self) -> "JsonlStreamWriter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def from_jsonl(path) -> Tuple[Dict, List[Dict]]:
    """Parse a JSONL trace back into ``(meta, span dicts)``.

    Reads both formats: batch ``span`` lines (:func:`to_jsonl`) and
    streamed ``span_start``/``span_end`` pairs (:class:`JsonlStreamWriter`)
    — pairs are merged, a start whose end never made it to disk stays an
    open span (``dur=None``), and an unparseable final line (the process
    died mid-write) ends the parse with the valid prefix kept."""
    meta: Dict = {}
    spans: List[Dict] = []
    by_sid: Dict[int, Dict] = {}
    with open(path) as fh:
        for line in fh:
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break  # torn tail — keep everything before it
            t = rec.get("type")
            if t == "meta":
                meta = rec
            elif t == "span":
                rec.pop("type")
                spans.append(rec)
            elif t == "span_start":
                rec.pop("type")
                rec["dur"] = None
                spans.append(rec)
                by_sid[rec["sid"]] = rec
            elif t == "span_end":
                sp = by_sid.get(rec["sid"])
                if sp is not None:
                    sp["dur"] = rec.get("dur")
                    sp["attrs"].update(rec.get("attrs") or {})
    return meta, spans


def build_tree(spans: Sequence[Dict]) -> List[Dict]:
    """Nest parsed span dicts into the same forest ``Tracer.tree()`` builds."""
    nodes = {s["sid"]: {"name": s["name"], "cat": s["cat"], "t0": s["t0"],
                        "dur": s["dur"], "attrs": dict(s["attrs"]),
                        "children": []}
             for s in spans}
    roots: List[Dict] = []
    for s in spans:
        pid = s.get("parent")
        if pid is not None and pid in nodes:
            nodes[pid]["children"].append(nodes[s["sid"]])
        else:
            roots.append(nodes[s["sid"]])
    return roots


# ----------------------------------------------------------- Chrome trace
def to_chrome_trace(source: Union[Tracer, Sequence[Span]], path,
                    meta: Optional[Dict] = None) -> str:
    """Write Chrome ``trace_event`` JSON (complete "X" events, ts/dur µs)."""
    spans = _spans_of(source)
    tids = {}
    events: List[Dict] = [{
        "ph": "M", "pid": 0, "tid": 0, "name": "process_name",
        "args": {"name": "repro-engine"},
    }]
    for sp in spans:
        tid = tids.setdefault(sp.tid, len(tids))
        events.append({
            "ph": "X",
            "pid": 0,
            "tid": tid,
            "name": sp.name,
            "cat": sp.cat,
            "ts": sp.t0 * 1e6,
            "dur": (sp.dur or 0.0) * 1e6,
            "args": sp.attrs,
        })
    doc = {"traceEvents": events, "displayTimeUnit": "ms",
           "otherData": meta or {}}
    with open(path, "w") as fh:
        fh.write(_dumps(doc))
    return str(path)


# ---------------------------------------------------------- summary table
def _fmt_bytes(n) -> str:
    if n is None:
        return "-"
    n = float(n)
    for unit in ("B", "KB", "MB", "GB"):
        if abs(n) < 1024 or unit == "GB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}GB"


def _render(rows: List[Tuple[str, ...]]) -> List[str]:
    widths = [max(len(r[i]) for r in rows) for i in range(len(rows[0]))]
    lines = ["  ".join(c.ljust(w) for c, w in zip(r, widths)).rstrip()
             for r in rows]
    lines.insert(1, "  ".join("-" * w for w in widths))
    return lines


def span_attribution(source: Union[Tracer, Sequence[Span]]
                     ) -> List[Dict]:
    """Per span-name timing attribution over a whole trace: wall time a
    span held (*total*) split into *self* time (the span's own work) and
    *child* time (wall covered by its direct sub-spans). Self-time is
    where an optimization lands — a span whose total is all child time is
    just an umbrella. Sorted by self-time, descending."""
    spans = _spans_of(source)
    child_by_parent: Dict[int, float] = {}
    for sp in spans:
        if sp.parent is not None:
            child_by_parent[sp.parent] = (child_by_parent.get(sp.parent, 0.0)
                                          + (sp.dur or 0.0))
    acc: Dict[Tuple[str, str], Dict] = {}
    for sp in spans:
        dur = sp.dur or 0.0
        child = min(dur, child_by_parent.get(sp.sid, 0.0))
        row = acc.setdefault((sp.name, sp.cat), {
            "name": sp.name, "cat": sp.cat, "count": 0,
            "total_s": 0.0, "self_s": 0.0, "child_s": 0.0})
        row["count"] += 1
        row["total_s"] += dur
        row["child_s"] += child
        row["self_s"] += dur - child
    return sorted(acc.values(), key=lambda r: -r["self_s"])


def summary_table(source: Union[Tracer, Sequence[Span]],
                  attribution: bool = True) -> str:
    """Per-query one-liners from the trace's ``query`` spans, followed by
    the span-level self-vs-child timing attribution (suppressed with
    ``attribution=False``)."""
    spans = _spans_of(source)
    rows = [("query", "ms", "pd", "pb", "net(real)", "net(sim)", "s_out r",
             "cache")]
    for sp in spans:
        if sp.name != "query":
            continue
        a = sp.attrs
        ratio = a.get("s_out_est_ratio")
        hits, n_pd = a.get("cache_hits"), a.get("n_pushdown")
        cache = "-"
        if isinstance(hits, int) and hits > 0:
            cache = (f"{hits}/{n_pd}" if isinstance(n_pd, int) and n_pd
                     else str(hits))
        rows.append((
            str(a.get("qid", "?")),
            f"{(sp.dur or 0.0) * 1e3:.1f}",
            str(a.get("n_pushdown", "-")),
            str(a.get("n_pushback", "-")),
            _fmt_bytes(a.get("real_net_bytes")),
            _fmt_bytes(a.get("sim_net_bytes")),
            f"{ratio:.2f}" if isinstance(ratio, float) else "-",
            cache,
        ))
    lines = _render(rows)
    if attribution:
        att = span_attribution(spans)
        if att:
            arows = [("span", "cat", "n", "total ms", "self ms", "child ms",
                      "self%")]
            for r in att:
                pct = (100.0 * r["self_s"] / r["total_s"]
                       if r["total_s"] > 0 else 0.0)
                arows.append((r["name"], r["cat"], str(r["count"]),
                              f"{r['total_s'] * 1e3:.1f}",
                              f"{r['self_s'] * 1e3:.1f}",
                              f"{r['child_s'] * 1e3:.1f}",
                              f"{pct:.0f}%"))
            lines += ["", *_render(arows)]
    return "\n".join(lines)
