"""Pushdown-amenability analysis (the paper's §4.1 principle, executable).

An operator is *pushdown-amenable* when a storage node can run it on its
own partition without coordination and without unbounded output:

- **partition-parallel** (local): ``op(concat(p1..pn))`` equals
  ``merge(op(p1)..op(pn))`` for a cheap merge — the operator distributes
  over the partitioning of its input table;
- **output-reducing** (bounded): the per-partition output is no larger than
  the input (selection, projection) or bounded by a constant (partial
  aggregation's group cap, top-k's K, the 1-bit/row selection bitmap).

Operators that align rows *across* partitions — joins, global sorts — fail
the first condition; opaque compute-layer code (``PyOp``) fails both by
construction. Partial aggregation and top-k pass with a *merge obligation*:
the compute layer must re-aggregate / re-select over the concatenated
partials (``partial=True`` below; the splitter emits the merge node).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

from repro.compiler import ir

# aggregation functions that decompose into per-partition partials + a merge
DECOMPOSABLE = {"sum": "sum", "count": "sum", "min": "min", "max": "max"}


@dataclasses.dataclass(frozen=True)
class Amenability:
    pushable: bool
    partial: bool          # pushable, but the residual must merge partials
    reason: str


def classify(node: ir.Node) -> Amenability:
    """Amenability of a single operator, by the §4.1 criteria."""
    if isinstance(node, (ir.Scan, ir.Merged)):
        return Amenability(True, False,
                           "scan is partition-parallel by definition")
    if isinstance(node, ir.Filter):
        return Amenability(True, False,
                           "selection is row-local and output-reducing")
    if isinstance(node, ir.Project):
        return Amenability(True, False,
                           "projection is row-local and output-reducing")
    if isinstance(node, ir.Map):
        return Amenability(True, False,
                           "scalar expressions are row-local; output adds "
                           "one bounded column per derive")
    if isinstance(node, ir.Aggregate):
        bad = sorted({fn for _, fn, _ in node.aggs if fn not in DECOMPOSABLE})
        if bad:
            return Amenability(False, False,
                               f"aggregate fns {bad} are not decomposable "
                               "into partials + merge")
        return Amenability(True, True,
                           "decomposable aggregate: bounded per-partition "
                           "partials, compute layer merges")
    if isinstance(node, ir.TopK):
        return Amenability(True, True,
                           "top-k: per-partition top-k (K-bounded) is a "
                           "superset of the global top-k; re-select at merge")
    if isinstance(node, ir.Shuffle):
        return Amenability(True, False,
                           "partition function is row-local and bounded "
                           "(log2 n bits/row); §4.2 shuffle pushdown")
    if isinstance(node, (ir.Join, ir.SemiJoin)):
        return Amenability(False, False,
                           "join aligns rows across partitions of two "
                           "tables — not partition-parallel")
    if isinstance(node, ir.Sort):
        return Amenability(False, False,
                           "global sort is a cross-partition total order "
                           "and is not output-reducing")
    if isinstance(node, ir.PyOp):
        return Amenability(False, False,
                           "opaque compute-layer code: no locality or "
                           "boundedness guarantees")
    raise TypeError(f"unknown IR node: {node!r}")


def analyze(root: ir.Node) -> List[Tuple[ir.Node, Amenability]]:
    """Per-node classification for a whole plan (preorder)."""
    return [(n, classify(n)) for n in ir.walk(root)]


def report(root: ir.Node) -> Dict[str, Dict[str, int]]:
    """Summary: node-type -> {pushable, partial, blocked} counts."""
    out: Dict[str, Dict[str, int]] = {}
    for node, am in analyze(root):
        row = out.setdefault(type(node).__name__,
                             {"pushable": 0, "partial": 0, "blocked": 0})
        if am.partial:
            row["partial"] += 1
        elif am.pushable:
            row["pushable"] += 1
        else:
            row["blocked"] += 1
    return out
