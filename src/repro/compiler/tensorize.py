"""TQP-style residual tensorization: whole residual IR -> fused jax.jit.

The residual interpreter (``compiler/interpreter.py``) walks IR nodes
per-operator in numpy. This module instead *lowers* a query's residual —
Filter / Project / Map / Aggregate / Join / SemiJoin / TopK / Sort /
Shuffle — into **one fused jax program per segment**, jit-compiled once
per input-shape bucket and reusable unchanged on CPU/GPU/TPU (Tensor
Query Processor's design, SNIPPETS.md snippet 1). The lowerings are
chosen for what XLA:CPU is actually good at — gathers, elementwise ops
and reductions — and against what it is bad at (single-threaded sorts,
scatters and ``top_k``), which a measurement pass on this machine showed
to be 3-5x slower than numpy at residual cardinalities:

========== ================================================================
IR node    tensor lowering
========== ================================================================
Filter     predicate closure (``expressions_jax.compile_expr_jnp``) ANDed
           into the validity mask — no gather, rows stay in place
Project    column-subset of the masked table (missing columns drop,
           mirroring the interpreter)
Map        derive lambdas written against numpy trace through a
           numpy-protocol shim (``__array_ufunc__``/``__array_function__``
           routed to jax.numpy), so ``np.maximum``/``np.isin``-style
           derives stay inside the jit instead of host round-trips
Aggregate  keyed: mixed-radix key codes over the *observed* per-key value
           bounds (see below) -> ``jax.ops.segment_sum``-family
           reductions, group compaction by cumsum+searchsorted — no sort
           anywhere; falls back to a lexsorted-key-encoding path when a
           key is non-integral or the code domain is too large.
           keyless: masked whole-column reductions
Join       build-host / probe-device: every right side is materialized
           host-side as a named build leaf, and a dense key LUT over its
           key domain is scattered in numpy (cheap) -> the in-trace join
           is a pure gather chain (many-to-one; duplicate right keys are
           detected on the host and replay the interpreter oracle).
           When LUT specialization is infeasible (non-integer keys, huge
           domain) the probe uses in-trace sort + ``searchsorted`` +
           gather with an in-program duplicate-key fallback flag
SemiJoin   LUT membership probe on the validity mask (anti negates);
           sorted-membership test when no LUT is available
TopK       ``jax.lax.top_k`` over ±inf-masked scores, static k
Sort       ``jnp.lexsort`` with an invalid-rows-last primary key;
           descending reverses the valid prefix (matches the
           interpreter's ``order[::-1]`` anti-stable tie behavior)
Shuffle    row-preserving no-op (redistribution marker)
PyOp       segmentation boundary: the residual partitions into maximal
           jittable segments around each PyOp, whose host function runs
           on materialized tables between segments
========== ================================================================

Leaf-adjacent {Filter, Project, Map, Shuffle} chains over Merged/Scan
leaves (and over already-materialized PyOp outputs) are *input
preparation*: they are evaluated host-side through the interpreter
(shared-memo per run, so DAG-shared chains evaluate once) before the
tensor program runs, exactly like the storage layer's pushdown stages
run before the residual. That keeps the padded row domain the device
program sees as small as the data actually is, and it is what makes the
join LUTs buildable on the host.

**Observe-first specialization.** The first ``execute`` of a residual
runs the instrumented interpreter oracle (whose result it returns) and
records, per keyed Aggregate, the per-key value bounds of its input, and
per Join/SemiJoin, the right side's key domain — the same measured-not-
assumed discipline as the executor's calibrated gather/concat crossover.
The jitted program bakes those bounds in; an in-trace guard flags any
later run whose keys leave the observed domain, which triggers a
re-observation and a re-specialized jit (bounds are unioned; capped at
``_RESPEC_CAP`` generations before the residual settles on the oracle).

Tables are represented as padded columns plus a validity mask: every
input is padded to a power-of-two row bucket, so repeated runs at
similar cardinalities reuse the compiled program (the jit cache is keyed
by ``(stage, generation, inputs, dtypes, buckets)`` — hit/miss
accounting is returned per run and surfaced in ``QueryRun``). All tensor
arithmetic runs under ``jax.experimental.enable_x64`` so results stay
comparable with the float64 numpy oracle; the interpreter remains that
oracle and ``tests/test_tensorize.py`` pins identity across all 15 TPC-H
residuals, every execution mode, and random decision vectors.

``core.runtime.run_residual`` dispatches between the two backends
(``EngineConfig.residual``); ``"auto"`` uses a calibrated merged-row
crossover (``calibrate_residual_threshold``), overridable via
``REPRO_RESIDUAL_THRESHOLD`` / ``REPRO_NO_CALIBRATE``.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.compiler import ir
from repro.obs import trace as obs_trace
from repro.obs.metrics import get_metrics
from repro.queryproc import expressions_jax as exj
from repro.queryproc.table import ColumnTable

_MIN_BUCKET = 16
_LUT_CAP = 1 << 23       # max dense key-LUT domain (32 MiB of int32-ish)
_AGG_DOM_CAP = 1 << 18   # max mixed-radix aggregate code domain
_RESPEC_CAP = 8          # re-specializations before settling on the oracle


class TensorFallback(Exception):
    """Raised when a lowering guard trips. ``respec=True`` marks guards an
    observation refresh can cure (keys left the observed domain);
    ``respec=False`` marks data shapes the lowering cannot express
    (duplicate right join keys: the tensor join is many-to-one). Either
    way ``execute`` replays the interpreter oracle for this run."""

    def __init__(self, msg: str = "", respec: bool = False):
        super().__init__(msg)
        self.respec = respec


def _x64():
    from jax.experimental import enable_x64
    return enable_x64()


class _MT:
    """Tracing-time masked table: padded columns + validity mask."""
    __slots__ = ("cols", "valid")

    def __init__(self, cols, valid):
        self.cols = cols
        self.valid = valid


def _unshim(v):
    return v.x if isinstance(v, _NpShim) else v


class _NpShim:
    """numpy-protocol adapter around a jax tracer: residual Map derives
    are written against numpy (``np.maximum``, ``np.isin``, operators,
    ``.astype``), and jax tracers in this jax version implement neither
    ``__array_ufunc__`` nor ``__array_function__`` — a raw trace dies
    with a TracerArrayConversionError. Wrapping the derive's inputs here
    reroutes both protocols (and the operator surface) to the
    ``jax.numpy`` twins, so the whole derive stays inside the jit.

    (The obvious alternative — ``jax.pure_callback`` — deadlocks on the
    CPU backend for large programs: the callback runs on an XLA
    execution thread and converting its device-put arguments back to
    numpy blocks on that same busy pool.)"""
    __slots__ = ("x",)
    __array_priority__ = 1000

    def __init__(self, x):
        self.x = x

    # ---- numpy dispatch protocols
    def __array_ufunc__(self, ufunc, method, *inputs, **kwargs):
        import jax.numpy as jnp
        if method != "__call__" or kwargs.get("out") is not None:
            return NotImplemented
        jf = getattr(jnp, ufunc.__name__, None)
        if jf is None:
            return NotImplemented
        kwargs.pop("out", None)
        return _NpShim(jf(*[_unshim(a) for a in inputs], **kwargs))

    def __array_function__(self, func, types, args, kwargs):
        import jax.numpy as jnp
        jf = getattr(jnp, func.__name__, None)
        if jf is None:
            return NotImplemented

        def conv(v):  # jnp rejects raw tuples/lists where numpy coerces
            v = _unshim(v)
            return jnp.asarray(np.asarray(v)) if isinstance(
                v, (tuple, list)) else v

        return _NpShim(jf(*[conv(a) for a in args],
                          **{k: conv(v) for k, v in kwargs.items()}))

    # ---- array-ish surface
    @property
    def dtype(self):
        return self.x.dtype

    @property
    def shape(self):
        return self.x.shape

    @property
    def ndim(self):
        return self.x.ndim

    def astype(self, dt):
        return _NpShim(self.x.astype(dt))

    def __neg__(self):
        return _NpShim(-self.x)

    def __invert__(self):
        return _NpShim(~self.x)


def _shim_binop(name: str, reflected: bool = False):
    import operator
    op = getattr(operator, name)

    def fwd(self, other):
        return _NpShim(op(self.x, _unshim(other)))

    def rev(self, other):
        return _NpShim(op(_unshim(other), self.x))

    return rev if reflected else fwd


for _nm in ("add", "sub", "mul", "truediv", "floordiv", "mod", "pow",
            "and_", "or_", "xor"):
    _dunder = _nm.rstrip("_")
    setattr(_NpShim, f"__{_dunder}__", _shim_binop(_nm))
    setattr(_NpShim, f"__r{_dunder}__", _shim_binop(_nm, reflected=True))
for _nm in ("lt", "le", "gt", "ge", "eq", "ne"):
    setattr(_NpShim, f"__{_nm}__", _shim_binop(_nm))


@dataclasses.dataclass
class _Stage:
    """One maximal jittable segment. ``jit_roots`` are lowered inside a
    single jit; host-resident roots are prepared by the interpreter;
    ``pyop`` (if any) then runs host-side on the materialized root tables
    and its output enters the environment as ``out_name``. ``names`` /
    ``luts`` (the stage's jit inputs) are filled post-observation by
    ``_build_jits``."""
    index: int
    roots: Tuple[ir.Node, ...]
    jit_roots: Tuple[ir.Node, ...]
    pyop: Optional[ir.PyOp]
    out_name: Optional[str]
    names: List[str] = dataclasses.field(default_factory=list)
    luts: List[Tuple[str, str, str, bool]] = dataclasses.field(
        default_factory=list)


@dataclasses.dataclass
class _Artifact:
    """Compile-once product for one residual object. ``obs`` (the
    observation-derived aggregate bounds and join modes) is None until
    the first execute; the jit fns are built from it and rebuilt on each
    re-specialization (``gen`` bumps, the shape cache clears)."""
    stages: List[_Stage]
    pyop_names: Dict[int, str]       # id(PyOp) -> env key
    leaf_names: Dict[int, str]       # id(host-resident node) -> env key
    prep_nodes: Dict[str, ir.Node]   # env key -> host-resident node
    preds: Dict[int, Callable]       # id(Filter) -> jnp predicate closure
    agg_nodes: List[ir.Aggregate]    # keyed aggregates (observation targets)
    jn_nodes: List[ir.Node]          # Join/SemiJoin nodes (mode targets)
    obs: Optional[Dict] = None       # {"agg": {id: spec}, "join": {id: mode}}
    jit_fns: List[Optional[Callable]] = dataclasses.field(
        default_factory=list)
    seen: set = dataclasses.field(default_factory=set)  # jit-cache keys
    gen: int = 0
    respecs: int = 0
    lock: threading.Lock = dataclasses.field(default_factory=threading.Lock)
    disabled: bool = False           # tracing failed / respec cap: oracle-only


@dataclasses.dataclass
class TensorRun:
    """One ``execute`` call's result + jit-cache accounting."""
    table: ColumnTable
    jit_hits: int = 0
    jit_misses: int = 0
    fell_back: bool = False
    observed: bool = False
    n_stages: int = 0


# ------------------------------------------------------------ compilation
def _postorder_pyops(node: ir.Node) -> List[ir.PyOp]:
    out: List[ir.PyOp] = []
    seen: set = set()

    def rec(n: ir.Node) -> None:
        if id(n) in seen:
            return
        seen.add(id(n))
        for c in n.inputs():
            rec(c)
        if isinstance(n, ir.PyOp):
            out.append(n)

    rec(node)
    return out


def _host_res(n: ir.Node, memo: Dict[int, bool]) -> bool:
    """Host-resident: materializable outside the jit — a leaf table, an
    already-executed PyOp output, or a {Filter,Project,Map,Shuffle}
    chain over one. These become prep units / LUT sources."""
    r = memo.get(id(n))
    if r is None:
        if isinstance(n, (ir.Merged, ir.Scan, ir.PyOp)):
            r = True
        elif isinstance(n, (ir.Filter, ir.Project, ir.Map, ir.Shuffle)):
            r = _host_res(n.child, memo)
        else:
            r = False
        memo[id(n)] = r
    return r


def _assign_leaves(residual: ir.Node, pyops: List[ir.PyOp],
                   pyop_names: Dict[int, str], hmemo: Dict[int, bool]
                   ) -> Tuple[Dict[int, str], Dict[str, ir.Node]]:
    """Name every maximal host-resident subtree the jit segments read:
    bare leaves keep their table name (so the shape-cache key is
    legible), prep chains get ``__prep{n}``, PyOp outputs their stage
    name. Traversal stops at a named subtree except to find embedded
    PyOps, whose children are earlier stages' roots."""
    leaf_names: Dict[int, str] = {}
    prep_nodes: Dict[str, ir.Node] = {}
    seen: set = set()
    ctr = 0

    def name_leaf(n: ir.Node) -> None:
        nonlocal ctr
        if id(n) in leaf_names:
            return
        if isinstance(n, (ir.Merged, ir.Scan)):
            nm = n.table
        elif isinstance(n, ir.PyOp):
            nm = pyop_names[id(n)]
        else:
            nm = f"__prep{ctr}"
            ctr += 1
        leaf_names[id(n)] = nm
        if not isinstance(n, ir.PyOp):
            prep_nodes[nm] = n

    def visit_pyops_under(n: ir.Node) -> None:
        for d in ir.walk(n):
            if isinstance(d, ir.PyOp):
                for c in d.children:
                    visit(c)

    def visit(n: ir.Node) -> None:
        if id(n) in seen:
            return
        seen.add(id(n))
        if _host_res(n, hmemo):
            name_leaf(n)
            visit_pyops_under(n)
            return
        if isinstance(n, (ir.Join, ir.SemiJoin)):
            # build side: always host-materialized (the interpreter builds
            # the small side, the device program probes it with gathers —
            # or the sorted fallback reads it as a padded leaf input)
            visit(n.left)
            name_leaf(n.right)
            visit_pyops_under(n.right)
            return
        for c in n.inputs():
            visit(c)

    visit(residual)
    for p in pyops:
        for c in p.children:
            visit(c)
    return leaf_names, prep_nodes


def compile_residual(residual: ir.Node) -> _Artifact:
    """Partition the residual into maximal jittable segments around its
    PyOps, name the host-resident leaves, and pre-compile the Filter
    predicates. Jit functions are built after the first observation run
    (``_build_jits``) because the aggregate/join lowerings specialize on
    observed key domains."""
    pyops = _postorder_pyops(residual)
    pyop_names = {id(p): f"__pyop{i}" for i, p in enumerate(pyops)}
    hmemo: Dict[int, bool] = {}
    leaf_names, prep_nodes = _assign_leaves(residual, pyops, pyop_names,
                                            hmemo)
    stages: List[_Stage] = []
    for p in pyops:
        roots = tuple(p.children)
        stages.append(_Stage(
            index=len(stages), roots=roots,
            jit_roots=tuple(r for r in roots if not _host_res(r, hmemo)),
            pyop=p, out_name=pyop_names[id(p)]))
    roots = (residual,)
    stages.append(_Stage(
        index=len(stages), roots=roots,
        jit_roots=tuple(r for r in roots if not _host_res(r, hmemo)),
        pyop=None, out_name=None))
    with _x64():
        preds = {id(n): exj.compile_expr_jnp(n.predicate)
                 for n in ir.walk(residual) if isinstance(n, ir.Filter)}
    agg_nodes = [n for n in ir.walk(residual)
                 if isinstance(n, ir.Aggregate) and n.keys]
    jn_nodes = [n for n in ir.walk(residual)
                if isinstance(n, (ir.Join, ir.SemiJoin))]
    return _Artifact(stages=stages, pyop_names=pyop_names,
                     leaf_names=leaf_names, prep_nodes=prep_nodes,
                     preds=preds, agg_nodes=agg_nodes, jn_nodes=jn_nodes)


# ------------------------------------------------------------ observation
def _observe(art: _Artifact, memo: Dict[int, ColumnTable]) -> None:
    """Specialize from an instrumented oracle run: per keyed Aggregate,
    the per-key (min, dim) bounds of its *input* (unioned with prior
    generations, so re-specialization only ever widens); per
    Join/SemiJoin, whether the right side supports a dense host LUT."""
    prev = art.obs or {"agg": {}, "join": {}}
    agg: Dict[int, Tuple] = dict(prev["agg"])
    join: Dict[int, Tuple] = {}
    for node in art.agg_nodes:
        spec = agg.get(id(node))
        if spec is not None and spec[0] == "lex":
            continue  # non-integral keys are sticky: stay on the sort path
        ct = memo.get(id(node.child))
        if ct is None:
            if spec is None:
                agg[id(node)] = ("code", (0,) * len(node.keys),
                                 (1,) * len(node.keys))
            continue
        cols = [np.asarray(ct.cols[k]) if k in ct.cols else None
                for k in node.keys]
        if any(c is None or c.dtype.kind not in "iub" for c in cols):
            agg[id(node)] = ("lex",)
            continue
        if len(ct) == 0:
            mins = [0] * len(cols)
            maxs = [0] * len(cols)
        else:
            mins = [int(c.min()) for c in cols]
            maxs = [int(c.max()) for c in cols]
        if spec is not None:
            mins = [min(a, b) for a, b in zip(mins, spec[1])]
            maxs = [max(mx, om + od - 1)
                    for mx, om, od in zip(maxs, spec[1], spec[2])]
        dims = [mx - mn + 1 for mn, mx in zip(mins, maxs)]
        dom = 1
        for d in dims:
            dom *= d
        agg[id(node)] = (("code", tuple(mins), tuple(dims))
                         if dom <= _AGG_DOM_CAP else ("lex",))
    for j, node in enumerate(art.jn_nodes):
        mode: Tuple = ("sorted",)
        rname = art.leaf_names.get(id(node.right))
        rt = memo.get(id(node.right))
        if rname is not None and rt is not None and node.rkey in rt.cols:
            rk = np.asarray(rt.cols[node.rkey])
            if rk.dtype.kind in "iub":
                dom = (1 if len(rk) == 0
                       else int(rk.max()) - int(rk.min()) + 1)
                if dom <= _LUT_CAP:
                    mode = ("lut", f"__lut{j}", rname)
        join[id(node)] = mode
    art.obs = {"agg": agg, "join": join}


def _stage_io(art: _Artifact, st: _Stage
              ) -> Tuple[List[str], List[Tuple[str, str, str, bool]]]:
    """Jit inputs for one stage: the host-resident leaf names its lowering
    will read, plus the LUT specs (name, right leaf, right key, is_join)
    to build on the host each run. Mirrors ``_lower_node``'s recursion —
    LUT semi-joins never read the right table, LUT joins read it only
    for the gathers."""
    names: List[str] = []
    luts: List[Tuple[str, str, str, bool]] = []
    seen: set = set()

    def add(nm: str) -> None:
        if nm not in names:
            names.append(nm)

    def rec(n: ir.Node) -> None:
        if id(n) in seen:
            return
        seen.add(id(n))
        nm = art.leaf_names.get(id(n))
        if nm is not None:
            add(nm)
            return
        if isinstance(n, (ir.Join, ir.SemiJoin)):
            mode = art.obs["join"][id(n)]
            if mode[0] == "lut":
                rec(n.left)
                _, jname, rname = mode
                luts.append((jname, rname, n.rkey, isinstance(n, ir.Join)))
                if isinstance(n, ir.Join):
                    add(rname)
                return
        for c in n.inputs():
            rec(c)

    for r in st.jit_roots:
        rec(r)
    return names, luts


def _build_jits(art: _Artifact) -> None:
    import jax
    fns: List[Optional[Callable]] = []
    for st in art.stages:
        st.names, st.luts = _stage_io(art, st)
        fns.append(jax.jit(_make_stage_fn(st, art)) if st.jit_roots
                   else None)
    art.jit_fns = fns
    art.seen = set()


def _make_stage_fn(stage: _Stage, art: _Artifact) -> Callable:
    def stage_fn(inputs):
        import jax.numpy as jnp
        ctx: Dict = {"memo": {}, "flags": [], "respec": [],
                     "inputs": inputs, "art": art}
        outs = []
        for root in stage.jit_roots:
            mt = _lower(root, ctx)
            outs.append({"cols": dict(mt.cols), "valid": mt.valid})
        flag = jnp.asarray(False)
        for f in ctx["flags"]:
            flag = flag | f
        resp = jnp.asarray(False)
        for f in ctx["respec"]:
            resp = resp | f
        return {"outs": outs, "fallback": flag, "respec": resp}

    return stage_fn


# --------------------------------------------------------------- lowering
def _lower(node: ir.Node, ctx: Dict) -> _MT:
    memo = ctx["memo"]
    if id(node) in memo:
        return memo[id(node)]
    out = _lower_node(node, ctx)
    memo[id(node)] = out
    return out


def _leaf(name: str, ctx: Dict) -> _MT:
    leaf = ctx["inputs"][name]
    return _MT(dict(leaf["cols"]), leaf["valid"])


def _lower_node(node: ir.Node, ctx: Dict) -> _MT:
    import jax.numpy as jnp

    nm = ctx["art"].leaf_names.get(id(node))
    if nm is not None:  # host-resident: prep chain / leaf / PyOp output
        return _leaf(nm, ctx)
    if isinstance(node, ir.Shuffle):  # redistribution marker: row-preserving
        return _lower(node.child, ctx)

    if isinstance(node, ir.Filter):
        t = _lower(node.child, ctx)
        mask = ctx["art"].preds[id(node)](t.cols)
        return _MT(t.cols, t.valid & mask)

    if isinstance(node, ir.Project):
        t = _lower(node.child, ctx)
        return _MT({c: t.cols[c] for c in node.columns if c in t.cols},
                   t.valid)

    if isinstance(node, ir.Map):
        t = _lower(node.child, ctx)
        cols = dict(t.cols)
        for name, incols, fn in node.derives:
            args = [_NpShim(cols[c]) for c in incols]
            cols[name] = jnp.asarray(_unshim(fn(*args)))
        return _MT(cols, t.valid)

    if isinstance(node, ir.Aggregate):
        return _lower_aggregate(node, _lower(node.child, ctx), ctx)
    if isinstance(node, ir.Join):
        return _lower_join(node, ctx)
    if isinstance(node, ir.SemiJoin):
        return _lower_semijoin(node, ctx)
    if isinstance(node, ir.TopK):
        return _lower_topk(node, _lower(node.child, ctx))
    if isinstance(node, ir.Sort):
        return _lower_sort(node, _lower(node.child, ctx))
    raise TypeError(f"unknown IR node: {node!r}")


def _minmax_sentinel(dtype, want_max: bool):
    import jax.numpy as jnp
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.inf if want_max else -jnp.inf
    info = jnp.iinfo(dtype)
    return info.max if want_max else info.min


def _lower_aggregate(node: ir.Aggregate, t: _MT, ctx: Dict) -> _MT:
    if not node.keys:
        return _agg_keyless(node, t)
    spec = ctx["art"].obs["agg"][id(node)]
    if spec[0] == "code":
        return _agg_code(node, t, spec, ctx)
    return _agg_lex(node, t)


def _agg_keyless(node: ir.Aggregate, t: _MT) -> _MT:
    import jax.numpy as jnp

    # keyless: one output row; the all-invalid (empty-input) case
    # selects 0, matching the interpreter's empty-table row
    n_valid = jnp.sum(t.valid)
    out = {}
    for name, fn, col in node.aggs:
        arr = t.cols[col] if col else next(iter(t.cols.values()))
        if fn == "count":
            v = n_valid.astype(jnp.int64)
        elif fn == "sum":
            v = jnp.sum(jnp.where(t.valid, arr, jnp.zeros((), arr.dtype)))
        elif fn == "mean":
            s = jnp.sum(jnp.where(t.valid, arr, 0).astype(jnp.float64))
            v = jnp.where(n_valid > 0, s / jnp.maximum(n_valid, 1), 0.0)
        else:
            sent = _minmax_sentinel(arr.dtype, want_max=(fn == "min"))
            red = jnp.min if fn == "min" else jnp.max
            v = red(jnp.where(t.valid, arr, sent))
            v = jnp.where(n_valid > 0, v, jnp.zeros((), v.dtype))
        out[name] = v[None]
    return _MT(out, jnp.ones((1,), bool))


def _agg_code(node: ir.Aggregate, t: _MT, spec: Tuple, ctx: Dict) -> _MT:
    """Sort-free grouped aggregation: each row's keys encode into one
    mixed-radix code over the observed per-key bounds, segment reductions
    run directly on the codes (ascending code order == the ascending
    lexicographic key order np.unique gives the interpreter), and group
    compaction is a cumsum + searchsorted over the code domain. Rows
    whose keys left the observed domain raise the in-trace respec flag;
    invalid rows park in the extra segment ``D``."""
    import jax
    import jax.numpy as jnp

    _, mins, dims = spec
    D = 1
    for d in dims:
        D *= d
    strides = []
    s = 1
    for d in reversed(dims):
        strides.append(s)
        s *= d
    strides = list(reversed(strides))

    oob = jnp.zeros(t.valid.shape, bool)
    code = jnp.zeros(t.valid.shape, jnp.int64)
    key_dtypes = []
    for k, mn, d, stp in zip(node.keys, mins, dims, strides):
        col = t.cols[k]
        key_dtypes.append(col.dtype)
        off = col.astype(jnp.int64) - mn
        oob = oob | (off < 0) | (off >= d)
        code = code + jnp.clip(off, 0, d - 1) * stp
    ctx["respec"].append(jnp.any(t.valid & oob))

    # Small domains lower to a one-hot contraction (XLA:CPU dots are
    # multi-threaded; its segment scatters are not). Large domains keep
    # the scatter — the N x D one-hot would not fit the cache anyway.
    n_rows = t.valid.shape[0]
    onehot = None
    if D <= 512 and n_rows * D <= (1 << 22):
        onehot = (code[:, None] == jnp.arange(D)[None, :]) & t.valid[:, None]
        onehot_f = onehot.astype(jnp.float64)
        cnt = jnp.sum(onehot, axis=0).astype(jnp.int64)
    else:
        gid = jnp.where(t.valid, code, D)
        cnt = jax.ops.segment_sum(t.valid.astype(jnp.int64), gid,
                                  num_segments=D + 1)[:D]
    present = cnt > 0
    n_groups = jnp.sum(present)
    ranks = jnp.cumsum(present.astype(jnp.int64))
    oc = jnp.clip(jnp.searchsorted(ranks, jnp.arange(1, D + 1)), 0, D - 1)
    out = {}
    for k, mn, d, stp, dt in zip(node.keys, mins, dims, strides, key_dtypes):
        out[k] = (mn + (oc // stp) % d).astype(dt)
    def gsum(vals):
        masked = jnp.where(t.valid, vals, 0).astype(jnp.float64)
        if onehot is not None:
            return masked @ onehot_f
        return jax.ops.segment_sum(masked, gid, num_segments=D + 1)[:D]

    def gminmax(vals, fn):
        sent = _minmax_sentinel(vals.dtype, want_max=(fn == "min"))
        if onehot is not None:
            red = jnp.min if fn == "min" else jnp.max
            return red(jnp.where(onehot, vals[:, None], sent), axis=0)
        red = jax.ops.segment_min if fn == "min" else jax.ops.segment_max
        return red(jnp.where(t.valid, vals, sent), gid,
                   num_segments=D + 1)[:D]

    for name, fn, col in node.aggs:
        if fn == "count":
            out[name] = cnt[oc]
        elif fn == "sum":
            out[name] = gsum(t.cols[col])[oc]
        elif fn == "mean":
            out[name] = (gsum(t.cols[col]) / jnp.maximum(cnt, 1))[oc]
        else:
            out[name] = gminmax(t.cols[col], fn)[oc]
    return _MT(out, jnp.arange(D) < n_groups)


def _agg_lex(node: ir.Aggregate, t: _MT) -> _MT:
    """General grouped aggregation for non-integral or huge-domain keys:
    lexsorted key encoding -> group-boundary flags -> segment reductions.
    Slower than ``_agg_code`` (XLA:CPU sorts are single-threaded) but
    makes no assumption about the key values."""
    import jax
    import jax.numpy as jnp

    n = t.valid.shape[0]
    key_arrs = [t.cols[k] for k in node.keys]
    # primary sort key pushes invalid rows last; groups are contiguous
    # runs of equal keys among the valid prefix (lexicographic ascending
    # — the exact group order np.unique gives the interpreter)
    inval = (~t.valid).astype(jnp.int32)
    order = jnp.lexsort(tuple(reversed(key_arrs)) + (inval,))
    vs = t.valid[order]
    ks = [a[order] for a in key_arrs]
    if n > 1:
        same = jnp.ones((n - 1,), bool)
        for a in ks:
            same = same & (a[1:] == a[:-1])
        changed = jnp.concatenate([jnp.ones((1,), bool), ~same])
    else:
        changed = jnp.ones((n,), bool)
    new_group = vs & changed
    n_groups = jnp.sum(new_group)
    # invalid rows park in segment n-1: they exist only when n_groups < n,
    # so the segment they pollute is always masked-out padding
    gid = jnp.where(vs, jnp.cumsum(new_group) - 1, n - 1)
    starts = jnp.clip(
        jax.ops.segment_min(jnp.arange(n), gid, num_segments=n), 0, n - 1)
    out = {k: a[starts] for k, a in zip(node.keys, ks)}
    for name, fn, col in node.aggs:
        if fn == "count":
            out[name] = jax.ops.segment_sum(vs.astype(jnp.int64), gid,
                                            num_segments=n)
            continue
        vals = t.cols[col][order]
        if fn == "sum":
            out[name] = jax.ops.segment_sum(
                jnp.where(vs, vals, 0).astype(jnp.float64), gid,
                num_segments=n)
        elif fn == "mean":
            sm = jax.ops.segment_sum(
                jnp.where(vs, vals, 0).astype(jnp.float64), gid,
                num_segments=n)
            c = jax.ops.segment_sum(vs.astype(jnp.int64), gid,
                                    num_segments=n)
            out[name] = sm / jnp.maximum(c, 1)
        elif fn == "min":
            out[name] = jax.ops.segment_min(vals, gid, num_segments=n)
        else:
            out[name] = jax.ops.segment_max(vals, gid, num_segments=n)
    return _MT(out, jnp.arange(n) < n_groups)


def _lut_probe(l: _MT, lkey: str, jname: str, ctx: Dict):
    """Probe a host-built dense key LUT: two gathers and a few compares —
    the whole join, as far as the device program is concerned."""
    import jax.numpy as jnp

    li = ctx["inputs"][jname]
    lut, kmin = li["lut"], li["kmin"]
    size = lut.shape[0]
    off = l.cols[lkey].astype(jnp.int64) - kmin
    inb = (off >= 0) & (off < size)
    ridx = lut[jnp.clip(off, 0, size - 1)]
    return l.valid & inb & (ridx >= 0), ridx


def _sorted_lookup(l: _MT, r: _MT, lkey: str, rkey: str):
    """General join/semi-join probe for non-LUT rights: sort the valid
    right keys (invalid -> +inf keeps the array fully sorted),
    searchsorted the left keys."""
    import jax.numpy as jnp

    n = r.valid.shape[0]
    rk = jnp.where(r.valid, r.cols[rkey].astype(jnp.float64), jnp.inf)
    order = jnp.argsort(rk)
    rs = rk[order]
    lk = l.cols[lkey].astype(jnp.float64)
    lo = jnp.clip(jnp.searchsorted(rs, lk), 0, n - 1)
    found = l.valid & (rs[lo] == lk)
    return order, rs, lo, found


def _lower_join(node: ir.Join, ctx: Dict) -> _MT:
    import jax.numpy as jnp

    l = _lower(node.left, ctx)
    mode = ctx["art"].obs["join"][id(node)]
    if mode[0] == "lut":
        _, jname, rname = mode
        found, ridx = _lut_probe(l, node.lkey, jname, ctx)
        r = ctx["inputs"][rname]
        safe = jnp.clip(ridx, 0, None)
        cols = dict(l.cols)
        for k, v in r["cols"].items():
            if k != node.rkey or node.lkey != node.rkey:
                cols[k if k not in cols else f"r_{k}"] = v[safe]
        return _MT(cols, found)
    r = _lower(node.right, ctx)
    order, rs, lo, found = _sorted_lookup(l, r, node.lkey, node.rkey)
    ridx = order[lo]
    cols = dict(l.cols)
    for k, v in r.cols.items():
        if k != node.rkey or node.lkey != node.rkey:
            cols[k if k not in cols else f"r_{k}"] = v[ridx]
    # m:1 guard: adjacent equal *valid* (finite) sorted keys mean a left
    # row could match several right rows — the host replays the oracle
    if rs.shape[0] > 1:
        ctx["flags"].append(
            jnp.any((rs[1:] == rs[:-1]) & jnp.isfinite(rs[:-1])))
    return _MT(cols, found)


def _lower_semijoin(node: ir.SemiJoin, ctx: Dict) -> _MT:
    l = _lower(node.left, ctx)
    mode = ctx["art"].obs["join"][id(node)]
    if mode[0] == "lut":
        found, _ = _lut_probe(l, node.lkey, mode[1], ctx)
    else:
        r = _lower(node.right, ctx)
        _, _, _, found = _sorted_lookup(l, r, node.lkey, node.rkey)
    mask = l.valid & ~found if node.anti else found
    return _MT(l.cols, mask)


def _lower_topk(node: ir.TopK, t: _MT) -> _MT:
    import jax
    import jax.numpy as jnp

    n = t.valid.shape[0]
    k = min(node.k, n)
    v = t.cols[node.col].astype(jnp.float64)
    scores = jnp.where(t.valid, -v if node.ascending else v, -jnp.inf)
    _, idx = jax.lax.top_k(scores, k)
    return _MT({c: a[idx] for c, a in t.cols.items()},
               jnp.arange(k) < jnp.minimum(k, jnp.sum(t.valid)))


def _lower_sort(node: ir.Sort, t: _MT) -> _MT:
    import jax.numpy as jnp

    n = t.valid.shape[0]
    inval = (~t.valid).astype(jnp.int32)
    order = jnp.lexsort(
        tuple(t.cols[c] for c in reversed(node.columns)) + (inval,))
    n_valid = jnp.sum(t.valid)
    if not node.ascending:
        # reverse only the valid prefix: identical tie order to the
        # interpreter's full-array order[::-1] on its (all-valid) rows
        i = jnp.arange(n)
        order = order[jnp.where(i < n_valid, n_valid - 1 - i, i)]
    return _MT({c: a[order] for c, a in t.cols.items()},
               jnp.arange(n) < n_valid)


# --------------------------------------------------------- host LUT build
def _build_lut(rt: ColumnTable, rkey: str, is_join: bool
               ) -> Tuple[np.ndarray, np.ndarray]:
    """Dense key -> right-row-index LUT over the right side's key domain
    (-1 = absent), built with numpy's (fast, parallel-enough) scatter.
    The length is pow2-bucketed so re-runs at similar domains reuse the
    jit; ``kmin`` rides along as a dynamic scalar input."""
    rk = np.asarray(rt.cols[rkey])
    if rk.dtype.kind not in "iub":
        raise TensorFallback("non-integral LUT join key")
    n = len(rk)
    if n == 0:
        return np.full(_MIN_BUCKET, -1, np.int64), np.asarray(0, np.int64)
    kmin = int(rk.min())
    dom = int(rk.max()) - kmin + 1
    if dom > _LUT_CAP:
        raise TensorFallback("LUT key domain left the observed cap",
                             respec=True)
    lut = np.full(_bucket(dom), -1, np.int64)
    offs = rk.astype(np.int64) - kmin
    lut[offs] = np.arange(n, dtype=np.int64)
    if is_join and int((lut >= 0).sum()) != n:
        raise TensorFallback("duplicate right join keys (m:n)")
    return lut, np.asarray(kmin, np.int64)


# ------------------------------------------------------- artifact caching
_ART_CACHE: "OrderedDict[int, Tuple[ir.Node, _Artifact]]" = OrderedDict()
_ART_CACHE_CAP = 128


def _artifact(residual: ir.Node) -> _Artifact:
    """Compile-once LRU keyed by residual identity (the node is retained,
    so its id cannot be reused while cached) — same discipline as
    ``executor.compile_push_plan`` and the interpreter's ``_PRED_CACHE``."""
    hit = _ART_CACHE.get(id(residual))
    if hit is not None and hit[0] is residual:
        _ART_CACHE.move_to_end(id(residual))
        return hit[1]
    tr = obs_trace.get_tracer()
    with tr.span("residual_compile", cat="compiler",
                 shape=ir.describe(residual)) as sp:
        t0 = time.perf_counter()
        art = compile_residual(residual)
        get_metrics().counter("residual.compiles").inc()
        if tr.enabled:
            sp.set(n_stages=len(art.stages),
                   compile_ms=round(1e3 * (time.perf_counter() - t0), 3))
    _ART_CACHE[id(residual)] = (residual, art)
    while len(_ART_CACHE) > _ART_CACHE_CAP:
        _ART_CACHE.popitem(last=False)
    return art


# -------------------------------------------------------------- execution
def _bucket(rows: int) -> int:
    b = _MIN_BUCKET
    while b < rows:
        b <<= 1
    return b


def _pad_table(tab: ColumnTable) -> Tuple[Dict, Tuple]:
    rows = len(tab)
    b = _bucket(rows)
    valid = np.zeros(b, bool)
    valid[:rows] = True
    cols = {}
    for c, a in tab.cols.items():
        if b == rows:
            cols[c] = a
        else:
            pad = np.zeros(b - rows, a.dtype)
            cols[c] = np.concatenate([a, pad])
    sig = (b,) + tuple(sorted((c, a.dtype.str) for c, a in tab.cols.items()))
    return {"cols": cols, "valid": valid}, sig


def _unpad(out: Dict) -> ColumnTable:
    mask = np.asarray(out["valid"])
    return ColumnTable({c: np.asarray(a)[mask]
                        for c, a in out["cols"].items()})


def _observe_run(art: _Artifact, residual: ir.Node,
                 merged: Dict[str, ColumnTable]) -> TensorRun:
    """First execute of a residual: run the instrumented oracle, record
    aggregate key bounds / join LUT feasibility from its memo, and build
    the specialized jit fns. The oracle's table is this run's result."""
    from repro.compiler import interpreter

    tr = obs_trace.get_tracer()
    with tr.span("residual_observe", cat="compiler") as sp:
        t0 = time.perf_counter()
        memo: Dict[int, ColumnTable] = {}
        result = interpreter._run(residual, merged, memo)
        _observe(art, memo)
        _build_jits(art)
        if tr.enabled:
            sp.set(n_stages=len(art.stages),
                   ms=round(1e3 * (time.perf_counter() - t0), 3))
    m = get_metrics()
    m.counter("residual.observes").inc()
    m.counter("residual.tensor.runs").inc()
    return TensorRun(table=result, observed=True, n_stages=len(art.stages))


def _respecialize(art: _Artifact, residual: ir.Node,
                  merged: Dict[str, ColumnTable]) -> ColumnTable:
    """An in-trace domain guard tripped: re-observe on the offending
    input (bounds union, so specialization only widens), rebuild the jit
    fns, bump the generation. Capped: a residual whose key domains never
    settle goes back to the oracle for good."""
    from repro.compiler import interpreter

    with art.lock:
        art.respecs += 1
        if art.respecs > _RESPEC_CAP:
            art.disabled = True
            return interpreter.run(residual, merged)
        memo: Dict[int, ColumnTable] = {}
        result = interpreter._run(residual, merged, memo)
        _observe(art, memo)
        _build_jits(art)
        art.gen += 1
        get_metrics().counter("residual.respecs").inc()
        return result


def execute(residual: ir.Node, merged: Dict[str, ColumnTable]) -> TensorRun:
    """Run a residual through the tensor backend. Results are identical to
    ``interpreter.run`` (the oracle); on a lowering-guard trip the oracle
    is replayed host-side and ``fell_back`` is set."""
    from repro.compiler import interpreter

    art = _artifact(residual)
    tr = obs_trace.get_tracer()
    m = get_metrics()
    if art.disabled:
        m.counter("residual.fallbacks").inc()
        return TensorRun(table=interpreter.run(residual, merged),
                         fell_back=True, n_stages=len(art.stages))
    if art.obs is None:
        with art.lock:
            if art.obs is None:
                return _observe_run(art, residual, merged)

    hits = misses = 0
    env: Dict[str, ColumnTable] = {}        # PyOp stage outputs
    imemo: Dict[int, ColumnTable] = {}      # shared host-prep memo
    host_tabs: Dict[str, ColumnTable] = {}
    result: Optional[ColumnTable] = None
    fell_back = False

    def host_tab(name: str) -> ColumnTable:
        t = env.get(name)
        if t is not None:
            return t
        t = host_tabs.get(name)
        if t is None:
            t = interpreter._run(art.prep_nodes[name], merged, imemo)
            host_tabs[name] = t
        return t

    try:
        with _x64():
            for st in art.stages:
                out_tabs: Dict[int, ColumnTable] = {}
                if st.jit_roots:
                    inputs: Dict = {}
                    key: Tuple = (st.index, art.gen)
                    for name in st.names:
                        inputs[name], sig = _pad_table(host_tab(name))
                        key += (name,) + sig
                    for jname, rname, rkey, is_join in st.luts:
                        lut, kmin = _build_lut(host_tab(rname), rkey,
                                               is_join)
                        inputs[jname] = {"lut": lut, "kmin": kmin}
                        key += (jname, lut.shape[0])
                    stage_hit = key in art.seen
                    if stage_hit:
                        hits += 1
                    else:
                        misses += 1
                        art.seen.add(key)
                    t0 = time.perf_counter()
                    out = art.jit_fns[st.index](inputs)
                    if bool(out["respec"]):
                        raise TensorFallback(
                            "aggregate keys left the observed domain",
                            respec=True)
                    if bool(out["fallback"]):
                        raise TensorFallback(f"stage {st.index}")
                    if tr.enabled:
                        tr.event("residual_jit_cache", cat="compiler",
                                 stage=st.index, hit=stage_hit,
                                 ms=round(1e3 * (time.perf_counter() - t0),
                                          3))
                    for root, o in zip(st.jit_roots, out["outs"]):
                        out_tabs[id(root)] = _unpad(o)
                if st.pyop is not None:
                    tables = [out_tabs[id(r)] if id(r) in out_tabs
                              else host_tab(art.leaf_names[id(r)])
                              for r in st.roots]
                    t = st.pyop.fn(*tables)
                    env[st.out_name] = t
                    imemo[id(st.pyop)] = t
                else:
                    r0 = st.roots[0]
                    result = (out_tabs[id(r0)] if id(r0) in out_tabs
                              else host_tab(art.leaf_names[id(r0)]))
    except TensorFallback as e:
        fell_back = True
        m.counter("residual.fallbacks").inc()
        if e.respec:
            result = _respecialize(art, residual, merged)
        if result is None:
            result = interpreter.run(residual, merged)
    except Exception:
        # lowering/tracing failed (e.g. a derive the shim cannot route):
        # the oracle still answers, and this residual stays on it
        art.disabled = True
        fell_back = True
        m.counter("residual.fallbacks").inc()
        m.counter("residual.errors").inc()
        result = interpreter.run(residual, merged)
    m.counter("residual.tensor.runs").inc()
    m.counter("residual.jit_cache.hits").inc(hits)
    m.counter("residual.jit_cache.misses").inc(misses)
    assert result is not None
    return TensorRun(table=result, jit_hits=hits, jit_misses=misses,
                     fell_back=fell_back, n_stages=len(art.stages))


def run(residual: ir.Node, merged: Dict[str, ColumnTable]) -> ColumnTable:
    """Interpreter-signature twin: evaluate and return just the table."""
    return execute(residual, merged).table


# ------------------------------------------------- auto-dispatch crossover
DEFAULT_RESIDUAL_THRESHOLD = 64_000  # merged rows; used when not calibrated
_AUTO_THRESHOLD: Optional[float] = None


def calibrate_residual_threshold(
        sizes: Tuple[int, ...] = (4_000, 16_000, 64_000),
        repeats: int = 3) -> float:
    """Measure the interpreter-vs-tensor crossover on a synthetic
    join+aggregate residual (the residual-dominant shape) and return the
    merged-row count above which the warm tensor backend wins on this
    machine. Scans sizes downward and stops at the first interpreter win,
    so a noisy tensor win at tiny sizes can never drag the threshold down
    below a size where the interpreter is actually faster."""
    from repro.compiler import interpreter

    rng = np.random.default_rng(0)
    f = ir.Merged("fact")
    d = ir.Merged("dim")
    residual = ir.Aggregate(ir.Join(f, d, "k", "k"), ("g",),
                            (("s", "sum", "v"), ("c", "count", "v")))
    n_dim = 512
    dim = ColumnTable({"k": np.arange(n_dim, dtype=np.int64),
                       "g": rng.integers(0, 32, n_dim).astype(np.int64)})

    def best_of(fn) -> float:
        fn()
        best = np.inf
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    lowest_tensor_win = None
    for size in sorted(sizes, reverse=True):
        fact = ColumnTable({
            "k": rng.integers(0, n_dim, size).astype(np.int64),
            "v": rng.uniform(0.0, 100.0, size)})
        merged = {"fact": fact, "dim": dim}
        execute(residual, merged)  # observe pass (returns the oracle)
        t_interp = best_of(lambda: interpreter.run(residual, merged))
        t_tensor = best_of(lambda: execute(residual, merged))
        if t_interp <= t_tensor:
            break
        lowest_tensor_win = size
    if lowest_tensor_win is None:
        return float("inf")  # tensor never won: auto stays on the oracle
    lower = max((s for s in sizes if s < lowest_tensor_win), default=None)
    return (float(lowest_tensor_win) if lower is None
            else float(np.sqrt(lowest_tensor_win * lower)))


def auto_threshold() -> float:
    """Lazy calibrated crossover for ``EngineConfig.residual="auto"`` —
    deferred to first use (unlike the filter-stage import-time
    calibration) because it jit-compiles a probe program."""
    global _AUTO_THRESHOLD
    if _AUTO_THRESHOLD is not None:
        return _AUTO_THRESHOLD
    env = os.environ.get("REPRO_RESIDUAL_THRESHOLD")
    if env:
        _AUTO_THRESHOLD = float(env)
    elif os.environ.get("REPRO_NO_CALIBRATE"):
        _AUTO_THRESHOLD = float(DEFAULT_RESIDUAL_THRESHOLD)
    else:
        try:
            _AUTO_THRESHOLD = calibrate_residual_threshold()
        except Exception:  # pragma: no cover - calibration is best-effort
            _AUTO_THRESHOLD = float(DEFAULT_RESIDUAL_THRESHOLD)
    return _AUTO_THRESHOLD
