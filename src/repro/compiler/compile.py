"""Compiler entry points: IR -> amenability split -> engine-ready Query.

``compile_query(qid)`` is the drop-in replacement for the seed's hand-built
``queries.build_query``: it builds the query's logical-plan IR, runs the
splitter, and packages the storage frontier (``PushPlan`` per table) plus a
generic residual interpreter as the ``Query`` the engine executes.

``fact_selectivity`` reproduces the seed's evaluation knob (Figs 13/14) at
the IR level: the fact table's pushable filters are replaced by
``l_quantity <= 50*sel`` before splitting, leaving derives/aggregates and
the residual untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.compiler import analyzer, interpreter, ir, pushability, splitter, tpch_ir
from repro.queryproc import expressions as ex
from repro.queryproc.expressions import Col
from repro.queryproc.queries import Query

QUERY_IDS: List[str] = list(tpch_ir.QUERY_IDS)


@dataclasses.dataclass
class CompiledQuery:
    """A compiled query plus everything the compilation derived."""
    qid: str
    root: ir.Node                       # logical plan as authored
    residual: ir.Node                   # compute-side remainder
    query: Query                        # engine-ready (plans + compute)
    amenability: List                   # [(node, Amenability)] for root
    # per-table stages the fused batch executor runs in one pass —
    # shuffle/bitmap-bearing frontiers are marked batchable here
    batchable: Dict[str, tuple] = dataclasses.field(default_factory=dict)

    @property
    def plans(self):
        return self.query.plans

    def frontier_signature(self, with_shuffle: bool = False) -> Dict[str, str]:
        return splitter.frontier_signature(
            self.query.plans,
            self.query.shuffle_keys if with_shuffle else None)

    def frontier_size(self) -> int:
        return splitter.frontier_size(self.query.plans)


def compile_ir(root: ir.Node, qid: str = "Q?") -> CompiledQuery:
    """Compile an arbitrary logical plan (not just the TPC-H registry)."""
    sp = splitter.split(root)
    residual = sp.residual
    q = Query(qid=qid.upper(), plans=sp.plans,
              compute=lambda merged: interpreter.run(residual, merged),
              shuffle_keys=sp.shuffle_keys)
    return CompiledQuery(qid.upper(), root, residual, q,
                         analyzer.analyze(root), batchable=sp.batchable)


def compile_query_detailed(qid: str,
                           fact_selectivity: Optional[float] = None
                           ) -> CompiledQuery:
    root = tpch_ir.build_ir(qid)
    if fact_selectivity is not None and "lineitem" in ir.base_tables(root):
        thresh = float(np.ceil(50 * fact_selectivity))
        root = substitute_fact_predicate(
            root, Col("l_quantity") <= thresh)
    return compile_ir(root, qid)


def compile_query(qid: str, fact_selectivity: Optional[float] = None) -> Query:
    """IR -> split -> engine-ready Query (the main entry point)."""
    return compile_query_detailed(qid, fact_selectivity).query


# ----------------------------------------------- fact-selectivity rewrite
def substitute_fact_predicate(root: ir.Node, pred: ex.Expr,
                              table: str = "lineitem") -> ir.Node:
    """Replace the fact table's *pushable* filters (base-column predicates
    on the unary chain above its Scan) with ``pred``; residual filters on
    derived columns (Q4's _late, Q12's _ontime) are preserved."""

    def rec(node: ir.Node, memo: Dict[int, ir.Node]) -> ir.Node:
        if id(node) in memo:
            return memo[id(node)]
        if isinstance(node, ir.Scan):
            out: ir.Node = ir.Filter(node, pred) if node.table == table \
                else node
        elif isinstance(node, ir.UNARY_TYPES):
            child = rec(node.child, memo)
            # the splitter's own absorption rule (compiler/pushability.py)
            # decides what counts as a pushable fact filter — one shared
            # predicate, so substitution and splitting cannot drift
            if (isinstance(node, ir.Filter)
                    and pushability.chain_scan_table(node) == table
                    and pushability.filter_absorbable(node)):
                out = child  # original pushable fact filter: dropped
            else:
                out = ir.rebuild_unary(node, child)
        elif isinstance(node, (ir.Join, ir.SemiJoin)):
            out = dataclasses.replace(node, left=rec(node.left, memo),
                                      right=rec(node.right, memo))
        elif isinstance(node, ir.PyOp):
            out = dataclasses.replace(node, children=tuple(
                rec(c, memo) for c in node.children))
        else:
            out = node
        memo[id(node)] = out
        return out

    return rec(root, {})


