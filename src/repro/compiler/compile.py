"""Compiler entry points: IR -> amenability split -> engine-ready Query.

``compile_query(qid)`` is the drop-in replacement for the seed's hand-built
``queries.build_query``: it builds the query's logical-plan IR, runs the
splitter, and packages the storage frontier (``PushPlan`` per table) plus a
generic residual interpreter as the ``Query`` the engine executes. It
always pushes the **maximal** amenable frontier.

``compile_query_costed(qid, catalog, ...)`` is the cost-based front door:
it enumerates every candidate cut point along each table's absorbable
chain (``splitter.split(cuts=...)``), scores each candidate with the §3.3
cost model over the catalog's real partitions (``core.cost.cut_score`` —
predicted storage CPU + result-ship time; the k=0 candidate IS the
raw-projection baseline), lowers sound multi-table predicates onto their
tables (``compiler.multitable``, conjunct pushdown or the §4.2
selection-bitmap exchange, whichever is cheaper), and picks the argmin
per table. An optional ``CardinalityCorrector`` rescales every
candidate's estimated ``s_out`` with measured-feedback ratios, so the
chosen cut converges toward observed truth across runs
(docs/compiler.md, docs/runtime.md).

``fact_selectivity`` reproduces the seed's evaluation knob (Figs 13/14) at
the IR level: the fact table's pushable filters are replaced by
``l_quantity <= 50*sel`` before splitting, leaving derives/aggregates and
the residual untouched.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.compiler import (analyzer, interpreter, ir, multitable,
                            pushability, splitter, tpch_ir)
from repro.core.cost import CardinalityCorrector, StorageResources, cut_score
from repro.core.plan import PushPlan, plan_signature
from repro.obs import trace as obs_trace
from repro.queryproc import expressions as ex
from repro.queryproc.expressions import Col
from repro.queryproc.queries import Query

QUERY_IDS: List[str] = list(tpch_ir.QUERY_IDS)


@dataclasses.dataclass
class CutChoice:
    """How the cost-based chooser cut one table's chain."""
    table: str
    chosen: int                      # absorbed-prefix length picked
    maximal: int                     # the maximal frontier's prefix length
    scores: Tuple[float, ...]        # per candidate k = 0..maximal
    signatures: Tuple[str, ...]      # per candidate frontier signature
    bitmap: bool = False             # §4.2 exchange lowered onto this table
    lowered: Optional[str] = None    # repr of the implied predicate, if any

    @property
    def differs(self) -> bool:
        return self.chosen != self.maximal or self.bitmap \
            or self.lowered is not None


@dataclasses.dataclass
class CompiledQuery:
    """A compiled query plus everything the compilation derived."""
    qid: str
    root: ir.Node                       # logical plan as authored
    residual: ir.Node                   # compute-side remainder
    query: Query                        # engine-ready (plans + compute)
    amenability: List                   # [(node, Amenability)] for root
    # per-table stages the fused batch executor runs in one pass —
    # shuffle/bitmap-bearing frontiers are marked batchable here
    batchable: Dict[str, tuple] = dataclasses.field(default_factory=dict)
    # the split itself (candidate-cut enumeration, chosen/maximal cuts)
    split: Optional[splitter.SplitResult] = None
    # cost-based compilation only: per-table chooser report
    cut_report: Optional[List[CutChoice]] = None

    @property
    def plans(self):
        return self.query.plans

    def frontier_signature(self, with_shuffle: bool = False) -> Dict[str, str]:
        return splitter.frontier_signature(
            self.query.plans,
            self.query.shuffle_keys if with_shuffle else None)

    def frontier_size(self) -> int:
        return splitter.frontier_size(self.query.plans)


def compile_ir(root: ir.Node, qid: str = "Q?",
               cuts: Optional[Dict[str, int]] = None,
               bitmap_tables: Optional[frozenset] = None,
               clustered: Optional[Dict[str, str]] = None) -> CompiledQuery:
    """Compile an arbitrary logical plan (not just the TPC-H registry).
    ``cuts``/``bitmap_tables`` force a specific frontier cut per table
    (see ``splitter.split``) — the property harness uses this to execute
    every enumerated candidate. ``clustered`` (table -> cluster key, from
    ``Catalog.clustered``) unlocks post-agg HAVING absorption."""
    sp = splitter.split(root, cuts=cuts, bitmap_tables=bitmap_tables,
                        clustered=clustered)
    residual = sp.residual
    q = Query(qid=qid.upper(), plans=sp.plans,
              compute=lambda merged: interpreter.run(residual, merged),
              shuffle_keys=sp.shuffle_keys, residual=residual)
    return CompiledQuery(qid.upper(), root, residual, q,
                         analyzer.analyze(root), batchable=sp.batchable,
                         split=sp)


def compile_query_detailed(qid: str,
                           fact_selectivity: Optional[float] = None
                           ) -> CompiledQuery:
    root = tpch_ir.build_ir(qid)
    if fact_selectivity is not None and "lineitem" in ir.base_tables(root):
        thresh = float(np.ceil(50 * fact_selectivity))
        root = substitute_fact_predicate(
            root, Col("l_quantity") <= thresh)
    return compile_ir(root, qid)


def compile_query(qid: str, fact_selectivity: Optional[float] = None) -> Query:
    """IR -> split -> engine-ready Query (the main entry point)."""
    return compile_query_detailed(qid, fact_selectivity).query


# ----------------------------------------------- cost-based cut selection
def _candidate_score(plan: PushPlan, table: str, catalog,
                     res: StorageResources,
                     corrector: Optional[CardinalityCorrector],
                     qid: str) -> float:
    """Predicted cost of pushing this candidate frontier: summed
    ``cut_score`` (storage CPU + result-ship time) over the table's real
    partitions, with the corrector's measured s_out ratio applied."""
    from repro.core.executor import compile_push_plan  # deferred: cycle-free
    cplan = compile_push_plan(plan)
    sig = plan_signature(plan)
    has_work = bool(plan.predicate is not None or plan.derive
                    or plan.agg is not None or plan.top_k is not None)
    total = 0.0
    for part in catalog.partitions_of(table):
        cost = cplan.estimate_cost(part)
        if corrector is not None:
            # exact-signature correction only: candidates of different
            # signatures compete, so measured ratios must not leak across
            cost = corrector.correct(qid, table, sig, cost, exact=True)
        total += cut_score(cost, res, has_work)
    return total


def compile_query_costed(qid: str, catalog,
                         res: Optional[StorageResources] = None,
                         corrector: Optional[CardinalityCorrector] = None,
                         fact_selectivity: Optional[float] = None,
                         multitable_lowering: bool = True,
                         compute_bw: float = multitable.DEFAULT_COMPUTE_BW
                         ) -> CompiledQuery:
    """Cost-based frontier selection: enumerate candidate cuts, score each
    against the catalog, lower sound multi-table predicates, pick the
    cheapest cut per table. Results are equivalent to ``compile_query``'s
    maximal frontier for every choice (the residual replays whatever was
    not pushed; tests/test_cost_split.py pins it), so this is purely a
    traffic/CPU optimization — the kind the corrector's online feedback is
    allowed to re-steer."""
    tr = obs_trace.get_tracer()
    with tr.span("compile", cat="compiler", qid=qid.upper(),
                 costed=True) as sp:
        cq = _compile_query_costed(qid, catalog, res, corrector,
                                   fact_selectivity, multitable_lowering,
                                   compute_bw)
        if tr.enabled:
            for ch in cq.cut_report or []:
                tr.event("cut_scoring", cat="compiler", table=ch.table,
                         chosen=ch.chosen, maximal=ch.maximal,
                         scores=list(ch.scores),
                         signatures=list(ch.signatures),
                         bitmap=ch.bitmap, lowered=ch.lowered)
            sp.set(n_tables=len(cq.cut_report or []),
                   frontier=cq.frontier_signature())
    return cq


def _compile_query_costed(qid: str, catalog,
                          res: Optional[StorageResources],
                          corrector: Optional[CardinalityCorrector],
                          fact_selectivity: Optional[float],
                          multitable_lowering: bool,
                          compute_bw: float) -> CompiledQuery:
    res = res if res is not None else StorageResources()
    root = tpch_ir.build_ir(qid)
    if fact_selectivity is not None and "lineitem" in ir.base_tables(root):
        thresh = float(np.ceil(50 * fact_selectivity))
        root = substitute_fact_predicate(root, Col("l_quantity") <= thresh)
    lowerings: List[multitable.Lowering] = []
    if multitable_lowering:
        root, lowerings = multitable.lower(root, catalog, res, compute_bw)
    lowered_by_table = {lw.table: lw for lw in lowerings}
    bitmap_tables = frozenset(t for t, lw in lowered_by_table.items()
                              if lw.bitmap)

    # catalog-proven group-locality (clustered tables) widens the candidate
    # set with post-agg HAVING frontiers; unclustered catalogs enumerate
    # exactly the seed candidates
    clustered = dict(getattr(catalog, "clustered", {}) or {})
    probe = splitter.split(root, clustered=clustered)  # maximal split
    cuts: Dict[str, int] = {}
    report: List[CutChoice] = []
    for table in sorted(probe.candidates):
        cands = probe.candidates[table]
        scored = []
        for plan in cands:
            if (table in bitmap_tables and plan.predicate is not None
                    and plan.agg is None and plan.top_k is None):
                plan = dataclasses.replace(plan, bitmap_only=True)
            scored.append((plan, _candidate_score(plan, table, catalog, res,
                                                  corrector, qid)))
        # ties prefer the deeper cut, so equal-cost data keeps the maximal
        # frontier (and the goldens stay put)
        best = min(range(len(scored)), key=lambda j: (scored[j][1], -j))
        cuts[table] = best
        lw = lowered_by_table.get(table)
        report.append(CutChoice(
            table=table, chosen=best, maximal=len(cands) - 1,
            scores=tuple(s for _, s in scored),
            signatures=tuple(plan_signature(p) for p, _ in scored),
            bitmap=table in bitmap_tables,
            lowered=repr(lw.predicate) if lw is not None else None))

    cq = compile_ir(root, qid, cuts=cuts, bitmap_tables=bitmap_tables,
                    clustered=clustered)
    cq.cut_report = report
    return cq


# ----------------------------------------------- fact-selectivity rewrite
def substitute_fact_predicate(root: ir.Node, pred: ex.Expr,
                              table: str = "lineitem") -> ir.Node:
    """Replace the fact table's *pushable* filters (base-column predicates
    on the unary chain above its Scan) with ``pred``; residual filters on
    derived columns (Q4's _late, Q12's _ontime) are preserved."""

    def rec(node: ir.Node, memo: Dict[int, ir.Node]) -> ir.Node:
        if id(node) in memo:
            return memo[id(node)]
        if isinstance(node, ir.Scan):
            out: ir.Node = ir.Filter(node, pred) if node.table == table \
                else node
        elif isinstance(node, ir.UNARY_TYPES):
            child = rec(node.child, memo)
            # the splitter's own absorption rule (compiler/pushability.py)
            # decides what counts as a pushable fact filter — one shared
            # predicate, so substitution and splitting cannot drift
            if (isinstance(node, ir.Filter)
                    and pushability.chain_scan_table(node) == table
                    and pushability.filter_absorbable(node)):
                out = child  # original pushable fact filter: dropped
            else:
                out = ir.rebuild_unary(node, child)
        elif isinstance(node, (ir.Join, ir.SemiJoin)):
            out = dataclasses.replace(node, left=rec(node.left, memo),
                                      right=rec(node.right, memo))
        elif isinstance(node, ir.PyOp):
            out = dataclasses.replace(node, children=tuple(
                rec(c, memo) for c in node.children))
        else:
            out = node
        memo[id(node)] = out
        return out

    return rec(root, {})


