"""Plan splitter: cut a query DAG into storage frontier + compute residual.

For every ``Scan``-rooted branch the splitter climbs the unary operator
chain and absorbs the **maximal pushdown-amenable prefix** (per
``analyzer.classify``) into a ``core.plan.PushPlan`` — respecting the
PushPlan stage order ``predicate -> derive -> (agg | project) -> top_k`` —
then rebuilds everything above the cut as a *residual* plan rooted at
``Merged(table)`` leaves. Absorbed partial operators leave their merge
obligation in the residual:

- partial ``Aggregate``  -> residual re-aggregates the partials
  (``sum/count -> sum``, ``min -> min``, ``max -> max``);
- partial ``TopK``       -> residual re-selects top-k over the concatenated
  per-partition top-k supersets.

``Shuffle`` markers anywhere on a branch are recorded as the branch's
redistribution key (``Query.shuffle_keys``, the Fig-15 evaluation) and
dropped from both sides — the partition function itself is amenable but its
execution path lives in ``core/shuffle.py``.

The cut is *per branch*, so one query can push a full filter+derive+partial
aggregation on the fact table while shipping a dimension table whole — and,
unlike the hand-built seed plans, dimension-side filters written at their
natural relational position (below the join) are pushed too: strictly
larger frontiers on Q5/Q8 (a whole new filter stage on ``nation``) and a
strictly stronger pushed predicate on Q22 (the nation-list conjunct joins
the balance filter in the same stage).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.compiler import analyzer, ir, pushability
from repro.core.plan import PushPlan, batchable_stages
from repro.queryproc import expressions as ex


class CompileError(ValueError):
    pass


@dataclasses.dataclass
class SplitResult:
    residual: ir.Node
    plans: Dict[str, PushPlan]
    shuffle_keys: Dict[str, str]
    # per-table stages the fused batch executor runs in one vectorized pass
    # (core.executor.batchable_stages) — shuffle/bitmap-bearing frontiers
    # included since the executor emits their aux products batched; the
    # engine and the shuffle/bitmap evaluations consult this instead of
    # assuming only scan->filter->agg chains batch
    batchable: Dict[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=dict)


def split(root: ir.Node) -> SplitResult:
    plans: Dict[str, PushPlan] = {}
    skeys: Dict[str, str] = {}
    residual = _rec(root, plans, skeys, {})
    batchable = {t: batchable_stages(p, skeys.get(t))
                 for t, p in plans.items()}
    return SplitResult(residual, plans, skeys, batchable)


# ------------------------------------------------------------------ walk
def _rec(node: ir.Node, plans: Dict[str, PushPlan], skeys: Dict[str, str],
         memo: Dict[int, ir.Node]) -> ir.Node:
    # id-keyed memo: shared subtrees (Q17 joins its own join output back)
    # split once and stay shared in the residual
    if id(node) in memo:
        return memo[id(node)]
    chain = _chain_to_scan(node)
    if chain is not None:
        out = _lower_chain(chain, plans, skeys)
    elif isinstance(node, (ir.Join, ir.SemiJoin)):
        out = dataclasses.replace(node,
                                  left=_rec(node.left, plans, skeys, memo),
                                  right=_rec(node.right, plans, skeys, memo))
    elif isinstance(node, ir.PyOp):
        out = dataclasses.replace(node, children=tuple(
            _rec(c, plans, skeys, memo) for c in node.children))
    elif isinstance(node, ir.UNARY_TYPES):
        out = ir.rebuild_unary(node, _rec(node.child, plans, skeys, memo))
    elif isinstance(node, ir.Merged):
        out = node
    else:
        raise CompileError(f"cannot split node {node!r}")
    memo[id(node)] = out
    return out


def _chain_to_scan(node: ir.Node) -> Optional[List[ir.Node]]:
    """[Scan, op1, op2, ...] when ``node`` heads a pure unary chain over a
    Scan leaf; None otherwise (the chain bottoms out at a join/PyOp)."""
    above: List[ir.Node] = []
    cur = node
    while isinstance(cur, ir.UNARY_TYPES):
        above.append(cur)
        cur = cur.child
    if isinstance(cur, ir.Scan):
        return [cur] + above[::-1]
    return None


# ----------------------------------------------------------------- lower
def _lower_chain(chain: List[ir.Node], plans: Dict[str, PushPlan],
                 skeys: Dict[str, str]) -> ir.Node:
    scan = chain[0]
    assert isinstance(scan, ir.Scan)
    table = scan.table
    if table in plans:
        raise CompileError(f"table {table!r} scanned more than once")

    ops_chain: List[ir.Node] = []
    for node in chain[1:]:
        if isinstance(node, ir.Shuffle):  # marker: record + drop
            skeys[table] = node.key
        else:
            ops_chain.append(node)

    pred: Optional[ex.Expr] = None
    derives: List[ir.DeriveSpec] = []
    out_derived: List[str] = []  # derives not (yet) pruned by a Project
    columns: Tuple[str, ...] = scan.columns
    agg: Optional[Tuple[Tuple[str, ...], Tuple[ir.AggSpec, ...]]] = None
    topk: Optional[Tuple[str, int, bool]] = None

    absorbed = 0
    for node in ops_chain:
        if not analyzer.classify(node).pushable:
            break
        if isinstance(node, ir.Filter):
            # the shared pushability rule (compiler/pushability.py): only
            # base-column predicates below any agg/top-k may be absorbed —
            # the same predicate substitute_fact_predicate uses, so the
            # two walks cannot drift
            if not pushability.filter_absorbable(node):
                break
            pred = (node.predicate if pred is None
                    else ex.And(pred, node.predicate))
        elif isinstance(node, ir.Map):
            if agg or topk:
                break
            derives.extend(node.derives)
            out_derived.extend(n for n, _, _ in node.derives)
        elif isinstance(node, ir.Project):
            if agg or topk:
                break
            # an explicit projection decides the output schema — derives
            # below it that it dropped must not be re-added
            columns = node.columns
            out_derived = []
        elif isinstance(node, ir.Aggregate):
            if agg or topk:
                break
            agg = (node.keys, node.aggs)
        elif isinstance(node, ir.TopK):
            # top-k over *partial* aggregates could drop the true winner;
            # only absorb when no aggregation was pushed below it
            if agg or topk:
                break
            topk = (node.col, node.k, node.ascending)
            # the ordering column must ship — both the storage-side select
            # and the residual re-select need it in the output schema
            if node.col not in columns and node.col not in out_derived:
                columns = tuple(columns) + (node.col,)
        else:
            break
        absorbed += 1

    if agg is not None:
        out_columns = tuple(agg[0])
    else:
        out_columns = tuple(columns) + tuple(
            n for n in out_derived if n not in columns)
    plans[table] = PushPlan(
        table, out_columns, predicate=pred, derive=tuple(derives),
        agg=(tuple(agg[0]), tuple(agg[1])) if agg is not None else None,
        top_k=topk)

    residual: ir.Node = ir.Merged(table)
    if agg is not None:
        keys, specs = agg
        merge = tuple((out, analyzer.DECOMPOSABLE[fn], out)
                      for out, fn, _ in specs)
        residual = ir.Aggregate(residual, tuple(keys), merge)
    if topk is not None:
        col, k, asc = topk
        residual = ir.TopK(residual, col, k, asc)
    for node in ops_chain[absorbed:]:
        residual = ir.rebuild_unary(node, residual)
    return residual


# ----------------------------------------------------- frontier reporting
_STAGES = ("filter", "derive", "agg", "topk")


def frontier_signature(plans: Dict[str, PushPlan],
                       shuffle_keys: Optional[Dict[str, str]] = None
                       ) -> Dict[str, str]:
    """Per-table signature of the pushed stages, e.g.
    {'lineitem': 'scan+filter+derive+agg', 'orders': 'scan'}. Passing the
    split's ``shuffle_keys`` marks shuffle-bearing frontiers
    (``...+shuffle``) — the batch executor runs the partition function in
    the same fused pass as the rest of the chain."""
    out = {}
    for table, p in sorted(plans.items()):
        stages = ["scan"]
        if p.predicate is not None:
            stages.append("filter")
        if p.bitmap_only:
            stages.append("bitmap")
        if p.derive:
            stages.append("derive")
        if p.agg is not None:
            stages.append("agg")
        if p.top_k is not None:
            stages.append("topk")
        if p.shuffle is not None or (shuffle_keys and table in shuffle_keys):
            stages.append("shuffle")
        out[table] = "+".join(stages)
    return out


def frontier_size(plans: Dict[str, PushPlan]) -> int:
    """Total pushed stages across tables — the partial order used to show
    a compiled frontier is *strictly larger* than a hand-built one."""
    return sum(sig.count("+") + 1
               for sig in frontier_signature(plans).values())
