"""Plan splitter: cut a query DAG into storage frontier + compute residual.

For every ``Scan``-rooted branch the splitter climbs the unary operator
chain and absorbs the **maximal pushdown-amenable prefix** (per
``analyzer.classify``) into a ``core.plan.PushPlan`` — respecting the
PushPlan stage order ``predicate -> derive -> (agg | project) -> top_k`` —
then rebuilds everything above the cut as a *residual* plan rooted at
``Merged(table)`` leaves. Absorbed partial operators leave their merge
obligation in the residual:

- partial ``Aggregate``  -> residual re-aggregates the partials
  (``sum/count -> sum``, ``min -> min``, ``max -> max``);
- partial ``TopK``       -> residual re-selects top-k over the concatenated
  per-partition top-k supersets.

``Shuffle`` markers anywhere on a branch are recorded as the branch's
redistribution key (``Query.shuffle_keys``, the Fig-15 evaluation) and
dropped from both sides — the partition function itself is amenable but its
execution path lives in ``core/shuffle.py``.

The cut is *per branch*, so one query can push a full filter+derive+partial
aggregation on the fact table while shipping a dimension table whole — and,
unlike the hand-built seed plans, dimension-side filters written at their
natural relational position (below the join) are pushed too: strictly
larger frontiers on Q5/Q8 (a whole new filter stage on ``nation``) and a
strictly stronger pushed predicate on Q22 (the nation-list conjunct joins
the balance filter in the same stage).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.compiler import analyzer, ir, pushability
from repro.core.plan import PushPlan, batchable_stages, plan_signature
from repro.queryproc import expressions as ex


class CompileError(ValueError):
    pass


@dataclasses.dataclass
class SplitResult:
    residual: ir.Node
    plans: Dict[str, PushPlan]
    shuffle_keys: Dict[str, str]
    # per-table stages the fused batch executor runs in one vectorized pass
    # (core.executor.batchable_stages) — shuffle/bitmap-bearing frontiers
    # included since the executor emits their aux products batched; the
    # engine and the shuffle/bitmap evaluations consult this instead of
    # assuming only scan->filter->agg chains batch
    batchable: Dict[str, Tuple[str, ...]] = dataclasses.field(
        default_factory=dict)
    # candidate-cut enumeration: per table, the PushPlan for every cut
    # point k = 0..max_cut along the absorbable chain prefix
    # (candidates[t][k]; candidates[t][max_cut[t]] is the maximal
    # frontier). ``cuts`` records where this split actually cut.
    candidates: Dict[str, List[PushPlan]] = dataclasses.field(
        default_factory=dict)
    cuts: Dict[str, int] = dataclasses.field(default_factory=dict)
    max_cut: Dict[str, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _SplitCtx:
    """State threaded through one split walk."""
    plans: Dict[str, PushPlan]
    skeys: Dict[str, str]
    cuts: Optional[Dict[str, int]]          # requested cut per table
    bitmap_tables: frozenset                # lower these to bitmap_only
    candidates: Dict[str, List[PushPlan]]
    chosen: Dict[str, int]
    max_cut: Dict[str, int]
    clustered: Dict[str, str]               # table -> cluster key (catalog
    #                                         group-locality proof; unlocks
    #                                         post-agg HAVING absorption)


def split(root: ir.Node, cuts: Optional[Dict[str, int]] = None,
          bitmap_tables: Optional[frozenset] = None,
          clustered: Optional[Dict[str, str]] = None) -> SplitResult:
    """Cut the plan into storage frontier + residual.

    By default every chain absorbs its **maximal** amenable prefix (the
    seed behavior, unchanged). ``cuts`` selects a shallower cut per table:
    ``cuts[table] = k`` absorbs only the first ``k`` absorbable operators
    (k = 0 is the raw-projection baseline — ship the accessed columns, the
    residual replays the whole chain). Any k is *correct* — the residual
    re-runs everything above the cut — which is what lets
    ``compile.compile_query_costed`` pick k by estimated cost, and the
    property harness (tests/test_cost_split.py) execute random cuts.

    ``bitmap_tables`` marks tables whose pushed predicate is lowered to
    the §4.2 selection-bitmap exchange (``PushPlan.bitmap_only``): the
    storage node ships the packed predicate-verdict bitmap alongside the
    filtered columns, so the compute side can combine verdicts with
    bitwise ops instead of re-evaluating its share of a multi-table
    predicate (see compiler/multitable.py). Only applied to frontiers
    without an absorbed aggregate/top-k.

    ``clustered`` maps table -> cluster key (``Catalog.clustered``): for
    those tables a Filter *above* an absorbed group-by whose keys include
    the cluster key may be absorbed too (storage-side HAVING over partial
    aggregates, Q18) — sound because group-locality makes each partial
    group final, so pruning partials prunes exactly the groups the
    residual filter would prune.
    """
    ctx = _SplitCtx({}, {}, cuts, frozenset(bitmap_tables or ()), {}, {}, {},
                    dict(clustered or {}))
    residual = _rec(root, ctx, {})
    if cuts:
        unknown = set(cuts) - set(ctx.plans)
        if unknown:
            raise CompileError(f"cuts for unscanned tables: {sorted(unknown)}")
    batchable = {t: batchable_stages(p, ctx.skeys.get(t))
                 for t, p in ctx.plans.items()}
    return SplitResult(residual, ctx.plans, ctx.skeys, batchable,
                       ctx.candidates, ctx.chosen, ctx.max_cut)


# ------------------------------------------------------------------ walk
def _rec(node: ir.Node, ctx: _SplitCtx, memo: Dict[int, ir.Node]) -> ir.Node:
    # id-keyed memo: shared subtrees (Q17 joins its own join output back)
    # split once and stay shared in the residual
    if id(node) in memo:
        return memo[id(node)]
    chain = _chain_to_scan(node)
    if chain is not None:
        out = _lower_chain(chain, ctx)
    elif isinstance(node, (ir.Join, ir.SemiJoin)):
        out = dataclasses.replace(node,
                                  left=_rec(node.left, ctx, memo),
                                  right=_rec(node.right, ctx, memo))
    elif isinstance(node, ir.PyOp):
        out = dataclasses.replace(node, children=tuple(
            _rec(c, ctx, memo) for c in node.children))
    elif isinstance(node, ir.UNARY_TYPES):
        out = ir.rebuild_unary(node, _rec(node.child, ctx, memo))
    elif isinstance(node, ir.Merged):
        out = node
    else:
        raise CompileError(f"cannot split node {node!r}")
    memo[id(node)] = out
    return out


def _chain_to_scan(node: ir.Node) -> Optional[List[ir.Node]]:
    """[Scan, op1, op2, ...] when ``node`` heads a pure unary chain over a
    Scan leaf; None otherwise (the chain bottoms out at a join/PyOp)."""
    above: List[ir.Node] = []
    cur = node
    while isinstance(cur, ir.UNARY_TYPES):
        above.append(cur)
        cur = cur.child
    if isinstance(cur, ir.Scan):
        return [cur] + above[::-1]
    return None


# ----------------------------------------------------------------- lower
@dataclasses.dataclass
class _ChainState:
    """Absorption state after the first k absorbable chain operators."""
    pred: Optional[ex.Expr] = None
    derives: Tuple[ir.DeriveSpec, ...] = ()
    out_derived: Tuple[str, ...] = ()  # derives not (yet) pruned by Project
    columns: Tuple[str, ...] = ()
    agg: Optional[Tuple[Tuple[str, ...], Tuple[ir.AggSpec, ...]]] = None
    topk: Optional[Tuple[str, int, bool]] = None
    having: Optional[ex.Expr] = None   # post-agg filter (clustered only)


def _absorption_states(scan: ir.Scan, ops_chain: List[ir.Node],
                       cluster_key: Optional[str] = None
                       ) -> List[_ChainState]:
    """One state per cut point k = 0..M along the absorbable prefix.

    The step rules are the seed's absorption loop, with one addition: on
    clustered tables a Filter above an absorbed Aggregate may absorb as a
    HAVING stage. The invariant the enumeration leans on is therefore
    relaxed from "an absorbed Aggregate/TopK is always last" to "after an
    absorbed Aggregate only HAVING Filters may follow" — a shallow cut
    below the agg still never needs partial-merge obligations, and a cut
    between agg and having replays the Filter over the merged partials
    (a no-op on survivors under group-locality)."""
    states = [_ChainState(columns=scan.columns)]
    st = states[0]
    for node in ops_chain:
        if not analyzer.classify(node).pushable:
            break
        if isinstance(node, ir.Filter):
            if st.agg is not None or st.topk is not None:
                # post-agg filter: HAVING absorption. Sound only when the
                # catalog proves group-locality (cluster key is one of the
                # group keys) and the predicate reads only the partial
                # aggregate's output schema (keys + agg outputs).
                if (st.agg is not None and st.topk is None
                        and cluster_key is not None
                        and cluster_key in st.agg[0]
                        and ex.columns_of(node.predicate)
                        <= set(st.agg[0]) | {o for o, _, _ in st.agg[1]}):
                    st = dataclasses.replace(
                        st, having=(node.predicate if st.having is None
                                    else ex.And(st.having, node.predicate)))
                    states.append(st)
                    continue
                break
            # the shared pushability rule (compiler/pushability.py): only
            # base-column predicates below any agg/top-k may be absorbed —
            # the same predicate substitute_fact_predicate uses, so the
            # two walks cannot drift
            if not pushability.filter_absorbable(node):
                break
            st = dataclasses.replace(
                st, pred=(node.predicate if st.pred is None
                          else ex.And(st.pred, node.predicate)))
        elif isinstance(node, ir.Map):
            if st.agg or st.topk:
                break
            st = dataclasses.replace(
                st, derives=st.derives + tuple(node.derives),
                out_derived=st.out_derived + tuple(
                    n for n, _, _ in node.derives))
        elif isinstance(node, ir.Project):
            if st.agg or st.topk:
                break
            # an explicit projection decides the output schema — derives
            # below it that it dropped must not be re-added
            st = dataclasses.replace(st, columns=node.columns,
                                     out_derived=())
        elif isinstance(node, ir.Aggregate):
            if st.agg or st.topk:
                break
            st = dataclasses.replace(st, agg=(node.keys, node.aggs))
        elif isinstance(node, ir.TopK):
            # top-k over *partial* aggregates could drop the true winner;
            # only absorb when no aggregation was pushed below it
            if st.agg or st.topk:
                break
            cols = st.columns
            # the ordering column must ship — both the storage-side select
            # and the residual re-select need it in the output schema
            if node.col not in cols and node.col not in st.out_derived:
                cols = tuple(cols) + (node.col,)
            st = dataclasses.replace(
                st, topk=(node.col, node.k, node.ascending), columns=cols)
        else:
            break
        states.append(st)
    return states


def _needed_above(states: List[_ChainState], ops_chain: List[ir.Node],
                  k: int, skey: Optional[str]) -> set:
    """Base/derived column names a cut at k must ship so the residual can
    replay ``ops_chain[k:M]`` and still feed everything above the chain.

    Seeded with the *maximal* plan's output schema (whatever consumes the
    chain under the maximal split consumes a subset of it), then walked
    backward over the replayed operators: each op removes the names it
    produces and adds the names it consumes."""
    M = len(states) - 1
    top = states[M]
    if top.agg is not None:
        keys, specs = top.agg
        need = set(keys) | {out for out, _, _ in specs}
    else:
        need = set(top.columns) | set(top.out_derived)
    if skey is not None:
        need.add(skey)
    for node in reversed(ops_chain[k:M]):
        if isinstance(node, ir.Filter):
            need |= ex.columns_of(node.predicate)
        elif isinstance(node, ir.Map):
            need -= {n for n, _, _ in node.derives}
            for _, incols, _ in node.derives:
                need |= set(incols)
        elif isinstance(node, ir.Aggregate):
            need -= {out for out, _, _ in node.aggs}
            need |= set(node.keys) | {c for _, _, c in node.aggs if c}
        elif isinstance(node, ir.TopK):
            need.add(node.col)
        # Project: pure restriction — consumes nothing new, and anything
        # needed above it already lies inside its output schema
    return need


def _maximal_out_schema(states: List[_ChainState]) -> Tuple[str, ...]:
    """Output schema of the maximal-frontier plan — what everything above
    the chain observes. Shallow cuts project their replayed chain back to
    this, so the extra replay-input columns they ship can never leak into
    the merged schema (and from there into a Join-rooted result)."""
    top = states[-1]
    if top.agg is not None:
        keys, specs = top.agg
        return tuple(keys) + tuple(out for out, _, _ in specs)
    return tuple(top.columns) + tuple(
        n for n in top.out_derived if n not in top.columns)


def _plan_at(table: str, states: List[_ChainState],
             ops_chain: List[ir.Node], k: int,
             skey: Optional[str]) -> PushPlan:
    st = states[k]
    if st.agg is not None:
        out_columns = tuple(st.agg[0])
    else:
        out_columns = tuple(st.columns) + tuple(
            n for n in st.out_derived if n not in st.columns)
        if k < len(states) - 1:
            # shallow cut: additionally ship the inputs of the operators
            # the residual will replay
            need = _needed_above(states, ops_chain, k, skey)
            out_columns = out_columns + tuple(
                sorted(c for c in need if c not in out_columns))
    return PushPlan(
        table, out_columns, predicate=st.pred, derive=st.derives,
        agg=(tuple(st.agg[0]), tuple(st.agg[1])) if st.agg is not None
        else None,
        top_k=st.topk, having=st.having)


def _lower_chain(chain: List[ir.Node], ctx: _SplitCtx) -> ir.Node:
    scan = chain[0]
    assert isinstance(scan, ir.Scan)
    table = scan.table
    if table in ctx.plans:
        raise CompileError(f"table {table!r} scanned more than once")

    ops_chain: List[ir.Node] = []
    for node in chain[1:]:
        if isinstance(node, ir.Shuffle):  # marker: record + drop
            ctx.skeys[table] = node.key
        else:
            ops_chain.append(node)

    skey = ctx.skeys.get(table)
    states = _absorption_states(scan, ops_chain, ctx.clustered.get(table))
    max_k = len(states) - 1
    k = max_k if ctx.cuts is None else ctx.cuts.get(table, max_k)
    if not 0 <= k <= max_k:
        raise CompileError(
            f"cut {k} out of range for {table!r} (max {max_k})")

    plan = _plan_at(table, states, ops_chain, k, skey)
    if (table in ctx.bitmap_tables and plan.predicate is not None
            and plan.agg is None and plan.top_k is None):
        # §4.2 exchange: ship the packed predicate-verdict bitmap alongside
        plan = dataclasses.replace(plan, bitmap_only=True)
    ctx.plans[table] = plan
    ctx.candidates[table] = [_plan_at(table, states, ops_chain, j, skey)
                             for j in range(max_k + 1)]
    ctx.chosen[table] = k
    ctx.max_cut[table] = max_k

    st = states[k]
    residual: ir.Node = ir.Merged(table)
    if st.agg is not None:
        keys, specs = st.agg
        merge = tuple((out, analyzer.DECOMPOSABLE[fn], out)
                      for out, fn, _ in specs)
        residual = ir.Aggregate(residual, tuple(keys), merge)
        if st.having is not None:
            # re-apply the absorbed HAVING after the partial merge — a
            # no-op on the storage-filtered survivors under group-locality,
            # kept so the residual mirrors the original operator sequence
            residual = ir.Filter(residual, st.having)
    if st.topk is not None:
        col, kk, asc = st.topk
        residual = ir.TopK(residual, col, kk, asc)
    if k < max_k:
        # shallow cut: replay the unabsorbed absorbable prefix, then
        # project back to the maximal frontier's output schema so the
        # extra replay-input columns the plan shipped stay chain-local
        for node in ops_chain[k:max_k]:
            residual = ir.rebuild_unary(node, residual)
        residual = ir.Project(residual, _maximal_out_schema(states))
        for node in ops_chain[max_k:]:
            residual = ir.rebuild_unary(node, residual)
    else:
        for node in ops_chain[k:]:
            residual = ir.rebuild_unary(node, residual)
    return residual


# ----------------------------------------------------- frontier reporting
_STAGES = ("filter", "derive", "agg", "topk")


def frontier_signature(plans: Dict[str, PushPlan],
                       shuffle_keys: Optional[Dict[str, str]] = None
                       ) -> Dict[str, str]:
    """Per-table signature of the pushed stages, e.g.
    {'lineitem': 'scan+filter+derive+agg', 'orders': 'scan'}. Passing the
    split's ``shuffle_keys`` marks shuffle-bearing frontiers
    (``...+shuffle``) — the batch executor runs the partition function in
    the same fused pass as the rest of the chain."""
    return {table: plan_signature(
                p, shuffle_keys.get(table) if shuffle_keys else None)
            for table, p in sorted(plans.items())}


def frontier_size(plans: Dict[str, PushPlan]) -> int:
    """Total pushed stages across tables — the partial order used to show
    a compiled frontier is *strictly larger* than a hand-built one."""
    return sum(sig.count("+") + 1
               for sig in frontier_signature(plans).values())
