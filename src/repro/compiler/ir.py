"""Logical-plan IR for the pushdown compiler.

A query is a DAG of relational nodes over the existing ``Expr`` predicate
trees (``repro.queryproc.expressions``). The IR deliberately mirrors the
operator vocabulary of ``queryproc/operators.py`` — every node has an exact
compute-layer implementation there — while the *storage-amenable* subset
(the paper's §4.1 "local + bounded" operators) additionally lowers to
``core.plan.PushPlan`` stages.

Node inputs are other nodes; ``Scan`` and ``Merged`` are the leaves.
``Merged(table)`` only appears in *residual* plans produced by the splitter:
it denotes the concatenation of the per-partition pushdown results of one
table (what ``engine.execute_requests`` hands to ``Query.compute``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterator, List, Sequence, Tuple

from repro.queryproc import expressions as ex

# (out_name, agg_fn, in_col); agg_fn in {"sum","count","min","max","mean"};
# "count" ignores in_col.
AggSpec = Tuple[str, str, str]
# (out_name, (in_cols...), fn) — same shape as PushPlan.derive entries.
DeriveSpec = Tuple[str, Tuple[str, ...], Callable]


class Node:
    """Base class; concrete nodes are frozen dataclasses."""

    def inputs(self) -> Tuple["Node", ...]:
        return tuple(getattr(self, f.name) for f in dataclasses.fields(self)
                     if isinstance(getattr(self, f.name), Node))


@dataclasses.dataclass(frozen=True)
class Scan(Node):
    """Leaf: scan of a base table. ``columns`` are the base columns this
    branch exports downstream (derived columns are added by Map nodes)."""
    table: str
    columns: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Merged(Node):
    """Residual leaf: merged per-partition pushdown results of ``table``."""
    table: str


@dataclasses.dataclass(frozen=True)
class Filter(Node):
    child: Node
    predicate: ex.Expr


@dataclasses.dataclass(frozen=True)
class Project(Node):
    child: Node
    columns: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Map(Node):
    """Row-wise derived columns (S3-Select-style scalar expressions)."""
    child: Node
    derives: Tuple[DeriveSpec, ...]


@dataclasses.dataclass(frozen=True)
class Aggregate(Node):
    child: Node
    keys: Tuple[str, ...]
    aggs: Tuple[AggSpec, ...]


@dataclasses.dataclass(frozen=True)
class Join(Node):
    """Hash equi-join; argument order matches ops.hash_join(left, right)."""
    left: Node
    right: Node
    lkey: str
    rkey: str


@dataclasses.dataclass(frozen=True)
class SemiJoin(Node):
    """Keep left rows with (anti: without) a key match on the right."""
    left: Node
    right: Node
    lkey: str
    rkey: str
    anti: bool = False


@dataclasses.dataclass(frozen=True)
class Shuffle(Node):
    """Redistribution requirement on ``key`` for the downstream join
    (drives the Fig-15 shuffle-pushdown evaluation; row-preserving)."""
    child: Node
    key: str


@dataclasses.dataclass(frozen=True)
class TopK(Node):
    child: Node
    col: str
    k: int
    ascending: bool = False


@dataclasses.dataclass(frozen=True)
class Sort(Node):
    child: Node
    columns: Tuple[str, ...]
    ascending: bool = True


@dataclasses.dataclass(frozen=True)
class PyOp(Node):
    """Escape hatch for compute-only logic with no relational encoding
    (e.g. Q15's having-max, Q22's data-dependent threshold). ``fn`` takes
    one ColumnTable per input node; never pushdown-amenable."""
    children: Tuple[Node, ...]
    fn: Callable
    note: str = ""

    def inputs(self) -> Tuple[Node, ...]:
        return self.children


UNARY_TYPES = (Filter, Project, Map, Aggregate, Shuffle, TopK, Sort)


def walk(node: Node) -> Iterator[Node]:
    """Preorder DAG walk (each node yielded once)."""
    seen = set()
    stack = [node]
    while stack:
        n = stack.pop()
        if id(n) in seen:
            continue
        seen.add(id(n))
        yield n
        stack.extend(reversed(n.inputs()))


def scans(node: Node) -> List[Scan]:
    return [n for n in walk(node) if isinstance(n, Scan)]


def base_tables(node: Node) -> List[str]:
    return sorted({s.table for s in scans(node)})


def rebuild_unary(node: Node, child: Node) -> Node:
    """Copy a unary node onto a new input."""
    assert isinstance(node, UNARY_TYPES), node
    return dataclasses.replace(node, child=child)


def describe(node: Node) -> str:
    """One-line structural signature, e.g. 'Join(Merged[a],Merged[b])'."""
    if isinstance(node, (Scan, Merged)):
        tag = "Scan" if isinstance(node, Scan) else "Merged"
        return f"{tag}[{node.table}]"
    name = type(node).__name__
    return f"{name}({','.join(describe(i) for i in node.inputs())})"


def op_counts(node: Node) -> dict:
    """Multiset of node-type names — the residual-shape golden signature."""
    out: dict = {}
    for n in walk(node):
        out[type(n).__name__] = out.get(type(n).__name__, 0) + 1
    return out
