"""Generic residual interpreter over ``queryproc/operators.py``.

Replaces the per-query hand-written ``compute`` closures of the seed: the
splitter's residual IR is evaluated bottom-up against the merged pushdown
results (``Dict[table, ColumnTable]``), each node dispatching to the exact
numpy operator the closures used. One interpreter, fifteen queries.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.compiler import ir
from repro.queryproc import expressions as ex
from repro.queryproc import operators as ops
from repro.queryproc.table import ColumnTable


def run(node: ir.Node, merged: Dict[str, ColumnTable]) -> ColumnTable:
    """Evaluate a residual plan against the merged pushdown results.
    Shared subtrees (DAGs) are evaluated once via an id-keyed memo."""
    return _run(node, merged, {})


def _run(node: ir.Node, merged: Dict[str, ColumnTable],
         memo: Dict[int, ColumnTable]) -> ColumnTable:
    if id(node) in memo:
        return memo[id(node)]
    out = _eval(node, merged, memo)
    memo[id(node)] = out
    return out


def _eval(node: ir.Node, merged: Dict[str, ColumnTable],
          memo: Dict[int, ColumnTable]) -> ColumnTable:
    def run(n, m):  # noqa: A001 — keep the recursive body readable
        return _run(n, m, memo)

    if isinstance(node, (ir.Merged, ir.Scan)):
        return merged[node.table]
    if isinstance(node, ir.Filter):
        t = run(node.child, merged)
        return t.filter(ex.evaluate(node.predicate, t))
    if isinstance(node, ir.Project):
        t = run(node.child, merged)
        return t.select([c for c in node.columns if c in t.cols])
    if isinstance(node, ir.Map):
        t = run(node.child, merged)
        cols = dict(t.cols)
        for name, incols, fn in node.derives:
            cols[name] = fn(*[cols[c] for c in incols])
        return ColumnTable(cols)
    if isinstance(node, ir.Aggregate):
        t = run(node.child, merged)
        return ops.grouped_agg(t, list(node.keys),
                               {out: (fn, col) for out, fn, col in node.aggs})
    if isinstance(node, ir.Join):
        return ops.hash_join(run(node.left, merged), run(node.right, merged),
                             node.lkey, node.rkey)
    if isinstance(node, ir.SemiJoin):
        left = run(node.left, merged)
        right = run(node.right, merged)
        mask = np.isin(left.cols[node.lkey], np.unique(right.cols[node.rkey]))
        return left.filter(~mask if node.anti else mask)
    if isinstance(node, ir.TopK):
        return ops.top_k(run(node.child, merged), node.col, node.k,
                         node.ascending)
    if isinstance(node, ir.Sort):
        return ops.sort_table(run(node.child, merged), list(node.columns),
                              ascending=node.ascending)
    if isinstance(node, ir.Shuffle):  # redistribution marker: row-preserving
        return run(node.child, merged)
    if isinstance(node, ir.PyOp):
        return node.fn(*[run(c, merged) for c in node.children])
    raise TypeError(f"unknown IR node: {node!r}")
