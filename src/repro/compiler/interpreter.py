"""Generic residual interpreter over ``queryproc/operators.py``.

Replaces the per-query hand-written ``compute`` closures of the seed: the
splitter's residual IR is evaluated bottom-up against the merged pushdown
results (``Dict[table, ColumnTable]``), each node dispatching to the exact
numpy operator the closures used. One interpreter, fifteen queries.

Residual Filter predicates are lowered once per node (the engine evaluates
the same residual for every execution mode and benchmark repeat), mirroring
the storage layer's compile-once executor (``core.executor``).
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Dict, Tuple

import numpy as np

from repro.compiler import ir
from repro.queryproc import expressions as ex
from repro.queryproc import operators as ops
from repro.queryproc.table import ColumnTable


_PRED_CACHE: "OrderedDict[int, Tuple[ir.Filter, Callable]]" = OrderedDict()
_PRED_CACHE_CAP = 4096   # bounded: a query has a handful of these


def _compiled_pred(node: ir.Filter) -> Callable:
    """Compile-once cache for residual Filter predicates, keyed by node
    identity (the node itself is retained, so its id cannot be reused).
    LRU-bounded: at capacity the least-recently-used entry is evicted —
    the hot working set survives, unlike a wholesale clear that would
    recompile every live query's predicates on the next touch."""
    hit = _PRED_CACHE.get(id(node))
    if hit is not None and hit[0] is node:
        _PRED_CACHE.move_to_end(id(node))
        return hit[1]
    fn = ex.compile_expr(node.predicate)
    _PRED_CACHE[id(node)] = (node, fn)
    _PRED_CACHE.move_to_end(id(node))
    while len(_PRED_CACHE) > _PRED_CACHE_CAP:
        _PRED_CACHE.popitem(last=False)
    return fn


def run(node: ir.Node, merged: Dict[str, ColumnTable]) -> ColumnTable:
    """Evaluate a residual plan against the merged pushdown results.
    Shared subtrees (DAGs) are evaluated once via an id-keyed memo."""
    return _run(node, merged, {})


def _run(node: ir.Node, merged: Dict[str, ColumnTable],
         memo: Dict[int, ColumnTable]) -> ColumnTable:
    if id(node) in memo:
        return memo[id(node)]
    out = _eval(node, merged, memo)
    memo[id(node)] = out
    return out


def _eval(node: ir.Node, merged: Dict[str, ColumnTable],
          memo: Dict[int, ColumnTable]) -> ColumnTable:
    def run(n, m):  # noqa: A001 — keep the recursive body readable
        return _run(n, m, memo)

    if isinstance(node, (ir.Merged, ir.Scan)):
        return merged[node.table]
    if isinstance(node, ir.Filter):
        t = run(node.child, merged)
        return t.filter(_compiled_pred(node)(t.cols))
    if isinstance(node, ir.Project):
        t = run(node.child, merged)
        return t.select([c for c in node.columns if c in t.cols])
    if isinstance(node, ir.Map):
        t = run(node.child, merged)
        cols = dict(t.cols)
        for name, incols, fn in node.derives:
            cols[name] = fn(*[cols[c] for c in incols])
        return ColumnTable(cols)
    if isinstance(node, ir.Aggregate):
        t = run(node.child, merged)
        return ops.grouped_agg(t, list(node.keys),
                               {out: (fn, col) for out, fn, col in node.aggs})
    if isinstance(node, ir.Join):
        return ops.hash_join(run(node.left, merged), run(node.right, merged),
                             node.lkey, node.rkey)
    if isinstance(node, ir.SemiJoin):
        left = run(node.left, merged)
        right = run(node.right, merged)
        # np.isin builds its own hash/sort structure over the test values —
        # pre-unique'ing them was a redundant O(n log n) pass
        mask = np.isin(left.cols[node.lkey], right.cols[node.rkey])
        return left.filter(~mask if node.anti else mask)
    if isinstance(node, ir.TopK):
        return ops.top_k(run(node.child, merged), node.col, node.k,
                         node.ascending)
    if isinstance(node, ir.Sort):
        return ops.sort_table(run(node.child, merged), list(node.columns),
                              ascending=node.ascending)
    if isinstance(node, ir.Shuffle):  # redistribution marker: row-preserving
        return run(node.child, merged)
    if isinstance(node, ir.PyOp):
        return node.fn(*[run(c, merged) for c in node.children])
    raise TypeError(f"unknown IR node: {node!r}")
