"""Multi-table predicate lowering: implied per-table predicates + the
§4.2 selection-bitmap exchange.

A residual ``Filter`` sitting above the joins whose predicate spans
several base tables (Q7's two-nation OR, Q19's brand/container/quantity
OR-of-ANDs) cannot be pushed as-is — it is not partition-parallel over any
single table. But each table's *implied* predicate can: the strongest
single-table consequence of the original predicate (``And`` keeps the
owned side, ``Or`` requires both branches to imply something). Rows a
table drops under its implied predicate could never survive the original
filter, and inner equi-joins / row-preserving operators keep the
surviving rows' relative order — so inserting the implied filter directly
above the table's ``Scan`` (where the splitter absorbs it) leaves the
final query result **byte-identical** while strictly shrinking the bytes
the table ships. A soundness walk guards the insertion: the path from the
multi-table filter down to the scan must not cross an ``Aggregate``,
``TopK``, ``PyOp``, a ``SemiJoin`` right side, a shared (DAG) subtree, or
a ``Map`` that shadows a predicate column.

Two lowering encodings per table, chosen by cost (the paper's §4.2
design-space discussion):

- **conjunct pushdown** — the implied predicate joins the table's pushed
  filter stage; the compute layer re-evaluates the full multi-table
  predicate over the (smaller) join output.
- **bitmap exchange** (``PushPlan.bitmap_only``) — the storage node
  additionally ships the packed predicate-verdict bitmap (1 bit/row), so
  the compute side can combine per-table verdicts with cheap bitwise ops
  (``core.bitmap.combine_bitmaps``) instead of re-reading this table's
  predicate columns across the join fan-out. Worth its 1 bit/row exactly
  when the saved re-evaluation outweighs the extra ship + combine
  (:func:`exchange_pays`) — high-selectivity, few-column conjuncts (Q19's
  ``l_quantity`` bound) qualify; highly selective dimension restrictions
  (Q19's part disjunction, Q7's nation lists) do not.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from repro.compiler import ir
from repro.core.cost import StorageResources
from repro.queryproc import expressions as ex

#: compute-node operator bandwidth the exchange scoring assumes when the
#: caller does not pass the engine's (matches EngineConfig.compute_bw)
DEFAULT_COMPUTE_BW = 2.4e9


@dataclasses.dataclass(frozen=True)
class Lowering:
    """One implied predicate lowered onto one table's frontier."""
    table: str
    predicate: ex.Expr          # implied single-table predicate
    bitmap: bool                # §4.2 exchange encoding chosen?
    est_selectivity: float      # of the implied predicate, table stats
    source: str                 # repr of the multi-table predicate


# ------------------------------------------------------------ implication
def implied_predicate(expr: ex.Expr, owned: Set[str]) -> Optional[ex.Expr]:
    """Strongest predicate over ``owned`` columns implied by ``expr``
    (None when nothing is implied). ``And`` keeps whichever side implies;
    ``Or`` weakens — both branches must imply, else nothing does. A
    column-column compare within one table qualifies; across tables it
    implies nothing."""
    if isinstance(expr, ex.And):
        left = implied_predicate(expr.left, owned)
        right = implied_predicate(expr.right, owned)
        if left is None:
            return right
        if right is None:
            return left
        return ex.And(left, right)
    if isinstance(expr, ex.Or):
        left = implied_predicate(expr.left, owned)
        right = implied_predicate(expr.right, owned)
        if left is None or right is None:
            return None
        return ex.Or(left, right)
    cols = ex.columns_of(expr)
    if cols and cols <= owned:
        return expr
    return None


# --------------------------------------------------------- soundness walk
def _parent_counts(root: ir.Node) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    for node in ir.walk(root):
        for child in node.inputs():
            counts[id(child)] = counts.get(id(child), 0) + 1
    return counts


def _path_to_scan(node: ir.Node, table: str) -> Optional[List[ir.Node]]:
    """Nodes from ``node`` down to ``Scan(table)`` when every step is
    row-removal-safe; None otherwise. Aggregate/TopK (row counts feed the
    result), PyOp (opaque) and a SemiJoin's right side (membership tests
    invert under anti-joins) block the descent."""
    if isinstance(node, ir.Scan):
        return [node] if node.table == table else None
    if isinstance(node, (ir.Aggregate, ir.TopK, ir.PyOp, ir.Merged)):
        return None
    if isinstance(node, ir.SemiJoin):
        sub = _path_to_scan(node.left, table)
        return [node] + sub if sub is not None else None
    if isinstance(node, ir.Join):
        for side in (node.left, node.right):
            sub = _path_to_scan(side, table)
            if sub is not None:
                return [node] + sub
        return None
    if isinstance(node, ir.UNARY_TYPES):
        sub = _path_to_scan(node.child, table)
        return [node] + sub if sub is not None else None
    return None


def _path_sound(path: List[ir.Node], pred_cols: Set[str],
                parents: Dict[int, int]) -> bool:
    for node in path:
        if parents.get(id(node), 0) > 1:
            return False  # shared subtree: the other consumer sees fewer rows
        if isinstance(node, ir.Map) and (
                {n for n, _, _ in node.derives} & pred_cols):
            return False  # derive shadows a predicate column
    return True


# ------------------------------------------------------- exchange scoring
def exchange_pays(sel: float, n_pred_cols: int, res: StorageResources,
                  compute_bw: float = DEFAULT_COMPUTE_BW) -> bool:
    """Per-row economics of shipping the verdict bitmap (§4.2 exchange)
    instead of having the compute layer re-evaluate this table's share of
    the multi-table predicate:

    - saved at compute: re-reading the ``n_pred_cols`` shipped predicate
      columns over the surviving rows — ``sel * 8 * n_pred_cols`` bytes;
    - paid: 1 bit/row across the per-stream network share plus the
      bitwise combine at compute.
    """
    saved = sel * 8.0 * n_pred_cols / compute_bw
    paid = 0.125 * (1.0 / res.stream_bw + 1.0 / compute_bw)
    return saved > paid


# ---------------------------------------------------------------- rewrite
def _insert_filters(node: ir.Node, by_table: Dict[str, ex.Expr],
                    memo: Dict[int, ir.Node]) -> ir.Node:
    if id(node) in memo:
        return memo[id(node)]
    if isinstance(node, ir.Scan):
        out: ir.Node = (ir.Filter(node, by_table[node.table])
                        if node.table in by_table else node)
    elif isinstance(node, (ir.Join, ir.SemiJoin)):
        out = dataclasses.replace(
            node, left=_insert_filters(node.left, by_table, memo),
            right=_insert_filters(node.right, by_table, memo))
    elif isinstance(node, ir.PyOp):
        out = dataclasses.replace(node, children=tuple(
            _insert_filters(c, by_table, memo) for c in node.children))
    elif isinstance(node, ir.UNARY_TYPES):
        out = ir.rebuild_unary(node,
                               _insert_filters(node.child, by_table, memo))
    else:
        out = node
    memo[id(node)] = out
    return out


def lower(root: ir.Node, catalog, res: StorageResources,
          compute_bw: float = DEFAULT_COMPUTE_BW
          ) -> Tuple[ir.Node, List[Lowering]]:
    """Lower every sound multi-table predicate of ``root`` onto its
    tables' frontiers. Returns the rewritten plan (implied filters
    inserted directly above the scans, where the splitter absorbs them)
    plus the per-table :class:`Lowering` records — tables whose record has
    ``bitmap=True`` should split with ``bitmap_tables`` so their frontier
    carries the §4.2 exchange."""
    owned_by_table: Dict[str, Set[str]] = {
        t: set(parts[0].data.columns) for t, parts in catalog.tables.items()
        if parts}
    owner: Dict[str, str] = {c: t for t, cols in owned_by_table.items()
                             for c in cols}
    parents = _parent_counts(root)

    implied_by_table: Dict[str, ex.Expr] = {}
    source_by_table: Dict[str, List[str]] = {}
    for node in ir.walk(root):
        if not isinstance(node, ir.Filter):
            continue
        pred_cols = ex.columns_of(node.predicate)
        span = {owner[c] for c in pred_cols if c in owner}
        if len(span) < 2:
            continue
        for table in sorted(span):
            implied = implied_predicate(node.predicate, owned_by_table[table])
            if implied is None:
                continue
            path = _path_to_scan(node.child, table)
            if path is None or not _path_sound(path, pred_cols, parents):
                continue
            prev = implied_by_table.get(table)
            implied_by_table[table] = (implied if prev is None
                                       else ex.And(prev, implied))
            source_by_table.setdefault(table, []).append(
                repr(node.predicate))
    if not implied_by_table:
        return root, []

    lowerings: List[Lowering] = []
    for table, implied in sorted(implied_by_table.items()):
        stats = catalog.scan_table(table).stats()
        sel = ex.estimate_selectivity(implied, stats)
        bitmap = exchange_pays(sel, len(ex.columns_of(implied)), res,
                               compute_bw)
        lowerings.append(Lowering(table, implied, bitmap, sel,
                                  "; ".join(source_by_table[table])))
    return _insert_filters(root, implied_by_table, {}), lowerings
