"""Multi-table predicate lowering: implied per-table predicates + the
§4.2 selection-bitmap exchange.

A residual ``Filter`` sitting above the joins whose predicate spans
several base tables (Q7's two-nation OR, Q19's brand/container/quantity
OR-of-ANDs) cannot be pushed as-is — it is not partition-parallel over any
single table. But each table's *implied* predicate can: the strongest
single-table consequence of the original predicate (``And`` keeps the
owned side, ``Or`` requires both branches to imply something). Rows a
table drops under its implied predicate could never survive the original
filter, and inner equi-joins / row-preserving operators keep the
surviving rows' relative order — so inserting the implied filter directly
above the table's ``Scan`` (where the splitter absorbs it) leaves the
final query result **byte-identical** while strictly shrinking the bytes
the table ships. A soundness walk guards the insertion: the path from the
multi-table filter down to the scan must not cross an ``Aggregate``,
``TopK``, ``PyOp``, a ``SemiJoin`` right side, a shared (DAG) subtree, or
a ``Map`` that shadows a predicate column.

Two lowering encodings per table, chosen by cost (the paper's §4.2
design-space discussion):

- **conjunct pushdown** — the implied predicate joins the table's pushed
  filter stage; the compute layer re-evaluates the full multi-table
  predicate over the (smaller) join output.
- **bitmap exchange** (``PushPlan.bitmap_only``) — the storage node
  additionally ships the packed predicate-verdict bitmap (1 bit/row), so
  the compute side can combine per-table verdicts with cheap bitwise ops
  (``core.bitmap.combine_bitmaps``) instead of re-reading this table's
  predicate columns across the join fan-out. Worth its 1 bit/row exactly
  when the saved re-evaluation outweighs the extra ship + combine
  (:func:`exchange_pays`) — high-selectivity, few-column conjuncts (Q19's
  ``l_quantity`` bound) qualify; highly selective dimension restrictions
  (Q19's part disjunction, Q7's nation lists) do not.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

import numpy as np

from repro.compiler import ir, pushability
from repro.core.cost import StorageResources
from repro.queryproc import expressions as ex

#: compute-node operator bandwidth the exchange scoring assumes when the
#: caller does not pass the engine's (matches EngineConfig.compute_bw)
DEFAULT_COMPUTE_BW = 2.4e9


@dataclasses.dataclass(frozen=True)
class Lowering:
    """One implied predicate lowered onto one table's frontier."""
    table: str
    predicate: ex.Expr          # implied single-table predicate
    bitmap: bool                # §4.2 exchange encoding chosen?
    est_selectivity: float      # of the implied predicate, table stats
    source: str                 # repr of the multi-table predicate


# ------------------------------------------------------------ implication
def implied_predicate(expr: ex.Expr, owned: Set[str],
                      domains: Optional[Dict[str, FrozenSet]] = None
                      ) -> Optional[ex.Expr]:
    """Strongest predicate over ``owned`` columns implied by ``expr``
    (None when nothing is implied). ``And`` keeps whichever side implies;
    ``Or`` weakens — both branches must imply, else nothing does. A
    column-column compare within one table qualifies; across tables it
    implies nothing *on its own* — but when ``domains`` carries the value
    domain of the far column (derived from a restricted dimension table and
    propagated over inner equi-joins by :func:`lower`), a cross-table
    equality translates into an ``In`` over the owned column: Q5's
    ``c_nationkey == s_nationkey`` under ``s_nationkey ∈ region-2 nations``
    implies ``In(c_nationkey, region-2 nations)``."""
    if isinstance(expr, ex.And):
        left = implied_predicate(expr.left, owned, domains)
        right = implied_predicate(expr.right, owned, domains)
        if left is None:
            return right
        if right is None:
            return left
        return ex.And(left, right)
    if isinstance(expr, ex.Or):
        left = implied_predicate(expr.left, owned, domains)
        right = implied_predicate(expr.right, owned, domains)
        if left is None or right is None:
            return None
        return ex.Or(left, right)
    cols = ex.columns_of(expr)
    if cols and cols <= owned:
        return expr
    if (domains and isinstance(expr, ex.Cmp) and expr.op == "=="
            and isinstance(expr.value, ex.Col)):
        for mine, other in ((expr.col.name, expr.value.name),
                            (expr.value.name, expr.col.name)):
            dom = domains.get(other)
            if mine in owned and other not in owned and dom:
                return ex.In(ex.Col(mine), tuple(sorted(dom)))
    return None


# ------------------------------------------------------- value domains
#: tables larger than this are never evaluated for domains (dimension
#: tables only — the derivation scans the real data once)
DOMAIN_MAX_ROWS = 4096
#: a domain wider than this cannot win as an In-filter
DOMAIN_MAX_VALUES = 512


def _chain_domains(node: ir.Node, catalog,
                   memo: Dict[int, Dict[str, FrozenSet]]
                   ) -> Dict[str, FrozenSet]:
    """Per-column value domains of the rows a unary chain over a *small*
    Scan produces: evaluate the chain's absorbable filters against the
    base table and collect each base column's surviving distinct values.
    Only domains *strictly narrower* than the column's full NDV qualify —
    an ``In`` over every value is vacuous and would pollute frontiers."""
    if id(node) in memo:
        return memo[id(node)]
    out: Dict[str, FrozenSet] = {}
    preds: List[ex.Expr] = []
    cur = node
    ok = True
    while isinstance(cur, ir.UNARY_TYPES):
        if isinstance(cur, (ir.Aggregate, ir.TopK)):
            ok = False  # output rows are groups, not base rows
            break
        if isinstance(cur, ir.Filter):
            if not pushability.filter_absorbable(cur):
                ok = False
                break
            preds.append(cur.predicate)
        cur = cur.child
    if ok and isinstance(cur, ir.Scan) and preds:
        data = catalog.scan_table(cur.table)
        base = set(data.columns)
        if (len(data) <= DOMAIN_MAX_ROWS
                and all(ex.columns_of(p) <= base for p in preds)):
            mask = np.ones(len(data), dtype=bool)
            for p in preds:
                mask &= np.asarray(ex.evaluate(p, data), dtype=bool)
            for c in data.columns:
                col = np.asarray(data.cols[c])
                vals = np.unique(col[mask])
                if 0 < len(vals) <= DOMAIN_MAX_VALUES \
                        and len(vals) < len(np.unique(col)):
                    out[c] = frozenset(v.item() for v in vals)
    memo[id(node)] = out
    return out


def _equality_atoms(pred: ex.Expr):
    """Top-level ``a == b`` column-column conjuncts of an And-tree."""
    if isinstance(pred, ex.And):
        yield from _equality_atoms(pred.left)
        yield from _equality_atoms(pred.right)
    elif (isinstance(pred, ex.Cmp) and pred.op == "=="
          and isinstance(pred.value, ex.Col)):
        yield pred.col.name, pred.value.name


def _output_facts(root: ir.Node, parents: Dict[int, int], catalog
                  ) -> Dict[int, Dict[str, FrozenSet]]:
    """For every node, the column-domain facts that hold for each of its
    rows *that contributes to the final output* — the license to drop the
    violating rows early.

    Facts are born at inner equi-joins whose other side is a restricted
    small-table chain (a row only survives the join if its key matches a
    surviving dimension value) and at equality filter conjuncts (a
    surviving row carries equal values, so a domain transfers across the
    atom). They flow *down* the plan, because a child row that reaches the
    output does so through its parent — gated by the same soundness rules
    as the multi-table walk: a shared (DAG) subtree resets (the other
    consumer sees all rows), Aggregate/TopK/PyOp reset (removed rows fold
    into surviving outputs), a Map drops facts on columns it shadows, and
    a SemiJoin's membership side never receives facts (removing its rows
    flips matches)."""
    facts_at: Dict[int, Dict[str, FrozenSet]] = {}
    domains_memo: Dict[int, Dict[str, FrozenSet]] = {}

    def visit(node: ir.Node, facts: Dict[str, FrozenSet]) -> None:
        if parents.get(id(node), 0) > 1:
            facts = {}
        prev = facts_at.get(id(node))
        if prev is not None:
            facts = {c: d for c, d in prev.items() if facts.get(c) == d}
            if facts == prev:
                return  # fixpoint for this node
        facts_at[id(node)] = facts
        if isinstance(node, (ir.Aggregate, ir.TopK, ir.PyOp, ir.Merged)):
            down: Dict[str, FrozenSet] = {}
        elif isinstance(node, ir.Map):
            shadowed = {n for n, _, _ in node.derives}
            down = {c: d for c, d in facts.items() if c not in shadowed}
        elif isinstance(node, ir.Filter):
            down = dict(facts)
            for a, b in _equality_atoms(node.predicate):
                if a in down and b not in down:
                    down[b] = down[a]
                elif b in down and a not in down:
                    down[a] = down[b]
        else:
            down = facts
        if isinstance(node, ir.Join):
            lfacts, rfacts = dict(down), dict(down)
            dom = _chain_domains(node.right, catalog, domains_memo
                                 ).get(node.rkey)
            if dom:
                lfacts[node.lkey] = (lfacts[node.lkey] & dom
                                     if node.lkey in lfacts else dom)
            dom = _chain_domains(node.left, catalog, domains_memo
                                 ).get(node.lkey)
            if dom:
                rfacts[node.rkey] = (rfacts[node.rkey] & dom
                                     if node.rkey in rfacts else dom)
            visit(node.left, lfacts)
            visit(node.right, rfacts)
            return
        if isinstance(node, ir.SemiJoin):
            lfacts = dict(down)
            if not node.anti:
                dom = _chain_domains(node.right, catalog, domains_memo
                                     ).get(node.rkey)
                if dom:
                    lfacts[node.lkey] = (lfacts[node.lkey] & dom
                                         if node.lkey in lfacts else dom)
            visit(node.left, lfacts)
            visit(node.right, {})
            return
        for child in node.inputs():
            visit(child, down)

    visit(root, {})
    # close the facts *at* each Filter node over its own equality atoms —
    # a row surviving the output passed the filter, so the transfer holds
    # at the node too (implied_predicate consumes these as `domains`)
    for node in ir.walk(root):
        if not isinstance(node, ir.Filter):
            continue
        facts = dict(facts_at.get(id(node), {}))
        for a, b in _equality_atoms(node.predicate):
            if a in facts and b not in facts:
                facts[b] = facts[a]
            elif b in facts and a not in facts:
                facts[a] = facts[b]
        facts_at[id(node)] = facts
    return facts_at


# --------------------------------------------------------- soundness walk
def _parent_counts(root: ir.Node) -> Dict[int, int]:
    counts: Dict[int, int] = {}
    for node in ir.walk(root):
        for child in node.inputs():
            counts[id(child)] = counts.get(id(child), 0) + 1
    return counts


def _path_to_scan(node: ir.Node, table: str) -> Optional[List[ir.Node]]:
    """Nodes from ``node`` down to ``Scan(table)`` when every step is
    row-removal-safe; None otherwise. Aggregate/TopK (row counts feed the
    result), PyOp (opaque) and a SemiJoin's right side (membership tests
    invert under anti-joins) block the descent."""
    if isinstance(node, ir.Scan):
        return [node] if node.table == table else None
    if isinstance(node, (ir.Aggregate, ir.TopK, ir.PyOp, ir.Merged)):
        return None
    if isinstance(node, ir.SemiJoin):
        sub = _path_to_scan(node.left, table)
        return [node] + sub if sub is not None else None
    if isinstance(node, ir.Join):
        for side in (node.left, node.right):
            sub = _path_to_scan(side, table)
            if sub is not None:
                return [node] + sub
        return None
    if isinstance(node, ir.UNARY_TYPES):
        sub = _path_to_scan(node.child, table)
        return [node] + sub if sub is not None else None
    return None


def _path_sound(path: List[ir.Node], pred_cols: Set[str],
                parents: Dict[int, int]) -> bool:
    for node in path:
        if parents.get(id(node), 0) > 1:
            return False  # shared subtree: the other consumer sees fewer rows
        if isinstance(node, ir.Map) and (
                {n for n, _, _ in node.derives} & pred_cols):
            return False  # derive shadows a predicate column
    return True


# ------------------------------------------------------- exchange scoring
def exchange_pays(sel: float, n_pred_cols: int, res: StorageResources,
                  compute_bw: float = DEFAULT_COMPUTE_BW) -> bool:
    """Per-row economics of shipping the verdict bitmap (§4.2 exchange)
    instead of having the compute layer re-evaluate this table's share of
    the multi-table predicate:

    - saved at compute: re-reading the ``n_pred_cols`` shipped predicate
      columns over the surviving rows — ``sel * 8 * n_pred_cols`` bytes;
    - paid: 1 bit/row across the per-stream network share plus the
      bitwise combine at compute.
    """
    saved = sel * 8.0 * n_pred_cols / compute_bw
    paid = 0.125 * (1.0 / res.stream_bw + 1.0 / compute_bw)
    return saved > paid


# ---------------------------------------------------------------- rewrite
def _insert_filters(node: ir.Node, by_table: Dict[str, ex.Expr],
                    memo: Dict[int, ir.Node]) -> ir.Node:
    if id(node) in memo:
        return memo[id(node)]
    if isinstance(node, ir.Scan):
        out: ir.Node = (ir.Filter(node, by_table[node.table])
                        if node.table in by_table else node)
    elif isinstance(node, (ir.Join, ir.SemiJoin)):
        out = dataclasses.replace(
            node, left=_insert_filters(node.left, by_table, memo),
            right=_insert_filters(node.right, by_table, memo))
    elif isinstance(node, ir.PyOp):
        out = dataclasses.replace(node, children=tuple(
            _insert_filters(c, by_table, memo) for c in node.children))
    elif isinstance(node, ir.UNARY_TYPES):
        out = ir.rebuild_unary(node,
                               _insert_filters(node.child, by_table, memo))
    else:
        out = node
    memo[id(node)] = out
    return out


def lower(root: ir.Node, catalog, res: StorageResources,
          compute_bw: float = DEFAULT_COMPUTE_BW
          ) -> Tuple[ir.Node, List[Lowering]]:
    """Lower every sound multi-table predicate of ``root`` onto its
    tables' frontiers. Returns the rewritten plan (implied filters
    inserted directly above the scans, where the splitter absorbs them)
    plus the per-table :class:`Lowering` records — tables whose record has
    ``bitmap=True`` should split with ``bitmap_tables`` so their frontier
    carries the §4.2 exchange."""
    owned_by_table: Dict[str, Set[str]] = {
        t: set(parts[0].data.columns) for t, parts in catalog.tables.items()
        if parts}
    owner: Dict[str, str] = {c: t for t, cols in owned_by_table.items()
                             for c in cols}
    parents = _parent_counts(root)
    facts_at = _output_facts(root, parents, catalog)

    implied_by_table: Dict[str, ex.Expr] = {}
    seen_conjuncts: Dict[str, Set[str]] = {}
    source_by_table: Dict[str, List[str]] = {}

    def _add(table: str, implied: ex.Expr, source: str) -> None:
        if repr(implied) in seen_conjuncts.setdefault(table, set()):
            return  # same conjunct from filter- and domain-derivation
        seen_conjuncts[table].add(repr(implied))
        prev = implied_by_table.get(table)
        implied_by_table[table] = (implied if prev is None
                                   else ex.And(prev, implied))
        source_by_table.setdefault(table, []).append(source)

    for node in ir.walk(root):
        if not isinstance(node, ir.Filter):
            continue
        pred_cols = ex.columns_of(node.predicate)
        span = {owner[c] for c in pred_cols if c in owner}
        if len(span) < 2:
            continue
        for table in sorted(span):
            implied = implied_predicate(node.predicate,
                                        owned_by_table[table],
                                        facts_at.get(id(node)))
            if implied is None:
                continue
            path = _path_to_scan(node.child, table)
            if path is None or not _path_sound(path, pred_cols, parents):
                continue
            _add(table, implied, repr(node.predicate))

    # scan-level domain lowerings: a fact that survived the gated descent
    # all the way to a Scan is directly an implied In-filter on that table
    # (Q8's region-restricted nation join narrows customer without any
    # multi-table filter in between). Tables scanned more than once are
    # skipped — _insert_filters keys by table name, so a fact proven for
    # one scan instance must not leak onto the other.
    all_scans = ir.scans(root)
    scan_count: Dict[str, int] = {}
    for s in all_scans:
        scan_count[s.table] = scan_count.get(s.table, 0) + 1
    for s in all_scans:
        if scan_count[s.table] > 1:
            continue
        facts = facts_at.get(id(s)) or {}
        for col in sorted(facts):
            if owner.get(col) != s.table:
                continue
            _add(s.table, ex.In(ex.Col(col), tuple(sorted(facts[col]))),
                 f"domain[{col}]")
    if not implied_by_table:
        return root, []

    lowerings: List[Lowering] = []
    for table, implied in sorted(implied_by_table.items()):
        stats = catalog.scan_table(table).stats()
        sel = ex.estimate_selectivity(implied, stats)
        bitmap = exchange_pays(sel, len(ex.columns_of(implied)), res,
                               compute_bw)
        lowerings.append(Lowering(table, implied, bitmap, sel,
                                  "; ".join(source_by_table[table])))
    return _insert_filters(root, implied_by_table, {}), lowerings
