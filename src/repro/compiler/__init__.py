"""Logical-plan compiler with pushdown-amenability analysis.

Turns the paper's §4.1 amenability principle — *partition-parallel,
output-reducing operator prefixes are pushdown-amenable; cross-partition
joins and sorts are not* — from prose into executable code:

- ``ir.py``          relational IR (Scan/Filter/Project/Map/Aggregate/
                     Join/SemiJoin/Shuffle/TopK/Sort/PyOp) over the
                     existing ``Expr`` predicates
- ``analyzer.py``    per-operator amenability classification
- ``splitter.py``    maximal storage frontier (lowered to ``PushPlan``)
                     + compute-side residual
- ``interpreter.py`` generic residual evaluator over
                     ``queryproc/operators.py`` (replaces the seed's
                     per-query compute closures)
- ``tpch_ir.py``     the 15 TPC-H queries as IR constructions
- ``compile.py``     ``compile_query(qid)`` -> engine-ready ``Query``

New workloads are IR construction, not new closures — see docs/compiler.md.
"""
from repro.compiler import (analyzer, interpreter, ir,  # noqa: F401
                            multitable, splitter)
from repro.compiler.compile import (CompiledQuery, CutChoice,  # noqa: F401
                                    QUERY_IDS, compile_ir, compile_query,
                                    compile_query_costed,
                                    compile_query_detailed,
                                    substitute_fact_predicate)
from repro.compiler.splitter import (CompileError,  # noqa: F401
                                     frontier_signature, frontier_size)
