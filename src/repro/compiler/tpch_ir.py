"""The TPC-H workload as logical-plan IR constructions.

Same 15 queries as the seed's hand-built ``queryproc/queries.py`` (every
query named in the paper's figures), but expressed as relational IR: the
amenability split is *derived* by the compiler instead of frozen at
authoring time. Filters are written at their natural relational position —
on the branch that owns their columns — which lets the splitter push
dimension-table predicates the hand-built plans evaluated at the compute
layer, with identical results: strictly larger storage frontiers on Q5/Q8
(a new filter stage on ``nation``) and a strictly stronger pushed
predicate on Q22 (the nation-list conjunct, same stage count).

``Shuffle`` markers mirror the seed's ``shuffle_keys`` declarations (the
Fig-15 distributed-shuffle evaluation). ``PyOp`` appears exactly twice —
Q15's having-max and Q22's data-dependent balance threshold — the only
logic in the workload with no relational encoding.
"""
from __future__ import annotations

from typing import Callable, Dict, List

import numpy as np

from repro.compiler import ir
from repro.queryproc.expressions import Col
from repro.queryproc.queries import CHARGE, DISC_PRICE, REV
from repro.queryproc.table import ColumnTable
from repro.queryproc.tpch import date

C = Col


# --------------------------------------------------------------------- Q1
def q1_ir() -> ir.Node:
    cutoff = date(1998, 8, 2) - 90
    n: ir.Node = ir.Scan("lineitem", ("l_returnflag", "l_linestatus"))
    n = ir.Filter(n, C("l_shipdate") <= cutoff)
    n = ir.Map(n, (DISC_PRICE, CHARGE))
    n = ir.Aggregate(n, ("l_returnflag", "l_linestatus"),
                     (("sum_qty", "sum", "l_quantity"),
                      ("sum_base", "sum", "l_extendedprice"),
                      ("sum_disc", "sum", "disc_price"),
                      ("sum_charge", "sum", "charge"),
                      ("cnt", "count", "")))
    return ir.Sort(n, ("l_returnflag", "l_linestatus"))


# --------------------------------------------------------------------- Q3
def q3_ir() -> ir.Node:
    D = date(1995, 3, 15)
    cu: ir.Node = ir.Filter(ir.Scan("customer", ("c_custkey",)),
                            C("c_mktsegment").eq(1))
    od: ir.Node = ir.Scan("orders", ("o_orderkey", "o_custkey", "o_orderdate",
                                     "o_shippriority"))
    od = ir.Shuffle(ir.Filter(od, C("o_orderdate") < D), "o_orderkey")
    li: ir.Node = ir.Scan("lineitem", ("l_orderkey",))
    li = ir.Map(ir.Filter(li, C("l_shipdate") > D), (REV,))
    li = ir.Shuffle(li, "l_orderkey")
    j = ir.Join(od, cu, "o_custkey", "c_custkey")
    j = ir.Join(li, j, "l_orderkey", "o_orderkey")
    g = ir.Aggregate(j, ("l_orderkey", "o_orderdate", "o_shippriority"),
                     (("revenue", "sum", "revenue"),))
    return ir.TopK(g, "revenue", 10)


# --------------------------------------------------------------------- Q4
def q4_ir() -> ir.Node:
    D = date(1993, 7, 1)
    od: ir.Node = ir.Scan("orders", ("o_orderkey", "o_orderpriority"))
    od = ir.Shuffle(ir.Filter(od, C("o_orderdate").between(D, D + 92)),
                    "o_orderkey")
    li: ir.Node = ir.Scan("lineitem", ("l_orderkey",))
    li = ir.Map(li, (("_late", ("l_commitdate", "l_receiptdate"),
                      lambda c, r: (c < r).astype(np.int32)),))
    li = ir.Shuffle(li, "l_orderkey")
    late = ir.Filter(li, C("_late").eq(1))  # derived col: stays residual
    semi = ir.SemiJoin(od, late, "o_orderkey", "l_orderkey")
    return ir.Aggregate(semi, ("o_orderpriority",), (("cnt", "count", ""),))


# --------------------------------------------------------------------- Q5
def q5_ir() -> ir.Node:
    D = date(1994, 1, 1)
    cu: ir.Node = ir.Scan("customer", ("c_custkey", "c_nationkey"))
    od: ir.Node = ir.Scan("orders", ("o_orderkey", "o_custkey"))
    od = ir.Shuffle(ir.Filter(od, C("o_orderdate").between(D, D + 365)),
                    "o_orderkey")
    li: ir.Node = ir.Map(ir.Scan("lineitem", ("l_orderkey", "l_suppkey")),
                         (REV,))
    li = ir.Shuffle(li, "l_orderkey")
    su: ir.Node = ir.Scan("supplier", ("s_suppkey", "s_nationkey"))
    # region filter at its natural position: pushed to storage (the seed's
    # hand-built plan ships all 25 nations and filters at compute)
    na: ir.Node = ir.Filter(ir.Scan("nation", ("n_nationkey",)),
                            C("n_regionkey").eq(2))
    j = ir.Join(od, cu, "o_custkey", "c_custkey")
    j = ir.Join(li, j, "l_orderkey", "o_orderkey")
    j = ir.Join(j, su, "l_suppkey", "s_suppkey")
    j = ir.Filter(j, C("c_nationkey").eq(C("s_nationkey")))
    j = ir.Join(j, na, "s_nationkey", "n_nationkey")
    g = ir.Aggregate(j, ("s_nationkey",), (("revenue", "sum", "revenue"),))
    return ir.Sort(g, ("revenue",), ascending=False)


# --------------------------------------------------------------------- Q6
def q6_ir() -> ir.Node:
    D = date(1994, 1, 1)
    n: ir.Node = ir.Scan("lineitem", ())
    n = ir.Filter(n, (C("l_shipdate").between(D, D + 365)
                      & C("l_discount").between(0.05, 0.0701)
                      & (C("l_quantity") < 24)))
    n = ir.Map(n, (("disc_rev", ("l_extendedprice", "l_discount"),
                    lambda e, d: e * d),))
    return ir.Aggregate(n, (), (("revenue", "sum", "disc_rev"),))


# --------------------------------------------------------------------- Q7
def q7_ir() -> ir.Node:
    d0, d1 = date(1995, 1, 1), date(1996, 12, 31)
    li: ir.Node = ir.Scan("lineitem",
                          ("l_orderkey", "l_suppkey", "l_shipdate"))
    li = ir.Filter(li, C("l_shipdate").between(d0, d1 + 1))
    li = ir.Map(li, (("volume", ("l_extendedprice", "l_discount"),
                      lambda e, d: e * (1 - d)),))
    li = ir.Shuffle(li, "l_orderkey")
    od: ir.Node = ir.Shuffle(ir.Scan("orders", ("o_orderkey", "o_custkey")),
                             "o_orderkey")
    cu: ir.Node = ir.Scan("customer", ("c_custkey", "c_nationkey"))
    su: ir.Node = ir.Scan("supplier", ("s_suppkey", "s_nationkey"))
    j = ir.Join(li, su, "l_suppkey", "s_suppkey")
    j = ir.Join(j, od, "l_orderkey", "o_orderkey")
    j = ir.Join(j, cu, "o_custkey", "c_custkey")
    j = ir.Filter(j, (C("s_nationkey").eq(5) & C("c_nationkey").eq(7))
                  | (C("s_nationkey").eq(7) & C("c_nationkey").eq(5)))
    j = ir.Map(j, (("l_year", ("l_shipdate",),
                    lambda s: (s // 365).astype(np.int32)),))
    g = ir.Aggregate(j, ("s_nationkey", "c_nationkey", "l_year"),
                     (("revenue", "sum", "volume"),))
    return ir.Sort(g, ("s_nationkey", "c_nationkey", "l_year"))


# --------------------------------------------------------------------- Q8
def q8_ir() -> ir.Node:
    d0, d1 = date(1995, 1, 1), date(1996, 12, 31)
    od: ir.Node = ir.Scan("orders", ("o_orderkey", "o_custkey",
                                     "o_orderdate"))
    od = ir.Shuffle(ir.Filter(od, C("o_orderdate").between(d0, d1 + 1)),
                    "o_orderkey")
    li: ir.Node = ir.Scan("lineitem", ("l_orderkey", "l_partkey",
                                       "l_suppkey"))
    li = ir.Map(li, (("volume", ("l_extendedprice", "l_discount"),
                      lambda e, d: e * (1 - d)),))
    li = ir.Shuffle(li, "l_orderkey")
    pa: ir.Node = ir.Filter(ir.Scan("part", ("p_partkey",)),
                            C("p_type").eq(42))
    cu: ir.Node = ir.Scan("customer", ("c_custkey", "c_nationkey"))
    su: ir.Node = ir.Scan("supplier", ("s_suppkey", "s_nationkey"))
    # region filter pushed (seed joins all nations, filters at compute)
    na: ir.Node = ir.Filter(ir.Scan("nation", ("n_nationkey",)),
                            C("n_regionkey").eq(1))
    j = ir.Join(li, pa, "l_partkey", "p_partkey")
    j = ir.Join(j, od, "l_orderkey", "o_orderkey")
    j = ir.Join(j, cu, "o_custkey", "c_custkey")
    j = ir.Join(j, na, "c_nationkey", "n_nationkey")
    j = ir.Join(j, su, "l_suppkey", "s_suppkey")
    j = ir.Map(j, (("o_year", ("o_orderdate",),
                    lambda d: (d // 365).astype(np.int32)),
                   ("nat_volume", ("s_nationkey", "volume"),
                    lambda s, v: (s == 3).astype(np.float64) * v)))
    g = ir.Aggregate(j, ("o_year",), (("nat", "sum", "nat_volume"),
                                      ("total", "sum", "volume")))
    g = ir.Map(g, (("mkt_share", ("nat", "total"),
                    lambda n, t: n / np.maximum(t, 1e-9)),))
    return ir.Project(g, ("o_year", "mkt_share"))


# -------------------------------------------------------------------- Q10
def q10_ir() -> ir.Node:
    D = date(1993, 10, 1)
    cu: ir.Node = ir.Scan("customer", ("c_custkey", "c_nationkey",
                                       "c_acctbal"))
    od: ir.Node = ir.Scan("orders", ("o_orderkey", "o_custkey"))
    od = ir.Shuffle(ir.Filter(od, C("o_orderdate").between(D, D + 92)),
                    "o_orderkey")
    li: ir.Node = ir.Scan("lineitem", ("l_orderkey",))
    li = ir.Map(ir.Filter(li, C("l_returnflag").eq(2)), (REV,))
    li = ir.Shuffle(li, "l_orderkey")
    j = ir.Join(li, od, "l_orderkey", "o_orderkey")
    j = ir.Join(j, cu, "o_custkey", "c_custkey")
    g = ir.Aggregate(j, ("o_custkey",), (("revenue", "sum", "revenue"),))
    return ir.TopK(g, "revenue", 20)


# -------------------------------------------------------------------- Q12
def q12_ir() -> ir.Node:
    D = date(1994, 1, 1)
    li: ir.Node = ir.Scan("lineitem", ("l_orderkey", "l_shipmode"))
    li = ir.Filter(li, C("l_shipmode").isin((0, 4))
                   & C("l_receiptdate").between(D, D + 365))
    li = ir.Map(li, (("_ontime",
                      ("l_shipdate", "l_commitdate", "l_receiptdate"),
                      lambda s, c, r: ((s < c) & (c < r)).astype(np.int32)),))
    li = ir.Shuffle(li, "l_orderkey")
    li = ir.Filter(li, C("_ontime").eq(1))  # derived col: stays residual
    od: ir.Node = ir.Shuffle(
        ir.Scan("orders", ("o_orderkey", "o_orderpriority")), "o_orderkey")
    j = ir.Join(li, od, "l_orderkey", "o_orderkey")
    j = ir.Map(j, (("high", ("o_orderpriority",),
                    lambda p: np.isin(p, (0, 1)).astype(np.int64)),
                   ("low", ("high",), lambda h: 1 - h)))
    g = ir.Aggregate(j, ("l_shipmode",), (("high_cnt", "sum", "high"),
                                          ("low_cnt", "sum", "low")))
    return ir.Sort(g, ("l_shipmode",))


# -------------------------------------------------------------------- Q14
def q14_ir() -> ir.Node:
    D = date(1995, 9, 1)
    li: ir.Node = ir.Scan("lineitem", ("l_partkey",))
    li = ir.Map(ir.Filter(li, C("l_shipdate").between(D, D + 30)), (REV,))
    li = ir.Shuffle(li, "l_partkey")
    pa: ir.Node = ir.Shuffle(ir.Scan("part", ("p_partkey", "p_type")),
                             "p_partkey")
    j = ir.Join(li, pa, "l_partkey", "p_partkey")
    j = ir.Map(j, (("promo", ("p_type", "revenue"),
                    lambda t, r: (t < 15).astype(np.float64) * r),))
    g = ir.Aggregate(j, (), (("num", "sum", "promo"),
                             ("den", "sum", "revenue")))
    g = ir.Map(g, (("promo_revenue", ("num", "den"),
                    lambda n, d: 100.0 * n / np.maximum(d, 1e-9)),))
    return ir.Project(g, ("promo_revenue",))


# -------------------------------------------------------------------- Q15
def _q15_top(g: ColumnTable) -> ColumnTable:
    mx = g.cols["total_rev"].max() if len(g) else 0.0
    return g.filter(g.cols["total_rev"] >= mx - 1e-9)


def q15_ir() -> ir.Node:
    D = date(1996, 1, 1)
    li: ir.Node = ir.Scan("lineitem", ())
    li = ir.Map(ir.Filter(li, C("l_shipdate").between(D, D + 92)), (REV,))
    li = ir.Aggregate(li, ("l_suppkey",), (("total_rev", "sum", "revenue"),))
    li = ir.Shuffle(li, "l_suppkey")
    su: ir.Node = ir.Scan("supplier", ("s_suppkey", "s_nationkey"))
    top = ir.PyOp((li,), _q15_top, note="having total_rev == max(total_rev)")
    return ir.Join(top, su, "l_suppkey", "s_suppkey")


# -------------------------------------------------------------------- Q17
def q17_ir() -> ir.Node:
    li: ir.Node = ir.Shuffle(
        ir.Scan("lineitem", ("l_partkey", "l_quantity", "l_extendedprice")),
        "l_partkey")
    pa: ir.Node = ir.Filter(ir.Scan("part", ("p_partkey",)),
                            C("p_brand").eq(3) & C("p_container").eq(7))
    pa = ir.Shuffle(pa, "p_partkey")
    j = ir.Join(li, pa, "l_partkey", "p_partkey")
    g = ir.Aggregate(j, ("l_partkey",), (("avg_qty", "mean", "l_quantity"),))
    jj = ir.Join(j, g, "l_partkey", "l_partkey")  # shared subtree: j reused
    jj = ir.Map(jj, (("qty_thresh", ("avg_qty",), lambda a: 0.2 * a),))
    jj = ir.Filter(jj, C("l_quantity") < C("qty_thresh"))
    s = ir.Aggregate(jj, (), (("total", "sum", "l_extendedprice"),))
    s = ir.Map(s, (("avg_yearly", ("total",), lambda t: t / 7.0),))
    return ir.Project(s, ("avg_yearly",))


# -------------------------------------------------------------------- Q18
def q18_ir(threshold: float = 150.0) -> ir.Node:
    li: ir.Node = ir.Scan("lineitem", ())
    li = ir.Aggregate(li, ("l_orderkey",), (("sum_qty", "sum", "l_quantity"),))
    li = ir.Shuffle(li, "l_orderkey")
    big = ir.Filter(li, C("sum_qty") > threshold)
    od: ir.Node = ir.Shuffle(
        ir.Scan("orders", ("o_orderkey", "o_custkey", "o_orderdate",
                           "o_totalprice")), "o_orderkey")
    j = ir.Join(big, od, "l_orderkey", "o_orderkey")
    return ir.TopK(j, "o_totalprice", 100)


# -------------------------------------------------------------------- Q19
def q19_ir() -> ir.Node:
    li: ir.Node = ir.Scan("lineitem", ("l_partkey", "l_quantity"))
    li = ir.Filter(li, (C("l_shipmode").isin((0, 1))
                        & C("l_shipinstruct").eq(2)
                        & ((C("l_quantity").between(1, 12)
                            | C("l_quantity").between(10, 21))
                           | C("l_quantity").between(20, 31))))
    li = ir.Shuffle(ir.Map(li, (REV,)), "l_partkey")
    pa: ir.Node = ir.Shuffle(
        ir.Scan("part", ("p_partkey", "p_brand", "p_container", "p_size")),
        "p_partkey")
    j = ir.Join(li, pa, "l_partkey", "p_partkey")
    j = ir.Filter(j, ((C("p_brand").eq(3) & (C("p_container") < 10)
                       & (C("l_quantity") < 12) & (C("p_size") <= 5))
                      | (C("p_brand").eq(5) & (C("p_container") < 20)
                         & (C("l_quantity") < 21) & (C("p_size") <= 10))
                      | (C("p_brand").eq(9) & (C("p_container") < 40)
                         & (C("l_quantity") < 31) & (C("p_size") <= 15))))
    return ir.Aggregate(j, (), (("revenue", "sum", "revenue"),))


# -------------------------------------------------------------------- Q22
def _q22_rich(c: ColumnTable) -> ColumnTable:
    avg = c.cols["c_acctbal"].mean() if len(c) else 0.0
    return c.filter(c.cols["c_acctbal"] > avg)


def q22_ir() -> ir.Node:
    cu: ir.Node = ir.Scan("customer", ("c_custkey", "c_nationkey",
                                       "c_acctbal"))
    # both conjuncts pushed (seed pushes only the balance predicate and
    # evaluates the nation list at compute)
    cu = ir.Filter(cu, (C("c_acctbal") > 0.0)
                   & C("c_nationkey").isin((13, 17, 19, 21, 23)))
    od: ir.Node = ir.Shuffle(ir.Scan("orders", ("o_custkey",)), "o_custkey")
    rich = ir.PyOp((cu,), _q22_rich, note="acctbal above segment average")
    noord = ir.SemiJoin(rich, od, "c_custkey", "o_custkey", anti=True)
    g = ir.Aggregate(noord, ("c_nationkey",),
                     (("numcust", "count", ""),
                      ("totacctbal", "sum", "c_acctbal")))
    return ir.Sort(g, ("c_nationkey",))


IR_BUILDERS: Dict[str, Callable[[], ir.Node]] = {
    f.__name__[:-3].upper(): f for f in (
        q1_ir, q3_ir, q4_ir, q5_ir, q6_ir, q7_ir, q8_ir, q10_ir, q12_ir,
        q14_ir, q15_ir, q17_ir, q18_ir, q19_ir, q22_ir)}
QUERY_IDS: List[str] = sorted(IR_BUILDERS, key=lambda q: int(q[1:]))


def build_ir(qid: str) -> ir.Node:
    return IR_BUILDERS[qid.upper()]()
