"""Shared filter-pushability rule (ROADMAP open item).

The splitter's absorption loop and ``compile.substitute_fact_predicate``'s
drop-walk used to encode the same question twice — "is this Filter a
pushable storage-side filter, or residual?" — with independently-maintained
conditions that could drift. Both now call :func:`filter_absorbable`, the
single source of truth:

A ``Filter`` on a unary chain over a ``Scan`` is pushable iff

1. no ``Aggregate``/``TopK`` sits below it on the chain — the PushPlan
   stage order evaluates predicates *before* (partial) aggregation, so a
   filter above an absorbed aggregate is residual by construction (it
   filters merged partials, e.g. Q18's HAVING); and
2. its predicate touches only base columns — columns produced below it on
   the chain (Map derives, Aggregate outputs: Q4's ``_late``, Q12's
   ``_ontime``) do not exist at the storage scan's predicate stage.

``Shuffle`` markers are row-preserving and produce no columns, so the walks
skip through them.
"""
from __future__ import annotations

from typing import Optional, Set

from repro.compiler import ir
from repro.queryproc import expressions as ex


def chain_scan_table(node: ir.Node) -> Optional[str]:
    """The base table when ``node`` sits on a pure unary chain over a Scan;
    None when the chain bottoms out at a join/PyOp leaf."""
    cur = node
    while isinstance(cur, ir.UNARY_TYPES):
        cur = cur.child
    return cur.table if isinstance(cur, ir.Scan) else None


def blocking_op_below(node: ir.Node) -> bool:
    """True when an Aggregate/TopK sits strictly below ``node`` on its
    unary chain (condition 1 above)."""
    cur = node.child if isinstance(node, ir.UNARY_TYPES) else node
    while isinstance(cur, ir.UNARY_TYPES):
        if isinstance(cur, (ir.Aggregate, ir.TopK)):
            return True
        cur = cur.child
    return False


def derived_names_below(node: ir.Node) -> Set[str]:
    """Columns that only exist above some producer strictly below ``node``
    on its unary chain — Map derives AND Aggregate outputs (condition 2)."""
    names: Set[str] = set()
    cur = node.child if isinstance(node, ir.UNARY_TYPES) else node
    while isinstance(cur, ir.UNARY_TYPES):
        if isinstance(cur, ir.Map):
            names |= {n for n, _, _ in cur.derives}
        elif isinstance(cur, ir.Aggregate):
            names |= {out for out, _, _ in cur.aggs}
        cur = cur.child
    return names


def filter_absorbable(node: ir.Filter) -> bool:
    """THE shared predicate: may this Filter be absorbed into the storage
    frontier (splitter), equivalently dropped as a pushable fact filter by
    the fact-selectivity substitution (compile)?"""
    return (not blocking_op_below(node)
            and not (ex.columns_of(node.predicate) & derived_names_below(node)))
