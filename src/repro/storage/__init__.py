from repro.storage.catalog import Catalog, Partition, StorageNode  # noqa: F401
