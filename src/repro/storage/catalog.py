"""Disaggregated storage layer: nodes holding columnar partitions.

Mirrors the paper's prototype (§5.1): data objects on node-local storage,
accessed by the compute layer through per-partition requests. Tables are
sharded into fixed-row partitions (the paper uses ~150 MB objects) and
round-robin distributed over the storage nodes.

Byte accounting uses the per-column *stored* sizes from the compression
model in ``repro.queryproc.table`` (column-oriented format: a request only
pays for the columns it touches — the paper's Parquet setup).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.queryproc.table import ColumnTable


@dataclasses.dataclass
class Partition:
    table: str
    index: int          # partition number within the table
    node_id: int        # storage node that owns it
    data: ColumnTable
    # monotone version stamp: every append/update bumps it, so any cached
    # derivation of this partition's bytes (core.result_cache keys entries
    # by it) can detect staleness without content hashing
    version: int = 0

    def bytes_stored(self, columns: Optional[Sequence[str]] = None) -> int:
        return self.data.nbytes(columns, stored=True)

    def bytes_raw(self, columns: Optional[Sequence[str]] = None) -> int:
        return self.data.nbytes(columns, stored=False)


@dataclasses.dataclass
class StorageNode:
    node_id: int
    partitions: List[Partition] = dataclasses.field(default_factory=list)


class Catalog:
    """Table -> partitions placement across storage nodes."""

    def __init__(self, num_nodes: int = 1):
        self.nodes: List[StorageNode] = [StorageNode(i) for i in range(num_nodes)]
        self.tables: Dict[str, List[Partition]] = {}
        # table -> cluster key: partition boundaries are aligned to runs of
        # this key, so every key value is wholly inside one partition
        # (group-locality — what makes storage-side HAVING over partial
        # aggregates sound; see compiler/splitter.py)
        self.clustered: Dict[str, str] = {}

    @property
    def num_nodes(self) -> int:
        return len(self.nodes)

    def add_table(self, name: str, data: ColumnTable, rows_per_partition: int,
                  cluster_key: Optional[str] = None):
        """Shard ``data`` into ~fixed-row partitions.

        With ``cluster_key`` the table is first stably sorted by that key
        and each partition boundary is pushed forward to the end of the
        key run it lands in — partitions stay ~rows_per_partition rows but
        no key value straddles two partitions."""
        parts: List[Partition] = []
        if cluster_key is not None:
            order = np.argsort(np.asarray(data.cols[cluster_key]),
                               kind="stable")
            data = ColumnTable({k: np.asarray(v)[order]
                                for k, v in data.cols.items()})
            self.clustered[name] = cluster_key
            sk = np.asarray(data.cols[cluster_key])
            n = len(data)
            bounds = [0]
            while bounds[-1] < n:
                j = min(n, bounds[-1] + rows_per_partition)
                if j < n:
                    # extend to the end of the run of sk[j-1]
                    j = int(np.searchsorted(sk, sk[j - 1], side="right"))
                bounds.append(j)
            slices = [slice(bounds[i], bounds[i + 1])
                      for i in range(len(bounds) - 1)]
        else:
            n = len(data)
            num_parts = max(1, -(-n // rows_per_partition))
            slices = [slice(i * rows_per_partition,
                            min(n, (i + 1) * rows_per_partition))
                      for i in range(num_parts)]
        for i, sl in enumerate(slices):
            chunk = ColumnTable({k: v[sl] for k, v in data.cols.items()})
            node = self.nodes[i % self.num_nodes]
            part = Partition(name, i, node.node_id, chunk)
            node.partitions.append(part)
            parts.append(part)
        self.tables[name] = parts

    def append_to_partition(self, table: str, index: int,
                            rows: ColumnTable) -> Partition:
        """Append ``rows`` to one partition and bump its version stamp.

        Cached results derived from the old bytes go stale and are evicted
        lazily on their next lookup (core.result_cache). Callers are
        responsible for respecting a clustered table's group-locality
        invariant — appended rows must not introduce cluster-key values
        owned by another partition."""
        part = self.tables[table][index]
        part.data = ColumnTable({
            c: np.concatenate([np.asarray(v), np.asarray(rows.cols[c])])
            for c, v in part.data.cols.items()})
        part.version += 1
        return part

    def update_partition(self, table: str, index: int,
                         data: ColumnTable) -> Partition:
        """Replace one partition's bytes wholesale; bumps the version stamp
        (same staleness contract as ``append_to_partition``)."""
        part = self.tables[table][index]
        part.data = data
        part.version += 1
        return part

    def group_local(self, table: str, keys) -> bool:
        """True iff a group-by over ``keys`` cannot straddle partitions —
        i.e. the table is clustered and its cluster key is one of the
        group keys."""
        ck = self.clustered.get(table)
        return ck is not None and ck in tuple(keys)

    def partitions_of(self, table: str) -> List[Partition]:
        return self.tables[table]

    def scan_table(self, table: str, columns: Optional[Sequence[str]] = None
                   ) -> ColumnTable:
        parts = self.tables[table]
        tabs = [p.data if columns is None else p.data.select(columns)
                for p in parts]
        return ColumnTable.concat(tabs)

    def iter_partitions(self) -> Iterator[Partition]:
        for node in self.nodes:
            yield from node.partitions

    def table_bytes(self, table: str, columns=None, stored=True) -> int:
        return sum((p.bytes_stored(columns) if stored else p.bytes_raw(columns))
                   for p in self.tables[table])
