"""Logical-axis -> mesh-axis sharding rules (GSPMD via NamedSharding).

Every ParamSpec in the model zoo carries *logical* axis names
("embed", "heads", "mlp", "experts", "vocab", "batch", "kv_seq", ...).
This module turns a spec tree into `NamedSharding`s for a concrete mesh.

Baseline layout (paper-faithful "eager" distribution; the hillclimb in
EXPERIMENTS.md §Perf iterates on these rules):

- FSDP  : "embed" (the d_model dim present in every matmul weight) shards
          over the `data` axis -> ZeRO-3-style weight/grad/opt-state sharding.
- TP    : "heads"/"kv_heads"/"mlp"/"inner"/"experts"/"vocab" shard over
          `model` (Megatron-style).
- DP    : "batch" shards over (`pod`, `data`) — the pod axis is pure DP.
- SP    : "kv_seq" (decode KV caches) shards over `model`; flash-decoding
          style partial-softmax combines are left to GSPMD (an all-reduce of
          (B, H, 1, hd) partials — tiny).

A rule is applied *only if divisible* and only if the mesh axis is not
already consumed by an earlier dim of the same tensor; otherwise the dim
falls through to the next candidate axis (or replication). This is what
lets one rule table serve kv_heads=1 (recurrentgemma) through kv_heads=20
(qwen1.5) without per-arch special cases.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import params as Pm

# logical axis -> ordered candidate mesh axes. Each candidate is either a
# mesh-axis name or a tuple of names (sharded over their product).
Rules = Dict[Optional[str], Tuple]

BASELINE_RULES: Rules = {
    "embed": ("data",),
    "vocab": ("model",),
    "mlp": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "experts": ("model",),
    "inner": ("model",),
    "inner2": (),          # second dim of square recurrent mats: replicated
    "layers": (),          # scanned dim: never sharded
    "batch": (("pod", "data"), "data"),
    "kv_seq": ("model",),
    "kv_hd": (),           # kv head_dim: sharded only when kv_heads can't be
    "act_seq": (),         # residual-stream sequence dim (SP rules enable)
    "attn_seq": ("model",),  # context-parallel fallback inside attention when
                             # the head count doesn't divide the model axis
    None: (),
}

# Serving layout: NO FSDP — per-token weight all-gathers would dominate
# decode (measured 16.8 GB/step on deepseek-67b decode_32k under the train
# rules). Weights shard over `model` on heads/mlp/vocab, and over kv head_dim
# when the kv-head count doesn't divide the axis; `data` carries the batch
# and the KV-cache; `kv_seq` takes `model`.
INFERENCE_RULES: Rules = dict(
    BASELINE_RULES,
    embed=(),
    kv_hd=("model",),
    # 2D expert sharding: experts take `model`, the ffn dim falls through to
    # `data`. Contractions against the (E, C, d) dispatch buffer psum over
    # `data` — no batch conflict, since expert compute has no batch dim.
    mlp=("model", "data"),
)

# §Perf: sequence-parallel residual stream — activations stay sharded on
# the seq dim over `model` between attention/MLP blocks, so backward's
# dx reductions become reduce-scatters of bf16 shards instead of fp32
# full-tensor all-reduces (Megatron-SP made rule-driven).
SP_RULES: Rules = dict(BASELINE_RULES, act_seq=("model",))

# Beyond-paper variant used by the §Perf hillclimb: fully-sharded states
# (FSDP over data *and* pod) + sequence-parallel activations.
ZERO3_POD_RULES: Rules = dict(
    BASELINE_RULES,
    embed=(("pod", "data"), "data"),
    act_seq=("model",),
)

# assignment priority: TP-critical names first, then FSDP/batch, then
# sequence fallbacks — so e.g. `attn_seq` only takes `model` when the head
# dim couldn't (40 heads on a 16-wide axis).
_PRIORITY = {
    "vocab": 0, "experts": 0,
    "heads": 1, "kv_heads": 1, "mlp": 1, "inner": 1,
    "kv_hd": 2,
    "embed": 3,
    "batch": 4,
    "kv_seq": 5, "attn_seq": 5, "act_seq": 5,
}


def _axis_size(mesh: Mesh, cand) -> int:
    names = cand if isinstance(cand, tuple) else (cand,)
    sz = 1
    for n in names:
        sz *= mesh.shape[n]
    return sz


def _cand_names(cand) -> Tuple[str, ...]:
    return cand if isinstance(cand, tuple) else (cand,)


def spec_to_pspec(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
                  mesh: Mesh, rules: Rules) -> P:
    """Greedy assignment of mesh axes to tensor dims, in _PRIORITY order
    (ties broken left-to-right), each mesh axis used at most once."""
    used: set = set()
    out = [None] * len(shape)
    order = sorted(range(len(shape)),
                   key=lambda i: (_PRIORITY.get(axes[i], 9), i))
    for i in order:
        dim, name = shape[i], axes[i]
        for cand in rules.get(name, ()):
            names = _cand_names(cand)
            if any(n not in mesh.shape for n in names):
                continue
            if any(n in used for n in names):
                continue
            if dim % _axis_size(mesh, cand) != 0 or dim == 0:
                continue
            out[i] = cand
            used.update(names)
            break
    # trim trailing Nones (canonical PartitionSpec form)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_pspecs(spec_tree, mesh: Mesh, rules: Rules = BASELINE_RULES):
    return Pm.tree_map_specs(
        lambda s: spec_to_pspec(s.shape, s.axes, mesh, rules), spec_tree)


def tree_shardings(spec_tree, mesh: Mesh, rules: Rules = BASELINE_RULES):
    return Pm.tree_map_specs(
        lambda s: NamedSharding(mesh, spec_to_pspec(s.shape, s.axes, mesh, rules)),
        spec_tree)


def abstract(spec_tree, mesh: Mesh, rules: Rules = BASELINE_RULES):
    """ShapeDtypeStruct tree with shardings attached (AOT dry-run input)."""
    return Pm.tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(mesh, spec_to_pspec(s.shape, s.axes, mesh, rules))),
        spec_tree)


def batch_pspec(mesh: Mesh, rules: Rules = BASELINE_RULES) -> P:
    """PartitionSpec entry for a batch dim under these rules."""
    return spec_to_pspec((1 << 30,), ("batch",), mesh, rules)


def batch_axes(mesh: Mesh, rules: Rules = BASELINE_RULES) -> Tuple[str, ...]:
    ps = batch_pspec(mesh, rules)
    if not ps:
        return ()
    e = ps[0]
    return e if isinstance(e, tuple) else (e,)
