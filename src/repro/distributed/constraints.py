"""Logical activation-sharding constraints.

Model code is mesh-agnostic: it annotates activations with *logical* axis
names via ``cs(x, "batch", "act_seq", "heads", None)``. When a mesh+rules
context is active (set by ``repro.launch.steps`` while tracing a step),
the names resolve through the same rule table as the parameters and become
``with_sharding_constraint``; otherwise ``cs`` is a no-op (smoke tests,
single-device runs).

Why this exists: FSDP shards the *contracting* dim of every weight, so
without activation anchors GSPMD tends to resolve the batch-vs-contracting
conflict by replicating attention heads / MLP hidden activations — measured
~7x per-layer FLOP inflation on the 16x16 mesh (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import NamedSharding

# NOTE: repro.distributed.sharding is imported lazily inside cs() —
# model modules import this file, and sharding.py imports the model
# param helpers (cycle otherwise).

_ACTIVE = contextvars.ContextVar("repro_act_ctx", default=None)


@contextlib.contextmanager
def activation_sharding(mesh, rules=None):
    if rules is None:
        from repro.distributed import sharding as shd
        rules = shd.BASELINE_RULES
    tok = _ACTIVE.set((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def active() -> bool:
    return _ACTIVE.get() is not None


def cs(x: jax.Array, *names):
    """Constrain ``x``'s dims to the mesh axes the logical ``names`` map to
    (per-dim divisibility-checked; unmapped dims replicate)."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    from repro.distributed import sharding as shd
    mesh, rules = ctx
    spec = shd.spec_to_pspec(x.shape, names, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def cs_like(x: jax.Array, sharding):
    """Constrain to an explicit NamedSharding (e.g. grads -> param layout)."""
    if _ACTIVE.get() is None:
        return x
    return jax.lax.with_sharding_constraint(x, sharding)


def mesh_axis_size(name: str) -> int:
    """Size of a mesh axis in the active context (1 when inactive/absent).
    Lets model code make divisibility-dependent impl choices (§Perf)."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return 1
    mesh, _ = ctx
    return mesh.shape.get(name, 1)
