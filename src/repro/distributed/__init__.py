from repro.distributed import sharding  # noqa: F401

# NOTE: `workers` (the multi-process storage tier) is intentionally NOT
# imported here — it pulls in multiprocessing/socket machinery that every
# in-process engine path should stay free of. Import it explicitly:
#     from repro.distributed.workers import WorkerPool, pool_for
