"""Explicit shard_map collectives: the paper's shuffle as an in-mesh
primitive + distributed-optimization tricks.

- ``expert_all_to_all_dispatch``: the in-mesh analogue of distributed-
  data-shuffle pushdown (§4.2). The baseline MoE keeps the (E, C, d)
  buffer sharded over the expert axis and lets GSPMD re-shard; this
  variant hash-routes tokens to expert shards with ONE all_to_all from the
  producer — exactly Fig 5(b)'s "partition at the source, send straight to
  the target" applied to the TP mesh. Used by the §Perf hillclimb.

- ``compressed_psum``: int8 error-feedback gradient all-reduce. Gradients
  quantize to int8 with a per-tensor scale; the quantization error feeds
  back into the next step's gradient (error-feedback keeps SGD unbiased
  in the long run). Cross-pod (DCN) traffic drops 4x for f32 grads.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


# ---------------------------------------------------------- EP dispatch
def expert_all_to_all_dispatch(x_by_expert: jax.Array, mesh: Mesh,
                               axis: str = "model") -> jax.Array:
    """(E, C, d) token buffer, E sharded over ``axis`` at the *producer*
    (each shard scattered its local tokens into all E expert slots) ->
    buffer where shard i holds ONLY its experts' rows from every producer,
    i.e. the post-shuffle layout. One all_to_all; no all-gather.

    Mirrors ops.shuffle_partition: partition at source, route to target."""
    E = x_by_expert.shape[0]
    n = mesh.shape[axis]
    assert E % n == 0, (E, n)

    def body(local):  # local: (E, C_local, d) — producer's slice over C
        # split expert dim into n groups and exchange: group j -> shard j
        return jax.lax.all_to_all(local, axis, split_axis=0, concat_axis=1,
                                  tiled=True)

    return shard_map(body, mesh=mesh,
                     in_specs=P(None, axis, None),
                     out_specs=P(axis, None, None))(x_by_expert)


def expert_all_to_all_combine(y_by_expert: jax.Array, mesh: Mesh,
                              axis: str = "model") -> jax.Array:
    """Inverse of the dispatch (expert results back to producers)."""
    def body(local):  # (E_local, C, d)
        return jax.lax.all_to_all(local, axis, split_axis=1, concat_axis=0,
                                  tiled=True)

    return shard_map(body, mesh=mesh,
                     in_specs=P(axis, None, None),
                     out_specs=P(None, axis, None))(y_by_expert)


# ------------------------------------------------- compressed all-reduce
def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(grad: jax.Array, err: jax.Array, mesh: Mesh,
                    axis: str = "pod") -> Tuple[jax.Array, jax.Array]:
    """Error-feedback int8 all-reduce over ``axis``.

    grad: this shard's gradient contribution (f32), err: carried
    quantization error from the previous step (same shape). Returns
    (reduced gradient estimate, new error). Traffic: 1 byte/elem over the
    cross-pod axis instead of 4 (plus one scalar)."""
    def body(g, e):
        v = g + e
        # agree on a COMMON scale first (one scalar all-reduce) so the
        # integer psum dequantizes exactly; per-element error is then only
        # each shard's own rounding, which the feedback carries forward
        scale = jax.lax.pmax(jnp.maximum(jnp.max(jnp.abs(v)), 1e-30), axis) \
            / 127.0
        q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
        new_err = v - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis)
        approx = total.astype(jnp.float32) * scale
        return approx, new_err

    n = mesh.shape[axis]
    if n == 1:
        # degenerate mesh: nothing to reduce, but the carried error MUST
        # still fold into the estimate — dropping it here would silently
        # bias error-feedback (the shard_map path returns g+e exactly,
        # since a single shard's common-scale quantization round-trips
        # through its own rounding and new_err absorbs the difference:
        # approx + new_err == g + e). Conservation pinned by
        # tests/test_distributed.py::test_compressed_psum_n1_error_feedback.
        return grad + err, jnp.zeros_like(err)
    return shard_map(body, mesh=mesh,
                     in_specs=(P(axis), P(axis)),
                     out_specs=(P(axis), P(axis)))(grad, err)
