"""Multi-process storage tier: real worker processes behind a wire codec.

``runtime.run_stream`` historically *simulated* storage nodes as thread
pools inside one process — the Arbitrator reacted to simulator slot
counts, not actual storage-side pressure. This module splits the storage
layer into real **storage-worker processes** (one per catalog node, forked
``multiprocessing`` children talking over a socketpair), each owning the
disjoint partition set of its node:

- the compute layer dispatches compiled ``PushPlan``s **over the wire**
  (a small length-prefixed codec: u32 frame length | u32 header length |
  JSON header | raw body — ColumnTable columns travel as raw dtype/shape
  tagged buffers, plan specs as a marshal-backed pickle that survives the
  lambdas in ``derive`` tuples);
- pushback fetches the raw accessed-column projection as **real
  serialized bytes** (``fetch_projection``), so the transfer is an actual
  inter-process copy, not an in-heap view;
- every worker response carries a live load snapshot (queue depth,
  in-flight, CPU occupancy) that the pool publishes into the very
  ``stream.node<N>.exec_queue``/``ship_queue`` gauges the Arbitrator's
  ``MeasuredLoad`` polls — per-worker admission control reacting to real
  storage-side pressure (``burn()`` injects that pressure for the
  decision-shift benchmark);
- worker-side spans ride back in the response and are adopted into the
  compute-side trace under the dispatching span (span-id handoff:
  requests carry the parent span's ``sid``, worker records echo it as
  ``remote_parent``);
- a dead channel (worker SIGKILL -> EOF) or an overdue request surfaces
  as :class:`core.faults.WorkerFault` (``crash``/``timeout``) and flows
  through the existing retry -> deadline -> demote-to-pushback recovery
  machinery — the fault domain moved from injected schedules to real
  process failure, and recovery stays byte-identical (demotion replays
  from the parent's catalog copy: the durable-store tier is outside the
  storage fault domain, per the PR-8 contract).

``EngineConfig.storage_tier="process"`` routes execution through a pool;
``"inproc"`` (the default) is the oracle — all 15 queries are
byte-identical across tiers for any decision vector and fault schedule
(tests/test_workers.py). See docs/distributed.md for the wire protocol
and the load-signal schema.
"""
from __future__ import annotations

import atexit
import hashlib
import io
import itertools
import json
import marshal
import multiprocessing
import os
import pickle
import queue
import signal
import socket
import struct
import threading
import time
import types
from concurrent.futures import Future, TimeoutError as FutTimeout
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core import faults as _faults
from repro.core.executor import (EXECUTOR_REFERENCE, CompiledPushPlan,
                                 compile_push_plan)
from repro.core.plan import execute_push_plan
from repro.obs import trace as obs_trace
from repro.obs.metrics import get_metrics
from repro.queryproc.table import ColumnTable

__all__ = ["WorkerPool", "pool_for", "close_all_pools",
           "encode_plan", "decode_plan"]

_U32 = struct.Struct("<I")


# ------------------------------------------------------------- wire framing
def _write_frame(sock: socket.socket, header: Dict, body: bytes = b"") -> int:
    """One length-prefixed frame: u32 total | u32 hlen | header | body.
    Returns the bytes written (the wire-byte accounting unit)."""
    h = json.dumps(header, separators=(",", ":")).encode("utf-8")
    frame = b"".join((_U32.pack(4 + len(h) + len(body)), _U32.pack(len(h)),
                      h, body))
    sock.sendall(frame)
    return len(frame)


def _read_exact(sock: socket.socket, n: int) -> bytearray:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    while got < n:
        k = sock.recv_into(view[got:], n - got)
        if k == 0:
            raise EOFError("channel closed")
        got += k
    return buf


def _read_frame(sock: socket.socket) -> Tuple[Dict, memoryview, int]:
    """Returns (header, body view, total frame bytes)."""
    total = _U32.unpack(bytes(_read_exact(sock, 4)))[0]
    payload = _read_exact(sock, total)
    hlen = _U32.unpack(bytes(payload[:4]))[0]
    header = json.loads(bytes(payload[4:4 + hlen]).decode("utf-8"))
    return header, memoryview(payload)[4 + hlen:], 4 + total


# ------------------------------------------------------- value/table codec
class _Cursor:
    """Sequential reader over a frame body (buffers decode in the order
    they were appended by ``_enc``)."""

    def __init__(self, body):
        self.body = memoryview(body)
        self.off = 0

    def take(self, n: int) -> memoryview:
        v = self.body[self.off:self.off + n]
        self.off += n
        return v


def _enc_arr(a: np.ndarray, bufs: List[bytes]) -> Dict:
    a = np.ascontiguousarray(a)
    raw = a.tobytes()
    bufs.append(raw)
    return {"!": "nd", "d": a.dtype.str, "s": list(a.shape), "n": len(raw)}


def _enc(v, bufs: List[bytes]):
    """Encode a value tree into a JSON-able header structure + raw body
    buffers. Covers everything a push-plan result/aux can hold: scalars,
    numpy arrays, ColumnTables, and (possibly nested) list/tuple/dict."""
    if v is None or isinstance(v, (bool, str)):
        return v
    if isinstance(v, (int, np.integer)):
        return int(v)
    if isinstance(v, (float, np.floating)):
        return float(v)
    if isinstance(v, np.ndarray):
        return _enc_arr(v, bufs)
    if isinstance(v, ColumnTable):
        return {"!": "ct",
                "c": [[c, _enc_arr(v.cols[c], bufs)] for c in v.columns]}
    if isinstance(v, tuple):
        return {"!": "tu", "v": [_enc(x, bufs) for x in v]}
    if isinstance(v, list):
        return {"!": "li", "v": [_enc(x, bufs) for x in v]}
    if isinstance(v, dict):
        return {"!": "di",
                "v": [[_enc(k, bufs), _enc(x, bufs)] for k, x in v.items()]}
    raise TypeError(f"not wire-encodable: {type(v).__name__}")


def _dec_arr(spec: Dict, cur: _Cursor) -> np.ndarray:
    raw = cur.take(spec["n"])
    # frombuffer over the received bytearray: writable, zero extra copies
    return np.frombuffer(raw, dtype=np.dtype(spec["d"])).reshape(spec["s"])


def _dec(v, cur: _Cursor):
    if isinstance(v, dict):
        t = v["!"]
        if t == "nd":
            return _dec_arr(v, cur)
        if t == "ct":
            return ColumnTable({c: _dec_arr(s, cur) for c, s in v["c"]})
        if t == "tu":
            return tuple(_dec(x, cur) for x in v["v"])
        if t == "li":
            return [_dec(x, cur) for x in v["v"]]
        if t == "di":
            return {_dec(k, cur): _dec(x, cur) for k, x in v["v"]}
        raise TypeError(f"unknown wire tag {t!r}")
    return v


# ---------------------------------------------------------- PushPlan codec
def _rebuild_fn(code_b: bytes, module: str, name: str, defaults,
                closure_vals):
    """Reconstruct a (possibly lambda) function from its marshalled code
    object, rebound to its defining module's globals on the receiving
    side (the worker imports the same code, so ``np`` etc. resolve)."""
    code = marshal.loads(code_b)
    try:
        import importlib
        g = importlib.import_module(module).__dict__
    except Exception:  # noqa: BLE001 — fall back to a numpy-bearing scope
        g = {"np": np, "__builtins__": __builtins__}
    cells = None
    if closure_vals is not None:
        cells = tuple(types.CellType(v) for v in closure_vals)
    return types.FunctionType(code, g, name, defaults, cells)


class _PlanPickler(pickle.Pickler):
    """Pickler whose function reducer marshals ``__code__`` — the
    ``derive`` entries of real query plans are lambdas (not plain
    picklable); Expr trees and the PushPlan dataclass pickle normally."""

    def reducer_override(self, obj):
        if isinstance(obj, types.FunctionType):
            try:
                import importlib
                mod = importlib.import_module(obj.__module__)
                if getattr(mod, obj.__qualname__, None) is obj:
                    return NotImplemented   # importable by name: pickle as
                    #   the usual global ref (also breaks the recursion on
                    #   _rebuild_fn itself)
            except Exception:  # noqa: BLE001 — fall through to marshal
                pass
            try:
                code = marshal.dumps(obj.__code__)
            except ValueError:
                return NotImplemented
            closure = None
            if obj.__closure__:
                vals = []
                for cell in obj.__closure__:
                    try:
                        vals.append(cell.cell_contents)
                    except ValueError:
                        vals.append(None)
                closure = tuple(vals)
            return (_rebuild_fn, (code, obj.__module__ or "builtins",
                                  obj.__name__, obj.__defaults__, closure))
        return NotImplemented


def encode_plan(plan) -> bytes:
    buf = io.BytesIO()
    _PlanPickler(buf, protocol=5).dump(plan)
    return buf.getvalue()


def decode_plan(spec: bytes):
    return pickle.loads(spec)


# ----------------------------------------------------------- worker process
def _worker_entry(child_sock: socket.socket, parent_sock: socket.socket,
                  node_id: int, slots: int) -> None:
    try:
        parent_sock.close()   # our inherited copy of the parent's end:
        # while it stays open here, the parent would never see EOF
    except OSError:
        pass
    _WorkerServer(child_sock, node_id, slots).run()


class _WorkerServer:
    """One storage node: owns its partitions, executes pushed plans with
    an internal ``slots``-thread pool, serves raw projections, and stamps
    a load snapshot on every response."""

    def __init__(self, sock: socket.socket, node_id: int, slots: int):
        self.sock = sock
        self.node = node_id
        self.slots = max(1, slots)
        self.parts: Dict[Tuple[str, int], ColumnTable] = {}
        self.versions: Dict[Tuple[str, int], int] = {}
        self.plans: Dict[str, CompiledPushPlan] = {}
        self.q: "queue.Queue" = queue.Queue()
        self.pending = {"exec": 0, "fetch": 0}
        self.inflight = 0
        self.done = 0
        self.die_after: Optional[int] = None
        self.lock = threading.Lock()
        self.wlock = threading.Lock()
        self.cpu0 = (time.process_time(), time.perf_counter())

    # ------------------------------------------------------------- protocol
    def run(self) -> None:   # pragma: no cover — runs in the child process
        for _ in range(self.slots):
            threading.Thread(target=self._work, daemon=True).start()
        while True:
            try:
                header, body, _ = _read_frame(self.sock)
            except (EOFError, OSError):
                os._exit(0)
            kind = header["kind"]
            if kind == "shutdown":
                os._exit(0)
            elif kind == "load":
                self._install(header, body)
                self._reply({"req": header["req"], "ok": True})
            elif kind == "poll":
                self._reply({"req": header["req"], "ok": True})
            elif kind == "die_after":
                with self.lock:
                    self.die_after = int(header["n"])
                self._reply({"req": header["req"], "ok": True})
            elif kind == "burn":
                for _ in range(int(header.get("tasks", 1))):
                    with self.lock:
                        self.pending["exec"] += 1
                    self.q.put(({"kind": "burn", "req": None,
                                 "seconds": header["seconds"]}, b""))
                self._reply({"req": header["req"], "ok": True})
            else:                       # exec | fetch — the work queue
                with self.lock:
                    self.pending["exec" if kind == "exec" else "fetch"] += 1
                self.q.put((header, body))

    def _work(self) -> None:   # pragma: no cover — child process threads
        while True:
            header, body = self.q.get()
            kind = header["kind"]
            with self.lock:
                if self.die_after is not None and self.done >= self.die_after:
                    # the pinned worker-kill schedule: die mid-wave, with
                    # this request (and any queued peers) in flight
                    os.kill(os.getpid(), signal.SIGKILL)
                self.pending["exec" if kind in ("exec", "burn")
                             else "fetch"] -= 1
                self.inflight += 1
            spans = None
            bufs: List[bytes] = []
            try:
                if kind == "burn":
                    end = time.perf_counter() + float(header["seconds"])
                    x = 1.0
                    while time.perf_counter() < end:
                        x = x * 1.0000001 + 1.0   # real CPU occupancy
                    resp: Dict = {}
                elif kind == "exec":
                    resp, bufs, spans = self._exec(header, body)
                else:
                    resp, bufs, spans = self._fetch(header, body)
                hdr = dict(resp, req=header["req"], ok=True)
            except BaseException as e:  # noqa: BLE001 — shipped to parent
                hdr = {"req": header["req"], "ok": False,
                       "error": f"{type(e).__name__}: {e}"}
                bufs = []
            with self.lock:
                self.inflight -= 1
                self.done += 1
            if spans:
                hdr["spans"] = spans
            if hdr["req"] is not None:
                self._reply(hdr, b"".join(bufs))

    def _reply(self, header: Dict, body: bytes = b"") -> None:
        header["load"] = self._load_snapshot()
        with self.wlock:
            try:
                _write_frame(self.sock, header, body)
            except OSError:   # parent is gone; nothing left to serve
                os._exit(0)

    # ------------------------------------------------------------- handlers
    def _install(self, header: Dict, body) -> None:
        cur = _Cursor(body)
        cols = {c: np.array(_dec_arr(s, cur), copy=True)
                for c, s in header["cols"]}
        key = (header["table"], int(header["index"]))
        self.parts[key] = ColumnTable(cols)
        self.versions[key] = int(header["version"])

    def _compiled(self, header: Dict, cur: _Cursor) -> CompiledPushPlan:
        key = header["plan_key"]
        if "plan" in header:
            spec = bytes(cur.take(header["plan"]))
            if key not in self.plans:
                self.plans[key] = compile_push_plan(decode_plan(spec))
        return self.plans[key]

    def _tabs(self, header: Dict) -> List[ColumnTable]:
        out = []
        for (table, index), ver in zip(header["parts"], header["versions"]):
            key = (table, int(index))
            if self.versions.get(key) != int(ver):
                raise RuntimeError(
                    f"stale partition {key}: worker holds "
                    f"v{self.versions.get(key)}, request wants v{ver}")
            out.append(self.parts[key])
        return out

    def _exec(self, header: Dict, body) -> Tuple[Dict, List[bytes], List]:
        cur = _Cursor(body)
        cplan = self._compiled(header, cur)
        bms = _dec(header["bms"], cur) if "bms" in header else None
        tabs = self._tabs(header)
        t0 = time.perf_counter()
        if header["executor"] == EXECUTOR_REFERENCE:
            out = [execute_push_plan(cplan.plan, t,
                                     None if bms is None else bms[i])
                   for i, t in enumerate(tabs)]
        else:
            parts_res, aux = cplan.execute_batch_parts(
                tabs, bms, header.get("threshold"))
            out = list(zip(parts_res, aux))
        dur = time.perf_counter() - t0
        bufs: List[bytes] = []
        vals = _enc([[res, aux] for res, aux in out], bufs)
        spans = self._spans(header, "worker_execute", dur, tabs, out)
        return {"vals": vals}, bufs, spans

    def _fetch(self, header: Dict, body) -> Tuple[Dict, List[bytes], List]:
        cur = _Cursor(body)
        cplan = self._compiled(header, cur)
        tabs = self._tabs(header)
        t0 = time.perf_counter()
        projs = [cplan.raw_projection(t) for t in tabs]
        dur = time.perf_counter() - t0
        bufs: List[bytes] = []
        vals = _enc(projs, bufs)
        spans = self._spans(header, "worker_fetch", dur, tabs, None)
        return {"vals": vals}, bufs, spans

    def _spans(self, header: Dict, name: str, dur: float, tabs,
               out) -> Optional[List[Dict]]:
        if not header.get("trace"):
            return None
        attrs = {"node": self.node, "pid": os.getpid(),
                 "table": header["parts"][0][0], "n_parts": len(tabs)}
        if out is not None:
            attrs["rows_out"] = int(sum(len(res) for res, _ in out))
        return [{"name": name, "t0": 0.0, "dur": dur,
                 "remote_parent": header.get("span"), "attrs": attrs}]

    def _load_snapshot(self) -> Dict:
        with self.lock:
            snap = {"exec_q": self.pending["exec"],
                    "ship_q": self.pending["fetch"],
                    "inflight": self.inflight, "done": self.done}
        cpu_t, wall_t = time.process_time(), time.perf_counter()
        dcpu = cpu_t - self.cpu0[0]
        dwall = wall_t - self.cpu0[1]
        if dwall > 1e-3:
            self.cpu0 = (cpu_t, wall_t)
            snap["cpu"] = round(min(1.0, dcpu / (dwall * self.slots)), 4)
        else:
            snap["cpu"] = None
        return snap


# ----------------------------------------------------------- parent channel
class WorkerChannel:
    """Parent-side end of one worker's socketpair: a writer lock, a reader
    thread resolving per-request futures, and :class:`WorkerFault`
    mapping for a dead or overdue channel."""

    def __init__(self, node_id: int, slots: int,
                 timeout_s: Optional[float] = None):
        self.node = node_id
        self.timeout_s = timeout_s
        parent_sock, child_sock = socket.socketpair()
        ctx = multiprocessing.get_context("fork")
        self.proc = ctx.Process(target=_worker_entry,
                                args=(child_sock, parent_sock, node_id,
                                      slots),
                                daemon=True)
        self.proc.start()
        child_sock.close()
        self.sock = parent_sock
        self._pending: Dict[int, Future] = {}
        self._plock = threading.Lock()
        self._wlock = threading.Lock()
        self._rid = itertools.count()
        self.dead: Optional[str] = None        # fault kind once failed
        self.bytes_sent = 0
        self.bytes_recv = 0
        self.last_load: Optional[Dict] = None
        threading.Thread(target=self._read_loop, daemon=True).start()

    def _read_loop(self) -> None:
        try:
            while True:
                header, body, n = _read_frame(self.sock)
                self.bytes_recv += n
                self.last_load = header.get("load") or self.last_load
                with self._plock:
                    fut = self._pending.pop(header["req"], None)
                if fut is None:
                    continue
                if header.get("ok"):
                    fut.set_result((header, body))
                else:
                    fut.set_exception(RuntimeError(
                        f"worker {self.node} remote error: "
                        f"{header.get('error')}"))
        except (EOFError, OSError):
            self._fail(_faults.FAULT_CRASH)

    def _fail(self, kind: str) -> None:
        self.dead = kind
        with self._plock:
            pending, self._pending = self._pending, {}
        for fut in pending.values():
            fut.set_exception(_faults.WorkerFault(
                kind, self.node, "channel closed mid-request"))

    def request(self, header: Dict, body: bytes = b"",
                timeout: Optional[float] = None) -> Tuple[Dict, memoryview]:
        if self.dead is not None:
            raise _faults.WorkerFault(self.dead, self.node, "worker dead")
        rid = next(self._rid)
        header["req"] = rid
        fut: Future = Future()
        with self._plock:
            self._pending[rid] = fut
        try:
            with self._wlock:
                self.bytes_sent += _write_frame(self.sock, header, body)
        except OSError as e:
            with self._plock:
                self._pending.pop(rid, None)
            raise _faults.WorkerFault(_faults.FAULT_CRASH, self.node,
                                      f"send failed: {e}")
        try:
            return fut.result(timeout=timeout if timeout is not None
                              else self.timeout_s)
        except FutTimeout:
            with self._plock:
                self._pending.pop(rid, None)
            raise _faults.WorkerFault(_faults.FAULT_TIMEOUT, self.node,
                                      f"request overdue ({self.timeout_s}s)")

    def post(self, header: Dict) -> None:
        """Fire-and-forget (shutdown): no future, failures ignored."""
        header["req"] = None
        try:
            with self._wlock:
                _write_frame(self.sock, header)
        except OSError:
            pass

    def close(self) -> None:
        self.post({"kind": "shutdown"})
        self.proc.join(timeout=2.0)
        if self.proc.is_alive():
            self.proc.kill()
            self.proc.join(timeout=2.0)
        try:
            self.sock.close()
        except OSError:
            pass


# --------------------------------------------------------------- the pool
class WorkerPool:
    """One storage-worker process per catalog node.

    Construction forks the workers and ships each node's partitions over
    the wire (so the tier exercises the codec end to end, independent of
    the fork's memory inheritance). ``execute_group``/``fetch_projection``
    are the two tier entry points ``core.runtime`` dispatches through;
    both re-ship any partition whose catalog version moved since the last
    ship (append/update staleness), publish the worker's load snapshot
    into the ``stream.*`` gauges, and surface channel failures as
    :class:`core.faults.WorkerFault` — appending each to the pool's
    real-fault ledger (:attr:`events`) for exact reconciliation."""

    def __init__(self, catalog, pd_slots: int = 2,
                 request_timeout_s: Optional[float] = None):
        self.catalog = catalog
        self.nodes = [n.node_id for n in catalog.nodes]
        self.channels = {n: WorkerChannel(n, pd_slots, request_timeout_s)
                         for n in self.nodes}
        self._shipped_ver: Dict[int, Dict[Tuple[str, int], int]] = \
            {n: {} for n in self.nodes}
        self._shipped_plans: Dict[int, set] = {n: set() for n in self.nodes}
        self._plan_specs: Dict[int, Tuple[str, bytes, object]] = {}
        self._plock = threading.Lock()
        self.events: List[Dict] = []       # real-fault ledger
        self._elock = threading.Lock()
        self.closed = False
        for node in self.nodes:
            for part in catalog.nodes[node].partitions:
                self._ship_partition(node, part)

    # --------------------------------------------------------- partitions
    def _ship_partition(self, node: int, part) -> None:
        data = part.data
        bufs: List[bytes] = []
        cols = [[c, _enc_arr(data.cols[c], bufs)] for c in data.columns]
        self.channels[node].request(
            {"kind": "load", "table": part.table, "index": part.index,
             "version": part.version, "cols": cols}, b"".join(bufs))
        self._shipped_ver[node][(part.table, part.index)] = part.version

    def _refresh_parts(self, node: int, sub) -> None:
        shipped = self._shipped_ver[node]
        for r in sub:
            if shipped.get((r.table, r.part.index)) != r.part.version:
                self._ship_partition(node, r.part)

    # -------------------------------------------------------------- plans
    def _plan_ref(self, node: int, plan) -> Tuple[str, Optional[bytes]]:
        pid = id(plan)
        with self._plock:
            ent = self._plan_specs.get(pid)
            if ent is None:
                spec = encode_plan(plan)
                key = hashlib.blake2b(spec, digest_size=8).hexdigest()
                # the plan ref rides along so id(plan) stays pinned
                ent = self._plan_specs[pid] = (key, spec, plan)
            key, spec, _ = ent
            if key in self._shipped_plans[node]:
                return key, None
            return key, spec

    # ------------------------------------------------------- tier entries
    def execute_group(self, cplan: CompiledPushPlan, sub, executor: str,
                      threshold: Optional[float],
                      bitmaps: Optional[Dict[int, np.ndarray]] = None,
                      parent: Optional[obs_trace.Span] = None
                      ) -> List[Tuple[ColumnTable, Dict]]:
        """Dispatch one pushdown group to its node's worker and decode the
        per-partition ``(result, aux)`` pairs — byte-identical to the
        in-process executor on the same decision vector."""
        node = sub[0].part.node_id
        tr = obs_trace.get_tracer()
        try:
            self._refresh_parts(node, sub)
            key, spec = self._plan_ref(node, cplan.plan)
            header: Dict = {"kind": "exec", "plan_key": key,
                            "executor": executor, "threshold": threshold,
                            "parts": [[r.table, r.part.index] for r in sub],
                            "versions": [r.part.version for r in sub]}
            bufs: List[bytes] = []
            if spec is not None:
                header["plan"] = len(spec)
                bufs.append(spec)
            if bitmaps:
                header["bms"] = _enc([bitmaps[r.req_id] for r in sub], bufs)
            if tr.enabled:
                header["trace"] = True
                header["span"] = parent.sid if parent is not None else None
            t_send = time.perf_counter()
            rh, rb = self.channels[node].request(header, b"".join(bufs))
            if spec is not None:
                self._shipped_plans[node].add(key)
            out = [(res, aux) for res, aux in _dec(rh["vals"], _Cursor(rb))]
            get_metrics().counter("wire.pushdown_result_bytes").inc(len(rb))
            self._publish(node, rh.get("load"))
            self._adopt(tr, rh.get("spans"), parent, t_send)
            return out
        except _faults.WorkerFault as wf:
            self._record_fault(wf, table=sub[0].table, op="exec")
            raise

    def fetch_projection(self, cplan: CompiledPushPlan, sub,
                         parent: Optional[obs_trace.Span] = None
                         ) -> List[ColumnTable]:
        """The pushback transfer, for real: the worker serializes each
        partition's raw accessed-column projection and the decoded bytes
        cross the process boundary — the compute layer replays the
        compiled plan over exactly these tables."""
        node = sub[0].part.node_id
        tr = obs_trace.get_tracer()
        try:
            self._refresh_parts(node, sub)
            key, spec = self._plan_ref(node, cplan.plan)
            header: Dict = {"kind": "fetch", "plan_key": key,
                            "parts": [[r.table, r.part.index] for r in sub],
                            "versions": [r.part.version for r in sub]}
            bufs: List[bytes] = []
            if spec is not None:
                header["plan"] = len(spec)
                bufs.append(spec)
            if tr.enabled:
                header["trace"] = True
                header["span"] = parent.sid if parent is not None else None
            t_send = time.perf_counter()
            rh, rb = self.channels[node].request(header, b"".join(bufs))
            if spec is not None:
                self._shipped_plans[node].add(key)
            tabs = _dec(rh["vals"], _Cursor(rb))
            get_metrics().counter("wire.pushback_ship_bytes").inc(len(rb))
            self._publish(node, rh.get("load"))
            self._adopt(tr, rh.get("spans"), parent, t_send)
            return tabs
        except _faults.WorkerFault as wf:
            self._record_fault(wf, table=sub[0].table, op="fetch")
            raise

    # ----------------------------------------------------------- signals
    def _publish(self, node: int, load: Optional[Dict]) -> None:
        if not load:
            return
        m = get_metrics()
        m.gauge(f"stream.node{node}.exec_queue").set(load["exec_q"])
        m.gauge(f"stream.node{node}.ship_queue").set(load["ship_q"])
        m.gauge(f"storage.node{node}.inflight").set(load["inflight"])
        if load.get("cpu") is not None:
            m.gauge(f"storage.node{node}.cpu").set(load["cpu"])

    def publish_load(self) -> Dict[int, Optional[Dict]]:
        """Poll every live worker and publish its queue-depth / in-flight /
        CPU-occupancy snapshot into the gauges ``MeasuredLoad`` reads
        (``stream.node<N>.exec_queue``/``ship_queue`` plus the
        ``storage.node<N>.*`` extras). Dead workers keep their last
        published value — the breaker, not the gauge, routes around
        them."""
        out: Dict[int, Optional[Dict]] = {}
        for node, ch in self.channels.items():
            try:
                rh, _ = ch.request({"kind": "poll"})
                self._publish(node, rh.get("load"))
                out[node] = rh.get("load")
            except _faults.WorkerFault:
                out[node] = None
        return out

    def _adopt(self, tr, recs, parent, t_send: float) -> None:
        """Stitch worker-side span records into the compute-side trace:
        each record becomes a real span parented under the dispatching
        span, its clock mapped onto the send timestamp (wire latency is
        absorbed into the offset — the worker reports t0 relative to its
        own handling start)."""
        if not recs or not tr.enabled:
            return
        base = t_send - tr.t0
        for rec in recs:
            sp = tr.start(rec["name"], cat="worker", parent=parent,
                          **rec.get("attrs", {}))
            if sp is obs_trace.NULL_SPAN:
                continue
            sp.attrs["remote_parent"] = rec.get("remote_parent")
            sp.t0 = base + float(rec.get("t0") or 0.0)
            tr.end(sp)
            sp.dur = float(rec.get("dur") or 0.0)
            tr.amend(sp)   # re-emit: a streaming sink saw the wrong dur

    def _record_fault(self, wf: "_faults.WorkerFault", table: str,
                      op: str) -> None:
        with self._elock:
            self.events.append({"kind": wf.kind, "node": wf.node,
                                "table": table, "op": op})

    def fault_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        with self._elock:
            for ev in self.events:
                out[ev["kind"]] = out.get(ev["kind"], 0) + 1
        return out

    # ----------------------------------------------------- chaos controls
    def kill(self, node: int) -> None:
        """SIGKILL one worker process (the chaos tests' hammer)."""
        self.channels[node].proc.kill()

    def die_after(self, node: int, n: int) -> None:
        """Pinned worker-kill schedule: the worker SIGKILLs *itself* when
        it is about to start work item ``n+1`` — deterministic by request
        count, guaranteed mid-wave."""
        self.channels[node].request({"kind": "die_after", "n": n})

    def burn(self, node: int, seconds: float, tasks: int = 1) -> None:
        """Occupy ``tasks`` work items of real CPU on one worker — the
        injected storage-side pressure the decision-shift benchmark
        measures the Arbitrator against."""
        self.channels[node].request({"kind": "burn", "seconds": seconds,
                                     "tasks": tasks})

    def wire_bytes(self) -> Dict[str, int]:
        return {"sent": sum(ch.bytes_sent for ch in self.channels.values()),
                "recv": sum(ch.bytes_recv for ch in self.channels.values())}

    def alive(self, node: int) -> bool:
        return self.channels[node].dead is None \
            and self.channels[node].proc.is_alive()

    def close(self) -> None:
        if self.closed:
            return
        self.closed = True
        for ch in self.channels.values():
            ch.close()


# ------------------------------------------------------------ pool registry
_POOLS: Dict[int, Tuple[object, WorkerPool]] = {}
_POOLS_LOCK = threading.Lock()


def pool_for(catalog, pd_slots: int = 2) -> WorkerPool:
    """The process-wide pool for ``catalog`` (created on first use; the
    registry pins the catalog so ``id()`` keys stay unambiguous). Engine
    configs with ``storage_tier="process"`` and no explicit
    ``worker_pool`` route here."""
    with _POOLS_LOCK:
        ent = _POOLS.get(id(catalog))
        if ent is not None and not ent[1].closed:
            return ent[1]
        pool = WorkerPool(catalog, pd_slots=pd_slots)
        _POOLS[id(catalog)] = (catalog, pool)
        return pool


def close_all_pools() -> None:
    with _POOLS_LOCK:
        pools = [p for _, p in _POOLS.values()]
        _POOLS.clear()
    for p in pools:
        p.close()


atexit.register(close_all_pools)
