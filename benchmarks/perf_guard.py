"""CI monotone guard over the consolidated ``BENCH_engine.json`` trajectory.

Every wall-clock suite (executor / shuffle / bitmap_storage /
bitmap_compute / runtime) appends a headline entry per run. This guard
fails when the newest entry of any suite regresses below the previous
entry *at the same scale factor* (quick-mode sf=2 CI entries are never
compared against full sf=4 local entries) beyond a wall-clock-noise
tolerance, when any entry recorded a result divergence, when the
``runtime`` suite's newest adaptive A/B lost to the worse forced baseline
(``adaptive_ok``), when the ``correction`` suite's newest feedback
loop failed to shrink the s_out estimate error (``converged``), when
the ``obs`` suite's newest enabled-tracing overhead measurement blew its
bound (``obs_overhead_ok`` — the tentpole's <2% promise), when the ``cache`` suite's newest warm arm failed its serve contract
(``cache_ok`` — fully-warm hit rate, warm arbitration flipping
partitions to pushdown, ``cache_hits`` reconciled with admits), or when
the ``distributed`` suite's newest process-tier arm broke its contract
(``distributed_ok`` — byte-identity across tiers, real worker pressure
flipping at least one Arbitrator decision, process-tier adaptive not
losing to its own eager baseline).

A suite whose newest entry has **no comparable prior** (prior entries
exist, but none at the same sf) is a hard failure, not a silent pass:
before this guard grew teeth, a quick-mode run against a history recorded
only at another sf compared nothing and still printed "trajectory
monotone". Record a same-sf baseline first (the repo ships sf=2 entries
for exactly this reason). A suite's *first-ever* entry is reported loudly
but cannot fail — there is nothing it could have regressed from. Run
after the quick benchmarks:

    PYTHONPATH=src python -m benchmarks.executor_bench --quick
    PYTHONPATH=src python -m benchmarks.shuffle --real-quick
    PYTHONPATH=src python -m benchmarks.bitmap_storage --real-quick
    PYTHONPATH=src python -m benchmarks.bitmap_compute --real-quick
    PYTHONPATH=src python -m benchmarks.adaptive --real-quick
    PYTHONPATH=src python -m benchmarks.adaptive --correction-quick
    PYTHONPATH=src python -m benchmarks.obs_overhead --quick
    PYTHONPATH=src python -m benchmarks.cache --real-quick
    PYTHONPATH=src JAX_PLATFORMS=cpu python -m benchmarks.residual --real-quick
    PYTHONPATH=src python -m benchmarks.distributed_tier --quick
    PYTHONPATH=src python -m benchmarks.perf_guard
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Tuple

from benchmarks import common

# shared CI runners are noisy; a real regression from a batching change
# shows up far below this (the batch paths are >= 1.5x, not 0.85x)
TOLERANCE = 0.85
# the runtime suite's speedup is adaptive-vs-worse-baseline — structurally
# ~1.0-1.3 and wall-clock-noisy (thread scheduling on shared runners), so
# its monotone guard only catches collapses; the hard per-run invariant is
# ``adaptive_ok`` (adaptive must not lose to the worse forced baseline).
# The cache warm/cold ratio is likewise wall-clock-noisy on shared
# runners; its hard per-run invariant is ``cache_ok``. The chaos suite's
# speedup (recovery vs query-restart baseline) varies with how many
# restarts the pinned schedule forces; its hard per-run invariant is
# ``chaos_ok`` (byte-identity + full recovery + not losing to either
# coping baseline). The residual suite's all-15 total mixes tensor wins
# with queries auto-dispatch keeps on the interpreter (tiny inputs, the
# lexsort-aggregate outlier) — jit wall-clock noise swings it; its hard
# per-run invariant is ``residual_ok`` (identity + no fallbacks + the
# residual-dominant subset's 1.3x floor). The distributed suite's speedup
# (process-tier adaptive vs its own eager baseline) is structurally ~1.0
# and thread-scheduling-noisy; its hard per-run invariant is
# ``distributed_ok`` (identity + a real pressure-induced decision flip +
# adaptive not losing to eager on its own tier).
SUITE_TOLERANCE = {"runtime": 0.60, "cache": 0.60, "chaos": 0.60,
                   "residual": 0.60, "distributed": 0.60}


def check(doc: dict, tolerance: float = TOLERANCE
          ) -> Tuple[List[str], List[str]]:
    """Returns (failures, notices). Failures exit nonzero; notices are
    printed loudly but pass (a suite's first-ever entry)."""
    failures: List[str] = []
    notices: List[str] = []
    for suite, entry in sorted(doc.items()):
        hist = [h for h in entry.get("history", []) if isinstance(h, dict)]
        if not hist:
            continue
        last = hist[-1]
        if not last.get("all_identical", True):
            failures.append(f"{suite}: newest entry diverged from the "
                            "reference executor")
        if last.get("adaptive_ok") is False:
            failures.append(
                f"{suite}: newest adaptive A/B lost to the worse forced "
                f"baseline ({last.get('t_adaptive_ms')}ms vs "
                f"{last.get('worse_baseline_ms')}ms)")
        if last.get("converged") is False:
            failures.append(
                f"{suite}: newest correction loop did not shrink the "
                f"s_out estimate error (err {last.get('err_first')} -> "
                f"{last.get('err_last')})")
        if last.get("obs_overhead_ok") is False:
            failures.append(
                f"{suite}: enabled-tracing overhead "
                f"{100 * last.get('overhead', 0):+.2f}% exceeded the "
                f"{100 * last.get('bound', 0):.0f}% bound "
                f"({last.get('t_traced_ms')}ms traced vs "
                f"{last.get('t_untraced_ms')}ms untraced)")
        if last.get("cache_ok") is False:
            failures.append(
                f"{suite}: newest warm-cache arm broke its serve contract "
                f"(hit rate {last.get('hit_rate')}, "
                f"{last.get('flipped')} decisions flipped)")
        if last.get("chaos_ok") is False:
            failures.append(
                f"{suite}: newest chaos arm broke the recovery contract "
                f"(identical={last.get('all_identical')}, recovered_rate="
                f"{last.get('recovered_rate')}, recovery "
                f"{last.get('t_recovery_ms')}ms vs fail-to-error "
                f"{last.get('t_fail_to_error_ms')}ms / no-pushdown "
                f"{last.get('t_no_pushdown_ms')}ms)")
        if last.get("distributed_ok") is False:
            failures.append(
                f"{suite}: newest process-tier arm broke its contract "
                f"(identical={last.get('all_identical')}, "
                f"decision_flips={last.get('decision_flips')}, adaptive "
                f"{last.get('t_process_adaptive_ms')}ms vs eager "
                f"{last.get('t_process_eager_ms')}ms)")
        if last.get("residual_ok") is False:
            failures.append(
                f"{suite}: newest tensor-residual arm broke its contract "
                f"(identical={last.get('all_identical')}, subset "
                f"{last.get('subset_speedup')}x below the floor or a "
                "query fell back to the interpreter)")
        rr = last.get("recovered_rate")
        if rr is not None and rr < 1.0:
            failures.append(
                f"{suite}: recovered-query rate {rr} below 1.0 — demotion "
                "must make every faulted query complete, never error")
        hr = last.get("hit_rate")
        if hr is not None and hr < 0.99:
            failures.append(
                f"{suite}: warm hit rate {hr} below the fully-warm bound "
                "(every pushdown partition of a pre-filled mix must serve "
                "from cache)")
        if "total_speedup" not in last:
            continue  # not a wall-clock trajectory entry
        tol = min(tolerance, SUITE_TOLERANCE.get(suite, tolerance))
        speed_hist = [h for h in hist if "total_speedup" in h]
        prior = [h for h in speed_hist[:-1] if h.get("sf") == last.get("sf")]
        if not prior:
            if len(speed_hist) == 1:
                notices.append(
                    f"{suite}: first recorded entry "
                    f"(sf={last.get('sf')}) — nothing to guard yet")
            else:
                # history exists but at other scale factors only: the old
                # guard silently compared nothing here — fail loudly
                failures.append(
                    f"{suite}: newest entry (sf={last.get('sf')}) has no "
                    f"comparable prior — history holds sf="
                    f"{sorted({h.get('sf') for h in speed_hist[:-1]})} "
                    "only; record a same-sf baseline first")
            continue
        prev = prior[-1]
        if last["total_speedup"] < tol * prev["total_speedup"]:
            failures.append(
                f"{suite}: total_speedup {last['total_speedup']:.3f} fell "
                f"below {tol:.2f} * previous "
                f"{prev['total_speedup']:.3f} (sf={last.get('sf')})")
    return failures, notices


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default=str(common.ROOT_BENCH))
    ap.add_argument("--tolerance", type=float, default=TOLERANCE)
    args = ap.parse_args()
    path = Path(args.path)
    if not path.exists():
        print(f"perf_guard: {path} missing — run the benchmarks first")
        return 1
    doc = json.loads(path.read_text())
    failures, notices = check(doc, args.tolerance)
    for suite, entry in sorted(doc.items()):
        hist = [h for h in entry.get("history", [])
                if isinstance(h, dict) and "total_speedup" in h]
        traj = " -> ".join(f"{h['total_speedup']:.2f}x(sf={h.get('sf')})"
                           for h in hist)
        print(f"{suite:>16}: {traj or '(no wall-clock entries)'}")
    for n in notices:
        print(f"\nNOTICE: {n}")
    if failures:
        print("\nPERF REGRESSION:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nperf_guard: trajectory monotone (within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
