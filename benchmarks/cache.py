"""Pushed-result cache A/B: warm repeated-query mix vs cold adaptive.

The ``cache`` suite measures what the semantic pushed-result cache
(``core.result_cache``) buys under repeated-query traffic — the
FlexPushdownDB-style workload the tier targets:

- **cold** arm: the adaptive engine with no cache runs a storage-heavy
  query mix end to end (REAL wall-clock, best-of-N, GC paused),
- **warm** arm: the same mix against a cache pre-filled by one untimed
  eager pass — every pushdown partition is served from the cache and the
  storage-side operator work disappears.

Byte-identity of every arm against the uncached eager reference is
asserted OUTSIDE the timed region, every query. A separate verification
pass (also untimed) re-runs the warm mix collecting per-query
``QueryRun``s to compute the hit rate (served partitions / admitted
pushdown requests) — ``cache_ok`` demands a fully-warm serve.

``run_flip`` is the decision integration check: under starved storage
compute (``storage_power=0.01``) cold adaptive pushes every Q6 partition
back; after an eager fill the warm ``plan_requests`` cost hints collapse
``compute_in`` and arbitration flips all partitions to pushdown, served
entirely from cache with ``cache_hits == n_admitted``.

Headline lands in ``BENCH_engine.json`` under the ``cache`` suite;
``benchmarks.perf_guard`` keeps the warm/cold speedup trajectory monotone
and hard-fails on ``cache_ok`` regressions.
"""
from __future__ import annotations

import numpy as np

from repro.core import engine
from repro.core.cost import StorageResources
from repro.core.result_cache import ResultCache
from repro.queryproc import queries as Q

from benchmarks import common

# storage-heavy, cache-friendly mix (no apply_bitmap plans — those are
# deliberately uncacheable); the CI perf smoke shares this configuration
REAL_QUICK_KWARGS = {"qids": ("Q1", "Q6", "Q14"), "repeats": 3, "sf": 2.0}
QIDS = ("Q1", "Q6", "Q12", "Q14")


def _assert_identical(a, b, ctx):
    assert a.columns == b.columns, (ctx, a.columns, b.columns)
    for c in a.columns:
        assert a.cols[c].dtype == b.cols[c].dtype and np.array_equal(
            a.cols[c], b.cols[c], equal_nan=True), (ctx, c)


def run_real(qids=QIDS, repeats: int = 3, sf: float = None,
             power: float = 0.25) -> dict:
    sf = sf or common.SF
    cat = common.catalog(num_nodes=2, sf=sf)
    qids = tuple(qids)
    res = StorageResources(storage_power=power)
    queries = [Q.build_query(qid) for qid in qids]
    refs = [engine.run_query(q, cat, engine.EngineConfig(mode="eager")).result
            for q in queries]

    cold_cfg = engine.EngineConfig(res=res, mode="adaptive")
    cache = ResultCache()
    warm_cfg = engine.EngineConfig(res=res, mode="adaptive",
                                   result_cache=cache)
    # identity of the cold arm, asserted before anything is timed
    for q, ref in zip(queries, refs):
        _assert_identical(ref, engine.run_query(q, cat, cold_cfg).result,
                          ("cold", q.qid))
    # untimed eager pass fills every partition's entry (cold adaptive may
    # push back; eager guarantees full coverage for the warm arm)
    for q in queries:
        engine.run_query(q, cat, engine.EngineConfig(
            res=res, mode="eager", result_cache=cache))
    # untimed warm verification pass: identity + hit accounting
    hits = admitted = 0
    for q, ref in zip(queries, refs):
        r = engine.run_query(q, cat, warm_cfg)
        _assert_identical(ref, r.result, ("warm", q.qid))
        hits += r.cache_hits
        admitted += r.n_admitted
    hit_rate = hits / max(1, admitted)

    def run_mix(cfg):
        for q in queries:
            engine.run_query(q, cat, cfg)

    t_cold = common.best_time(lambda: run_mix(cold_cfg), repeats)
    t_warm = common.best_time(lambda: run_mix(warm_cfg), repeats)

    flip = run_flip(sf=sf)
    cache_ok = (hit_rate >= 0.99 and flip["reconciled"]
                and flip["flipped"] > 0)
    return {
        "sf": sf, "power": power, "repeats": repeats, "qids": list(qids),
        "t_cold_ms": 1e3 * t_cold, "t_warm_ms": 1e3 * t_warm,
        "total_speedup": t_cold / max(t_warm, 1e-9),
        "all_identical": True,           # asserted per arm above
        "warm_hits": hits, "warm_admitted": admitted,
        "hit_rate": hit_rate, "flip": flip, "cache_ok": cache_ok,
        "cache_stats": cache.stats(),
    }


def run_flip(sf: float = None) -> dict:
    """Cold adaptive pushes back; a warm cache flips the same partitions
    to pushdown, fully served, with hits == admitted."""
    sf = sf or common.SF
    cat = common.catalog(num_nodes=2, sf=sf)
    res = StorageResources(storage_power=0.01)
    q = Q.build_query("Q6")
    n_parts = len(engine.plan_requests(q, cat))
    ref = engine.run_query(q, cat, engine.EngineConfig(mode="eager")).result
    cache = ResultCache()
    cold = engine.run_query(q, cat, engine.EngineConfig(
        res=res, mode="adaptive", result_cache=cache))
    engine.run_query(q, cat, engine.EngineConfig(
        res=res, mode="eager", result_cache=cache))
    warm = engine.run_query(q, cat, engine.EngineConfig(
        res=res, mode="adaptive", result_cache=cache))
    _assert_identical(ref, cold.result, "flip-cold")
    _assert_identical(ref, warm.result, "flip-warm")
    return {
        "n_parts": n_parts,
        "cold_admitted": cold.n_admitted, "warm_admitted": warm.n_admitted,
        "flipped": warm.n_admitted - cold.n_admitted,
        "warm_hits": warm.cache_hits,
        "reconciled": warm.cache_hits == warm.n_admitted,
    }


def run(qids=QIDS, repeats: int = 3, sf: float = None) -> dict:
    return {"real": run_real(qids=qids, repeats=repeats, sf=sf)}


QUICK_KWARGS = dict(REAL_QUICK_KWARGS)


def _headline(real: dict) -> dict:
    return {"sf": real["sf"], "power": real["power"],
            "total_speedup": round(real["total_speedup"], 3),
            "t_cold_ms": round(real["t_cold_ms"], 2),
            "t_warm_ms": round(real["t_warm_ms"], 2),
            "hit_rate": round(real["hit_rate"], 4),
            "flipped": real["flip"]["flipped"],
            "cache_ok": real["cache_ok"],
            "all_identical": real["all_identical"]}


def update_root_bench(out: dict):
    return common.update_root_bench_real("cache", out, headline_fn=_headline)


def render(out: dict) -> str:
    real = out.get("real", out)
    f = real["flip"]
    rows = [["cold adaptive", f'{real["t_cold_ms"]:.1f}', "-", "-"],
            ["warm adaptive", f'{real["t_warm_ms"]:.1f}',
             real["warm_hits"], real["warm_admitted"]]]
    hdr = ["arm", "wall_ms", "hits", "pushdown"]
    return common.table(rows, hdr) + (
        f'\ncache (sf={real["sf"]}, power={real["power"]}, '
        f'mix={",".join(real["qids"])}): warm {real["total_speedup"]:.2f}x '
        f'over cold, hit rate {100 * real["hit_rate"]:.1f}%, '
        f'identical={real["all_identical"]}\n'
        f'decision flip (power=0.01): {f["cold_admitted"]}/{f["n_parts"]} '
        f'cold pushdown -> {f["warm_admitted"]}/{f["n_parts"]} warm '
        f'({f["flipped"]} flipped), warm hits {f["warm_hits"]} '
        f'reconciled={f["reconciled"]}, ok={real["cache_ok"]}')


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--real-quick", action="store_true",
                    help="3-query mix at sf=2 (CI smoke)")
    args = ap.parse_args()
    o = run_real(**REAL_QUICK_KWARGS) if args.real_quick else run_real()
    update_root_bench(o)
    print(render(o))
