"""§Roofline table: read the dry-run JSONs and render per (arch x shape):
compute / memory / collective terms (seconds), dominant bottleneck,
MODEL_FLOPS/HLO_FLOPS useful ratio, and roofline fraction (mfu).

Two memory figures are shown (see EXPERIMENTS.md §Dry-run for why):
- mem(raw): HLO 'bytes accessed' from the CPU-backend compile — an upper
  bound (XLA:CPU barely fuses and upcasts bf16 dot operands to f32),
- mem(adj): analytic TPU-fused lower bound — weight+state+cache traffic
  plus boundary activations (computed in repro.launch.analysis).
"""
from __future__ import annotations

import glob
import json
from pathlib import Path

from benchmarks import common


def load(mesh: str = "16x16", report_dir: str = "reports/dryrun"):
    rows = []
    for f in sorted(glob.glob(f"{report_dir}/{mesh}/*.json")):
        r = json.loads(Path(f).read_text())
        if r.get("status") != "ok" or "roofline" not in r:
            continue
        rows.append(r)
    return rows


def run(mesh: str = "16x16") -> dict:
    rows = load(mesh)
    out = {"mesh": mesh, "cells": []}
    for r in rows:
        rl = r["roofline"]
        out["cells"].append({
            "arch": r["arch"], "shape": r["shape"],
            "variant": r.get("variant", "baseline"),
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"] + rl["dcn_s"],
            "dominant": rl.get("dominant_adj", rl["dominant"]),
            "mfu": rl["mfu"],
            "useful_frac": rl["useful_frac"],
            "mem_adj_s": rl.get("memory_adj_s"),
            "mfu_adj": rl.get("mfu_adj"),
        })
    return out


def render(out: dict) -> str:
    rows = []
    for c in out["cells"]:
        rows.append([c["arch"], c["shape"], f'{c["compute_s"]:.4f}',
                     f'{c["memory_s"]:.4f}',
                     f'{c["mem_adj_s"]:.4f}' if c["mem_adj_s"] else "-",
                     f'{c["collective_s"]:.4f}', c["dominant"],
                     f'{c["mfu"]:.3f}',
                     f'{c["mfu_adj"]:.3f}' if c["mfu_adj"] else "-",
                     f'{c["useful_frac"]:.2f}'])
    hdr = ["arch", "shape", "compute_s", "mem_raw_s", "mem_adj_s",
           "coll_s", "dominant", "mfu_raw", "mfu_adj", "useful"]
    return common.table(rows, hdr)


if __name__ == "__main__":
    o = run()
    common.save_report("roofline", o)
    print(render(o))
