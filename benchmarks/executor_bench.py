"""Executor suite: REAL wall-clock of the fused batched executor vs the
per-partition reference path (not simulated makespan — the one benchmark
that measures what the Python actually does).

For every TPC-H query: plan the per-partition requests once, then time

- ``reference``  the seed's interpretive loop (``execute_push_plan`` per
                 partition, plan re-walked each time),
- ``batched``    compile-once plans + one vectorized multi-partition pass
                 per (table, plan) (``core.executor``),

asserting the merged tables are byte-identical every repeat. Also times
``plan_requests`` both ways (compiled cost memoization vs per-partition
recomputation). The consolidated summary lands in ``BENCH_engine.json`` at
the repo root — one file appended per PR, the cross-PR perf trajectory.
"""
from __future__ import annotations

from pathlib import Path
from typing import Dict, List

import numpy as np

from benchmarks import common
from repro.core import engine
from repro.core import executor as executor_mod
from repro.core.executor import compile_push_plan
from repro.core.plan import estimate_cost
from repro.obs import trace as obs_trace
from repro.queryproc import queries as Q

ROOT_BENCH = common.ROOT_BENCH
# the CI perf smoke and `run.py --quick` share this exact configuration
QUICK_KWARGS = {"qids": ("Q1", "Q6", "Q12", "Q14", "Q18"), "repeats": 3,
                "sf": 2.0}

_time = common.median_time


def _tables_identical(a, b) -> bool:
    if set(a) != set(b):
        return False
    for t in a:
        if a[t].columns != b[t].columns:
            return False
        for c in a[t].columns:
            x, y = a[t].cols[c], b[t].cols[c]
            if x.dtype != y.dtype or not np.array_equal(x, y, equal_nan=True):
                return False
    return True


def run(qids=None, repeats: int = 5, sf: float = None) -> Dict:
    qids = list(qids or Q.QUERY_IDS)
    cat = common.catalog(num_nodes=2, sf=sf or common.SF)
    n_parts = len(cat.partitions_of("lineitem"))
    queries: Dict[str, Dict] = {}
    for qid in qids:
        q = Q.build_query(qid)
        reqs = engine.plan_requests(q, cat)
        ref = engine.execute_requests(reqs, engine.EXECUTOR_REFERENCE)
        channel = obs_trace.filter_decision_channel()
        channel.clear()
        bat = engine.execute_requests(reqs, engine.EXECUTOR_BATCHED)
        # which adaptive filter branch each (table, plan) batch took
        counts = channel.counts("branch")
        branches = {b: counts.get(b, 0) for b in ("gather", "concat")}
        identical = _tables_identical(ref, bat)
        assert identical, f"{qid}: batched merged tables diverge"
        t_ref = _time(lambda: engine.execute_requests(
            reqs, engine.EXECUTOR_REFERENCE), repeats)
        t_bat = _time(lambda: engine.execute_requests(
            reqs, engine.EXECUTOR_BATCHED), repeats)
        # planning: compiled per-plan cost memoization vs per-partition
        t_plan_ref = _time(
            lambda: [estimate_cost(r.plan, r.part) for r in reqs], repeats)
        t_plan_bat = _time(
            lambda: [compile_push_plan(r.plan).estimate_cost(r.part)
                     for r in reqs], repeats)
        queries[qid] = {
            "n_requests": len(reqs),
            "t_reference_ms": 1e3 * t_ref,
            "t_batched_ms": 1e3 * t_bat,
            "speedup": t_ref / max(t_bat, 1e-12),
            "t_plan_reference_ms": 1e3 * t_plan_ref,
            "t_plan_batched_ms": 1e3 * t_plan_bat,
            "plan_speedup": t_plan_ref / max(t_plan_bat, 1e-12),
            "identical": identical,
            "filter_branches": branches,
        }
    vals = list(queries.values())
    tot_ref = sum(v["t_reference_ms"] for v in vals)
    tot_bat = sum(v["t_batched_ms"] for v in vals)
    out = {
        "sf": sf or common.SF,
        "lineitem_partitions": n_parts,
        "repeats": repeats,
        "queries": queries,
        "all_identical": all(v["identical"] for v in vals),
        "total_reference_ms": tot_ref,
        "total_batched_ms": tot_bat,
        "total_speedup": tot_ref / max(tot_bat, 1e-12),
        "geomean_speedup": float(np.exp(np.mean(
            [np.log(v["speedup"]) for v in vals]))),
        "min_speedup": min(v["speedup"] for v in vals),
        "max_speedup": max(v["speedup"] for v in vals),
        "filter_gather_threshold": executor_mod.FILTER_GATHER_THRESHOLD,
    }
    return out


def render(out: Dict) -> str:
    rows: List[List] = []
    for qid, v in out["queries"].items():
        br = v.get("filter_branches", {})
        rows.append([qid, v["n_requests"],
                     f"{v['t_reference_ms']:.2f}", f"{v['t_batched_ms']:.2f}",
                     f"{v['speedup']:.2f}x", f"{v['plan_speedup']:.2f}x",
                     f"g{br.get('gather', 0)}/c{br.get('concat', 0)}",
                     "yes" if v["identical"] else "NO"])
    head = ["query", "reqs", "ref_ms", "batched_ms", "speedup",
            "plan_speedup", "filter", "identical"]
    summary = (f"\ntotal {out['total_reference_ms']:.1f}ms -> "
               f"{out['total_batched_ms']:.1f}ms "
               f"({out['total_speedup']:.2f}x; geomean "
               f"{out['geomean_speedup']:.2f}x, min {out['min_speedup']:.2f}x)"
               f"\nadaptive filter threshold "
               f"{out['filter_gather_threshold']:.2f} "
               "(gN/cM = N gather / M concat batches)")
    return common.table(rows, head) + summary


def update_root_bench(out: Dict, path: Path = ROOT_BENCH) -> Path:
    headline = {
        "sf": out["sf"],
        "total_speedup": round(out["total_speedup"], 3),
        "geomean_speedup": round(out["geomean_speedup"], 3),
        "total_batched_ms": round(out["total_batched_ms"], 2),
        "total_reference_ms": round(out["total_reference_ms"], 2),
        "all_identical": out["all_identical"],
    }
    return common.update_root_bench("executor", out, headline, path)


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="5 queries, 3 repeats, sf=2 (the CI perf smoke)")
    args = ap.parse_args()
    result = run(**(QUICK_KWARGS if args.quick else {}))
    common.save_report("executor", result)
    p = update_root_bench(result)
    print(render(result))
    print(f"\nwrote reports/bench/executor.json and {p}")
    if not result["all_identical"]:
        raise SystemExit(1)
