"""Benchmark driver: ``python -m benchmarks.run [--quick] [--only NAME]``.

Runs every paper-figure benchmark (DESIGN.md §7), saves JSON reports under
reports/bench/, prints the tables, and checks the paper's headline claims
(soft — a failed claim prints WARN, the exit code reflects hard errors
only; EXPERIMENTS.md §Paper-validation interprets the numbers).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (adaptive, bitmap_compute, bitmap_storage, breakdown,
                        cache, common, compiler_bench, executor_bench,
                        kernels_bench, network, optimal_gap, pa_aware,
                        roofline, shuffle)

SUITES = {
    "fig6_adaptive": adaptive,
    "fig7_optimal_gap": optimal_gap,
    "fig8_network": network,
    "fig9_breakdown": breakdown,
    "fig10_12_pa_aware": pa_aware,
    "fig13_bitmap_storage": bitmap_storage,
    "fig14_bitmap_compute": bitmap_compute,
    "fig15_shuffle": shuffle,
    "kernels": kernels_bench,
    "roofline": roofline,
    "compiler": compiler_bench,
    "executor": executor_bench,
    "cache": cache,
}


def check_claims(results: dict) -> list:
    warns = []

    def claim(name, ok):
        print(f"[{'OK  ' if ok else 'WARN'}] {name}")
        if not ok:
            warns.append(name)

    r = results.get("fig6_adaptive")
    if r:
        claim("Fig6: break-even speedup >= 1.3x avg (paper 1.5x)",
              r["breakeven_speedup_avg"] >= 1.3)
        claim("Fig6: best break-even speedup >= 1.7x (paper 1.9x)",
              r["breakeven_speedup_max"] >= 1.7)
        if "real" in r:
            claim("Runtime: real split results byte-identical across modes",
                  r["real"]["all_identical"])
            claim("Runtime: real adaptive wall-clock >= worse forced "
                  "baseline", r["real"]["adaptive_ok"])
        if "correction" in r:
            c = r["correction"]
            claim("Correction: s_out estimate error shrinks across runs",
                  c["converged"])
            claim("Correction: cost-based cut ships >=20% fewer net bytes "
                  "on a lowered query", c["net_saved_frac_max"] >= 0.2)
            claim("Correction: corrected chooser flips >=1 estimation-bias "
                  "cut toward measured truth",
                  len(c["corrected_flips"]) >= 1)
            claim("Correction: maximal/costed/corrected results identical",
                  c["all_identical"])
    r = results.get("fig7_optimal_gap")
    if r:
        claim("Fig7: avg Eq6 admit-count gap <= 8% (paper 1-2%; residual "
              "is Alg-1 spill under per-stream caps, see EXPERIMENTS.md)",
              r["avg_gap_frac"] <= 0.08)
    r = results.get("fig8_network")
    if r:
        claim("Fig8: eager saves >= 5x traffic on Q14 (paper ~10x)",
              r["queries"]["Q14"]["eager_saving_x"] >= 5)
    r = results.get("fig10_12_pa_aware")
    if r:
        claim("Fig10: PA-aware speeds up Q14 (paper up to 1.9x)",
              r["speedup_q14"] >= 1.05)
        claim("Fig12: PA-aware reduces CPU or network usage",
              r["cpu_reduction"] > 0 or r["net_reduction"] > 0)
    r = results.get("fig13_bitmap_storage")
    if r:
        claim("Fig13: bitmap-from-storage >= 2.5x best (paper 3.0x)",
              r["max_speedup"] >= 2.5)
    r = results.get("fig14_bitmap_compute")
    if r:
        claim("Fig14: bitmap-from-compute >= 1.7x best (paper 2.0-2.6x)",
              r["max_speedup"] >= 1.7)
    r = results.get("fig15_shuffle")
    if r:
        claim("Fig15: shuffle pushdown avg >= 1.2x vs baseline (paper 1.3x)",
              r["avg_speedup_vs_baseline"] >= 1.2)
        claim("Fig15: shuffle pushdown avg >= 1.5x vs no-pd (paper 1.8x)",
              r["avg_speedup_vs_npd"] >= 1.5)
        if "real" in r:
            claim("Shuffle batch path >= 1.5x wall-clock over reference",
                  r["real"]["total_speedup"] >= 1.5)
    for name, label in (("fig13_bitmap_storage", "Storage-bitmap"),
                        ("fig14_bitmap_compute", "Bitmap-apply")):
        r = results.get(name)
        if r and "real" in r:
            claim(f"{label} batch path >= 1.5x wall-clock over reference",
                  r["real"]["total_speedup"] >= 1.5)
            claim(f"{label} batch path byte-identical to reference",
                  r["real"]["all_identical"])
    r = results.get("compiler")
    if r:
        claim("Compiler: every compiled query equals the hand-built plan",
              r["all_equal"])
        claim("Compiler: >= 1 query with strictly larger pushed frontier",
              r["n_larger_frontier"] >= 1)
        claim("Compiler: plan compilation under 50 ms per query",
              r["compile_ms_max"] < 50.0)
    r = results.get("executor")
    if r:
        claim("Executor: batched merged tables byte-identical on all queries",
              r["all_identical"])
        claim("Executor: >= 2x total wall-clock over per-partition reference",
              r["total_speedup"] >= 2.0)
    r = results.get("cache")
    if r:
        real = r.get("real", r)
        claim("Cache: warm repeated-query mix >= 2x wall-clock over cold "
              "adaptive", real["total_speedup"] >= 2.0)
        claim("Cache: every arm byte-identical to the uncached reference",
              real["all_identical"])
        claim("Cache: warm arbitration flips partitions to pushdown with "
              "hits reconciled", real["cache_ok"])
    return warns


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    ap.add_argument("--quick", action="store_true",
                    help="fewer power points / queries")
    args = ap.parse_args()
    names = list(SUITES) if not args.only else args.only.split(",")

    results, failed = {}, []
    for name in names:
        mod = SUITES[name]
        t0 = time.time()
        print(f"\n=== {name} " + "=" * max(1, 60 - len(name)))
        try:
            kwargs = {}
            if args.quick and name == "fig6_adaptive":
                kwargs = {"powers": (1.0, 0.5, 0.25, 0.06),
                          "qids": ("Q1", "Q6", "Q12", "Q14", "Q19")}
            if args.quick and name == "executor":
                kwargs = executor_bench.QUICK_KWARGS
            if args.quick and name == "cache":
                kwargs = cache.QUICK_KWARGS
            out = mod.run(**kwargs)
            results[name] = out
            common.save_report(name, out)
            if hasattr(mod, "update_root_bench"):
                mod.update_root_bench(out)
            print(mod.render(out))
            print(f"[{time.time()-t0:.1f}s]")
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()

    print("\n=== claim checks " + "=" * 43)
    warns = check_claims(results)
    print(f"\n{len(names)-len(failed)}/{len(names)} suites ran, "
          f"{len(warns)} claim warnings, {len(failed)} hard failures")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
