"""Fig 13: selection-bitmap pushdown, bitmap constructed at the STORAGE
layer (output columns cached at compute; predicate columns are not).

Baseline = eager pushdown shipping filtered output columns. Bitmap = ship
the packed bitmap, filter the cached columns at compute. Sweeps the fact
filter selectivity. Claims: biggest wins at HIGH selectivity-fraction
(non-selective filters -> shipping rows is expensive, a bitmap is 1
bit/row): paper sees up to 3.0x on Q14/Q19 at sel 0.9, >90% traffic saved;
still ~1.3-1.8x at sel 0.1.
"""
from __future__ import annotations

from repro.core import engine
from repro.core.bitmap import CacheState, rewrite_all
from repro.core.simulator import MODE_EAGER
from repro.queryproc import expressions as ex
from repro.queryproc import queries as Q

from benchmarks import common

SELECTIVITIES = (0.1, 0.3, 0.5, 0.7, 0.9)


def _cache_outputs_only(query) -> CacheState:
    """Cache = the fact plan's output columns; predicate columns excluded."""
    plan = query.plans["lineitem"]
    pred_cols = ex.columns_of(plan.predicate) if plan.predicate else set()
    derived = {n for n, _, _ in plan.derive}
    base_out = set()
    for c in plan.columns:
        if c in derived:
            continue
        base_out.add(c)
    for _, incols, _ in plan.derive:
        base_out |= set(incols)
    cache = CacheState()
    cache.cache_columns("lineitem", base_out - pred_cols)
    return cache


def run(qids=("Q3", "Q4", "Q12", "Q14", "Q19"), sels=SELECTIVITIES) -> dict:
    cat = common.catalog()
    out = {"selectivities": list(sels), "queries": {}}
    for qid in qids:
        speeds, savings = [], []
        for sel in sels:
            q = Q.build_query(qid, fact_selectivity=sel)
            cfg = common.engine_cfg(MODE_EAGER, 1.0)
            reqs = engine.plan_requests(q, cat)
            base = engine.run_query(q, cat, cfg, requests=reqs)
            rw_reqs, metrics = rewrite_all(reqs, _cache_outputs_only(q))
            bm = engine.run_query(q, cat, cfg, requests=rw_reqs)
            # compute-layer ingest cost follows the bytes actually SHIPPED:
            # late materialization skips the deserialize+filter pass for
            # cached columns (they are applied in place by bitmap_apply)
            t_base = base.t_pushable + base.net_bytes / cfg.compute_bw
            t_bm = bm.t_pushable + bm.net_bytes / cfg.compute_bw
            speeds.append(t_base / t_bm)
            savings.append(1 - metrics["net_bitmap"]
                           / max(metrics["net_baseline"], 1))
        out["queries"][qid] = {"speedup": speeds, "traffic_saved": savings}
    out["max_speedup"] = max(max(d["speedup"])
                             for d in out["queries"].values())
    return out


def render(out: dict) -> str:
    rows = []
    for qid, d in out["queries"].items():
        rows.append([qid] + [f"{s:.2f}x" for s in d["speedup"]]
                    + [" ".join(f"{v*100:.0f}%" for v in d["traffic_saved"])])
    hdr = ["query"] + [f"sel={s}" for s in out["selectivities"]] + ["traffic saved"]
    return common.table(rows, hdr) + (
        f'\nmax speedup {out["max_speedup"]:.2f}x (paper Fig 13: up to 3.0x, '
        f'>90% transfer saved at sel 0.9)')


if __name__ == "__main__":
    o = run()
    common.save_report("fig13_bitmap_storage", o)
    print(render(o))
