"""Fig 13: selection-bitmap pushdown, bitmap constructed at the STORAGE
layer (output columns cached at compute; predicate columns are not).

Baseline = eager pushdown shipping filtered output columns. Bitmap = ship
the packed bitmap, filter the cached columns at compute. Sweeps the fact
filter selectivity. Claims: biggest wins at HIGH selectivity-fraction
(non-selective filters -> shipping rows is expensive, a bitmap is 1
bit/row): paper sees up to 3.0x on Q14/Q19 at sel 0.9, >90% traffic saved;
still ~1.3-1.8x at sel 0.1.

``run_real`` additionally measures REAL wall-clock of the storage-side
bitmap construction (a ``bitmap_only`` plan: predicate -> packed bitmap +
filtered uncached columns): per-partition reference loop vs the batch
executor's fused aux pass, byte-identity asserted. Headline lands in
``BENCH_engine.json`` under ``bitmap_storage``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import engine
from repro.core.bitmap import CacheState, rewrite_all
from repro.core.executor import compile_push_plan
from repro.core.plan import execute_push_plan
from repro.core.simulator import MODE_EAGER
from repro.queryproc import expressions as ex
from repro.queryproc import queries as Q

from benchmarks import common

SELECTIVITIES = (0.1, 0.3, 0.5, 0.7, 0.9)
# the CI perf smoke shares this exact configuration
REAL_QUICK_KWARGS = {"qids": ("Q6", "Q14", "Q19"), "repeats": 3, "sf": 2.0}


def _cache_outputs_only(query) -> CacheState:
    """Cache = the fact plan's output columns; predicate columns excluded."""
    plan = query.plans["lineitem"]
    pred_cols = ex.columns_of(plan.predicate) if plan.predicate else set()
    derived = {n for n, _, _ in plan.derive}
    base_out = set()
    for c in plan.columns:
        if c in derived:
            continue
        base_out.add(c)
    for _, incols, _ in plan.derive:
        base_out |= set(incols)
    cache = CacheState()
    cache.cache_columns("lineitem", base_out - pred_cols)
    return cache


def run(qids=("Q3", "Q4", "Q12", "Q14", "Q19"), sels=SELECTIVITIES) -> dict:
    cat = common.catalog()
    out = {"selectivities": list(sels), "queries": {}}
    for qid in qids:
        speeds, savings = [], []
        for sel in sels:
            q = Q.build_query(qid, fact_selectivity=sel)
            cfg = common.engine_cfg(MODE_EAGER, 1.0)
            reqs = engine.plan_requests(q, cat)
            base = engine.run_query(q, cat, cfg, requests=reqs)
            rw_reqs, metrics = rewrite_all(reqs, _cache_outputs_only(q))
            bm = engine.run_query(q, cat, cfg, requests=rw_reqs)
            # compute-layer ingest cost follows the bytes actually SHIPPED:
            # late materialization skips the deserialize+filter pass for
            # cached columns (they are applied in place by bitmap_apply)
            t_base = base.t_pushable + base.net_bytes / cfg.compute_bw
            t_bm = bm.t_pushable + bm.net_bytes / cfg.compute_bw
            speeds.append(t_base / t_bm)
            savings.append(1 - metrics["net_bitmap"]
                           / max(metrics["net_baseline"], 1))
        out["queries"][qid] = {"speedup": speeds, "traffic_saved": savings}
    out["max_speedup"] = max(max(d["speedup"])
                             for d in out["queries"].values())
    # real wall-clock of the storage-side bitmap construction (batch path)
    out["real"] = run_real(qids=qids)
    return out


# ------------------------------------------- real wall-clock (batch path)
def bitmap_plan(plan):
    """The Fig-3 request the storage node actually runs: the pushed fact
    plan's predicate, emitting the packed bitmap + filtered base output
    columns (derives/aggs stay at compute where the cache lives)."""
    if plan.predicate is None:
        return None
    derived = {n for n, _, _ in plan.derive}
    cols = tuple(c for c in plan.accessed_columns() if c not in derived)
    return dataclasses.replace(plan, columns=cols, derive=(), agg=None,
                               top_k=None, bitmap_only=True)


def run_real(qids=("Q1", "Q3", "Q4", "Q6", "Q12", "Q14", "Q19"),
             repeats: int = 3, sf: float = None, table: str = "lineitem"
             ) -> dict:
    """REAL wall-clock of storage-side bitmap construction: per-partition
    reference vs the batch executor's fused bitmap_only aux pass."""
    cat = common.catalog(num_nodes=2, sf=sf or common.SF)
    parts = [p.data for p in cat.partitions_of(table)]
    queries = {}
    for qid in qids:
        plan = bitmap_plan(Q.build_query(qid).plans[table])
        if plan is None:
            continue
        cplan = compile_push_plan(plan)
        ref_out = [execute_push_plan(plan, p) for p in parts]
        bat_parts, bat_aux = cplan.execute_batch_parts(parts)
        for (rt, raux), bt, ba in zip(ref_out, bat_parts, bat_aux):
            assert np.array_equal(raux["bitmap"], ba["bitmap"]), qid
            for c in rt.columns:
                assert rt.cols[c].dtype == bt.cols[c].dtype and \
                    np.array_equal(rt.cols[c], bt.cols[c],
                                   equal_nan=True), (qid, c)
        t_ref = common.best_time(
            lambda: [execute_push_plan(plan, p) for p in parts], repeats)
        t_bat = common.best_time(
            lambda: cplan.execute_batch_parts(parts), repeats)
        queries[qid] = {"n_partitions": len(parts),
                        "t_reference_ms": 1e3 * t_ref,
                        "t_batched_ms": 1e3 * t_bat,
                        "speedup": t_ref / max(t_bat, 1e-12),
                        "identical": True}
    return common.summarize_real(queries, sf or common.SF, repeats)


def render_real(out: dict) -> str:
    if not out["queries"]:
        return "real storage-bitmap path: no predicate-bearing queries"
    rows = [[qid, v["n_partitions"], f"{v['t_reference_ms']:.2f}",
             f"{v['t_batched_ms']:.2f}", f"{v['speedup']:.2f}x"]
            for qid, v in out["queries"].items()]
    hdr = ["query", "parts", "ref_ms", "batched_ms", "speedup"]
    return common.table(rows, hdr) + (
        f"\nreal storage-bitmap path: total "
        f"{out['total_reference_ms']:.1f}ms -> "
        f"{out['total_batched_ms']:.1f}ms ({out['total_speedup']:.2f}x; "
        f"geomean {out['geomean_speedup']:.2f}x)")


def update_root_bench(out: dict):
    return common.update_root_bench_real("bitmap_storage", out)


def render(out: dict) -> str:
    rows = []
    for qid, d in out["queries"].items():
        rows.append([qid] + [f"{s:.2f}x" for s in d["speedup"]]
                    + [" ".join(f"{v*100:.0f}%" for v in d["traffic_saved"])])
    hdr = ["query"] + [f"sel={s}" for s in out["selectivities"]] + ["traffic saved"]
    txt = common.table(rows, hdr) + (
        f'\nmax speedup {out["max_speedup"]:.2f}x (paper Fig 13: up to 3.0x, '
        f'>90% transfer saved at sel 0.9)')
    if "real" in out:
        txt += "\n\n" + render_real(out["real"])
    return txt


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--real-quick", action="store_true",
                    help="real wall-clock only, 3 queries, sf=2 (CI smoke)")
    args = ap.parse_args()
    if args.real_quick:
        o = run_real(**REAL_QUICK_KWARGS)
        update_root_bench(o)
        print(render_real(o))
    else:
        o = run()
        common.save_report("fig13_bitmap_storage", o)
        update_root_bench(o)
        print(render(o))
