"""Residual backend A/B: numpy interpreter vs tensorized jax.jit programs.

Wall-clock of the compute layer's residual evaluation (the post-pushdown
joins / aggregates / TopK) over the merged all-pushdown tables, per TPC-H
query: the ``compiler.interpreter`` oracle vs ``compiler.tensorize``'s
fused jit programs, **identity asserted outside the timed region** and
jit compilation measured separately (observe pass, first-jit cold pass,
then warm best-of-N — only warm runs race the interpreter; that is the
steady state the engine sees, since the shape-bucketed jit cache makes
every later same-bucket execution warm).

The guarded headline is the **residual-dominant subset** (multi-join
probe pipelines: Q4/Q5/Q7/Q8/Q18, where the residual is join+aggregate
over 10k-100k merged rows). Tiny-input queries (Q1/Q6 ship a handful of
pre-aggregated rows) and the lexsort-aggregate outlier (Q3's huge-domain
multi-key group) run interpreter-side under ``residual="auto"`` anyway —
they are reported, not guarded. ``residual_ok`` (CI-enforced by
``benchmarks.perf_guard``) = every query identical, no fallbacks, and
subset speedup >= the 1.3x floor.
"""
from __future__ import annotations

import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.compiler import compile_query_detailed, interpreter, tensorize
from repro.compiler.tpch_ir import QUERY_IDS
from repro.core import engine
from repro.queryproc.table import ColumnTable

from benchmarks import common

# residual-dominant: the residual is a multi-join probe pipeline over the
# fact table's merged rows — the workload the tensor backend targets
SUBSET = ("Q4", "Q5", "Q7", "Q8", "Q18")
SUBSET_FLOOR = 1.3   # acceptance: CI-enforced minimum subset speedup

# the CI perf smoke shares this exact configuration
REAL_QUICK_KWARGS = {"repeats": 3, "sf": 2.0}


def _merged_tables(cq, cat):
    """All-pushdown merged inputs (identical for any decision vector —
    pinned by tests/test_runtime.py — so one vector suffices here)."""
    out = {}
    for t, plan in cq.plans.items():
        parts = [engine.execute_push_plan(plan, p.data)[0]
                 for p in cat.partitions_of(t)]
        out[t] = ColumnTable.concat(parts)
    return out


def run_real(qids=tuple(QUERY_IDS), repeats: int = 3, sf: float = None,
             subset=SUBSET) -> dict:
    sf = sf or common.SF
    cat = common.catalog(num_nodes=2, sf=sf)
    queries = {}
    all_ok = True
    no_fallback = True
    for qid in qids:
        cq = compile_query_detailed(qid)
        merged = _merged_tables(cq, cat)
        rows = sum(len(t) for t in merged.values())
        ref = interpreter.run(cq.residual, merged)
        # outside the timed region: observe pass, first jit, identity
        with common.Timer() as t_obs:
            tensorize.execute(cq.residual, merged)
        with common.Timer() as t_jit:
            r_cold = tensorize.execute(cq.residual, merged)
        r_warm = tensorize.execute(cq.residual, merged)
        identical = engine.results_equal(ref, r_warm.table)
        all_ok &= identical
        no_fallback &= not (r_cold.fell_back or r_warm.fell_back)
        t_int = common.best_time(
            lambda: interpreter.run(cq.residual, merged), repeats)
        t_ten = common.best_time(
            lambda: tensorize.execute(cq.residual, merged), repeats)
        queries[qid] = {
            "rows_in": rows, "n_stages": r_warm.n_stages,
            "jit_hits_warm": r_warm.jit_hits,
            "fell_back": bool(r_cold.fell_back or r_warm.fell_back),
            "t_observe_ms": 1e3 * t_obs.elapsed,
            "t_first_jit_ms": 1e3 * t_jit.elapsed,
            "t_reference_ms": 1e3 * t_int,   # interpreter
            "t_batched_ms": 1e3 * t_ten,     # tensor, warm jit cache
            "speedup": t_int / max(t_ten, 1e-12),
            "identical": identical}
    sub = [q for q in subset if q in queries]
    sub_ref = sum(queries[q]["t_reference_ms"] for q in sub)
    sub_ten = sum(queries[q]["t_batched_ms"] for q in sub)
    sub_speed = sub_ref / max(sub_ten, 1e-12)
    out = common.summarize_real(
        queries, sf, repeats,
        subset=list(sub), subset_speedup=sub_speed,
        subset_floor=SUBSET_FLOOR,
        residual_ok=bool(all_ok and no_fallback
                         and sub_speed >= SUBSET_FLOOR))
    out["all_identical"] = all_ok
    return out


def _headline(real: dict):
    h = common.real_headline(real)
    if h is None:
        return None
    h.update(subset_speedup=round(real["subset_speedup"], 3),
             residual_ok=real["residual_ok"],
             all_identical=real["all_identical"])
    return h


def update_root_bench(out: dict):
    return common.update_root_bench_real("residual", out,
                                         headline_fn=_headline)


def render_real(out: dict) -> str:
    rows = [[qid, v["rows_in"], v["n_stages"],
             "fb" if v["fell_back"] else "-",
             f"{v['t_observe_ms']:.1f}", f"{v['t_first_jit_ms']:.1f}",
             f"{v['t_reference_ms']:.2f}", f"{v['t_batched_ms']:.2f}",
             f"{v['speedup']:.2f}x"] for qid, v in out["queries"].items()]
    hdr = ["query", "rows_in", "stages", "fb", "observe_ms", "jit_ms",
           "interp_ms", "tensor_ms", "speedup"]
    return common.table(rows, hdr) + (
        f"\nresidual backend A/B (warm jit cache): total "
        f"{out['total_reference_ms']:.1f}ms -> "
        f"{out['total_batched_ms']:.1f}ms ({out['total_speedup']:.2f}x; "
        f"geomean {out['geomean_speedup']:.2f}x)\n"
        f"residual-dominant subset {'+'.join(out['subset'])}: "
        f"{out['subset_speedup']:.2f}x (floor {out['subset_floor']:.1f}x) "
        f"residual_ok={out['residual_ok']} "
        f"all_identical={out['all_identical']}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--real-quick", action="store_true",
                    help="sf=2 configuration (CI perf smoke)")
    args = ap.parse_args()
    o = run_real(**REAL_QUICK_KWARGS) if args.real_quick else run_real()
    if not args.real_quick:
        common.save_report("residual_backend", o)
    update_root_bench(o)
    print(render_real(o))
