"""Fig 15: distributed-data-shuffle pushdown on 4-node clusters.

Baseline pushdown: storage executes filter/project, results land round-
robin on the compute nodes, which hash-redistribute ((n-1)/n crosses the
compute fabric). Shuffle pushdown: the storage nodes partition and route
directly to the join's target node. Claims: avg 1.3x over baseline
pushdown / 1.8x over no pushdown; >=1.7x on Q7/Q8/Q17 (non-selective base
scans); little effect on Q6/Q15/Q19 (selective filters); compute-fabric
traffic nearly eliminated for base-table redistribution.
"""
from __future__ import annotations

from repro.core import engine
from repro.core.shuffle import ShuffleConfig, run_shuffle
from repro.core.simulator import MODE_NO_PUSHDOWN
from repro.queryproc import queries as Q

from benchmarks import common

NODES = 4


def run(qids=None) -> dict:
    qids = qids or Q.QUERY_IDS
    cat = common.catalog(num_nodes=NODES)
    scfg = ShuffleConfig(num_compute_nodes=NODES)
    out = {"queries": {}}
    sp_base, sp_npd = [], []
    for qid in qids:
        q = Q.build_query(qid)
        cfg = common.engine_cfg("eager", 1.0, num_compute_nodes=NODES)
        npd = engine.run_query(q, cat, common.engine_cfg(
            MODE_NO_PUSHDOWN, 1.0, num_compute_nodes=NODES))
        base = run_shuffle(q, cat, cfg, scfg, pushdown=False)
        push = run_shuffle(q, cat, cfg, scfg, pushdown=True)
        # no-pushdown baseline also pays the compute-side redistribution
        npd_total = npd.t_total + base.cross_compute_bytes / (
            scfg.compute_net_bw * NODES)
        d = {
            "t_no_pushdown": npd_total,
            "t_baseline_pushdown": base.t_total,
            "t_shuffle_pushdown": push.t_total,
            "cross_bytes_baseline": base.cross_compute_bytes,
            "cross_bytes_pushdown": push.cross_compute_bytes,
            "speedup_vs_baseline": base.t_total / push.t_total,
            "speedup_vs_npd": npd_total / push.t_total,
            "cross_traffic_saved": 1 - push.cross_compute_bytes
            / max(base.cross_compute_bytes, 1),
        }
        sp_base.append(d["speedup_vs_baseline"])
        sp_npd.append(d["speedup_vs_npd"])
        out["queries"][qid] = d
    out["avg_speedup_vs_baseline"] = sum(sp_base) / len(sp_base)
    out["avg_speedup_vs_npd"] = sum(sp_npd) / len(sp_npd)
    return out


def render(out: dict) -> str:
    rows = []
    for qid, d in out["queries"].items():
        rows.append([qid, f'{d["t_no_pushdown"]:.3f}',
                     f'{d["t_baseline_pushdown"]:.3f}',
                     f'{d["t_shuffle_pushdown"]:.3f}',
                     f'{d["speedup_vs_baseline"]:.2f}x',
                     f'{d["speedup_vs_npd"]:.2f}x',
                     f'{d["cross_traffic_saved"]*100:.0f}%'])
    hdr = ["query", "no-pd", "base-pd", "shuffle-pd", "vs base", "vs npd",
           "xtraffic saved"]
    return common.table(rows, hdr) + (
        f'\navg {out["avg_speedup_vs_baseline"]:.2f}x vs baseline pushdown, '
        f'{out["avg_speedup_vs_npd"]:.2f}x vs no pushdown '
        f'(paper Fig 15: 1.3x / 1.8x)')


if __name__ == "__main__":
    o = run()
    common.save_report("fig15_shuffle", o)
    print(render(o))
