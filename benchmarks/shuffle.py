"""Fig 15: distributed-data-shuffle pushdown on 4-node clusters.

Baseline pushdown: storage executes filter/project, results land round-
robin on the compute nodes, which hash-redistribute ((n-1)/n crosses the
compute fabric). Shuffle pushdown: the storage nodes partition and route
directly to the join's target node. Claims: avg 1.3x over baseline
pushdown / 1.8x over no pushdown; >=1.7x on Q7/Q8/Q17 (non-selective base
scans); little effect on Q6/Q15/Q19 (selective filters); compute-fabric
traffic nearly eliminated for base-table redistribution.

``run_real`` additionally measures REAL wall-clock of the storage-side
shuffle execution — each shuffle-marked table's pushed plan with
``shuffle=(key, n)``, per-partition reference loop vs the batch executor's
fused aux pass — asserting per-partition byte-identity (results, slices,
position vectors) every repeat. Headline lands in ``BENCH_engine.json``
under the ``shuffle`` suite (the cross-PR perf trajectory).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import engine
from repro.core.executor import compile_push_plan
from repro.core.plan import execute_push_plan
from repro.core.shuffle import ShuffleConfig, run_shuffle
from repro.core.simulator import MODE_NO_PUSHDOWN
from repro.queryproc import queries as Q

from benchmarks import common

NODES = 4
# the CI perf smoke shares this exact configuration
REAL_QUICK_KWARGS = {"qids": ("Q3", "Q12", "Q14"), "repeats": 3, "sf": 2.0}


def run(qids=None) -> dict:
    qids = qids or Q.QUERY_IDS
    cat = common.catalog(num_nodes=NODES)
    scfg = ShuffleConfig(num_compute_nodes=NODES)
    out = {"queries": {}}
    sp_base, sp_npd = [], []
    for qid in qids:
        q = Q.build_query(qid)
        cfg = common.engine_cfg("eager", 1.0, num_compute_nodes=NODES)
        npd = engine.run_query(q, cat, common.engine_cfg(
            MODE_NO_PUSHDOWN, 1.0, num_compute_nodes=NODES))
        base = run_shuffle(q, cat, cfg, scfg, pushdown=False)
        push = run_shuffle(q, cat, cfg, scfg, pushdown=True)
        # no-pushdown baseline also pays the compute-side redistribution
        npd_total = npd.t_total + base.cross_compute_bytes / (
            scfg.compute_net_bw * NODES)
        d = {
            "t_no_pushdown": npd_total,
            "t_baseline_pushdown": base.t_total,
            "t_shuffle_pushdown": push.t_total,
            "cross_bytes_baseline": base.cross_compute_bytes,
            "cross_bytes_pushdown": push.cross_compute_bytes,
            "speedup_vs_baseline": base.t_total / push.t_total,
            "speedup_vs_npd": npd_total / push.t_total,
            "cross_traffic_saved": 1 - push.cross_compute_bytes
            / max(base.cross_compute_bytes, 1),
        }
        sp_base.append(d["speedup_vs_baseline"])
        sp_npd.append(d["speedup_vs_npd"])
        out["queries"][qid] = d
    out["avg_speedup_vs_baseline"] = sum(sp_base) / len(sp_base)
    out["avg_speedup_vs_npd"] = sum(sp_npd) / len(sp_npd)
    # real wall-clock of the storage-side shuffle execution (batch path)
    out["real"] = run_real(qids=qids if qids != Q.QUERY_IDS else None)
    return out


# ------------------------------------------- real wall-clock (batch path)
def _shuffle_plan(q, table: str, n: int):
    """The query's pushed plan for ``table`` with the shuffle partition
    function attached — the §4.2 request the storage node actually runs.
    The shuffle key must survive into the plan's output schema."""
    plan = q.plans[table]
    key = q.shuffle_keys[table]
    if plan.agg is not None or plan.top_k is not None:
        # shuffling partial aggregates only makes sense on a group key
        return None if key not in (plan.agg[0] if plan.agg else ()) else \
            dataclasses.replace(plan, shuffle=(key, n))
    cols = (plan.columns if key in plan.columns
            else tuple(plan.columns) + (key,))
    return dataclasses.replace(plan, columns=cols, shuffle=(key, n))


def _assert_shuffle_identical(ref_out, bat_parts, bat_aux, ctx):
    for (rt, raux), bt, ba in zip(ref_out, bat_parts, bat_aux):
        for c in rt.columns:
            assert rt.cols[c].dtype == bt.cols[c].dtype and np.array_equal(
                rt.cols[c], bt.cols[c], equal_nan=True), (ctx, c)
        assert np.array_equal(raux["position_vector"],
                              ba["position_vector"]), ctx
        for rp, bp in zip(raux["shuffle_parts"], ba["shuffle_parts"]):
            for c in rp.columns:
                assert np.array_equal(rp.cols[c], bp.cols[c],
                                      equal_nan=True), (ctx, c)


def run_real(qids=None, repeats: int = 3, sf: float = None,
             n_nodes: int = NODES) -> dict:
    """REAL wall-clock of storage-side shuffle execution: per-partition
    reference (plan walk + n boolean filters per partition) vs the batch
    executor's single fused pass with shuffle aux."""
    cat = common.catalog(num_nodes=2, sf=sf or common.SF)
    queries = {}
    for qid in qids or Q.QUERY_IDS:
        q = Q.build_query(qid)
        t_ref = t_bat = 0.0
        tables = []
        for table in q.shuffle_keys:
            plan = _shuffle_plan(q, table, n_nodes)
            if plan is None:
                continue
            parts = [p.data for p in cat.partitions_of(table)]
            cplan = compile_push_plan(plan)
            ref_out = [execute_push_plan(plan, p) for p in parts]
            bat_parts, bat_aux = cplan.execute_batch_parts(parts)
            _assert_shuffle_identical(ref_out, bat_parts, bat_aux,
                                      (qid, table))
            t_ref += common.best_time(
                lambda: [execute_push_plan(plan, p) for p in parts], repeats)
            t_bat += common.best_time(
                lambda: cplan.execute_batch_parts(parts), repeats)
            tables.append(table)
        if not tables:
            continue
        queries[qid] = {"tables": tables, "n_partitions": sum(
            len(cat.partitions_of(t)) for t in tables),
            "t_reference_ms": 1e3 * t_ref, "t_batched_ms": 1e3 * t_bat,
            "speedup": t_ref / max(t_bat, 1e-12), "identical": True}
    return common.summarize_real(queries, sf or common.SF, repeats,
                                 n_nodes=n_nodes)


def render_real(out: dict) -> str:
    if not out["queries"]:
        return "real shuffle path: no shuffle-eligible queries"
    rows = [[qid, "+".join(v["tables"]), v["n_partitions"],
             f"{v['t_reference_ms']:.2f}", f"{v['t_batched_ms']:.2f}",
             f"{v['speedup']:.2f}x"] for qid, v in out["queries"].items()]
    hdr = ["query", "shuffled tables", "parts", "ref_ms", "batched_ms",
           "speedup"]
    return common.table(rows, hdr) + (
        f"\nreal shuffle path: total {out['total_reference_ms']:.1f}ms -> "
        f"{out['total_batched_ms']:.1f}ms ({out['total_speedup']:.2f}x; "
        f"geomean {out['geomean_speedup']:.2f}x, "
        f"min {out['min_speedup']:.2f}x)")


def update_root_bench(out: dict):
    return common.update_root_bench_real("shuffle", out)


def render(out: dict) -> str:
    rows = []
    for qid, d in out["queries"].items():
        rows.append([qid, f'{d["t_no_pushdown"]:.3f}',
                     f'{d["t_baseline_pushdown"]:.3f}',
                     f'{d["t_shuffle_pushdown"]:.3f}',
                     f'{d["speedup_vs_baseline"]:.2f}x',
                     f'{d["speedup_vs_npd"]:.2f}x',
                     f'{d["cross_traffic_saved"]*100:.0f}%'])
    hdr = ["query", "no-pd", "base-pd", "shuffle-pd", "vs base", "vs npd",
           "xtraffic saved"]
    txt = common.table(rows, hdr) + (
        f'\navg {out["avg_speedup_vs_baseline"]:.2f}x vs baseline pushdown, '
        f'{out["avg_speedup_vs_npd"]:.2f}x vs no pushdown '
        f'(paper Fig 15: 1.3x / 1.8x)')
    if "real" in out:
        txt += "\n\n" + render_real(out["real"])
    return txt


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--real-quick", action="store_true",
                    help="real wall-clock only, 3 queries, sf=2 (CI smoke)")
    args = ap.parse_args()
    if args.real_quick:
        o = run_real(**REAL_QUICK_KWARGS)
        update_root_bench(o)
        print(render_real(o))
    else:
        o = run()
        common.save_report("fig15_shuffle", o)
        update_root_bench(o)
        print(render(o))
