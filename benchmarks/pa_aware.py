"""Figs 10-12: PA-aware adaptive pushdown under concurrent queries.

Q14 (high pushdown amenability) + Q12 (lower PA) submitted together.
Claims: PA-aware improves both queries vs plain adaptive (paper: Q14 up to
1.9x, Q12 up to 1.2x); Q14 gains admitted slots, Q12 loses them but does
not slow down; CPU/network resource usage drops (paper: -15% CPU, -31%
network).
"""
from __future__ import annotations

from repro.core import engine
from repro.core.simulator import (MODE_ADAPTIVE, MODE_ADAPTIVE_PA, MODE_EAGER,
                                  MODE_NO_PUSHDOWN)
from repro.queryproc import queries as Q

from benchmarks import common


def run(powers=common.POWERS) -> dict:
    cat = common.catalog()
    qs = [Q.build_query("Q12"), Q.build_query("Q14")]
    out = {"powers": list(powers), "modes": {}}
    for m in (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE, MODE_ADAPTIVE_PA):
        per_q = {"Q12": [], "Q14": []}
        res_usage = []
        for p in powers:
            cfg = common.engine_cfg(m, p)
            runs = engine.run_concurrent(qs, cat, cfg)
            for qid in per_q:
                per_q[qid].append({
                    "t_total": runs[qid].t_total,
                    "admitted": runs[qid].n_admitted,
                    "pushed_back": runs[qid].n_pushed_back})
            sim = runs["Q12"].sim
            res_usage.append({"cpu_s": sum(sim.cpu_busy_by_node.values()),
                              "net_bytes": sim.net_bytes})
        out["modes"][m] = {"queries": per_q, "resources": res_usage}
    # headline numbers
    ad, pa = out["modes"][MODE_ADAPTIVE], out["modes"][MODE_ADAPTIVE_PA]
    out["speedup_q14"] = max(
        a["t_total"] / b["t_total"] for a, b in
        zip(ad["queries"]["Q14"], pa["queries"]["Q14"]))
    out["speedup_q12"] = max(
        a["t_total"] / b["t_total"] for a, b in
        zip(ad["queries"]["Q12"], pa["queries"]["Q12"]))
    out["cpu_reduction"] = max(
        1 - b["cpu_s"] / max(a["cpu_s"], 1e-12) for a, b in
        zip(ad["resources"], pa["resources"]))
    out["net_reduction"] = max(
        1 - b["net_bytes"] / max(a["net_bytes"], 1e-12) for a, b in
        zip(ad["resources"], pa["resources"]))
    return out


def render(out: dict) -> str:
    rows = []
    for m, d in out["modes"].items():
        for qid in ("Q12", "Q14"):
            rows.append([m, qid]
                        + [f'{e["t_total"]:.3f}' for e in d["queries"][qid]]
                        + [" ".join(str(e["admitted"])
                                    for e in d["queries"][qid])])
    hdr = ["mode", "query"] + [f"t@{p}" for p in out["powers"]] + ["admitted"]
    foot = (f'\nPA-aware vs adaptive: Q14 {out["speedup_q14"]:.2f}x, '
            f'Q12 {out["speedup_q12"]:.2f}x  (paper: 1.9x / 1.2x); '
            f'CPU -{out["cpu_reduction"]*100:.0f}%, '
            f'net -{out["net_reduction"]*100:.0f}% (paper: -15% / -31%)')
    return common.table(rows, hdr) + foot


if __name__ == "__main__":
    o = run()
    common.save_report("fig10_12_pa_aware", o)
    print(render(o))
