"""Fig 14: selection-bitmap pushdown, bitmap constructed at the COMPUTE
layer (predicate columns cached; output columns are not).

The compute node filters its cached predicate columns, ships the bitmap;
the storage node applies it WITHOUT scanning the predicate columns.
Claims: wins at LOW selectivity (less data dominates -> scan/CPU savings
show): paper sees 2.0x/2.6x on Q12/Q19 as sel -> 0; disk bytes read drop
10-46%, columns accessed drop 18-56%.

``run_real`` additionally measures REAL wall-clock of the storage-side
bitmap *application* (an ``apply_bitmap`` plan: compute-shipped packed
bitmaps filter the output columns, predicate columns never scanned):
per-partition reference loop vs the batch executor's fused pass, byte-
identity asserted. Headline lands in ``BENCH_engine.json`` under
``bitmap_compute``.
"""
from __future__ import annotations

import numpy as np

from repro.core import engine
from repro.core.bitmap import CacheState, rewrite_all
from repro.core.executor import compile_push_plan
from repro.core.plan import PushPlan, execute_push_plan
from repro.core.simulator import MODE_EAGER
from repro.queryproc import expressions as ex
from repro.queryproc import operators as np_ops
from repro.queryproc import queries as Q

from benchmarks import common

SELECTIVITIES = (0.02, 0.1, 0.3, 0.5, 0.9)
# the CI perf smoke shares this exact configuration
REAL_QUICK_KWARGS = {"qids": ("Q6", "Q14", "Q19"), "repeats": 3, "sf": 2.0}


def _cache_predicates_only(query) -> CacheState:
    plan = query.plans["lineitem"]
    pred_cols = ex.columns_of(plan.predicate) if plan.predicate else set()
    cache = CacheState()
    cache.cache_columns("lineitem", pred_cols)
    return cache


def run(qids=("Q3", "Q4", "Q12", "Q14", "Q19"), sels=SELECTIVITIES) -> dict:
    cat = common.catalog()
    out = {"selectivities": list(sels), "queries": {}}
    for qid in qids:
        speeds, disk_saved, cols_skipped = [], [], []
        for sel in sels:
            q = Q.build_query(qid, fact_selectivity=sel)
            cfg = common.engine_cfg(MODE_EAGER, 1.0)
            reqs = engine.plan_requests(q, cat)
            base = engine.run_query(q, cat, cfg, requests=reqs)
            rw_reqs, metrics = rewrite_all(reqs, _cache_predicates_only(q))
            bm = engine.run_query(q, cat, cfg, requests=rw_reqs)
            t_base = base.t_pushable + base.net_bytes / cfg.compute_bw
            t_bm = bm.t_pushable + bm.net_bytes / cfg.compute_bw
            speeds.append(t_base / t_bm)
            base_in = sum(r.cost.s_in for r in reqs if r.table == "lineitem")
            disk_saved.append(metrics["disk_saved"] / max(base_in, 1))
            cols_skipped.append(metrics["cols_skipped"])
        out["queries"][qid] = {"speedup": speeds, "disk_saved": disk_saved,
                               "cols_skipped_total": cols_skipped}
    out["max_speedup"] = max(max(d["speedup"]) for d in out["queries"].values())
    # real wall-clock of the storage-side bitmap application (batch path)
    out["real"] = run_real(qids=qids)
    return out


# ------------------------------------------- real wall-clock (batch path)
def run_real(qids=("Q1", "Q3", "Q4", "Q6", "Q12", "Q14", "Q19"),
             repeats: int = 3, sf: float = None, table: str = "lineitem"
             ) -> dict:
    """REAL wall-clock of storage-side bitmap application: compute builds
    the bitmaps (outside the timer — that work moves across the network,
    Fig 4), then the storage node applies them to every partition —
    per-partition reference loop vs one fused batch pass."""
    cat = common.catalog(num_nodes=2, sf=sf or common.SF)
    parts = [p.data for p in cat.partitions_of(table)]
    queries = {}
    for qid in qids:
        plan = Q.build_query(qid).plans[table]
        if plan.predicate is None:
            continue
        pred_cols = ex.columns_of(plan.predicate)
        derived = {n for n, _, _ in plan.derive}
        out_cols = tuple(c for c in plan.accessed_columns()
                         if c not in derived and c not in pred_cols)
        if not out_cols:
            continue
        # compute layer: evaluate the cached predicate columns, pack
        bitmaps = [np_ops.selection_bitmap(p, plan.predicate) for p in parts]
        aplan = PushPlan(table, out_cols, apply_bitmap=True)
        cplan = compile_push_plan(aplan)
        ref_out = [execute_push_plan(aplan, p, bitmap=w)
                   for p, w in zip(parts, bitmaps)]
        bat_parts, _ = cplan.execute_batch_parts(parts, bitmaps)
        for (rt, _), bt in zip(ref_out, bat_parts):
            for c in rt.columns:
                assert rt.cols[c].dtype == bt.cols[c].dtype and \
                    np.array_equal(rt.cols[c], bt.cols[c],
                                   equal_nan=True), (qid, c)
        t_ref = common.best_time(
            lambda: [execute_push_plan(aplan, p, bitmap=w)
                     for p, w in zip(parts, bitmaps)], repeats)
        t_bat = common.best_time(
            lambda: cplan.execute_batch_parts(parts, bitmaps), repeats)
        queries[qid] = {"n_partitions": len(parts),
                        "n_out_cols": len(out_cols),
                        "t_reference_ms": 1e3 * t_ref,
                        "t_batched_ms": 1e3 * t_bat,
                        "speedup": t_ref / max(t_bat, 1e-12),
                        "identical": True}
    return common.summarize_real(queries, sf or common.SF, repeats)


def render_real(out: dict) -> str:
    if not out["queries"]:
        return "real bitmap-apply path: no eligible queries"
    rows = [[qid, v["n_partitions"], v["n_out_cols"],
             f"{v['t_reference_ms']:.2f}", f"{v['t_batched_ms']:.2f}",
             f"{v['speedup']:.2f}x"] for qid, v in out["queries"].items()]
    hdr = ["query", "parts", "out_cols", "ref_ms", "batched_ms", "speedup"]
    return common.table(rows, hdr) + (
        f"\nreal bitmap-apply path: total "
        f"{out['total_reference_ms']:.1f}ms -> "
        f"{out['total_batched_ms']:.1f}ms ({out['total_speedup']:.2f}x; "
        f"geomean {out['geomean_speedup']:.2f}x)")


def update_root_bench(out: dict):
    return common.update_root_bench_real("bitmap_compute", out)


def render(out: dict) -> str:
    rows = []
    for qid, d in out["queries"].items():
        rows.append([qid] + [f"{s:.2f}x" for s in d["speedup"]]
                    + [" ".join(f"{v*100:.0f}%" for v in d["disk_saved"])])
    hdr = ["query"] + [f"sel={s}" for s in out["selectivities"]] + ["disk saved"]
    txt = common.table(rows, hdr) + (
        f'\nmax speedup {out["max_speedup"]:.2f}x (paper Fig 14: 2.0-2.6x '
        f'as sel->0; 10-46% scan reduction)')
    if "real" in out:
        txt += "\n\n" + render_real(out["real"])
    return txt


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--real-quick", action="store_true",
                    help="real wall-clock only, 3 queries, sf=2 (CI smoke)")
    args = ap.parse_args()
    if args.real_quick:
        o = run_real(**REAL_QUICK_KWARGS)
        update_root_bench(o)
        print(render_real(o))
    else:
        o = run()
        common.save_report("fig14_bitmap_compute", o)
        update_root_bench(o)
        print(render(o))
