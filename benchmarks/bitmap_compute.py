"""Fig 14: selection-bitmap pushdown, bitmap constructed at the COMPUTE
layer (predicate columns cached; output columns are not).

The compute node filters its cached predicate columns, ships the bitmap;
the storage node applies it WITHOUT scanning the predicate columns.
Claims: wins at LOW selectivity (less data dominates -> scan/CPU savings
show): paper sees 2.0x/2.6x on Q12/Q19 as sel -> 0; disk bytes read drop
10-46%, columns accessed drop 18-56%.
"""
from __future__ import annotations

from repro.core import engine
from repro.core.bitmap import CacheState, rewrite_all
from repro.core.simulator import MODE_EAGER
from repro.queryproc import expressions as ex
from repro.queryproc import queries as Q

from benchmarks import common

SELECTIVITIES = (0.02, 0.1, 0.3, 0.5, 0.9)


def _cache_predicates_only(query) -> CacheState:
    plan = query.plans["lineitem"]
    pred_cols = ex.columns_of(plan.predicate) if plan.predicate else set()
    cache = CacheState()
    cache.cache_columns("lineitem", pred_cols)
    return cache


def run(qids=("Q3", "Q4", "Q12", "Q14", "Q19"), sels=SELECTIVITIES) -> dict:
    cat = common.catalog()
    out = {"selectivities": list(sels), "queries": {}}
    for qid in qids:
        speeds, disk_saved, cols_skipped = [], [], []
        for sel in sels:
            q = Q.build_query(qid, fact_selectivity=sel)
            cfg = common.engine_cfg(MODE_EAGER, 1.0)
            reqs = engine.plan_requests(q, cat)
            base = engine.run_query(q, cat, cfg, requests=reqs)
            rw_reqs, metrics = rewrite_all(reqs, _cache_predicates_only(q))
            bm = engine.run_query(q, cat, cfg, requests=rw_reqs)
            t_base = base.t_pushable + base.net_bytes / cfg.compute_bw
            t_bm = bm.t_pushable + bm.net_bytes / cfg.compute_bw
            speeds.append(t_base / t_bm)
            base_in = sum(r.cost.s_in for r in reqs if r.table == "lineitem")
            disk_saved.append(metrics["disk_saved"] / max(base_in, 1))
            cols_skipped.append(metrics["cols_skipped"])
        out["queries"][qid] = {"speedup": speeds, "disk_saved": disk_saved,
                               "cols_skipped_total": cols_skipped}
    out["max_speedup"] = max(max(d["speedup"]) for d in out["queries"].values())
    return out


def render(out: dict) -> str:
    rows = []
    for qid, d in out["queries"].items():
        rows.append([qid] + [f"{s:.2f}x" for s in d["speedup"]]
                    + [" ".join(f"{v*100:.0f}%" for v in d["disk_saved"])])
    hdr = ["query"] + [f"sel={s}" for s in out["selectivities"]] + ["disk saved"]
    return common.table(rows, hdr) + (
        f'\nmax speedup {out["max_speedup"]:.2f}x (paper Fig 14: 2.0-2.6x '
        f'as sel->0; 10-46% scan reduction)')


if __name__ == "__main__":
    o = run()
    common.save_report("fig14_bitmap_compute", o)
    print(render(o))
