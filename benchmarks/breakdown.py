"""Fig 9: execution-time breakdown (Q12/Q14) at high/medium/low power.

Claims: the non-pushable portion is stable across modes; adaptive's
pushdown and pushback paths finish near-simultaneously (the balance
condition T_pd_part ~= T_pb_part of Eq 2).
"""
from __future__ import annotations

from repro.core import engine
from repro.core.arbitrator import PUSHBACK, PUSHDOWN
from repro.core.simulator import MODE_ADAPTIVE, MODE_EAGER, MODE_NO_PUSHDOWN
from repro.queryproc import queries as Q

from benchmarks import common


def run(qids=("Q12", "Q14"), powers=(1.0, 0.375, 0.12)) -> dict:
    cat = common.catalog()
    out = {"powers": list(powers), "queries": {}}
    for qid in qids:
        q = Q.build_query(qid)
        rows = []
        for p in powers:
            entry = {"power": p}
            for m in (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE):
                r = engine.run_query(q, cat, common.engine_cfg(m, p))
                fins = {PUSHDOWN: 0.0, PUSHBACK: 0.0}
                for path, _s, f in r.sim.per_request.values():
                    fins[path] = max(fins[path], f)
                entry[m] = {"t_total": r.t_total,
                            "t_pushable": r.t_pushable,
                            "t_nonpushable": r.t_nonpushable,
                            "pd_part_finish": fins[PUSHDOWN],
                            "pb_part_finish": fins[PUSHBACK]}
            a = entry[MODE_ADAPTIVE]
            lo = min(a["pd_part_finish"], a["pb_part_finish"])
            hi = max(a["pd_part_finish"], a["pb_part_finish"])
            entry["balance"] = lo / hi if hi > 0 else 1.0
            rows.append(entry)
        out["queries"][qid] = rows
    return out


def render(out: dict) -> str:
    rows = []
    for qid, rs in out["queries"].items():
        for e in rs:
            a = e[MODE_ADAPTIVE]
            rows.append([qid, e["power"],
                         f'{e[MODE_NO_PUSHDOWN]["t_total"]:.3f}',
                         f'{e[MODE_EAGER]["t_total"]:.3f}',
                         f'{a["t_total"]:.3f}',
                         f'{a["pd_part_finish"]:.3f}',
                         f'{a["pb_part_finish"]:.3f}',
                         f'{e["balance"]:.2f}',
                         f'{a["t_nonpushable"]:.3f}'])
    hdr = ["query", "power", "npd", "eager", "adaptive", "pd-part", "pb-part",
           "balance", "non-pushable"]
    return common.table(rows, hdr) + \
        "\n(balance -> 1.0 means pd/pb paths finish together, Eq 2)"


if __name__ == "__main__":
    o = run()
    common.save_report("fig9_breakdown", o)
    print(render(o))
