"""Compiler suite: per-query plan-compile time and compiled-vs-hand-built
makespan deltas (JSON), so future PRs get a trajectory.

For every TPC-H query: compile through ``repro.compiler.compile_query``
(IR -> amenability split -> PushPlans + residual), run both the compiled
and the seed's hand-built plans through the engine, and report

- ``compile_ms``      median wall-clock of IR construction + split,
- ``frontier_*``      pushed-stage counts (compiled vs hand-built; the
                      compiled frontier is never smaller),
- ``makespan_*``      simulated pushable-phase makespan both ways and the
                      delta fraction (negative = compiled plans faster,
                      e.g. pushed dimension filters shrink S_out),
- ``equal``           result equality, asserted.
"""
from __future__ import annotations

import time
from typing import Dict

from benchmarks import common
from repro.compiler import compile_query_detailed
from repro.compiler.splitter import frontier_size
from repro.core import engine
from repro.queryproc import queries as Q


def run(qids=None, repeats: int = 5) -> Dict:
    qids = qids or Q.QUERY_IDS
    cat = common.catalog(num_nodes=2)
    cfg = common.engine_cfg("adaptive")
    queries: Dict[str, Dict] = {}
    for qid in qids:
        times = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            cq = compile_query_detailed(qid)
            times.append(time.perf_counter() - t0)
        legacy = Q.build_query_legacy(qid)
        rc = engine.run_query(cq.query, cat, cfg)
        rl = engine.run_query(legacy, cat, cfg)
        equal = engine.results_equal(rc.result, rl.result)
        assert equal, f"{qid}: compiled result diverges from hand-built"
        delta = (rc.t_pushable - rl.t_pushable) / max(rl.t_pushable, 1e-12)
        queries[qid] = {
            "compile_ms": 1e3 * sorted(times)[len(times) // 2],
            "frontier_compiled": frontier_size(cq.query.plans),
            "frontier_hand_built": frontier_size(legacy.plans),
            "makespan_compiled": rc.t_pushable,
            "makespan_hand_built": rl.t_pushable,
            "makespan_delta_frac": delta,
            "net_bytes_compiled": rc.net_bytes,
            "net_bytes_hand_built": rl.net_bytes,
            "equal": equal,
        }
    vals = list(queries.values())
    return {
        "queries": queries,
        "all_equal": all(v["equal"] for v in vals),
        "compile_ms_max": max(v["compile_ms"] for v in vals),
        "n_larger_frontier": sum(
            v["frontier_compiled"] > v["frontier_hand_built"] for v in vals),
        "avg_makespan_delta_frac": (
            sum(v["makespan_delta_frac"] for v in vals) / len(vals)),
    }


def render(out: Dict) -> str:
    rows = [[qid, f"{v['compile_ms']:.2f}",
             f"{v['frontier_compiled']} vs {v['frontier_hand_built']}",
             f"{v['makespan_delta_frac']*100:+.1f}%",
             f"{(v['net_bytes_compiled']/max(v['net_bytes_hand_built'],1)-1)*100:+.1f}%"]
            for qid, v in out["queries"].items()]
    tbl = common.table(rows, ["query", "compile ms", "frontier (c vs h)",
                              "makespan delta", "net delta"])
    return (f"{tbl}\n{out['n_larger_frontier']} queries with strictly "
            f"larger compiled frontier; all results equal="
            f"{out['all_equal']}")
