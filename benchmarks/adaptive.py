"""Fig 6: Adaptive vs No-pushdown vs Eager across storage computational
power, all queries. Claims checked:

- eager degrades as power drops and crosses below no-pushdown,
- adaptive ~= min(baselines) everywhere (tolerance for Alg-1's greedy
  spill tail), and BEATS both around the break-even point,
- break-even speedup up to ~1.9x (paper: 1.5x average, 1.9x best).

``run_real`` additionally drives the decision-faithful runtime
(``core.runtime.run_stream``) for REAL wall-clock: arrival-timed query
waves execute their simulated decision split on per-node worker pools
(pushdown storage-side batched, pushback shipped raw + replayed at the
compute layer), adaptive vs the two forced baselines, asserting
byte-identical results across modes every run. Headline lands in
``BENCH_engine.json`` under the ``runtime`` suite.

``run_correction`` is the online-feedback A/B (the ``correction`` suite):
repeated runs through a shared ``CardinalityCorrector`` must shrink the
``s_out_estimate_ratio`` error round over round, the cost-based frontier
cut must ship fewer real net bytes than the maximal frontier on a
lowered query (Q19), and the corrected chooser re-scores the
estimation-bias cuts against measured bytes (which flip depends on the
catalog's NDV profile — Q4 flips at every tested sf) — results
byte-identical throughout.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core import engine
from repro.core.cost import CardinalityCorrector
from repro.core.simulator import (MODE_ADAPTIVE, MODE_EAGER, MODE_NO_PUSHDOWN)
from repro.queryproc import queries as Q

from benchmarks import common

# the CI perf smoke shares this exact configuration
REAL_QUICK_KWARGS = {"qids": ("Q1", "Q6", "Q12", "Q14"), "repeats": 3,
                     "sf": 2.0}
CORRECTION_QUICK_KWARGS = {"qids": ("Q1", "Q4", "Q14", "Q18", "Q19"),
                           "rounds": 4, "sf": 2.0}
CHAOS_QUICK_KWARGS = {"sf": 1.0, "seed": 2026}


def run(powers=common.POWERS, qids=None) -> dict:
    cat = common.catalog()
    qids = qids or Q.QUERY_IDS
    out = {"powers": list(powers), "queries": {}}
    best_even, avg_even = 0.0, []
    for qid in qids:
        q = Q.build_query(qid)
        per_mode = {m: [] for m in (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE)}
        admitted = []
        for p in powers:
            for m in per_mode:
                r = engine.run_query(q, cat, common.engine_cfg(m, p))
                per_mode[m].append(r.t_total)
                if m == MODE_ADAPTIVE:
                    admitted.append(r.n_admitted)
        npd, eag, ada = (per_mode[m] for m in
                         (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE))
        # break-even: power where eager and no-pushdown actually cross.
        # Queries whose curves never meet in range (non-pushable-dominated:
        # the paper's "insensitive" Q2/Q3/Q18 class) have no break-even
        # point and are excluded from the break-even average, as in Fig 6.
        i = min(range(len(powers)), key=lambda i: abs(eag[i] - npd[i]))
        crosses = abs(eag[i] - npd[i]) / npd[i] <= 0.15
        sp = min(npd[i], eag[i]) / ada[i]
        if crosses:
            best_even = max(best_even, sp)
            avg_even.append(sp)
        out["queries"][qid] = {
            "no_pushdown": npd, "eager": eag, "adaptive": ada,
            "admitted": admitted,
            "break_even_power": powers[i] if crosses else None,
            "break_even_speedup": sp if crosses else None,
        }
    out["breakeven_speedup_max"] = best_even
    out["breakeven_speedup_avg"] = sum(avg_even) / max(1, len(avg_even))
    out["num_breakeven_queries"] = len(avg_even)
    # real wall-clock of the decision-faithful runtime (stream driver)
    out["real"] = run_real(qids=qids if qids != Q.QUERY_IDS else None)
    # online-correction A/B (cost-calibrated frontier loop)
    out["correction"] = run_correction()
    # fault-tolerance A/B (recovery vs fail-to-error vs blanket pushback)
    out["chaos"] = run_chaos(**CHAOS_QUICK_KWARGS)
    return out


# ---------------------------------------- real wall-clock (stream driver)
REAL_MODES = (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE)


def _stream(qids, wave_gap: float):
    from repro.core import runtime
    return [runtime.StreamQuery(Q.build_query(qid), arrival=i * wave_gap)
            for i, qid in enumerate(qids)]


def run_real(qids=None, repeats: int = 3, sf: float = None,
             power: float = 0.375, wave_gap: float = 0.01) -> dict:
    """REAL wall-clock A/B of the decision-faithful runtime: the same
    arrival-timed multi-query stream under adaptive vs the two forced
    baselines. ``storage_power`` shrinks the per-node pushdown worker pool
    (multi-tenancy emulated with real threads, like the paper caps the
    actor scheduler), so eager really queues behind the throttled storage
    workers while no-pushdown really pays the ship-and-replay copies —
    adaptive must not lose to the worse of the two. Byte-identity of every
    query result across all three modes is asserted every repeat."""
    from repro.core import runtime
    from repro.core.cost import StorageResources

    sf = sf or common.SF
    cat = common.catalog(num_nodes=2, sf=sf)
    qids = tuple(qids or Q.QUERY_IDS)
    stream = _stream(qids, wave_gap)
    res = StorageResources(storage_power=power)
    repeats = max(1, repeats)
    per_mode = {}
    best: dict = {m: None for m in REAL_MODES}
    runs: dict = {m: None for m in REAL_MODES}
    reference = None                     # first measured run's results
    # repeats interleave across modes (mode A, B, C, A, B, C, ...): a
    # machine-load burst then hits every mode instead of biasing whichever
    # mode owned that timing window; best-of per mode is the estimator
    for rep in range(repeats + 1):       # first round is the warm-up
        for mode in REAL_MODES:
            r = runtime.run_stream(
                stream, cat, engine.EngineConfig(res=res, mode=mode))
            if rep == 0:
                continue
            # byte-identity asserted EVERY measured repeat, not only on
            # the kept best-of run — a racy divergence anywhere aborts
            if reference is None:
                reference = r.results
            else:
                _assert_results_identical(reference, r.results, mode, qids)
            if best[mode] is None or r.wall_clock < best[mode]:
                best[mode], runs[mode] = r.wall_clock, r
    for mode in REAL_MODES:
        run = runs[mode]
        per_mode[mode] = {
            "wall_clock_ms": 1e3 * best[mode],
            "n_pushdown": run.n_pushdown, "n_pushback": run.n_pushback,
            "real_net_bytes": run.real_net_bytes,
            # stream-relative completion times (arrival + queueing
            # included) — queue position, NOT per-query execution cost
            "finish_ms": {qid: 1e3 * d["finish_s"]
                          for qid, d in run.per_query.items()},
        }
    t_ad = per_mode[MODE_ADAPTIVE]["wall_clock_ms"]
    t_eg = per_mode[MODE_EAGER]["wall_clock_ms"]
    t_np = per_mode[MODE_NO_PUSHDOWN]["wall_clock_ms"]
    worse, best_base = max(t_eg, t_np), min(t_eg, t_np)
    return {
        "sf": sf, "power": power, "repeats": repeats, "wave_gap": wave_gap,
        "qids": list(qids), "modes": per_mode,
        "all_identical": True,           # asserted per repeat above
        "t_adaptive_ms": t_ad, "t_eager_ms": t_eg, "t_no_pushdown_ms": t_np,
        "worse_baseline_ms": worse, "best_baseline_ms": best_base,
        # the monotone trajectory number: adaptive vs the worse baseline
        "total_speedup": worse / max(t_ad, 1e-9),
        # adaptive must not LOSE to the worse forced baseline (the paper's
        # core adaptive claim, Fig 6); the 1.15 band absorbs thread-
        # scheduling noise on 2-core shared runners — the recorded sf=4
        # trajectory entries run well above 1.0
        "adaptive_ok": t_ad <= 1.15 * worse,
    }


def _assert_results_identical(base, other, mode, qids):
    for qid in qids:
        a, b = base[qid], other[qid]
        assert a.columns == b.columns, (mode, qid, a.columns, b.columns)
        for c in a.columns:
            assert a.cols[c].dtype == b.cols[c].dtype and np.array_equal(
                a.cols[c], b.cols[c], equal_nan=True), (mode, qid, c)


# ------------------------------------ trace-enabled smoke (CI artifacts)
TRACE_SMOKE_KWARGS = {"qids": ("Q1", "Q6", "Q12", "Q18"), "sf": 1.0}


def run_trace_smoke(qids=None, sf: float = 1.0, power: float = 0.375,
                    wave_gap: float = 0.01,
                    out_dir: str = "reports/trace") -> dict:
    """Trace-enabled CI smoke: one traced arrival-timed stream at sf=1.

    Asserts the trace reconciles EXACTLY with the driver's accounting —
    each ``query`` span's ``real_net_bytes`` equals ``per_query``'s, and
    the ``storage_execute``/``compute_replay`` spans under it sum to the
    same number — then writes the three exporter artifacts (JSONL, Chrome
    ``trace_event`` loadable in chrome://tracing or Perfetto, terse
    summary table) for CI upload."""
    from pathlib import Path

    from repro import obs
    from repro.core import runtime
    from repro.core.cost import StorageResources
    from repro.obs import export as obs_export
    from repro.queryproc import tpch

    qids = tuple(qids or Q.QUERY_IDS)
    cat = tpch.build_catalog(sf=sf, num_nodes=2, rows_per_partition=4_000)
    stream = _stream(qids, wave_gap)
    cfg = engine.EngineConfig(res=StorageResources(storage_power=power),
                              mode=MODE_ADAPTIVE)
    with obs.tracing() as tr:
        run = runtime.run_stream(stream, cat, cfg)
    spans = tr.snapshot()
    (stream_span,) = [s for s in spans if s.name == "run_stream"]
    assert stream_span.attrs["real_net_bytes"] == run.real_net_bytes
    qspans = {s.attrs["qid"]: s for s in spans if s.name == "query"}
    assert set(qspans) == set(run.per_query)
    for key, sp in qspans.items():
        want = run.per_query[key]["real_net_bytes"]
        assert sp.attrs["real_net_bytes"] == want, key
        got = sum(s.attrs["shipped_bytes"] for s in spans
                  if s.parent == sp.sid
                  and s.name in ("storage_execute", "compute_replay"))
        assert got == want, (key, got, want)   # EXACT, not approximate
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    meta = {"sf": sf, "mode": MODE_ADAPTIVE, "power": power,
            "qids": list(qids)}
    paths = {
        "jsonl": obs_export.to_jsonl(tr, out / "stream_trace.jsonl", meta),
        "chrome": obs_export.to_chrome_trace(
            tr, out / "stream_trace_chrome.json", meta),
        "summary": str(out / "stream_trace_summary.txt"),
    }
    summary = obs_export.summary_table(tr)
    Path(paths["summary"]).write_text(summary + "\n")
    return {"sf": sf, "qids": list(qids), "n_spans": len(spans),
            "real_net_bytes": run.real_net_bytes,
            "reconciled_exactly": True, "artifacts": paths,
            "summary": summary}


# ------------------------------------------ chaos A/B (fault tolerance)
# The fleet failure model: a ~10% fleet-wide uncorrelated transient /
# timeout rate, plus one *degraded node* (node 0) crashing half of its
# storage requests. Real outages are sticky per machine — and an i.i.d.
# 10% essentially never fails 3 retries in a row, so the correlated
# component is what actually exercises exhaustion -> demotion (and kills
# the fail-to-error baseline's queries). The fault domain is the storage
# NODE, not the pushdown operator: a degraded node fails raw-projection
# reads exactly like pushdown executes, so blanket no-pushdown does not
# dodge the outage — it just pays ship-and-replay on top of the same
# retries. Only the local-replay fallback (compute-side, after pushback
# exhaustion) sits outside the fault domain.
CHAOS_SPEC = "node0.crash:0.5,transient:0.06,timeout:0.04"
CHAOS_FAILURE_RATE = 0.10       # the fleet-wide (uncorrelated) component
CHAOS_MAX_RESTARTS = 50


def _chaos_plans(qids, seed: int):
    """One pinned-schedule plan per query (seed offset by position): each
    query's injections are independent of how many times its *neighbors*
    restarted, so every arm rehearses the same per-query schedule."""
    from repro.core.faults import FaultPlan
    return {qid: FaultPlan.from_spec(CHAOS_SPEC, seed=seed + i)
            for i, qid in enumerate(qids)}


def run_chaos(qids=None, sf: float = None, seed: int = 2026,
              power: float = 1.0, wave_gap: float = 0.005) -> dict:
    """Fault-tolerance A/B under ~10% storage failure, sf=1 query mix.

    Four arms over identical pinned per-query fault schedules:

    - ``clean``          — adaptive, no faults (reference results/times)
    - ``recovery``       — adaptive + retry/deadline + breaker, exhausted
      groups demoted to pushback: every query must complete byte-identical
      to clean (``recovered_rate`` == 1.0)
    - ``fail_to_error``  — same faults, ``demote_on_exhaust=False``: an
      exhausted group aborts the query, which restarts from scratch under
      the next deterministic schedule (epoch bump) until it completes —
      the recovery-at-query-granularity baseline
    - ``no_pushdown``    — blanket pushback: the degraded node fails its
      raw-projection reads just like pushdown executes, so this arm pays
      the same retries PLUS full ship-and-replay on every request

    ``chaos_ok`` (enforced by perf_guard like ``adaptive_ok``): all
    results byte-identical, full recovery, and adaptive-with-recovery not
    losing to EITHER the fail-to-error baseline or blanket no-pushdown on
    total wall clock. Also asserted: the injection ledgers reconcile
    exactly with the runs' recovery accounting, and a streamed
    (``run_stream``) chaos pass with hedging returns byte-identical
    results too."""
    import time as _time

    from repro.core import runtime
    from repro.core.cost import StorageResources
    from repro.core.faults import (CircuitBreaker, FaultExhausted,
                                   FaultPlan, HedgePolicy, RetryPolicy)

    sf = sf or 1.0
    cat = common.catalog(num_nodes=2, sf=sf)
    qids = tuple(qids or Q.QUERY_IDS)
    res = StorageResources(storage_power=power)
    retry = RetryPolicy()
    strict = RetryPolicy(demote_on_exhaust=False)

    def timed(qid, cfg):
        t0 = _time.perf_counter()
        r = engine.run_query(Q.build_query(qid), cat, cfg)
        return _time.perf_counter() - t0, r

    # ---- clean reference -------------------------------------------------
    clean_t, clean_res = {}, {}
    for qid in qids:
        clean_t[qid], r = timed(qid, engine.EngineConfig(
            res=res, mode=MODE_ADAPTIVE))
        clean_res[qid] = r.result

    # ---- recovery: demote-on-exhaust + circuit breaker -------------------
    plans = _chaos_plans(qids, seed)
    breaker = CircuitBreaker()
    rec_t, n_demoted, n_retries, n_injected = {}, 0, 0, 0
    all_identical = True
    for qid in qids:
        rec_t[qid], r = timed(qid, engine.EngineConfig(
            res=res, mode=MODE_ADAPTIVE, faults=plans[qid], retry=retry,
            breaker=breaker))
        all_identical &= engine.results_equal(clean_res[qid], r.result)
        rec = r.recovery or {}
        n_demoted += rec.get("n_demoted", 0)
        n_retries += rec.get("retries", 0)
        n_injected += rec.get("faults_injected", 0)
    # ledger reconciliation: the schedules' own event logs count exactly
    # the injections the runs accounted
    ledger = sum(sum(p.counts().values()) for p in plans.values())
    assert ledger == n_injected, (ledger, n_injected)
    recovered_rate = 1.0                   # demotion never surfaces an error

    # ---- fail-to-error: whole-query restart on exhaustion ----------------
    plans_fte = _chaos_plans(qids, seed)   # fresh ledgers, same schedules
    fte_t, restarts, first_try = {}, 0, 0
    for qid in qids:
        cfg = engine.EngineConfig(res=res, mode=MODE_ADAPTIVE,
                                  faults=plans_fte[qid], retry=strict)
        t_total, tries = 0.0, 0
        while True:
            tries += 1
            t0 = _time.perf_counter()
            try:
                engine.run_query(Q.build_query(qid), cat, cfg)
                t_total += _time.perf_counter() - t0
                break
            except FaultExhausted:
                t_total += _time.perf_counter() - t0
                plans_fte[qid].bump_epoch()   # next deterministic schedule
                if tries > CHAOS_MAX_RESTARTS:
                    raise
        fte_t[qid] = t_total
        restarts += tries - 1
        first_try += tries == 1

    # ---- blanket no-pushdown under the same schedules --------------------
    npd_t = {}
    plans_npd = _chaos_plans(qids, seed)
    for qid in qids:
        npd_t[qid], r = timed(qid, engine.EngineConfig(
            res=res, mode=MODE_NO_PUSHDOWN, faults=plans_npd[qid],
            retry=retry))
        all_identical &= engine.results_equal(clean_res[qid], r.result)

    # ---- streamed chaos pass: run_stream + hedging, byte-identity --------
    stream = _stream(qids, wave_gap)
    s_clean = runtime.run_stream(stream, cat, engine.EngineConfig(
        res=res, mode=MODE_ADAPTIVE))
    s_chaos = runtime.run_stream(stream, cat, engine.EngineConfig(
        res=res, mode=MODE_ADAPTIVE,
        faults=FaultPlan.from_spec(CHAOS_SPEC, seed=seed),
        retry=retry, hedge=HedgePolicy(), breaker=CircuitBreaker()))
    _assert_results_identical(s_clean.results, s_chaos.results, "chaos",
                              list(s_clean.results))

    t_clean = sum(clean_t.values())
    t_rec = sum(rec_t.values())
    t_fte = sum(fte_t.values())
    t_npd = sum(npd_t.values())
    p99 = lambda d: float(np.percentile(list(d.values()), 99))  # noqa: E731
    return {
        "sf": sf, "power": power, "seed": seed, "qids": list(qids),
        "spec": CHAOS_SPEC, "failure_rate": CHAOS_FAILURE_RATE,
        "all_identical": bool(all_identical),
        "stream_identical": True,          # asserted above
        "recovered_rate": recovered_rate,
        "n_demoted": n_demoted, "retries": n_retries,
        "faults_injected": n_injected,
        "fte_restarts": restarts,
        "fte_first_try_rate": first_try / len(qids),
        "stream_demoted": s_chaos.n_demoted,
        "stream_retries": s_chaos.retries,
        "stream_hedged": s_chaos.hedged,
        "t_clean_ms": 1e3 * t_clean,
        "t_recovery_ms": 1e3 * t_rec,
        "t_fail_to_error_ms": 1e3 * t_fte,
        "t_no_pushdown_ms": 1e3 * t_npd,
        "p99_clean_ms": 1e3 * p99(clean_t),
        "p99_recovery_ms": 1e3 * p99(rec_t),
        "p99_degradation": p99(rec_t) / max(p99(clean_t), 1e-9),
        # the monotone trajectory number: recovery vs the query-restart
        # baseline over the same schedules
        "total_speedup": t_fte / max(t_rec, 1e-9),
        # recovery must not lose to EITHER coping strategy (1.15 band
        # absorbs scheduling noise on shared runners, like adaptive_ok)
        "chaos_ok": bool(all_identical and recovered_rate >= 1.0
                         and t_rec <= 1.15 * t_fte
                         and t_rec <= 1.15 * t_npd),
    }


def _chaos_headline(out: dict) -> dict:
    return {k: out[k] for k in
            ("sf", "seed", "failure_rate", "all_identical",
             "stream_identical", "recovered_rate", "n_demoted", "retries",
             "faults_injected", "fte_restarts", "p99_degradation",
             "t_recovery_ms", "t_fail_to_error_ms", "t_no_pushdown_ms",
             "total_speedup", "chaos_ok")}


def update_root_bench_chaos(out: dict):
    return common.update_root_bench("chaos", out, _chaos_headline(out))


def render_chaos(out: dict) -> str:
    rows = [
        ["clean", f'{out["t_clean_ms"]:.1f}', "-", "-", "-"],
        ["recovery", f'{out["t_recovery_ms"]:.1f}', out["n_demoted"],
         out["retries"], out["faults_injected"]],
        ["fail_to_error", f'{out["t_fail_to_error_ms"]:.1f}',
         f'{out["fte_restarts"]} restarts', "-", "-"],
        ["no_pushdown", f'{out["t_no_pushdown_ms"]:.1f}', "-", "-", "-"],
    ]
    hdr = ["arm", "wall_ms", "demoted", "retries", "injected"]
    return common.table(rows, hdr) + (
        f'\nchaos (sf={out["sf"]}, ~{100 * out["failure_rate"]:.0f}% '
        f'storage failure, seed={out["seed"]}): recovered '
        f'{100 * out["recovered_rate"]:.0f}% of queries, p99 degradation '
        f'{out["p99_degradation"]:.2f}x, recovery vs query-restart '
        f'{out["total_speedup"]:.2f}x, identical={out["all_identical"]}, '
        f'stream_identical={out["stream_identical"]} (hedged='
        f'{out["stream_hedged"]}), ok={out["chaos_ok"]}')


# --------------------------- process-tier chaos arm (real worker kill)
PROCESS_TIER_KWARGS = {"qids": ("Q1", "Q6", "Q12", "Q14"), "sf": 1.0,
                       "kill_after": 2}


def run_process_tier(qids=None, sf: float = 1.0, power: float = 0.375,
                     wave_gap: float = 0.005, kill_after: int = 2) -> dict:
    """Chaos A/B through the REAL multi-process storage tier under a
    pinned worker-kill schedule (docs/distributed.md): node 0's worker
    self-SIGKILLs before work item ``kill_after``+1 — deterministic by
    work-item count, no injected schedule involved.

    Three arms over the same arrival-timed stream:

    - ``clean``            — in-process tier, no faults (the reference)
    - ``recovery``         — process tier + the kill schedule: the dead
      channel's ``WorkerFault`` flows through retry -> demote-to-pushback
      (local replay from the parent's catalog copy), results
      byte-identical to clean
    - ``fail_and_restart`` — same kill, ``demote_on_exhaust=False``: the
      stream aborts and restarts from scratch on a replacement pool

    Hard-asserted (the CI step fails on any violation): byte-identity
    across all arms, the killed worker really dead, ``n_demoted`` > 0,
    the pool's real-fault ledger reconciling exactly with the
    ``faults.*`` counters, and recovery not losing to
    restart-on-replacement wall clock (``chaos_ok``)."""
    import time as _time

    from repro.core import runtime
    from repro.core.cost import StorageResources
    from repro.core.faults import RetryPolicy
    from repro.distributed.workers import WorkerPool
    from repro.obs import metrics as om

    sf = sf or 1.0
    cat = common.catalog(num_nodes=2, sf=sf)
    qids = tuple(qids or Q.QUERY_IDS)
    res = StorageResources(storage_power=power)
    stream = _stream(qids, wave_gap)
    retry = RetryPolicy()
    prev_metrics = om.get_metrics()

    def timed_stream(cfg):
        t0 = _time.perf_counter()
        r = runtime.run_stream(stream, cat, cfg)
        return _time.perf_counter() - t0, r

    # measured_feedback off: arms must not see each other's gauges
    t_clean, clean = timed_stream(engine.EngineConfig(
        res=res, mode=MODE_ADAPTIVE, measured_feedback=False))

    # ---- recovery: real SIGKILL mid-stream, demote-to-pushback -----------
    om.set_metrics(om.Metrics())          # isolate the recovery ledger
    pool = WorkerPool(cat, pd_slots=res.pd_slots)
    try:
        pool.die_after(0, kill_after)
        t_rec, rec = timed_stream(engine.EngineConfig(
            res=res, mode=MODE_ADAPTIVE, worker_pool=pool, retry=retry,
            measured_feedback=False))
        _assert_results_identical(clean.results, rec.results,
                                  "process_recovery", qids)
        assert not pool.alive(0) and pool.alive(1)
        assert rec.n_demoted > 0          # recovery actually happened
        events = list(pool.events)
        c = om.get_metrics().snapshot()["counters"]
        # exact reconciliation: every channel fault the pool recorded was
        # counted once by the recovery loop, by kind and by (node, path)
        assert len(events) > 0 and c.get("faults.crash", 0) + \
            c.get("faults.timeout", 0) == len(events)
        per_node_path = sum(v for k, v in c.items()
                            if k.startswith("faults.node")
                            and k.endswith(".failures"))
        assert per_node_path == len(events)
    finally:
        pool.close()
        om.set_metrics(prev_metrics)

    # ---- fail-and-restart: abort, replace the pool, rerun ----------------
    strict = RetryPolicy(demote_on_exhaust=False)
    t_fte, restarts = 0.0, 0
    armed = True                          # only the first pool is doomed
    while True:
        p = WorkerPool(cat, pd_slots=res.pd_slots)
        try:
            if armed:
                p.die_after(0, kill_after)
            t0 = _time.perf_counter()
            try:
                fte = runtime.run_stream(stream, cat, engine.EngineConfig(
                    res=res, mode=MODE_ADAPTIVE, worker_pool=p,
                    retry=strict, measured_feedback=False))
                t_fte += _time.perf_counter() - t0
                break
            except RuntimeError:
                t_fte += _time.perf_counter() - t0
                restarts += 1
                armed = False             # the crashed node gets replaced
                if restarts > CHAOS_MAX_RESTARTS:
                    raise
        finally:
            p.close()
    _assert_results_identical(clean.results, fte.results,
                              "process_fail_and_restart", qids)
    assert restarts >= 1                  # the kill really aborted a run
    ok = bool(t_rec <= 1.15 * t_fte)
    assert ok, ("recovery lost to restart-on-replacement", t_rec, t_fte)
    return {
        "sf": sf, "power": power, "kill_after": kill_after,
        "qids": list(qids), "all_identical": True,
        "n_demoted": rec.n_demoted, "retries": rec.retries,
        "real_faults": len(events), "restarts": restarts,
        "t_clean_ms": 1e3 * t_clean, "t_recovery_ms": 1e3 * t_rec,
        "t_fail_and_restart_ms": 1e3 * t_fte,
        "total_speedup": t_fte / max(t_rec, 1e-9),
        "chaos_ok": ok,
    }


def render_process_tier(out: dict) -> str:
    rows = [
        ["clean (inproc)", f'{out["t_clean_ms"]:.1f}', "-", "-"],
        ["recovery", f'{out["t_recovery_ms"]:.1f}', out["n_demoted"],
         out["real_faults"]],
        ["fail_and_restart", f'{out["t_fail_and_restart_ms"]:.1f}',
         f'{out["restarts"]} restarts', "-"],
    ]
    hdr = ["arm", "wall_ms", "demoted", "real faults"]
    return common.table(rows, hdr) + (
        f'\nprocess-tier chaos (sf={out["sf"]}, worker 0 killed after '
        f'{out["kill_after"]} items): recovery vs restart-on-replacement '
        f'{out["total_speedup"]:.2f}x, {out["real_faults"]} real channel '
        f'faults reconciled, identical={out["all_identical"]}, '
        f'ok={out["chaos_ok"]}')


# ------------------------------------ online-correction A/B (correction)
def run_correction(qids=None, rounds: int = 4, sf: float = None,
                   power: float = 1.0) -> dict:
    """Before/after-correction A/B of the cost-calibrated frontier loop.

    Measured every run: (1) repeated runs through one
    ``CardinalityCorrector`` shrink the mean ``|log s_out_estimate_ratio|``
    (``converged`` — enforced by perf_guard); (2) per query, the real net
    bytes of the cost-based cut vs the maximal frontier (Q19's lowered
    predicates ship strictly fewer); (3) which cuts the corrected chooser
    moves back toward measured truth (``corrected_flips`` — e.g. Q4's
    derive-bias cut; which cuts flip depends on the catalog's NDV
    profile, so this is reported and claim-checked in ``run.py``, not
    hard-asserted per query). Results byte-identical throughout
    (``all_identical``)."""
    from repro.compiler import compile_query_costed, compile_query_detailed

    sf = sf or common.SF
    cat = common.catalog(num_nodes=2, sf=sf)
    qids = tuple(qids or ("Q1", "Q4", "Q7", "Q14", "Q18", "Q19"))
    corr = CardinalityCorrector()
    cfg = engine.EngineConfig(res=common.engine_cfg("eager", power).res,
                              mode="eager", corrector=corr)

    # (1) feedback rounds: estimate-error trajectory over repeated runs
    per_round_err = []
    for _ in range(max(2, rounds)):
        errs = []
        for qid in qids:
            r = engine.run_query(Q.build_query(qid), cat, cfg)
            ratio = r.net_bytes_recon["s_out_estimate_ratio"]
            if ratio:
                errs.append(abs(math.log(ratio)))
        per_round_err.append(float(np.mean(errs)))
    converged = (per_round_err[-1] <= per_round_err[0] + 1e-12
                 and per_round_err[-1] <= 0.5 * per_round_err[0] + 1e-12)

    # (2) cost-based cut vs maximal frontier: real net bytes, eager mode
    plain = engine.EngineConfig(res=cfg.res, mode="eager")
    cost_cut = {}
    costed_sig = {}        # reused by (3): the uncorrected chooser's pick
    all_identical = True
    for qid in qids:
        mx = compile_query_detailed(qid)
        cs = compile_query_costed(qid, cat)
        costed_sig[qid] = cs.frontier_signature()
        rm = engine.run_query(mx.query, cat, plain)
        rc = engine.run_query(cs.query, cat, plain)
        identical = engine.results_equal(rm.result, rc.result)
        all_identical &= identical
        cost_cut[qid] = {
            "maximal_bytes": rm.real_net_bytes,
            "costed_bytes": rc.real_net_bytes,
            "saved_frac": 1.0 - rc.real_net_bytes / max(1, rm.real_net_bytes),
            "signature_maximal": mx.frontier_signature(),
            "signature_costed": costed_sig[qid],
            "identical": identical,
        }

    # (3) corrected chooser: cuts that move once measurement disagrees
    corrected_flips = {}
    for qid in qids:
        after = compile_query_costed(qid, cat,
                                     corrector=corr).frontier_signature()
        if costed_sig[qid] != after:
            corrected_flips[qid] = {"before": costed_sig[qid],
                                    "after": after}

    return {
        "sf": sf, "power": power, "rounds": rounds, "qids": list(qids),
        "per_round_err": per_round_err,
        "err_first": per_round_err[0], "err_last": per_round_err[-1],
        "converged": bool(converged),
        "cost_cut": cost_cut,
        "net_saved_frac_max": max(d["saved_frac"] for d in
                                  cost_cut.values()),
        "corrected_flips": corrected_flips,
        "all_identical": bool(all_identical),
        "corrector": corr.snapshot(),
    }


def _correction_headline(out: dict) -> dict:
    return {"sf": out["sf"],
            "err_first": round(out["err_first"], 4),
            "err_last": round(out["err_last"], 6),
            "converged": out["converged"],
            "net_saved_frac_max": round(out["net_saved_frac_max"], 4),
            "n_corrected_flips": len(out["corrected_flips"]),
            "all_identical": out["all_identical"]}


def update_root_bench_correction(out: dict):
    return common.update_root_bench("correction", out,
                                    _correction_headline(out))


def render_correction(out: dict) -> str:
    rows = [[qid,
             d["maximal_bytes"], d["costed_bytes"],
             f'{100 * d["saved_frac"]:.1f}%',
             "yes" if d["identical"] else "NO"]
            for qid, d in out["cost_cut"].items()]
    hdr = ["query", "maximal bytes", "costed bytes", "saved", "identical"]
    err = " -> ".join(f"{e:.4f}" for e in out["per_round_err"])
    flips = ", ".join(f"{q}" for q in out["corrected_flips"]) or "none"
    return common.table(rows, hdr) + (
        f'\ncorrection (sf={out["sf"]}): |log s_out ratio| {err} '
        f'(converged={out["converged"]}), corrected cut flips: {flips}, '
        f'best net-byte saving {100 * out["net_saved_frac_max"]:.1f}%')


def render_real(out: dict) -> str:
    rows = [[m, f'{out["modes"][m]["wall_clock_ms"]:.1f}',
             out["modes"][m]["n_pushdown"], out["modes"][m]["n_pushback"],
             out["modes"][m]["real_net_bytes"]] for m in REAL_MODES]
    hdr = ["mode", "wall_ms", "pushdown", "pushback", "real net bytes"]
    return common.table(rows, hdr) + (
        f'\nreal runtime (sf={out["sf"]}, power={out["power"]}): adaptive '
        f'{out["t_adaptive_ms"]:.1f}ms vs worse baseline '
        f'{out["worse_baseline_ms"]:.1f}ms ({out["total_speedup"]:.2f}x), '
        f'identical={out["all_identical"]}, ok={out["adaptive_ok"]}')


def _real_headline(real: dict) -> dict:
    return {"sf": real["sf"], "power": real["power"],
            "total_speedup": round(real["total_speedup"], 3),
            "t_adaptive_ms": round(real["t_adaptive_ms"], 2),
            "worse_baseline_ms": round(real["worse_baseline_ms"], 2),
            "best_baseline_ms": round(real["best_baseline_ms"], 2),
            "adaptive_ok": real["adaptive_ok"],
            "all_identical": real["all_identical"]}


def update_root_bench(out: dict):
    return common.update_root_bench_real("runtime", out,
                                         headline_fn=_real_headline)


def render(out: dict) -> str:
    rows = []
    for qid, d in out["queries"].items():
        be = (f'{d["break_even_speedup"]:.2f}x@{d["break_even_power"]}'
              if d["break_even_speedup"] else "no crossing")
        rows.append([qid,
                     " ".join(f"{e/n:.2f}" for e, n in
                              zip(d["eager"], d["no_pushdown"])),
                     " ".join(f"{a/n:.2f}" for a, n in
                              zip(d["adaptive"], d["no_pushdown"])),
                     be])
    hdr = ["query", "eager/npd per power", "adaptive/npd per power",
           "breakeven"]
    foot = (f'\nbreak-even speedup: avg {out["breakeven_speedup_avg"]:.2f}x, '
            f'max {out["breakeven_speedup_max"]:.2f}x '
            f'(paper Fig 6: avg 1.5x, best 1.9x)')
    txt = common.table(rows, hdr) + foot
    if "real" in out:
        txt += "\n\n" + render_real(out["real"])
    if "correction" in out:
        txt += "\n\n" + render_correction(out["correction"])
    if "chaos" in out:
        txt += "\n\n" + render_chaos(out["chaos"])
    return txt


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--real-quick", action="store_true",
                    help="real wall-clock runtime only, 4 queries, sf=2 "
                         "(CI smoke)")
    ap.add_argument("--correction-quick", action="store_true",
                    help="online-correction A/B only, sf=2 (CI smoke)")
    ap.add_argument("--trace-smoke", action="store_true",
                    help="traced sf=1 stream with exact reconciliation; "
                         "writes JSONL + Chrome trace + summary artifacts")
    ap.add_argument("--chaos-quick", action="store_true",
                    help="fault-tolerance A/B, sf=1 mix under a pinned "
                         "~10%% storage-failure schedule (CI chaos smoke)")
    ap.add_argument("--process-tier", action="store_true",
                    help="chaos A/B through the real multi-process storage "
                         "tier under a pinned worker-kill schedule "
                         "(hard-asserting; CI chaos smoke)")
    args = ap.parse_args()
    if args.process_tier:
        print(render_process_tier(run_process_tier(**PROCESS_TIER_KWARGS)))
    elif args.chaos_quick:
        o = run_chaos(**CHAOS_QUICK_KWARGS)
        update_root_bench_chaos(o)
        print(render_chaos(o))
    elif args.real_quick:
        o = run_real(**REAL_QUICK_KWARGS)
        update_root_bench(o)
        print(render_real(o))
    elif args.trace_smoke:
        o = run_trace_smoke(**TRACE_SMOKE_KWARGS)
        print(o["summary"])
        print(f"\n{o['n_spans']} spans, real net bytes "
              f"{o['real_net_bytes']}, reconciled exactly; artifacts:")
        for k, p in o["artifacts"].items():
            print(f"  {k}: {p}")
    elif args.correction_quick:
        o = run_correction(**CORRECTION_QUICK_KWARGS)
        update_root_bench_correction(o)
        print(render_correction(o))
    else:
        o = run()
        common.save_report("fig6_adaptive", o)
        update_root_bench(o)
        print(render(o))
        update_root_bench_correction(o["correction"])
        update_root_bench_chaos(o["chaos"])
