"""Fig 6: Adaptive vs No-pushdown vs Eager across storage computational
power, all queries. Claims checked:

- eager degrades as power drops and crosses below no-pushdown,
- adaptive ~= min(baselines) everywhere (tolerance for Alg-1's greedy
  spill tail), and BEATS both around the break-even point,
- break-even speedup up to ~1.9x (paper: 1.5x average, 1.9x best).
"""
from __future__ import annotations

from repro.core import engine
from repro.core.simulator import (MODE_ADAPTIVE, MODE_EAGER, MODE_NO_PUSHDOWN)
from repro.queryproc import queries as Q

from benchmarks import common


def run(powers=common.POWERS, qids=None) -> dict:
    cat = common.catalog()
    qids = qids or Q.QUERY_IDS
    out = {"powers": list(powers), "queries": {}}
    best_even, avg_even = 0.0, []
    for qid in qids:
        q = Q.build_query(qid)
        per_mode = {m: [] for m in (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE)}
        admitted = []
        for p in powers:
            for m in per_mode:
                r = engine.run_query(q, cat, common.engine_cfg(m, p))
                per_mode[m].append(r.t_total)
                if m == MODE_ADAPTIVE:
                    admitted.append(r.n_admitted)
        npd, eag, ada = (per_mode[m] for m in
                         (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE))
        # break-even: power where eager and no-pushdown actually cross.
        # Queries whose curves never meet in range (non-pushable-dominated:
        # the paper's "insensitive" Q2/Q3/Q18 class) have no break-even
        # point and are excluded from the break-even average, as in Fig 6.
        i = min(range(len(powers)), key=lambda i: abs(eag[i] - npd[i]))
        crosses = abs(eag[i] - npd[i]) / npd[i] <= 0.15
        sp = min(npd[i], eag[i]) / ada[i]
        if crosses:
            best_even = max(best_even, sp)
            avg_even.append(sp)
        out["queries"][qid] = {
            "no_pushdown": npd, "eager": eag, "adaptive": ada,
            "admitted": admitted,
            "break_even_power": powers[i] if crosses else None,
            "break_even_speedup": sp if crosses else None,
        }
    out["breakeven_speedup_max"] = best_even
    out["breakeven_speedup_avg"] = sum(avg_even) / max(1, len(avg_even))
    out["num_breakeven_queries"] = len(avg_even)
    return out


def render(out: dict) -> str:
    rows = []
    for qid, d in out["queries"].items():
        be = (f'{d["break_even_speedup"]:.2f}x@{d["break_even_power"]}'
              if d["break_even_speedup"] else "no crossing")
        rows.append([qid,
                     " ".join(f"{e/n:.2f}" for e, n in
                              zip(d["eager"], d["no_pushdown"])),
                     " ".join(f"{a/n:.2f}" for a, n in
                              zip(d["adaptive"], d["no_pushdown"])),
                     be])
    hdr = ["query", "eager/npd per power", "adaptive/npd per power",
           "breakeven"]
    foot = (f'\nbreak-even speedup: avg {out["breakeven_speedup_avg"]:.2f}x, '
            f'max {out["breakeven_speedup_max"]:.2f}x '
            f'(paper Fig 6: avg 1.5x, best 1.9x)')
    return common.table(rows, hdr) + foot


if __name__ == "__main__":
    o = run()
    common.save_report("fig6_adaptive", o)
    print(render(o))
