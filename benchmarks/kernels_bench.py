"""Kernel micro-bench: Pallas (interpret) vs jnp oracle vs numpy engine.

On this CPU container interpret-mode wall time is NOT a TPU performance
signal — correctness + structural numbers (VMEM footprint per block,
bytes/row) are what carries to hardware; wall times are recorded for
regression tracking only.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.queryproc.expressions import Col

from benchmarks import common


def _time(fn, *args, reps=3):
    fn(*args)  # warm
    t0 = time.time()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.time() - t0) / reps


def run(rows=65_536) -> dict:
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.integers(1, 51, rows).astype(np.float32))
    d = jnp.asarray(rng.uniform(0, 0.11, rows).astype(np.float32))
    vals = jnp.asarray(rng.normal(size=rows).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, rows).astype(np.int32))
    keys = jnp.asarray(rng.integers(0, 1 << 31, rows).astype(np.int32))

    pred = ops.compile_predicate((Col("q") <= 24) & (Col("d") > 0.05))
    out = {"rows": rows, "kernels": {}}

    words = ops.predicate_bitmap({"q": q, "d": d}, pred)
    out["kernels"]["predicate_bitmap"] = {
        "pallas_s": _time(lambda: ops.predicate_bitmap({"q": q, "d": d}, pred)),
        "ref_s": _time(lambda: ref.predicate_bitmap(
            {"q": q, "d": d}, pred)),
        "vmem_block_bytes": 2 * ops.DEFAULT_BLOCK * 4,
        "out_bytes_per_row": 1 / 8,
    }
    out["kernels"]["bitmap_apply"] = {
        "pallas_s": _time(lambda: ops.bitmap_apply(words, vals)),
        "ref_s": _time(lambda: ref.bitmap_apply(
            jnp.pad(words, (0, 0)), vals.reshape(-1))),
        "vmem_block_bytes": ops.DEFAULT_BLOCK * 4 + ops.DEFAULT_BLOCK // 8,
    }
    out["kernels"]["grouped_agg"] = {
        "pallas_s": _time(lambda: ops.grouped_agg(ids, vals, 64)),
        "ref_s": _time(lambda: ref.grouped_agg(ids, vals, 64)),
        "vmem_block_bytes": ops.DEFAULT_BLOCK * (4 + 4) + 65 * 8,
        "mxu_shape": (1, ops.DEFAULT_BLOCK, 65),
    }
    out["kernels"]["hash_partition"] = {
        "pallas_s": _time(lambda: ops.hash_partition(keys, 16)),
        "ref_s": _time(lambda: ref.hash_partition(keys.reshape(-1), 16)),
        "vmem_block_bytes": ops.DEFAULT_BLOCK * 8 + 16 * 4,
    }
    return out


def render(out: dict) -> str:
    rows = [[k, f'{v["pallas_s"]*1e3:.1f}ms', f'{v["ref_s"]*1e3:.1f}ms',
             f'{v.get("vmem_block_bytes", 0)/1024:.0f}KiB']
            for k, v in out["kernels"].items()]
    return common.table(rows, ["kernel", "pallas(interp)", "jnp ref",
                               "VMEM/block"]) + \
        "\n(interpret-mode times are correctness-path only; see docstring)"


if __name__ == "__main__":
    o = run()
    common.save_report("kernels", o)
    print(render(o))
