"""Enabled-tracing overhead guard: the tentpole's <2% promise, measured.

Runs the sf=1 all-queries suite with tracing OFF (the default NULL
tracer) and ON (a fresh ``Tracer`` per traced run, so span lists never
accumulate across repeats), interleaved per query — each query's two arms
are timed back-to-back every repeat (alternating which arm goes first, so
any ordering bias cancels), with the garbage collector disabled inside
the timing windows and a full collect between repeats (the traced arm
allocates span/record objects; letting gen-0 collections land inside its
windows would bill GC to tracing). The estimator is the **sum of
per-query best-of times**: each query's minimum converges to its own
noise floor, which keeps the total far tighter than best-of over
whole-suite passes (where one scheduler hiccup anywhere poisons the
pass). Byte identity between the arms is asserted on every repeat.

Each repeat times **three** arms per query: untraced, traced, and a
second identical untraced arm (the A/A placebo). The placebo
differential — untraced vs untraced, measured through the exact same
interleave and estimator — is what the harness reads when there is
*nothing* to measure: on a quiet machine ~0, on a loaded CI box it
captures the estimator's noise-floor bias directly. The asserted
quantity is the traced differential **minus the (non-negative) placebo**
— a calibrated A/B-over-A/A reading, so a loaded box doesn't convert
measurement bias into a spurious overhead regression.

The headline is the **minimum over independent measurement blocks**
(each block = its own full calibrated estimate). Block noise is
one-sided — load only ever slows runs, and unconverged minima only ever
inflate a differential — so the minimum block is the least-noise
estimate of the true overhead, while a genuine regression inflates every
block and still fails the bound.

One bias survives all of the above: per-*process* code/data layout.  An
interpreter launch fixes allocation and code placement for its lifetime,
and that can shift one arm by a point or two **uniformly across every
block**, with a converged (near-zero) placebo — neither the calibration
nor min-over-blocks can see it. So the CLI entry point runs the whole
measurement in freshly **spawned** subprocesses (spawn, not fork — a
fork inherits the parent's layout) and keeps the best of a small number
of attempts, stopping early on a pass. Same one-sided argument as the
blocks: only the high side can fail the bound, and a genuine regression
shifts every process.

The headline lands in ``BENCH_engine.json`` under the ``obs`` suite with
``obs_overhead_ok`` — ``benchmarks.perf_guard`` fails CI when the
measured overhead exceeds :data:`BOUND`.
"""
from __future__ import annotations

import gc
import time
from typing import Dict, Optional

import numpy as np

from benchmarks import common
from repro import obs
from repro.core import engine
from repro.queryproc import queries as Q
from repro.queryproc import tpch

BOUND = 0.02            # enabled-tracing overhead bound (fraction)
SF = 1.0                # the acceptance surface: sf=1 all-queries suite
ROWS_PER_PART = 6_000   # the catalog's default partitioning (~10*sf
#                         fact-table objects, the paper's sizing)


def _measure_block(qids, queries, base_res, run_off, run_on,
                   repeats: int) -> Dict:
    """One independent calibrated estimate (per-query interleaved arms:
    untraced / traced / untraced A/A placebo, order rotated per repeat)."""
    best = {arm: {qid: float("inf") for qid in qids}
            for arm in ("off", "on", "placebo")}
    arms = ("off", "on", "placebo")
    n_spans = 0
    identical = True
    gc_was_enabled = gc.isenabled()
    try:
        for rep in range(max(1, repeats)):
            gc.enable()
            gc.collect()
            gc.disable()
            spans_this_rep = 0
            rot = arms[rep % 3:] + arms[:rep % 3]
            for qid, q in queries.items():
                for arm in rot:
                    t0 = time.perf_counter()
                    if arm == "on":
                        res, tr = run_on(q)
                    else:
                        res = run_off(q)
                    best[arm][qid] = min(best[arm][qid],
                                         time.perf_counter() - t0)
                    for c in base_res[qid].columns:
                        if not np.array_equal(base_res[qid].cols[c],
                                              res.cols[c], equal_nan=True):
                            identical = False
                spans_this_rep += len(tr.snapshot())
            n_spans = spans_this_rep
    finally:
        if gc_was_enabled:
            gc.enable()
    t_off = sum(best["off"].values())
    t_on = sum(best["on"].values())
    t_aa = sum(best["placebo"].values())
    raw = t_on / max(t_off, 1e-12) - 1.0
    placebo = t_aa / max(t_off, 1e-12) - 1.0
    return {
        "t_untraced_ms": 1e3 * t_off,
        "t_traced_ms": 1e3 * t_on,
        "raw_overhead": raw,
        "placebo": placebo,
        "overhead": raw - max(0.0, placebo),
        "per_query_ms": {qid: {"off": 1e3 * best["off"][qid],
                               "on": 1e3 * best["on"][qid],
                               "placebo": 1e3 * best["placebo"][qid]}
                         for qid in qids},
        "n_spans_per_iteration": n_spans,
        "all_identical": identical,
    }


def run(qids=None, repeats: int = 15, blocks: int = 4, sf: float = SF,
        mode: str = "adaptive") -> Dict:
    cat = tpch.build_catalog(sf=sf, num_nodes=2,
                             rows_per_partition=ROWS_PER_PART)
    qids = tuple(qids or Q.QUERY_IDS)
    queries = {qid: Q.build_query(qid) for qid in qids}
    cfg = engine.EngineConfig(mode=mode)

    def run_off(q):
        return engine.run_query(q, cat, cfg).result

    def run_on(q):
        with obs.tracing() as tr:       # fresh tracer: no cross-run growth
            res = engine.run_query(q, cat, cfg).result
        return res, tr

    base_res = {qid: run_off(q) for qid, q in queries.items()}  # warm-up
    for q in queries.values():
        run_on(q)
    stats = [_measure_block(qids, queries, base_res, run_off, run_on,
                            repeats) for _ in range(max(1, blocks))]
    block_overheads = [s["overhead"] for s in stats]
    best = min(stats, key=lambda s: s["overhead"])
    identical = all(s["all_identical"] for s in stats)
    overhead = best["overhead"]
    return {
        "sf": sf, "mode": mode, "repeats": repeats, "blocks": len(stats),
        "qids": list(qids),
        "n_spans_per_iteration": best["n_spans_per_iteration"],
        "t_untraced_ms": best["t_untraced_ms"],
        "t_traced_ms": best["t_traced_ms"],
        "per_query_ms": best["per_query_ms"],
        "block_overheads": block_overheads,
        "block_raw_overheads": [s["raw_overhead"] for s in stats],
        "block_placebos": [s["placebo"] for s in stats],
        "raw_overhead": best["raw_overhead"],
        "placebo": best["placebo"],
        "overhead": overhead,
        "bound": BOUND,
        "all_identical": identical,
        "obs_overhead_ok": bool(identical and overhead <= BOUND),
    }


def update_root_bench(out: Dict):
    common.update_root_bench("obs", out, {
        "sf": out["sf"], "overhead": out["overhead"],
        "t_untraced_ms": out["t_untraced_ms"],
        "t_traced_ms": out["t_traced_ms"],
        "all_identical": out["all_identical"],
        "obs_overhead_ok": out["obs_overhead_ok"],
    })


def render(out: Dict) -> str:
    verdict = "OK" if out["obs_overhead_ok"] else "FAIL"
    blocks = ", ".join(
        f"{100 * r:+.2f}%-{100 * max(0.0, p):.2f}%aa"
        for r, p in zip(out.get("block_raw_overheads", []),
                        out.get("block_placebos", [])))
    return (
        f"tracing overhead (sf={out['sf']}, {len(out['qids'])} queries, "
        f"min of {out['blocks']} blocks x best of {out['repeats']}): "
        f"{out['t_untraced_ms']:.1f}ms off vs {out['t_traced_ms']:.1f}ms on "
        f"-> {100 * out['overhead']:+.2f}% "
        f"(raw {100 * out['raw_overhead']:+.2f}%, "
        f"A/A placebo {100 * out['placebo']:+.2f}%; blocks: {blocks}) "
        f"(bound {100 * out['bound']:.0f}%, "
        f"{out['n_spans_per_iteration']} spans/iter"
        + (f", attempt {out['attempt']}" if "attempt" in out else "")
        + f") [{verdict}]")


def _measure_once(quick: bool) -> Dict:
    return run(repeats=10, blocks=3) if quick else run()


def _child(quick: bool, conn) -> None:
    conn.send(_measure_once(quick))
    conn.close()


def measure(quick: bool = False, attempts: int = 2) -> Dict:
    """Best of ``attempts`` fresh-process measurements (early exit on a
    pass); falls back to in-process when spawning is unavailable."""
    import multiprocessing as mp

    best: Optional[Dict] = None
    for att in range(max(1, attempts)):
        try:
            ctx = mp.get_context("spawn")
            rx, tx = ctx.Pipe(duplex=False)
            p = ctx.Process(target=_child, args=(quick, tx))
            p.start()
            tx.close()
            o = rx.recv()
            p.join()
        except Exception:
            o = _measure_once(quick)
        o["attempt"] = att + 1
        if best is None or o["overhead"] < best["overhead"]:
            best = o
        if o["obs_overhead_ok"]:
            break
    return best


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="3 blocks x 10 repeats (CI smoke); the sf=1 "
                         "surface either way")
    ap.add_argument("--attempts", type=int, default=2,
                    help="fresh-process measurement attempts (best kept; "
                         "early exit on a pass)")
    args = ap.parse_args()
    o = measure(quick=args.quick, attempts=args.attempts)
    common.save_report("obs_overhead", o)
    update_root_bench(o)
    print(render(o))
