"""Shared benchmark harness: dataset setup, run helpers, table printing.

Every benchmark mirrors one paper figure (DESIGN.md §7 maps them) and
returns a JSON-serializable dict saved under reports/bench/. Scale: sf=4
(~240k-row fact table, ~160 partitions) — big enough that per-request
tails amortize like the paper's SF50 setup, small enough for one CPU.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List

from repro.core.cost import StorageResources
from repro.core.engine import EngineConfig
from repro.queryproc import tpch

SF = 4.0
ROWS_PER_PART = 1_500
POWERS = (1.0, 0.75, 0.5, 0.375, 0.25, 0.12, 0.06)
REPORT_DIR = Path("reports/bench")

_catalogs: Dict = {}


def catalog(num_nodes: int = 1, sf: float = SF):
    key = (num_nodes, sf)
    if key not in _catalogs:
        _catalogs[key] = tpch.build_catalog(
            sf=sf, num_nodes=num_nodes, rows_per_partition=ROWS_PER_PART)
    return _catalogs[key]


def engine_cfg(mode: str, power: float = 1.0,
               num_compute_nodes: int = 1) -> EngineConfig:
    return EngineConfig(res=StorageResources(storage_power=power), mode=mode,
                        num_compute_nodes=num_compute_nodes)


def save_report(name: str, data: dict) -> Path:
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    path = REPORT_DIR / f"{name}.json"
    path.write_text(json.dumps(data, indent=1, default=float))
    return path


def table(rows: List[List], header: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    lines = [fmt.format(*header), fmt.format(*["-" * w for w in widths])]
    lines += [fmt.format(*[str(c) for c in r]) for r in rows]
    return "\n".join(lines)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.elapsed = time.time() - self.t0
