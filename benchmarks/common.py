"""Shared benchmark harness: dataset setup, run helpers, table printing.

Every benchmark mirrors one paper figure (DESIGN.md §7 maps them) and
returns a JSON-serializable dict saved under reports/bench/. Scale: sf=4
(~240k-row fact table, ~160 partitions) — big enough that per-request
tails amortize like the paper's SF50 setup, small enough for one CPU.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional

from repro.core.cost import StorageResources
from repro.core.engine import EngineConfig
from repro.queryproc import tpch

SF = 4.0
ROWS_PER_PART = 1_500
POWERS = (1.0, 0.75, 0.5, 0.375, 0.25, 0.12, 0.06)
REPORT_DIR = Path("reports/bench")

_catalogs: Dict = {}


def catalog(num_nodes: int = 1, sf: float = SF):
    key = (num_nodes, sf)
    if key not in _catalogs:
        _catalogs[key] = tpch.build_catalog(
            sf=sf, num_nodes=num_nodes, rows_per_partition=ROWS_PER_PART)
    return _catalogs[key]


def engine_cfg(mode: str, power: float = 1.0,
               num_compute_nodes: int = 1) -> EngineConfig:
    return EngineConfig(res=StorageResources(storage_power=power), mode=mode,
                        num_compute_nodes=num_compute_nodes)


def save_report(name: str, data: dict) -> Path:
    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    path = REPORT_DIR / f"{name}.json"
    path.write_text(json.dumps(data, indent=1, default=float))
    return path


ROOT_BENCH = Path("BENCH_engine.json")


def update_root_bench(suite: str, latest: dict, headline: dict,
                      path: Path = ROOT_BENCH) -> Path:
    """Consolidated cross-PR trajectory file at the repo root: per suite a
    ``latest`` full report plus an appended ``history`` of headline numbers
    (executor / shuffle / bitmap wall-clock suites all land here; the CI
    perf-smoke uploads the file and ``benchmarks.perf_guard`` enforces that
    the trajectory stays monotone)."""
    doc = {}
    if path.exists():
        try:
            doc = json.loads(path.read_text())
        except (ValueError, OSError):
            doc = {}
    entry = doc.setdefault(suite, {"history": []})
    entry["latest"] = latest
    entry.setdefault("history", []).append(headline)
    path.write_text(json.dumps(doc, indent=1, default=float))
    return path


def summarize_real(queries: Dict[str, dict], sf: float, repeats: int,
                   **extra) -> dict:
    """Summary dict shared by every ``run_real`` wall-clock suite
    (shuffle / bitmap_storage / bitmap_compute). ``queries`` maps qid ->
    per-query timings with ``t_reference_ms``/``t_batched_ms``/``speedup``;
    byte-identity is asserted by the caller before timing. Safe when no
    query qualified (geomean/min are omitted rather than NaN)."""
    tot_ref = sum(v["t_reference_ms"] for v in queries.values())
    tot_bat = sum(v["t_batched_ms"] for v in queries.values())
    out = {"sf": sf, "repeats": repeats, "queries": queries,
           "all_identical": True,  # asserted per partition by the caller
           "total_reference_ms": tot_ref, "total_batched_ms": tot_bat,
           "total_speedup": tot_ref / max(tot_bat, 1e-12), **extra}
    if queries:
        import numpy as np
        out["geomean_speedup"] = float(np.exp(np.mean(
            [np.log(v["speedup"]) for v in queries.values()])))
        out["min_speedup"] = min(v["speedup"] for v in queries.values())
    return out


def real_headline(real: Optional[dict]) -> Optional[dict]:
    """Trajectory headline for a ``summarize_real`` dict; None when the
    suite timed nothing (nothing worth recording — or guarding)."""
    if not real or not real.get("queries") or "geomean_speedup" not in real:
        return None
    return {
        "sf": real["sf"],
        "total_speedup": round(real["total_speedup"], 3),
        "geomean_speedup": round(real["geomean_speedup"], 3),
        "total_batched_ms": round(real["total_batched_ms"], 2),
        "total_reference_ms": round(real["total_reference_ms"], 2),
        "all_identical": real["all_identical"],
    }


def update_root_bench_real(suite: str, out: dict,
                           headline_fn=None) -> Optional[Path]:
    """Record a run_real suite (or a run() dict carrying one under
    ``"real"``) into the consolidated trajectory. ``headline_fn`` lets a
    suite with a different headline shape (the runtime A/B) reuse the
    same routing; it defaults to ``real_headline``."""
    real = out.get("real") if "real" in out else out
    headline = (headline_fn or real_headline)(real) if real else None
    if headline is None:
        return None
    return update_root_bench(suite, real, headline)


def median_time(fn, repeats: int) -> float:
    """Median wall-clock of ``fn`` over ``repeats`` runs (plus one warm-up
    for compile caches / page-ins)."""
    fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2]


def best_time(fn, repeats: int) -> float:
    """Min wall-clock of ``fn`` over ``repeats`` runs, GC paused during
    timing — the standard microbenchmark estimator: allocator/GC noise in a
    shared container only ever inflates a sample, so the minimum is the
    least-biased reading of the actual work."""
    import gc
    fn()  # warm (compile caches, page in columns)
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
    finally:
        if was_enabled:
            gc.enable()
        gc.collect()
    return best


def table(rows: List[List], header: List[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    fmt = "  ".join(f"{{:<{w}}}" for w in widths)
    lines = [fmt.format(*header), fmt.format(*["-" * w for w in widths])]
    lines += [fmt.format(*[str(c) for c in r]) for r in rows]
    return "\n".join(lines)


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.elapsed = time.time() - self.t0
