"""Fig 7: pushback heuristic vs the theoretical optimal bound (§3.1).

Compares the Arbitrator's admitted-pushdown count against (a) the discrete
oracle split (global view, Eq 1-3 fluid model) and (b) the closed-form
Eq 6 ``n = k/(k+1) N`` on the mean request. Paper: 1-2% relative gap.
"""
from __future__ import annotations

from repro.core import engine, optimum
from repro.core.simulator import MODE_ADAPTIVE
from repro.queryproc import queries as Q

from benchmarks import common


def run(qids=("Q12", "Q14"), powers=common.POWERS) -> dict:
    cat = common.catalog()
    out = {"powers": list(powers), "queries": {}}
    for qid in qids:
        q = Q.build_query(qid)
        reqs = engine.plan_requests(q, cat)
        # the heuristic arbitrates *lineitem* fact requests and the small
        # dim-table ones together; the oracle sees the same set
        rows = []
        for p in powers:
            cfg = common.engine_cfg(MODE_ADAPTIVE, p)
            r = engine.run_query(q, cat, cfg, requests=reqs)
            from repro.core.simulator import SimRequest
            sim_reqs = [SimRequest(x.req_id, x.part.node_id, qid, x.cost)
                        for x in reqs]
            oracle = optimum.simulated_optimum(sim_reqs, cfg.res)
            fluid = optimum.discrete_optimum([x.cost for x in reqs], cfg.res)
            eq6 = optimum.uniform_prediction([x.cost for x in reqs], cfg.res)
            N = len(reqs)
            rows.append({
                "power": p, "N": N,
                "heuristic": r.n_admitted,
                "oracle": oracle.n_pushdown,
                "fluid_oracle": fluid.n_pushdown,
                "eq6": eq6.n_pushdown,
                # the paper's Fig-7 metric: heuristic admit count vs the
                # theoretical result from Eq 6 (§6.2 Case Study)
                "gap_frac": abs(r.n_admitted - eq6.n_pushdown) / max(1, N),
                # beyond-paper: vs the simulated global-view oracle
                "n_gap_frac": abs(r.n_admitted - oracle.n_pushdown)
                / max(1, N),
                "t_adaptive": r.t_pushable,
                "t_oracle": oracle.time,
            })
        out["queries"][qid] = rows
    gaps = [r["gap_frac"] for rows in out["queries"].values() for r in rows]
    out["max_gap_frac"] = max(gaps)
    out["avg_gap_frac"] = sum(gaps) / len(gaps)
    return out


def render(out: dict) -> str:
    rows = []
    for qid, rs in out["queries"].items():
        for r in rs:
            rows.append([qid, r["power"], r["N"], r["heuristic"], r["oracle"],
                         r["eq6"], f'{r["gap_frac"]*100:.1f}%',
                         f'{r["t_adaptive"]/max(r["t_oracle"],1e-12):.3f}'])
    hdr = ["query", "power", "N", "heuristic n", "oracle n", "Eq6 n",
           "eq6-gap", "t/t_sim_opt"]
    foot = (f'\navg Eq6 admit-count gap {out["avg_gap_frac"]*100:.1f}%, max '
            f'{out["max_gap_frac"]*100:.1f}% (paper: 1-2%)')
    return common.table(rows, hdr) + foot


if __name__ == "__main__":
    o = run()
    common.save_report("fig7_optimal_gap", o)
    print(render(o))
