"""Fig 8: storage<->compute network traffic per mode (Q12/Q14).

Claims: no-pushdown and eager are flat across power; eager saves up to an
order of magnitude; adaptive interpolates with power (it trades network
for storage CPU at runtime).
"""
from __future__ import annotations

from repro.core import engine
from repro.core.simulator import MODE_ADAPTIVE, MODE_EAGER, MODE_NO_PUSHDOWN
from repro.queryproc import queries as Q

from benchmarks import common


def run(qids=("Q12", "Q14"), powers=common.POWERS) -> dict:
    cat = common.catalog()
    out = {"powers": list(powers), "queries": {}}
    for qid in qids:
        q = Q.build_query(qid)
        d = {}
        for m in (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE):
            d[m] = [engine.run_query(q, cat, common.engine_cfg(m, p)).net_bytes
                    for p in powers]
        d["eager_saving_x"] = d[MODE_NO_PUSHDOWN][0] / max(d[MODE_EAGER][0], 1)
        out["queries"][qid] = d
    return out


def render(out: dict) -> str:
    rows = []
    for qid, d in out["queries"].items():
        for m in (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE):
            rows.append([qid, m] + [f"{b/2**20:.1f}" for b in d[m]])
    hdr = ["query", "mode"] + [f"MiB@{p}" for p in out["powers"]]
    foot = "\n" + "; ".join(
        f'{qid}: eager saves {d["eager_saving_x"]:.1f}x'
        for qid, d in out["queries"].items()) + " (paper: up to ~10x)"
    return common.table(rows, hdr) + foot


if __name__ == "__main__":
    o = run()
    common.save_report("fig8_network", o)
    print(render(o))
