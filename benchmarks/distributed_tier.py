"""The ``distributed`` suite: the multi-process storage tier A/B'd
against the in-process oracle, plus the decision-shift measurement.

Three arms over the same arrival-timed stream (best-of interleaved
repeats, byte-identity asserted across arms every repeat):

- ``inproc_adaptive``   — the PR-4 in-process tier (the oracle)
- ``process_adaptive``  — real storage-worker processes behind the wire
  codec (docs/distributed.md): plans dispatched over the wire, pushback
  projections crossing the process boundary as serialized bytes
- ``process_eager``     — forced all-pushdown on the process tier (the
  within-tier baseline the adaptive arm must not lose to)

Then the paper's §3 claim that adaptive pushdown should react to *real*
storage-side pressure: `burn()` loads one worker with genuine CPU spin,
one `poll` publishes its live queue-depth snapshot into the gauges the
Arbitrator's `MeasuredLoad` reads, and the same queries re-arbitrate —
the suite records how many node-0 decisions flip from pushdown to
pushback (``decision_flips``), with results asserted byte-identical
across the flip (any decision vector is correct; that is what makes the
shift safe). ``distributed_ok`` (a perf_guard hard check) requires
byte-identity everywhere, at least one pressure-induced flip, and the
process-tier adaptive arm not losing to the within-tier eager baseline.
"""
from __future__ import annotations

import numpy as np

from repro.core import engine
from repro.core.cost import StorageResources
from repro.core.simulator import MODE_ADAPTIVE, MODE_EAGER
from repro.obs import metrics as om
from repro.queryproc import queries as Q

from benchmarks import common

# the CI perf smoke shares this exact configuration (sf=2 like the other
# quick suites, so the trajectory stays same-sf comparable)
QUICK_KWARGS = {"qids": ("Q1", "Q6", "Q12", "Q14"), "repeats": 3,
                "sf": 2.0}

ARMS = ("inproc_adaptive", "process_adaptive", "process_eager")
BURN_SECONDS = 0.12       # per injected work item of real CPU spin
BURN_TASKS = 30           # ~30 queued items -> a deep node-0 exec queue


def _stream(qids, wave_gap: float):
    from repro.core import runtime
    return [runtime.StreamQuery(Q.build_query(qid), arrival=i * wave_gap)
            for i, qid in enumerate(qids)]


def _assert_identical(base, other, arm, qids):
    for qid in qids:
        a, b = base[qid], other[qid]
        assert a.columns == b.columns, (arm, qid, a.columns, b.columns)
        for c in a.columns:
            assert a.cols[c].dtype == b.cols[c].dtype and np.array_equal(
                a.cols[c], b.cols[c], equal_nan=True), (arm, qid, c)


def _node0_pushdowns(run: engine.QueryRun) -> int:
    dec = run.sim.decisions()
    return sum(1 for r in run.requests
               if r.part.node_id == 0 and dec.get(r.req_id) == "pushdown")


def run_distributed(qids=None, repeats: int = 3, sf: float = None,
                    power: float = 0.375, wave_gap: float = 0.01) -> dict:
    """Process-tier A/B + decision shift under injected worker load."""
    from repro.core import runtime
    from repro.distributed.workers import WorkerPool

    sf = sf or common.SF
    cat = common.catalog(num_nodes=2, sf=sf)
    qids = tuple(qids or Q.QUERY_IDS)
    res = StorageResources(storage_power=power)
    stream = _stream(qids, wave_gap)
    prev_metrics = om.get_metrics()
    om.set_metrics(om.Metrics())       # stale gauges must not leak in
    pool = WorkerPool(cat, pd_slots=res.pd_slots)
    try:
        cfgs = {
            "inproc_adaptive": engine.EngineConfig(res=res,
                                                   mode=MODE_ADAPTIVE),
            "process_adaptive": engine.EngineConfig(
                res=res, mode=MODE_ADAPTIVE, worker_pool=pool),
            "process_eager": engine.EngineConfig(
                res=res, mode=MODE_EAGER, worker_pool=pool),
        }
        best = {a: None for a in ARMS}
        runs = {a: None for a in ARMS}
        reference = None
        # interleaved repeats + best-of per arm, as in adaptive.run_real:
        # a machine-load burst hits every arm instead of biasing one
        for rep in range(repeats + 1):      # first round is the warm-up
            for arm in ARMS:
                r = runtime.run_stream(stream, cat, cfgs[arm],
                                       time_scale=0)
                if rep == 0:
                    continue
                if reference is None:
                    reference = r.results
                else:
                    _assert_identical(reference, r.results, arm, qids)
                if best[arm] is None or r.wall_clock < best[arm]:
                    best[arm], runs[arm] = r.wall_clock, r
        per_arm = {arm: {
            "wall_clock_ms": 1e3 * best[arm],
            "n_pushdown": runs[arm].n_pushdown,
            "n_pushback": runs[arm].n_pushback,
            "real_net_bytes": runs[arm].real_net_bytes,
        } for arm in ARMS}
        wire = pool.wire_bytes()

        # ---- decision shift under real worker CPU pressure ---------------
        pool.publish_load()               # idle snapshot -> gauges
        idle_runs = {qid: engine.run_query(Q.build_query(qid), cat,
                                           cfgs["process_adaptive"])
                     for qid in qids}
        idle_pd0 = {qid: _node0_pushdowns(r) for qid, r in idle_runs.items()}
        pool.burn(0, BURN_SECONDS, tasks=BURN_TASKS)
        loaded = pool.publish_load()[0]   # live queue-depth snapshot
        busy_runs = {qid: engine.run_query(Q.build_query(qid), cat,
                                           cfgs["process_adaptive"])
                     for qid in qids}
        busy_pd0 = {qid: _node0_pushdowns(r) for qid, r in busy_runs.items()}
        for qid in qids:                  # any decision vector is correct
            _assert_identical({qid: idle_runs[qid].result},
                              {qid: busy_runs[qid].result}, "shift", (qid,))
        flips = {qid: idle_pd0[qid] - busy_pd0[qid] for qid in qids}
        decision_flips = int(sum(max(0, f) for f in flips.values()))
    finally:
        pool.close()
        om.set_metrics(prev_metrics)

    t_in = per_arm["inproc_adaptive"]["wall_clock_ms"]
    t_pa = per_arm["process_adaptive"]["wall_clock_ms"]
    t_pe = per_arm["process_eager"]["wall_clock_ms"]
    return {
        "sf": sf, "power": power, "repeats": repeats, "wave_gap": wave_gap,
        "qids": list(qids), "arms": per_arm,
        "all_identical": True,            # asserted per repeat + per flip
        "wire_bytes_sent": wire["sent"], "wire_bytes_recv": wire["recv"],
        "t_inproc_adaptive_ms": t_in,
        "t_process_adaptive_ms": t_pa,
        "t_process_eager_ms": t_pe,
        # what the wire costs over the in-heap oracle (informational)
        "process_overhead": t_pa / max(t_in, 1e-9),
        "node0_load_snapshot": loaded,
        "idle_node0_pushdowns": int(sum(idle_pd0.values())),
        "busy_node0_pushdowns": int(sum(busy_pd0.values())),
        "decision_flips": decision_flips,
        "flips_by_query": flips,
        # the monotone trajectory number: within-tier adaptive vs eager
        "total_speedup": t_pe / max(t_pa, 1e-9),
        # the hard contract: identity everywhere, real pressure moved real
        # decisions, and adaptive does not lose to eager on its own tier
        # (1.15 band absorbs scheduling noise, like adaptive_ok/chaos_ok)
        "distributed_ok": bool(decision_flips >= 1 and t_pa <= 1.15 * t_pe),
    }


def headline(out: dict) -> dict:
    return {"sf": out["sf"], "power": out["power"],
            "total_speedup": round(out["total_speedup"], 3),
            "t_process_adaptive_ms": round(out["t_process_adaptive_ms"], 2),
            "t_process_eager_ms": round(out["t_process_eager_ms"], 2),
            "t_inproc_adaptive_ms": round(out["t_inproc_adaptive_ms"], 2),
            "process_overhead": round(out["process_overhead"], 3),
            "decision_flips": out["decision_flips"],
            "distributed_ok": out["distributed_ok"],
            "all_identical": out["all_identical"]}


def update_root_bench(out: dict):
    return common.update_root_bench("distributed", out, headline(out))


def render(out: dict) -> str:
    rows = [[arm, f'{d["wall_clock_ms"]:.1f}', d["n_pushdown"],
             d["n_pushback"], d["real_net_bytes"]]
            for arm, d in out["arms"].items()]
    hdr = ["arm", "wall_ms", "pushdown", "pushback", "real net bytes"]
    snap = out["node0_load_snapshot"] or {}
    return common.table(rows, hdr) + (
        f'\ndistributed (sf={out["sf"]}, power={out["power"]}): process '
        f'adaptive {out["t_process_adaptive_ms"]:.1f}ms vs eager '
        f'{out["t_process_eager_ms"]:.1f}ms ({out["total_speedup"]:.2f}x), '
        f'wire overhead {out["process_overhead"]:.2f}x vs inproc, '
        f'{out["wire_bytes_sent"] + out["wire_bytes_recv"]} wire bytes\n'
        f'decision shift: node-0 pushdowns {out["idle_node0_pushdowns"]} '
        f'(idle) -> {out["busy_node0_pushdowns"]} (exec_q='
        f'{snap.get("exec_q")}, cpu={snap.get("cpu")}): '
        f'{out["decision_flips"]} flips, identical='
        f'{out["all_identical"]}, ok={out["distributed_ok"]}')


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="4 queries at sf=2 (the CI configuration)")
    args = ap.parse_args()
    o = run_distributed(**QUICK_KWARGS) if args.quick else run_distributed()
    common.save_report("distributed_tier", o)
    update_root_bench(o)
    print(render(o))
