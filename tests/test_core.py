"""Adaptive-pushdown core: cost model, optimum (Eq 1-7), Algorithm 1,
simulator invariants — unit + property tests (hypothesis optional: a
deterministic sweep covers the same invariants when it is absent)."""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dependency — see pyproject.toml [test]
    HAVE_HYPOTHESIS = False

from repro.core import optimum
from repro.core.arbitrator import PUSHBACK, PUSHDOWN, Arbitrator
from repro.core.cost import RequestCost, StorageResources
from repro.core.simulator import (MODE_ADAPTIVE, MODE_ADAPTIVE_PA, MODE_EAGER,
                                  MODE_NO_PUSHDOWN, SimRequest, simulate)

RES = StorageResources()


def _cost(s_in=400_000, s_out=40_000, comp=800_000):
    return RequestCost(s_in=s_in, s_out=s_out, compute_in=comp)


# ------------------------------------------------------------- Eq 6 / 7
def test_eq6_closed_form():
    assert optimum.n_opt_uniform(100, 1.0) == pytest.approx(50.0)
    assert optimum.n_opt_uniform(100, 3.0) == pytest.approx(75.0)
    assert optimum.n_opt_uniform(100, 0.0) == 0.0  # no pushdown layer


def _check_eq7(k, N):
    """T_opt = k/(k+1) T_pd = 1/(k+1) T_npd <= min(T_pd, T_npd)."""
    t_pd = 1.0
    t_npd = k * t_pd
    t_opt = optimum.t_opt_uniform(t_pd, k)
    assert t_opt <= min(t_pd, t_npd) + 1e-9
    assert t_opt == pytest.approx(t_npd / (k + 1.0))
    # n monotone in k
    assert (optimum.n_opt_uniform(N, k + 1.0)
            >= optimum.n_opt_uniform(N, k) - 1e-9)


if HAVE_HYPOTHESIS:
    @given(st.floats(0.01, 50.0), st.integers(1, 500))
    @settings(max_examples=50, deadline=None)
    def test_eq7_speedup_bounds(k, N):
        _check_eq7(k, N)


@pytest.mark.parametrize("k", [0.01, 0.3, 1.0, 3.7, 50.0])
@pytest.mark.parametrize("N", [1, 17, 500])
def test_eq7_speedup_bounds_deterministic(k, N):
    _check_eq7(k, N)


def _check_discrete_optimum(specs):
    costs = [RequestCost(a, b, c) for a, b, c in specs]
    best = optimum.discrete_optimum(costs, RES)
    all_pd = optimum._time_of_split(costs, [True] * len(costs), RES)[0]
    all_pb = optimum._time_of_split(costs, [False] * len(costs), RES)[0]
    assert best.time <= min(all_pd, all_pb) + 1e-9
    assert 0 <= best.n_pushdown <= len(costs)


if HAVE_HYPOTHESIS:
    @given(st.lists(st.tuples(st.integers(10_000, 10**6),
                              st.integers(100, 10**6),
                              st.integers(10_000, 2 * 10**6)),
                    min_size=2, max_size=40))
    @settings(max_examples=30, deadline=None)
    def test_discrete_optimum_beats_endpoints(specs):
        _check_discrete_optimum(specs)


@pytest.mark.parametrize("seed", range(12))
def test_discrete_optimum_beats_endpoints_deterministic(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 41))
    specs = [(int(rng.integers(10_000, 10**6)),
              int(rng.integers(100, 10**6)),
              int(rng.integers(10_000, 2 * 10**6))) for _ in range(n)]
    _check_discrete_optimum(specs)


# ------------------------------------------------------------ Algorithm 1
def test_arbitrator_slot_accounting():
    arb = Arbitrator(RES)
    assigned = []
    for i in range(100):
        assigned += arb.submit(i, _cost())
    assert arb.free_pd >= 0 and arb.free_pb >= 0
    assert len(assigned) == RES.pd_slots + RES.pb_slots  # both pools filled
    done = 0
    while len(assigned) < 100:
        path = assigned[done][1]
        assigned += arb.release(path)
        done += 1
    assert sorted(r for r, _ in assigned) == list(range(100))
    assert arb.admitted + arb.pushed_back == 100


def test_arbitrator_prefers_faster_path():
    # pushdown much faster: first pd_slots assignments must be pushdown
    arb = Arbitrator(RES)
    out = []
    for i in range(RES.pd_slots):
        out += arb.submit(i, _cost(s_in=10**6, s_out=100, comp=10**5))
    assert all(p == PUSHDOWN for _, p in out)
    # pushback much faster (incompressible big output)
    arb2 = Arbitrator(RES)
    out2 = []
    for i in range(RES.pb_slots):
        out2 += arb2.submit(i, _cost(s_in=10**5, s_out=2 * 10**6, comp=10**7))
    assert all(p == PUSHBACK for _, p in out2)


def test_forced_paths():
    for forced, want in ((PUSHDOWN, RES.pd_slots), (PUSHBACK, RES.pb_slots)):
        arb = Arbitrator(RES, forced_path=forced)
        out = []
        for i in range(64):
            out += arb.submit(i, _cost())
        assert len(out) == want and all(p == forced for _, p in out)


def test_pa_queue_sorted():
    arb = Arbitrator(RES, pa_aware=True)
    rng = np.random.default_rng(0)
    for i in range(50):
        arb.submit(i, _cost(s_in=int(rng.integers(10**4, 10**6)),
                            s_out=int(rng.integers(10**3, 10**6)),
                            comp=int(rng.integers(10**4, 10**6))))
    pas = [p.pa for p in arb.queue]
    assert pas == sorted(pas, reverse=True)


# -------------------------------------------------------------- simulator
def _requests(n=60, seed=0, nodes=1):
    rng = np.random.default_rng(seed)
    return [SimRequest(i, i % nodes, "q",
                       _cost(s_in=int(rng.integers(10**5, 10**6)),
                             s_out=int(rng.integers(10**3, 10**5)),
                             comp=int(rng.integers(10**5, 2 * 10**6))))
            for i in range(n)]


@pytest.mark.parametrize("mode", [MODE_NO_PUSHDOWN, MODE_EAGER,
                                  MODE_ADAPTIVE, MODE_ADAPTIVE_PA])
def test_simulator_conservation(mode):
    reqs = _requests()
    sim = simulate(reqs, RES, mode)
    assert len(sim.per_request) == len(reqs)      # everything completes
    assert sim.makespan > 0
    for _, start, finish in sim.per_request.values():
        assert finish >= start >= 0
    if mode == MODE_EAGER:
        assert sim.admitted() == len(reqs)
    if mode == MODE_NO_PUSHDOWN:
        assert sim.admitted() == 0


def test_simulator_capacity_lower_bounds():
    """makespan >= work / capacity for each resource (fluid feasibility)."""
    reqs = _requests()
    sim = simulate(reqs, RES, MODE_EAGER)
    cpu_work = sum(r.cost.compute_in for r in reqs)
    assert sim.makespan >= cpu_work / (RES.eff_core_bw * RES.pd_slots) - 1e-9
    sim2 = simulate(reqs, RES, MODE_NO_PUSHDOWN)
    net_work = sum(r.cost.s_in for r in reqs)
    assert sim2.makespan >= net_work / RES.net_bw - 1e-9


def test_adaptive_near_min_of_baselines():
    reqs = _requests(120)
    for power in (1.0, 0.5, 0.25, 0.06):
        res = RES.with_power(power)
        t = {m: simulate(reqs, res, m).makespan
             for m in (MODE_NO_PUSHDOWN, MODE_EAGER, MODE_ADAPTIVE)}
        # Alg-1 greedy spill allows a bounded excursion above the best
        # baseline (see EXPERIMENTS.md §Paper-validation)
        assert t[MODE_ADAPTIVE] <= 1.35 * min(t[MODE_NO_PUSHDOWN],
                                              t[MODE_EAGER])


def test_forced_decisions_mode():
    reqs = _requests(30)
    dec = {r.req_id: (PUSHDOWN if r.req_id % 3 else PUSHBACK) for r in reqs}
    sim = simulate(reqs, RES, decisions=dec)
    for rid, (path, _, _) in sim.per_request.items():
        assert path == dec[rid]


def test_power_scaling_monotone_for_eager():
    reqs = _requests(80)
    times = [simulate(reqs, RES.with_power(p), MODE_EAGER).makespan
             for p in (1.0, 0.5, 0.25, 0.12)]
    assert all(a <= b + 1e-9 for a, b in zip(times, times[1:]))


def test_multi_node():
    reqs = _requests(64, nodes=4)
    sim = simulate(reqs, RES, MODE_ADAPTIVE)
    one = simulate(_requests(64, nodes=1), RES, MODE_ADAPTIVE)
    assert sim.makespan <= one.makespan + 1e-9  # 4 nodes >= 1 node
