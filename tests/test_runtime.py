"""Decision-faithful runtime: the Arbitrator's per-request decisions route
real execution, and the merged result is byte-identical for ANY decision
vector — all-pushdown, all-pushback, or any random mix — across all 15
TPC-H queries and all 4 engine modes. Plus: real net-bytes reconciliation
(the pushback component must match the simulator exactly), the live
decision callback, the request-order merge of hand-built multi-plan
request lists, and the row-wise ``results_equal`` regression.

Property tests use hypothesis when present; a deterministic seed sweep
covers the same invariants when it is absent."""
import dataclasses

import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dependency — see pyproject.toml [test]
    HAVE_HYPOTHESIS = False

from repro.core import engine, runtime
from repro.core.arbitrator import PUSHBACK, PUSHDOWN
from repro.core.cost import StorageResources
from repro.core.simulator import SimRequest, simulate
from repro.queryproc import queries as Q
from repro.queryproc import tpch
from repro.queryproc.table import ColumnTable

CAT = tpch.build_catalog(sf=1.0, num_nodes=2, rows_per_partition=4_000)


def assert_tables_identical(a: ColumnTable, b: ColumnTable, ctx=""):
    assert a.columns == b.columns, (ctx, a.columns, b.columns)
    for c in a.columns:
        x, y = a.cols[c], b.cols[c]
        assert x.dtype == y.dtype, (ctx, c, x.dtype, y.dtype)
        assert np.array_equal(x, y, equal_nan=True), (ctx, c)


def _decision_vector(reqs, seed: int):
    rng = np.random.default_rng(seed)
    return {r.req_id: (PUSHDOWN if rng.random() < 0.5 else PUSHBACK)
            for r in reqs}


# --------------------------------- any decision vector, identical bytes
def _check_split_identity(qid: str, seed: int):
    q = Q.build_query(qid)
    reqs = engine.plan_requests(q, CAT)
    oracle = engine.execute_requests(reqs)   # all storage-side, batched
    vectors = {
        "all_pushdown": {r.req_id: PUSHDOWN for r in reqs},
        "all_pushback": {r.req_id: PUSHBACK for r in reqs},
        "random": _decision_vector(reqs, seed),
    }
    for name, dec in vectors.items():
        split = runtime.execute_split(reqs, dec)
        assert set(split.merged) == set(oracle)
        for table in oracle:
            assert_tables_identical(oracle[table], split.merged[table],
                                    (qid, name, table))
        n_pb = sum(1 for v in dec.values() if v == PUSHBACK)
        assert split.n_pushback == n_pb
        assert split.n_pushdown == len(reqs) - n_pb
        assert [o.req_id for o in split.outcomes] == [r.req_id for r in reqs]
        for o in split.outcomes:
            assert o.replayed == (dec[o.req_id] == PUSHBACK)
            assert o.shipped_bytes > 0


@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_any_decision_vector_byte_identical(qid):
    # crc32, not hash(): a failing vector must be reconstructable across
    # processes (str hashing is randomized per interpreter)
    import zlib
    _check_split_identity(qid, seed=zlib.crc32(qid.encode()))


if HAVE_HYPOTHESIS:
    @given(st.sampled_from(Q.QUERY_IDS), st.integers(0, 10 ** 6))
    @settings(max_examples=15, deadline=None)
    def test_decision_vector_property(qid, seed):
        _check_split_identity(qid, seed)


@pytest.mark.parametrize("seed", range(3))
def test_decision_vector_property_deterministic(seed):
    for qid in ("Q1", "Q8", "Q18"):
        _check_split_identity(qid, seed=seed * 1000 + 7)


def test_split_reference_executor_identical():
    """The decision split is executor-agnostic: the per-partition reference
    loop over the same split produces the same bytes."""
    q = Q.build_query("Q3")
    reqs = engine.plan_requests(q, CAT)
    dec = _decision_vector(reqs, 42)
    bat = runtime.execute_split(reqs, dec, executor="batched")
    ref = runtime.execute_split(reqs, dec, executor="reference")
    for table in bat.merged:
        assert_tables_identical(bat.merged[table], ref.merged[table], table)
    assert bat.pushback_bytes == ref.pushback_bytes


# ------------------------------------------------ per-mode real execution
@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_modes_byte_identical_under_real_split(qid):
    """run_query's real merged execution is byte-identical whether the
    decision vector forces storage-side, compute-side, or adaptive mixes
    — the final result cannot depend on where the bytes flowed."""
    q = Q.build_query(qid)
    runs = {m: engine.run_query(q, CAT, engine.EngineConfig(mode=m))
            for m in engine.MODES}
    base = runs["eager"]
    assert base.n_pushed_back == 0 and base.n_admitted == len(base.requests)
    npd = runs["no_pushdown"]
    assert npd.n_admitted == 0 and npd.n_pushed_back == len(npd.requests)
    assert all(o.replayed for o in npd.outcomes)
    assert not any(o.replayed for o in base.outcomes)
    for mode, r in runs.items():
        assert_tables_identical(base.result, r.result, (qid, mode))
        # the real split mirrors the simulated decisions exactly
        assert sum(1 for o in r.outcomes if o.path == PUSHDOWN) \
            == r.n_admitted, (qid, mode)


@pytest.mark.parametrize("mode", engine.MODES)
def test_net_bytes_reconciliation(mode):
    """Real pushback bytes == simulated pushback bytes exactly (both count
    stored accessed-column bytes); the pushdown delta is exactly the cost
    model's s_out estimation error."""
    for qid in ("Q1", "Q6", "Q14", "Q19"):
        r = engine.run_query(Q.build_query(qid), CAT,
                             engine.EngineConfig(mode=mode))
        rec = r.net_bytes_recon
        assert rec["real_pushback_bytes"] == rec["sim_pushback_bytes"]
        assert rec["sim_net_bytes"] == pytest.approx(r.net_bytes)
        assert rec["real_net_bytes"] == pytest.approx(r.real_net_bytes)
        assert r.real_net_bytes > 0
        if r.n_admitted == 0:
            # all pushed back: real traffic matches the simulator to the byte
            assert r.real_net_bytes == pytest.approx(r.net_bytes)


def test_raw_projection_replay_identical():
    """Replaying a compiled plan over the shipped raw projection equals
    executing it over the full partition — the pushback contract."""
    from repro.core.executor import compile_push_plan
    from repro.core.plan import execute_push_plan
    for qid in ("Q1", "Q12", "Q19"):
        q = Q.build_query(qid)
        for table, plan in q.plans.items():
            cplan = compile_push_plan(plan)
            for part in CAT.partitions_of(table)[:3]:
                proj = cplan.raw_projection(part.data)
                assert set(proj.columns) <= set(part.data.columns)
                full, _ = execute_push_plan(plan, part.data)
                ship, _ = cplan.execute(proj)
                assert_tables_identical(full, ship, (qid, table))


def test_shuffle_aux_replays_at_compute():
    """Pushed-back shuffle plans emit identical aux by-products from the
    compute-layer replay (the PR 3 aux paths ride through the split)."""
    from repro.core.executor import compile_push_plan
    from repro.core.plan import execute_push_plan
    q = Q.build_query("Q3")
    plan = dataclasses.replace(q.plans["lineitem"],
                               shuffle=("l_orderkey", 4))
    cplan = compile_push_plan(plan)
    parts = [p.data for p in CAT.partitions_of("lineitem")[:4]]
    shipped = [cplan.raw_projection(p) for p in parts]
    got, aux = cplan.execute_batch_parts(shipped)
    for p, g, a in zip(parts, got, aux):
        ref, ref_aux = execute_push_plan(plan, p)
        assert_tables_identical(ref, g)
        np.testing.assert_array_equal(ref_aux["position_vector"],
                                      a["position_vector"])
        for rp, bp in zip(ref_aux["shuffle_parts"], a["shuffle_parts"]):
            assert_tables_identical(rp, bp)


# -------------------------------------------------- live decision callback
def test_arbitrator_decision_callback():
    """simulate(on_decision=...) reports every request exactly once, with
    the same path the SimResult records — the hook the stream driver uses
    to let arbitration order real work."""
    q = Q.build_query("Q14")
    reqs = engine.plan_requests(q, CAT)
    sim_reqs = [SimRequest(r.req_id, r.part.node_id, q.qid, r.cost)
                for r in reqs]
    for mode in engine.MODES:
        seen = []
        sim = simulate(sim_reqs, StorageResources(storage_power=0.25), mode,
                       on_decision=lambda rid, path: seen.append((rid, path)))
        assert sorted(rid for rid, _ in seen) == sorted(r.req_id for r in reqs)
        assert dict(seen) == sim.decisions(), mode


def test_forced_decisions_callback():
    """The oracle (_ForcedArbitrator) path emits the hook too."""
    reqs = [SimRequest(i, 0, "Q", engine.RequestCost(
        s_in=10_000, s_out=1_000, compute_in=10_000)) for i in range(6)]
    decisions = {i: (PUSHDOWN if i % 2 else PUSHBACK) for i in range(6)}
    seen = {}
    simulate(reqs, StorageResources(), decisions=decisions,
             on_decision=lambda rid, path: seen.setdefault(rid, path))
    assert seen == decisions


# ------------------------------------------------- concurrent stream driver
def test_stream_driver_modes_identical():
    """The arrival-timed wall-clock driver returns byte-identical results
    in every mode, and its split counts match the shared simulation."""
    qids = ("Q1", "Q6", "Q12")
    stream = [runtime.StreamQuery(Q.build_query(qid), arrival=i * 0.005)
              for i, qid in enumerate(qids)]
    base = None
    for mode in engine.MODES:
        cfg = engine.EngineConfig(res=StorageResources(storage_power=0.25),
                                  mode=mode)
        run = runtime.run_stream(stream, CAT, cfg)
        assert run.wall_clock > 0 and set(run.per_query) == set(qids)
        assert run.n_pushdown == run.sim.admitted()
        assert run.n_pushback == sum(
            run.sim.pushed_back_by_query.get(qid, 0) for qid in qids)
        if base is None:
            base = run.results
        for qid in qids:
            assert_tables_identical(base[qid], run.results[qid], (mode, qid))
    # and the stream results equal a solo run_query
    solo = engine.run_query(Q.build_query("Q12"), CAT,
                            engine.EngineConfig(mode="adaptive"))
    assert_tables_identical(solo.result, base["Q12"], "stream-vs-solo")


def test_stream_driver_repeated_query():
    """The same query id submitted twice in one stream executes twice
    (keyed Q6 / Q6#1), each instance byte-identical to the solo run."""
    stream = [runtime.StreamQuery(Q.build_query("Q6"), arrival=0.0),
              runtime.StreamQuery(Q.build_query("Q6"), arrival=0.002)]
    run = runtime.run_stream(stream, CAT,
                             engine.EngineConfig(mode="adaptive"))
    assert set(run.results) == {"Q6", "Q6#1"}
    n_req = len(engine.plan_requests(Q.build_query("Q6"), CAT))
    assert run.n_pushdown + run.n_pushback == 2 * n_req
    solo = engine.run_query(Q.build_query("Q6"), CAT,
                            engine.EngineConfig(mode="adaptive"))
    for key in ("Q6", "Q6#1"):
        assert_tables_identical(solo.result, run.results[key], key)


# ------------------------- request-order merge (multi-plan request lists)
def test_multi_plan_request_list_byte_identical():
    """A hand-built request list interleaving several distinct plans for
    one table now merges byte-identically (not just row-set-equal) to the
    reference executor — the old group-order caveat is gone."""
    q = Q.build_query("Q6")
    base_plan = q.plans["lineitem"]
    clone = dataclasses.replace(base_plan)   # distinct plan object
    parts = CAT.partitions_of("lineitem")
    reqs = []
    for i, part in enumerate(parts):
        plan = base_plan if i % 2 == 0 else clone   # interleave two plans
        reqs.append(engine.PlannedRequest(
            i, q.qid, "lineitem", part, plan,
            engine.compile_push_plan(plan).estimate_cost(part)))
    ref = engine.execute_requests(reqs, engine.EXECUTOR_REFERENCE)
    bat = engine.execute_requests(reqs, engine.EXECUTOR_BATCHED)
    assert_tables_identical(ref["lineitem"], bat["lineitem"])
    # the decision split honors the same request-order contract
    split = runtime.execute_split(reqs, _decision_vector(reqs, 3))
    assert_tables_identical(ref["lineitem"], split.merged["lineitem"])


# ------------------------------------- online s_out correction loop
def _ratio_err(run):
    import math
    r = run.net_bytes_recon["s_out_estimate_ratio"]
    return abs(math.log(r))


def test_corrector_error_shrinks_monotonically():
    """K repeated runs through a shared CardinalityCorrector: the
    s_out_estimate_ratio error is non-increasing and collapses after the
    first observation (stationary workload, seeded catalog — no
    wall-clock dependence anywhere)."""
    from repro.core.cost import CardinalityCorrector
    corr = CardinalityCorrector()
    cfg = engine.EngineConfig(mode="eager", corrector=corr)
    for qid in ("Q1", "Q14", "Q18"):
        errs = [_ratio_err(engine.run_query(Q.build_query(qid), CAT, cfg))
                for _ in range(4)]
        assert errs[0] > 0, (qid, errs)  # the model starts biased
        for a, b in zip(errs, errs[1:]):
            assert b <= a + 1e-12, (qid, errs)
        assert errs[-1] <= 0.05 * errs[0] + 1e-12, (qid, errs)
    assert corr.n_observations >= 12


def test_corrector_ewma_decays_geometrically():
    """Unit-level: with smoothing, a persistent bias is approached by a
    (1 - alpha)^k factor per observation — strictly monotone decay."""
    import math
    from repro.core.cost import CardinalityCorrector
    corr = CardinalityCorrector(alpha=0.5)
    corr.observe("Q", "t", "scan", est_s_out=100.0, real_s_out=100.0)
    errs = []
    for _ in range(6):
        # true ratio is 2.0; corrected estimate approaches it
        errs.append(abs(math.log(2.0 * 100.0 /
                                 (100.0 * corr.ratio("Q", "t", "scan")))))
        corr.observe("Q", "t", "scan", 100.0, 200.0)
    assert all(b < a for a, b in zip(errs, errs[1:])), errs
    assert errs[-1] < 0.1 * errs[0]


def test_corrector_never_flips_results():
    """Corrections rescale estimates — decisions may move, bytes may
    move, the result may not: byte-identity under correction on/off, all
    modes."""
    from repro.core.cost import CardinalityCorrector
    corr = CardinalityCorrector()
    warm = engine.EngineConfig(mode="eager", corrector=corr)
    for _ in range(2):   # learn real ratios first
        for qid in ("Q3", "Q14", "Q18"):
            engine.run_query(Q.build_query(qid), CAT, warm)
    for qid in ("Q3", "Q14", "Q18"):
        for mode in engine.MODES:
            plain = engine.run_query(Q.build_query(qid), CAT,
                                     engine.EngineConfig(mode=mode))
            corrected = engine.run_query(
                Q.build_query(qid), CAT,
                engine.EngineConfig(mode=mode, corrector=corr))
            assert_tables_identical(plain.result, corrected.result,
                                    (qid, mode))
            # the correction really reached the arbitrated costs
            if corrected.n_admitted:
                assert corrected.net_bytes_recon["sim_pushdown_bytes"] \
                    != plain.net_bytes_recon["sim_pushdown_bytes"] or \
                    corr.ratio(qid, "lineitem") == 1.0, (qid, mode)


def test_corrector_clamps_degenerate_observations():
    from repro.core.cost import CardinalityCorrector
    corr = CardinalityCorrector(clamp=32.0)
    corr.observe("Q", "t", None, est_s_out=1.0, real_s_out=1e12)
    assert corr.ratio("Q", "t") == 32.0
    # the report shows the applied (clamped) correction, not the raw EWMA
    assert all(v <= 32.0 for v in corr.snapshot().values())
    corr2 = CardinalityCorrector()
    corr2.observe("Q", "t", None, est_s_out=0.0, real_s_out=100.0)  # no-op
    assert corr2.ratio("Q", "t") == 1.0


def test_reconciliation_per_table_breakdown():
    r = engine.run_query(Q.build_query("Q14"), CAT,
                         engine.EngineConfig(mode="eager"))
    by_table = r.net_bytes_recon["by_table"]
    assert set(by_table) == {"lineitem", "part"}
    for t, row in by_table.items():
        assert row["real_pushdown_bytes"] > 0
        assert row["s_out_estimate_ratio"] == pytest.approx(
            row["sim_pushdown_bytes"] / row["real_pushdown_bytes"])
    total = sum(row["real_pushdown_bytes"] for row in by_table.values())
    assert total == r.net_bytes_recon["real_pushdown_bytes"]


def test_stream_driver_feeds_corrector():
    """Two identical streams through run_stream with a shared corrector:
    the second stream's per-query estimate error shrinks, results stay
    byte-identical."""
    import math
    from repro.core.cost import CardinalityCorrector
    corr = CardinalityCorrector()
    cfg = engine.EngineConfig(mode="eager", corrector=corr)
    stream = [runtime.StreamQuery(Q.build_query(qid), arrival=i * 0.002)
              for i, qid in enumerate(("Q1", "Q14"))]
    first = runtime.run_stream(stream, CAT, cfg)
    assert corr.n_observations > 0
    second = runtime.run_stream(stream, CAT, cfg)
    for qid in ("Q1", "Q14"):
        assert_tables_identical(first.results[qid], second.results[qid], qid)
        e1 = abs(math.log(first.per_query[qid]["s_out_estimate_ratio"]))
        e2 = abs(math.log(second.per_query[qid]["s_out_estimate_ratio"]))
        assert e2 <= e1 + 1e-12, (qid, e1, e2)
    assert any(abs(math.log(
        second.per_query[q]["s_out_estimate_ratio"])) < 1e-6
        for q in ("Q1", "Q14"))


# --------------------------------------------- results_equal regression
def test_results_equal_rejects_different_row_sets():
    """Per-column independent sorting (the old implementation) declares
    these equal — every column holds the same value multiset — but the
    row SETS differ. The row-wise lexsort must reject them."""
    a = ColumnTable({"x": np.array([1, 2]), "y": np.array([2, 1])})
    b = ColumnTable({"x": np.array([1, 2]), "y": np.array([1, 2])})
    # the old per-column check would have passed:
    assert all(np.array_equal(np.sort(a.cols[c]), np.sort(b.cols[c]))
               for c in a.columns)
    assert not engine.results_equal(a, b)
    assert not engine.results_equal(b, a)


def test_results_equal_accepts_row_permutations_and_tolerance():
    rng = np.random.default_rng(0)
    n = 257
    a = ColumnTable({"k": rng.integers(0, 50, n),
                     "g": rng.integers(0, 3, n),
                     "v": rng.normal(size=n)})
    perm = rng.permutation(n)
    b = ColumnTable({c: v[perm] for c, v in a.cols.items()})
    assert engine.results_equal(a, b)
    # sub-tolerance float jitter on the permuted copy still passes
    j = ColumnTable(dict(b.cols, v=b.cols["v"] + 1e-9))
    assert engine.results_equal(a, j)
    # a genuine value change fails
    w = np.array(b.cols["v"])
    w[0] += 1.0
    assert not engine.results_equal(a, ColumnTable(dict(b.cols, v=w)))
    # row-count / schema mismatches fail, empties pass
    assert not engine.results_equal(
        a, ColumnTable({c: v[:-1] for c, v in a.cols.items()}))
    assert engine.results_equal(
        ColumnTable({"x": np.array([], np.int64)}),
        ColumnTable({"x": np.array([], np.int64)}))
