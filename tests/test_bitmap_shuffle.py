"""The two new pushdown operators (§4.2): selection bitmap and distributed
shuffle — real-execution equivalence + accounting invariants.

``hypothesis`` is optional: when absent, the property-based test is
skipped and a deterministic seed-sweep fallback covers the same
split-predicate invariant, so the tier-1 suite stays green either way."""
import numpy as np
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dependency — see pyproject.toml [test]
    HAVE_HYPOTHESIS = False

import jax.numpy as jnp

from repro.core import engine
from repro.core.bitmap import (CacheState, combine_bitmaps,
                               compute_side_apply_batched, rewrite_all,
                               split_predicate, storage_side_bitmap,
                               storage_side_bitmap_batched)
from repro.core.plan import PushPlan, execute_push_plan
from repro.core.shuffle import (apply_position_vector, shuffle_at_compute,
                                shuffle_at_storage, shuffle_at_storage_batched)
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.queryproc import expressions as ex
from repro.queryproc import operators as np_ops
from repro.queryproc import queries as Q
from repro.queryproc import tpch
from repro.queryproc.expressions import Col
from repro.queryproc.table import ColumnTable

CAT = tpch.build_catalog(sf=1.0, num_nodes=4, rows_per_partition=4_000)


def _tables_identical(a: ColumnTable, b: ColumnTable, ctx=""):
    assert a.columns == b.columns, (ctx, a.columns, b.columns)
    for c in a.columns:
        assert a.cols[c].dtype == b.cols[c].dtype, (ctx, c)
        assert np.array_equal(a.cols[c], b.cols[c], equal_nan=True), (ctx, c)


# ------------------------------------------------------ selection bitmap
def test_storage_bitmap_plus_device_apply_equals_filter():
    """Fig 3 path: storage builds bitmap, device filters the cached column
    with the Pallas kernel -> same rows as a direct filter."""
    part = CAT.partitions_of("lineitem")[0].data
    pred = (Col("l_quantity") <= 25) & (Col("l_shipmode").isin((0, 1)))
    words, filtered_uncached = storage_side_bitmap(part, pred, ["l_orderkey"])
    # device side: apply the shipped bitmap to the "cached" column
    cached = jnp.asarray(part.cols["l_extendedprice"].astype(np.float32))
    masked, cnt = kops.bitmap_apply(jnp.asarray(words), cached)
    direct = np_ops.filter_table(part, pred)
    assert int(cnt) == len(direct)
    got = np.asarray(masked)
    np.testing.assert_allclose(np.sort(got[got != 0]),
                               np.sort(direct.cols["l_extendedprice"]),
                               rtol=1e-6)
    np.testing.assert_array_equal(filtered_uncached.cols["l_orderkey"],
                                  direct.cols["l_orderkey"])


def test_split_predicate_and_combine():
    """Fine-grained AND split: compute-side + storage-side bitmaps AND
    together to the full predicate (§4.2 design-space)."""
    part = CAT.partitions_of("lineitem")[0].data
    pred = (Col("l_quantity") <= 30) & (Col("l_discount") > 0.02) \
        & (Col("l_shipmode").isin((0, 1, 2)))
    cached = {"l_quantity", "l_discount"}
    comp, stor = split_predicate(pred, cached)
    assert comp is not None and stor is not None
    assert ex.columns_of(comp) <= cached
    w1 = np_ops.selection_bitmap(part, comp)
    w2 = np_ops.selection_bitmap(part, stor)
    full = np_ops.selection_bitmap(part, pred)
    np.testing.assert_array_equal(combine_bitmaps(w1, w2), full)


def test_bitmap_rewrite_accounting():
    q = Q.build_query("Q14", fact_selectivity=0.5)
    reqs = engine.plan_requests(q, CAT)
    # storage-side: outputs cached
    cache = CacheState()
    cache.cache_columns("lineitem", {"l_partkey", "l_extendedprice",
                                     "l_discount"})
    rw, met = rewrite_all(reqs, cache)
    assert met["net_bitmap"] < met["net_baseline"]
    assert all(r.cost.s_out <= b.cost.s_out for r, b in zip(rw, reqs)
               if r.table == "lineitem")
    # compute-side: predicates cached -> storage scans fewer bytes
    cache2 = CacheState()
    cache2.cache_columns("lineitem", {"l_quantity"})
    rw2, met2 = rewrite_all(reqs, cache2)
    assert met2["disk_saved"] > 0
    assert all(r.cost.s_in <= b.cost.s_in for r, b in zip(rw2, reqs)
               if r.table == "lineitem")


_SPLIT_COLS = ("l_quantity", "l_discount", "l_tax", "l_shipmode")


def _check_split_semantics(cached):
    """Any cache set: split conjuncts re-AND to the original."""
    part = CAT.partitions_of("lineitem")[0].data
    pred = (Col("l_quantity") <= 30) & (Col("l_discount") > 0.02) \
        & (Col("l_tax") < 0.05) & (Col("l_shipmode").isin((0, 1)))
    comp, stor = split_predicate(pred, cached)
    want = ex.evaluate(pred, part)
    got = np.ones(len(part), bool)
    if comp is not None:
        got &= ex.evaluate(comp, part)
    if stor is not None:
        got &= ex.evaluate(stor, part)
    np.testing.assert_array_equal(got, want)


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10**6))
    @settings(max_examples=20, deadline=None)
    def test_split_predicate_semantics(seed):
        rng = np.random.default_rng(seed)
        _check_split_semantics({c for c in _SPLIT_COLS
                                if rng.random() < 0.5})


@pytest.mark.parametrize("mask", range(16))
def test_split_predicate_semantics_deterministic(mask):
    """Non-hypothesis fallback: enumerates ALL 16 cache subsets of the
    4 predicate columns exactly (bitmask parametrization)."""
    _check_split_semantics({c for i, c in enumerate(_SPLIT_COLS)
                            if mask >> i & 1})


# ---------------------------------------------------- distributed shuffle
@pytest.mark.parametrize("table,key", [("lineitem", "l_orderkey"),
                                       ("orders", "o_custkey")])
def test_shuffle_placement_equivalence(table, key):
    """Storage-side shuffle == compute-side shuffle, per target node."""
    n = 4
    at_storage = shuffle_at_storage(CAT, table, key, n)
    at_compute = shuffle_at_compute(CAT, table, key, n)
    total = 0
    for s, c in zip(at_storage, at_compute):
        assert engine.results_equal(s, c)
        pid = np_ops.hash_partition_ids(s.cols[key], n)
        assert len(set(pid.tolist())) <= 1  # all rows belong to this target
        total += len(s)
    assert total == len(CAT.scan_table(table))


@pytest.mark.parametrize("table,key", [("lineitem", "l_orderkey"),
                                       ("orders", "o_custkey")])
def test_shuffle_at_storage_batched_byte_identical(table, key):
    """The batch executor's shuffle aux reproduces the per-partition
    reference shuffle exactly, per target node."""
    ref = shuffle_at_storage(CAT, table, key, 4)
    bat = shuffle_at_storage_batched(CAT, table, key, 4)
    for r, b in zip(ref, bat):
        _tables_identical(r, b, (table, key))


def test_storage_side_bitmap_batched_byte_identical():
    """Fig 3 batched: per-partition packed bitmaps + filtered uncached
    columns match the per-partition reference helper."""
    parts = [p.data for p in CAT.partitions_of("lineitem")]
    pred = (Col("l_quantity") <= 25) & (Col("l_shipmode").isin((0, 1)))
    out_cols = ["l_orderkey", "l_extendedprice"]
    words_b, tabs_b = storage_side_bitmap_batched(parts, pred, out_cols)
    for p, wb, tb in zip(parts, words_b, tabs_b):
        w, f = storage_side_bitmap(p, pred, out_cols)
        np.testing.assert_array_equal(w, wb)
        _tables_identical(f, tb, "fig3")


def test_compute_side_apply_batched_byte_identical():
    """Fig 4 batched: compute-built bitmaps applied to every partition in
    one pass match the per-partition apply_bitmap reference."""
    parts = [p.data for p in CAT.partitions_of("lineitem")]
    pred = Col("l_quantity") <= 30
    out_cols = ("l_orderkey", "l_extendedprice")
    bitmaps = [np_ops.selection_bitmap(p, pred) for p in parts]
    aplan = PushPlan("lineitem", out_cols, apply_bitmap=True)
    got = compute_side_apply_batched(parts, bitmaps, out_cols)
    for p, w, g in zip(parts, bitmaps, got):
        ref, _ = execute_push_plan(aplan, p, bitmap=w)
        _tables_identical(ref, g, "fig4")


# ------------------------------------------ properties: pack/ship/apply
def _check_bitmap_roundtrip(mask):
    """pack -> ship -> apply == the boolean mask, any length/alignment."""
    words = np_ops.pack_bitmap(mask)
    np.testing.assert_array_equal(np_ops.unpack_bitmap(words, len(mask)),
                                  mask)
    t = ColumnTable({"v": np.arange(len(mask), dtype=np.int64)})
    _tables_identical(np_ops.apply_bitmap(t, words), t.filter(mask))


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10**6), st.integers(1, 3000))
    @settings(max_examples=25, deadline=None)
    def test_bitmap_roundtrip_property(seed, n):
        rng = np.random.default_rng(seed)
        _check_bitmap_roundtrip(rng.random(n) < rng.random())


@pytest.mark.parametrize("n", [1, 31, 32, 33, 517, 2000])
@pytest.mark.parametrize("seed", [0, 1])
def test_bitmap_roundtrip_deterministic(n, seed):
    rng = np.random.default_rng(seed)
    _check_bitmap_roundtrip(rng.random(n) < 0.4)


def _check_position_vector_equivalence(seed, n_rows, n_targets):
    """Routing cached columns with the position vector lands every row on
    the same target as the storage-side hash partition (§4.2 interop)."""
    rng = np.random.default_rng(seed)
    t = ColumnTable({"k": rng.integers(0, 1 << 31, n_rows).astype(np.int64),
                     "v": rng.normal(size=n_rows)})
    pv = np_ops.position_vector(t, "k", n_targets)
    via_pv = apply_position_vector(t, pv, n_targets)
    via_hash = np_ops.shuffle_partition(t, "k", n_targets)
    assert sum(len(p) for p in via_pv) == n_rows
    for a, b in zip(via_pv, via_hash):
        _tables_identical(a, b, (seed, n_targets))


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 10**6), st.integers(0, 2000), st.integers(1, 16))
    @settings(max_examples=25, deadline=None)
    def test_position_vector_equivalence_property(seed, n_rows, n_targets):
        _check_position_vector_equivalence(seed, n_rows, n_targets)


@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("n_targets", [1, 4, 7])
def test_position_vector_equivalence_deterministic(seed, n_targets):
    _check_position_vector_equivalence(seed, 500 + 37 * seed, n_targets)


def test_shuffle_kernel_matches_engine():
    keys = CAT.partitions_of("lineitem")[0].data.cols["l_orderkey"]
    pids, hist = kops.hash_partition(jnp.asarray(keys), 4)
    np.testing.assert_array_equal(np.asarray(pids),
                                  np_ops.hash_partition_ids(keys, 4))
    assert int(np.asarray(hist).sum()) == len(keys)


def test_position_vector_bits():
    pv = np_ops.position_vector(CAT.partitions_of("lineitem")[0].data,
                                "l_orderkey", 4)
    assert pv.max() < 4 and pv.min() >= 0  # log2(4)=2 bits/row suffice


def test_fused_scan_shuffle_kernel_matches_engine():
    """The fused predicate -> bitmap-pack -> hash-partition kernel computes
    exactly what the numpy batch executor's aux emission computes."""
    part = CAT.partitions_of("lineitem")[0].data
    # f32-exact operands: quantities are small integers, shipmode is int
    pred = (Col("l_quantity") <= 25) & (Col("l_shipmode").isin((0, 1)))
    keys = part.cols["l_orderkey"]
    cols = {"l_quantity": jnp.asarray(part.cols["l_quantity"].astype(
        np.float32)), "l_shipmode": jnp.asarray(part.cols["l_shipmode"])}
    words, pids, hist = kops.fused_scan_shuffle(
        cols, kops.compile_predicate(pred), jnp.asarray(keys), 4,
        block=1024)
    mask = ex.evaluate(pred, part)
    want_pid = np_ops.hash_partition_ids(keys, 4)
    np.testing.assert_array_equal(np.asarray(words),
                                  np_ops.pack_bitmap(mask))
    np.testing.assert_array_equal(np.asarray(pids), want_pid)
    np.testing.assert_array_equal(
        np.asarray(hist), np.bincount(want_pid[mask], minlength=4))
