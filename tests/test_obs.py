"""Observability subsystem: span trees, decision channels, metrics, and
exporters — plus the two hard guarantees the tentpole promises:

1. **Byte identity**: tracing ON and OFF produce byte-identical query
   results across all 15 TPC-H queries and all 4 engine modes (the hooks
   observe, they never steer).
2. **Exact reconciliation**: the bytes a trace's execution spans claim
   were shipped equal ``QueryRun.real_net_bytes`` / the stream driver's
   per-query accounting *exactly* — same arithmetic, not a re-estimate.
"""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import engine, runtime
from repro.core.cost import StorageResources
from repro.obs import export as obs_export
from repro.obs.metrics import Metrics
from repro.obs.trace import (NULL_SPAN, NULL_TRACER, DecisionChannel, Tracer,
                             get_tracer, set_tracer, tracing)
from repro.queryproc import queries as Q
from repro.queryproc import tpch
from repro.queryproc.table import ColumnTable

CAT = tpch.build_catalog(sf=1.0, num_nodes=2, rows_per_partition=4_000)


def assert_tables_identical(a: ColumnTable, b: ColumnTable, ctx=""):
    assert a.columns == b.columns, (ctx, a.columns, b.columns)
    for c in a.columns:
        x, y = a.cols[c], b.cols[c]
        assert x.dtype == y.dtype, (ctx, c, x.dtype, y.dtype)
        assert np.array_equal(x, y, equal_nan=True), (ctx, c)


# ------------------------------------------------------------- tracer core
def test_default_tracer_is_disabled_noop():
    tr = get_tracer()
    assert tr is NULL_TRACER and not tr.enabled
    with tr.span("anything", foo=1) as sp:
        assert not sp                      # falsy null span
        sp.set(bar=2)                      # swallowed
    assert tr.snapshot() == [] and tr.tree() == []
    assert tr.start("x") is NULL_SPAN
    tr.end(NULL_SPAN, y=3)                 # no-op, no error


def test_span_nesting_and_parenting():
    with tracing() as tr:
        with tr.span("a") as a:
            with tr.span("b"):
                tr.event("e")
            det = tr.start("c", parent=a)
        tr.end(det, done=True)
    (ra,) = tr.tree()
    assert ra["name"] == "a"
    assert [c["name"] for c in ra["children"]] == ["b", "c"]
    assert ra["children"][0]["children"][0]["name"] == "e"
    assert ra["children"][0]["children"][0]["dur"] == 0.0
    assert ra["children"][1]["attrs"] == {"done": True}
    assert all(s.dur is not None for s in tr.snapshot())


def test_tracer_max_spans_drops_not_grows():
    tr = Tracer(max_spans=3)
    with tracing(tr):
        for _ in range(10):
            tr.event("e")
    assert len(tr.snapshot()) == 3 and tr.dropped == 7


def test_cross_thread_detached_span():
    with tracing() as tr:
        root = tr.start("root")

        def worker():
            with tr.span("child", parent=root):
                pass
            tr.end(root)

        t = threading.Thread(target=worker)
        t.start()
        t.join()
    (rt,) = tr.tree()
    assert rt["name"] == "root" and rt["dur"] is not None
    assert [c["name"] for c in rt["children"]] == ["child"]


# -------------------------------------------------------- decision channel
def test_decision_channel_cap_and_counts():
    ch = DecisionChannel(cap=4)
    for i in range(10):
        ch.record(branch="gather" if i % 2 else "concat", i=i)
    assert len(ch) == 4 and ch.dropped == 6
    assert sum(ch.counts("branch").values()) == 4
    ch.clear()
    assert len(ch) == 0 and ch.dropped == 0


def test_decision_channel_thread_safety():
    ch = DecisionChannel(cap=50_000)
    n_threads, per = 8, 2_000

    def writer(k):
        for i in range(per):
            ch.record(k=k, i=i)

    threads = [threading.Thread(target=writer, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(ch) == n_threads * per and ch.dropped == 0
    assert ch.counts("k") == {k: per for k in range(n_threads)}


def test_filter_decisions_deprecated_alias():
    """The old ``executor.FILTER_DECISIONS`` module global still reads (one
    release of compat) but is served from the bounded channel."""
    from repro.core import executor as X
    X.reset_filter_decisions()
    q = Q.build_query("Q6")
    reqs = engine.plan_requests(q, CAT)
    engine.execute_requests(reqs)
    log = X.FILTER_DECISIONS               # module __getattr__ alias
    assert len(log) > 0 and log[0]["table"] == "lineitem"
    counts = X.filter_decision_counts()
    assert counts["gather"] + counts["concat"] == len(log)


# --------------------------------------------------------------- metrics
def test_metrics_registry_and_epoch():
    m = Metrics()
    m.counter("a").inc()
    m.counter("a").inc(4)
    m.gauge("g").set(2.5)
    for v in (1, 2, 1000):
        m.histogram("h").observe(v)
    snap = m.snapshot()
    assert snap["counters"]["a"] == 5.0 and snap["gauges"]["g"] == 2.5
    assert snap["histograms"]["h"]["count"] == 3
    e1 = m.epoch()
    assert e1["counters"]["a"] == 5.0
    m.counter("a").inc(2)
    e2 = m.epoch()
    assert e2["counters"]["a"] == 2.0      # delta since previous epoch
    assert e2["epoch"] == e1["epoch"] + 1


def test_metrics_thread_safety():
    m = Metrics()
    n_threads, per = 8, 5_000

    def worker():
        for i in range(per):
            m.counter("c").inc()
            m.histogram("h").observe(i)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = m.snapshot()
    assert snap["counters"]["c"] == n_threads * per
    assert snap["histograms"]["h"]["count"] == n_threads * per


# ------------------------------------------------- span-tree goldens
def _names(node):
    return (node["name"], [_names(c) for c in node["children"]])


def test_span_tree_golden_q1():
    cfg = engine.EngineConfig(mode="adaptive")
    with tracing() as tr:
        engine.run_query(Q.build_query("Q1"), CAT, cfg)
    (qt,) = tr.tree()
    assert qt["name"] == "query" and qt["attrs"]["qid"] == "Q1"
    children = [c["name"] for c in qt["children"]]
    assert children == ["plan_requests", "arbitrate", "execute_split",
                        "residual_compute"]
    es = qt["children"][2]
    inner = [c["name"] for c in es["children"]]
    assert inner[-1] == "merge" and "storage_execute" in inner
    assert es["attrs"]["pushdown_bytes"] + es["attrs"]["pushback_bytes"] \
        == qt["attrs"]["real_net_bytes"]


def test_span_tree_golden_q19_costed():
    from repro.compiler import compile as C
    with tracing() as tr:
        cq = C.compile_query_costed("q19", CAT)
        engine.run_query(cq.query, CAT, engine.EngineConfig(mode="adaptive"))
    roots = [t["name"] for t in tr.tree()]
    assert roots == ["compile", "query"]
    comp = tr.tree()[0]
    cuts = [c for c in comp["children"] if c["name"] == "cut_scoring"]
    assert {c["attrs"]["table"] for c in cuts} == {"lineitem", "part"}
    for c in cuts:
        assert len(c["attrs"]["scores"]) == len(c["attrs"]["signatures"]) \
            == c["attrs"]["maximal"] + 1
        assert 0 <= c["attrs"]["chosen"] <= c["attrs"]["maximal"]


def test_span_tree_golden_q18_clustered_having():
    """The clustered-catalog Q18 trace shows the HAVING frontier: the
    chooser's ``cut_scoring`` event picks the ``scan+agg+having``
    candidate and the executed plan's signature carries it."""
    from repro.compiler import compile as C
    ccat = tpch.build_catalog(sf=1.0, num_nodes=2, rows_per_partition=4_000,
                              cluster={"lineitem": "l_orderkey"})
    with tracing() as tr:
        cq = C.compile_query_costed("q18", ccat)
        engine.run_query(cq.query, ccat, engine.EngineConfig(mode="adaptive"))
    (cut,) = [c for c in tr.tree()[0]["children"]
              if c["name"] == "cut_scoring"
              and c["attrs"]["table"] == "lineitem"]
    assert cut["attrs"]["signatures"][cut["attrs"]["chosen"]] \
        == "scan+agg+having"
    sigs = {s.attrs.get("signature") for s in tr.find("storage_execute")}
    assert "scan+agg+having" in sigs


def test_arbitrate_decision_channel_records_load():
    with tracing() as tr:
        engine.run_query(Q.build_query("Q6"), CAT,
                         engine.EngineConfig(mode="adaptive"))
    decs = tr.decisions.snapshot()
    assert len(decs) == len(engine.plan_requests(Q.build_query("Q6"), CAT))
    for d in decs:
        assert d["kind"] == "arbitrate"
        assert d["path"] in ("pushdown", "pushback")
        assert d["free_pd"] >= 0 and d["free_pb"] >= 0 \
            and d["queue_depth"] >= 0


# ------------------------------------- byte identity: tracing on vs off
@pytest.mark.parametrize("qid", Q.QUERY_IDS)
def test_tracing_byte_identity_all_modes(qid):
    q = Q.build_query(qid)
    for mode in engine.MODES:
        cfg = engine.EngineConfig(mode=mode)
        base = engine.run_query(q, CAT, cfg)           # tracing off
        with tracing():
            traced = engine.run_query(q, CAT, cfg)     # tracing on
        assert_tables_identical(base.result, traced.result, (qid, mode))
        assert base.real_net_bytes == traced.real_net_bytes, (qid, mode)


# --------------------------------------------------------- exporters
def _traced_q1():
    with tracing() as tr:
        engine.run_query(Q.build_query("Q1"), CAT,
                         engine.EngineConfig(mode="adaptive"))
    return tr


def test_jsonl_round_trip_tree_equality(tmp_path):
    tr = _traced_q1()
    path = tmp_path / "trace.jsonl"
    obs_export.to_jsonl(tr, path, meta={"suite": "test"})
    meta, spans = obs_export.from_jsonl(path)
    assert meta["format"] == "repro-trace-v1"
    assert meta["n_spans"] == len(tr.snapshot()) and meta["suite"] == "test"
    # round-tripped forest == the tracer's own (after JSON coercion)
    want = json.loads(json.dumps(tr.tree(), default=obs_export._coerce))
    assert obs_export.build_tree(spans) == want


def test_chrome_trace_is_valid_and_complete(tmp_path):
    tr = _traced_q1()
    path = tmp_path / "trace.json"
    obs_export.to_chrome_trace(tr, path, meta={"mode": "adaptive"})
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert events[0]["ph"] == "M"                      # process_name meta
    xs = [e for e in events if e["ph"] == "X"]
    assert len(xs) == len(tr.snapshot())
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0 and e["name"]
    assert {"query", "execute_split", "merge"} <= {e["name"] for e in xs}
    assert doc["otherData"] == {"mode": "adaptive"}


def test_summary_table_lists_queries():
    tr = _traced_q1()
    table = obs_export.summary_table(tr)
    lines = table.splitlines()
    assert lines[0].startswith("query") and any("Q1" in ln for ln in lines)


def test_numpy_attrs_coerce_to_json(tmp_path):
    with tracing() as tr:
        tr.event("e", a=np.int64(3), b=np.array([1, 2]),
                 c=np.float32(0.5), d={"x", "y"})
    _, (span,) = obs_export.from_jsonl(
        obs_export.to_jsonl(tr, tmp_path / "t.jsonl"))
    assert span["attrs"] == {"a": 3, "b": [1, 2], "c": 0.5, "d": ["x", "y"]}


# ------------------------------ stream driver: spans + exact reconciliation
def test_run_stream_trace_reconciles_exactly(tmp_path):
    """sf=1 streamed run: the Chrome-exportable trace's per-query spans
    carry real_net_bytes equal to the driver's accounting, and the
    execution spans under each query sum to it EXACTLY."""
    stream = [runtime.StreamQuery(Q.build_query(qid), arrival=i * 0.004)
              for i, qid in enumerate(("Q1", "Q6", "Q12", "Q18"))]
    cfg = engine.EngineConfig(res=StorageResources(storage_power=0.25),
                              mode="adaptive")
    base = runtime.run_stream(stream, CAT, cfg)
    with tracing() as tr:
        run = runtime.run_stream(stream, CAT, cfg)
    for qid in run.results:
        assert_tables_identical(base.results[qid], run.results[qid], qid)

    (st,) = [t for t in tr.tree() if t["name"] == "run_stream"]
    assert st["attrs"]["real_net_bytes"] == run.real_net_bytes
    qnodes = {c["attrs"]["qid"]: c for c in st["children"]
              if c["name"] == "query"}
    assert set(qnodes) == set(run.per_query)
    for key, qn in qnodes.items():
        want = run.per_query[key]["real_net_bytes"]
        assert qn["attrs"]["real_net_bytes"] == want, key
        got = sum(c["attrs"]["shipped_bytes"] for c in qn["children"]
                  if c["name"] in ("storage_execute", "compute_replay"))
        assert got == want, key            # EXACT, not approximate
    # pushback transfers appear whenever requests were pushed back
    if run.n_pushback:
        assert tr.find("pushback_ship")
    # wave samples carry live load signals
    for ws in tr.find("wave_sample"):
        assert "exec_queue" in ws.attrs and "ship_queue" in ws.attrs
    # and the whole thing exports as a loadable Chrome trace
    doc = json.loads(open(obs_export.to_chrome_trace(
        tr, tmp_path / "stream.json")).read())
    assert len(doc["traceEvents"]) == len(tr.snapshot()) + 1


def test_run_stream_metrics_consistent():
    from repro.obs.metrics import get_metrics, set_metrics
    stream = [runtime.StreamQuery(Q.build_query(qid), arrival=i * 0.003)
              for i, qid in enumerate(("Q1", "Q6", "Q6"))]
    cfg = engine.EngineConfig(mode="adaptive")
    m = Metrics()
    prev = set_metrics(m)
    try:
        run = runtime.run_stream(stream, CAT, cfg)
    finally:
        set_metrics(prev)
    snap = m.snapshot()
    assert snap["counters"]["stream.requests.pushdown"] == run.n_pushdown
    assert snap["counters"].get("stream.requests.pushback", 0) \
        == run.n_pushback
    assert snap["counters"]["stream.net_bytes.real"] == run.real_net_bytes
    assert snap["histograms"]["stream.query_finish_s"]["count"] \
        == len(stream)
    assert any(k.startswith("stream.node") for k in snap["gauges"])


def test_engine_metrics_counters():
    from repro.obs.metrics import set_metrics
    m = Metrics()
    prev = set_metrics(m)
    try:
        run = engine.run_query(Q.build_query("Q6"), CAT,
                               engine.EngineConfig(mode="adaptive"))
    finally:
        set_metrics(prev)
    snap = m.snapshot()
    assert snap["counters"]["engine.queries"] == 1
    assert snap["counters"]["engine.requests.pushdown"] == run.n_admitted
    assert snap["counters"]["engine.net_bytes.real"] == run.real_net_bytes


# --------------------------------------------- bitmap via execute_split
def test_compute_side_bitmap_routes_through_execute_split():
    """Satellite: the Fig-4 batched path now runs under execute_split —
    same results as the per-partition oracle, with spans to prove the
    routing."""
    from repro.core import bitmap as bm
    from repro.queryproc import operators as ops
    from repro.queryproc.expressions import Col

    parts = [p.data for p in CAT.partitions_of("lineitem")][:4]
    pred = Col("l_quantity") <= 25
    out_cols = ("l_orderkey", "l_extendedprice")
    words = [ops.selection_bitmap(p, pred) for p in parts]
    with tracing() as tr:
        got = bm.compute_side_apply_batched(parts, words, out_cols)
    want = [ops.apply_bitmap(p.select(list(out_cols)), w)
            for p, w in zip(parts, words)]
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert_tables_identical(g, w)
    es = tr.find("execute_split")
    assert es and es[0].attrs["n_pushdown"] == len(parts)
    assert tr.find("storage_execute")


# ------------------------------ crash-safe streaming export (JsonlStreamWriter)
def test_stream_writer_round_trip_merges_pairs(tmp_path):
    """Closed spans merge start+end (final dur + attrs), a span open at
    close-time reads back open (dur=None), writes after close are
    silently dropped."""
    path = tmp_path / "stream.jsonl"
    w = obs_export.JsonlStreamWriter(path, meta={"suite": "t"})
    tr = Tracer()
    tr.attach_sink(w)
    with tracing(tr):
        with tr.span("closed", qid="Q1") as sp:
            sp.set(late_attr=7)
            tr.event("ev", k=1)
        never = tr.start("never_closed")
    w.close()
    tr.end(never)                      # after close: dropped, no error
    meta, spans = obs_export.from_jsonl(path)
    assert meta["streaming"] is True and meta["suite"] == "t"
    by_name = {s["name"]: s for s in spans}
    assert by_name["closed"]["dur"] is not None
    assert by_name["closed"]["attrs"]["late_attr"] == 7   # end-side attrs won
    assert by_name["ev"]["dur"] == 0.0                    # events close too
    assert by_name["never_closed"]["dur"] is None         # still open on disk
    # the merged stream builds the same forest shape as the live tracer
    roots = obs_export.build_tree(spans)
    assert [r["name"] for r in roots] == ["closed", "never_closed"]
    assert [c["name"] for c in roots[0]["children"]] == ["ev"]


def test_stream_writer_tolerates_torn_tail(tmp_path):
    path = tmp_path / "stream.jsonl"
    with obs_export.JsonlStreamWriter(path) as w:
        tr = Tracer()
        tr.attach_sink(w)
        with tracing(tr):
            with tr.span("a"):
                pass
            with tr.span("b"):
                pass
    # simulate the process dying mid-write: chop the last line in half
    raw = path.read_bytes()
    path.write_bytes(raw[:len(raw) - 17])
    meta, spans = obs_export.from_jsonl(path)
    assert meta.get("streaming") is True
    names = [s["name"] for s in spans]
    assert "a" in names                 # the valid prefix survived
    a = next(s for s in spans if s["name"] == "a")
    assert a["dur"] is not None         # its end line landed before the tear


def test_stream_writer_survives_kill_dash_nine(tmp_path):
    """The satellite's contract end-to-end: a child process streaming a
    trace is SIGKILLed with spans open; the file left behind parses, the
    finished span has its dur, the in-flight spans read back open."""
    import signal
    import subprocess
    import sys
    import time

    path = tmp_path / "killed.jsonl"
    child = subprocess.Popen(
        [sys.executable, "-c", f"""
import sys, time
from repro.obs.trace import Tracer, tracing
from repro.obs.export import JsonlStreamWriter

w = JsonlStreamWriter({str(path)!r})
tr = Tracer()
tr.attach_sink(w)
with tracing(tr):
    with tr.span("finished", qid="Q1"):
        pass
    open_outer = tr.start("query", qid="Q9")
    open_inner = tr.start("storage_execute", parent=open_outer, node=0)
    print("SPANS_OPEN", flush=True)
    time.sleep(30)                     # killed long before this returns
"""],
        stdout=subprocess.PIPE, text=True, env={"PYTHONPATH": "src"},
        cwd="/root/repo")
    try:
        assert child.stdout.readline().strip() == "SPANS_OPEN"
        child.send_signal(signal.SIGKILL)
        child.wait(timeout=30)
    finally:
        if child.poll() is None:
            child.kill()
    assert child.returncode == -signal.SIGKILL
    meta, spans = obs_export.from_jsonl(path)
    assert meta.get("streaming") is True
    by_name = {s["name"]: s for s in spans}
    assert by_name["finished"]["dur"] is not None
    assert by_name["query"]["dur"] is None
    assert by_name["storage_execute"]["dur"] is None
    assert by_name["storage_execute"]["parent"] == by_name["query"]["sid"]
    assert by_name["query"]["attrs"]["qid"] == "Q9"


def test_stream_writer_matches_batch_export_shape(tmp_path):
    """Streaming a real engine run produces the same forest as the batch
    exporter over the same tracer — the crash-safe path loses nothing."""
    tr = Tracer()
    w = obs_export.JsonlStreamWriter(tmp_path / "live.jsonl")
    tr.attach_sink(w)
    with tracing(tr):
        engine.run_query(Q.build_query("Q6"), CAT,
                         engine.EngineConfig(mode="adaptive"))
    w.close()
    obs_export.to_jsonl(tr, tmp_path / "batch.jsonl")
    _, live = obs_export.from_jsonl(tmp_path / "live.jsonl")
    _, batch = obs_export.from_jsonl(tmp_path / "batch.jsonl")
    assert obs_export.build_tree(live) == obs_export.build_tree(batch)
