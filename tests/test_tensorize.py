"""Tensorized compute residual (compiler.tensorize + engine dispatch).

The load-bearing invariant: the interpreter is the oracle. For every
TPC-H residual, every engine mode, every decision vector, warm or cold
jit caches, and fault-demoted replays, the tensor backend's table is
identical (``engine.results_equal``) to the interpreter's. On top: the
observe -> jit-miss -> jit-hit protocol is pinned via ``TensorRun``
counters, shape buckets share compiled programs, out-of-domain keys
respecialize (gen bump) without changing results, duplicate-right-key
joins fall back gracefully, and ``compile_expr_jnp`` matches
``compile_expr`` bitwise on random columns.
"""
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

from repro.compiler import (compile_query, compile_query_detailed,
                            interpreter, ir, tensorize)
from repro.compiler.tpch_ir import QUERY_IDS
from repro.core import engine, runtime
from repro.core.arbitrator import PUSHBACK, PUSHDOWN
from repro.queryproc import expressions as ex
from repro.queryproc import tpch
from repro.queryproc.expressions import Col
from repro.queryproc.expressions_jax import compile_expr_jnp
from repro.queryproc.table import ColumnTable

CAT = tpch.build_catalog(sf=0.5, num_nodes=2, rows_per_partition=4_000)
CFG = engine.EngineConfig(mode="eager")


def merged_for(cq):
    """All-pushdown merged tables (identical for any decision vector)."""
    out = {}
    for t, plan in cq.plans.items():
        parts = [engine.execute_push_plan(plan, p.data)[0]
                 for p in CAT.partitions_of(t)]
        out[t] = ColumnTable.concat(parts)
    return out


# ------------------------------------------------ all-15 oracle identity
@pytest.mark.parametrize("qid", QUERY_IDS)
def test_tensor_matches_interpreter(qid):
    """observe -> first jit (miss) -> warm (hit): all three runs return
    the interpreter's exact table, and the warm run hits every stage."""
    cq = compile_query_detailed(qid)
    merged = merged_for(cq)
    ref = interpreter.run(cq.residual, merged)
    r_obs = tensorize.execute(cq.residual, merged)
    r_cold = tensorize.execute(cq.residual, merged)
    r_warm = tensorize.execute(cq.residual, merged)
    assert r_obs.observed and not r_cold.observed and not r_warm.observed
    for r in (r_obs, r_cold, r_warm):
        assert engine.results_equal(ref, r.table), qid
        assert not r.fell_back, qid
    # every jittable stage misses cold and hits warm (a stage may be
    # host-only — e.g. Q22's PyOp tail — and then touches no jit cache)
    assert r_cold.jit_hits == 0 and r_cold.jit_misses >= 1
    assert r_warm.jit_misses == 0
    assert r_warm.jit_hits == r_cold.jit_misses


def test_pyop_queries_partition_into_two_stages():
    """Q15/Q22 residuals contain a PyOp — the lowering must split into
    maximal jittable segments around it, not give up on the query."""
    for qid in ("Q15", "Q22"):
        cq = compile_query_detailed(qid)
        merged = merged_for(cq)
        tensorize.execute(cq.residual, merged)           # observe
        r = tensorize.execute(cq.residual, merged)
        assert r.n_stages == 2, qid
        assert not r.fell_back, qid


# ------------------------------------------- modes and decision vectors
@pytest.mark.parametrize("mode", engine.MODES)
def test_engine_modes_identical(mode):
    """Same query, same mode, both backends: identical results. The
    decision vector differs per mode; merged inputs do not — but the
    dispatch path (engine._run_decided) must behave under all four."""
    for qid in ("Q5", "Q22"):
        q = compile_query(qid)
        ri = engine.run_query(q, CAT, engine.EngineConfig(mode=mode))
        cfg_t = engine.EngineConfig(mode=mode, residual="tensor")
        engine.run_query(q, CAT, cfg_t)                  # observe
        rt = engine.run_query(q, CAT, cfg_t)
        assert engine.results_equal(ri.result, rt.result), (qid, mode)
        assert rt.residual_backend == "tensor"
        assert ri.residual_backend == "interpreter"


def test_random_decision_vectors_identical():
    """Hand-rolled pushdown/pushback splits: the merged tables are
    reassembly-identical, so the tensor residual must be too."""
    rng = np.random.default_rng(7)
    cq = compile_query_detailed("Q12")
    reqs = engine.plan_requests(cq.query, CAT)
    for _ in range(3):
        decisions = {r.req_id: (PUSHDOWN if rng.random() < 0.5 else PUSHBACK)
                     for r in reqs}
        split = runtime.execute_split(reqs, decisions, CFG.executor, None)
        ref = interpreter.run(cq.residual, split.merged)
        run = tensorize.execute(cq.residual, split.merged)
        assert engine.results_equal(ref, run.table)


def test_fault_demoted_replay_identical(monkeypatch):
    """Guaranteed-crash fault plan: every admitted group demotes to
    pushback replay — the tensor residual still matches the clean run."""
    from repro.core.faults import FaultPlan, RetryPolicy
    q = compile_query("Q6")
    clean = engine.run_query(q, CAT, CFG)
    cfg = engine.EngineConfig(
        mode="eager", residual="tensor",
        faults=FaultPlan.from_spec("pushdown.crash:1.0", seed=3),
        retry=RetryPolicy(sleep_scale=0.0))
    engine.run_query(q, CAT, cfg)                        # observe
    run = engine.run_query(q, CAT, cfg)
    assert run.recovery is not None and run.recovery["n_demoted"] > 0
    assert run.residual_backend == "tensor"
    assert engine.results_equal(clean.result, run.result)


# ------------------------------------------------- engine accounting/auto
def test_queryrun_jit_accounting():
    q = compile_query("Q14")
    cfg = engine.EngineConfig(mode="eager", residual="tensor")
    r1 = engine.run_query(q, CAT, cfg)
    r2 = engine.run_query(q, CAT, cfg)
    r3 = engine.run_query(q, CAT, cfg)
    assert r1.residual_jit["observed"] is True
    assert r2.residual_jit["misses"] == r2.residual_jit["n_stages"]
    assert r3.residual_jit["hits"] == r3.residual_jit["n_stages"]
    assert r3.residual_jit["misses"] == 0
    assert not r3.residual_jit["fell_back"]


def test_auto_mode_threshold(monkeypatch):
    """auto = tensor at/above the crossover, interpreter below; the env
    override feeds the same knob the calibration would."""
    q = compile_query("Q6")
    monkeypatch.setattr(tensorize, "_AUTO_THRESHOLD", None)
    monkeypatch.setenv("REPRO_RESIDUAL_THRESHOLD", "1")
    r_hi = engine.run_query(
        q, CAT, engine.EngineConfig(mode="eager", residual="auto"))
    assert r_hi.residual_backend == "tensor"
    monkeypatch.setattr(tensorize, "_AUTO_THRESHOLD", None)
    monkeypatch.setenv("REPRO_RESIDUAL_THRESHOLD", str(1 << 40))
    r_lo = engine.run_query(
        q, CAT, engine.EngineConfig(mode="eager", residual="auto"))
    assert r_lo.residual_backend == "interpreter"
    assert engine.results_equal(r_hi.result, r_lo.result)
    monkeypatch.setattr(tensorize, "_AUTO_THRESHOLD", None)


def test_calibration_returns_usable_threshold(monkeypatch):
    """The measured crossover is a positive row count (or inf when the
    tensor backend never wins — auto then stays on the oracle), and
    REPRO_NO_CALIBRATE pins the documented default."""
    th = tensorize.calibrate_residual_threshold(sizes=(512, 2_048),
                                                repeats=1)
    assert th > 0
    monkeypatch.setattr(tensorize, "_AUTO_THRESHOLD", None)
    monkeypatch.delenv("REPRO_RESIDUAL_THRESHOLD", raising=False)
    monkeypatch.setenv("REPRO_NO_CALIBRATE", "1")
    assert tensorize.auto_threshold() == tensorize.DEFAULT_RESIDUAL_THRESHOLD
    monkeypatch.setattr(tensorize, "_AUTO_THRESHOLD", None)


def test_unknown_backend_rejected():
    q = compile_query("Q6")
    with pytest.raises(ValueError, match="residual backend"):
        engine.run_query(q, CAT,
                         engine.EngineConfig(mode="eager", residual="bogus"))


def test_seed_queries_without_residual_fall_through():
    """Hand-built seed queries carry no residual IR: the tensor backend
    must transparently run their compute closure."""
    from repro.queryproc import queries as Q
    q = Q.build_query_legacy("Q6")
    assert q.residual is None
    r = engine.run_query(q, CAT,
                         engine.EngineConfig(mode="eager", residual="tensor"))
    ref = engine.run_query(q, CAT, CFG)
    assert r.residual_backend == "interpreter"
    assert engine.results_equal(r.result, ref.result)


# ------------------------------------------------ specialization machinery
def _agg_residual():
    return ir.Aggregate(ir.Merged("t"), ("k",), (("s", "sum", "v"),))


def _tab(keys, vals=None):
    keys = np.asarray(keys, dtype=np.int64)
    vals = (np.ones(len(keys)) if vals is None
            else np.asarray(vals, dtype=np.float64))
    return ColumnTable({"k": keys, "v": vals})


def test_respecialize_on_domain_growth():
    """Keys outside the observed domain trip the in-trace guard: that run
    falls back (still correct), the artifact respecializes (gen bump),
    and the next run jits cleanly over the widened bounds."""
    res = _agg_residual()
    small = {"t": _tab(np.arange(64) % 4)}
    big = {"t": _tab(np.arange(64) % 4 + 100)}       # disjoint key range
    tensorize.execute(res, small)                    # observe on small
    art = tensorize._artifact(res)
    assert art.gen == 0
    ok = tensorize.execute(res, small)
    assert not ok.fell_back
    r_fb = tensorize.execute(res, big)               # oob -> guard trips
    assert r_fb.fell_back
    assert engine.results_equal(interpreter.run(res, big), r_fb.table)
    assert art.gen == 1 and art.respecs == 1
    r_ok = tensorize.execute(res, big)               # widened spec jits
    assert not r_ok.fell_back and not art.disabled
    assert engine.results_equal(interpreter.run(res, big), r_ok.table)


def test_shape_buckets_share_jitted_programs():
    """Row counts in the same pow-2 bucket reuse the compiled program;
    crossing a bucket boundary compiles once more, results identical."""
    res = _agg_residual()
    m900 = {"t": _tab(np.arange(900) % 8)}
    m1000 = {"t": _tab(np.arange(1000) % 8)}
    m1500 = {"t": _tab(np.arange(1500) % 8)}
    tensorize.execute(res, m900)                     # observe
    r1 = tensorize.execute(res, m900)                # 1024-bucket miss
    assert r1.jit_misses == 1
    r2 = tensorize.execute(res, m1000)               # same bucket: hit
    assert r2.jit_hits == 1 and r2.jit_misses == 0
    r3 = tensorize.execute(res, m1500)               # 2048-bucket: miss
    assert r3.jit_misses == 1
    for m in (m900, m1000, m1500):
        got = tensorize.execute(res, m)
        assert engine.results_equal(interpreter.run(res, m), got.table)
        assert got.jit_hits == 1


def test_join_duplicate_right_keys_falls_back():
    """The dense-LUT probe requires unique build keys; a many-to-many
    right side must fall back to the interpreter with the same table."""
    res = ir.Join(ir.Merged("l"), ir.Merged("r"), "k", "rk")
    merged = {"l": ColumnTable({"k": np.asarray([1, 2, 3]),
                                "x": np.asarray([1.0, 2.0, 3.0])}),
              "r": ColumnTable({"rk": np.asarray([2, 2, 3]),
                                "y": np.asarray([10.0, 20.0, 30.0])})}
    tensorize.execute(res, merged)                   # observe
    run = tensorize.execute(res, merged)
    assert run.fell_back
    assert engine.results_equal(interpreter.run(res, merged), run.table)


def test_join_non_integer_keys_use_sorted_probe():
    """Float keys cannot index a dense LUT — the join must still jit via
    the in-trace sorted-probe path, not fall back."""
    res = ir.Join(ir.Merged("l"), ir.Merged("r"), "k", "rk")
    merged = {"l": ColumnTable({"k": np.asarray([1.5, 2.5, 3.5, 9.0]),
                                "x": np.asarray([1.0, 2.0, 3.0, 4.0])}),
              "r": ColumnTable({"rk": np.asarray([2.5, 3.5, 7.0]),
                                "y": np.asarray([10.0, 20.0, 30.0])})}
    ref = interpreter.run(res, merged)
    tensorize.execute(res, merged)                   # observe
    run = tensorize.execute(res, merged)
    assert not run.fell_back
    assert engine.results_equal(ref, run.table)


def test_empty_build_side():
    """An empty right table yields an empty (but well-formed) probe."""
    res = ir.SemiJoin(ir.Merged("l"), ir.Merged("r"), "k", "rk")
    merged = {"l": _tab([1, 2, 3]),
              "r": ColumnTable({"rk": np.asarray([], dtype=np.int64)})}
    ref = interpreter.run(res, merged)
    tensorize.execute(res, merged)                   # observe
    run = tensorize.execute(res, merged)
    assert len(run.table) == 0
    assert engine.results_equal(ref, run.table)


# --------------------------------------------- expression twin equivalence
def test_compile_expr_jnp_matches_numpy():
    import jax
    from jax.experimental import enable_x64
    rng = np.random.default_rng(11)
    cols = {"a": rng.integers(0, 50, 400).astype(np.int64),
            "b": rng.normal(size=400),
            "c": rng.integers(0, 5, 400).astype(np.int64)}
    exprs = [
        Col("a") < 25,
        (Col("a") >= 10) & (Col("b") <= 0.3),
        (Col("b") > Col("b")) | Col("c").eq(2),
        Col("c").isin((1, 3, 4)) & (Col("a") > 5),
        (Col("a") <= Col("a")) & Col("c").isin((0,)),
    ]
    with enable_x64():
        for e in exprs:
            want = ex.compile_expr(e)(cols)
            jf = jax.jit(compile_expr_jnp(e))
            got = np.asarray(jf({k: v for k, v in cols.items()}))
            assert np.array_equal(want, got), e
