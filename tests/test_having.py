"""Storage-side HAVING pushdown over partial aggregates (Q18).

Soundness hinges on catalog-proven *group locality*: a merge-monotone
HAVING filter may only run at the storage layer when the table is
clustered on a group key — then every group is partition-local, partials
equal finals, and filtering partials drops no group that would survive
the merge. Unclustered catalogs must enumerate exactly the seed's
candidates (no behavior change), and the residual re-applies the filter
so results stay byte-equal either way.
"""
import numpy as np
import pytest

from repro.compiler import compile as C
from repro.compiler import splitter, tpch_ir
from repro.core import engine
from repro.core.plan import execute_push_plan, plan_signature
from repro.core.executor import compile_push_plan
from repro.queryproc import tpch
from repro.queryproc.expressions import Col

CAT = tpch.build_catalog(sf=1.0, num_nodes=2, rows_per_partition=4_000)
CCAT = tpch.build_catalog(sf=1.0, num_nodes=2, rows_per_partition=4_000,
                          cluster={"lineitem": "l_orderkey"})


# ------------------------------------------------------ catalog clustering
def test_clustered_partitions_align_to_key_runs():
    parts = [p.data for p in CCAT.partitions_of("lineitem")]
    keys = [p.cols["l_orderkey"] for p in parts]
    for k in keys:
        assert np.all(np.diff(k) >= 0)            # sorted within partition
    for a, b in zip(keys, keys[1:]):
        assert a[-1] < b[0]                       # no key spans a boundary
    # same multiset of rows as the unclustered catalog
    rows_c = sum(len(p) for p in parts)
    rows_u = sum(len(p.data) for p in CAT.partitions_of("lineitem"))
    assert rows_c == rows_u


def test_group_local_predicate():
    assert CCAT.group_local("lineitem", ("l_orderkey",))
    assert CCAT.group_local("lineitem", ("l_orderkey", "l_returnflag"))
    assert not CCAT.group_local("lineitem", ("l_partkey",))
    assert not CAT.group_local("lineitem", ("l_orderkey",))
    assert not CCAT.group_local("orders", ("o_orderkey",))


# ----------------------------------------------------- candidate frontiers
def test_unclustered_candidates_unchanged():
    sp = splitter.split(tpch_ir.build_ir("q18"))
    sigs = tuple(plan_signature(p) for p in sp.candidates["lineitem"])
    assert sigs == ("scan", "scan+agg")
    assert all(p.having is None for p in sp.candidates["lineitem"])


def test_clustered_adds_having_candidate():
    sp = splitter.split(tpch_ir.build_ir("q18"),
                        clustered={"lineitem": "l_orderkey"})
    sigs = tuple(plan_signature(p) for p in sp.candidates["lineitem"])
    assert sigs == ("scan", "scan+agg", "scan+agg+having")
    having_plan = sp.candidates["lineitem"][-1]
    assert having_plan.having is not None
    assert having_plan.agg is not None


def test_wrong_cluster_key_does_not_absorb():
    sp = splitter.split(tpch_ir.build_ir("q18"),
                        clustered={"lineitem": "l_partkey"})
    sigs = tuple(plan_signature(p) for p in sp.candidates["lineitem"])
    assert sigs == ("scan", "scan+agg")


# ----------------------------------------------------------- correctness
def _sorted_rows(t):
    cols = sorted(t.columns)
    order = np.lexsort([t.cols[c] for c in cols])
    return {c: t.cols[c][order] for c in cols}


def assert_results_equal(a, b, ctx=""):
    assert set(a.columns) == set(b.columns) and len(a) == len(b), ctx
    ra, rb = _sorted_rows(a), _sorted_rows(b)
    for c in ra:
        assert np.allclose(ra[c], rb[c], equal_nan=True), (ctx, c)


@pytest.mark.parametrize("mode", ["no_pushdown", "eager", "adaptive",
                                  "adaptive_pa"])
def test_q18_having_cut_byte_equal_to_maximal(mode):
    """Costed compile on the clustered catalog picks the HAVING frontier
    and still produces the same rows as the maximal (seed) frontier —
    under every engine mode (pushback replays the having plan too)."""
    cfg = engine.EngineConfig(mode=mode)
    cq = C.compile_query_costed("q18", CCAT)
    (choice,) = [c for c in cq.cut_report if c.table == "lineitem"]
    assert choice.signatures[choice.chosen] == "scan+agg+having"
    got = engine.run_query(cq.query, CCAT, cfg).result
    want = engine.run_query(C.compile_query("q18"), CCAT,
                            engine.EngineConfig(mode="adaptive")).result
    assert_results_equal(got, want, mode)


def test_q18_unclustered_choice_unchanged():
    cq = C.compile_query_costed("q18", CAT)
    (choice,) = [c for c in cq.cut_report if c.table == "lineitem"]
    assert "having" not in choice.signatures[choice.chosen]


def test_every_forced_cut_equal_on_clustered_catalog():
    """Each enumerated candidate (including the new having cut) executes
    to the same final rows when forced."""
    root = tpch_ir.build_ir("q18")
    clustered = {"lineitem": "l_orderkey"}
    probe = splitter.split(root, clustered=clustered)
    n = len(probe.candidates["lineitem"])
    assert n == 3
    base = None
    for k in range(n):
        cq = C.compile_ir(root, "q18", cuts={"lineitem": k},
                          clustered=clustered)
        run = engine.run_query(cq.query, CCAT,
                               engine.EngineConfig(mode="adaptive"))
        if base is None:
            base = run.result
        else:
            assert_results_equal(base, run.result, f"cut={k}")


def test_having_plan_batched_matches_reference():
    """The fused batch executor applies the HAVING filter identically to
    the per-partition reference interpreter."""
    sp = splitter.split(tpch_ir.build_ir("q18"),
                        clustered={"lineitem": "l_orderkey"})
    plan = sp.candidates["lineitem"][-1]
    assert plan.having is not None
    parts = [p.data for p in CCAT.partitions_of("lineitem")]
    want = [execute_push_plan(plan, p)[0] for p in parts]
    got, _aux = compile_push_plan(plan).execute_batch_parts(parts)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        assert g.columns == w.columns
        for c in g.columns:
            assert np.array_equal(g.cols[c], w.cols[c]), c


def test_having_reduces_estimated_s_out():
    sp = splitter.split(tpch_ir.build_ir("q18"),
                        clustered={"lineitem": "l_orderkey"})
    agg_plan, having_plan = sp.candidates["lineitem"][1:]
    part = CCAT.partitions_of("lineitem")[0]
    c_agg = compile_push_plan(agg_plan).estimate_cost(part)
    c_hav = compile_push_plan(having_plan).estimate_cost(part)
    assert c_hav.s_out < c_agg.s_out
    assert c_hav.s_in == c_agg.s_in


def test_having_filters_partials_at_storage():
    """Executed storage-side output really is HAVING-filtered: every
    shipped partial satisfies the predicate."""
    sp = splitter.split(tpch_ir.build_ir("q18"),
                        clustered={"lineitem": "l_orderkey"})
    plan = sp.candidates["lineitem"][-1]
    parts = [p.data for p in CCAT.partitions_of("lineitem")]
    merged = compile_push_plan(plan).execute_batch(parts)
    assert len(merged) > 0
    assert np.all(merged.cols["sum_qty"] > 150.0)
    # and the shipped groups equal the HAVING-filtered global aggregate
    # (clustered => partition-local groups => partials ARE finals)
    import collections
    totals = collections.defaultdict(float)
    for p in parts:
        for k, v in zip(p.cols["l_orderkey"], p.cols["l_quantity"]):
            totals[int(k)] += float(v)
    want = sorted(k for k, v in totals.items() if v > 150.0)
    assert sorted(int(k) for k in merged.cols["l_orderkey"]) == want
